//! Property-based integration tests over the engine + comm substrate
//! (in-tree harness: `dpsnn::util::prop`).

use std::collections::HashMap;

use dpsnn::comm::aer::{decode_spikes, encode_spikes};
use dpsnn::config::NetworkParams;
use dpsnn::engine::partition::Partition;
use dpsnn::engine::spike::Spike;
use dpsnn::model::connectivity::{ConnectivityParams, IncomingSynapses};
use dpsnn::util::prop::forall;

#[test]
fn every_synapse_delivered_exactly_once_across_any_partition() {
    // For random networks and partitions: firing every neuron once must
    // deliver exactly n*m synaptic events, each to the rank owning its
    // target — no loss, no duplication, regardless of P.
    forall("exactly-once delivery", 20, |rng| {
        let n = 32 + rng.next_below(200);
        let m = 1 + rng.next_below(24);
        let p = 1 + rng.next_below(9);
        let cp = ConnectivityParams {
            seed: rng.next_u64(),
            n,
            m,
            dmin: 1,
            dmax: 8,
        };
        let part = Partition::even(n, p);
        let mut delivered: u64 = 0;
        let mut per_target: HashMap<(u32, u32), u32> = HashMap::new();
        for r in 0..p {
            let (lo, hi) = part.range(r);
            let inc = IncomingSynapses::build(&cp, lo, hi);
            for s in 0..n {
                let (tgts, _) = inc.row(s);
                delivered += tgts.len() as u64;
                for &t in tgts {
                    assert!(t + lo >= lo && t + lo < hi, "target outside rank");
                    *per_target.entry((s, t + lo)).or_default() += 1;
                }
            }
        }
        assert_eq!(delivered, n as u64 * m as u64);
        // cross-check against the generator's own view
        for s in (0..n).step_by(17) {
            let mut expect: HashMap<u32, u32> = HashMap::new();
            for (t, _) in cp.targets_of(s) {
                *expect.entry(t).or_default() += 1;
            }
            for (t, c) in expect {
                assert_eq!(
                    per_target.get(&(s, t)).copied().unwrap_or(0),
                    c,
                    "source {s} target {t}"
                );
            }
        }
    });
}

#[test]
fn aer_wire_format_fuzz() {
    forall("aer fuzz", 100, |rng| {
        let n = rng.next_below(500) as usize;
        let spikes: Vec<Spike> = (0..n)
            .map(|_| Spike::new(rng.next_u64() as u32, rng.next_below(1 << 20)))
            .collect();
        let mut wire = Vec::new();
        encode_spikes(&spikes, 1.0, &mut wire);
        assert_eq!(wire.len(), 12 * n, "paper: 12 bytes per spike");
        let mut back = Vec::new();
        decode_spikes(&wire, 1.0, &mut back).unwrap();
        assert_eq!(back, spikes);
    });
}

#[test]
fn partition_owner_total_and_weighted_consistency() {
    forall("partition consistency", 100, |rng| {
        let p = 1 + rng.next_below(32);
        let n = p + rng.next_below(5000);
        let part = Partition::even(n, p);
        // contiguity + coverage via boundary sampling
        let mut covered = 0u32;
        for r in 0..p {
            let (lo, hi) = part.range(r);
            assert!(lo < hi);
            covered += hi - lo;
            assert_eq!(part.owner(lo), r);
            assert_eq!(part.owner(hi - 1), r);
        }
        assert_eq!(covered, n);
    });
}

#[test]
fn network_rate_is_stable_across_partitioning_of_paper_family() {
    // The dynamics (not just plumbing): a driven mid-size network must
    // produce a plausible, partition-independent rate.
    let net = NetworkParams::tiny(2048);
    let run = |p: u32| {
        let mut cfg = dpsnn::config::RunConfig::default();
        cfg.net = net.clone();
        cfg.procs = p;
        cfg.sim_seconds = 0.5;
        dpsnn::coordinator::run(&cfg).unwrap()
    };
    let r1 = run(1);
    let r4 = run(4);
    assert_eq!(r1.total_spikes, r4.total_spikes);
    assert!(
        r1.mean_rate_hz > 0.1 && r1.mean_rate_hz < 50.0,
        "rate {} implausible",
        r1.mean_rate_hz
    );
}
