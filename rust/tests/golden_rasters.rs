//! Golden-raster regression corpus.
//!
//! A small preset matrix — both routing modes, flat + `tree:2,2`
//! topologies, step + min-delay cadence, materialized + procedural
//! connectivity — is run live and its rasters pinned as SHA-256 digests
//! in `rust/tests/data/golden_rasters.txt`. Any future change that
//! silently moves spike output fails here loudly instead of only when a
//! property test happens to cover the changed axis.
//!
//! Pin lifecycle: on the first run (no pins file) the digests are
//! written — bootstrap mode, because the build host is the only place
//! the crate can execute. Once the file exists it is enforced; CI runs
//! this test target twice so the enforce path is always exercised. To
//! intentionally re-baseline after a physics change, delete the pins
//! file and commit the regenerated one.
//!
//! Independent of the pins, two invariants always hold in-process:
//! every matrix config varies only raster-preserving axes, so ALL
//! digests must be identical to each other; and running any config
//! twice must reproduce its digest exactly.

use std::path::PathBuf;

use dpsnn::config::{
    ConnectivityMode, ExchangeCadence, LeaderRotation, NetworkParams, Routing, RunConfig,
    Topology, TreeShape,
};
use dpsnn::coordinator;
use dpsnn::metrics::raster_hash;

/// The common physics: every config shares this network (including
/// `delay_min_steps`, part of the delay draw), seed, procs and duration,
/// and varies only axes the determinism contract says preserve rasters.
fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(512);
    cfg.net.delay_min_steps = 4.min(cfg.net.delay_max_steps).max(1);
    cfg.procs = 4;
    cfg.sim_seconds = 0.2;
    cfg
}

/// (key, config) preset matrix. Keys are stable identifiers used in the
/// pins file — do not rename without re-baselining.
fn matrix() -> Vec<(&'static str, RunConfig)> {
    let tree22 = Topology::Tree(TreeShape::new(&[2, 2]).unwrap());
    let mut out = Vec::new();

    let cfg = base_cfg();
    out.push(("flat-filtered-step-mat", cfg));

    let mut cfg = base_cfg();
    cfg.routing = Routing::Broadcast;
    out.push(("flat-broadcast-step-mat", cfg));

    let mut cfg = base_cfg();
    cfg.exchange_every = ExchangeCadence::MinDelay;
    out.push(("flat-filtered-mindelay-mat", cfg));

    let mut cfg = base_cfg();
    cfg.topology = tree22;
    cfg.leader_rotation = LeaderRotation::RoundRobin;
    out.push(("tree22-filtered-step-mat", cfg));

    let mut cfg = base_cfg();
    cfg.topology = tree22;
    cfg.routing = Routing::Broadcast;
    cfg.exchange_every = ExchangeCadence::MinDelay;
    out.push(("tree22-broadcast-mindelay-mat", cfg));

    let mut cfg = base_cfg();
    cfg.connectivity = ConnectivityMode::Procedural;
    out.push(("flat-filtered-step-proc", cfg));

    let mut cfg = base_cfg();
    cfg.connectivity = ConnectivityMode::Procedural;
    cfg.routing = Routing::Broadcast;
    cfg.exchange_every = ExchangeCadence::MinDelay;
    out.push(("flat-broadcast-mindelay-proc", cfg));

    let mut cfg = base_cfg();
    cfg.connectivity = ConnectivityMode::Procedural;
    cfg.topology = tree22;
    cfg.exchange_every = ExchangeCadence::MinDelay;
    out.push(("tree22-filtered-mindelay-proc", cfg));

    for (k, cfg) in &out {
        cfg.validate().unwrap_or_else(|e| panic!("{k}: {e}"));
    }
    out
}

fn pins_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/golden_rasters.txt")
}

fn parse_pins(text: &str) -> Vec<(String, String)> {
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .filter_map(|l| {
            let (k, v) = l.split_once('=')?;
            Some((k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

#[test]
fn golden_raster_corpus() {
    let matrix = matrix();
    let digests: Vec<(String, String)> = matrix
        .iter()
        .map(|(key, cfg)| {
            let r = coordinator::run(cfg).unwrap_or_else(|e| panic!("{key}: {e}"));
            (key.to_string(), raster_hash(&r.pop_counts))
        })
        .collect();

    // Invariant 1 (pin-independent): these axes are raster-preserving,
    // so every config must produce the SAME raster.
    let reference = &digests[0].1;
    for (key, d) in &digests {
        assert_eq!(
            d, reference,
            "{key} diverged from {} — a raster-preserving axis moved the raster",
            digests[0].0
        );
    }

    // Invariant 2: re-running one config reproduces its digest.
    let (key0, cfg0) = &matrix[0];
    let again = coordinator::run(cfg0).unwrap();
    assert_eq!(
        raster_hash(&again.pop_counts),
        *reference,
        "{key0} is not reproducible within one process"
    );

    // Pins: enforce when present, bootstrap otherwise.
    let path = pins_path();
    match std::fs::read_to_string(&path) {
        Ok(text) => {
            let pins = parse_pins(&text);
            assert!(!pins.is_empty(), "pins file {} is empty", path.display());
            for (key, hash) in &pins {
                match digests.iter().find(|(k, _)| k == key) {
                    Some((_, d)) => assert_eq!(
                        d, hash,
                        "golden raster changed for {key} — if intentional, delete {} and \
                         commit the regenerated pins",
                        path.display()
                    ),
                    None => panic!(
                        "pinned config {key} is gone from the matrix — re-baseline {}",
                        path.display()
                    ),
                }
            }
            for (key, _) in &digests {
                assert!(
                    pins.iter().any(|(k, _)| k == key),
                    "matrix config {key} has no pin — delete {} to re-baseline",
                    path.display()
                );
            }
        }
        Err(_) => {
            // Bootstrap: first run on this checkout pins the corpus.
            let mut text = String::from(
                "# Golden raster digests (SHA-256 of per-step population spike counts).\n\
                 # Written by rust/tests/golden_rasters.rs on first run; enforced once\n\
                 # present. Delete this file to re-baseline after an intentional\n\
                 # physics change.\n",
            );
            for (key, d) in &digests {
                text.push_str(&format!("{key} = {d}\n"));
            }
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir).unwrap();
            }
            std::fs::write(&path, text).unwrap();
            eprintln!("bootstrapped golden raster pins at {}", path.display());
        }
    }
}
