//! Materialized-vs-procedural equivalence oracle.
//!
//! `--connectivity procedural` regenerates each firing source's
//! incoming row from the stateless connectome instead of indexing a
//! prebuilt CSR table, and queues it in compressed per-delay buckets
//! instead of the dense delay grid. None of that may be observable in
//! the physics: the raster must stay *bitwise identical* to the
//! materialized reference across partition policies, topologies,
//! exchange cadences, thread counts and process counts. These tests
//! are the lockdown; the pure-connectome property tests underneath
//! them pin the generator the procedural mode leans on.

use dpsnn::config::{
    ConnectivityMode, ExchangeCadence, Mode, NetworkParams, PartitionPolicy, RunConfig, Topology,
};
use dpsnn::coordinator::{self, RunResult};
use dpsnn::engine::partition::OwnedGids;
use dpsnn::metrics::memory;
use dpsnn::model::connectivity::{ConnectivityParams, IncomingSynapses, ProceduralSynapses};
use dpsnn::util::prop::forall;

fn base(n: u32, procs: u32, seconds: f64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(n);
    cfg.procs = procs;
    cfg.sim_seconds = seconds;
    cfg.seed = 42;
    cfg.mode = Mode::Live;
    cfg
}

/// Run the same config under both connectivity modes.
fn run_pair(mut cfg: RunConfig) -> (RunResult, RunResult) {
    cfg.connectivity = ConnectivityMode::Materialized;
    let mat = coordinator::run(&cfg).unwrap();
    cfg.connectivity = ConnectivityMode::Procedural;
    let pro = coordinator::run(&cfg).unwrap();
    (mat, pro)
}

fn assert_identical(mat: &RunResult, pro: &RunResult, label: &str) {
    assert!(mat.total_spikes > 0, "{label}: reference run was silent");
    assert_eq!(mat.pop_counts, pro.pop_counts, "{label}: raster diverged");
    assert_eq!(mat.total_spikes, pro.total_spikes, "{label}");
    assert_eq!(mat.total_syn_events, pro.total_syn_events, "{label}");
    assert_eq!(mat.total_ext_events, pro.total_ext_events, "{label}");
    assert_eq!(mat.total_exc_spikes, pro.total_exc_spikes, "{label}");
    assert_eq!(
        mat.rank_spikes, pro.rank_spikes,
        "{label}: per-rank spike placement diverged"
    );
}

#[test]
fn equivalent_across_partition_policies_and_process_counts() {
    for policy in [
        PartitionPolicy::Index,
        PartitionPolicy::RoundRobin,
        PartitionPolicy::GreedyComms,
    ] {
        for procs in [1u32, 3, 4, 8] {
            let mut cfg = base(512, procs, 0.3);
            cfg.partition = policy;
            let (mat, pro) = run_pair(cfg);
            assert_identical(&mat, &pro, &format!("{policy} P={procs}"));
        }
    }
}

#[test]
fn equivalent_across_topologies_and_cadences() {
    // tree:2,2 at P=6 is the ragged case: the last chassis is missing
    // half its boards, so leader election and chunk geometry differ
    // from the full tree.
    for (topo, procs) in [("nodes:2", 4u32), ("tree:2,2", 8), ("tree:2,2", 6)] {
        for cadence in [ExchangeCadence::Step, ExchangeCadence::MinDelay] {
            let mut cfg = base(512, procs, 0.3);
            // widen the min delay so min-delay batching really batches
            cfg.net.delay_min_steps = 4;
            cfg.topology = topo.parse::<Topology>().unwrap();
            cfg.exchange_every = cadence;
            let (mat, pro) = run_pair(cfg);
            assert_identical(&mat, &pro, &format!("{topo} P={procs} {cadence}"));
        }
    }
}

#[test]
fn equivalent_across_compute_threads() {
    let reference = {
        let mut cfg = base(512, 2, 0.3);
        cfg.compute_threads = 1;
        coordinator::run(&cfg).unwrap()
    };
    for threads in [1u32, 2, 4] {
        let mut cfg = base(512, 2, 0.3);
        cfg.compute_threads = threads;
        let (mat, pro) = run_pair(cfg);
        assert_identical(&mat, &pro, &format!("threads={threads}"));
        assert_eq!(
            reference.pop_counts, pro.pop_counts,
            "threads={threads}: threading must not show in the raster"
        );
    }
}

#[test]
fn measured_resident_bytes_match_the_closed_forms() {
    let net = NetworkParams::tiny(512);
    let (n, m, n_local) = (512u32, net.syn_per_neuron, 256u32);
    let (mat, pro) = run_pair(base(n, 2, 0.2));
    assert_eq!(mat.connectivity, ConnectivityMode::Materialized);
    assert_eq!(pro.connectivity, ConnectivityMode::Procedural);
    assert_eq!(mat.memory.len(), 2);
    assert_eq!(pro.memory.len(), 2);
    for mem in &mat.memory {
        // expected table size is stochastic around the closed form
        let closed = memory::materialized_synapse_bytes(n, m, n_local) as f64;
        let meas = mem.synapse_bytes as f64;
        assert!(
            (meas - closed).abs() <= 0.15 * closed,
            "materialized table {meas} B vs closed form {closed} B"
        );
        // the dense ring's size is exact, and materialized mode keeps
        // no regeneration scratch
        assert_eq!(
            mem.ring_bytes,
            memory::dense_ring_bytes(n_local, net.delay_max_steps)
        );
        assert_eq!(mem.scratch_bytes, 0);
    }
    for mem in &pro.memory {
        // index placement -> one owned interval -> the formula is exact
        assert_eq!(mem.synapse_bytes, memory::procedural_synapse_bytes(1));
        assert!(
            mem.ring_bytes
                >= memory::compressed_ring_bytes_idle(n_local, net.delay_max_steps, 1),
            "compressed ring below its idle floor"
        );
        memory::assert_procedural_state_bound(mem, m, n_local);
    }
    let worst_pro = pro.max_rank_memory_bytes();
    let worst_mat = mat.max_rank_memory_bytes();
    assert!(
        worst_pro < worst_mat,
        "procedural rank resident {worst_pro} B not below materialized {worst_mat} B"
    );
}

#[test]
fn connectome_generator_properties() {
    forall("synapse(s,k) invariants", 40, |rng| {
        let n = 50 + rng.next_below(400);
        let m = 1 + rng.next_below((n / 4).max(2));
        let dmax = 1 + rng.next_below(16);
        let dmin = 1 + rng.next_below(dmax);
        let cp = ConnectivityParams { seed: rng.next_u64(), n, m, dmin, dmax };
        let s = rng.next_below(n);
        // targets_of agrees with per-key enumeration; every synapse is
        // in range, never a self-connection, delay within [dmin, dmax]
        let row = cp.targets_of(s);
        assert_eq!(row.len(), m as usize);
        for (k, &(t, d)) in row.iter().enumerate() {
            assert!(t < n && t != s, "target {t} out of range for s={s}");
            assert!((d as u32) >= dmin && (d as u32) <= dmax, "delay {d}");
            assert_eq!((t, d), cp.synapse(s, k as u32), "stateless regen");
        }
        // however the network is split, source s lands exactly m local
        // synapses in total across all ranks
        let p = 1 + rng.next_below(6);
        let mut total = 0usize;
        for r in 0..p {
            let lo = (n as u64 * r as u64 / p as u64) as u32;
            let hi = (n as u64 * (r as u64 + 1) / p as u64) as u32;
            if lo == hi {
                continue;
            }
            let inc = IncomingSynapses::build(&cp, lo, hi);
            total += inc.row(s).0.len();
        }
        assert_eq!(total, m as usize, "split into {p} ranks lost synapses");
    });
}

#[test]
fn row_regeneration_matches_the_table_on_permuted_ownership() {
    forall("row_into == build_owned rows", 25, |rng| {
        let n = 120 + rng.next_below(200);
        let m = 1 + rng.next_below(n / 5);
        let dmax = 1 + rng.next_below(12);
        let cp = ConnectivityParams { seed: rng.next_u64(), n, m, dmin: 1, dmax };
        // a two-interval ownership, as a round-robin or greedy
        // placement would hand a rank
        let a = 1 + rng.next_below(n / 3);
        let lo2 = a + 1 + rng.next_below(n / 3);
        let hi2 = lo2 + 1 + rng.next_below(n - lo2);
        let owned = OwnedGids::from_intervals(vec![(0, a), (lo2, hi2)]);
        let table = IncomingSynapses::build_owned(&cp, &owned);
        let ps = ProceduralSynapses::new(cp, owned);
        let (mut tgt, mut dl) = (Vec::new(), Vec::new());
        let mut scratch: Vec<(u8, u32)> = Vec::new();
        for s in 0..n {
            tgt.clear();
            dl.clear();
            let len = ps.row_into(s, &mut tgt, &mut dl, &mut scratch);
            let (tt, td) = table.row(s);
            assert_eq!(len, tt.len(), "row length diverged at s={s}");
            assert_eq!(&tgt[..], tt, "targets diverged at s={s}");
            assert_eq!(&dl[..], td, "delays diverged at s={s}");
        }
        // two intervals, still O(state)
        assert_eq!(ps.resident_bytes() as u64, memory::procedural_synapse_bytes(2));
    });
}
