//! Transport-volume acceptance test for destination-filtered routing:
//! on the paper's default 20480-neuron network at P=8 live ranks, the
//! filtered protocol must deliver strictly fewer payload bytes per rank
//! than broadcast while producing the bitwise-identical spike raster.
//!
//! With the default connectivity (M = 1125 >> P = 8) the pair filter
//! degenerates to broadcast — every source projects into every rank —
//! so the reduction here comes from eliminating the transport loopback;
//! the sparse-network tests in `determinism.rs` exercise the pair-level
//! filtering. The simulated window is kept short: the per-rank synapse
//! build, not the stepping, dominates this test's runtime.

use dpsnn::config::{Mode, Routing, RunConfig};
use dpsnn::coordinator;

fn run(routing: Routing) -> coordinator::RunResult {
    let mut cfg = RunConfig::default(); // default net = paper 20480N
    cfg.procs = 8;
    cfg.sim_seconds = 0.05;
    cfg.mode = Mode::Live;
    cfg.routing = routing;
    coordinator::run(&cfg).unwrap()
}

#[test]
fn p8_default_network_filtered_receives_fewer_bytes() {
    let filtered = run(Routing::Filtered);
    let broadcast = run(Routing::Broadcast);
    assert!(filtered.total_spikes > 0, "network must be active");

    // identical physics under both protocols
    assert_eq!(filtered.pop_counts, broadcast.pop_counts);
    assert_eq!(filtered.total_spikes, broadcast.total_spikes);
    assert_eq!(filtered.total_syn_events, broadcast.total_syn_events);

    // strictly fewer received bytes — per rank and in total
    assert_eq!(filtered.comm_volume.len(), 8);
    let mut total_f = 0u64;
    let mut total_b = 0u64;
    for (rank, (f, b)) in filtered
        .comm_volume
        .iter()
        .zip(&broadcast.comm_volume)
        .enumerate()
    {
        assert!(
            f.bytes_recv < b.bytes_recv,
            "rank {rank}: filtered {} !< broadcast {}",
            f.bytes_recv,
            b.bytes_recv
        );
        assert!(f.bytes_sent <= b.bytes_sent, "rank {rank} sent more");
        total_f += f.bytes_recv;
        total_b += b.bytes_recv;
    }
    assert!(total_f < total_b);

    // broadcast receive volume is exactly P copies of the spike stream
    // (12 B/spike from each of the 8 ranks including the loopback).
    assert_eq!(total_b, broadcast.total_spikes * 12 * 8);
    // filtered drops at least the loopback copy
    assert!(total_f <= broadcast.total_spikes * 12 * 7);
}
