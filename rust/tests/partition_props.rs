//! Property-based tests for the placement layer: every allocator policy
//! must cover every neuron exactly once with every rank non-empty —
//! including ragged topology trees and weighted heterogeneous splits —
//! and the live simulation's spike-count/rate invariants must hold
//! across `--partition` × `--topology` × `--exchange-every`.
//!
//! Placement permutes neuron→rank ownership, so (unlike the routing,
//! cadence and topology axes, which are checked raster-bitwise against
//! a fixed partition) the cross-policy contract is stated on
//! partition-independent observables: the whole-population per-step
//! raster, the exc/inh spike split and the per-rank spike totals'
//! conservation. Because connectivity, stimulus and initial state are
//! pure functions of global ids, those are in fact bitwise equalities.

use std::collections::HashMap;

use dpsnn::comm::TopologyTree;
use dpsnn::config::{
    ExchangeCadence, NetworkParams, PartitionPolicy, RunConfig, Topology,
};
use dpsnn::engine::{AllocContext, Partition};
use dpsnn::model::connectivity::ConnectivityParams;
use dpsnn::util::prop::forall;

const POLICIES: [PartitionPolicy; 3] = [
    PartitionPolicy::Index,
    PartitionPolicy::RoundRobin,
    PartitionPolicy::GreedyComms,
];

/// Exactly-once coverage with no starved rank, for one placement.
fn assert_covers(part: &Partition, n: u32, p: u32, what: &str) {
    assert_eq!(part.n_total(), n, "{what}");
    assert_eq!(part.n_ranks(), p, "{what}");
    let mut seen = vec![false; n as usize];
    let mut total = 0u32;
    for r in 0..p {
        let owned = part.owned(r);
        assert!(!owned.is_empty(), "{what}: rank {r} got no neurons");
        total += owned.len();
        for gid in owned.iter() {
            assert!(gid < n, "{what}: gid {gid} out of range");
            assert!(!seen[gid as usize], "{what}: gid {gid} owned twice");
            seen[gid as usize] = true;
            assert_eq!(part.owner(gid), r, "{what}: owner({gid})");
            assert_eq!(part.try_owner(gid), Some(r), "{what}");
            assert_eq!(owned.gid_of(owned.local_of(gid)), gid, "{what}");
        }
    }
    assert_eq!(total, n, "{what}: sizes must sum to n");
    assert!(seen.iter().all(|&s| s), "{what}: some gid unowned");
}

#[test]
fn every_policy_covers_every_neuron_exactly_once() {
    forall("placement coverage", 40, |rng| {
        let p = 1 + rng.next_below(12);
        let n = p + rng.next_below(3000);
        let cp = ConnectivityParams {
            seed: rng.next_u64(),
            n,
            m: 1 + rng.next_below(8),
            dmin: 1,
            dmax: 4,
        };
        // random, usually ragged, tree over the ranks (k1 rarely
        // divides p): placement must stay a bijection regardless
        let shape = [1 + rng.next_below(4), 1 + rng.next_below(3)];
        let tree = TopologyTree::new(p, &shape);
        let ctx = AllocContext { connectivity: Some(&cp), tree: Some(&tree) };
        for policy in POLICIES {
            let part = Partition::allocate(policy, n, p, &ctx);
            assert_covers(
                &part,
                n,
                p,
                &format!("{policy:?} n={n} p={p} shape={shape:?}"),
            );
        }
    });
}

#[test]
fn index_and_round_robin_need_no_context() {
    // The context-free policies must also work without connectivity or
    // tree (the greedy policy documents its panic instead).
    forall("context-free placement", 25, |rng| {
        let p = 1 + rng.next_below(9);
        let n = p + rng.next_below(800);
        for policy in [PartitionPolicy::Index, PartitionPolicy::RoundRobin] {
            let part = Partition::allocate(policy, n, p, &AllocContext::empty());
            assert_covers(&part, n, p, &format!("{policy:?} n={n} p={p}"));
        }
        // index reproduces the historical contiguous split exactly
        let index =
            Partition::allocate(PartitionPolicy::Index, n, p, &AllocContext::empty());
        assert_eq!(index, Partition::even(n, p));
    });
}

#[test]
fn weighted_hetero_splits_cover_and_respect_boundaries() {
    forall("weighted coverage", 25, |rng| {
        let p = 2 + rng.next_below(7);
        let n = 4 * p + rng.next_below(2000);
        let weights: Vec<f64> = (0..p).map(|_| 0.5 + rng.next_f64() * 9.5).collect();
        let part = Partition::weighted(n, &weights);
        assert_covers(&part, n, p, &format!("weighted n={n} p={p}"));
        // contiguous by construction: range() must be usable
        let mut next = 0u32;
        for r in 0..p {
            let (lo, hi) = part.range(r);
            assert_eq!(lo, next, "weighted ranges must tile in order");
            next = hi;
        }
        assert_eq!(next, n);
    });
}

#[test]
fn boundary_gids_resolve_and_past_the_end_is_rejected() {
    let tree = TopologyTree::new(5, &[2]);
    let cp = ConnectivityParams { seed: 3, n: 333, m: 2, dmin: 1, dmax: 4 };
    let ctx = AllocContext { connectivity: Some(&cp), tree: Some(&tree) };
    for policy in POLICIES {
        let part = Partition::allocate(policy, 333, 5, &ctx);
        // first and last gid resolve under every policy
        let _ = part.owner(0);
        let _ = part.owner(332);
        assert!(part.try_owner(332).is_some());
        assert_eq!(part.try_owner(333), None, "{policy:?}");
        assert_eq!(part.try_owner(u32::MAX), None, "{policy:?}");
        let part2 = part.clone();
        let panics = std::panic::catch_unwind(move || part2.owner(333));
        assert!(panics.is_err(), "{policy:?}: owner(n) must panic");
    }
}

/// Run the tiny live network under one (policy, topology, cadence)
/// combination and return the partition-independent observables.
fn observables(
    policy: PartitionPolicy,
    topology: Topology,
    cadence: ExchangeCadence,
) -> (Vec<u32>, u64, u64, u64, Vec<u64>) {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(384);
    cfg.net.delay_min_steps = 4;
    cfg.procs = 4;
    cfg.sim_seconds = 0.1;
    cfg.partition = policy;
    cfg.topology = topology;
    cfg.exchange_every = cadence;
    let r = dpsnn::coordinator::run(&cfg).unwrap();
    assert_eq!(r.partition, policy);
    (
        r.pop_counts,
        r.total_spikes,
        r.total_exc_spikes,
        r.total_syn_events,
        r.rank_spikes,
    )
}

#[test]
fn spike_invariants_hold_across_partition_topology_and_cadence() {
    // 3 policies x 2 topologies x 2 cadences = 12 live runs of the same
    // physics: per-step population raster, total/excitatory spike
    // counts and synaptic-event totals must all be identical; per-rank
    // spike totals permute but always conserve the population sum.
    let topologies = [Topology::Flat, "tree:2".parse::<Topology>().unwrap()];
    let cadences = [ExchangeCadence::Step, ExchangeCadence::MinDelay];
    let (base_pop, base_spikes, base_exc, base_syn, _) =
        observables(PartitionPolicy::Index, Topology::Flat, ExchangeCadence::Step);
    assert!(base_spikes > 0, "network must be active");
    assert!(base_exc > 0 && base_exc < base_spikes, "both populations fire");
    // The placement (and so the per-rank spike split) is a function of
    // (policy, topology) only — greedy-comms reads the tree's link
    // costs, so its split may legitimately differ across topologies,
    // but the cadence must never move a neuron.
    let mut splits: HashMap<String, Vec<u64>> = HashMap::new();
    for policy in POLICIES {
        for topology in topologies {
            for cadence in cadences {
                let (pop, spikes, exc, syn, ranks) =
                    observables(policy, topology, cadence);
                let tag = format!("{policy:?}/{topology}/{cadence}");
                assert_eq!(pop, base_pop, "{tag}: raster changed");
                assert_eq!(spikes, base_spikes, "{tag}");
                assert_eq!(exc, base_exc, "{tag}: exc/inh split changed");
                assert_eq!(syn, base_syn, "{tag}: synaptic events changed");
                assert_eq!(ranks.iter().sum::<u64>(), base_spikes, "{tag}");
                assert_eq!(ranks.len(), 4, "{tag}");
                let prev = splits
                    .entry(format!("{policy:?}/{topology}"))
                    .or_insert_with(|| ranks.clone());
                assert_eq!(*prev, ranks, "{tag}: cadence changed the placement");
            }
        }
    }
    // and the scattering policy really does move neurons off the
    // contiguous split (a placement-level fact, independent of rates)
    let ctx = AllocContext::empty();
    assert_ne!(
        Partition::allocate(PartitionPolicy::RoundRobin, 384, 4, &ctx),
        Partition::allocate(PartitionPolicy::Index, 384, 4, &ctx),
    );
}
