//! Self-tuning acceptance tests: `--topology auto` (and friends) must
//! resolve through the analytic planner without touching the physics —
//! the raster stays bitwise identical to the flat reference across
//! routing protocols, exchange cadences and process counts, the result
//! records the resolved axes so any auto run is exactly replayable with
//! explicit flags, and the online re-planner switches cadence within
//! three windows of an injected regime shift without changing the
//! raster.

use std::sync::Arc;

use dpsnn::config::{
    AutoAxes, ExchangeCadence, LeaderRotation, Mode, NetworkParams, Routing, RunConfig,
};
use dpsnn::coordinator::live::run_live_with;
use dpsnn::coordinator::{self, OnlineReplanner, RunResult};

fn cfg(procs: u32, routing: Routing, cadence: ExchangeCadence) -> RunConfig {
    let mut c = RunConfig::default();
    c.net = NetworkParams::tiny(512);
    c.net.syn_per_neuron = 24; // sparse enough for pair filtering at P=8
    c.net.delay_min_steps = 4;
    c.procs = procs;
    c.sim_seconds = 0.15;
    c.seed = 2026;
    c.mode = Mode::Live;
    c.routing = routing;
    c.exchange_every = cadence;
    c
}

/// Re-run an auto-resolved result with its recorded concrete axes and
/// no auto flags — the replayability contract.
fn replay_explicit(base: &RunConfig, r: &RunResult) -> RunResult {
    let mut c = base.clone();
    c.auto = AutoAxes::default();
    c.topology = r.topology;
    c.exchange_every = r.exchange_every;
    c.leader_rotation = r.leader_rotation;
    c.compute_threads = r.compute_threads;
    coordinator::run(&c).unwrap()
}

#[test]
fn auto_topology_raster_is_bitwise_identical() {
    // routing × cadence × P: every all-auto run must match the flat
    // single-rank per-step reference raster bitwise, and its recorded
    // resolution must replay to the identical result.
    for &routing in &[Routing::Broadcast, Routing::Filtered] {
        let reference = coordinator::run(&cfg(1, routing, ExchangeCadence::Step)).unwrap();
        assert!(reference.total_spikes > 0, "network must be active");
        for &cadence in &[ExchangeCadence::Step, ExchangeCadence::MinDelay] {
            for &procs in &[1u32, 2, 4, 8] {
                let mut auto_cfg = cfg(procs, routing, cadence);
                auto_cfg.auto.topology = true;
                auto_cfg.auto.leader_rotation = true;
                auto_cfg.auto.compute_threads = true;
                let run = coordinator::run(&auto_cfg).unwrap();
                let tag = format!(
                    "P={procs} routing={routing} cadence={cadence} -> {}",
                    run.topology
                );
                assert_eq!(run.pop_counts, reference.pop_counts, "raster diverged: {tag}");
                assert_eq!(run.total_spikes, reference.total_spikes, "{tag}");
                assert_eq!(run.total_syn_events, reference.total_syn_events, "{tag}");
                assert!(run.auto.topology, "{tag}: auto flags must survive as metadata");
                // the recorded resolution replays bitwise
                let replay = replay_explicit(&auto_cfg, &run);
                assert_eq!(replay.pop_counts, run.pop_counts, "replay diverged: {tag}");
                assert_eq!(replay.topology, run.topology, "{tag}");
                assert!(!replay.auto.any(), "{tag}: explicit replay has no auto axes");
                assert!(replay.replans.is_empty(), "{tag}: no re-planner without auto");
            }
        }
    }
}

#[test]
fn all_auto_result_records_resolved_axes() {
    // Every axis on auto: the result must carry concrete post-planner
    // values (never a sentinel) plus the auto flags, and a modeled run
    // of the same config resolves to the same topology/cadence pick —
    // the planner is deterministic and mode-independent.
    let mut auto_cfg = cfg(8, Routing::Filtered, ExchangeCadence::Step);
    auto_cfg.auto.topology = true;
    auto_cfg.auto.exchange_every = true;
    auto_cfg.auto.leader_rotation = true;
    auto_cfg.auto.compute_threads = true;
    let live = coordinator::run(&auto_cfg).unwrap();
    assert!(live.auto.any());
    assert!((1..=256).contains(&live.compute_threads));
    // the summary names the resolved values
    let s = live.summary();
    assert!(s.contains("auto ["), "{s}");
    assert!(
        s.contains("topology") && s.contains("cadence") && s.contains("rotation"),
        "{s}"
    );
    let mut modeled_cfg = auto_cfg.clone();
    modeled_cfg.mode = Mode::Modeled;
    let modeled = coordinator::run(&modeled_cfg).unwrap();
    assert_eq!(modeled.topology, live.topology, "planner pick depends on mode");
    assert_eq!(
        modeled.exchange_every, live.exchange_every,
        "cadence pick depends on mode"
    );
}

#[test]
fn online_controller_switches_within_three_windows() {
    // Inject a regime shift by pinning the crossover threshold to each
    // extreme: the controller must cross over from the opposite
    // starting cadence at the first window boundary (well inside the
    // 3-window acceptance bound) and the raster must stay bitwise
    // identical to the static run either way.
    let base = cfg(4, Routing::Filtered, ExchangeCadence::MinDelay);
    let reference = coordinator::run(&base).unwrap();
    assert!(reference.total_spikes > 0, "network must be active");

    let run_with = |cadence: ExchangeCadence, crossover: f64| -> RunResult {
        let mut c = cfg(4, Routing::Filtered, cadence);
        c.auto.exchange_every = true;
        c.auto.leader_rotation = true;
        let rp = OnlineReplanner::from_config(&c)
            .unwrap()
            .with_crossover_bytes(crossover);
        run_live_with(&c, Some(Arc::new(rp))).unwrap()
    };

    // crossover 0: every payload reads as bandwidth-bound (the SWA
    // burst side) -> drop from min-delay batching to per-step.
    let to_step = run_with(ExchangeCadence::MinDelay, 0.0);
    assert_eq!(to_step.pop_counts, reference.pop_counts, "re-plan changed the raster");
    let first = to_step.replans.first().expect("controller never re-planned");
    assert!(first.window <= 2, "switched only at window {}", first.window);
    assert_eq!(first.epoch_steps, 1);

    // crossover ∞: nothing is ever bandwidth-bound (the quiet AW side)
    // -> stretch from per-step to the full min-delay window.
    let to_epoch = run_with(ExchangeCadence::Step, f64::INFINITY);
    assert_eq!(to_epoch.pop_counts, reference.pop_counts, "re-plan changed the raster");
    let first = to_epoch.replans.first().expect("controller never re-planned");
    assert!(first.window <= 2, "switched only at window {}", first.window);
    assert_eq!(first.epoch_steps, 4);
    assert_eq!(first.rotation, LeaderRotation::Fixed, "flat has no leaders");
}
