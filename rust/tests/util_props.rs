//! Property/fuzz tests over the in-tree utility substrate (the pieces
//! that replace unavailable crates.io dependencies).

use dpsnn::util::cli::Args;
use dpsnn::util::prop::forall;
use dpsnn::util::rng::SplitMix64;
use dpsnn::util::table::Table;
use dpsnn::util::tomlmini;

#[test]
fn tomlmini_round_trips_generated_documents() {
    forall("toml round trip", 60, |rng| {
        // generate a doc, render it, parse it back, compare
        let n_tables = 1 + rng.next_below(4);
        let mut text = String::new();
        let mut expect: Vec<(String, String, String)> = Vec::new();
        for t in 0..n_tables {
            let tname = format!("t{t}");
            text.push_str(&format!("[{tname}]\n"));
            for k in 0..1 + rng.next_below(5) {
                let key = format!("k{k}");
                match rng.next_below(4) {
                    0 => {
                        let v = rng.next_u64() as i64 % 100_000;
                        text.push_str(&format!("{key} = {v}\n"));
                        expect.push((tname.clone(), key, format!("i{v}")));
                    }
                    1 => {
                        let v = (rng.next_f64() * 100.0 * 8.0).round() / 8.0;
                        text.push_str(&format!("{key} = {v:?}\n"));
                        expect.push((tname.clone(), key, format!("f{v}")));
                    }
                    2 => {
                        let v = rng.next_below(2) == 1;
                        text.push_str(&format!("{key} = {v}\n"));
                        expect.push((tname.clone(), key, format!("b{v}")));
                    }
                    _ => {
                        let v = format!("s-{}", rng.next_below(1000));
                        text.push_str(&format!("{key} = \"{v}\"  # comment\n"));
                        expect.push((tname.clone(), key, format!("s{v}")));
                    }
                }
            }
        }
        let doc = tomlmini::parse(&text).unwrap();
        for (t, k, tagged) in expect {
            let v = doc.get(&t, &k).unwrap();
            match tagged.split_at(1) {
                ("i", rest) => assert_eq!(v.as_i64().unwrap().to_string(), rest),
                ("f", rest) => {
                    assert!((v.as_f64().unwrap() - rest.parse::<f64>().unwrap()).abs() < 1e-12)
                }
                ("b", rest) => assert_eq!(v.as_bool().unwrap().to_string(), rest),
                ("s", rest) => assert_eq!(v.as_str().unwrap(), rest),
                _ => unreachable!(),
            }
        }
    });
}

#[test]
fn tomlmini_never_panics_on_garbage() {
    forall("toml no panic", 200, |rng| {
        let len = rng.next_below(120) as usize;
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" [=]#\"\\abc0.5\n_x,".to_vec()[rng.next_below(17) as usize])
            .collect();
        let text = String::from_utf8_lossy(&bytes).to_string();
        let _ = tomlmini::parse(&text); // Ok or Err, never panic
    });
}

#[test]
fn cli_parser_never_panics_and_is_total() {
    forall("cli fuzz", 200, |rng| {
        let n = rng.next_below(10) as usize;
        let toks: Vec<String> = (0..n)
            .map(|_| {
                match rng.next_below(5) {
                    0 => format!("--k{}", rng.next_below(5)),
                    1 => format!("--k{}=v{}", rng.next_below(5), rng.next_below(5)),
                    2 => "--".to_string(),
                    3 => format!("pos{}", rng.next_below(5)),
                    _ => format!("{}", rng.next_below(100)),
                }
            })
            .collect();
        if let Ok(a) = Args::parse(toks.clone()) {
            // no token materializes more than one parsed item (an
            // `--k v` option consumes two tokens, `--k=v` one)
            let items = a.positional.len() + a.flags.len() + a.options.len();
            assert!(items <= toks.len(), "{toks:?} -> {a:?}");
        }
    });
}

#[test]
fn table_renders_any_content_without_panicking() {
    forall("table fuzz", 100, |rng| {
        let cols = 1 + rng.next_below(5) as usize;
        let header: Vec<String> = (0..cols).map(|c| format!("h{c}")).collect();
        let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("fuzz", &refs);
        for _ in 0..rng.next_below(10) {
            t.row((0..cols)
                .map(|_| {
                    let l = rng.next_below(12) as usize;
                    "x,\"#|".chars().cycle().take(l).collect::<String>()
                })
                .collect());
        }
        let rendered = t.render();
        assert!(rendered.contains("h0"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), t.rows.len() + 1);
    });
}

#[test]
fn splitmix_streams_do_not_collide_short_term() {
    let mut seen = std::collections::HashSet::new();
    for seed in 0..50u64 {
        let mut r = SplitMix64::new(seed);
        for _ in 0..100 {
            seen.insert(r.next_u64());
        }
    }
    assert_eq!(seen.len(), 5000, "output collision across streams");
}
