//! Exchange-cadence acceptance tests: epoch-batched exchange
//! (`--exchange-every min-delay`) must produce the bitwise-identical
//! spike raster to the paper's per-step protocol across process counts,
//! routing protocols and min-delay windows, while performing
//! ~`delay_min_steps`× fewer transport exchanges (and barriers).

use dpsnn::config::{ExchangeCadence, Mode, NetworkParams, Routing, RunConfig};
use dpsnn::coordinator::{self, RunResult};
use dpsnn::metrics::expected_exchanges;

fn cfg(procs: u32, routing: Routing, delay_min: u32, cadence: ExchangeCadence) -> RunConfig {
    let mut c = RunConfig::default();
    c.net = NetworkParams::tiny(512);
    c.net.syn_per_neuron = 24; // sparse enough for pair filtering at P=8
    c.net.delay_min_steps = delay_min;
    c.procs = procs;
    c.sim_seconds = 0.15;
    c.seed = 2026;
    c.mode = Mode::Live;
    c.routing = routing;
    c.exchange_every = cadence;
    c
}

/// Exchange count of the busiest rank (all ranks tie on a synchronous
/// collective, but take the max to be explicit).
fn exchanges(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.exchanges).max().unwrap_or(0)
}

#[test]
fn epoch_batched_raster_is_bitwise_identical() {
    // P ∈ {1, 2, 4, 8} × routing ∈ {broadcast, filtered} ×
    // delay_min_steps ∈ {1, 2, 4, 16}: min-delay batching must match the
    // single-rank per-step reference raster bitwise, with exactly
    // ceil(steps / delay_min) exchanges.
    for &delay_min in &[1u32, 2, 4, 16] {
        for &routing in &[Routing::Broadcast, Routing::Filtered] {
            let reference =
                coordinator::run(&cfg(1, routing, delay_min, ExchangeCadence::Step)).unwrap();
            assert!(
                reference.total_spikes > 0,
                "network must be active at dmin={delay_min}"
            );
            let steps = reference.pop_counts.len() as u32;
            for &procs in &[1u32, 2, 4, 8] {
                let batched =
                    coordinator::run(&cfg(procs, routing, delay_min, ExchangeCadence::MinDelay))
                        .unwrap();
                assert_eq!(
                    batched.pop_counts, reference.pop_counts,
                    "raster diverged: P={procs} routing={routing} dmin={delay_min}"
                );
                assert_eq!(batched.total_spikes, reference.total_spikes);
                assert_eq!(batched.total_syn_events, reference.total_syn_events);
                assert_eq!(batched.total_ext_events, reference.total_ext_events);
                assert_eq!(
                    exchanges(&batched),
                    expected_exchanges(steps, delay_min),
                    "P={procs} routing={routing} dmin={delay_min}"
                );
            }
        }
    }
}

#[test]
fn intermediate_cadence_also_identical() {
    // --exchange-every N between 1 and delay_min: same raster, N× fewer
    // exchanges.
    let reference =
        coordinator::run(&cfg(4, Routing::Filtered, 4, ExchangeCadence::Step)).unwrap();
    let every2 =
        coordinator::run(&cfg(4, Routing::Filtered, 4, ExchangeCadence::Every(2))).unwrap();
    assert_eq!(every2.pop_counts, reference.pop_counts);
    let steps = reference.pop_counts.len() as u32;
    assert_eq!(exchanges(&reference), steps as u64);
    assert_eq!(exchanges(&every2), expected_exchanges(steps, 2));
}

#[test]
fn cadence_beyond_min_delay_is_rejected() {
    let c = cfg(2, Routing::Filtered, 4, ExchangeCadence::Every(5));
    assert!(c.validate().is_err(), "epoch > delay_min must be rejected");
    cfg(2, Routing::Filtered, 4, ExchangeCadence::Every(4)).validate().unwrap();
}

#[test]
fn default_network_min_delay_cuts_exchanges_8x() {
    // The acceptance bar: on the paper's default 20480-neuron network
    // with a 16-step min-delay window, min-delay cadence must produce
    // the bitwise-identical raster with ≤ 1/8 the transport exchanges.
    // The window is kept short (the synapse build dominates runtime).
    let mut per_step = RunConfig::default(); // 20480N, filtered routing
    per_step.net.delay_min_steps = 16;
    per_step.sim_seconds = 0.05;
    per_step.mode = Mode::Live;
    per_step.procs = 8;
    let mut batched = per_step.clone();
    batched.exchange_every = ExchangeCadence::MinDelay;

    let a = coordinator::run(&per_step).unwrap();
    let b = coordinator::run(&batched).unwrap();
    assert!(a.total_spikes > 0, "network must be active");
    assert_eq!(a.pop_counts, b.pop_counts, "cadence changed the raster");
    assert_eq!(a.total_spikes, b.total_spikes);
    assert_eq!(a.total_syn_events, b.total_syn_events);

    let (xa, xb) = (exchanges(&a), exchanges(&b));
    assert!(
        xb * 8 <= xa,
        "min-delay must perform <= 1/8 the exchanges ({xb} vs {xa})"
    );
    // messages shrink with the exchanges: P-1 envelopes per collective
    let msgs = |r: &RunResult| r.comm_volume.iter().map(|c| c.messages).sum::<u64>();
    assert!(
        msgs(&b) * 8 <= msgs(&a),
        "messages must shrink with the exchange count ({} vs {})",
        msgs(&b),
        msgs(&a)
    );
}
