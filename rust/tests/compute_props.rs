//! Compute-engine acceptance tests: `--compute-threads N` must be
//! invisible in every observable output. The chunk geometry is a pure
//! function of (population size, N), each chunk owns a disjoint output
//! region, and per-chunk results reduce in ascending chunk order — so
//! the raster, the totals and the final membrane state are bitwise
//! identical for every thread count, composed with every partition
//! policy, transport topology and exchange cadence.
//!
//! The SoA masked kernel itself is held to the scalar push-variant
//! `step_native` as an op-for-op oracle over a long mixed-drive run.

use std::rc::Rc;

use dpsnn::config::{
    ExchangeCadence, Mode, NetworkParams, PartitionPolicy, RunConfig, Topology, TreeShape,
};
use dpsnn::coordinator;
use dpsnn::model::neuron::{step_native, StepParams};
use dpsnn::model::population::PopulationSoA;
use dpsnn::runtime::{NativeBackend, NeuronBackend};
use dpsnn::util::pool::ComputePool;

fn cfg(
    threads: u32,
    partition: PartitionPolicy,
    topology: Topology,
    cadence: ExchangeCadence,
) -> RunConfig {
    let mut c = RunConfig::default();
    c.net = NetworkParams::tiny(512);
    c.net.syn_per_neuron = 24; // sparse: lets greedy-comms actually move blocks
    c.net.delay_min_steps = 4;
    c.procs = 4;
    c.sim_seconds = 0.15;
    c.seed = 2026;
    c.mode = Mode::Live;
    c.compute_threads = threads;
    c.partition = partition;
    c.topology = topology;
    c.exchange_every = cadence;
    c
}

#[test]
fn threaded_rasters_are_bitwise_identical() {
    // threads {1,2,4} x partition {index, greedy-comms} x topology
    // {flat, tree:2,2}, all under min-delay epoch batching, against the
    // single-threaded flat per-step reference.
    let reference = coordinator::run(&cfg(
        1,
        PartitionPolicy::Index,
        Topology::Flat,
        ExchangeCadence::Step,
    ))
    .unwrap();
    assert!(reference.total_spikes > 0, "network must be active");
    let tree = Topology::Tree(TreeShape::new(&[2, 2]).unwrap());
    for &threads in &[1u32, 2, 4] {
        for &partition in &[PartitionPolicy::Index, PartitionPolicy::GreedyComms] {
            for &topology in &[Topology::Flat, tree] {
                let run = coordinator::run(&cfg(
                    threads,
                    partition,
                    topology,
                    ExchangeCadence::MinDelay,
                ))
                .unwrap();
                let tag = format!("threads={threads} partition={partition} topology={topology}");
                assert_eq!(run.pop_counts, reference.pop_counts, "raster diverged: {tag}");
                assert_eq!(run.total_spikes, reference.total_spikes, "{tag}");
                assert_eq!(run.total_exc_spikes, reference.total_exc_spikes, "{tag}");
                assert_eq!(run.total_syn_events, reference.total_syn_events, "{tag}");
                assert_eq!(run.total_ext_events, reference.total_ext_events, "{tag}");
            }
        }
    }
}

/// Deterministic mixed drive: per-neuron phase against per-step
/// modulation, strong enough to spike and weak enough to stay irregular.
fn drive(t: u32, j: usize) -> (f32, f32) {
    let syn = ((t as usize * 31 + j * 7) % 13) as f32 * 0.35;
    let ext = ((t as usize * 17 + j * 3) % 11) as f32 * 0.4;
    (syn, ext)
}

#[test]
fn soa_backend_matches_scalar_oracle_over_1k_steps() {
    // n = 300: not a multiple of the 64-element chunk alignment or the
    // 8-byte mask scan width, so tail lanes are exercised everywhere.
    let n = 300usize;
    let net = NetworkParams::tiny(n as u32);
    let params = StepParams::from_network(&net);
    let steps = 1000u32;

    // Scalar push-variant oracle on plain Vecs.
    let pop = PopulationSoA::init(&net, 2026, 0, n as u32);
    let (mut v, mut w, mut rf) = (pop.v.to_vec(), pop.w.to_vec(), pop.rf.to_vec());
    let sfa = pop.sfa_inc.to_vec();
    let mut i_syn = vec![0.0f32; n];
    let mut i_ext = vec![0.0f32; n];
    let mut oracle_spikes: Vec<Vec<u32>> = Vec::new();
    for t in 0..steps {
        for j in 0..n {
            let (s, e) = drive(t, j);
            i_syn[j] = s;
            i_ext[j] = e;
        }
        let mut spiked = Vec::new();
        step_native(&params, &mut v, &mut w, &mut rf, &i_syn, &i_ext, &sfa, &mut spiked);
        oracle_spikes.push(spiked);
    }
    let fired: usize = oracle_spikes.iter().map(|s| s.len()).sum();
    assert!(fired > 100, "oracle drive too weak to exercise spiking ({fired} spikes)");

    // The production masked SoA path, single- and multi-chunk.
    for &threads in &[1usize, 2, 4] {
        let pool = Rc::new(ComputePool::new(threads));
        let soa = PopulationSoA::init(&net, 2026, 0, n as u32);
        let mut be = NativeBackend::with_pool(&net, soa, pool);
        let mut spiked = Vec::new();
        for t in 0..steps {
            let ie = be.i_ext_mut();
            for j in 0..n {
                let (s, e) = drive(t, j);
                i_syn[j] = s;
                ie[j] = e;
            }
            spiked.clear();
            be.step(&i_syn, &mut spiked).unwrap();
            assert_eq!(
                spiked, oracle_spikes[t as usize],
                "threads={threads}: spikes diverged at step {t}"
            );
        }
        let (bv, bw, brf) = be.state();
        assert_eq!(bv, &v[..], "threads={threads}: final v diverged");
        assert_eq!(bw, &w[..], "threads={threads}: final w diverged");
        assert_eq!(brf, &rf[..], "threads={threads}: final rf diverged");
    }
}
