//! End-to-end shape tests: the full modeled pipeline must reproduce the
//! paper's qualitative findings (fast variants of the EXPERIMENTS.md
//! acceptance criteria — the harness lib tests cover the fine grain).

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;

fn modeled(platform: &str, ic: &str, procs: u32) -> dpsnn::coordinator::RunResult {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::paper_20480();
    cfg.procs = procs;
    cfg.sim_seconds = 1.0;
    cfg.mode = Mode::Modeled;
    cfg.platform = platform.into();
    cfg.interconnect = ic.into();
    coordinator::run(&cfg).unwrap()
}

#[test]
fn headline_realtime_at_32_procs_on_ib() {
    // Fig 2: the 20480-neuron configuration reaches (soft) real time
    // around 32 processes on Intel+IB.
    let r = modeled("xeon", "ib", 32);
    assert!(
        r.wall_s * 10.0 < 14.0,
        "10s-sim wall {:.1} s at 32 procs",
        r.wall_s * 10.0
    );
}

#[test]
fn latency_wall_kills_scaling_past_32() {
    let w32 = modeled("xeon", "ib", 32).wall_s;
    let w256 = modeled("xeon", "ib", 256).wall_s;
    assert!(w256 > 4.0 * w32, "no latency wall: {w32} -> {w256}");
}

#[test]
fn ib_beats_eth_in_time_and_energy() {
    for p in [32u32, 64] {
        let ib = modeled("westmere", "ib", p);
        let eth = modeled("westmere", "eth1g", p);
        assert!(ib.wall_s < eth.wall_s, "p={p} time");
        assert!(
            ib.energy.unwrap().energy_j < eth.energy.unwrap().energy_j,
            "p={p} energy"
        );
    }
}

#[test]
fn arm_cheaper_but_slower() {
    let arm = modeled("jetson", "eth1g", 4);
    let x86 = modeled("westmere", "ib", 4);
    assert!(arm.wall_s > 3.0 * x86.wall_s);
    assert!(arm.energy.unwrap().energy_j < x86.energy.unwrap().energy_j / 1.5);
}

#[test]
fn uj_per_synaptic_event_beats_compass_reference() {
    // Table IV: DPSNN on both platforms undercuts the published 5.7
    // uJ/syn-event Compass/TrueNorth figure.
    for (platform, ic, procs) in [("jetson", "eth1g", 4u32), ("westmere", "ib", 8)] {
        let r = modeled(platform, ic, procs);
        let uj = r.energy.unwrap().uj_per_syn_event;
        assert!(
            uj < dpsnn::metrics::energy::COMPASS_TRUENORTH_UJ,
            "{platform}: {uj:.2} uJ/event"
        );
    }
}

#[test]
fn recorded_trace_replays_through_modeled_platform() {
    // live run (this host) -> workload trace -> modeled Westmere replay:
    // the full record/replay loop, preserving spike statistics.
    let path = std::env::temp_dir().join(format!("dpsnn-e2e-trace-{}.csv", std::process::id()));
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(2048);
    cfg.procs = 4;
    cfg.sim_seconds = 0.5;
    cfg.mode = Mode::Live;
    cfg.record_trace = Some(path.to_string_lossy().to_string());
    let live = coordinator::run(&cfg).unwrap();
    let trace = dpsnn::trace::workload::WorkloadTrace::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(trace.total_spikes(), live.total_spikes);
    assert_eq!(trace.procs, 4);
    assert_eq!(trace.steps(), 500);

    // replay on a modeled platform at a different P
    let rebinned = trace.rebin(8).unwrap();
    let mut mcfg = RunConfig::default();
    mcfg.net = cfg.net.clone();
    mcfg.procs = 8;
    mcfg.mode = Mode::Modeled;
    mcfg.platform = "westmere".into();
    mcfg.interconnect = "ib".into();
    let modeled =
        dpsnn::coordinator::modeled::run_modeled_trace(&mcfg, &rebinned).unwrap();
    assert_eq!(modeled.total_spikes, live.total_spikes);
    assert!(modeled.wall_s > 0.0);
    assert!(modeled.energy.is_some());
}

#[test]
fn modeled_and_live_agree_on_workload_statistics() {
    // The analytic workload must match what the real engine produces
    // (rate within the regime band) so the timing model replays a
    // faithful load.
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::paper_20480();
    cfg.procs = 8;
    cfg.sim_seconds = 1.0;
    cfg.mode = Mode::Live;
    let live = coordinator::run(&cfg).unwrap();
    let modeled = modeled("xeon", "ib", 8);
    let ratio = live.mean_rate_hz / modeled.mean_rate_hz;
    assert!(
        (0.5..2.0).contains(&ratio),
        "live {:.2} Hz vs modeled {:.2} Hz",
        live.mean_rate_hz,
        modeled.mean_rate_hz
    );
}
