//! Per-job isolation properties for the resident multi-tenant server
//! (`runtime::server`).
//!
//! The load-bearing invariant: a job run through the server — with its
//! shared plan/placement/connectome/artifact caches, simnet-priced
//! scheduling and identical-config batching — produces a raster and
//! spike totals **bitwise identical** to the same config run solo
//! through `coordinator::run` (the CLI path). Exercised across
//! partition × topology × cadence × connectivity-mode × routing combos
//! with distinct seeds, plus a cache-poisoning check (two jobs differing
//! only in seed must not share RNG-dependent cached state), batching
//! identity, per-job failure containment, and progress-stream sanity.

use dpsnn::config::{
    ConnectivityMode, ExchangeCadence, JobSpec, NetworkParams, PartitionPolicy, Routing,
    RunConfig, ServeOptions, Topology, TreeShape,
};
use dpsnn::coordinator;
use dpsnn::runtime::{JobEvent, SimServer};

/// The shared tiny workload. Every combo keeps the same network physics
/// (including `delay_min_steps`, which changes the delay draw and so
/// the raster) and varies only the exchange/placement axes.
fn base_cfg(procs: u32, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(512);
    cfg.net.delay_min_steps = 4.min(cfg.net.delay_max_steps).max(1);
    cfg.procs = procs;
    cfg.sim_seconds = 0.2;
    cfg.seed = seed;
    cfg
}

/// One spec per combo of the cache-relevant axes, each with its own
/// seed so no two jobs may legally share RNG-dependent state.
fn combo_specs() -> Vec<JobSpec> {
    let tree22 = Topology::Tree(TreeShape::new(&[2, 2]).unwrap());
    let mut specs = Vec::new();

    let mut c = base_cfg(2, 11);
    c.partition = PartitionPolicy::Index;
    specs.push(JobSpec::new("index-flat-step-mat", c));

    let mut c = base_cfg(2, 22);
    c.partition = PartitionPolicy::RoundRobin;
    c.exchange_every = ExchangeCadence::MinDelay;
    specs.push(JobSpec::new("rr-flat-mindelay-mat", c));

    let mut c = base_cfg(4, 33);
    c.partition = PartitionPolicy::GreedyComms;
    c.topology = tree22;
    specs.push(JobSpec::new("greedy-tree22-step-mat", c));

    let mut c = base_cfg(4, 44);
    c.topology = Topology::Nodes(2);
    c.exchange_every = ExchangeCadence::MinDelay;
    c.connectivity = ConnectivityMode::Procedural;
    specs.push(JobSpec::new("index-nodes2-mindelay-proc", c));

    let mut c = base_cfg(2, 55);
    c.routing = Routing::Broadcast;
    c.connectivity = ConnectivityMode::Procedural;
    specs.push(JobSpec::new("index-flat-step-proc-bcast", c));

    for s in &specs {
        s.cfg.validate().unwrap();
    }
    specs
}

#[test]
fn concurrent_jobs_match_solo_runs_bitwise() {
    let specs = combo_specs();

    // Solo twins first: each config through the CLI path, no sharing.
    let solo: Vec<_> = specs
        .iter()
        .map(|s| coordinator::run(&s.cfg).unwrap())
        .collect();

    // All jobs through ONE resident server, concurrently (the 8-rank
    // budget forces several to run at once and the rest to queue
    // through the simnet-priced scheduler).
    let server = SimServer::start(ServeOptions { total_ranks: 8 });
    let handles: Vec<_> = specs
        .iter()
        .map(|s| server.submit(s.clone()).unwrap())
        .collect();
    let served: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();

    for ((spec, a), b) in specs.iter().zip(&solo).zip(&served) {
        assert_eq!(
            a.pop_counts, b.pop_counts,
            "raster diverged for {}",
            spec.name
        );
        assert_eq!(a.total_spikes, b.total_spikes, "{}", spec.name);
        assert_eq!(a.total_syn_events, b.total_syn_events, "{}", spec.name);
        assert_eq!(a.rank_spikes, b.rank_spikes, "{}", spec.name);
    }
}

#[test]
fn jobs_differing_only_in_seed_share_no_rng_state() {
    // greedy-comms placement reads the seed-dependent connectome, so a
    // poisoned placement/connectome cache would surface here: run two
    // jobs identical except for seed and require each to match its own
    // solo twin while differing from the other.
    let mk = |seed: u64| {
        let mut c = base_cfg(2, seed);
        c.partition = PartitionPolicy::GreedyComms;
        c
    };
    let solo_a = coordinator::run(&mk(101)).unwrap();
    let solo_b = coordinator::run(&mk(102)).unwrap();

    let server = SimServer::start(ServeOptions { total_ranks: 4 });
    let ha = server.submit(JobSpec::new("seed101", mk(101))).unwrap();
    let hb = server.submit(JobSpec::new("seed102", mk(102))).unwrap();
    let ra = ha.wait().unwrap();
    let rb = hb.wait().unwrap();

    assert_eq!(solo_a.pop_counts, ra.pop_counts, "seed 101 poisoned");
    assert_eq!(solo_b.pop_counts, rb.pop_counts, "seed 102 poisoned");
    assert_ne!(
        ra.pop_counts, rb.pop_counts,
        "distinct seeds must yield distinct rasters — shared RNG state?"
    );
}

#[test]
fn batched_identical_jobs_return_the_solo_result() {
    let cfg = base_cfg(2, 77);
    let solo = coordinator::run(&cfg).unwrap();

    // One rank budget below 2×procs would serialize; give exactly the
    // demand of one job so the twin queues and batching can trigger.
    let server = SimServer::start(ServeOptions { total_ranks: 2 });
    let h1 = server.submit(JobSpec::new("twin-a", cfg.clone())).unwrap();
    let h2 = server.submit(JobSpec::new("twin-b", cfg.clone())).unwrap();
    let h3 = server.submit(JobSpec::new("twin-c", cfg)).unwrap();
    let r1 = h1.wait().unwrap();
    let r2 = h2.wait().unwrap();
    let r3 = h3.wait().unwrap();
    let stats = server.cache_stats();

    for (tag, r) in [("a", &r1), ("b", &r2), ("c", &r3)] {
        assert_eq!(solo.pop_counts, r.pop_counts, "twin-{tag}");
        assert_eq!(solo.total_spikes, r.total_spikes, "twin-{tag}");
    }
    // With a 2-rank budget the first twin holds all ranks while the
    // identical others queue; at least one must have ridden its pass.
    assert!(
        stats.batched_jobs >= 1,
        "identical queued configs should batch: {stats:?}"
    );
}

#[test]
fn shared_caches_are_exercised_across_jobs() {
    // Job 2 shares job 1's placement key (same net/seed/procs/policy/
    // topology, different cadence) and must hit the placement cache;
    // job 3 changes only the policy, so its placement misses but its
    // connectome (net, seed) lookup hits.
    let mut a = base_cfg(2, 88);
    a.partition = PartitionPolicy::GreedyComms;
    let mut b = a.clone();
    b.exchange_every = ExchangeCadence::MinDelay;
    let mut c = a.clone();
    c.partition = PartitionPolicy::RoundRobin;

    let server = SimServer::start(ServeOptions { total_ranks: 2 });
    for (name, cfg) in [("warm", a), ("placement-reuse", b), ("connectome-reuse", c)] {
        server
            .submit(JobSpec::new(name, cfg))
            .unwrap()
            .wait()
            .unwrap();
    }
    let stats = server.cache_stats();
    assert!(stats.placement_hits >= 1, "{stats:?}");
    assert!(stats.connectome_hits >= 1, "{stats:?}");
}

#[test]
fn bad_artifact_dir_degrades_one_job_only() {
    let server = SimServer::start(ServeOptions { total_ranks: 2 });
    let mut bad = base_cfg(2, 5);
    bad.backend = dpsnn::config::Backend::Xla;
    bad.artifacts_dir = "/nonexistent/dpsnn-server-props".to_string();
    let h = server.submit(JobSpec::new("doomed", bad)).unwrap();
    let err = h.wait().unwrap_err().to_string();
    assert!(
        err.contains("artifacts") || err.contains("artifact"),
        "unexpected failure text: {err}"
    );
    // The server must still serve the next (native) job.
    let ok = server.submit(JobSpec::new("survivor", base_cfg(2, 6))).unwrap();
    assert!(ok.wait().is_ok());
}

#[test]
fn event_stream_is_ordered_and_progress_monotonic() {
    let server = SimServer::start(ServeOptions { total_ranks: 2 });
    let h = server.submit(JobSpec::new("events", base_cfg(2, 9))).unwrap();
    let mut saw_started = false;
    let mut last_step = 0u32;
    let mut finished = false;
    while let Ok(ev) = h.events().recv() {
        match ev {
            JobEvent::Queued => assert!(!saw_started, "Queued after Started"),
            JobEvent::Started => saw_started = true,
            JobEvent::Progress { step, steps } => {
                assert!(saw_started, "Progress before Started");
                assert!(step >= last_step, "progress went backwards");
                assert!(step <= steps);
                last_step = step;
            }
            JobEvent::Finished(r) => {
                assert!(saw_started);
                assert!(r.total_spikes > 0);
                finished = true;
                break;
            }
            JobEvent::Failed(m) => panic!("job failed: {m}"),
        }
    }
    assert!(finished, "no terminal event");
    assert!(last_step > 0, "no progress events streamed");
}
