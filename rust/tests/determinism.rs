//! Partition-determinism integration tests: the simulation must be
//! *bitwise identical* for any process count (connectivity, stimulus and
//! initial state are pure functions of global ids; synaptic weights live
//! on an exact f32 grid so accumulation order cannot matter).
//!
//! This is what makes the paper's strong-scaling sweeps simulate the same
//! network at every P.

use dpsnn::config::{Mode, NetworkParams, Routing, RunConfig};
use dpsnn::coordinator;

fn cfg(n: u32, procs: u32, seconds: f64, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(n);
    cfg.procs = procs;
    cfg.sim_seconds = seconds;
    cfg.seed = seed;
    cfg.mode = Mode::Live;
    cfg
}

/// A sparse variant (fan-out 8 instead of n/4) where destination
/// filtering drops whole source→rank pairs rather than degenerating to
/// broadcast.
fn sparse_cfg(procs: u32, routing: Routing) -> RunConfig {
    let mut c = cfg(512, procs, 0.3, 42);
    c.net.syn_per_neuron = 8;
    c.routing = routing;
    c
}

#[test]
fn raster_identical_across_partitionings() {
    let reference = coordinator::run(&cfg(1024, 1, 0.5, 42)).unwrap();
    assert!(reference.total_spikes > 0, "network must be active");
    for procs in [2u32, 3, 4, 8] {
        let r = coordinator::run(&cfg(1024, procs, 0.5, 42)).unwrap();
        assert_eq!(
            r.pop_counts, reference.pop_counts,
            "per-step population raster diverged at P={procs}"
        );
        assert_eq!(r.total_spikes, reference.total_spikes);
        assert_eq!(r.total_syn_events, reference.total_syn_events);
        assert_eq!(r.total_ext_events, reference.total_ext_events);
    }
}

#[test]
fn different_seeds_give_different_rasters() {
    let a = coordinator::run(&cfg(512, 2, 0.3, 1)).unwrap();
    let b = coordinator::run(&cfg(512, 2, 0.3, 2)).unwrap();
    assert_ne!(a.pop_counts, b.pop_counts);
}

#[test]
fn same_seed_reproduces_exactly() {
    let a = coordinator::run(&cfg(512, 4, 0.3, 7)).unwrap();
    let b = coordinator::run(&cfg(512, 4, 0.3, 7)).unwrap();
    assert_eq!(a.pop_counts, b.pop_counts);
    assert_eq!(a.total_spikes, b.total_spikes);
}

#[test]
fn filtered_routing_deterministic_across_process_counts() {
    // The raster with destination filtering on must be bitwise identical
    // for P in {1, 2, 4, 8} *and* identical to the broadcast raster, on
    // a sparse network where the filter really drops traffic.
    let reference = coordinator::run(&sparse_cfg(1, Routing::Broadcast)).unwrap();
    assert!(reference.total_spikes > 0, "sparse network must be active");
    for procs in [1u32, 2, 4, 8] {
        let r = coordinator::run(&sparse_cfg(procs, Routing::Filtered)).unwrap();
        assert_eq!(
            r.pop_counts, reference.pop_counts,
            "filtered raster diverged at P={procs}"
        );
        assert_eq!(r.total_spikes, reference.total_spikes);
        assert_eq!(r.total_syn_events, reference.total_syn_events);
        assert_eq!(r.total_ext_events, reference.total_ext_events);
    }
}

#[test]
fn filtered_routing_moves_fewer_bytes_on_sparse_networks() {
    // 512 neurons, fan-out 8, P=8: a source reaches ~1-(1-1/8)^8 ~ 66%
    // of ranks, so pair filtering (not just loopback elision) must cut
    // the network send volume.
    let filtered = coordinator::run(&sparse_cfg(8, Routing::Filtered)).unwrap();
    let broadcast = coordinator::run(&sparse_cfg(8, Routing::Broadcast)).unwrap();
    assert_eq!(filtered.pop_counts, broadcast.pop_counts);
    let sent = |r: &coordinator::RunResult| -> u64 {
        r.comm_volume.iter().map(|c| c.bytes_sent).sum()
    };
    let recv = |r: &coordinator::RunResult| -> u64 {
        r.comm_volume.iter().map(|c| c.bytes_recv).sum()
    };
    assert!(
        (sent(&filtered) as f64) < 0.9 * sent(&broadcast) as f64,
        "pair filtering should cut sends: {} vs {}",
        sent(&filtered),
        sent(&broadcast)
    );
    assert!(recv(&filtered) < recv(&broadcast));
}

#[test]
fn uneven_partitions_also_deterministic() {
    // 5 ranks over 1000 neurons: ranks own 200 each; 7 ranks: 142/143.
    let reference = coordinator::run(&cfg(1000, 1, 0.3, 99)).unwrap();
    for procs in [5u32, 7] {
        let r = coordinator::run(&cfg(1000, procs, 0.3, 99)).unwrap();
        assert_eq!(r.pop_counts, reference.pop_counts, "P={procs}");
    }
}
