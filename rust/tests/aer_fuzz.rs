//! No-panic fuzz/property tests for the AER wire decoders.
//!
//! The resident server feeds decoder inputs that crossed a transport,
//! so `decode_spikes` and `decode_spikes_epoch` must be total over
//! arbitrary bytes: corrupt, truncated and adversarial streams return
//! `Err` — they never panic and never over-allocate from attacker-
//! controlled headers. `util::prop::forall` catches panics per case and
//! re-raises them with the failing seed, so "the closure returned" IS
//! the no-panic assertion.

use dpsnn::comm::aer::{
    decode_spikes, decode_spikes_epoch, encode_spikes, encode_spikes_epoch,
};
use dpsnn::engine::spike::Spike;
use dpsnn::util::prop::forall;
use dpsnn::util::rng::SplitMix64;

const DT_MS: f64 = 1.0;

fn random_bytes(rng: &mut SplitMix64, max_len: u32) -> Vec<u8> {
    let len = rng.next_below(max_len + 1) as usize;
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

/// A valid, step-sorted spike sequence for round-trip mutation tests.
fn random_spikes(rng: &mut SplitMix64) -> Vec<Spike> {
    let n = rng.next_below(64) as usize;
    let mut step = 0u32;
    (0..n)
        .map(|_| {
            step += rng.next_below(3);
            Spike::new(rng.next_below(100_000), step)
        })
        .collect()
}

#[test]
fn flat_decoder_never_panics_or_overallocates_on_junk() {
    forall("aer-flat-junk", 500, |rng| {
        let buf = random_bytes(rng, 300);
        let mut out = Vec::new();
        match decode_spikes(&buf, DT_MS, &mut out) {
            Ok(n) => {
                assert_eq!(n * 12, buf.len(), "Ok must consume whole buffer");
                assert_eq!(out.len(), n);
            }
            Err(_) => {} // rejection is the expected path for junk
        }
        // Allocation must be bounded by the input, not by decoded
        // content (12 wire bytes per possible record).
        assert!(
            out.capacity() <= buf.len().max(8),
            "capacity {} for a {}-byte input",
            out.capacity(),
            buf.len()
        );
    });
}

#[test]
fn epoch_decoder_never_panics_or_overallocates_on_junk() {
    forall("aer-epoch-junk", 500, |rng| {
        let buf = random_bytes(rng, 300);
        let mut out = Vec::new();
        let _ = decode_spikes_epoch(&buf, DT_MS, &mut out);
        assert!(
            out.capacity() <= buf.len().max(8),
            "capacity {} for a {}-byte input",
            out.capacity(),
            buf.len()
        );
    });
}

#[test]
fn epoch_decoder_rejects_huge_count_headers_without_allocating() {
    forall("aer-epoch-hugecount", 200, |rng| {
        // A single header claiming an enormous run with little payload:
        // the decoder must Err on the length check, never reserve for
        // the claimed count.
        let mut buf = Vec::new();
        buf.extend_from_slice(&rng.next_below(1000).to_le_bytes());
        let huge = u32::MAX - rng.next_below(1000);
        buf.extend_from_slice(&huge.to_le_bytes());
        buf.extend(std::iter::repeat(0u8).take(rng.next_below(36) as usize));
        let mut out = Vec::new();
        assert!(decode_spikes_epoch(&buf, DT_MS, &mut out).is_err());
        assert!(out.capacity() <= 64, "reserved from an unvalidated header");
    });
}

#[test]
fn truncated_epoch_streams_err_or_decode_a_strict_prefix() {
    forall("aer-epoch-truncate", 300, |rng| {
        let spikes = random_spikes(rng);
        let mut buf = Vec::new();
        encode_spikes_epoch(&spikes, DT_MS, &mut buf);
        if buf.is_empty() {
            return;
        }
        let cut = rng.next_below(buf.len() as u32) as usize;
        let mut out = Vec::new();
        match decode_spikes_epoch(&buf[..cut], DT_MS, &mut out) {
            // A cut landing exactly on a run boundary decodes the runs
            // before it — a strict prefix, nothing fabricated.
            Ok(n) => {
                assert!(n < spikes.len() || spikes.is_empty());
                assert_eq!(&out[..], &spikes[..n], "prefix content diverged");
            }
            Err(_) => {}
        }
    });
}

#[test]
fn single_byte_corruption_never_panics() {
    forall("aer-epoch-bitflip", 300, |rng| {
        let spikes = random_spikes(rng);
        let mut buf = Vec::new();
        encode_spikes_epoch(&spikes, DT_MS, &mut buf);
        if buf.is_empty() {
            return;
        }
        let pos = rng.next_below(buf.len() as u32) as usize;
        let flip = 1u8 << rng.next_below(8);
        buf[pos] ^= flip;
        let mut out = Vec::new();
        // Either outcome is legal; surviving the bytes is the property.
        let _ = decode_spikes_epoch(&buf, DT_MS, &mut out);
        let mut out = Vec::new();
        let _ = decode_spikes(&buf, DT_MS, &mut out);
    });
}

#[test]
fn valid_epoch_streams_always_round_trip() {
    forall("aer-epoch-roundtrip", 300, |rng| {
        let spikes = random_spikes(rng);
        let mut buf = Vec::new();
        encode_spikes_epoch(&spikes, DT_MS, &mut buf);
        let mut out = Vec::new();
        let n = decode_spikes_epoch(&buf, DT_MS, &mut out).expect("valid stream");
        assert_eq!(n, spikes.len());
        assert_eq!(out, spikes);
    });
}
