//! Topology acceptance tests: the hierarchical node-leader transport
//! (`--topology nodes:<k>`) must produce the bitwise-identical spike
//! raster to the flat transport across process counts, routing
//! protocols and exchange cadences, while collapsing the inter-node
//! message count from the flat `P(P−1)` to `N(N−1)` per exchange — and
//! the live accounting must equal the interconnect model's closed-form
//! prediction *exactly*.

use dpsnn::comm::{NodeMap, TopologyTree};
use dpsnn::config::{
    ExchangeCadence, LeaderRotation, Mode, NetworkParams, Routing, RunConfig, Topology, TreeShape,
};
use dpsnn::coordinator::{self, RunResult};
use dpsnn::metrics::expected_exchanges;
use dpsnn::simnet::presets::IB;
use dpsnn::simnet::AllToAllModel;

fn cfg(procs: u32, routing: Routing, cadence: ExchangeCadence, topology: Topology) -> RunConfig {
    let mut c = RunConfig::default();
    c.net = NetworkParams::tiny(512);
    c.net.syn_per_neuron = 24; // sparse enough for pair filtering at P=8
    c.net.delay_min_steps = 4;
    c.procs = procs;
    c.sim_seconds = 0.15;
    c.seed = 2026;
    c.mode = Mode::Live;
    c.routing = routing;
    c.exchange_every = cadence;
    c.topology = topology;
    c
}

/// Exchange count of the busiest rank (all ranks tie on a synchronous
/// collective, but take the max to be explicit).
fn exchanges(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.exchanges).max().unwrap_or(0)
}

fn inter_messages(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.inter_messages).sum()
}

fn total_messages(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.messages).sum()
}

#[test]
fn hierarchical_raster_is_bitwise_identical() {
    // topology ∈ {nodes:2, nodes:4} × routing × cadence × P ∈ {1,2,4,8}:
    // every combination must match the flat single-rank per-step
    // reference raster bitwise (the same bar cadence_props sets).
    for &routing in &[Routing::Broadcast, Routing::Filtered] {
        let flat = cfg(1, routing, ExchangeCadence::Step, Topology::Flat);
        let reference = coordinator::run(&flat).unwrap();
        assert!(reference.total_spikes > 0, "network must be active");
        let steps = reference.pop_counts.len() as u32;
        for &cadence in &[ExchangeCadence::Step, ExchangeCadence::MinDelay] {
            for &procs in &[1u32, 2, 4, 8] {
                for &k in &[2u32, 4] {
                    let run =
                        coordinator::run(&cfg(procs, routing, cadence, Topology::Nodes(k)))
                            .unwrap();
                    let tag = format!("P={procs} routing={routing} cadence={cadence} nodes:{k}");
                    assert_eq!(run.pop_counts, reference.pop_counts, "raster diverged: {tag}");
                    assert_eq!(run.total_spikes, reference.total_spikes, "{tag}");
                    assert_eq!(run.total_syn_events, reference.total_syn_events, "{tag}");
                    assert_eq!(run.total_ext_events, reference.total_ext_events, "{tag}");
                    let epoch = cadence.epoch_steps(4);
                    assert_eq!(exchanges(&run), expected_exchanges(steps, epoch), "{tag}");
                }
            }
        }
    }
}

#[test]
fn live_message_accounting_equals_closed_form() {
    // For every (P, ranks_per_node) — even, ragged, solo-leader — the
    // per-exchange message total measured on the live transport must
    // equal NodeMap's closed form, and the inter-node count must equal
    // the interconnect model's prediction exactly (the acceptance bar).
    for &(procs, k) in &[(2u32, 1u32), (4, 2), (6, 4), (8, 3), (8, 4)] {
        let c = cfg(procs, Routing::Broadcast, ExchangeCadence::Step, Topology::Nodes(k));
        let run = coordinator::run(&c).unwrap();
        let x = exchanges(&run);
        assert!(x > 0);
        let map = NodeMap::new(procs, k);
        let total = total_messages(&run);
        assert_eq!(total, map.total_messages_per_exchange() * x, "P={procs} nodes:{k}");
        let model = AllToAllModel::new(IB, k);
        assert_eq!(total, model.hierarchical_messages(procs) * x, "P={procs} nodes:{k}");
        assert_eq!(
            inter_messages(&run),
            model.hierarchical_inter_messages(procs) * x,
            "P={procs} nodes:{k}: inter-node count must match the model"
        );
        // every rank's split is consistent
        for v in &run.comm_volume {
            assert_eq!(v.messages, v.intra_messages + v.inter_messages);
        }
    }
}

#[test]
fn acceptance_nodes4_at_p8_cuts_inter_node_messages() {
    // The PR's acceptance assert: nodes:4 at P=8 must move at least 2×
    // fewer inter-node messages than flat (it actually moves 28× fewer:
    // 8·7 = 56 pair envelopes collapse to 2·1 = 2 aggregated messages
    // per exchange), with the bitwise-identical raster.
    let fc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Flat);
    let hc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Nodes(4));
    let flat = coordinator::run(&fc).unwrap();
    let hier = coordinator::run(&hc).unwrap();
    assert!(flat.total_spikes > 0, "network must be active");
    assert_eq!(flat.pop_counts, hier.pop_counts, "topology changed the raster");
    assert_eq!(flat.total_syn_events, hier.total_syn_events);

    let x = exchanges(&flat);
    assert_eq!(x, exchanges(&hier), "same cadence, same collectives");
    let (fi, hi) = (inter_messages(&flat), inter_messages(&hier));
    assert!(hi * 2 <= fi, "nodes:4 must move >= 2x fewer inter-node messages ({hi} vs {fi})");
    // and exactly: flat puts all P(P-1) pair envelopes on the fabric,
    // the hierarchy N(N-1) aggregated messages
    assert_eq!(fi, 8 * 7 * x);
    assert_eq!(hi, 2 * x);
}

/// Live per-level message total of a run at one link level.
fn level_messages(r: &RunResult, lvl: usize) -> u64 {
    r.comm_volume
        .iter()
        .map(|c| c.level_messages.get(lvl).copied().unwrap_or(0))
        .sum()
}

#[test]
fn acceptance_tree_4_2_at_p16_is_bitwise_identical() {
    // The PR's acceptance bar: --topology tree:4,2 at P=16 (4 ranks
    // per board, 2 boards per chassis, 2 chassis) must produce a
    // bitwise-identical raster to flat, and the live per-level message
    // counts must equal the TopologyTree closed form exactly.
    let flat = coordinator::run(&cfg(
        16,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Flat,
    ))
    .unwrap();
    let shape = TreeShape::new(&[4, 2]).unwrap();
    let run = coordinator::run(&cfg(
        16,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Tree(shape),
    ))
    .unwrap();
    assert!(flat.total_spikes > 0, "network must be active");
    assert_eq!(flat.pop_counts, run.pop_counts, "tree:4,2 changed the raster");
    assert_eq!(flat.total_syn_events, run.total_syn_events);
    assert_eq!(run.topology, Topology::Tree(shape));

    let x = exchanges(&run);
    assert_eq!(x, exchanges(&flat), "same cadence, same collectives");
    let tree = TopologyTree::new(16, &[4, 2]);
    for lvl in 0..=2usize {
        assert_eq!(
            level_messages(&run, lvl),
            tree.messages_at_level(lvl) * x,
            "level {lvl} accounting diverged from the closed form"
        );
    }
    assert_eq!(inter_messages(&run), tree.fabric_messages_per_exchange() * x);
    assert_eq!(total_messages(&run), tree.total_messages_per_exchange() * x);
    // the top tier carries 2 chassis-pair messages per exchange where
    // the flat exchange paid 16·15 = 240 envelopes
    assert_eq!(tree.messages_at_level(2), 2);
    assert_eq!(inter_messages(&flat), 240 * x);
}

#[test]
fn ragged_trees_match_flat_and_closed_form() {
    // Group sizes that do NOT divide P at one or both levels: ragged
    // boards, ragged chassis, solo groups. Raster stays bitwise
    // identical and every link level matches the closed form.
    let reference = coordinator::run(&cfg(
        1,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Flat,
    ))
    .unwrap();
    assert!(reference.total_spikes > 0, "network must be active");
    for &(procs, shape) in &[
        (6u32, &[4u32, 2][..]),  // ragged boards (4, 2) under one chassis
        (10, &[3, 2]),           // boards (3, 3, 3, 1), chassis (2, 2)
        (7, &[2, 2]),            // boards (2, 2, 2, 1), chassis (2, 2)
    ] {
        let t = TreeShape::new(shape).unwrap();
        let run = coordinator::run(&cfg(
            procs,
            Routing::Filtered,
            ExchangeCadence::Step,
            Topology::Tree(t),
        ))
        .unwrap();
        let tag = format!("P={procs} tree:{t}");
        assert_eq!(run.pop_counts, reference.pop_counts, "raster diverged: {tag}");
        let x = exchanges(&run);
        let tree = TopologyTree::new(procs, shape);
        assert_eq!(
            total_messages(&run),
            tree.total_messages_per_exchange() * x,
            "{tag}"
        );
        for lvl in 0..=tree.depth() {
            assert_eq!(
                level_messages(&run, lvl),
                tree.messages_at_level(lvl) * x,
                "{tag} level {lvl}"
            );
        }
        for v in &run.comm_volume {
            assert_eq!(v.messages, v.intra_messages + v.inter_messages, "{tag}");
        }
    }
}

#[test]
fn leader_rotation_keeps_raster_and_totals_spreads_load() {
    // round-robin rotation must not change the raster or any summed
    // message count — it only moves the relay work between ranks.
    let shape = TreeShape::new(&[2, 2]).unwrap();
    let mut base = cfg(
        8,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Tree(shape),
    );
    let fixed = coordinator::run(&base).unwrap();
    base.leader_rotation = LeaderRotation::RoundRobin;
    let rot = coordinator::run(&base).unwrap();
    assert!(fixed.total_spikes > 0, "network must be active");
    assert_eq!(fixed.pop_counts, rot.pop_counts, "rotation changed the raster");
    assert_eq!(fixed.total_syn_events, rot.total_syn_events);
    assert_eq!(total_messages(&fixed), total_messages(&rot));
    assert_eq!(inter_messages(&fixed), inter_messages(&rot));
    for lvl in 0..=2usize {
        assert_eq!(level_messages(&fixed, lvl), level_messages(&rot, lvl), "level {lvl}");
    }
    // fixed leadership pins all fabric relaying onto first ranks:
    // rank 1 (a plain board member) never sends beyond its board
    assert_eq!(fixed.comm_volume[1].inter_messages, 0, "fixed: rank 1 led");
    // rotation walks leadership through every rank over the run
    for (rank, v) in rot.comm_volume.iter().enumerate() {
        assert!(v.inter_messages > 0, "rank {rank} never took a leader turn");
    }
    // and the per-exchange totals still equal the closed form
    let x = exchanges(&rot);
    let tree = TopologyTree::new(8, &[2, 2]);
    for lvl in 0..=2usize {
        assert_eq!(level_messages(&rot, lvl), tree.messages_at_level(lvl) * x);
    }
}

#[test]
fn nodes_sugar_equals_one_level_tree() {
    let a = coordinator::run(&cfg(
        8,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Nodes(4),
    ))
    .unwrap();
    let b = coordinator::run(&cfg(
        8,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Tree(TreeShape::one_level(4)),
    ))
    .unwrap();
    assert_eq!(a.pop_counts, b.pop_counts);
    assert_eq!(total_messages(&a), total_messages(&b));
    assert_eq!(inter_messages(&a), inter_messages(&b));
    assert_eq!(level_messages(&a, 0), level_messages(&b, 0));
    assert_eq!(level_messages(&a, 1), level_messages(&b, 1));
}

#[test]
fn tree_composes_with_min_delay_batching() {
    // tree:2,2 under min-delay cadence: exchanges shrink by the epoch
    // AND each exchange still costs the closed-form fabric messages —
    // the two axes multiply, tiers included.
    let shape = TreeShape::new(&[2, 2]).unwrap();
    let pc = cfg(
        8,
        Routing::Filtered,
        ExchangeCadence::Step,
        Topology::Tree(shape),
    );
    let bc = cfg(
        8,
        Routing::Filtered,
        ExchangeCadence::MinDelay,
        Topology::Tree(shape),
    );
    let per_step = coordinator::run(&pc).unwrap();
    let batched = coordinator::run(&bc).unwrap();
    assert_eq!(per_step.pop_counts, batched.pop_counts);
    let steps = per_step.pop_counts.len() as u32;
    // 8 ranks as tree:2,2 -> 4 boards, 2 chassis: per exchange the
    // fabric carries 4 board pairs + 2 board gathers + 2 chassis pairs
    let fabric = TopologyTree::new(8, &[2, 2]).fabric_messages_per_exchange();
    assert_eq!(fabric, 8);
    assert_eq!(exchanges(&per_step), steps as u64);
    assert_eq!(exchanges(&batched), expected_exchanges(steps, 4));
    assert_eq!(inter_messages(&per_step), fabric * steps as u64);
    assert_eq!(inter_messages(&batched), fabric * expected_exchanges(steps, 4));
}

#[test]
fn topology_composes_with_min_delay_batching() {
    // nodes:4 under min-delay cadence: exchanges shrink by the epoch
    // AND each exchange still costs only N(N-1) fabric messages — the
    // two axes multiply.
    let pc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Nodes(4));
    let bc = cfg(8, Routing::Filtered, ExchangeCadence::MinDelay, Topology::Nodes(4));
    let per_step = coordinator::run(&pc).unwrap();
    let batched = coordinator::run(&bc).unwrap();
    assert_eq!(per_step.pop_counts, batched.pop_counts);
    let steps = per_step.pop_counts.len() as u32;
    assert_eq!(exchanges(&per_step), steps as u64);
    assert_eq!(exchanges(&batched), expected_exchanges(steps, 4));
    assert_eq!(inter_messages(&per_step), 2 * steps as u64);
    assert_eq!(inter_messages(&batched), 2 * expected_exchanges(steps, 4));
}
