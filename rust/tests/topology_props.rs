//! Topology acceptance tests: the hierarchical node-leader transport
//! (`--topology nodes:<k>`) must produce the bitwise-identical spike
//! raster to the flat transport across process counts, routing
//! protocols and exchange cadences, while collapsing the inter-node
//! message count from the flat `P(P−1)` to `N(N−1)` per exchange — and
//! the live accounting must equal the interconnect model's closed-form
//! prediction *exactly*.

use dpsnn::comm::NodeMap;
use dpsnn::config::{ExchangeCadence, Mode, NetworkParams, Routing, RunConfig, Topology};
use dpsnn::coordinator::{self, RunResult};
use dpsnn::metrics::expected_exchanges;
use dpsnn::simnet::presets::IB;
use dpsnn::simnet::AllToAllModel;

fn cfg(procs: u32, routing: Routing, cadence: ExchangeCadence, topology: Topology) -> RunConfig {
    let mut c = RunConfig::default();
    c.net = NetworkParams::tiny(512);
    c.net.syn_per_neuron = 24; // sparse enough for pair filtering at P=8
    c.net.delay_min_steps = 4;
    c.procs = procs;
    c.sim_seconds = 0.15;
    c.seed = 2026;
    c.mode = Mode::Live;
    c.routing = routing;
    c.exchange_every = cadence;
    c.topology = topology;
    c
}

/// Exchange count of the busiest rank (all ranks tie on a synchronous
/// collective, but take the max to be explicit).
fn exchanges(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.exchanges).max().unwrap_or(0)
}

fn inter_messages(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.inter_messages).sum()
}

fn total_messages(r: &RunResult) -> u64 {
    r.comm_volume.iter().map(|c| c.messages).sum()
}

#[test]
fn hierarchical_raster_is_bitwise_identical() {
    // topology ∈ {nodes:2, nodes:4} × routing × cadence × P ∈ {1,2,4,8}:
    // every combination must match the flat single-rank per-step
    // reference raster bitwise (the same bar cadence_props sets).
    for &routing in &[Routing::Broadcast, Routing::Filtered] {
        let flat = cfg(1, routing, ExchangeCadence::Step, Topology::Flat);
        let reference = coordinator::run(&flat).unwrap();
        assert!(reference.total_spikes > 0, "network must be active");
        let steps = reference.pop_counts.len() as u32;
        for &cadence in &[ExchangeCadence::Step, ExchangeCadence::MinDelay] {
            for &procs in &[1u32, 2, 4, 8] {
                for &k in &[2u32, 4] {
                    let run =
                        coordinator::run(&cfg(procs, routing, cadence, Topology::Nodes(k)))
                            .unwrap();
                    let tag = format!("P={procs} routing={routing} cadence={cadence} nodes:{k}");
                    assert_eq!(run.pop_counts, reference.pop_counts, "raster diverged: {tag}");
                    assert_eq!(run.total_spikes, reference.total_spikes, "{tag}");
                    assert_eq!(run.total_syn_events, reference.total_syn_events, "{tag}");
                    assert_eq!(run.total_ext_events, reference.total_ext_events, "{tag}");
                    let epoch = cadence.epoch_steps(4);
                    assert_eq!(exchanges(&run), expected_exchanges(steps, epoch), "{tag}");
                }
            }
        }
    }
}

#[test]
fn live_message_accounting_equals_closed_form() {
    // For every (P, ranks_per_node) — even, ragged, solo-leader — the
    // per-exchange message total measured on the live transport must
    // equal NodeMap's closed form, and the inter-node count must equal
    // the interconnect model's prediction exactly (the acceptance bar).
    for &(procs, k) in &[(2u32, 1u32), (4, 2), (6, 4), (8, 3), (8, 4)] {
        let c = cfg(procs, Routing::Broadcast, ExchangeCadence::Step, Topology::Nodes(k));
        let run = coordinator::run(&c).unwrap();
        let x = exchanges(&run);
        assert!(x > 0);
        let map = NodeMap::new(procs, k);
        let total = total_messages(&run);
        assert_eq!(total, map.total_messages_per_exchange() * x, "P={procs} nodes:{k}");
        let model = AllToAllModel::new(IB, k);
        assert_eq!(total, model.hierarchical_messages(procs) * x, "P={procs} nodes:{k}");
        assert_eq!(
            inter_messages(&run),
            model.hierarchical_inter_messages(procs) * x,
            "P={procs} nodes:{k}: inter-node count must match the model"
        );
        // every rank's split is consistent
        for v in &run.comm_volume {
            assert_eq!(v.messages, v.intra_messages + v.inter_messages);
        }
    }
}

#[test]
fn acceptance_nodes4_at_p8_cuts_inter_node_messages() {
    // The PR's acceptance assert: nodes:4 at P=8 must move at least 2×
    // fewer inter-node messages than flat (it actually moves 28× fewer:
    // 8·7 = 56 pair envelopes collapse to 2·1 = 2 aggregated messages
    // per exchange), with the bitwise-identical raster.
    let fc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Flat);
    let hc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Nodes(4));
    let flat = coordinator::run(&fc).unwrap();
    let hier = coordinator::run(&hc).unwrap();
    assert!(flat.total_spikes > 0, "network must be active");
    assert_eq!(flat.pop_counts, hier.pop_counts, "topology changed the raster");
    assert_eq!(flat.total_syn_events, hier.total_syn_events);

    let x = exchanges(&flat);
    assert_eq!(x, exchanges(&hier), "same cadence, same collectives");
    let (fi, hi) = (inter_messages(&flat), inter_messages(&hier));
    assert!(hi * 2 <= fi, "nodes:4 must move >= 2x fewer inter-node messages ({hi} vs {fi})");
    // and exactly: flat puts all P(P-1) pair envelopes on the fabric,
    // the hierarchy N(N-1) aggregated messages
    assert_eq!(fi, 8 * 7 * x);
    assert_eq!(hi, 2 * x);
}

#[test]
fn topology_composes_with_min_delay_batching() {
    // nodes:4 under min-delay cadence: exchanges shrink by the epoch
    // AND each exchange still costs only N(N-1) fabric messages — the
    // two axes multiply.
    let pc = cfg(8, Routing::Filtered, ExchangeCadence::Step, Topology::Nodes(4));
    let bc = cfg(8, Routing::Filtered, ExchangeCadence::MinDelay, Topology::Nodes(4));
    let per_step = coordinator::run(&pc).unwrap();
    let batched = coordinator::run(&bc).unwrap();
    assert_eq!(per_step.pop_counts, batched.pop_counts);
    let steps = per_step.pop_counts.len() as u32;
    assert_eq!(exchanges(&per_step), steps as u64);
    assert_eq!(exchanges(&batched), expected_exchanges(steps, 4));
    assert_eq!(inter_messages(&per_step), 2 * steps as u64);
    assert_eq!(inter_messages(&batched), 2 * expected_exchanges(steps, 4));
}
