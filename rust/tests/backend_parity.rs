//! Native-vs-XLA backend parity: the AOT-compiled JAX/Pallas artifact and
//! the pure-rust implementation must advance the same network to the same
//! spike raster.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it)
//! and a build with real PJRT bindings: when `runtime::xla_available()`
//! is false (the offline `xla_stub` build) every test here skips itself.

use std::path::Path;

use dpsnn::config::{Backend, Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;

/// Returns true when the XLA path cannot run in this build; callers
/// `return` early, which `cargo test` reports as a pass (skip).
fn skip_without_runtime() -> bool {
    if dpsnn::runtime::xla_available() {
        return false;
    }
    eprintln!("skipping: PJRT bindings are stubbed out in this build");
    true
}

fn artifacts_available() -> bool {
    Path::new("artifacts").exists()
        && std::fs::read_dir("artifacts")
            .map(|mut d| d.any(|e| e.unwrap().file_name().to_string_lossy().ends_with(".hlo.txt")))
            .unwrap_or(false)
}

fn cfg(backend: Backend, procs: u32) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::tiny(1024);
    cfg.procs = procs;
    cfg.sim_seconds = 0.3;
    cfg.backend = backend;
    cfg.mode = Mode::Live;
    cfg
}

#[test]
fn xla_and_native_rasters_agree() {
    if skip_without_runtime() {
        return;
    }
    assert!(
        artifacts_available(),
        "artifacts/ missing — run `make artifacts` before `cargo test`"
    );
    let native = coordinator::run(&cfg(Backend::Native, 1)).unwrap();
    let xla = coordinator::run(&cfg(Backend::Xla, 1)).unwrap();
    assert!(native.total_spikes > 0);
    assert_eq!(
        native.pop_counts, xla.pop_counts,
        "XLA artifact and native rust diverged"
    );
    assert_eq!(native.total_syn_events, xla.total_syn_events);
}

#[test]
fn xla_backend_multi_rank() {
    if skip_without_runtime() {
        return;
    }
    assert!(artifacts_available(), "run `make artifacts` first");
    // each rank thread builds its own PJRT client (the client is not Send)
    let native = coordinator::run(&cfg(Backend::Native, 2)).unwrap();
    let xla = coordinator::run(&cfg(Backend::Xla, 2)).unwrap();
    assert_eq!(native.pop_counts, xla.pop_counts);
}

#[test]
fn xla_pads_population_to_artifact_rung() {
    if skip_without_runtime() {
        return;
    }
    assert!(artifacts_available(), "run `make artifacts` first");
    // 1000 is not an artifact rung: forces padding to 1024
    let mut c = cfg(Backend::Xla, 1);
    c.net = NetworkParams::tiny(1000);
    let r = coordinator::run(&c).unwrap();
    let mut cn = cfg(Backend::Native, 1);
    cn.net = NetworkParams::tiny(1000);
    let n = coordinator::run(&cn).unwrap();
    assert_eq!(r.pop_counts, n.pop_counts, "padding must be inert");
}
