//! Properties of the destination-filtered routing table
//! (`comm::routing`): the per-destination send buffers must carry
//! exactly the broadcast spike set restricted to ranks with local
//! postsynaptic targets, for any partitioning and connectivity shape.

use dpsnn::comm::routing::RoutingTable;
use dpsnn::engine::partition::Partition;
use dpsnn::model::connectivity::{ConnectivityParams, IncomingSynapses};
use dpsnn::util::prop::forall;

/// Union-of-buffers property: for every rank pair (src_rank, dst), the
/// set of sources the filter forwards equals the set of sources whose
/// incoming-synapse row at `dst` is non-empty (what broadcast would have
/// delivered to a non-trivial row).
#[test]
fn filtered_buffers_equal_broadcast_restricted_to_target_ranks() {
    forall("routing filter = restricted broadcast", 25, |rng| {
        let n = 16 + rng.next_below(100);
        let m = 1 + rng.next_below(12.min(n - 2));
        let p = 1 + rng.next_below(7);
        let cp = ConnectivityParams {
            seed: rng.next_u64(),
            n,
            m,
            dmin: 1,
            dmax: 4,
        };
        let part = Partition::even(n, p);
        let incoming: Vec<IncomingSynapses> = (0..p)
            .map(|r| {
                let (lo, hi) = part.range(r);
                IncomingSynapses::build(&cp, lo, hi)
            })
            .collect();
        for src_rank in 0..p {
            let table = RoutingTable::build(&cp, &part, src_rank);
            let (lo, hi) = part.range(src_rank);
            for dst in 0..p {
                // filtered: sources the table forwards to dst
                let sent: Vec<u32> = (lo..hi)
                    .filter(|&s| table.sends_to(s - lo, dst))
                    .collect();
                // broadcast restricted: sources with targets on dst
                let needed: Vec<u32> = (lo..hi)
                    .filter(|&s| !incoming[dst as usize].row(s).0.is_empty())
                    .collect();
                assert_eq!(
                    sent, needed,
                    "n={n} m={m} p={p} src_rank={src_rank} dst={dst}"
                );
            }
            // every source has m >= 1 targets, so it must reach >= 1 rank
            for s in lo..hi {
                assert!(table.rank_fanout(s - lo) >= 1, "source {s} routes nowhere");
            }
        }
    });
}

/// The rank-bitmap fan-out can never exceed the synapse fan-out (each
/// target adds at most one rank) nor the rank count.
#[test]
fn rank_fanout_is_bounded() {
    forall("routing fanout bounds", 25, |rng| {
        let n = 32 + rng.next_below(200);
        let m = 1 + rng.next_below(n / 2);
        let p = 1 + rng.next_below(15);
        let cp = ConnectivityParams {
            seed: rng.next_u64(),
            n,
            m,
            dmin: 1,
            dmax: 8,
        };
        let part = Partition::even(n, p);
        let rank = rng.next_below(p);
        let table = RoutingTable::build(&cp, &part, rank);
        for local in 0..table.n_local() {
            let fanout = table.rank_fanout(local);
            assert!(fanout >= 1 && fanout <= m.min(p));
            assert_eq!(fanout as usize, table.dest_ranks(local).count());
        }
        let mean = table.mean_rank_fanout();
        assert!(mean >= 1.0 && mean <= p as f64);
    });
}
