//! Per-job accounting for the resident simulation server.
//!
//! Each job that completes in `runtime::server` is condensed into a
//! [`JobReport`]: wall clock, the paper's headline J/synaptic-event
//! figure (same platform/power math as the `bench-smoke` subcommand),
//! and a SHA-256 fingerprint of the spike raster. The fingerprint is the
//! server's isolation receipt — a job run through the multi-tenant
//! scheduler must hash identically to the same config run solo.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::RunResult;
use crate::util::sha256;

use super::{joules_per_synaptic_event, SynapticEventCount};

/// SHA-256 over the per-step population spike counts, little-endian u32
/// wire order. Any change to spike timing or count anywhere in the run
/// changes this digest.
pub fn raster_hash(pop_counts: &[u32]) -> String {
    let mut h = sha256::Sha256::new();
    for &c in pop_counts {
        h.update(&c.to_le_bytes());
    }
    sha256::to_hex(&h.finalize())
}

/// Condensed per-job result streamed back to `serve` clients and written
/// into `BENCH_server.json`.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub name: String,
    pub wall_s: f64,
    pub sim_s: f64,
    pub total_spikes: u64,
    pub total_syn_events: u64,
    pub energy_j: f64,
    pub uj_per_syn_event: f64,
    pub raster_sha256: String,
}

impl JobReport {
    /// Price a finished run on the config's platform/interconnect models,
    /// mirroring the `bench-smoke` energy math (utilization = compute
    /// fraction of the component breakdown).
    pub fn from_result(name: &str, cfg: &RunConfig, r: &RunResult) -> Result<Self> {
        let platform = crate::platform::presets::platform_by_name(&cfg.platform)?;
        let link = crate::simnet::presets::interconnect_by_name(&cfg.interconnect)?;
        let power = crate::power::PowerModel::new(platform, link);
        let utilization = r.components.fractions().0;
        let energy_j = power.energy_to_solution_j(r.procs, utilization, r.wall_s);
        let events = SynapticEventCount::measured(r.total_syn_events, r.total_ext_events);
        let uj = joules_per_synaptic_event(energy_j, &events) * 1e6;
        Ok(Self {
            name: name.to_string(),
            wall_s: r.wall_s,
            sim_s: r.sim_s,
            total_spikes: r.total_spikes,
            total_syn_events: r.total_syn_events,
            energy_j,
            uj_per_syn_event: uj,
            raster_sha256: raster_hash(&r.pop_counts),
        })
    }

    /// One JSON object, hand-formatted (no serde offline).
    pub fn to_json(&self, indent: &str) -> String {
        format!(
            concat!(
                "{{\n",
                "{i}  \"name\": \"{name}\",\n",
                "{i}  \"wall_s\": {wall:.6},\n",
                "{i}  \"sim_s\": {sim:.3},\n",
                "{i}  \"total_spikes\": {spikes},\n",
                "{i}  \"total_syn_events\": {syn},\n",
                "{i}  \"energy_j\": {energy:.6},\n",
                "{i}  \"uj_per_syn_event\": {uj:.6},\n",
                "{i}  \"raster_sha256\": \"{hash}\"\n",
                "{i}}}"
            ),
            i = indent,
            name = self.name,
            wall = self.wall_s,
            sim = self.sim_s,
            spikes = self.total_spikes,
            syn = self.total_syn_events,
            energy = self.energy_j,
            uj = self.uj_per_syn_event,
            hash = self.raster_sha256,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raster_hash_is_order_and_value_sensitive() {
        let a = raster_hash(&[1, 2, 3]);
        assert_eq!(a, raster_hash(&[1, 2, 3]));
        assert_ne!(a, raster_hash(&[3, 2, 1]));
        assert_ne!(a, raster_hash(&[1, 2]));
        assert_ne!(a, raster_hash(&[1, 2, 4]));
        assert_eq!(a.len(), 64);
    }

    #[test]
    fn raster_hash_distinguishes_concatenation_ambiguity() {
        // [1, 256] and [256, 1] differ even though byte multisets match.
        assert_ne!(raster_hash(&[1, 256]), raster_hash(&[256, 1]));
    }
}
