//! Energy-efficiency metrics.

use super::synevents::SynapticEventCount;

/// The paper's Table IV metric: energy-to-solution divided by total
/// synaptic events, in microjoules per synaptic event.
pub fn joules_per_synaptic_event(energy_j: f64, events: &SynapticEventCount) -> f64 {
    energy_j / events.total()
}

/// Pretty µJ/event formatting used by the Table IV harness.
pub fn fmt_uj_per_event(energy_j: f64, events: &SynapticEventCount) -> String {
    format!("{:.1}", joules_per_synaptic_event(energy_j, events) * 1e6)
}

/// Published Compass/TrueNorth reference point (paper §V): 5.7 µJ per
/// synaptic event on a Core i7 950, baseline excluded.
pub const COMPASS_TRUENORTH_UJ: f64 = 5.7;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;

    #[test]
    fn arm_minimum_is_about_1p1_uj() {
        // Table III minimum 1110 J over 7.37e8 + ... events -> ~1.5 µJ;
        // the paper's 1.1 µJ divides by recurrent+external-ish counts.
        // Assert our formula on their numbers lands in the right decade.
        let net = NetworkParams::paper_20480();
        let ev = SynapticEventCount::expected(&net, 3.2, 10.0);
        let uj = joules_per_synaptic_event(1110.0, &ev) * 1e6;
        assert!((0.9..1.4).contains(&uj), "uj={uj}");
    }

    #[test]
    fn formatting() {
        let net = NetworkParams::paper_20480();
        let ev = SynapticEventCount::expected(&net, 3.2, 10.0);
        let s = fmt_uj_per_event(2500.0, &ev);
        assert!(s.parse::<f64>().is_ok());
    }
}
