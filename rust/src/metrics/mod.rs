//! Derived metrics: synaptic-event counts and the paper's headline
//! efficiency unit, joules per synaptic event.

pub mod synevents;
pub mod energy;

pub use energy::joules_per_synaptic_event;
pub use synevents::SynapticEventCount;
