//! Derived metrics: synaptic-event counts, the paper's headline
//! efficiency unit (joules per synaptic event), and per-rank
//! communication-volume accounting for the spike-routing study.

pub mod synevents;
pub mod energy;
pub mod comm_volume;
pub mod jobs;
pub mod memory;

pub use comm_volume::{
    expected_exchanges, pair_liveness, payload_level_bytes, predicted_payload_level_bytes,
    CommVolume,
};
pub use energy::joules_per_synaptic_event;
pub use jobs::{raster_hash, JobReport};
pub use memory::MemoryUse;
pub use synevents::SynapticEventCount;
