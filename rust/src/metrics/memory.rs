//! Analytic memory model for the per-rank resident state — the closed
//! forms behind `--connectivity auto` and the bench-smoke memory gate.
//!
//! Two stores dominate a rank's RAM at scale:
//!
//! * the incoming-synapse table — materialized, it is a delay-major CSR
//!   of every synapse whose target the rank owns:
//!   `(n + 1) * 4` bytes of row offsets plus `5` bytes per local synapse
//!   (u32 target + u8 delay), expected `m * n_local` local synapses
//!   under the homogeneous connectome. Procedural, it is O(state): the
//!   generator parameters plus the owned-interval list.
//! * the delay ring — dense, `(max_delay + 1) * stride` f32 accumulators
//!   (`stride` = n_local padded to a 64 B line); compressed, ONE such
//!   row plus per-(slot, chunk) event buckets whose capacity tracks the
//!   in-flight synaptic events, not the neuron count.
//!
//! Worked example (the 100× acceptance point): n = 2_000_000 neurons,
//! m = 1125, one rank. Materialized synapses cost
//! `(n+1)*4 + n*m*5 ≈ 11.3 GB` — past any per-rank budget this repo
//! targets — while the procedural store is a few dozen bytes and the
//! compressed ring ~8 MB of current-row accumulators. That is what
//! `metrics::memory` predicts, `RankEngine::memory_use` measures, and
//! the BENCH_memory.json gate pins.

use crate::config::{ConnectivityMode, NetworkParams};
use crate::engine::partition::OwnedGids;
use crate::model::connectivity::ConnectivityParams;
use crate::util::aligned::LANES_PER_LINE;

/// Default per-rank budget for the synapse + ring stores when
/// `--connectivity auto` asks the memory model to choose: 2 GiB,
/// comfortably inside one commodity node's share per rank. Materialized
/// tables that the closed form prices above this resolve to procedural.
pub const DEFAULT_RANK_BUDGET_BYTES: u64 = 2 << 30;

/// Measured resident bytes of one rank's scale-dominant stores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemoryUse {
    /// Incoming-synapse store (CSR table or procedural generator).
    pub synapse_bytes: u64,
    /// Delay-ring store (dense grid or compressed buckets).
    pub ring_bytes: u64,
    /// Transient delivery scratch (the procedural mode's regenerated
    /// row CSR). Scales with one delivery batch's events — a burst can
    /// briefly inflate it — so it is reported here and in `total()`,
    /// but excluded from the O(state) gate on the persistent store.
    pub scratch_bytes: u64,
}

impl MemoryUse {
    pub fn total(&self) -> u64 {
        self.synapse_bytes + self.ring_bytes + self.scratch_bytes
    }
}

/// Slot-row pitch of the delay rings: `n_local` f32 lanes padded up to
/// a whole 64 B cache line (mirrors `DelayRing::new`).
fn ring_stride(n_local: u32) -> u64 {
    (n_local as u64).div_ceil(LANES_PER_LINE as u64).max(1) * LANES_PER_LINE as u64
}

/// Expected resident bytes of the materialized [`IncomingSynapses`]
/// CSR for a rank owning `n_local` of `n` neurons: `(n + 1) * 4` row
/// offsets plus 5 bytes per expected local synapse (`m * n_local` —
/// each of the `n * m` synapses targets this rank with probability
/// `n_local / n` under the homogeneous connectome). The realized count
/// is stochastic; callers compare within a tolerance.
///
/// [`IncomingSynapses`]: crate::model::connectivity::IncomingSynapses
pub fn materialized_synapse_bytes(n: u32, m: u32, n_local: u32) -> u64 {
    (n as u64 + 1) * 4 + m as u64 * n_local as u64 * 5
}

/// Exact resident bytes of the procedural synapse store for a rank
/// owning `intervals` gid intervals: the generator parameters, the
/// owned-set header, and the interval list. O(state) — no term scales
/// with the synapse count (mirrors `ProceduralSynapses::resident_bytes`).
pub fn procedural_synapse_bytes(intervals: usize) -> u64 {
    (std::mem::size_of::<ConnectivityParams>()
        + std::mem::size_of::<OwnedGids>()
        + intervals * std::mem::size_of::<(u32, u32)>()) as u64
}

/// Exact resident bytes of the dense delay ring:
/// `(max_delay + 1) * stride` f32 accumulators.
pub fn dense_ring_bytes(n_local: u32, max_delay: u32) -> u64 {
    (max_delay as u64 + 1) * ring_stride(n_local) * 4
}

/// Resident bytes of an idle compressed delay ring: one dense
/// current row plus `(max_delay + 1) * chunks` empty bucket headers.
/// Steady-state adds the in-flight event capacity (8 bytes per queued
/// `(target, weight)`), which tracks activity, not the neuron count.
pub fn compressed_ring_bytes_idle(n_local: u32, max_delay: u32, chunks: u32) -> u64 {
    ring_stride(n_local) * 4
        + (max_delay as u64 + 1)
            * chunks as u64
            * std::mem::size_of::<Vec<(u32, f32)>>() as u64
}

/// Expected in-flight synaptic events in steady state at `rate_hz`:
/// each of the `n * m` synapses carries `rate_hz * mean_delay * dt`
/// undelivered weights on average. The compressed ring's bucket
/// capacity converges to (a small multiple of) this.
pub fn expected_inflight_events(net: &NetworkParams, n_local: u32, rate_hz: f64) -> f64 {
    let mean_delay = (net.delay_min_steps + net.delay_max_steps) as f64 / 2.0;
    net.n_neurons as f64 * net.syn_per_neuron as f64 * (n_local as f64 / net.n_neurons as f64)
        * rate_hz
        * mean_delay
        * net.dt_ms
        * 1e-3
}

/// The closed-form per-rank stores for either mode, for a rank owning
/// `n_local` neurons in one contiguous interval — the planner's
/// pricing input, the modeled runs' memory report and the whatif
/// tables' memory column.
pub fn predicted_memory_use(
    net: &NetworkParams,
    n_local: u32,
    mode: ConnectivityMode,
) -> MemoryUse {
    match mode {
        ConnectivityMode::Materialized => MemoryUse {
            synapse_bytes: materialized_synapse_bytes(net.n_neurons, net.syn_per_neuron, n_local),
            ring_bytes: dense_ring_bytes(n_local, net.delay_max_steps),
            scratch_bytes: 0,
        },
        ConnectivityMode::Procedural => MemoryUse {
            synapse_bytes: procedural_synapse_bytes(1),
            ring_bytes: compressed_ring_bytes_idle(n_local, net.delay_max_steps, 1),
            scratch_bytes: 0,
        },
    }
}

/// [`predicted_memory_use`] collapsed to a per-rank byte total.
pub fn predicted_rank_bytes(net: &NetworkParams, n_local: u32, mode: ConnectivityMode) -> u64 {
    predicted_memory_use(net, n_local, mode).total()
}

/// Resolve `--connectivity auto`: materialized while its closed-form
/// per-rank bytes (at the largest even-split rank) fit the budget,
/// procedural beyond it. Deterministic — a pure function of the network
/// shape and the rank count, so resolved runs replay exactly.
pub fn auto_connectivity_mode(net: &NetworkParams, procs: u32, budget_bytes: u64) -> ConnectivityMode {
    let n_local_max = net.n_neurons.div_ceil(procs.max(1));
    if predicted_rank_bytes(net, n_local_max, ConnectivityMode::Materialized) <= budget_bytes {
        ConnectivityMode::Materialized
    } else {
        ConnectivityMode::Procedural
    }
}

/// The bench-smoke / CI gate: a procedural rank's measured persistent
/// synapse store (`synapse_bytes` — the generator, NOT the transient
/// delivery scratch, which scales with batch activity) must be
/// O(state), never the O(synapse) table. Concretely: at most
/// `max(64 KiB, 1/8 of the materialized closed form)` (the honest
/// store sits orders of magnitude below either bound; a materialized
/// table sneaking in under the procedural flag sits at ratio 1).
/// Panics with the offending sizes on violation — the
/// seeded-regression test injects exactly that and expects this panic.
pub fn assert_procedural_state_bound(mem: &MemoryUse, m: u32, n_local: u32) {
    let materialized_scale = m as u64 * n_local as u64 * 5;
    let ceiling = (materialized_scale / 8).max(64 * 1024);
    assert!(
        mem.synapse_bytes <= ceiling,
        "procedural synapse store is not O(state): {} B resident vs \
         materialized closed form {} B (gate {} B; m={m}, n_local={n_local})",
        mem.synapse_bytes,
        materialized_scale,
        ceiling,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worked_2m_example_matches_the_docs() {
        // The ARCHITECTURE.md worked example: 2M neurons, M=1125, one
        // rank. Materialized ~11.3 GB, procedural store O(100 B).
        let mat = materialized_synapse_bytes(2_000_000, 1125, 2_000_000);
        assert!(mat > 11_000_000_000 && mat < 11_500_000_000, "{mat}");
        assert!(procedural_synapse_bytes(1) < 256);
        // dense ring at 2M/17 slots ~ 136 MB; compressed current row ~ 8 MB
        let dense = dense_ring_bytes(2_000_000, 16);
        assert!(dense > 130_000_000 && dense < 140_000_000, "{dense}");
        let comp = compressed_ring_bytes_idle(2_000_000, 16, 1);
        assert!(comp < dense / 10, "{comp} vs {dense}");
    }

    #[test]
    fn auto_mode_flips_at_the_budget() {
        let small = NetworkParams::tiny(1024);
        assert_eq!(
            auto_connectivity_mode(&small, 1, DEFAULT_RANK_BUDGET_BYTES),
            ConnectivityMode::Materialized
        );
        let big = NetworkParams::paper(2_000_000);
        assert_eq!(
            auto_connectivity_mode(&big, 1, DEFAULT_RANK_BUDGET_BYTES),
            ConnectivityMode::Procedural
        );
        // enough ranks spread the table back under the budget
        assert_eq!(
            auto_connectivity_mode(&big, 64, DEFAULT_RANK_BUDGET_BYTES),
            ConnectivityMode::Materialized
        );
        // deterministic: same inputs, same answer
        assert_eq!(
            auto_connectivity_mode(&big, 1, DEFAULT_RANK_BUDGET_BYTES),
            auto_connectivity_mode(&big, 1, DEFAULT_RANK_BUDGET_BYTES)
        );
    }

    #[test]
    fn state_bound_gate_accepts_honest_procedural_sizes() {
        let mem = MemoryUse {
            synapse_bytes: procedural_synapse_bytes(3),
            ring_bytes: 4096,
            scratch_bytes: 1 << 20,
        };
        // a burst-inflated delivery scratch never trips the gate on the
        // persistent store — only synapse_bytes is state-bound
        assert_procedural_state_bound(&mem, 1125, 2_000_000);
        assert_eq!(mem.total(), mem.synapse_bytes + 4096 + (1 << 20));
    }

    #[test]
    #[should_panic(expected = "not O(state)")]
    fn state_bound_gate_fails_loudly_on_a_materialized_store() {
        // Seeded regression: a materialized-sized table sneaking in
        // under the procedural flag must trip the gate.
        let mem = MemoryUse {
            synapse_bytes: materialized_synapse_bytes(20_480, 1125, 20_480),
            ring_bytes: 0,
            scratch_bytes: 0,
        };
        assert_procedural_state_bound(&mem, 1125, 20_480);
    }
}
