//! Synaptic-event accounting.
//!
//! Paper §V: "The total number of synaptic events is the product of the
//! number of neurons, the number of synapses per neuron, the average
//! firing rate and the total simulation time."

use crate::config::NetworkParams;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynapticEventCount {
    pub recurrent: f64,
    pub external: f64,
}

impl SynapticEventCount {
    /// Expected counts for a run at the given mean firing rate.
    pub fn expected(net: &NetworkParams, rate_hz: f64, sim_seconds: f64) -> Self {
        let n = net.n_neurons as f64;
        Self {
            recurrent: n * net.syn_per_neuron as f64 * rate_hz * sim_seconds,
            external: n * net.ext_syn_per_neuron as f64 * net.ext_rate_hz * sim_seconds,
        }
    }

    /// From measured engine counters.
    pub fn measured(recurrent: u64, external: u64) -> Self {
        Self { recurrent: recurrent as f64, external: external as f64 }
    }

    /// The Table IV denominator: recurrent + external synaptic events
    /// (this is the division that lands the paper's own numbers on
    /// 1.1 / 3.4 uJ per synaptic event).
    pub fn total(&self) -> f64 {
        self.recurrent + self.external
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_matches_paper_arithmetic() {
        // 20480 x 1125 x 3.2 Hz x 10 s = 7.37e8
        let net = NetworkParams::paper_20480();
        let c = SynapticEventCount::expected(&net, 3.2, 10.0);
        assert!((c.recurrent - 7.3728e8).abs() / 7.3728e8 < 1e-12);
        // external: 20480 x 400 x 3 Hz x 10 s = 2.4576e8
        assert!((c.external - 2.4576e8).abs() / 2.4576e8 < 1e-12);
    }
}
