//! Per-rank communication-volume accounting and the analytic traffic
//! expectations used by the harnesses.
//!
//! Live runs accumulate one [`CommVolume`] per rank from the transport's
//! [`ExchangeStats`]; modeled runs and the fig2/table1 harnesses use the
//! closed-form expectations below to compare broadcast with
//! destination-filtered routing without running the network.

use crate::comm::aer::SPIKE_WIRE_BYTES;
use crate::comm::topology::TopologyTree;
use crate::comm::transport::ExchangeStats;
use crate::engine::partition::Partition;
use crate::model::connectivity::ConnectivityParams;

/// Bytes/messages a rank moved through the transport over a whole run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CommVolume {
    /// Payload bytes sent to other ranks (self excluded).
    pub bytes_sent: u64,
    /// Payload bytes delivered to this rank, loopback block included
    /// (see [`ExchangeStats`]).
    pub bytes_recv: u64,
    /// Messages sent (envelopes included; `intra_messages +
    /// inter_messages`, see [`ExchangeStats`]).
    pub messages: u64,
    /// Messages that stayed inside the rank's (virtual) node: direct
    /// posts to same-node peers plus the gather message to the node
    /// leader. Zero under the flat topology, which has no node notion.
    pub intra_messages: u64,
    /// Messages that crossed nodes: every peer message under the flat
    /// topology, only the leaders' aggregated node-pair messages under
    /// `--topology nodes:<k>` — the count the hierarchical transport
    /// collapses from `P(P−1)` to `N(N−1)` per exchange.
    pub inter_messages: u64,
    /// Bytes carried by `intra_messages`.
    pub intra_bytes: u64,
    /// Bytes carried by `inter_messages`.
    pub inter_bytes: u64,
    /// Messages sent per link level of the topology tree (index 0 =
    /// intra-board; index `g` = crossing level-`g` group boundaries —
    /// see [`crate::comm::topology::TopologyTree`]). Empty under the
    /// flat topology. Summed over ranks and divided by `exchanges`,
    /// each level equals the tree's closed form exactly.
    pub level_messages: Vec<u64>,
    /// Bytes carried per link level (same indexing).
    pub level_bytes: Vec<u64>,
    /// Transport exchanges (all-to-all collectives) this rank took part
    /// in: one per step under per-step cadence, one per delay epoch
    /// under epoch batching. Each exchange is followed by exactly one
    /// barrier, so this is also the rank's barrier count.
    pub exchanges: u64,
    /// Cumulative payload bytes posted per destination rank — this
    /// rank's row of the run-total traffic matrix.
    pub per_dst_bytes: Vec<u64>,
}

impl CommVolume {
    /// Fold one exchange's accounting into the run totals.
    pub fn observe(&mut self, stats: &ExchangeStats) {
        self.bytes_sent += stats.bytes_sent;
        self.bytes_recv += stats.bytes_recv;
        self.messages += stats.messages;
        self.intra_messages += stats.intra_messages;
        self.inter_messages += stats.inter_messages;
        self.intra_bytes += stats.intra_bytes;
        self.inter_bytes += stats.inter_bytes;
        self.exchanges += 1;
        if self.per_dst_bytes.len() < stats.per_dst_bytes.len() {
            self.per_dst_bytes.resize(stats.per_dst_bytes.len(), 0);
        }
        for (acc, &b) in self.per_dst_bytes.iter_mut().zip(&stats.per_dst_bytes) {
            *acc += b;
        }
        if self.level_messages.len() < stats.level_messages.len() {
            self.level_messages.resize(stats.level_messages.len(), 0);
        }
        for (acc, &m) in self.level_messages.iter_mut().zip(&stats.level_messages) {
            *acc += m;
        }
        if self.level_bytes.len() < stats.level_bytes.len() {
            self.level_bytes.resize(stats.level_bytes.len(), 0);
        }
        for (acc, &b) in self.level_bytes.iter_mut().zip(&stats.level_bytes) {
            *acc += b;
        }
    }
}

/// Exchanges (and barriers) a run of `steps` steps performs under an
/// `epoch_steps`-step cadence: the last epoch may be short, so this is
/// the ceiling division — the ~`delay_min_steps`× reduction the
/// epoch-batched protocol buys.
pub fn expected_exchanges(steps: u32, epoch_steps: u32) -> u64 {
    steps.div_ceil(epoch_steps.max(1)) as u64
}

/// The realized pair-liveness matrix of a concrete placement:
/// `live[a][b]` = sources owned by rank `a` with at least one
/// postsynaptic target on rank `b` (including `a == b`). Under filtered
/// routing a spike from rank `a` puts bytes on the `a → b` wire iff its
/// source is live toward `b`, so `live[a][b] / size(a)` is the exact
/// per-spike traffic probability the placement realizes — the
/// partition-*dependent* counterpart of the expectation
/// [`pair_coverage`], and what comm-aware placement actually moves.
///
/// Cost: one full n×m sweep of the stateless connectome.
pub fn pair_liveness(cp: &ConnectivityParams, part: &Partition) -> Vec<Vec<u64>> {
    assert_eq!(cp.n, part.n_total(), "connectome/partition size mismatch");
    let p = part.n_ranks() as usize;
    let mut live = vec![vec![0u64; p]; p];
    let mut hit = vec![false; p];
    for s in 0..cp.n {
        let a = part.owner(s) as usize;
        hit.iter_mut().for_each(|h| *h = false);
        for k in 0..cp.m {
            let (t, _) = cp.synapse(s, k);
            let b = part.owner(t) as usize;
            if !hit[b] {
                hit[b] = true;
                live[a][b] += 1;
            }
        }
    }
    live
}

/// Split the run-total per-pair payload matrix accumulated in
/// `per_rank[src].per_dst_bytes[dst]` by the topology tree's link
/// levels (index 0 = intra-board). Loopback slots (`src == dst`) are
/// excluded — this is the payload the placement actually put on each
/// fabric tier, the measured side of the placement-pricing check.
pub fn payload_level_bytes(per_rank: &[CommVolume], tree: &TopologyTree) -> Vec<u64> {
    let mut lv = vec![0u64; tree.depth() + 1];
    for (src, v) in per_rank.iter().enumerate() {
        for (dst, &b) in v.per_dst_bytes.iter().enumerate() {
            if src != dst && b > 0 {
                lv[tree.link_level(src as u32, dst as u32)] += b;
            }
        }
    }
    lv
}

/// Predicted per-link-level payload bytes of a whole run under
/// *filtered* routing, from the placement's realized liveness matrix
/// and the observed per-rank spike totals: rank `a` emitting `S_a`
/// spikes puts `12 · S_a · live[a][b] / size(a)` expected bytes on the
/// `a → b` wire (sources spike near-uniformly under the homogeneous
/// drive). Compare against the measured [`payload_level_bytes`] — the
/// `simnet`-side prediction the bench checks to ~percent accuracy.
pub fn predicted_payload_level_bytes(
    cp: &ConnectivityParams,
    part: &Partition,
    rank_spikes: &[u64],
    tree: &TopologyTree,
) -> Vec<f64> {
    let p = part.n_ranks() as usize;
    assert_eq!(rank_spikes.len(), p, "need one spike total per rank");
    let live = pair_liveness(cp, part);
    let mut lv = vec![0.0f64; tree.depth() + 1];
    for a in 0..p {
        let size = part.size(a as u32) as f64;
        for b in 0..p {
            if a == b {
                continue;
            }
            let frac = live[a][b] as f64 / size;
            lv[tree.link_level(a as u32, b as u32)] +=
                SPIKE_WIRE_BYTES as f64 * rank_spikes[a] as f64 * frac;
        }
    }
    lv
}

/// Probability that a source neuron projects to at least one neuron of a
/// `block_size`-neuron rank, with `m` targets drawn uniformly from the
/// other `n - 1` neurons: `1 - (1 - block/(n-1))^m`.
///
/// This is the expected fraction of (source neuron, destination rank)
/// pairs the destination filter keeps. It is ~1 for `m >> p` (dense
/// connectivity degenerates to broadcast) and drops toward `m / p` once
/// the rank count passes the fan-out.
pub fn pair_coverage(n: u32, m: u32, block_size: f64) -> f64 {
    if n <= 1 {
        return 1.0;
    }
    let q = (block_size / (n as f64 - 1.0)).clamp(0.0, 1.0);
    1.0 - (1.0 - q).powf(m as f64)
}

/// Mean pair coverage over an even `procs`-way partition of `n` neurons.
pub fn mean_pair_coverage(n: u32, m: u32, procs: u32) -> f64 {
    if procs <= 1 {
        return 1.0;
    }
    pair_coverage(n, m, n as f64 / procs as f64)
}

/// Expected payload bytes one rank receives from the *other* ranks over
/// a run emitting `total_spikes`, under broadcast or filtered routing
/// (uniform emission across ranks; loopback excluded so the two
/// protocols are compared on network traffic alone).
pub fn expected_recv_bytes_per_rank(
    n: u32,
    m: u32,
    procs: u32,
    total_spikes: u64,
    filtered: bool,
) -> f64 {
    if procs <= 1 {
        return 0.0;
    }
    let from_others =
        total_spikes as f64 * (procs as f64 - 1.0) / procs as f64 * SPIKE_WIRE_BYTES as f64;
    if filtered {
        from_others * mean_pair_coverage(n, m, procs)
    } else {
        from_others
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_accumulates() {
        let mut v = CommVolume::default();
        v.observe(&ExchangeStats {
            bytes_sent: 10,
            bytes_recv: 14,
            messages: 3,
            intra_messages: 2,
            inter_messages: 1,
            intra_bytes: 6,
            inter_bytes: 4,
            level_messages: vec![2, 1],
            level_bytes: vec![6, 4],
            per_dst_bytes: vec![4, 0, 6, 4],
        });
        v.observe(&ExchangeStats {
            bytes_sent: 2,
            bytes_recv: 2,
            messages: 3,
            intra_messages: 1,
            inter_messages: 2,
            intra_bytes: 2,
            inter_bytes: 0,
            level_messages: vec![1, 1, 1],
            level_bytes: vec![2, 0, 0],
            per_dst_bytes: vec![0, 2, 0, 0],
        });
        assert_eq!(v.bytes_sent, 12);
        assert_eq!(v.bytes_recv, 16);
        assert_eq!(v.messages, 6);
        assert_eq!(v.intra_messages, 3);
        assert_eq!(v.inter_messages, 3);
        assert_eq!(v.intra_bytes, 8);
        assert_eq!(v.inter_bytes, 4);
        assert_eq!(v.exchanges, 2, "one exchange per observe()");
        assert_eq!(v.per_dst_bytes, vec![4, 2, 6, 4]);
        // per-level columns widen to the deepest tree observed
        assert_eq!(v.level_messages, vec![3, 2, 1]);
        assert_eq!(v.level_bytes, vec![8, 4, 0]);
    }

    #[test]
    fn pair_liveness_matches_the_incoming_rows() {
        // live[a][b] must equal the number of rank-a sources whose
        // incoming row on rank b is non-empty — liveness and the CSR
        // build are two views of the same stateless generator.
        use crate::config::PartitionPolicy;
        use crate::engine::partition::AllocContext;
        use crate::model::connectivity::IncomingSynapses;
        let cp = ConnectivityParams { seed: 5, n: 96, m: 3, dmin: 1, dmax: 4 };
        for policy in [PartitionPolicy::Index, PartitionPolicy::RoundRobin] {
            let part = Partition::allocate(policy, 96, 4, &AllocContext::empty());
            let live = pair_liveness(&cp, &part);
            let incoming: Vec<IncomingSynapses> = (0..4)
                .map(|r| IncomingSynapses::build_owned(&cp, part.owned(r)))
                .collect();
            for a in 0..4u32 {
                for b in 0..4u32 {
                    let want = part
                        .owned(a)
                        .iter()
                        .filter(|&s| !incoming[b as usize].row(s).0.is_empty())
                        .count() as u64;
                    assert_eq!(
                        live[a as usize][b as usize],
                        want,
                        "{policy:?} a={a} b={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn payload_level_split_conserves_off_diagonal_bytes() {
        // tree(4, [2]): boards {0,1} and {2,3} — 0↔1 and 2↔3 are level
        // 0, everything across the board boundary is level 1.
        let tree = TopologyTree::new(4, &[2]);
        let mut v0 = CommVolume::default();
        v0.per_dst_bytes = vec![99, 10, 20, 30]; // self slot must be ignored
        let mut v1 = CommVolume::default();
        v1.per_dst_bytes = vec![5, 0, 7, 0];
        let lv = payload_level_bytes(&[v0.clone(), v1.clone()], &tree);
        assert_eq!(lv, vec![15, 57]);
        let total_off_diag: u64 = lv.iter().sum();
        let manual: u64 = [&v0, &v1]
            .iter()
            .enumerate()
            .flat_map(|(src, v)| {
                v.per_dst_bytes
                    .iter()
                    .enumerate()
                    .filter(move |&(dst, _)| dst != src)
                    .map(|(_, &b)| b)
            })
            .sum();
        assert_eq!(total_off_diag, manual);
    }

    #[test]
    fn predicted_bytes_are_exact_when_every_source_spikes_once() {
        // If every neuron of rank a spikes exactly once, the filtered
        // payload a→b is exactly 12 · live[a][b] bytes; feeding
        // rank_spikes = sizes must reproduce that, split by level.
        let cp = ConnectivityParams { seed: 11, n: 64, m: 2, dmin: 1, dmax: 4 };
        let part = Partition::even(64, 4);
        let tree = TopologyTree::new(4, &[2]);
        let live = pair_liveness(&cp, &part);
        let sizes: Vec<u64> = (0..4).map(|r| part.size(r) as u64).collect();
        let pred = predicted_payload_level_bytes(&cp, &part, &sizes, &tree);
        let mut want = vec![0.0f64; 2];
        for a in 0..4usize {
            for b in 0..4usize {
                if a != b {
                    want[tree.link_level(a as u32, b as u32)] +=
                        (SPIKE_WIRE_BYTES as u64 * live[a][b]) as f64;
                }
            }
        }
        for (lv, (&p, &w)) in pred.iter().zip(want.iter()).enumerate() {
            assert!((p - w).abs() < 1e-6, "level {lv}: pred {p} want {w}");
        }
    }

    #[test]
    fn expected_exchanges_is_ceil_division() {
        assert_eq!(expected_exchanges(100, 1), 100);
        assert_eq!(expected_exchanges(100, 16), 7); // 6 full epochs + a short one
        assert_eq!(expected_exchanges(32, 16), 2);
        assert_eq!(expected_exchanges(0, 16), 0);
        assert_eq!(expected_exchanges(5, 0), 5, "zero epoch = per-step");
    }

    #[test]
    fn coverage_limits() {
        // dense: M >> P -> ~1 (broadcast degeneration)
        assert!(mean_pair_coverage(20_480, 1125, 8) > 0.999_999);
        // sparse: one target, P ranks -> ~1/P
        let c = mean_pair_coverage(1024, 1, 8);
        assert!((c - 1.0 / 8.0).abs() < 0.01, "c={c}");
        // single rank sees everything
        assert_eq!(mean_pair_coverage(1024, 16, 1), 1.0);
        // coverage shrinks as P grows past the fan-out
        let c64 = mean_pair_coverage(20_480, 32, 64);
        let c512 = mean_pair_coverage(20_480, 32, 512);
        assert!(c512 < c64 && c64 < 1.0, "c64={c64} c512={c512}");
    }

    #[test]
    fn expected_bytes_filtered_never_exceeds_broadcast() {
        for p in [2u32, 8, 64, 256] {
            let b = expected_recv_bytes_per_rank(20_480, 1125, p, 1_000_000, false);
            let f = expected_recv_bytes_per_rank(20_480, 1125, p, 1_000_000, true);
            assert!(f <= b, "p={p}: filtered {f} > broadcast {b}");
            assert!(b > 0.0);
        }
        let sparse_b = expected_recv_bytes_per_rank(1024, 4, 16, 1000, false);
        let sparse_f = expected_recv_bytes_per_rank(1024, 4, 16, 1000, true);
        assert!(sparse_f < 0.5 * sparse_b, "sparse nets filter hard");
    }
}
