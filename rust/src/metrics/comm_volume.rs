//! Per-rank communication-volume accounting and the analytic traffic
//! expectations used by the harnesses.
//!
//! Live runs accumulate one [`CommVolume`] per rank from the transport's
//! [`ExchangeStats`]; modeled runs and the fig2/table1 harnesses use the
//! closed-form expectations below to compare broadcast with
//! destination-filtered routing without running the network.

use crate::comm::aer::SPIKE_WIRE_BYTES;
use crate::comm::transport::ExchangeStats;

/// Bytes/messages a rank moved through the transport over a whole run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CommVolume {
    /// Payload bytes sent to other ranks (self excluded).
    pub bytes_sent: u64,
    /// Payload bytes delivered to this rank, loopback block included
    /// (see [`ExchangeStats`]).
    pub bytes_recv: u64,
    /// Messages sent (envelopes included; `intra_messages +
    /// inter_messages`, see [`ExchangeStats`]).
    pub messages: u64,
    /// Messages that stayed inside the rank's (virtual) node: direct
    /// posts to same-node peers plus the gather message to the node
    /// leader. Zero under the flat topology, which has no node notion.
    pub intra_messages: u64,
    /// Messages that crossed nodes: every peer message under the flat
    /// topology, only the leaders' aggregated node-pair messages under
    /// `--topology nodes:<k>` — the count the hierarchical transport
    /// collapses from `P(P−1)` to `N(N−1)` per exchange.
    pub inter_messages: u64,
    /// Bytes carried by `intra_messages`.
    pub intra_bytes: u64,
    /// Bytes carried by `inter_messages`.
    pub inter_bytes: u64,
    /// Messages sent per link level of the topology tree (index 0 =
    /// intra-board; index `g` = crossing level-`g` group boundaries —
    /// see [`crate::comm::topology::TopologyTree`]). Empty under the
    /// flat topology. Summed over ranks and divided by `exchanges`,
    /// each level equals the tree's closed form exactly.
    pub level_messages: Vec<u64>,
    /// Bytes carried per link level (same indexing).
    pub level_bytes: Vec<u64>,
    /// Transport exchanges (all-to-all collectives) this rank took part
    /// in: one per step under per-step cadence, one per delay epoch
    /// under epoch batching. Each exchange is followed by exactly one
    /// barrier, so this is also the rank's barrier count.
    pub exchanges: u64,
    /// Cumulative payload bytes posted per destination rank — this
    /// rank's row of the run-total traffic matrix.
    pub per_dst_bytes: Vec<u64>,
}

impl CommVolume {
    /// Fold one exchange's accounting into the run totals.
    pub fn observe(&mut self, stats: &ExchangeStats) {
        self.bytes_sent += stats.bytes_sent;
        self.bytes_recv += stats.bytes_recv;
        self.messages += stats.messages;
        self.intra_messages += stats.intra_messages;
        self.inter_messages += stats.inter_messages;
        self.intra_bytes += stats.intra_bytes;
        self.inter_bytes += stats.inter_bytes;
        self.exchanges += 1;
        if self.per_dst_bytes.len() < stats.per_dst_bytes.len() {
            self.per_dst_bytes.resize(stats.per_dst_bytes.len(), 0);
        }
        for (acc, &b) in self.per_dst_bytes.iter_mut().zip(&stats.per_dst_bytes) {
            *acc += b;
        }
        if self.level_messages.len() < stats.level_messages.len() {
            self.level_messages.resize(stats.level_messages.len(), 0);
        }
        for (acc, &m) in self.level_messages.iter_mut().zip(&stats.level_messages) {
            *acc += m;
        }
        if self.level_bytes.len() < stats.level_bytes.len() {
            self.level_bytes.resize(stats.level_bytes.len(), 0);
        }
        for (acc, &b) in self.level_bytes.iter_mut().zip(&stats.level_bytes) {
            *acc += b;
        }
    }
}

/// Exchanges (and barriers) a run of `steps` steps performs under an
/// `epoch_steps`-step cadence: the last epoch may be short, so this is
/// the ceiling division — the ~`delay_min_steps`× reduction the
/// epoch-batched protocol buys.
pub fn expected_exchanges(steps: u32, epoch_steps: u32) -> u64 {
    steps.div_ceil(epoch_steps.max(1)) as u64
}

/// Probability that a source neuron projects to at least one neuron of a
/// `block_size`-neuron rank, with `m` targets drawn uniformly from the
/// other `n - 1` neurons: `1 - (1 - block/(n-1))^m`.
///
/// This is the expected fraction of (source neuron, destination rank)
/// pairs the destination filter keeps. It is ~1 for `m >> p` (dense
/// connectivity degenerates to broadcast) and drops toward `m / p` once
/// the rank count passes the fan-out.
pub fn pair_coverage(n: u32, m: u32, block_size: f64) -> f64 {
    if n <= 1 {
        return 1.0;
    }
    let q = (block_size / (n as f64 - 1.0)).clamp(0.0, 1.0);
    1.0 - (1.0 - q).powf(m as f64)
}

/// Mean pair coverage over an even `procs`-way partition of `n` neurons.
pub fn mean_pair_coverage(n: u32, m: u32, procs: u32) -> f64 {
    if procs <= 1 {
        return 1.0;
    }
    pair_coverage(n, m, n as f64 / procs as f64)
}

/// Expected payload bytes one rank receives from the *other* ranks over
/// a run emitting `total_spikes`, under broadcast or filtered routing
/// (uniform emission across ranks; loopback excluded so the two
/// protocols are compared on network traffic alone).
pub fn expected_recv_bytes_per_rank(
    n: u32,
    m: u32,
    procs: u32,
    total_spikes: u64,
    filtered: bool,
) -> f64 {
    if procs <= 1 {
        return 0.0;
    }
    let from_others =
        total_spikes as f64 * (procs as f64 - 1.0) / procs as f64 * SPIKE_WIRE_BYTES as f64;
    if filtered {
        from_others * mean_pair_coverage(n, m, procs)
    } else {
        from_others
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_accumulates() {
        let mut v = CommVolume::default();
        v.observe(&ExchangeStats {
            bytes_sent: 10,
            bytes_recv: 14,
            messages: 3,
            intra_messages: 2,
            inter_messages: 1,
            intra_bytes: 6,
            inter_bytes: 4,
            level_messages: vec![2, 1],
            level_bytes: vec![6, 4],
            per_dst_bytes: vec![4, 0, 6, 4],
        });
        v.observe(&ExchangeStats {
            bytes_sent: 2,
            bytes_recv: 2,
            messages: 3,
            intra_messages: 1,
            inter_messages: 2,
            intra_bytes: 2,
            inter_bytes: 0,
            level_messages: vec![1, 1, 1],
            level_bytes: vec![2, 0, 0],
            per_dst_bytes: vec![0, 2, 0, 0],
        });
        assert_eq!(v.bytes_sent, 12);
        assert_eq!(v.bytes_recv, 16);
        assert_eq!(v.messages, 6);
        assert_eq!(v.intra_messages, 3);
        assert_eq!(v.inter_messages, 3);
        assert_eq!(v.intra_bytes, 8);
        assert_eq!(v.inter_bytes, 4);
        assert_eq!(v.exchanges, 2, "one exchange per observe()");
        assert_eq!(v.per_dst_bytes, vec![4, 2, 6, 4]);
        // per-level columns widen to the deepest tree observed
        assert_eq!(v.level_messages, vec![3, 2, 1]);
        assert_eq!(v.level_bytes, vec![8, 4, 0]);
    }

    #[test]
    fn expected_exchanges_is_ceil_division() {
        assert_eq!(expected_exchanges(100, 1), 100);
        assert_eq!(expected_exchanges(100, 16), 7); // 6 full epochs + a short one
        assert_eq!(expected_exchanges(32, 16), 2);
        assert_eq!(expected_exchanges(0, 16), 0);
        assert_eq!(expected_exchanges(5, 0), 5, "zero epoch = per-step");
    }

    #[test]
    fn coverage_limits() {
        // dense: M >> P -> ~1 (broadcast degeneration)
        assert!(mean_pair_coverage(20_480, 1125, 8) > 0.999_999);
        // sparse: one target, P ranks -> ~1/P
        let c = mean_pair_coverage(1024, 1, 8);
        assert!((c - 1.0 / 8.0).abs() < 0.01, "c={c}");
        // single rank sees everything
        assert_eq!(mean_pair_coverage(1024, 16, 1), 1.0);
        // coverage shrinks as P grows past the fan-out
        let c64 = mean_pair_coverage(20_480, 32, 64);
        let c512 = mean_pair_coverage(20_480, 32, 512);
        assert!(c512 < c64 && c64 < 1.0, "c64={c64} c512={c512}");
    }

    #[test]
    fn expected_bytes_filtered_never_exceeds_broadcast() {
        for p in [2u32, 8, 64, 256] {
            let b = expected_recv_bytes_per_rank(20_480, 1125, p, 1_000_000, false);
            let f = expected_recv_bytes_per_rank(20_480, 1125, p, 1_000_000, true);
            assert!(f <= b, "p={p}: filtered {f} > broadcast {b}");
            assert!(b > 0.0);
        }
        let sparse_b = expected_recv_bytes_per_rank(1024, 4, 16, 1000, false);
        let sparse_f = expected_recv_bytes_per_rank(1024, 4, 16, 1000, true);
        assert!(sparse_f < 0.5 * sparse_b, "sparse nets filter hard");
    }
}
