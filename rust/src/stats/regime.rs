//! Brain-state regime classification.
//!
//! The paper's network "is able to enter both an asynchronous awake-like
//! regime and a deep-sleep-like slow wave activity, by tuning the values
//! of SFA and stimulation". We classify a run from its binned population
//! rate: slow-wave activity alternates high-rate Up states with
//! near-silent Down states (strongly bimodal, high CV), the awake
//! asynchronous-irregular regime holds a steady rate (low CV).

use super::rates::RateMonitor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// Asynchronous awake-like: steady irregular firing.
    AsynchronousAwake,
    /// Slow-wave-activity-like: Up/Down state alternation.
    SlowWave,
    /// Not enough activity to classify.
    Quiescent,
}

/// Classify from the rate monitor, discarding `skip_steps` of transient.
/// `bin` should be ~25–50 ms to resolve Up/Down states.
pub fn classify_regime(m: &RateMonitor, bin: usize, skip_steps: usize) -> Regime {
    let rate = m.steady_rate_hz(skip_steps);
    if rate < 0.2 {
        return Regime::Quiescent;
    }
    let cv = m.rate_cv(bin, skip_steps);
    // Down states push whole bins near zero => CV well above Poisson noise.
    if cv > 0.75 {
        Regime::SlowWave
    } else {
        Regime::AsynchronousAwake
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_steady_as_awake() {
        let mut m = RateMonitor::new(1000, 1.0);
        let mut r = crate::util::rng::SplitMix64::new(1);
        for _ in 0..3000 {
            m.record(r.next_poisson(3.2)); // ~3.2 Hz steady
        }
        assert_eq!(classify_regime(&m, 50, 500), Regime::AsynchronousAwake);
    }

    #[test]
    fn classifies_updown_as_slow_wave() {
        let mut m = RateMonitor::new(1000, 1.0);
        let mut r = crate::util::rng::SplitMix64::new(2);
        for t in 0..3000usize {
            let up = (t / 300) % 2 == 0;
            m.record(if up { r.next_poisson(10.0) } else { r.next_poisson(0.1) });
        }
        assert_eq!(classify_regime(&m, 50, 500), Regime::SlowWave);
    }

    #[test]
    fn classifies_silence_as_quiescent() {
        let mut m = RateMonitor::new(1000, 1.0);
        for _ in 0..1000 {
            m.record(0);
        }
        assert_eq!(classify_regime(&m, 50, 0), Regime::Quiescent);
    }
}
