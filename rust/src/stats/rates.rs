//! Population firing-rate monitoring.

/// Accumulates per-step population spike counts and derives rates.
#[derive(Debug, Clone, Default)]
pub struct RateMonitor {
    pub n_neurons: u32,
    pub dt_ms: f64,
    /// Spikes per step, whole population.
    pub counts: Vec<u32>,
}

impl RateMonitor {
    pub fn new(n_neurons: u32, dt_ms: f64) -> Self {
        Self { n_neurons, dt_ms, counts: Vec::new() }
    }

    pub fn record(&mut self, spikes_this_step: u32) {
        self.counts.push(spikes_this_step);
    }

    pub fn steps(&self) -> usize {
        self.counts.len()
    }

    /// Mean rate over [from, to) steps, Hz.
    pub fn mean_rate_hz_in(&self, from: usize, to: usize) -> f64 {
        let to = to.min(self.counts.len());
        if from >= to || self.n_neurons == 0 {
            return 0.0;
        }
        let spikes: u64 = self.counts[from..to].iter().map(|&c| c as u64).sum();
        let secs = (to - from) as f64 * self.dt_ms * 1e-3;
        spikes as f64 / self.n_neurons as f64 / secs
    }

    /// Whole-run mean rate, Hz.
    pub fn mean_rate_hz(&self) -> f64 {
        self.mean_rate_hz_in(0, self.counts.len())
    }

    /// Rate after discarding an initial transient, Hz.
    pub fn steady_rate_hz(&self, skip_steps: usize) -> f64 {
        self.mean_rate_hz_in(skip_steps, self.counts.len())
    }

    /// Instantaneous population rate series (Hz), binned at `bin` steps.
    pub fn rate_series_hz(&self, bin: usize) -> Vec<f64> {
        assert!(bin >= 1);
        self.counts
            .chunks(bin)
            .map(|c| {
                let spikes: u64 = c.iter().map(|&x| x as u64).sum();
                let secs = c.len() as f64 * self.dt_ms * 1e-3;
                spikes as f64 / self.n_neurons as f64 / secs
            })
            .collect()
    }

    /// Coefficient of variation of the binned rate series — low for
    /// asynchronous regimes, high for slow oscillations.
    pub fn rate_cv(&self, bin: usize, skip_steps: usize) -> f64 {
        let series: Vec<f64> = self
            .counts
            .iter()
            .skip(skip_steps)
            .copied()
            .collect::<Vec<u32>>()
            .chunks(bin)
            .map(|c| c.iter().map(|&x| x as f64).sum::<f64>() / c.len() as f64)
            .collect();
        if series.len() < 2 {
            return 0.0;
        }
        let mean = series.iter().sum::<f64>() / series.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        let var = series.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / series.len() as f64;
        var.sqrt() / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_rate() {
        let mut m = RateMonitor::new(1000, 1.0);
        for _ in 0..1000 {
            m.record(5); // 5 spikes/ms over 1000 neurons = 5 Hz
        }
        assert!((m.mean_rate_hz() - 5.0).abs() < 1e-9);
        assert!((m.steady_rate_hz(500) - 5.0).abs() < 1e-9);
        assert!(m.rate_cv(50, 0) < 1e-9);
    }

    #[test]
    fn oscillating_rate_has_high_cv() {
        let mut m = RateMonitor::new(1000, 1.0);
        for t in 0..2000usize {
            // up/down states: 250 ms at 12 Hz, 250 ms near-silent
            let up = (t / 250) % 2 == 0;
            m.record(if up { 12 } else { 0 });
        }
        assert!(m.rate_cv(50, 0) > 0.8);
        assert!((m.mean_rate_hz() - 6.0).abs() < 0.1);
    }

    #[test]
    fn binned_series() {
        let mut m = RateMonitor::new(100, 1.0);
        for _ in 0..100 {
            m.record(1);
        }
        let s = m.rate_series_hz(10);
        assert_eq!(s.len(), 10);
        assert!((s[0] - 10.0).abs() < 1e-9); // 1 spike/ms over 100 = 10 Hz
    }
}
