//! Activity statistics: firing rates and brain-state regime detection
//! (asynchronous awake-like vs slow-wave-activity-like dynamics).

pub mod rates;
pub mod regime;

pub use rates::RateMonitor;
pub use regime::{classify_regime, Regime};
