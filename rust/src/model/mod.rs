//! The neural model: LIF+SFA dynamics, partition-independent connectivity,
//! and the external Poisson stimulus.

pub mod neuron;
pub mod population;
pub mod connectivity;
pub mod poisson;

pub use connectivity::{ConnectivityParams, IncomingSynapses, ProceduralSynapses};
pub use neuron::{collect_fired, step_native, step_native_masked, StepParams};
pub use population::PopulationSoA;
