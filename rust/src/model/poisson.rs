//! External Poisson stimulus.
//!
//! Each neuron receives 400 "external" synapses, each delivering a
//! Poissonian spike train at ~3 Hz (paper §II). Per step the number of
//! external events per neuron is Poisson(400 * 3 Hz * 1 ms = 1.2); the
//! injected current is `count * j_ext`.
//!
//! Draws are keyed by `(seed, gid, step)` with the counter-based RNG, so
//! the stimulus — like the connectivity — is a pure function of the
//! global neuron id and is identical under any process partitioning.
//!
//! **Hot path** (EXPERIMENTS.md §Perf): λ is fixed for a run, so the
//! sampler uses a precomputed inverse-CDF table — one `hash4` and a short
//! scan per neuron — instead of Knuth's product loop (which burns an
//! `exp` and ~λ+1 uniform draws per neuron and profiled at ~50% of the
//! whole step).

use crate::config::NetworkParams;
use crate::util::pool::{ComputePool, SyncPtr};
use crate::util::rng::hash2_fast;

/// CDF table length: P(X > 40 | λ ≤ 8) < 1e-19, far below u64 resolution
/// for the λ ≈ 1.2 regime this models.
const CDF_LEN: usize = 40;

#[derive(Debug, Clone)]
pub struct ExternalStimulus {
    seed: u64,
    /// Expected events per neuron per step.
    lambda: f64,
    /// Efficacy per external event (mV, quantized).
    j_ext: f32,
    /// cdf[k] = floor(P(X <= k) * 2^64): sample by scanning for the
    /// first k with u64 < cdf[k].
    cdf: [u64; CDF_LEN],
    /// Precomputed k * j_ext currents for table hits.
    currents: [f32; CDF_LEN],
}

impl ExternalStimulus {
    pub fn new(p: &NetworkParams, seed: u64) -> Self {
        Self::with_lambda(p.ext_lambda_per_step(), p.j_ext, seed)
    }

    pub fn with_lambda(lambda: f64, j_ext: f32, seed: u64) -> Self {
        assert!(lambda >= 0.0 && lambda < 32.0, "lambda {lambda} out of range");
        let mut cdf = [u64::MAX; CDF_LEN];
        let mut currents = [0.0f32; CDF_LEN];
        let mut pmf = (-lambda).exp(); // P(X = 0)
        let mut acc = 0.0f64;
        for k in 0..CDF_LEN {
            acc += pmf;
            cdf[k] = if acc >= 1.0 {
                u64::MAX
            } else {
                (acc * (u64::MAX as f64)) as u64
            };
            currents[k] = k as f32 * j_ext;
            pmf *= lambda / (k + 1) as f64;
        }
        cdf[CDF_LEN - 1] = u64::MAX;
        Self { seed, lambda, j_ext, cdf, currents }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Draw the event count for one (gid, step) cell.
    ///
    /// Branchless for the overwhelming probability mass (k <= 7 covers
    /// >99.999% at λ = 1.2): since the CDF is monotone, the indicators
    /// `u >= cdf[i]` form a prefix of ones whose sum is exactly k.
    #[inline(always)]
    fn draw(&self, gid: u64, step: u64) -> usize {
        let u = hash2_fast(self.seed ^ 0xE873, gid, step);
        let c = &self.cdf;
        let mut k = (u >= c[0]) as usize;
        k += (u >= c[1]) as usize;
        k += (u >= c[2]) as usize;
        k += (u >= c[3]) as usize;
        k += (u >= c[4]) as usize;
        k += (u >= c[5]) as usize;
        k += (u >= c[6]) as usize;
        k += (u >= c[7]) as usize;
        if k == 8 {
            // cold tail
            while u >= c[k] {
                k += 1;
            }
        }
        k
    }

    /// Fill `i_ext[j]` with the external current for neuron `gid0 + j`
    /// at `step` (overwrites the buffer) and return the total number of
    /// external events injected.
    pub fn fill(&self, step: u32, gid0: u32, i_ext: &mut [f32]) -> u64 {
        // NOTE (§Perf iteration log): a manual 4-wide unroll was tried
        // here and measured 3.6% *slower* than this scalar loop (the
        // compiler already pipelines the independent hash chains);
        // reverted.
        let mut events = 0u64;
        for (j, out) in i_ext.iter_mut().enumerate() {
            let k = self.draw(gid0 as u64 + j as u64, step as u64);
            events += k as u64;
            *out = self.currents[k];
        }
        events
    }

    /// [`Self::fill`] over a whole rank's owned buffer at once, chunked
    /// across the compute pool.
    ///
    /// `segs` maps the buffer onto global ids: `(offset, gid0, len)` per
    /// owned interval, ascending and tiling `i_ext` exactly. Each pool
    /// chunk fills its fixed `[lo, hi)` sub-range of the buffer; because
    /// every lane is a pure function of `(seed, gid, step)` and the
    /// per-chunk event counts are exact u64s summed in chunk order, the
    /// result — buffer and count — is identical for every chunk count.
    ///
    /// `events` is per-chunk scratch, resized to the pool's chunk count.
    pub fn fill_chunked(
        &self,
        step: u32,
        segs: &[(usize, u32, usize)],
        pool: &ComputePool,
        events: &mut Vec<u64>,
        i_ext: &mut [f32],
    ) -> u64 {
        debug_assert_eq!(segs.iter().map(|s| s.2).sum::<usize>(), i_ext.len());
        if pool.chunks() == 1 {
            let mut total = 0u64;
            for &(off, gid0, len) in segs {
                total += self.fill(step, gid0, &mut i_ext[off..off + len]);
            }
            return total;
        }
        let n = i_ext.len();
        events.clear();
        events.resize(pool.chunks(), 0);
        let ev = SyncPtr(events.as_mut_ptr());
        let buf = SyncPtr(i_ext.as_mut_ptr());
        // the closure captures the chunk count, not the pool (not Sync)
        let chunks = pool.chunks();
        pool.run(&|c| {
            let r = crate::util::pool::chunk_range(chunks, c, n);
            let mut acc = 0u64;
            for &(off, gid0, len) in segs {
                let lo = r.start.max(off);
                let hi = r.end.min(off + len);
                if lo < hi {
                    // SAFETY: chunk ranges are disjoint; this chunk is the
                    // only writer of buf[lo..hi) and events[c].
                    let out =
                        unsafe { std::slice::from_raw_parts_mut(buf.0.add(lo), hi - lo) };
                    acc += self.fill(step, gid0 + (lo - off) as u32, out);
                }
            }
            unsafe { *ev.0.add(c) = acc };
        });
        events.iter().sum()
    }

    /// Total external events implied by a filled buffer (diagnostics).
    pub fn events_in(&self, i_ext: &[f32]) -> u64 {
        if self.j_ext == 0.0 {
            return 0;
        }
        i_ext.iter().map(|&x| (x / self.j_ext).round() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn stim() -> (NetworkParams, ExternalStimulus) {
        let p = NetworkParams::paper(2048);
        let s = ExternalStimulus::new(&p, 7);
        (p, s)
    }

    #[test]
    fn partition_independent() {
        let (_, s) = stim();
        let mut whole = vec![0.0f32; 256];
        s.fill(13, 0, &mut whole);
        let mut lo = vec![0.0f32; 128];
        let mut hi = vec![0.0f32; 128];
        s.fill(13, 0, &mut lo);
        s.fill(13, 128, &mut hi);
        assert_eq!(&whole[..128], &lo[..]);
        assert_eq!(&whole[128..], &hi[..]);
    }

    #[test]
    fn varies_with_step_and_neuron() {
        let (_, s) = stim();
        let mut a = vec![0.0f32; 64];
        let mut b = vec![0.0f32; 64];
        s.fill(1, 0, &mut a);
        s.fill(2, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn mean_rate_matches_lambda_and_counts_agree() {
        let (p, s) = stim();
        assert!((s.lambda() - 1.2).abs() < 1e-12);
        let mut buf = vec![0.0f32; 2048];
        let mut events = 0u64;
        let steps = 200;
        for t in 0..steps {
            let e = s.fill(t, 0, &mut buf);
            assert_eq!(e, s.events_in(&buf), "returned count vs recount");
            events += e;
        }
        let per_neuron_per_step = events as f64 / (2048.0 * steps as f64);
        assert!(
            (per_neuron_per_step - 1.2).abs() < 0.02,
            "measured {per_neuron_per_step}"
        );
        // currents are multiples of j_ext (quantized grid)
        assert!(buf.iter().all(|&x| (x / p.j_ext).fract() == 0.0));
    }

    #[test]
    fn cdf_sampler_matches_knuth_distribution() {
        // the table sampler must agree with the reference Knuth sampler
        // on the full histogram, not just the mean
        let lambda = 1.2;
        let s = ExternalStimulus::with_lambda(lambda, 1.0, 42);
        let n = 200_000u64;
        let mut hist_table = [0u64; 12];
        for i in 0..n {
            let k = s.draw(i, 0).min(11);
            hist_table[k] += 1;
        }
        let mut rng = SplitMix64::new(99);
        let mut hist_knuth = [0u64; 12];
        for _ in 0..n {
            let k = (rng.next_poisson(lambda) as usize).min(11);
            hist_knuth[k] += 1;
        }
        for k in 0..8 {
            let a = hist_table[k] as f64 / n as f64;
            let b = hist_knuth[k] as f64 / n as f64;
            assert!(
                (a - b).abs() < 0.01,
                "k={k}: table {a:.4} vs knuth {b:.4}"
            );
        }
    }

    #[test]
    fn chunked_fill_matches_plain_fill() {
        let (_, s) = stim();
        // two owned intervals, like a scattered placement
        let segs = [(0usize, 100u32, 130usize), (130usize, 700u32, 170usize)];
        let mut reference = vec![0.0f32; 300];
        let mut ev_ref = 0u64;
        for &(off, gid0, len) in &segs {
            ev_ref += s.fill(9, gid0, &mut reference[off..off + len]);
        }
        for threads in [1usize, 2, 3, 4] {
            let pool = ComputePool::new(threads);
            let mut buf = vec![0.0f32; 300];
            let mut scratch = Vec::new();
            let ev = s.fill_chunked(9, &segs, &pool, &mut scratch, &mut buf);
            assert_eq!(ev, ev_ref, "threads={threads}");
            assert_eq!(buf, reference, "threads={threads}");
        }
    }

    #[test]
    fn zero_lambda_is_silent() {
        let s = ExternalStimulus::with_lambda(0.0, 1.0, 1);
        let mut buf = vec![1.0f32; 32];
        assert_eq!(s.fill(0, 0, &mut buf), 0);
        assert!(buf.iter().all(|&x| x == 0.0));
    }
}
