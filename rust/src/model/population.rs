//! Per-rank population state (structure-of-arrays) and initialization.

use crate::config::NetworkParams;
use crate::util::rng::keyed;

/// The dynamic state of the neurons owned by one rank, in SoA layout
/// matching the kernel ABI: v, w, rf plus the static sfa_inc vector.
#[derive(Debug, Clone)]
pub struct PopulationState {
    /// Global id of the first local neuron.
    pub gid0: u32,
    pub v: Vec<f32>,
    pub w: Vec<f32>,
    pub rf: Vec<f32>,
    /// Per-neuron SFA increment: `sfa_inc` for excitatory, 0 for inhibitory.
    pub sfa_inc: Vec<f32>,
}

impl PopulationState {
    /// Initialize neurons [gid0, gid0+n) of the network described by `p`.
    ///
    /// Membrane potentials start at a seeded uniform value in
    /// [v_floor/4, theta*0.8) — keyed by *global* id, so initial state is
    /// partition-independent (the same neuron gets the same v whichever
    /// rank owns it).
    pub fn init(p: &NetworkParams, seed: u64, gid0: u32, n: u32) -> Self {
        let mut v = Vec::with_capacity(n as usize);
        for gid in gid0..gid0 + n {
            let mut r = keyed(seed, 0x11F0, gid as u64, 0);
            let span = p.theta * 0.8 - p.v_floor * 0.25;
            v.push(p.v_floor * 0.25 + r.next_f64() as f32 * span);
        }
        let sfa_inc = (gid0..gid0 + n)
            .map(|gid| if p.is_exc(gid) { p.sfa_inc } else { 0.0 })
            .collect();
        Self {
            gid0,
            v,
            w: vec![0.0; n as usize],
            rf: vec![0.0; n as usize],
            sfa_inc,
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    /// Local index -> global neuron id.
    pub fn gid(&self, local: u32) -> u32 {
        self.gid0 + local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_partition_independent() {
        let p = NetworkParams::tiny(256);
        let whole = PopulationState::init(&p, 42, 0, 256);
        let lo = PopulationState::init(&p, 42, 0, 128);
        let hi = PopulationState::init(&p, 42, 128, 128);
        assert_eq!(&whole.v[..128], &lo.v[..]);
        assert_eq!(&whole.v[128..], &hi.v[..]);
        assert_eq!(&whole.sfa_inc[..128], &lo.sfa_inc[..]);
        assert_eq!(&whole.sfa_inc[128..], &hi.sfa_inc[..]);
    }

    #[test]
    fn sfa_follows_exc_inh_split() {
        let p = NetworkParams::tiny(100); // 80 exc / 20 inh
        let s = PopulationState::init(&p, 1, 0, 100);
        assert!(s.sfa_inc[..80].iter().all(|&x| x > 0.0));
        assert!(s.sfa_inc[80..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn initial_v_below_threshold() {
        let p = NetworkParams::tiny(512);
        let s = PopulationState::init(&p, 7, 0, 512);
        assert!(s.v.iter().all(|&v| v < p.theta && v >= p.v_floor));
        // and not all identical
        assert!(s.v.windows(2).any(|w| w[0] != w[1]));
    }
}
