//! Per-rank population state (structure-of-arrays) and initialization.

use crate::config::NetworkParams;
use crate::engine::partition::OwnedGids;
use crate::util::aligned::AlignedF32;
use crate::util::rng::keyed;

/// The dynamic state of the neurons owned by one rank, in cache-aligned
/// SoA layout matching the kernel ABI: v, w, rf plus the static sfa_inc
/// vector and the per-step external-input buffer i_ext. Every array is
/// one contiguous 64 B-aligned allocation ([`AlignedF32`]), so the masked
/// LIF+SFA update streams them with aligned vector loads and the chunked
/// threaded update can split them on cache-line boundaries.
///
/// The synaptic input i_syn deliberately does *not* live here: it is the
/// delay ring's current slot ([`crate::engine::DelayRing::current`]),
/// borrowed per step — copying it into the SoA would cost a full memory
/// pass per step for no locality gain (the ring slot is itself aligned
/// and unit-stride).
///
/// Local index order is ascending gid over the owned set (matching
/// [`OwnedGids`] local numbering), which is `gid0 + local` only for
/// contiguous placements.
#[derive(Debug, Clone)]
pub struct PopulationSoA {
    /// Smallest owned global id.
    pub gid0: u32,
    pub v: AlignedF32,
    pub w: AlignedF32,
    pub rf: AlignedF32,
    /// Per-neuron SFA increment: `sfa_inc` for excitatory, 0 for inhibitory.
    pub sfa_inc: AlignedF32,
    /// External Poisson input for the step being integrated (filled by the
    /// engine via [`crate::runtime::NeuronBackend::i_ext_mut`]).
    pub i_ext: AlignedF32,
}

impl PopulationSoA {
    /// Initialize the contiguous neurons [gid0, gid0+n).
    pub fn init(p: &NetworkParams, seed: u64, gid0: u32, n: u32) -> Self {
        Self::init_owned(p, seed, &OwnedGids::contiguous(gid0, gid0 + n))
    }

    /// Initialize the neurons a placement policy assigned to one rank.
    ///
    /// Membrane potentials start at a seeded uniform value in
    /// [v_floor/4, theta*0.8) — keyed by *global* id, so initial state is
    /// partition-independent (the same neuron gets the same v whichever
    /// rank owns it, under whichever placement policy).
    pub fn init_owned(p: &NetworkParams, seed: u64, owned: &OwnedGids) -> Self {
        let n = owned.len() as usize;
        let mut v = Vec::with_capacity(n);
        let mut sfa_inc = Vec::with_capacity(n);
        let span = p.theta * 0.8 - p.v_floor * 0.25;
        for gid in owned.iter() {
            let mut r = keyed(seed, 0x11F0, gid as u64, 0);
            v.push(p.v_floor * 0.25 + r.next_f64() as f32 * span);
            sfa_inc.push(if p.is_exc(gid) { p.sfa_inc } else { 0.0 });
        }
        Self {
            gid0: owned.first(),
            v: AlignedF32::from_slice(&v),
            w: AlignedF32::zeroed(n),
            rf: AlignedF32::zeroed(n),
            sfa_inc: AlignedF32::from_slice(&sfa_inc),
            i_ext: AlignedF32::zeroed(n),
        }
    }

    pub fn len(&self) -> usize {
        self.v.len()
    }

    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_partition_independent() {
        let p = NetworkParams::tiny(256);
        let whole = PopulationSoA::init(&p, 42, 0, 256);
        let lo = PopulationSoA::init(&p, 42, 0, 128);
        let hi = PopulationSoA::init(&p, 42, 128, 128);
        assert_eq!(&whole.v[..128], &lo.v[..]);
        assert_eq!(&whole.v[128..], &hi.v[..]);
        assert_eq!(&whole.sfa_inc[..128], &lo.sfa_inc[..]);
        assert_eq!(&whole.sfa_inc[128..], &hi.sfa_inc[..]);
    }

    #[test]
    fn init_owned_is_a_gather_of_the_whole() {
        // scattered ownership gets exactly the same per-gid state the
        // whole-network init produces — placement permutes, never perturbs
        let p = NetworkParams::tiny(256);
        let whole = PopulationSoA::init(&p, 42, 0, 256);
        let owned = OwnedGids::from_intervals(vec![(16, 32), (200, 208)]);
        let part = PopulationSoA::init_owned(&p, 42, &owned);
        assert_eq!(part.gid0, 16);
        assert_eq!(part.len(), 24);
        for (local, gid) in owned.iter().enumerate() {
            assert_eq!(part.v[local], whole.v[gid as usize], "gid {gid}");
            assert_eq!(part.sfa_inc[local], whole.sfa_inc[gid as usize]);
        }
    }

    #[test]
    fn sfa_follows_exc_inh_split() {
        let p = NetworkParams::tiny(100); // 80 exc / 20 inh
        let s = PopulationSoA::init(&p, 1, 0, 100);
        assert!(s.sfa_inc[..80].iter().all(|&x| x > 0.0));
        assert!(s.sfa_inc[80..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn initial_v_below_threshold() {
        let p = NetworkParams::tiny(512);
        let s = PopulationSoA::init(&p, 7, 0, 512);
        assert!(s.v.iter().all(|&v| v < p.theta && v >= p.v_floor));
        // and not all identical
        assert!(s.v.windows(2).any(|w| w[0] != w[1]));
        // state arrays live on the cache-line grid (SoA contract)
        assert_eq!(s.v.as_ptr() as usize % 64, 0);
        assert_eq!(s.i_ext.len(), 512);
    }
}
