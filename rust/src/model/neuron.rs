//! LIF + Spike-Frequency-Adaptation dynamics — native rust implementation.
//!
//! This mirrors, op for op, the Pallas kernel in
//! `python/compile/kernels/lif_sfa.py` (and its jnp oracle). The native
//! path is the always-available baseline backend; the XLA backend executes
//! the AOT artifact of the same arithmetic. Keeping the operation order
//! identical keeps the two backends numerically interchangeable.

use crate::config::NetworkParams;

/// Per-step scalar parameters, the rust-side twin of the kernel's
/// `params[8]` vector (same order; see aot.py manifest ABI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepParams {
    pub decay_v: f32,
    pub decay_w: f32,
    pub theta: f32,
    pub v_reset: f32,
    pub t_ref_steps: f32,
    pub v_floor: f32,
}

impl StepParams {
    pub fn from_network(p: &NetworkParams) -> Self {
        Self {
            decay_v: (-p.dt_ms / p.tau_m_ms).exp() as f32,
            decay_w: (-p.dt_ms / p.tau_w_ms).exp() as f32,
            theta: p.theta,
            v_reset: p.v_reset,
            t_ref_steps: (p.t_ref_ms / p.dt_ms).round() as f32,
            v_floor: p.v_floor,
        }
    }

    /// Pack into the kernel ABI vector (f32[8]).
    pub fn to_abi(&self) -> [f32; 8] {
        [
            self.decay_v,
            self.decay_w,
            self.theta,
            self.v_reset,
            self.t_ref_steps,
            self.v_floor,
            0.0,
            0.0,
        ]
    }
}

/// Branchless variant for the hot path (§Perf): writes per-neuron fired
/// flags into `mask` instead of pushing indices, which lets LLVM
/// vectorize the state-update loop; the (rare) fired indices are
/// collected by a separate fast scan in the caller.
#[allow(clippy::too_many_arguments)]
pub fn step_native_masked(
    p: &StepParams,
    v: &mut [f32],
    w: &mut [f32],
    rf: &mut [f32],
    i_syn: &[f32],
    i_ext: &[f32],
    sfa_inc: &[f32],
    mask: &mut [u8],
) {
    let n = v.len();
    debug_assert!(
        w.len() == n
            && rf.len() == n
            && i_syn.len() == n
            && i_ext.len() == n
            && sfa_inc.len() == n
            && mask.len() == n
    );
    for j in 0..n {
        let i = i_syn[j] + i_ext[j];
        let active = rf[j] <= 0.0;
        let v_int = (v[j] * p.decay_v + i - w[j]).max(p.v_floor);
        let v_new = if active { v_int } else { p.v_reset };
        let fired = active && v_new >= p.theta;
        v[j] = if fired { p.v_reset } else { v_new };
        w[j] = w[j] * p.decay_w + if fired { sfa_inc[j] } else { 0.0 };
        rf[j] = if fired {
            p.t_ref_steps
        } else {
            (rf[j] - 1.0).max(0.0)
        };
        mask[j] = fired as u8;
    }
}

/// Collect the indices of set bytes in `mask` (sparse: ~0.3% at 3.2 Hz).
/// Appends `base + index` for each set byte; the threaded backend passes
/// each chunk's start so per-chunk vectors concatenate into global-order
/// local indices.
///
/// Scans 8 lanes at a time through a u64 view; on a nonzero word the set
/// bytes are walked directly with `trailing_zeros` + clear-lowest-bit
/// (mask bytes are 0/1 — `step_native_masked` writes `fired as u8` — so
/// each set byte is exactly one set bit).
pub fn collect_fired_offset(mask: &[u8], base: u32, spiked: &mut Vec<u32>) -> usize {
    let before = spiked.len();
    let mut j = 0usize;
    let chunks = mask.chunks_exact(8);
    let rem = chunks.remainder();
    for c in chunks {
        let mut word = u64::from_le_bytes(c.try_into().unwrap());
        debug_assert!(c.iter().all(|&m| m <= 1), "mask bytes must be 0/1");
        while word != 0 {
            let b = (word.trailing_zeros() >> 3) as usize;
            spiked.push(base + (j + b) as u32);
            word &= word - 1;
        }
        j += 8;
    }
    for (b, &m) in rem.iter().enumerate() {
        if m != 0 {
            spiked.push(base + (j + b) as u32);
        }
    }
    spiked.len() - before
}

/// [`collect_fired_offset`] from local index 0.
pub fn collect_fired(mask: &[u8], spiked: &mut Vec<u32>) -> usize {
    collect_fired_offset(mask, 0, spiked)
}

/// Advance one 1 ms step for a population slice.
///
/// * `v`, `w`, `rf` — state vectors, updated in place.
/// * `i_syn`, `i_ext` — input currents for this step (mV increments).
/// * `sfa_inc` — per-neuron SFA increment (0 for inhibitory neurons).
/// * `spiked` — output: local indices of neurons that fired, appended.
///
/// Returns the number of spikes.
pub fn step_native(
    p: &StepParams,
    v: &mut [f32],
    w: &mut [f32],
    rf: &mut [f32],
    i_syn: &[f32],
    i_ext: &[f32],
    sfa_inc: &[f32],
    spiked: &mut Vec<u32>,
) -> usize {
    let n = v.len();
    debug_assert!(
        w.len() == n && rf.len() == n && i_syn.len() == n && i_ext.len() == n
            && sfa_inc.len() == n
    );
    let before = spiked.len();
    for j in 0..n {
        let i = i_syn[j] + i_ext[j];
        let active = rf[j] <= 0.0;
        // identical op order to the kernel: v*decay + i - w, then floor
        let v_int = (v[j] * p.decay_v + i - w[j]).max(p.v_floor);
        let v_new = if active { v_int } else { p.v_reset };
        let fired = active && v_new >= p.theta;
        v[j] = if fired { p.v_reset } else { v_new };
        w[j] = w[j] * p.decay_w + if fired { sfa_inc[j] } else { 0.0 };
        rf[j] = if fired {
            p.t_ref_steps
        } else {
            (rf[j] - 1.0).max(0.0)
        };
        if fired {
            spiked.push(j as u32);
        }
    }
    spiked.len() - before
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> StepParams {
        StepParams {
            decay_v: (-1.0f64 / 20.0).exp() as f32,
            decay_w: (-1.0f64 / 500.0).exp() as f32,
            theta: 20.0,
            v_reset: 0.0,
            t_ref_steps: 2.0,
            v_floor: -40.0,
        }
    }

    #[test]
    fn derives_from_network() {
        let p = StepParams::from_network(&NetworkParams::paper_20480());
        assert!((p.decay_v - (-0.05f64).exp() as f32).abs() < 1e-7);
        assert_eq!(p.t_ref_steps, 2.0);
        assert_eq!(p.theta, 20.0);
        let abi = p.to_abi();
        assert_eq!(abi[0], p.decay_v);
        assert_eq!(abi[4], 2.0);
    }

    #[test]
    fn masked_matches_push_variant() {
        use crate::util::prop::forall;
        forall("masked == push", 50, |rng| {
            let p = StepParams {
                decay_v: 0.95,
                decay_w: 0.998,
                theta: 20.0,
                v_reset: 0.0,
                t_ref_steps: 2.0,
                v_floor: -40.0,
            };
            let n = 1 + rng.next_below(300) as usize;
            let mk = |rng: &mut crate::util::rng::SplitMix64, lo: f64, hi: f64| {
                (0..n)
                    .map(|_| (lo + rng.next_f64() * (hi - lo)) as f32)
                    .collect::<Vec<f32>>()
            };
            let v = mk(rng, -40.0, 25.0);
            let w = mk(rng, 0.0, 5.0);
            let rf: Vec<f32> = (0..n).map(|_| rng.next_below(3) as f32).collect();
            let i_syn = mk(rng, -30.0, 30.0);
            let i_ext = mk(rng, 0.0, 3.0);
            let sfa = mk(rng, 0.0, 0.5);
            let (mut v1, mut w1, mut rf1) = (v.clone(), w.clone(), rf.clone());
            let (mut v2, mut w2, mut rf2) = (v, w, rf);
            let mut spiked1 = Vec::new();
            step_native(&p, &mut v1, &mut w1, &mut rf1, &i_syn, &i_ext, &sfa, &mut spiked1);
            let mut mask = vec![0u8; n];
            let mut spiked2 = Vec::new();
            step_native_masked(&p, &mut v2, &mut w2, &mut rf2, &i_syn, &i_ext, &sfa, &mut mask);
            collect_fired(&mask, &mut spiked2);
            assert_eq!(spiked1, spiked2);
            assert_eq!(v1, v2);
            assert_eq!(w1, w2);
            assert_eq!(rf1, rf2);
        });
    }

    #[test]
    fn collect_fired_scans_all_alignments() {
        // sparse (every 3rd), dense all-ones, and alternating masks all
        // exercise the per-word bit loop across word boundaries and tails
        let patterns: [&dyn Fn(usize) -> bool; 3] =
            [&|j| j % 3 == 0, &|_| true, &|j| j % 2 == 0];
        for (pi, set) in patterns.iter().enumerate() {
            for n in [0usize, 1, 7, 8, 9, 63, 64, 65] {
                let mut mask = vec![0u8; n];
                let mut expect = Vec::new();
                for j in (0..n).filter(|&j| set(j)) {
                    mask[j] = 1;
                    expect.push(j as u32);
                }
                let mut got = Vec::new();
                assert_eq!(collect_fired(&mask, &mut got), expect.len(), "p{pi} n={n}");
                assert_eq!(got, expect, "p{pi} n={n}");
            }
        }
    }

    #[test]
    fn collect_fired_offset_rebases_indices() {
        let mut mask = vec![0u8; 19];
        mask[0] = 1;
        mask[8] = 1;
        mask[18] = 1;
        let mut got = Vec::new();
        assert_eq!(collect_fired_offset(&mask, 1000, &mut got), 3);
        assert_eq!(got, vec![1000, 1008, 1018]);
    }

    #[test]
    fn subthreshold_decay() {
        let p = params();
        let mut v = vec![10.0f32];
        let mut w = vec![0.0f32];
        let mut rf = vec![0.0f32];
        let mut sp = Vec::new();
        let n = step_native(&p, &mut v, &mut w, &mut rf, &[0.0], &[0.0], &[0.0], &mut sp);
        assert_eq!(n, 0);
        assert!((v[0] - 10.0 * p.decay_v).abs() < 1e-6);
    }

    #[test]
    fn fires_resets_and_is_refractory() {
        let p = params();
        let mut v = vec![19.5f32];
        let mut w = vec![0.0f32];
        let mut rf = vec![0.0f32];
        let mut sp = Vec::new();
        step_native(&p, &mut v, &mut w, &mut rf, &[5.0], &[0.0], &[0.5], &mut sp);
        assert_eq!(sp, vec![0]);
        assert_eq!(v[0], 0.0);
        assert_eq!(rf[0], 2.0);
        assert!((w[0] - 0.5).abs() < 1e-6);
        // two refractory steps: huge input must not trigger a spike
        for expect_rf in [1.0f32, 0.0] {
            sp.clear();
            let n = step_native(&p, &mut v, &mut w, &mut rf, &[100.0], &[0.0], &[0.5], &mut sp);
            assert_eq!(n, 0);
            assert_eq!(rf[0], expect_rf);
            assert_eq!(v[0], 0.0);
        }
        // now it can fire again
        sp.clear();
        let n = step_native(&p, &mut v, &mut w, &mut rf, &[100.0], &[0.0], &[0.5], &mut sp);
        assert_eq!(n, 1);
    }

    #[test]
    fn floor_clamps() {
        let p = params();
        let mut v = vec![0.0f32];
        let mut w = vec![0.0f32];
        let mut rf = vec![0.0f32];
        let mut sp = Vec::new();
        step_native(&p, &mut v, &mut w, &mut rf, &[-500.0], &[0.0], &[0.0], &mut sp);
        assert_eq!(v[0], -40.0);
    }

    #[test]
    fn sfa_builds_up_under_drive() {
        let p = params();
        let n = 1;
        let mut v = vec![0.0f32; n];
        let mut w = vec![0.0f32; n];
        let mut rf = vec![0.0f32; n];
        let mut sp = Vec::new();
        let mut spikes_first_100 = 0;
        let mut spikes_last_100 = 0;
        for t in 0..2000 {
            sp.clear();
            let k = step_native(&p, &mut v, &mut w, &mut rf, &[22.0], &[0.0], &[1.0], &mut sp);
            if t < 100 {
                spikes_first_100 += k;
            }
            if t >= 1900 {
                spikes_last_100 += k;
            }
        }
        // adaptation must slow the late firing rate (fatigue)
        assert!(
            spikes_last_100 < spikes_first_100,
            "first={spikes_first_100} last={spikes_last_100}"
        );
        assert!(w[0] > 0.0);
    }
}
