//! Partition-independent connectivity generation.
//!
//! The paper's benchmark network uses a *homogeneously sparse* synaptic
//! adjacency matrix with a constant number of synapses projected per
//! neuron (M = 1125). We generate it with a stateless counter-based RNG:
//! synapse `k` of source neuron `s` is a pure function of
//! `(seed, s, k)` — so every rank can regenerate exactly the synapses
//! whose *targets* it owns, with no communication, and the network is
//! identical regardless of the process count. This is what makes the
//! strong-scaling experiments simulate the *same* network at every P and
//! enables the bitwise partition-determinism tests.

use crate::config::NetworkParams;
use crate::engine::partition::OwnedGids;
use crate::util::rng::keyed;

/// Immutable description of the random connectome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConnectivityParams {
    pub seed: u64,
    /// Total neurons.
    pub n: u32,
    /// Synapses projected per neuron (out-degree).
    pub m: u32,
    /// Axonal delay range in steps, inclusive.
    pub dmin: u32,
    pub dmax: u32,
}

impl ConnectivityParams {
    pub fn from_network(p: &NetworkParams, seed: u64) -> Self {
        Self {
            seed,
            n: p.n_neurons,
            m: p.syn_per_neuron,
            dmin: p.delay_min_steps,
            dmax: p.delay_max_steps,
        }
    }

    /// Synapse `k` (0..m) of source `s`: returns (target gid, delay steps).
    ///
    /// Self-connections are excluded by drawing from [0, n-1) and shifting
    /// past `s`. Stateless: any rank computes the same answer.
    #[inline]
    pub fn synapse(&self, s: u32, k: u32) -> (u32, u8) {
        let mut r = keyed(self.seed, 0x5CA8, s as u64, k as u64);
        let mut tgt = r.next_below(self.n - 1);
        if tgt >= s {
            tgt += 1;
        }
        let delay = r.next_range(self.dmin, self.dmax) as u8;
        (tgt, delay)
    }

    /// All targets of one source (test/diagnostic helper).
    pub fn targets_of(&self, s: u32) -> Vec<(u32, u8)> {
        (0..self.m).map(|k| self.synapse(s, k)).collect()
    }
}

/// CSR list of the synapses *incoming to one rank*, grouped by source
/// neuron: for each of the N possible sources, the local targets this
/// rank owns. This is DPSNN's distribution scheme ("a set of neighbouring
/// neurons and incoming synapses is assigned to each process").
#[derive(Debug, Clone)]
pub struct IncomingSynapses {
    /// Neurons resident on this rank.
    n_local: u32,
    /// Row offsets per source gid: len n+1.
    row_ptr: Vec<u32>,
    /// Target *local* indices (the owner's local numbering).
    tgt_local: Vec<u32>,
    /// Per-synapse delay in steps.
    delay: Vec<u8>,
}

impl IncomingSynapses {
    /// Generate the incoming synapses for the rank owning the
    /// contiguous range [lo, hi) (the index-order placement).
    pub fn build(cp: &ConnectivityParams, lo: u32, hi: u32) -> Self {
        assert!(lo < hi && hi <= cp.n, "bad range [{lo},{hi}) for n={}", cp.n);
        Self::build_owned(cp, &OwnedGids::contiguous(lo, hi))
    }

    /// Generate the incoming synapses for the rank owning `owned` —
    /// any union of gid intervals a placement policy produced; target
    /// indices are the owner's *local* numbering
    /// ([`OwnedGids::local_of`]).
    ///
    /// Cost: iterates all n*m synapses of the network (each rank does the
    /// full sweep — the price of zero-communication construction; ~50 M
    /// draws/s, amortized once per run).
    pub fn build_owned(cp: &ConnectivityParams, owned: &OwnedGids) -> Self {
        assert!(!owned.is_empty(), "a rank must own at least one neuron");
        assert!(
            owned.intervals().last().unwrap().1 <= cp.n,
            "owned gids exceed network size {}",
            cp.n
        );
        let mut row_ptr = Vec::with_capacity(cp.n as usize + 1);
        let mut tgt_local = Vec::new();
        let mut delay = Vec::new();
        let mut scratch: Vec<(u8, u32)> = Vec::with_capacity(cp.m as usize);
        row_ptr.push(0u32);
        for s in 0..cp.n {
            scratch.clear();
            for k in 0..cp.m {
                let (t, d) = cp.synapse(s, k);
                if let Some(local) = owned.try_local_of(t) {
                    scratch.push((d, local));
                }
            }
            // Delay-major row order: delivery then writes each delay
            // slot's accumulator in one contiguous burst (hot-path
            // locality, EXPERIMENTS.md §Perf). Accumulation order is
            // irrelevant to the result (exact-grid weights).
            scratch.sort_unstable();
            for &(d, t) in &scratch {
                tgt_local.push(t);
                delay.push(d);
            }
            let len: u32 = tgt_local
                .len()
                .try_into()
                .expect("more than u32::MAX local synapses");
            row_ptr.push(len);
        }
        Self {
            n_local: owned.len(),
            row_ptr,
            tgt_local,
            delay,
        }
    }

    /// Neurons resident on this rank.
    pub fn n_local(&self) -> u32 {
        self.n_local
    }

    /// The synapses from source gid `s` onto this rank's neurons.
    #[inline(always)]
    pub fn row(&self, s: u32) -> (&[u32], &[u8]) {
        let a = self.row_ptr[s as usize] as usize;
        let b = self.row_ptr[s as usize + 1] as usize;
        (&self.tgt_local[a..b], &self.delay[a..b])
    }

    /// Total synapses stored on this rank.
    pub fn n_synapses(&self) -> usize {
        self.tgt_local.len()
    }

    /// Approximate resident bytes (capacity planning / DESIGN §Perf).
    pub fn resident_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.tgt_local.len() * 5
    }
}

/// Procedural stand-in for [`IncomingSynapses`]: instead of a prebuilt
/// CSR table, a firing source's row is regenerated on demand from the
/// stateless connectome and filtered to the rank's owned gids.
///
/// Resident memory is O(state) — the generator parameters plus the
/// owned-interval list — instead of O(synapse), which is what lets a
/// 100×-scale network fit on one node (Knight & Nowotny; Kurth et al.
/// 2021). Because [`ConnectivityParams::synapse`] is a pure function of
/// `(seed, s, k)` and the regenerated row is sorted exactly like
/// [`IncomingSynapses::build_owned`] sorts its scratch (delay-major,
/// ascending local target within each equal-delay run), delivery through
/// a regenerated row is bitwise identical to delivery through the
/// materialized table.
#[derive(Debug, Clone)]
pub struct ProceduralSynapses {
    cp: ConnectivityParams,
    owned: OwnedGids,
}

impl ProceduralSynapses {
    pub fn new(cp: ConnectivityParams, owned: OwnedGids) -> Self {
        assert!(!owned.is_empty(), "a rank must own at least one neuron");
        assert!(
            owned.intervals().last().unwrap().1 <= cp.n,
            "owned gids exceed network size {}",
            cp.n
        );
        Self { cp, owned }
    }

    /// Neurons resident on this rank.
    pub fn n_local(&self) -> u32 {
        self.owned.len()
    }

    /// The generator parameters this store regenerates rows from.
    pub fn params(&self) -> &ConnectivityParams {
        &self.cp
    }

    /// Regenerate source `s`'s incoming row for this rank into the
    /// caller's buffers (appended; not cleared here so several rows can
    /// be packed into one scratch CSR). Identical content and order to
    /// [`IncomingSynapses::row`] on the same ownership: delay-major,
    /// ascending local target within each equal-delay run — the
    /// invariant `deliver_row_offset_ranged`'s run walk depends on.
    /// Returns the number of synapses appended.
    pub fn row_into(
        &self,
        s: u32,
        tgt_local: &mut Vec<u32>,
        delay: &mut Vec<u8>,
        scratch: &mut Vec<(u8, u32)>,
    ) -> usize {
        scratch.clear();
        for k in 0..self.cp.m {
            let (t, d) = self.cp.synapse(s, k);
            if let Some(local) = self.owned.try_local_of(t) {
                scratch.push((d, local));
            }
        }
        scratch.sort_unstable();
        for &(d, t) in scratch.iter() {
            tgt_local.push(t);
            delay.push(d);
        }
        scratch.len()
    }

    /// Resident bytes of the procedural store: the generator params plus
    /// the owned-interval list. O(state), independent of synapse count —
    /// the closed form `metrics::memory::procedural_synapse_bytes` pins.
    pub fn resident_bytes(&self) -> usize {
        std::mem::size_of::<ConnectivityParams>()
            + std::mem::size_of::<OwnedGids>()
            + self.owned.intervals().len() * std::mem::size_of::<(u32, u32)>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn cp(n: u32, m: u32) -> ConnectivityParams {
        ConnectivityParams { seed: 99, n, m, dmin: 1, dmax: 16 }
    }

    #[test]
    fn synapse_is_deterministic_and_in_range() {
        let c = cp(1000, 100);
        for s in [0u32, 1, 500, 999] {
            for k in [0u32, 1, 50, 99] {
                let (t1, d1) = c.synapse(s, k);
                let (t2, d2) = c.synapse(s, k);
                assert_eq!((t1, d1), (t2, d2));
                assert!(t1 < 1000);
                assert_ne!(t1, s, "self-connection at s={s} k={k}");
                assert!((1..=16).contains(&(d1 as u32)));
            }
        }
    }

    #[test]
    fn out_degree_is_exact() {
        let c = cp(200, 50);
        for s in 0..200 {
            assert_eq!(c.targets_of(s).len(), 50);
        }
    }

    #[test]
    fn partition_union_equals_whole() {
        // The synapses seen by P ranks must exactly tile the full list.
        let c = cp(128, 32);
        let whole = IncomingSynapses::build(&c, 0, 128);
        for p in [2u32, 4, 8] {
            let mut total = 0usize;
            for r in 0..p {
                let lo = r * 128 / p;
                let hi = (r + 1) * 128 / p;
                let part = IncomingSynapses::build(&c, lo, hi);
                total += part.n_synapses();
                // every row of the part must be a sub-multiset of the whole row
                for s in 0..128 {
                    let (wt, _) = whole.row(s);
                    let (pt, _) = part.row(s);
                    for &t in pt {
                        assert!(wt.contains(&(t + lo)));
                    }
                }
            }
            assert_eq!(total, whole.n_synapses());
        }
    }

    #[test]
    fn rows_match_targets_of_as_multiset_and_are_delay_sorted() {
        let c = cp(64, 16);
        let inc = IncomingSynapses::build(&c, 0, 64);
        for s in 0..64u32 {
            let (tgts, dels) = inc.row(s);
            assert_eq!(tgts.len(), 16);
            // delay-major storage order (delivery locality)
            assert!(dels.windows(2).all(|w| w[0] <= w[1]), "row not sorted");
            // same multiset as the stateless generator
            let mut got: Vec<(u8, u32)> =
                dels.iter().zip(tgts).map(|(&d, &t)| (d, t)).collect();
            let mut expect: Vec<(u8, u32)> =
                c.targets_of(s).into_iter().map(|(t, d)| (d, t)).collect();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn owned_build_matches_the_stateless_generator() {
        // scattered two-interval ownership: rows must hold exactly the
        // synapses whose targets fall in the owned set, delay-sorted,
        // with targets in the owner's local numbering
        let c = cp(128, 32);
        let owned = OwnedGids::from_intervals(vec![(8, 24), (96, 112)]);
        let part = IncomingSynapses::build_owned(&c, &owned);
        assert_eq!(part.n_local(), 32);
        for s in 0..128u32 {
            let (pt, pd) = part.row(s);
            assert!(pd.windows(2).all(|w| w[0] <= w[1]), "row {s} not sorted");
            let mut got: Vec<(u8, u32)> =
                pd.iter().zip(pt).map(|(&d, &t)| (d, t)).collect();
            let mut expect: Vec<(u8, u32)> = c
                .targets_of(s)
                .into_iter()
                .filter_map(|(t, d)| owned.try_local_of(t).map(|l| (d, l)))
                .collect();
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect, "s={s}");
        }
        // contiguous build is literally the one-interval special case
        let a = IncomingSynapses::build(&c, 16, 48);
        let b = IncomingSynapses::build_owned(&c, &OwnedGids::contiguous(16, 48));
        assert_eq!(a.n_synapses(), b.n_synapses());
        for s in 0..128u32 {
            assert_eq!(a.row(s), b.row(s));
        }
    }

    #[test]
    fn procedural_rows_match_materialized_bitwise() {
        let c = cp(128, 32);
        for owned in [
            OwnedGids::contiguous(0, 128),
            OwnedGids::contiguous(40, 73),
            OwnedGids::from_intervals(vec![(8, 24), (96, 112)]),
        ] {
            let mat = IncomingSynapses::build_owned(&c, &owned);
            let prc = ProceduralSynapses::new(c, owned.clone());
            assert_eq!(prc.n_local(), mat.n_local());
            let (mut tl, mut dl, mut sc) = (Vec::new(), Vec::new(), Vec::new());
            for s in 0..128u32 {
                tl.clear();
                dl.clear();
                let k = prc.row_into(s, &mut tl, &mut dl, &mut sc);
                let (mt, md) = mat.row(s);
                assert_eq!(k, mt.len(), "s={s}");
                assert_eq!(&tl[..], mt, "targets differ at s={s}");
                assert_eq!(&dl[..], md, "delays differ at s={s}");
            }
            // O(state): a few machine words, never O(synapse)
            assert!(
                prc.resident_bytes() < 256,
                "procedural store grew with synapses: {} B",
                prc.resident_bytes()
            );
            assert!(mat.resident_bytes() > prc.resident_bytes());
        }
    }

    #[test]
    fn target_distribution_is_roughly_uniform() {
        let c = cp(100, 99);
        let mut hits = vec![0u32; 100];
        for s in 0..100 {
            for (t, _) in c.targets_of(s) {
                hits[t as usize] += 1;
            }
        }
        let total: u32 = hits.iter().sum();
        assert_eq!(total, 9900);
        let mean = total as f64 / 100.0;
        for (i, &h) in hits.iter().enumerate() {
            assert!(
                (h as f64) > mean * 0.5 && (h as f64) < mean * 1.5,
                "target {i} hit {h} times (mean {mean})"
            );
        }
    }

    #[test]
    fn property_partition_tiling_random_shapes() {
        forall("partition tiling", 25, |rng| {
            let n = 16 + rng.next_below(100);
            let m = 1 + rng.next_below(n - 2);
            let p = 1 + rng.next_below(7);
            let c = ConnectivityParams { seed: rng.next_u64(), n, m, dmin: 1, dmax: 4 };
            let whole = IncomingSynapses::build(&c, 0, n);
            let mut total = 0;
            for r in 0..p {
                let lo = (r as u64 * n as u64 / p as u64) as u32;
                let hi = ((r + 1) as u64 * n as u64 / p as u64) as u32;
                if lo == hi {
                    continue;
                }
                total += IncomingSynapses::build(&c, lo, hi).n_synapses();
            }
            assert_eq!(total, whole.n_synapses());
            assert_eq!(whole.n_synapses(), (n * m) as usize);
        });
    }
}
