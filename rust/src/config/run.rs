//! Run configuration: execution mode, backend, process count, platform,
//! and TOML file loading.

use std::path::Path;

use anyhow::{bail, Result};

use super::network::NetworkParams;
use crate::util::tomlmini;

/// Which neuron-dynamics implementation the engine uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust LIF+SFA update (always available; the baseline).
    Native,
    /// AOT-compiled JAX/Pallas artifact executed through PJRT.
    Xla,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "xla" | "pjrt" => Ok(Backend::Xla),
            other => bail!("unknown backend {other:?} (native|xla)"),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "native"),
            Backend::Xla => write!(f, "xla"),
        }
    }
}

/// How spikes travel between ranks (see [`crate::comm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Every rank sends every spike to every rank (the paper's baseline).
    Broadcast,
    /// Destination-filtered AER routing: spikes travel only to ranks
    /// owning at least one postsynaptic target, local spikes never loop
    /// back through the transport. Bitwise-identical rasters, strictly
    /// fewer received bytes.
    Filtered,
}

impl std::str::FromStr for Routing {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "broadcast" | "bcast" => Ok(Routing::Broadcast),
            "filtered" | "filter" => Ok(Routing::Filtered),
            other => bail!("unknown routing {other:?} (broadcast|filtered)"),
        }
    }
}

impl std::fmt::Display for Routing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Routing::Broadcast => write!(f, "broadcast"),
            Routing::Filtered => write!(f, "filtered"),
        }
    }
}

/// Which neuron→rank placement policy builds the
/// [`crate::engine::partition::Partition`] (see the `Allocator` trait
/// there). Placement permutes *ownership* only — connectivity and
/// stimulus are pure functions of gid, so the spike raster is bitwise
/// identical under every policy; what changes is which traffic crosses
/// which topology tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionPolicy {
    /// Contiguous index-order blocks (the paper's layout; identical to
    /// the historical even split).
    #[default]
    Index,
    /// Placement blocks dealt round-robin across ranks — the locality
    /// worst case, useful as a bracketing baseline.
    RoundRobin,
    /// Comm-aware placement: pack strongly-connected blocks onto the
    /// same rank/board/chassis using the partition-independent
    /// connectome and the topology tree's link levels.
    GreedyComms,
}

impl std::str::FromStr for PartitionPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "index" => Ok(PartitionPolicy::Index),
            "round-robin" | "roundrobin" => Ok(PartitionPolicy::RoundRobin),
            "greedy-comms" | "greedycomms" => Ok(PartitionPolicy::GreedyComms),
            other => bail!(
                "unknown partition policy {other:?} (index|round-robin|greedy-comms)"
            ),
        }
    }
}

impl std::fmt::Display for PartitionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionPolicy::Index => write!(f, "index"),
            PartitionPolicy::RoundRobin => write!(f, "round-robin"),
            PartitionPolicy::GreedyComms => write!(f, "greedy-comms"),
        }
    }
}

/// How each rank holds its incoming-synapse table (see
/// [`crate::model::connectivity`]).
///
/// `materialized` builds the delay-major CSR rows up front — O(synapse)
/// resident bytes, fastest delivery. `procedural` keeps only the
/// generator parameters and the rank's owned intervals, regenerating a
/// firing source's row on the fly from the counter-keyed RNG — O(state)
/// resident bytes, the unlock for 100×-scale networks whose synapse
/// tables no longer fit in RAM (Knight & Nowotny; Kurth et al. 2021).
/// The connectome is a pure function of `(seed, source, k)` either way,
/// so the spike raster is bitwise identical between the modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConnectivityMode {
    /// Prebuilt incoming-synapse CSR rows (O(synapse) memory).
    #[default]
    Materialized,
    /// Rows regenerated on demand from the stateless connectome
    /// (O(state) memory), paired with the compressed delay ring.
    Procedural,
}

impl std::str::FromStr for ConnectivityMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "materialized" | "mat" => Ok(ConnectivityMode::Materialized),
            "procedural" | "proc" => Ok(ConnectivityMode::Procedural),
            other => bail!("unknown connectivity mode {other:?} (materialized|procedural)"),
        }
    }
}

impl std::fmt::Display for ConnectivityMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConnectivityMode::Materialized => write!(f, "materialized"),
            ConnectivityMode::Procedural => write!(f, "procedural"),
        }
    }
}

/// How often ranks exchange spikes and synchronize (the live step
/// protocol in [`crate::coordinator`]; modeled runs price the same
/// choice analytically).
///
/// A spike emitted at step `t` cannot be integrated anywhere before
/// `t + delay_min_steps` (every synapse carries at least the minimum
/// axonal delay), so any cadence up to one exchange per
/// `delay_min_steps`-step window preserves the spike raster bitwise
/// while dividing the number of latency-bound collectives — the
/// Kurth/Rhodes min-delay batching the paper's latency wall calls for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeCadence {
    /// Exchange + barrier every network step (the paper's protocol and
    /// the fidelity baseline the repro harnesses pin).
    Step,
    /// Exchange + barrier once per `delay_min_steps` window — the widest
    /// causally-safe epoch the network allows.
    MinDelay,
    /// Exchange + barrier every `n` steps. `n` must not exceed
    /// `delay_min_steps` (enforced by [`RunConfig::validate`]).
    Every(u32),
}

impl ExchangeCadence {
    /// Epoch length in steps for a network with the given minimum delay.
    pub fn epoch_steps(&self, delay_min_steps: u32) -> u32 {
        match self {
            ExchangeCadence::Step => 1,
            ExchangeCadence::MinDelay => delay_min_steps.max(1),
            ExchangeCadence::Every(n) => (*n).max(1),
        }
    }
}

impl std::str::FromStr for ExchangeCadence {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "step" | "per-step" => Ok(ExchangeCadence::Step),
            "min-delay" | "mindelay" => Ok(ExchangeCadence::MinDelay),
            other => {
                let n: u32 = other.parse().map_err(|_| {
                    anyhow::anyhow!("unknown exchange cadence {other:?} (step|min-delay|N)")
                })?;
                if n == 0 {
                    bail!("exchange cadence must be at least 1 step");
                }
                Ok(ExchangeCadence::Every(n))
            }
        }
    }
}

impl std::fmt::Display for ExchangeCadence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExchangeCadence::Step => write!(f, "step"),
            ExchangeCadence::MinDelay => write!(f, "min-delay"),
            ExchangeCadence::Every(n) => write!(f, "{n}"),
        }
    }
}

/// Maximum depth of a `tree:` topology. Four tiers cover the paper's
/// ExaNeSt/EuroExa context (board → chassis → rack) with one to spare.
pub const MAX_TREE_LEVELS: usize = 4;

/// Branching factors of an L-level topology tree, smallest tier first:
/// `tree:4,2` means 4 ranks per board and 2 boards per chassis (any
/// number of chassis). Fixed capacity so [`Topology`] stays `Copy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreeShape {
    levels: [u32; MAX_TREE_LEVELS],
    n_levels: u8,
}

impl TreeShape {
    /// Build a shape from branching factors (smallest tier first).
    pub fn new(levels: &[u32]) -> Result<Self> {
        if levels.is_empty() {
            bail!("tree topology needs at least one level (tree:<k1>[,<k2>...])");
        }
        if levels.len() > MAX_TREE_LEVELS {
            bail!(
                "tree topology supports at most {MAX_TREE_LEVELS} levels, got {}",
                levels.len()
            );
        }
        if levels.iter().any(|&k| k == 0) {
            bail!("tree topology branching factors must be at least 1");
        }
        let mut arr = [1u32; MAX_TREE_LEVELS];
        arr[..levels.len()].copy_from_slice(levels);
        Ok(Self {
            levels: arr,
            n_levels: levels.len() as u8,
        })
    }

    /// One-level shape (`nodes:<k>` sugar). Panics on `k == 0`.
    pub fn one_level(k: u32) -> Self {
        Self::new(&[k]).expect("one-level shape needs k >= 1")
    }

    /// The branching factors, smallest tier first.
    pub fn levels(&self) -> &[u32] {
        &self.levels[..self.n_levels as usize]
    }

    /// Number of grouping levels (1 = boards only, 3 = board → chassis
    /// → rack).
    pub fn depth(&self) -> usize {
        self.n_levels as usize
    }

    /// Ranks per lowest-tier group (board).
    pub fn ranks_per_board(&self) -> u32 {
        self.levels[0]
    }
}

impl std::fmt::Display for TreeShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, k) in self.levels().iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{k}")?;
        }
        Ok(())
    }
}

/// Who pays the aggregation CPU cost in a hierarchical topology: the
/// per-group leaders that gather, aggregate and scatter each exchange.
///
/// `fixed` pins every group's leadership to its first rank (rank 0 of
/// each board leads the board, the chassis, the rack...), so the same
/// ranks do leader work every exchange. `round-robin` rotates
/// leadership through the group members exchange by exchange, spreading
/// the aggregation CPU load evenly — message counts, bytes on each
/// link level and the spike raster are unchanged (the rotation decides
/// *who* relays, never *what* travels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LeaderRotation {
    /// The first rank of each group leads every exchange.
    #[default]
    Fixed,
    /// Leadership rotates through the group members per exchange.
    RoundRobin,
}

impl std::str::FromStr for LeaderRotation {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" => Ok(LeaderRotation::Fixed),
            "round-robin" | "roundrobin" | "rr" => Ok(LeaderRotation::RoundRobin),
            other => bail!("unknown leader rotation {other:?} (fixed|round-robin)"),
        }
    }
}

impl std::fmt::Display for LeaderRotation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LeaderRotation::Fixed => write!(f, "fixed"),
            LeaderRotation::RoundRobin => write!(f, "round-robin"),
        }
    }
}

/// How live ranks are grouped onto the fabric hierarchy — the transport
/// *topology* (see [`crate::comm`]).
///
/// Orthogonal to [`Routing`] (*where* spikes travel) and
/// [`ExchangeCadence`] (*how often*): topology decides *what crosses
/// the fabric*. `flat` sends every rank pair's message through the
/// shared transport (`P(P−1)` messages per exchange — the paper's
/// measured regime); `tree:<k1>,<k2>,...` groups ranks into an L-level
/// hierarchy (k1 ranks per board, k2 boards per chassis, k3 chassis per
/// rack) and aggregates traffic at per-group leaders so sibling groups
/// exchange ONE framed message per ordered pair at every level — the
/// multi-tier exchange of the ExaNeSt-class fabrics the paper argues
/// for. `nodes:<k>` is sugar for the one-level `tree:<k>`. The spike
/// raster is bitwise identical whatever the topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// One shared mailbox fabric for every rank pair (the baseline).
    Flat,
    /// Two-level node-leader aggregation with this many ranks per node
    /// (sugar for the one-level tree).
    Nodes(u32),
    /// L-level leader hierarchy (board → chassis → rack ...).
    Tree(TreeShape),
}

impl Topology {
    /// Ranks per lowest-tier group (virtual node / board), when the
    /// topology declares one.
    pub fn ranks_per_node(&self) -> Option<u32> {
        match self {
            Topology::Flat => None,
            Topology::Nodes(k) => Some(*k),
            Topology::Tree(t) => Some(t.ranks_per_board()),
        }
    }

    /// The grouping tree this topology declares (`None` for flat);
    /// `nodes:<k>` is sugar for the one-level `tree:<k>`.
    pub fn tree(&self) -> Option<TreeShape> {
        match self {
            Topology::Flat => None,
            Topology::Nodes(k) => Some(TreeShape::one_level(*k)),
            Topology::Tree(t) => Some(*t),
        }
    }
}

impl std::str::FromStr for Topology {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let s = s.to_ascii_lowercase();
        if s == "flat" {
            return Ok(Topology::Flat);
        }
        if let Some(rest) = s.strip_prefix("nodes:") {
            let k: u32 = rest.parse().map_err(|_| {
                anyhow::anyhow!("bad ranks-per-node in topology {s:?} (nodes:<k>)")
            })?;
            if k == 0 {
                bail!("topology nodes:<k> needs at least 1 rank per node");
            }
            return Ok(Topology::Nodes(k));
        }
        if let Some(rest) = s.strip_prefix("tree:") {
            let mut levels = Vec::new();
            for part in rest.split(',') {
                let k: u32 = part.trim().parse().map_err(|_| {
                    anyhow::anyhow!(
                        "bad branching factor {part:?} in topology {s:?} \
                         (tree:<k1>[,<k2>...])"
                    )
                })?;
                levels.push(k);
            }
            return Ok(Topology::Tree(TreeShape::new(&levels)?));
        }
        bail!("unknown topology {s:?} (flat|nodes:<k>|tree:<k1>[,<k2>...])")
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Topology::Flat => write!(f, "flat"),
            Topology::Nodes(k) => write!(f, "nodes:{k}"),
            Topology::Tree(t) => write!(f, "tree:{t}"),
        }
    }
}

/// Which exchange axes the self-tuning runtime chooses (`auto` on the
/// CLI / in TOML). The concrete [`RunConfig`] fields always hold a
/// valid value — flagged axes are *overwritten* by the analytic
/// planner ([`crate::simnet::autotune`]) before dispatch, and the flags
/// survive into [`RunResult`](crate::coordinator::RunResult) so a run
/// can report which of its resolved values were planner picks.
///
/// Kept as a sidecar struct (rather than `Auto` enum variants on
/// [`Topology`] et al.) so every downstream `match` stays total over
/// concrete values: after resolution no code path can meet an
/// unresolved axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AutoAxes {
    /// `--topology auto`: planner picks flat vs a divisor-chain tree.
    pub topology: bool,
    /// `--exchange-every auto`: planner picks the epoch length, and
    /// live runs re-plan it online at window boundaries.
    pub exchange_every: bool,
    /// `--leader-rotation auto`: planner picks fixed vs round-robin,
    /// and live runs re-plan it online with the cadence.
    pub leader_rotation: bool,
    /// `--compute-threads auto`: resolved from the host parallelism.
    pub compute_threads: bool,
    /// `--connectivity auto`: resolved from the analytic memory model
    /// (materialized when the synapse table fits the per-rank budget,
    /// procedural beyond it).
    pub connectivity: bool,
}

impl AutoAxes {
    /// Any axis left for the planner to choose?
    pub fn any(&self) -> bool {
        self.topology
            || self.exchange_every
            || self.leader_rotation
            || self.compute_threads
            || self.connectivity
    }

    /// The planner-driven axes (everything except compute threads,
    /// which resolves from the host alone).
    pub fn any_planned(&self) -> bool {
        self.topology || self.exchange_every || self.leader_rotation
    }

    /// Comma-separated list of the flagged axes (for run summaries).
    pub fn describe(&self) -> String {
        let mut v = Vec::new();
        if self.topology {
            v.push("topology");
        }
        if self.exchange_every {
            v.push("exchange-every");
        }
        if self.leader_rotation {
            v.push("leader-rotation");
        }
        if self.compute_threads {
            v.push("compute-threads");
        }
        if self.connectivity {
            v.push("connectivity");
        }
        v.join(",")
    }
}

/// How the run is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Actually run P ranks as threads on this host and measure wall-clock.
    Live,
    /// Drive the calibrated platform timing/energy models with a workload
    /// trace (recorded or analytic) — the substitution for the paper's
    /// clusters and boards (DESIGN.md §2).
    Modeled,
}

impl std::str::FromStr for Mode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "live" => Ok(Mode::Live),
            "modeled" | "model" => Ok(Mode::Modeled),
            other => bail!("unknown mode {other:?} (live|modeled)"),
        }
    }
}

/// Full description of one simulation run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub net: NetworkParams,
    /// MPI-style process (rank) count.
    pub procs: u32,
    /// Simulated activity duration (the paper simulates 10 s).
    pub sim_seconds: f64,
    pub seed: u64,
    pub backend: Backend,
    pub mode: Mode,
    /// Spike exchange protocol (live: actual wire traffic; modeled: how
    /// the interconnect model prices the traffic matrix).
    pub routing: Routing,
    /// Spike exchange cadence: every step (the paper's protocol) or
    /// batched over up to `delay_min_steps`-step epochs. Rasters are
    /// bitwise identical either way; only the number of collectives
    /// (and their per-message latency bill) changes.
    pub exchange_every: ExchangeCadence,
    /// Transport topology: flat (every rank pair on the fabric) or
    /// leader-hierarchical aggregation (live: the L-level
    /// `HierCluster`; modeled: the tree exchange pricing with this
    /// grouping). `nodes:<k>` is sugar for the one-level `tree:<k>`.
    pub topology: Topology,
    /// Leader-rotation policy for hierarchical topologies: which rank
    /// of each group pays the aggregation CPU cost per exchange.
    /// Ignored under the flat topology.
    pub leader_rotation: LeaderRotation,
    /// Neuron→rank placement policy (live runs; modeled runs price the
    /// index layout). `greedy-comms` reads the connectome and the
    /// topology tree at startup to co-locate strongly-coupled blocks.
    pub partition: PartitionPolicy,
    /// How each rank stores its incoming synapses: prebuilt CSR rows
    /// (`materialized`, O(synapse) memory) or on-the-fly regeneration
    /// from the stateless connectome (`procedural`, O(state) memory,
    /// paired with the compressed delay ring). Rasters are bitwise
    /// identical between the modes.
    pub connectivity: ConnectivityMode,
    /// Intra-rank compute threads (`--compute-threads`): the neuron
    /// update, Poisson fill and synaptic delivery split into this many
    /// fixed chunks per rank. Rasters are bitwise identical for every
    /// value (chunk geometry is deterministic and every chunk writes a
    /// disjoint region; see `util::pool`).
    pub compute_threads: u32,
    /// Exchange axes the self-tuning runtime resolves (`auto` values).
    /// The concrete fields above always hold valid values; flagged axes
    /// are overwritten by the planner before dispatch (see
    /// [`crate::simnet::autotune::resolve`]).
    pub auto: AutoAxes,
    /// Platform preset name for modeled runs (see `platform::presets`).
    pub platform: String,
    /// Interconnect preset for modeled runs ("ib", "eth1g", ...).
    pub interconnect: String,
    /// Directory holding AOT artifacts for the Xla backend.
    pub artifacts_dir: String,
    /// Print per-second progress during live runs.
    pub progress: bool,
    /// Record the per-step/per-rank workload trace (live runs) to this
    /// path — replayable through the modeled platforms via `dpsnn replay`.
    pub record_trace: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            net: NetworkParams::default(),
            procs: 1,
            sim_seconds: 10.0,
            seed: 0xD509_55E5, // "DSPNN" homage
            backend: Backend::Native,
            mode: Mode::Live,
            routing: Routing::Filtered,
            exchange_every: ExchangeCadence::Step,
            topology: Topology::Flat,
            leader_rotation: LeaderRotation::Fixed,
            partition: PartitionPolicy::Index,
            connectivity: ConnectivityMode::Materialized,
            compute_threads: 1,
            auto: AutoAxes::default(),
            platform: "xeon".to_string(),
            interconnect: "ib".to_string(),
            artifacts_dir: "artifacts".to_string(),
            progress: false,
            record_trace: None,
        }
    }
}

impl RunConfig {
    pub fn steps(&self) -> u32 {
        self.net.steps_for_seconds(self.sim_seconds)
    }

    pub fn validate(&self) -> Result<()> {
        self.net.validate()?;
        if self.procs == 0 {
            bail!("procs must be >= 1");
        }
        if self.procs > self.net.n_neurons {
            bail!(
                "more processes ({}) than neurons ({})",
                self.procs,
                self.net.n_neurons
            );
        }
        if self.sim_seconds <= 0.0 {
            bail!("sim_seconds must be positive");
        }
        if let ExchangeCadence::Every(n) = self.exchange_every {
            if n == 0 {
                bail!("exchange_every must be at least 1 step");
            }
            if n > self.net.delay_min_steps {
                bail!(
                    "exchange_every = {n} exceeds delay_min_steps = {}: spikes \
                     would arrive after the first step they can influence",
                    self.net.delay_min_steps
                );
            }
        }
        // Topology::Tree needs no check here: TreeShape's constructors
        // already reject empty shapes and zero branching factors.
        if self.topology.ranks_per_node() == Some(0) {
            bail!("topology nodes:<k> needs at least 1 rank per node");
        }
        if self.compute_threads == 0 || self.compute_threads > 256 {
            bail!(
                "compute_threads = {} out of range 1..=256",
                self.compute_threads
            );
        }
        Ok(())
    }

    /// Load from a TOML file; unspecified keys keep their defaults.
    ///
    /// ```toml
    /// [network]
    /// neurons = 20480
    /// syn_per_neuron = 1125
    /// [run]
    /// procs = 8
    /// sim_seconds = 10.0
    /// backend = "native"
    /// mode = "live"
    /// platform = "xeon"
    /// interconnect = "ib"
    /// ```
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let doc = tomlmini::parse_file(path)?;
        Self::from_doc(&doc)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_doc(&tomlmini::parse(text)?)
    }

    fn from_doc(doc: &tomlmini::Doc) -> Result<Self> {
        let mut cfg = RunConfig::default();
        let n = doc.i64_or("network", "neurons", cfg.net.n_neurons as i64) as u32;
        cfg.net = NetworkParams::paper(n);
        let net = &mut cfg.net;
        net.syn_per_neuron =
            doc.i64_or("network", "syn_per_neuron", net.syn_per_neuron as i64) as u32;
        net.frac_exc = doc.f64_or("network", "frac_exc", net.frac_exc);
        net.ext_syn_per_neuron =
            doc.i64_or("network", "ext_syn_per_neuron", net.ext_syn_per_neuron as i64) as u32;
        net.ext_rate_hz = doc.f64_or("network", "ext_rate_hz", net.ext_rate_hz);
        net.delay_min_steps =
            doc.i64_or("network", "delay_min_steps", net.delay_min_steps as i64) as u32;
        net.delay_max_steps =
            doc.i64_or("network", "delay_max_steps", net.delay_max_steps as i64) as u32;
        net.tau_m_ms = doc.f64_or("network", "tau_m_ms", net.tau_m_ms);
        net.tau_w_ms = doc.f64_or("network", "tau_w_ms", net.tau_w_ms);
        net.theta = doc.f64_or("network", "theta", net.theta as f64) as f32;
        net.t_ref_ms = doc.f64_or("network", "t_ref_ms", net.t_ref_ms);
        net.j_exc =
            super::network::quantize_weight(doc.f64_or("network", "j_exc", net.j_exc as f64));
        net.j_inh =
            super::network::quantize_weight(doc.f64_or("network", "j_inh", net.j_inh as f64));
        net.j_ext =
            super::network::quantize_weight(doc.f64_or("network", "j_ext", net.j_ext as f64));
        net.sfa_inc =
            super::network::quantize_weight(doc.f64_or("network", "sfa_inc", net.sfa_inc as f64));

        cfg.procs = doc.i64_or("run", "procs", cfg.procs as i64) as u32;
        cfg.sim_seconds = doc.f64_or("run", "sim_seconds", cfg.sim_seconds);
        cfg.seed = doc.i64_or("run", "seed", cfg.seed as i64) as u64;
        cfg.backend = doc.str_or("run", "backend", &cfg.backend.to_string()).parse()?;
        cfg.mode = doc
            .str_or("run", "mode", if cfg.mode == Mode::Live { "live" } else { "modeled" })
            .parse()?;
        cfg.routing = doc
            .str_or("run", "routing", &cfg.routing.to_string())
            .parse()?;
        // The four auto-capable axes: the literal "auto" flags the axis
        // for the planner and leaves the (valid) default in place.
        let cadence = doc.str_or("run", "exchange_every", &cfg.exchange_every.to_string());
        if cadence.eq_ignore_ascii_case("auto") {
            cfg.auto.exchange_every = true;
        } else {
            cfg.exchange_every = cadence.parse()?;
        }
        let topology = doc.str_or("run", "topology", &cfg.topology.to_string());
        if topology.eq_ignore_ascii_case("auto") {
            cfg.auto.topology = true;
        } else {
            cfg.topology = topology.parse()?;
        }
        let rotation = doc.str_or("run", "leader_rotation", &cfg.leader_rotation.to_string());
        if rotation.eq_ignore_ascii_case("auto") {
            cfg.auto.leader_rotation = true;
        } else {
            cfg.leader_rotation = rotation.parse()?;
        }
        cfg.partition = doc
            .str_or("run", "partition", &cfg.partition.to_string())
            .parse()?;
        let connectivity = doc.str_or("run", "connectivity", &cfg.connectivity.to_string());
        if connectivity.eq_ignore_ascii_case("auto") {
            cfg.auto.connectivity = true;
        } else {
            cfg.connectivity = connectivity.parse()?;
        }
        match doc.get("run", "compute_threads") {
            Some(v) if v.as_str().is_some_and(|s| s.eq_ignore_ascii_case("auto")) => {
                cfg.auto.compute_threads = true;
            }
            Some(v) => {
                cfg.compute_threads = v.as_i64().ok_or_else(|| {
                    anyhow::anyhow!("compute_threads must be an integer or \"auto\"")
                })? as u32;
            }
            None => {}
        }
        cfg.platform = doc.str_or("run", "platform", &cfg.platform);
        cfg.interconnect = doc.str_or("run", "interconnect", &cfg.interconnect);
        cfg.artifacts_dir = doc.str_or("run", "artifacts_dir", &cfg.artifacts_dir);
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_round_trip() {
        let cfg = RunConfig::from_toml_str(
            r#"
            [network]
            neurons = 4096
            syn_per_neuron = 512
            ext_rate_hz = 4.0
            [run]
            procs = 4
            sim_seconds = 2.5
            backend = "native"
            mode = "modeled"
            platform = "jetson"
            interconnect = "eth1g"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.net.n_neurons, 4096);
        assert_eq!(cfg.net.syn_per_neuron, 512);
        assert_eq!(cfg.procs, 4);
        assert_eq!(cfg.mode, Mode::Modeled);
        assert_eq!(cfg.platform, "jetson");
        assert_eq!(cfg.steps(), 2500);
    }

    #[test]
    fn compute_threads_parses_and_validates() {
        assert_eq!(RunConfig::default().compute_threads, 1);
        let cfg = RunConfig::from_toml_str("[run]\ncompute_threads = 4").unwrap();
        assert_eq!(cfg.compute_threads, 4);
        let mut cfg = RunConfig::default();
        cfg.compute_threads = 0;
        assert!(cfg.validate().is_err(), "0 threads must fail");
        cfg.compute_threads = 257;
        assert!(cfg.validate().is_err(), "absurd thread count must fail");
        cfg.compute_threads = 256;
        cfg.validate().unwrap();
    }

    #[test]
    fn routing_parses_and_defaults_to_filtered() {
        assert_eq!(RunConfig::default().routing, Routing::Filtered);
        let cfg =
            RunConfig::from_toml_str("[run]\nrouting = \"broadcast\"").unwrap();
        assert_eq!(cfg.routing, Routing::Broadcast);
        assert!("filtered".parse::<Routing>().is_ok());
        assert!("carrier-pigeon".parse::<Routing>().is_err());
    }

    #[test]
    fn exchange_cadence_parses_and_validates() {
        let parse = |s: &str| s.parse::<ExchangeCadence>();
        assert_eq!(RunConfig::default().exchange_every, ExchangeCadence::Step);
        assert_eq!(parse("step").unwrap(), ExchangeCadence::Step);
        assert_eq!(parse("min-delay").unwrap(), ExchangeCadence::MinDelay);
        assert_eq!(parse("4").unwrap(), ExchangeCadence::Every(4));
        assert!(parse("0").is_err());
        assert!(parse("sometimes").is_err());
        // display round-trips through FromStr
        for s in ["step", "min-delay", "7"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        // epoch length resolution
        assert_eq!(ExchangeCadence::Step.epoch_steps(16), 1);
        assert_eq!(ExchangeCadence::MinDelay.epoch_steps(16), 16);
        assert_eq!(ExchangeCadence::Every(3).epoch_steps(16), 3);
    }

    #[test]
    fn exchange_cadence_capped_by_min_delay() {
        let mut cfg = RunConfig::default();
        cfg.net.delay_min_steps = 4;
        cfg.exchange_every = ExchangeCadence::Every(4);
        cfg.validate().unwrap();
        cfg.exchange_every = ExchangeCadence::Every(5);
        assert!(cfg.validate().is_err(), "epoch > delay_min must fail");
        // MinDelay is always safe, whatever the network's window is
        cfg.exchange_every = ExchangeCadence::MinDelay;
        cfg.validate().unwrap();
    }

    #[test]
    fn exchange_cadence_from_toml() {
        let cfg = RunConfig::from_toml_str(
            "[network]\ndelay_min_steps = 8\n[run]\nexchange_every = \"min-delay\"",
        )
        .unwrap();
        assert_eq!(cfg.exchange_every, ExchangeCadence::MinDelay);
        let cfg = RunConfig::from_toml_str(
            "[network]\ndelay_min_steps = 8\n[run]\nexchange_every = \"4\"",
        )
        .unwrap();
        assert_eq!(cfg.exchange_every, ExchangeCadence::Every(4));
        // default network: delay_min_steps = 1, so a 16-step epoch fails
        let r = RunConfig::from_toml_str("[run]\nexchange_every = \"16\"");
        assert!(r.is_err());
    }

    #[test]
    fn topology_parses_and_defaults_to_flat() {
        assert_eq!(RunConfig::default().topology, Topology::Flat);
        let parse = |s: &str| s.parse::<Topology>();
        assert_eq!(parse("flat").unwrap(), Topology::Flat);
        assert_eq!(parse("nodes:4").unwrap(), Topology::Nodes(4));
        assert_eq!(parse("NODES:16").unwrap(), Topology::Nodes(16));
        assert!(parse("nodes:0").is_err(), "zero ranks per node");
        assert!(parse("nodes:").is_err());
        assert!(parse("torus").is_err());
        // display round-trips through FromStr
        for s in ["flat", "nodes:4", "nodes:16"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        assert_eq!(Topology::Nodes(8).ranks_per_node(), Some(8));
        assert_eq!(Topology::Flat.ranks_per_node(), None);
    }

    #[test]
    fn tree_topology_parses_and_round_trips() {
        let parse = |s: &str| s.parse::<Topology>();
        let t42 = parse("tree:4,2").unwrap();
        assert_eq!(t42, Topology::Tree(TreeShape::new(&[4, 2]).unwrap()));
        assert_eq!(t42.ranks_per_node(), Some(4));
        assert_eq!(t42.tree().unwrap().levels(), &[4, 2]);
        assert_eq!(t42.tree().unwrap().depth(), 2);
        // nodes:<k> is sugar for the one-level tree
        assert_eq!(
            parse("nodes:4").unwrap().tree().unwrap().levels(),
            parse("tree:4").unwrap().tree().unwrap().levels()
        );
        assert!(Topology::Flat.tree().is_none());
        // display round-trips through FromStr
        for s in ["tree:4", "tree:4,2", "tree:2,2,2"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        // rejects malformed shapes
        assert!(parse("tree:").is_err());
        assert!(parse("tree:4,0").is_err());
        assert!(parse("tree:4,x").is_err());
        assert!(parse("tree:1,1,1,1,1").is_err(), "too many levels");
        assert!(TreeShape::new(&[]).is_err());
    }

    #[test]
    fn leader_rotation_parses_and_defaults_to_fixed() {
        assert_eq!(RunConfig::default().leader_rotation, LeaderRotation::Fixed);
        let parse = |s: &str| s.parse::<LeaderRotation>();
        assert_eq!(parse("fixed").unwrap(), LeaderRotation::Fixed);
        assert_eq!(parse("round-robin").unwrap(), LeaderRotation::RoundRobin);
        assert_eq!(parse("rr").unwrap(), LeaderRotation::RoundRobin);
        assert!(parse("random").is_err());
        for s in ["fixed", "round-robin"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        let cfg = RunConfig::from_toml_str(
            "[run]\ntopology = \"tree:2,2\"\nleader_rotation = \"round-robin\"",
        )
        .unwrap();
        assert_eq!(cfg.leader_rotation, LeaderRotation::RoundRobin);
        assert_eq!(cfg.topology.tree().unwrap().levels(), &[2, 2]);
    }

    #[test]
    fn partition_policy_parses_and_defaults_to_index() {
        assert_eq!(RunConfig::default().partition, PartitionPolicy::Index);
        let parse = |s: &str| s.parse::<PartitionPolicy>();
        assert_eq!(parse("index").unwrap(), PartitionPolicy::Index);
        assert_eq!(parse("round-robin").unwrap(), PartitionPolicy::RoundRobin);
        assert_eq!(parse("GREEDY-COMMS").unwrap(), PartitionPolicy::GreedyComms);
        assert_eq!(parse("greedycomms").unwrap(), PartitionPolicy::GreedyComms);
        assert!(parse("alphabetical").is_err());
        // display round-trips through FromStr
        for s in ["index", "round-robin", "greedy-comms"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        let cfg = RunConfig::from_toml_str(
            "[run]\npartition = \"greedy-comms\"\ntopology = \"tree:2,2\"",
        )
        .unwrap();
        assert_eq!(cfg.partition, PartitionPolicy::GreedyComms);
        assert!(RunConfig::from_toml_str("[run]\npartition = \"zorder\"").is_err());
    }

    #[test]
    fn connectivity_mode_parses_and_defaults_to_materialized() {
        assert_eq!(
            RunConfig::default().connectivity,
            ConnectivityMode::Materialized
        );
        let parse = |s: &str| s.parse::<ConnectivityMode>();
        assert_eq!(parse("materialized").unwrap(), ConnectivityMode::Materialized);
        assert_eq!(parse("PROCEDURAL").unwrap(), ConnectivityMode::Procedural);
        assert_eq!(parse("proc").unwrap(), ConnectivityMode::Procedural);
        assert!(parse("holographic").is_err());
        // display round-trips through FromStr
        for s in ["materialized", "procedural"] {
            assert_eq!(parse(s).unwrap().to_string(), s);
        }
        let cfg =
            RunConfig::from_toml_str("[run]\nconnectivity = \"procedural\"").unwrap();
        assert_eq!(cfg.connectivity, ConnectivityMode::Procedural);
        assert!(!cfg.auto.connectivity);
        // "auto" flags the axis for the memory-model resolution and
        // leaves the (valid) default in place
        let cfg = RunConfig::from_toml_str("[run]\nconnectivity = \"auto\"").unwrap();
        assert!(cfg.auto.connectivity && cfg.auto.any());
        assert_eq!(cfg.connectivity, ConnectivityMode::Materialized);
        assert_eq!(cfg.auto.describe(), "connectivity");
        assert!(RunConfig::from_toml_str("[run]\nconnectivity = \"dense\"").is_err());
    }

    #[test]
    fn topology_from_toml_and_validation() {
        let cfg = RunConfig::from_toml_str("[run]\ntopology = \"nodes:4\"").unwrap();
        assert_eq!(cfg.topology, Topology::Nodes(4));
        let cfg = RunConfig::from_toml_str("[run]\ntopology = \"flat\"").unwrap();
        assert_eq!(cfg.topology, Topology::Flat);
        assert!(RunConfig::from_toml_str("[run]\ntopology = \"nodes:0\"").is_err());
        // direct construction of the invalid value is caught by validate
        let mut cfg = RunConfig::default();
        cfg.topology = Topology::Nodes(0);
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn auto_axes_parse_from_toml() {
        assert!(!RunConfig::default().auto.any());
        let cfg = RunConfig::from_toml_str(
            "[run]\ntopology = \"auto\"\nexchange_every = \"auto\"\n\
             leader_rotation = \"auto\"\ncompute_threads = \"auto\"",
        )
        .unwrap();
        assert!(cfg.auto.topology);
        assert!(cfg.auto.exchange_every);
        assert!(cfg.auto.leader_rotation);
        assert!(cfg.auto.compute_threads);
        assert!(cfg.auto.any() && cfg.auto.any_planned());
        // flagged axes keep valid defaults until the planner resolves them
        assert_eq!(cfg.topology, Topology::Flat);
        assert_eq!(cfg.exchange_every, ExchangeCadence::Step);
        assert_eq!(cfg.compute_threads, 1);
        cfg.validate().unwrap();
        assert_eq!(
            cfg.auto.describe(),
            "topology,exchange-every,leader-rotation,compute-threads"
        );
        // explicit values still parse and leave the flags unset
        let cfg = RunConfig::from_toml_str(
            "[run]\ntopology = \"nodes:4\"\ncompute_threads = 2",
        )
        .unwrap();
        assert!(!cfg.auto.any());
        assert_eq!(cfg.compute_threads, 2);
        // compute_threads only accepts an integer or "auto"
        assert!(RunConfig::from_toml_str("[run]\ncompute_threads = \"many\"").is_err());
    }

    #[test]
    fn bad_backend_rejected() {
        let r = RunConfig::from_toml_str("[run]\nbackend = \"cuda\"");
        assert!(r.is_err());
    }

    #[test]
    fn validation_procs_vs_neurons() {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(16);
        cfg.procs = 32;
        assert!(cfg.validate().is_err());
    }
}
