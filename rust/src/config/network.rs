//! Neural-network parameters: population sizes, connectivity, dynamics.
//!
//! Defaults reproduce the paper's benchmark network (§II): 80% excitatory
//! LIF neurons with Spike-Frequency Adaptation and 20% inhibitory neurons
//! without SFA; a homogeneously sparse synaptic matrix with a constant
//! 1125 synapses projected per neuron; 400 external Poisson synapses per
//! neuron at ~3 Hz; 1 ms network time step; asynchronous-irregular firing
//! near 3.2 Hz after the initial transient.

use anyhow::{ensure, Result};

/// Synaptic weights are quantized to multiples of 2^-10 mV. With step
/// sums bounded well below 2^13, f32 addition of such values is *exact*,
/// which makes the accumulated synaptic current independent of delivery
/// order — and therefore the whole simulation bitwise-identical no matter
/// how many processes the network is partitioned over (DESIGN.md §7).
pub const WEIGHT_QUANTUM: f32 = 1.0 / 1024.0;

/// Snap a weight to the exact representable grid.
pub fn quantize_weight(w: f64) -> f32 {
    ((w / WEIGHT_QUANTUM as f64).round() as f32) * WEIGHT_QUANTUM
}

#[derive(Debug, Clone, PartialEq)]
pub struct NetworkParams {
    /// Total neurons in the network.
    pub n_neurons: u32,
    /// Excitatory fraction (paper: 0.8).
    pub frac_exc: f64,
    /// Synapses projected by each neuron (paper: 1125).
    pub syn_per_neuron: u32,
    /// Excitatory synaptic efficacy (mV, quantized).
    pub j_exc: f32,
    /// Inhibitory synaptic efficacy (mV, quantized, negative).
    pub j_inh: f32,
    /// Axonal delay range in whole time steps, inclusive.
    pub delay_min_steps: u32,
    pub delay_max_steps: u32,
    /// External stimulus: Poisson synapses per neuron and their rate.
    pub ext_syn_per_neuron: u32,
    pub ext_rate_hz: f64,
    /// External synapse efficacy (mV, quantized).
    pub j_ext: f32,
    /// Membrane time constant (ms).
    pub tau_m_ms: f64,
    /// SFA time constant (ms) and per-spike increment (mV) — excitatory only.
    pub tau_w_ms: f64,
    pub sfa_inc: f32,
    /// Spiking threshold / reset (mV relative to rest = 0) and lower barrier.
    pub theta: f32,
    pub v_reset: f32,
    pub v_floor: f32,
    /// Absolute refractory period (ms).
    pub t_ref_ms: f64,
    /// Network synchronization step (ms); the paper uses 1 ms.
    pub dt_ms: f64,
}

impl Default for NetworkParams {
    fn default() -> Self {
        Self::paper(20_480)
    }
}

impl NetworkParams {
    /// The paper's benchmark network scaled to `n` neurons.
    ///
    /// Dynamics constants are tuned (see `rust/tests/regime.rs`) so the
    /// network settles into an asynchronous-irregular regime near the
    /// paper's ~3.2 Hz mean rate under the 400-synapse 3 Hz external
    /// Poisson bath.
    pub fn paper(n: u32) -> Self {
        Self {
            n_neurons: n,
            frac_exc: 0.8,
            syn_per_neuron: 1125,
            j_exc: quantize_weight(0.40),
            j_inh: quantize_weight(-1.42),
            delay_min_steps: 1,
            delay_max_steps: 16,
            ext_syn_per_neuron: 400,
            ext_rate_hz: 3.0,
            j_ext: quantize_weight(0.96),
            tau_m_ms: 20.0,
            tau_w_ms: 500.0,
            sfa_inc: quantize_weight(0.12),
            theta: 20.0,
            v_reset: 0.0,
            v_floor: -40.0,
            t_ref_ms: 2.0,
            dt_ms: 1.0,
        }
    }

    /// Paper configurations: 20480N / 2.3E7 synapses.
    pub fn paper_20480() -> Self {
        Self::paper(20_480)
    }

    /// 320KN / 3.6E8 synapses (16x the base grid).
    pub fn paper_320k() -> Self {
        Self::paper(327_680)
    }

    /// 1280KN / 1.44E9 synapses (64x the base grid).
    pub fn paper_1280k() -> Self {
        Self::paper(1_310_720)
    }

    /// A small network for tests and quickstarts.
    pub fn tiny(n: u32) -> Self {
        let mut p = Self::paper(n);
        // keep in-degree ~constant relative to network size for small n so
        // the dynamics remain plausible: cap fan-out at n/4.
        p.syn_per_neuron = p.syn_per_neuron.min(n / 4).max(1);
        p
    }

    pub fn n_exc(&self) -> u32 {
        (self.n_neurons as f64 * self.frac_exc).round() as u32
    }

    pub fn n_inh(&self) -> u32 {
        self.n_neurons - self.n_exc()
    }

    /// First inhibitory global id; neurons [0, n_exc) are excitatory.
    pub fn inh_start(&self) -> u32 {
        self.n_exc()
    }

    pub fn is_exc(&self, gid: u32) -> bool {
        gid < self.inh_start()
    }

    /// Total recurrent synapses (the paper's "Synapses" row).
    pub fn total_synapses(&self) -> u64 {
        self.n_neurons as u64 * self.syn_per_neuron as u64
    }

    /// Expected external events per neuron per step.
    pub fn ext_lambda_per_step(&self) -> f64 {
        self.ext_syn_per_neuron as f64 * self.ext_rate_hz * self.dt_ms * 1e-3
    }

    /// Steps to simulate `seconds` of activity.
    pub fn steps_for_seconds(&self, seconds: f64) -> u32 {
        (seconds * 1000.0 / self.dt_ms).round() as u32
    }

    /// Expected synaptic events per wall-second of activity at `rate_hz`
    /// (the paper's cost unit: N * M * rate).
    pub fn syn_events_per_sim_second(&self, rate_hz: f64) -> f64 {
        self.n_neurons as f64 * self.syn_per_neuron as f64 * rate_hz
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_neurons >= 2, "need at least 2 neurons");
        ensure!(
            (0.0..=1.0).contains(&self.frac_exc),
            "frac_exc out of range"
        );
        ensure!(
            self.syn_per_neuron < self.n_neurons,
            "fan-out {} must be < n_neurons {}",
            self.syn_per_neuron,
            self.n_neurons
        );
        ensure!(
            self.delay_min_steps >= 1 && self.delay_min_steps <= self.delay_max_steps,
            "bad delay range"
        );
        ensure!(self.dt_ms > 0.0, "dt must be positive");
        ensure!(self.theta > self.v_reset, "theta must exceed v_reset");
        ensure!(self.j_inh <= 0.0, "j_inh must be <= 0");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_match_table1() {
        // Table I header: 20480N/2.30E7, 320KN/3.60E8, 1280KN/1.44E9.
        assert_eq!(NetworkParams::paper_20480().total_synapses(), 23_040_000);
        assert_eq!(NetworkParams::paper_320k().total_synapses(), 368_640_000);
        assert_eq!(NetworkParams::paper_1280k().total_synapses(), 1_474_560_000);
    }

    #[test]
    fn exc_inh_split() {
        let p = NetworkParams::paper_20480();
        assert_eq!(p.n_exc(), 16_384);
        assert_eq!(p.n_inh(), 4_096);
        assert!(p.is_exc(0) && p.is_exc(16_383));
        assert!(!p.is_exc(16_384));
    }

    #[test]
    fn weights_are_quantized() {
        let p = NetworkParams::paper_20480();
        for w in [p.j_exc, p.j_inh, p.j_ext, p.sfa_inc] {
            let q = w / WEIGHT_QUANTUM;
            assert_eq!(q.fract(), 0.0, "{w} not on the 2^-10 grid");
        }
    }

    #[test]
    fn ext_lambda() {
        let p = NetworkParams::paper_20480();
        // 400 synapses x 3 Hz x 1 ms = 1.2 expected events/step
        assert!((p.ext_lambda_per_step() - 1.2).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut p = NetworkParams::tiny(64);
        p.validate().unwrap();
        p.syn_per_neuron = 64;
        assert!(p.validate().is_err());
        let mut p2 = NetworkParams::tiny(64);
        p2.delay_min_steps = 0;
        assert!(p2.validate().is_err());
        let mut p3 = NetworkParams::tiny(64);
        p3.j_inh = 0.5;
        assert!(p3.validate().is_err());
    }

    #[test]
    fn tiny_caps_fanout() {
        let p = NetworkParams::tiny(100);
        assert_eq!(p.syn_per_neuron, 25);
        p.validate().unwrap();
    }
}
