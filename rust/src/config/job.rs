//! Job specs for the resident simulation server.
//!
//! A *job* is a named [`RunConfig`] payload. The server (`runtime::server`)
//! accepts many of them concurrently over its in-process queue; the name
//! travels through queueing, scheduling, and results so callers can match
//! streamed events back to submissions.
//!
//! On disk a job is the same TOML a `dpsnn run config.toml` invocation
//! takes, optionally extended with a `[job]` table:
//!
//! ```toml
//! [job]
//! name = "awake-4rank"     # default: the file stem
//!
//! [network]
//! neurons = 10000
//! # ... every [run]/[network] key RunConfig::from_toml_str accepts
//! ```

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::tomlmini;

use super::RunConfig;

/// One queued simulation: a display name plus the full run configuration.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub cfg: RunConfig,
}

impl JobSpec {
    pub fn new(name: impl Into<String>, cfg: RunConfig) -> Self {
        Self { name: name.into(), cfg }
    }

    /// Parse a job TOML. The `[job] name` key wins; otherwise the file
    /// stem names the job.
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading job spec {}", path.display()))?;
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "job".to_string());
        Self::from_toml_str(&stem, &text)
            .with_context(|| format!("parsing job spec {}", path.display()))
    }

    /// Parse a job TOML from a string, with `default_name` used when the
    /// `[job]` table does not name the job.
    pub fn from_toml_str(default_name: &str, text: &str) -> Result<Self> {
        // The doc is parsed twice (once for the job table, once inside
        // RunConfig) — tomlmini docs are a few dozen lines, so clarity
        // beats threading a Doc through RunConfig's private from_doc.
        let doc = tomlmini::parse(text)?;
        let name = doc.str_or("job", "name", default_name);
        let cfg = RunConfig::from_toml_str(text)?;
        Ok(Self { name, cfg })
    }
}

/// Resident-server sizing knobs (see `runtime::server::SimServer`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Rank budget shared by all in-flight jobs; the scheduler never
    /// admits a set of jobs whose `procs` sum exceeds it.
    pub total_ranks: u32,
}

impl Default for ServeOptions {
    fn default() -> Self {
        let ranks = std::thread::available_parallelism()
            .map(|n| n.get() as u32)
            .unwrap_or(4)
            .max(2);
        Self { total_ranks: ranks }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BODY: &str = "\
[network]
neurons = 512

[run]
sim_seconds = 0.1
procs = 2
seed = 7
";

    #[test]
    fn name_from_job_table() {
        let text = format!("[job]\nname = \"alpha\"\n{BODY}");
        let spec = JobSpec::from_toml_str("fallback", &text).unwrap();
        assert_eq!(spec.name, "alpha");
        assert_eq!(spec.cfg.procs, 2);
        assert_eq!(spec.cfg.seed, 7);
    }

    #[test]
    fn name_defaults_to_stem() {
        let spec = JobSpec::from_toml_str("fallback", BODY).unwrap();
        assert_eq!(spec.name, "fallback");
    }

    #[test]
    fn bad_toml_is_an_error_not_a_panic() {
        assert!(JobSpec::from_toml_str("x", "[run\nprocs = ").is_err());
    }

    #[test]
    fn default_serve_options_have_ranks() {
        assert!(ServeOptions::default().total_ranks >= 2);
    }
}
