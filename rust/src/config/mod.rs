//! Run and network configuration: typed parameter structs, paper presets,
//! and TOML loading built on [`crate::util::tomlmini`].

pub mod network;
pub mod run;

pub use network::NetworkParams;
pub use run::{
    AutoAxes, Backend, ConnectivityMode, ExchangeCadence, LeaderRotation, Mode,
    PartitionPolicy, Routing, RunConfig, Topology, TreeShape, MAX_TREE_LEVELS,
};
