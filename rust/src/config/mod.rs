//! Run and network configuration: typed parameter structs, paper presets,
//! and TOML loading built on [`crate::util::tomlmini`].

pub mod job;
pub mod network;
pub mod run;

pub use job::{JobSpec, ServeOptions};
pub use network::NetworkParams;
pub use run::{
    AutoAxes, Backend, ConnectivityMode, ExchangeCadence, LeaderRotation, Mode,
    PartitionPolicy, Routing, RunConfig, Topology, TreeShape, MAX_TREE_LEVELS,
};
