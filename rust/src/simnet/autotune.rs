//! The self-tuning planner: `auto` resolution for the exchange axes.
//!
//! Every exchange axis the previous PRs built — transport topology,
//! exchange cadence, leader rotation, intra-rank compute threads — had
//! to be hand-swept per platform, so the fastest configuration was
//! never the default one. This module makes `auto` a first-class value:
//! at run start it enumerates the candidate space and prices each
//! candidate with the *same closed forms the modeled replay uses*
//! ([`AllToAllModel::exchange_time_tree`],
//! [`AllToAllModel::exchange_time_filtered`],
//! [`AllToAllModel::exchange_time`], epoch framing, barrier time, and
//! the contention/working-set computation factors from
//! [`crate::timing::replay`]), then picks the argmin. Because the
//! pricing mirrors [`ModelRun::replay`](crate::timing::replay::ModelRun)
//! term by term (steady-state expectation instead of a stochastic
//! trace), the planner's pick coincides with the best hand-swept
//! modeled configuration up to Poisson noise — pinned by this module's
//! tests against a brute-force priced sweep on all six platform
//! presets, and by bench-smoke against full modeled sweeps.
//!
//! ## Candidate space
//!
//! * **Topology** — `flat` plus every divisor chain of P as a
//!   `tree:` shape: the first factor k1 (ranks per board) ranges over
//!   the divisors of P up to the platform's
//!   [`ranks_per_node`](crate::platform::presets::PlatformModel::ranks_per_node)
//!   (a board cannot hold more ranks than the node has cores), and each
//!   further tier splits the remaining group count by another divisor
//!   >= 2, down to [`MAX_TREE_LEVELS`]. Redundant single-group tails
//!   are not enumerated.
//! * **Cadence** — the divisors of `delay_min_steps` (any of them keeps
//!   the raster bitwise identical; non-divisors are legal but never
//!   cheaper than the neighbouring divisor under the pricing below).
//! * **Rotation** — `fixed` or `round-robin`; per-exchange wall time is
//!   rotation-invariant in the model (barrier-separated phases), so
//!   rotation is chosen by a load rule, not by the argmin.
//!
//! ## Why cadence is a crossover rule, not a raw argmin
//!
//! Under the link model the per-step cost of an epoch of length `e`,
//! `(α + cpu)/e + b/β + framing/β`, is monotonically non-increasing in
//! `e` — a raw argmin would always answer "min-delay" and could never
//! re-plan when the regime shifts. The principled stopping rule is the
//! latency–bandwidth **crossover**: batching pays while the epoch
//! message is latency-dominated; once its payload passes
//! `CROSSOVER_FACTOR x (α + cpu + fabric) x β` of the slowest tier the
//! collective crosses, the remaining α amortization is bounded by
//! `1/CROSSOVER_FACTOR` of the serialization cost (so the pick stays
//! within ~6% of the unconstrained minimum at the default factor of
//! 16) while each extra step only grows burst memory and end-of-window
//! skew. Concretely: the paper's AW regime (~3.2 Hz, tiny payloads)
//! resolves to `min-delay`; SWA-class bursts (bandwidth-bound) shorten
//! the epoch toward per-step — exactly the regime switch the online
//! re-planner in [`crate::coordinator::live`] performs at window
//! boundaries from *measured* payload.
//!
//! ## Rotation rule
//!
//! Leader rotation spreads the per-exchange aggregation CPU over the
//! group members at zero modeled latency cost. It matters when the
//! leader lap is heavy — the bandwidth-bound regime — and is pure
//! overhead churn when exchanges are latency-bound (a fixed leader
//! keeps its gather buffers warm). So: `round-robin` iff the topology
//! is hierarchical and the expected min-delay window payload passes the
//! same crossover, else `fixed`.

use anyhow::{Context, Result};

use crate::comm::aer::{epoch_framing_bytes, SPIKE_WIRE_BYTES};
use crate::config::{
    AutoAxes, ExchangeCadence, LeaderRotation, RunConfig, Topology, TreeShape, MAX_TREE_LEVELS,
};
use crate::metrics::comm_volume::mean_pair_coverage;
use crate::platform::presets::{platform_by_name, PlatformModel};
use crate::simnet::alltoall_model::AllToAllModel;
use crate::simnet::link::LinkModel;
use crate::simnet::presets::interconnect_by_name;
use crate::timing::replay::{contention_factor, working_set_factor, SPIKE_OVERHEAD_S};
use crate::trace::analytic::AnalyticWorkload;

/// Batch until the epoch payload is this many times the
/// latency–bandwidth product of the slowest link the collective
/// crosses. Past that point the residual per-message latency is
/// `<= 1/CROSSOVER_FACTOR` of the serialization cost, so stopping
/// keeps the pick within ~6% of the unconstrained cadence minimum.
pub const CROSSOVER_FACTOR: f64 = 16.0;

/// Expected comm + barrier + computation cost per network step of one
/// candidate configuration, in seconds (steady-state expectation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PricedCost {
    /// Slowest-rank computation (contention depends on the candidate's
    /// claimed node packing, so this is *not* constant across shapes).
    pub comp_s: f64,
    /// Collective exchange, amortized over the epoch.
    pub comm_s: f64,
    /// Barrier: dissemination + skew terms, amortized like the replay.
    pub barrier_s: f64,
}

impl PricedCost {
    pub fn total(&self) -> f64 {
        self.comp_s + self.comm_s + self.barrier_s
    }
}

/// Axes the caller has already fixed (explicit CLI/TOML values); `None`
/// means "planner's choice". Cadence is fixed as an epoch length in
/// steps.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanAxes {
    pub topology: Option<Topology>,
    pub cadence_steps: Option<u32>,
    pub rotation: Option<LeaderRotation>,
}

/// The planner's pick plus its predicted cost.
#[derive(Debug, Clone)]
pub struct Plan {
    pub topology: Topology,
    pub cadence: ExchangeCadence,
    pub rotation: LeaderRotation,
    /// Predicted per-step cost of the pick.
    pub cost: PricedCost,
    /// Topology candidates priced (1 when the topology was fixed).
    pub candidates: usize,
}

/// Analytic planner for the exchange axes of one run.
#[derive(Debug, Clone)]
pub struct Planner {
    platform: PlatformModel,
    link: LinkModel,
    net: crate::config::NetworkParams,
    procs: u32,
    /// Steady-state mean firing rate the payload expectation uses (Hz).
    rate_hz: f64,
    /// Expected payload bytes per ordered rank pair per step, before
    /// any coverage thinning (mirrors the replay's
    /// `mean_rank_spikes x SPIKE_WIRE_BYTES` accrual).
    bytes_per_pair_step: f64,
    /// Filtered-routing pair coverage (None = broadcast pricing).
    coverage: Option<f64>,
}

impl Planner {
    /// Build the planner from a run config: platform + interconnect
    /// presets, and the expected payload from the stateless connectome
    /// (steady-state rate of the paper regime; the settling transient
    /// is ignored, as the replay's long-run behaviour is).
    pub fn from_config(cfg: &RunConfig) -> Result<Self> {
        let platform = platform_by_name(&cfg.platform).context("autotune planner platform")?;
        let link =
            interconnect_by_name(&cfg.interconnect).context("autotune planner interconnect")?;
        let rate_hz = AnalyticWorkload::paper_regime(cfg.net.clone(), cfg.seed).rate_hz;
        let spikes_per_rank_step =
            cfg.net.n_neurons as f64 / cfg.procs.max(1) as f64 * rate_hz * cfg.net.dt_ms * 1e-3;
        let coverage = (cfg.routing == crate::config::Routing::Filtered).then(|| {
            mean_pair_coverage(cfg.net.n_neurons, cfg.net.syn_per_neuron, cfg.procs)
        });
        Ok(Self {
            platform,
            link,
            net: cfg.net.clone(),
            procs: cfg.procs,
            rate_hz,
            bytes_per_pair_step: spikes_per_rank_step * SPIKE_WIRE_BYTES as f64,
            coverage,
        })
    }

    /// Expected payload bytes per ordered rank pair per step.
    pub fn bytes_per_pair_step(&self) -> f64 {
        self.bytes_per_pair_step
    }

    /// Topology candidates: flat plus every divisor-chain tree of P
    /// whose board size fits the platform's cores per node.
    pub fn candidates(&self) -> Vec<Topology> {
        let p = self.procs;
        let mut out = vec![Topology::Flat];
        let k1_max = self.platform.ranks_per_node().min(p);
        for k1 in divisors(p) {
            if k1 < 2 || k1 > k1_max {
                continue;
            }
            let mut chain = vec![k1];
            push_chains(&mut out, &mut chain, p / k1);
        }
        out
    }

    /// Causally-safe cadence candidates: the divisors of the network's
    /// minimum delay, ascending.
    pub fn cadence_candidates(&self) -> Vec<u32> {
        divisors(self.net.delay_min_steps.max(1))
    }

    /// The latency–bandwidth crossover payload (bytes) of the slowest
    /// tier this topology's collective crosses, scaled by
    /// [`CROSSOVER_FACTOR`].
    pub fn crossover_bytes(&self, topology: &Topology) -> f64 {
        let link = match topology.tree() {
            Some(shape) => *self
                .platform
                .tree_links(self.link, shape.depth())
                .last()
                .unwrap_or(&self.link),
            None => self.link,
        };
        CROSSOVER_FACTOR
            * (link.alpha_s + link.cpu_overhead_s + link.fabric_msg_cost_s)
            * link.beta_bps
    }

    /// Is this per-pair-per-step payload bandwidth-bound for the given
    /// topology — i.e. does even a full min-delay window pass the
    /// crossover? (The SWA-vs-AW regime predicate: SWA bursts answer
    /// true, the quiet AW regime false.)
    pub fn bandwidth_bound(&self, topology: &Topology, bytes_per_pair_step: f64) -> bool {
        let dmin = self.net.delay_min_steps.max(1);
        bytes_per_pair_step * dmin as f64 >= self.crossover_bytes(topology)
    }

    /// Epoch length (steps) for the given expected payload: the
    /// smallest min-delay divisor whose epoch payload passes the
    /// crossover, or the full min-delay window while latency-bound.
    pub fn cadence_steps_for(&self, topology: &Topology, bytes_per_pair_step: f64) -> u32 {
        let dmin = self.net.delay_min_steps.max(1);
        for e in self.cadence_candidates() {
            if bytes_per_pair_step * e as f64 >= self.crossover_bytes(topology) {
                return e;
            }
        }
        dmin
    }

    /// [`Self::cadence_steps_for`] expressed as the config enum (the
    /// form a replay of the resolved run passes back on the CLI).
    pub fn cadence_for(&self, topology: &Topology, bytes_per_pair_step: f64) -> ExchangeCadence {
        cadence_enum(
            self.cadence_steps_for(topology, bytes_per_pair_step),
            self.net.delay_min_steps.max(1),
        )
    }

    /// Rotation rule: spread the leader aggregation CPU when the regime
    /// is bandwidth-bound and the topology actually has leaders.
    pub fn rotation_for(&self, topology: &Topology, bytes_per_pair_step: f64) -> LeaderRotation {
        match topology.tree() {
            Some(shape)
                if shape.ranks_per_board() >= 2
                    && self.bandwidth_bound(topology, bytes_per_pair_step) =>
            {
                LeaderRotation::RoundRobin
            }
            _ => LeaderRotation::Fixed,
        }
    }

    /// Price one candidate at the planner's expected payload.
    pub fn price(&self, topology: &Topology, epoch_steps: u32) -> PricedCost {
        self.price_with(topology, epoch_steps, self.bytes_per_pair_step)
    }

    /// Price one candidate at an explicit per-pair-per-step payload
    /// (the online re-planner prices *measured* windows through this).
    ///
    /// Mirrors one steady-state step of
    /// [`ModelRun::replay`](crate::timing::replay::ModelRun::replay):
    /// same exchange closed forms, same epoch framing, same barrier
    /// dissemination + skew terms, same contention/working-set
    /// computation factors — so an argmin over candidates here agrees
    /// with an argmin over full modeled sweeps.
    pub fn price_with(
        &self,
        topology: &Topology,
        epoch_steps: u32,
        bytes_per_pair_step: f64,
    ) -> PricedCost {
        let p = self.procs;
        let e = epoch_steps.max(1);
        let exch = self.exchange_s(topology, e, bytes_per_pair_step);
        let (model, ranks_per_node) = self.model_for(topology);
        let comp = self.comp_per_step(ranks_per_node);
        PricedCost {
            comp_s: comp,
            comm_s: exch / e as f64,
            barrier_s: 0.01 * comp + (model.barrier_time(p) + 0.05 * exch) / e as f64,
        }
    }

    /// Predicted seconds of ONE collective exchange for a candidate at
    /// the given payload — what the online re-planner compares its
    /// measured per-window exchange lap against.
    pub fn predict_exchange_s(
        &self,
        topology: &Topology,
        epoch_steps: u32,
        bytes_per_pair_step: f64,
    ) -> f64 {
        self.exchange_s(topology, epoch_steps.max(1), bytes_per_pair_step)
    }

    /// Pick the best configuration, honoring any axes the caller fixed.
    /// Deterministic: candidates are enumerated in a stable order and
    /// only a strictly cheaper candidate displaces the incumbent, so
    /// ties resolve to the earliest (flat, then shallower trees).
    pub fn plan(&self, fixed: PlanAxes) -> Plan {
        let cands = match fixed.topology {
            Some(t) => vec![t],
            None => self.candidates(),
        };
        let b = self.bytes_per_pair_step;
        let mut best: Option<(Topology, u32, PricedCost)> = None;
        for t in &cands {
            let e = fixed
                .cadence_steps
                .unwrap_or_else(|| self.cadence_steps_for(t, b));
            let cost = self.price(t, e);
            if best
                .as_ref()
                .is_none_or(|(_, _, c)| cost.total() < c.total())
            {
                best = Some((*t, e, cost));
            }
        }
        let (topology, e, cost) = best.expect("candidate set is never empty");
        Plan {
            topology,
            cadence: cadence_enum(e, self.net.delay_min_steps.max(1)),
            rotation: fixed
                .rotation
                .unwrap_or_else(|| self.rotation_for(&topology, b)),
            cost,
            candidates: cands.len(),
        }
    }

    /// One collective's priced seconds (shared by price/predict).
    fn exchange_s(&self, topology: &Topology, e: u32, bytes_per_pair_step: f64) -> f64 {
        let p = self.procs;
        let bytes =
            (bytes_per_pair_step * e as f64).round() as u64 + epoch_framing_bytes(e, e);
        let (model, _) = self.model_for(topology);
        match topology.tree() {
            // Filtering thins the aggregated payload; the per-level
            // pair message counts are unchanged (replay's contract).
            Some(shape) => {
                let thinned = (bytes as f64 * self.coverage.unwrap_or(1.0)).round() as u64;
                let links = self.platform.tree_links(self.link, shape.depth());
                model
                    .exchange_time_tree(p, thinned, shape.levels(), &links)
                    .total()
            }
            None => match self.coverage {
                Some(q) => model.exchange_time_filtered(p, bytes, q).total(),
                None => model.exchange_time(p, bytes).total(),
            },
        }
    }

    /// The comm model + node packing a candidate topology declares
    /// (exactly what `coordinator::modeled` builds for it).
    fn model_for(&self, topology: &Topology) -> (AllToAllModel, u32) {
        match topology.ranks_per_node() {
            Some(k1) => (AllToAllModel::new(self.link, k1), k1),
            None => (
                self.platform.comm_model(self.link),
                self.platform.ranks_per_node(),
            ),
        }
    }

    /// Slowest-rank computation per step under the candidate's claimed
    /// node packing (the contention term is the only packing-dependent
    /// part; mirrors the replay's homogeneous-cluster step).
    fn comp_per_step(&self, ranks_per_node: u32) -> f64 {
        let p = self.procs.max(1);
        let n = self.net.n_neurons as f64;
        let share = 1.0 / p as f64;
        let cont = contention_factor(p, ranks_per_node);
        let ws = working_set_factor(n * share);
        let spikes_net = n * self.rate_hz * self.net.dt_ms * 1e-3;
        let syn_step = spikes_net * self.net.syn_per_neuron as f64;
        let ext_step = n * self.net.ext_lambda_per_step();
        let core = self.platform.node.core;
        core.comp_time(
            n * share,
            syn_step * share * ws * cont,
            ext_step * share * cont,
        ) + spikes_net * self.coverage.unwrap_or(1.0) * SPIKE_OVERHEAD_S
            / core.speed_vs_westmere()
    }
}

/// Resolve every `auto` axis of a config into concrete values.
///
/// Returns the resolved config (the [`AutoAxes`] flags are kept as
/// metadata recording *which* values were planner picks) and the plan
/// when any planner-driven axis was flagged. A config with no `auto`
/// axes passes through untouched.
pub fn resolve(cfg: &RunConfig) -> Result<(RunConfig, Option<Plan>)> {
    if !cfg.auto.any() {
        return Ok((cfg.clone(), None));
    }
    let mut out = cfg.clone();
    if cfg.auto.compute_threads {
        out.compute_threads = auto_compute_threads(cfg.procs);
    }
    if cfg.auto.connectivity {
        // Memory-model axis, not a comm-planner one: materialize the
        // synapse table while the closed-form per-rank bytes fit the
        // budget, regenerate procedurally beyond it.
        out.connectivity = crate::metrics::memory::auto_connectivity_mode(
            &cfg.net,
            cfg.procs,
            crate::metrics::memory::DEFAULT_RANK_BUDGET_BYTES,
        );
    }
    let plan = if cfg.auto.any_planned() {
        let planner = Planner::from_config(cfg)?;
        let dmin = cfg.net.delay_min_steps.max(1);
        let plan = planner.plan(PlanAxes {
            topology: (!cfg.auto.topology).then_some(cfg.topology),
            cadence_steps: (!cfg.auto.exchange_every)
                .then(|| cfg.exchange_every.epoch_steps(dmin)),
            rotation: (!cfg.auto.leader_rotation).then_some(cfg.leader_rotation),
        });
        if cfg.auto.topology {
            out.topology = plan.topology;
        }
        if cfg.auto.exchange_every {
            out.exchange_every = plan.cadence;
        }
        if cfg.auto.leader_rotation {
            out.leader_rotation = plan.rotation;
        }
        Some(plan)
    } else {
        None
    };
    out.validate().context("auto-resolved config")?;
    Ok((out, plan))
}

/// `--compute-threads auto`: the host's available parallelism divided
/// across the run's rank threads (each rank owns one compute pool, so
/// P ranks x this many workers together fill the host without
/// oversubscribing), clamped to the validated 1..=256 range.
pub fn auto_compute_threads(procs: u32) -> u32 {
    let avail = std::thread::available_parallelism()
        .map(|n| n.get() as u32)
        .unwrap_or(1);
    (avail / procs.max(1)).clamp(1, 256)
}

/// Map an epoch length back to the cadence enum: the boundary values
/// get their symbolic names so resolved summaries read like the CLI.
fn cadence_enum(e: u32, dmin: u32) -> ExchangeCadence {
    if e <= 1 {
        ExchangeCadence::Step
    } else if e == dmin {
        ExchangeCadence::MinDelay
    } else {
        ExchangeCadence::Every(e)
    }
}

/// Divisors of `n`, ascending (1 and `n` included).
fn divisors(n: u32) -> Vec<u32> {
    let n = n.max(1);
    (1..=n).filter(|d| n % d == 0).collect()
}

/// DFS over the remaining group count: emit the current chain, then
/// split further by every divisor that leaves >= 2 groups.
fn push_chains(out: &mut Vec<Topology>, chain: &mut Vec<u32>, groups: u32) {
    out.push(Topology::Tree(
        TreeShape::new(chain).expect("chain factors are validated divisors"),
    ));
    if chain.len() >= MAX_TREE_LEVELS {
        return;
    }
    for k in divisors(groups) {
        if k >= 2 && k < groups {
            chain.push(k);
            push_chains(out, chain, groups / k);
            chain.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, NetworkParams};
    use crate::platform::presets::all_names;

    /// 20480N on 32 ranks with a 16-step min-delay window — the
    /// bench-smoke autotune operating point.
    fn paper_cfg(platform: &str) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::paper_20480();
        cfg.net.delay_min_steps = 16;
        cfg.net.delay_max_steps = cfg.net.delay_max_steps.max(16);
        cfg.procs = 32;
        cfg.mode = Mode::Modeled;
        cfg.platform = platform.to_string();
        cfg.interconnect = platform_by_name(platform)
            .unwrap()
            .default_interconnect
            .to_string();
        cfg
    }

    #[test]
    fn candidates_are_flat_plus_divisor_chains() {
        let mut cfg = paper_cfg("xeon");
        cfg.procs = 8;
        let planner = Planner::from_config(&cfg).unwrap();
        let shapes: Vec<String> = planner.candidates().iter().map(|t| t.to_string()).collect();
        assert_eq!(shapes, ["flat", "tree:2", "tree:2,2", "tree:4", "tree:8"]);
        // board size capped by the platform's cores per node (trenz: 4)
        let mut cfg = paper_cfg("trenz");
        cfg.procs = 8;
        let planner = Planner::from_config(&cfg).unwrap();
        let shapes: Vec<String> = planner.candidates().iter().map(|t| t.to_string()).collect();
        assert_eq!(shapes, ["flat", "tree:2", "tree:2,2", "tree:4"]);
        // P=1 has no tree candidates at all
        let mut cfg = paper_cfg("xeon");
        cfg.procs = 1;
        let planner = Planner::from_config(&cfg).unwrap();
        assert_eq!(planner.candidates(), vec![Topology::Flat]);
    }

    #[test]
    fn cadence_crossover_rule_tracks_the_regime() {
        let cfg = paper_cfg("xeon");
        let planner = Planner::from_config(&cfg).unwrap();
        let flat = Topology::Flat;
        // AW-class payloads (a few spikes per pair-window) stay far
        // under the crossover: batch the whole min-delay window.
        let aw = planner.bytes_per_pair_step();
        assert!(aw < 1e3, "AW payload should be tiny, got {aw}");
        assert!(!planner.bandwidth_bound(&flat, aw));
        assert_eq!(planner.cadence_steps_for(&flat, aw), 16);
        assert_eq!(planner.cadence_for(&flat, aw), ExchangeCadence::MinDelay);
        // SWA-class bursts pass the crossover in a single step:
        // exchange every step.
        let swa = planner.crossover_bytes(&flat) * 2.0;
        assert!(planner.bandwidth_bound(&flat, swa));
        assert_eq!(planner.cadence_steps_for(&flat, swa), 1);
        assert_eq!(planner.cadence_for(&flat, swa), ExchangeCadence::Step);
        // intermediate payloads land on an intermediate divisor
        let mid = planner.crossover_bytes(&flat) / 4.0;
        assert_eq!(planner.cadence_steps_for(&flat, mid), 4);
        assert_eq!(planner.cadence_for(&flat, mid), ExchangeCadence::Every(4));
    }

    #[test]
    fn rotation_rule_spreads_leaders_only_when_bandwidth_bound() {
        let cfg = paper_cfg("xeon");
        let planner = Planner::from_config(&cfg).unwrap();
        let tree: Topology = "tree:4,2".parse().unwrap();
        let aw = planner.bytes_per_pair_step();
        let swa = planner.crossover_bytes(&tree) * 2.0;
        assert_eq!(planner.rotation_for(&tree, aw), LeaderRotation::Fixed);
        assert_eq!(planner.rotation_for(&tree, swa), LeaderRotation::RoundRobin);
        // flat has no leaders to rotate, whatever the regime
        assert_eq!(
            planner.rotation_for(&Topology::Flat, swa),
            LeaderRotation::Fixed
        );
    }

    #[test]
    fn argmin_matches_brute_force_on_all_presets() {
        for name in all_names() {
            let cfg = paper_cfg(name);
            let planner = Planner::from_config(&cfg).unwrap();
            let plan = planner.plan(PlanAxes::default());
            // Brute force: every candidate topology x every causally
            // safe cadence (all values, not just the divisors the
            // planner considers).
            let mut brute = f64::INFINITY;
            for t in planner.candidates() {
                for e in 1..=cfg.net.delay_min_steps {
                    brute = brute.min(planner.price(&t, e).total());
                }
            }
            let pick = plan.cost.total();
            assert!(
                pick <= 1.10 * brute,
                "{name}: planner pick {pick:.3e} vs brute-force best \
                 {brute:.3e} ({:.1}% off)",
                100.0 * (pick / brute - 1.0)
            );
            // With the cadence fixed the planner is a pure argmin over
            // topologies: its pick's cost must equal the brute-force
            // minimum exactly (identical pricing code on both sides).
            let fixed = planner.plan(PlanAxes {
                cadence_steps: Some(1),
                ..Default::default()
            });
            let brute_topo_cost = planner
                .candidates()
                .iter()
                .map(|t| planner.price(t, 1).total())
                .fold(f64::INFINITY, f64::min);
            assert_eq!(
                fixed.cost.total(),
                brute_topo_cost,
                "{name}: fixed-cadence argmin diverged from brute force"
            );
        }
    }

    #[test]
    fn plan_is_deterministic_and_honors_fixed_axes() {
        let cfg = paper_cfg("xeon");
        let planner = Planner::from_config(&cfg).unwrap();
        let a = planner.plan(PlanAxes::default());
        let b = planner.plan(PlanAxes::default());
        assert_eq!(a.topology, b.topology);
        assert_eq!(a.cadence, b.cadence);
        assert_eq!(a.rotation, b.rotation);
        assert!(a.candidates > 1);
        // fixed axes pass through verbatim
        let fixed = planner.plan(PlanAxes {
            topology: Some(Topology::Flat),
            cadence_steps: Some(2),
            rotation: Some(LeaderRotation::RoundRobin),
        });
        assert_eq!(fixed.topology, Topology::Flat);
        assert_eq!(fixed.cadence, ExchangeCadence::Every(2));
        assert_eq!(fixed.rotation, LeaderRotation::RoundRobin);
        assert_eq!(fixed.candidates, 1);
    }

    #[test]
    fn resolve_replaces_only_flagged_axes() {
        let mut cfg = paper_cfg("xeon");
        cfg.auto.topology = true;
        cfg.auto.exchange_every = true;
        cfg.auto.leader_rotation = true;
        cfg.auto.compute_threads = true;
        let (resolved, plan) = resolve(&cfg).unwrap();
        let plan = plan.expect("planned axes were flagged");
        assert_eq!(resolved.topology, plan.topology);
        assert_eq!(resolved.exchange_every, plan.cadence);
        assert_eq!(resolved.leader_rotation, plan.rotation);
        assert!((1..=256).contains(&resolved.compute_threads));
        assert!(resolved.auto.any(), "flags survive as metadata");
        resolved.validate().unwrap();
        // AW payloads are latency-bound: the planner must batch
        assert_eq!(resolved.exchange_every, ExchangeCadence::MinDelay);
        // a config without auto axes passes through untouched
        let cfg = paper_cfg("xeon");
        let (same, plan) = resolve(&cfg).unwrap();
        assert!(plan.is_none());
        assert_eq!(same.topology, cfg.topology);
        assert_eq!(same.exchange_every, cfg.exchange_every);
        assert_eq!(same.compute_threads, cfg.compute_threads);
        // partial: only compute-threads flagged -> no plan needed
        let mut cfg = paper_cfg("xeon");
        cfg.auto.compute_threads = true;
        let (resolved, plan) = resolve(&cfg).unwrap();
        assert!(plan.is_none());
        assert!((1..=256).contains(&resolved.compute_threads));
    }

    #[test]
    fn resolve_picks_connectivity_from_the_memory_model() {
        use crate::config::ConnectivityMode;
        // 20480N split over 32 ranks fits any budget: materialize.
        let mut cfg = paper_cfg("xeon");
        cfg.auto.connectivity = true;
        let (resolved, plan) = resolve(&cfg).unwrap();
        assert!(plan.is_none(), "connectivity needs no comm planner");
        assert!(resolved.auto.connectivity, "flag survives as metadata");
        assert_eq!(resolved.connectivity, ConnectivityMode::Materialized);
        // The 100x point on one rank cannot materialize (~11.3 GB
        // closed form vs the 2 GiB budget): procedural.
        cfg.net = NetworkParams::paper(2_000_000);
        cfg.procs = 1;
        let (resolved, _) = resolve(&cfg).unwrap();
        assert_eq!(resolved.connectivity, ConnectivityMode::Procedural);
    }

    #[test]
    fn auto_compute_threads_stays_in_range() {
        for procs in [1, 2, 8, 1024] {
            let t = auto_compute_threads(procs);
            assert!((1..=256).contains(&t), "procs={procs} -> {t}");
        }
        // dividing the host across many ranks floors at one worker
        assert_eq!(auto_compute_threads(u32::MAX), 1);
    }

    #[test]
    fn pricing_mirrors_the_modeled_replay() {
        // The planner's steady-state per-step price must match a real
        // replay of a constant-rate trace through ModelRun within the
        // Poisson noise — this is the contract that makes the argmin
        // transfer to full modeled sweeps.
        use crate::platform::hetero::HeteroCluster;
        use crate::timing::replay::ModelRun;
        use crate::trace::analytic::AnalyticWorkload;

        let cfg = paper_cfg("xeon");
        let planner = Planner::from_config(&cfg).unwrap();
        let platform = platform_by_name("xeon").unwrap();
        let link = interconnect_by_name("ib").unwrap();
        let w = AnalyticWorkload::paper_regime(cfg.net.clone(), cfg.seed);
        let trace = w.generate(cfg.procs, 10.0);
        let steps = trace.steps() as f64;

        for (topo, e) in [
            (Topology::Flat, 1u32),
            (Topology::Flat, 16),
            ("tree:8,2".parse().unwrap(), 16),
        ] {
            let run = match topo.tree() {
                None => ModelRun::new(
                    HeteroCluster::homogeneous(
                        platform.node.core,
                        cfg.procs,
                        platform.ranks_per_node(),
                    ),
                    platform.comm_model(link),
                ),
                Some(shape) => ModelRun::new(
                    HeteroCluster::homogeneous(
                        platform.node.core,
                        cfg.procs,
                        shape.ranks_per_board(),
                    ),
                    AllToAllModel::new(link, shape.ranks_per_board()),
                )
                .with_tree(
                    shape.levels().to_vec(),
                    platform.tree_links(link, shape.depth()),
                ),
            }
            .with_exchange_every(e)
            .with_filter_coverage(mean_pair_coverage(
                cfg.net.n_neurons,
                cfg.net.syn_per_neuron,
                cfg.procs,
            ));
            let outcome = run.replay(&trace);
            let priced = planner.price(&topo, e);
            let ratio = priced.total() / (outcome.wall_s / steps);
            assert!(
                (0.9..1.1).contains(&ratio),
                "{topo} e={e}: planner {:.3e}/step vs replay {:.3e}/step",
                priced.total(),
                outcome.wall_s / steps
            );
        }
    }
}
