//! Point-to-point link cost model (LogGP-flavoured).

/// Cost parameters of one transport class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    pub name: &'static str,
    /// One-way small-message latency, seconds (the paper's bottleneck).
    pub alpha_s: f64,
    /// Asymptotic bandwidth, bytes/second.
    pub beta_bps: f64,
    /// Per-message CPU overhead on the sender (stack traversal), seconds.
    pub cpu_overhead_s: f64,
    /// Fabric-wide cost per in-flight message (switch/arbiter occupancy):
    /// the term that makes P² small-message all-to-all collapse — the
    /// paper's latency wall.
    pub fabric_msg_cost_s: f64,
    /// Active power drawn by one NIC/port while communicating, watts
    /// (Table II: IB draws ~30 W less than ETH across a 2-node run).
    pub nic_active_w: f64,
}

impl LinkModel {
    /// Time for one message of `bytes` on this link.
    #[inline]
    pub fn message_time(&self, bytes: u64) -> f64 {
        self.alpha_s + self.cpu_overhead_s + bytes as f64 / self.beta_bps
    }

    /// Latency-dominated regime check: is a message of `bytes` spending
    /// most of its time in α rather than serialization?
    pub fn latency_dominated(&self, bytes: u64) -> bool {
        self.alpha_s > bytes as f64 / self.beta_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ib() -> LinkModel {
        crate::simnet::presets::IB
    }
    fn eth() -> LinkModel {
        crate::simnet::presets::ETH1G
    }

    #[test]
    fn message_time_monotone_in_size() {
        let l = ib();
        assert!(l.message_time(10) < l.message_time(10_000));
        assert!(l.message_time(0) >= l.alpha_s);
    }

    #[test]
    fn spike_packets_are_latency_dominated() {
        // the paper's 12-byte AER payloads x a few hundred spikes
        for l in [ib(), eth()] {
            assert!(
                l.latency_dominated(12 * 200),
                "{}: small spike packets must be latency-bound",
                l.name
            );
        }
    }

    #[test]
    fn eth_latency_dwarfs_ib() {
        assert!(eth().alpha_s > 5.0 * ib().alpha_s);
    }

    #[test]
    fn large_transfers_become_bandwidth_bound() {
        let l = ib();
        assert!(!l.latency_dominated(100 * 1024 * 1024));
    }
}
