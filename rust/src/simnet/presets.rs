//! Named interconnect presets.
//!
//! α/β anchors are textbook values for the hardware classes the paper
//! used; they are *not* fitted to the paper's tables — the tables are
//! regenerated from these and the platform models (EXPERIMENTS.md
//! records the residuals).

use anyhow::{bail, Result};

use super::link::LinkModel;

/// InfiniBand ConnectX-class: RDMA small-message latency ~1.6 us,
/// ~32 Gb/s effective, light CPU involvement.
pub const IB: LinkModel = LinkModel {
    name: "ib",
    alpha_s: 3.2e-6,
    beta_bps: 4.0e9,
    cpu_overhead_s: 0.3e-6,
    fabric_msg_cost_s: 0.4e-6,
    nic_active_w: 4.0,
};

/// 1 Gb Ethernet through the kernel TCP stack (the clusters' "ETH" and
/// the Trenz/Jetson GbE): tens of microseconds per small message.
pub const ETH1G: LinkModel = LinkModel {
    name: "eth1g",
    alpha_s: 28.0e-6,
    beta_bps: 0.117e9, // ~940 Mb/s effective
    cpu_overhead_s: 4.0e-6,
    fabric_msg_cost_s: 1.8e-6,
    nic_active_w: 16.0,
};

/// Intra-node shared-memory transport (MPI shm BTL class).
pub const SHM: LinkModel = LinkModel {
    name: "shm",
    alpha_s: 0.4e-6,
    beta_bps: 8.0e9,
    cpu_overhead_s: 0.1e-6,
    fabric_msg_cost_s: 0.0,
    nic_active_w: 0.0,
};

/// The ExaNeSt custom low-latency interconnect target (used by the
/// what-if ablation in `examples/`): IB-class latency on an embedded
/// fabric.
pub const EXANEST: LinkModel = LinkModel {
    name: "exanest",
    alpha_s: 1.0e-6,
    beta_bps: 1.25e9,
    cpu_overhead_s: 0.3e-6,
    fabric_msg_cost_s: 0.25e-6,
    nic_active_w: 1.5,
};

pub fn interconnect_by_name(name: &str) -> Result<LinkModel> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "ib" | "infiniband" => IB,
        "eth" | "eth1g" | "gbe" | "ethernet" => ETH1G,
        "shm" => SHM,
        "exanest" => EXANEST,
        other => bail!("unknown interconnect {other:?} (ib|eth1g|shm|exanest)"),
    })
}

pub fn all() -> Vec<LinkModel> {
    vec![IB, ETH1G, SHM, EXANEST]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_alias() {
        assert_eq!(interconnect_by_name("IB").unwrap().name, "ib");
        assert_eq!(interconnect_by_name("gbe").unwrap().name, "eth1g");
        assert!(interconnect_by_name("myrinet").is_err());
    }

    #[test]
    fn ib_vs_eth_power_ordering() {
        // Table II: IB draws measurably less power in operation than ETH.
        assert!(IB.nic_active_w < ETH1G.nic_active_w);
    }

    #[test]
    fn shm_is_fastest() {
        for l in all() {
            assert!(SHM.alpha_s <= l.alpha_s);
        }
    }
}
