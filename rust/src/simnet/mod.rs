//! Interconnect models — the substitution for the paper's physical
//! fabrics (InfiniBand ConnectX, 1 Gb Ethernet; DESIGN.md §2).
//!
//! The paper's central observation is that spike exchange is
//! *latency-dominated*: every rank sends P-1 small messages (12 B/spike)
//! every simulated millisecond, so message count grows as P² while
//! payloads shrink. A LogGP-style per-message cost `α + bytes/β` with
//! per-NIC serialization reproduces exactly that wall — and
//! [`AllToAllModel::exchange_time_epoch`] prices the counter-move,
//! min-delay epoch batching, which pays α once per
//! `delay_min_steps`-step window instead of once per step.

pub mod link;
pub mod alltoall_model;
pub mod autotune;
pub mod presets;

pub use alltoall_model::AllToAllModel;
pub use autotune::{Plan, PlanAxes, Planner};
pub use link::LinkModel;
pub use presets::interconnect_by_name;
