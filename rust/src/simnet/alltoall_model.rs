//! Timing model of the synchronous all-to-all spike exchange.
//!
//! Two additive regimes (calibration walk-through in DESIGN.md §8):
//!
//! * **per-rank software term** — each rank posts P-1 point-to-point
//!   messages, intra-node pairs over the shared-memory transport,
//!   inter-node pairs over the network: `Σ (α + cpu + bytes/β)`.
//! * **fabric term** — all inter-node messages of the step cross the
//!   switch/arbitration fabric: `n_msgs · fabric_msg_cost + bytes/bisection`.
//!   This is the quadratic-in-P component that produces the paper's
//!   latency wall (Fig 2's upturn past 32 processes, Table I's 91.7%
//!   communication share at 256 processes).
//!
//! The model is deliberately homogeneous-workload: with the paper's
//! homogeneous connection probability every rank sends the same payload
//! to every other rank.
//!
//! Beyond the flat exchange, the model prices the leader-aggregated
//! protocols of [`crate::comm::hier::HierCluster`]:
//! [`AllToAllModel::exchange_time_hierarchical`] for the two-level
//! node-leader split, and [`AllToAllModel::exchange_time_tree`] for the
//! general L-level board → chassis → rack hierarchy with **per-level
//! link parameters** (each tier its own latency/bandwidth — see
//! [`crate::platform::presets::PlatformModel::tree_links`]). Message
//! counts always come from the exact ragged-aware closed forms in
//! [`crate::comm::topology`], so live accounting, model prediction and
//! what-if sweeps can be compared number for number.

use super::link::LinkModel;
use super::presets::SHM;

#[derive(Debug, Clone, Copy)]
pub struct AllToAllModel {
    /// Inter-node link (IB / ETH / ExaNeSt).
    pub net: LinkModel,
    /// Intra-node transport.
    pub shm: LinkModel,
    /// Ranks packed per node (paper Intel nodes: 16; Trenz: 4; Jetson: 8).
    pub ranks_per_node: u32,
}

/// Per-step communication decomposition (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommBreakdown {
    /// Slowest rank's software send/receive time.
    pub software: f64,
    /// Fabric occupancy of the whole exchange.
    pub fabric: f64,
}

impl CommBreakdown {
    pub fn total(&self) -> f64 {
        self.software + self.fabric
    }
}

impl AllToAllModel {
    pub fn new(net: LinkModel, ranks_per_node: u32) -> Self {
        assert!(ranks_per_node >= 1);
        Self { net, shm: SHM, ranks_per_node }
    }

    /// Number of nodes hosting `p` ranks.
    pub fn nodes(&self, p: u32) -> u32 {
        p.div_ceil(self.ranks_per_node)
    }

    /// Remote/local peer counts for one rank in a `p`-rank job.
    fn peers(&self, p: u32) -> (u32, u32) {
        let local = (self.ranks_per_node.min(p)) - 1;
        let remote = p - 1 - local;
        (remote, local)
    }

    /// Time for one all-to-all exchange where each rank sends
    /// `bytes_per_msg` to each of the other p-1 ranks.
    pub fn exchange_time(&self, p: u32, bytes_per_msg: u64) -> CommBreakdown {
        if p <= 1 {
            return CommBreakdown::default();
        }
        let (remote, local) = self.peers(p);
        let software = remote as f64 * self.net.message_time(bytes_per_msg)
            + local as f64 * self.shm.message_time(bytes_per_msg);
        let internode_msgs = (p as u64) * (remote as u64);
        let internode_bytes = internode_msgs * bytes_per_msg;
        // bisection: half the node NICs' aggregate bandwidth
        let bisection_bps = self.net.beta_bps * (self.nodes(p) as f64 / 2.0).max(1.0);
        let fabric = internode_msgs as f64 * self.net.fabric_msg_cost_s
            + internode_bytes as f64 / bisection_bps;
        CommBreakdown { software, fabric }
    }

    /// One exchange per `epoch_steps`-step delay window (the
    /// epoch-batched AER protocol,
    /// [`crate::comm::aer::encode_spikes_epoch`]): the per-message
    /// latency α, CPU overhead and fabric message cost are paid once per
    /// window, while the payload is the window's full spike traffic plus
    /// one 8-byte run header per step. Returns the cost of the whole
    /// window — compare against `epoch_steps ×`
    /// [`Self::exchange_time`]`(p, bytes_per_step_msg)` for the paper's
    /// per-step protocol. This is the latency-vs-bandwidth tradeoff as a
    /// first-class what-if: near real time the exchange is
    /// latency-dominated, so batching approaches an `epoch_steps`×
    /// communication speedup.
    pub fn exchange_time_epoch(
        &self,
        p: u32,
        bytes_per_step_msg: u64,
        epoch_steps: u32,
    ) -> CommBreakdown {
        let e = epoch_steps.max(1);
        let framing = crate::comm::aer::epoch_framing_bytes(e, e);
        self.exchange_time(p, bytes_per_step_msg * e as u64 + framing)
    }

    /// Time for one **hierarchical** (node-leader aggregated) exchange —
    /// the live [`crate::comm::hier::HierCluster`] protocol priced
    /// end-to-end, assuming even index-order packing of
    /// `ranks_per_node` ranks per node:
    ///
    /// 1. **direct intra-node posts** — k−1 shared-memory messages of
    ///    `bytes_per_msg` per rank;
    /// 2. **gather** — each member's off-node payload
    ///    (`(P−k)·bytes_per_msg` plus 8-byte per-destination frames)
    ///    reaches its leader as ONE shared-memory message;
    /// 3. **inter-node exchange** — each leader sends ONE aggregated
    ///    message per other node carrying the node pair's `k × k`
    ///    sub-buffers (12-byte source-tagged frames): `N(N−1)` fabric
    ///    messages per exchange instead of the flat `P(P−1)`;
    /// 4. **scatter** — the incoming aggregates fan back out to the
    ///    members over shared memory, mirroring the gather.
    ///
    /// The software term is the *leader's* lap (the busiest rank —
    /// non-leaders only pay 1+2). Inter-node payload bytes are conserved
    /// versus the flat exchange (`N(N−1)·k² = P(P−k)` pair payloads, plus
    /// framing): hierarchy trades per-message latency and fabric
    /// occupancy, not bandwidth. Message counts come from the same
    /// closed form the live transport satisfies exactly
    /// ([`Self::hierarchical_messages`]).
    pub fn exchange_time_hierarchical(&self, p: u32, bytes_per_msg: u64) -> CommBreakdown {
        if p <= 1 {
            return CommBreakdown::default();
        }
        let n = self.nodes(p);
        if n == 1 {
            // one node: the whole exchange is the node-local flat path
            return self.exchange_time(p, bytes_per_msg);
        }
        let k = self.ranks_per_node.min(p) as u64;
        let (remote, local) = self.peers(p);
        let b = bytes_per_msg;
        let gather_bytes = remote as u64 * (b + crate::comm::hier::GATHER_FRAME_BYTES as u64);
        let pair_bytes = k * k * (b + crate::comm::hier::HIER_FRAME_BYTES as u64);
        // leader's software lap: direct posts + (k-1) gather receives +
        // (N-1) aggregated sends + (k-1) scatter sends
        let software = local as f64 * self.shm.message_time(b)
            + 2.0 * local as f64 * self.shm.message_time(gather_bytes)
            + (n - 1) as f64 * self.net.message_time(pair_bytes);
        let internode_msgs = n as u64 * (n as u64 - 1);
        let internode_bytes = internode_msgs * pair_bytes;
        let bisection_bps = self.net.beta_bps * (n as f64 / 2.0).max(1.0);
        let fabric = internode_msgs as f64 * self.net.fabric_msg_cost_s
            + internode_bytes as f64 / bisection_bps;
        CommBreakdown { software, fabric }
    }

    /// Time for one **L-level tree** exchange (`--topology
    /// tree:<k1>,<k2>,...`): the live [`crate::comm::hier::HierCluster`]
    /// protocol priced end-to-end with one [`LinkModel`] per fabric
    /// tier. `shape` holds the branching factors (ranks per board,
    /// boards per chassis, ...); `level_links[t]` prices link level
    /// `t + 1` (level 0 is always the shared-memory transport; missing
    /// entries fall back to this model's `net` link).
    ///
    /// The software term sums the barrier-separated leader laps, level
    /// by level: direct board posts, then per boundary the gather
    /// receive + scatter send mirror (`2(c−1)` messages of the child
    /// blob), the `sib−1` aggregated sibling-pair posts, and the ONE
    /// up-forward of everything bound beyond the parent. The fabric
    /// term charges each tier's link with that tier's exact closed-form
    /// message count ([`crate::comm::topology::TopologyTree`], ragged
    /// shapes included; up/down forwards count twice — once per
    /// direction), with payload sizes from the even-packing model.
    /// Leader *rotation* never appears here: the phases are
    /// barrier-separated, so per-exchange wall time is
    /// rotation-invariant — rotation spreads which rank pays the CPU,
    /// which matters for per-rank load and energy, not latency.
    ///
    /// A one-level `shape` with default links reproduces
    /// [`Self::exchange_time_hierarchical`] exactly; callers should
    /// pack the model with `ranks_per_node == shape[0]` so the
    /// single-board degenerate case agrees too.
    pub fn exchange_time_tree(
        &self,
        p: u32,
        bytes_per_msg: u64,
        shape: &[u32],
        level_links: &[LinkModel],
    ) -> CommBreakdown {
        if p <= 1 {
            return CommBreakdown::default();
        }
        // One source of truth for the packing arithmetic: the same tree
        // the live transport's accounting is tested against.
        let tree = crate::comm::topology::TopologyTree::new(p, shape);
        let depth = tree.depth();
        let groups = |g: usize| -> u64 { tree.n_groups(g) as u64 };
        if groups(1) <= 1 {
            // one board: the whole exchange is the board-local flat path
            return self.exchange_time(p, bytes_per_msg);
        }
        let b = bytes_per_msg;
        let link = |g: usize| -> LinkModel {
            if g == 0 {
                self.shm
            } else {
                level_links.get(g - 1).copied().unwrap_or(self.net)
            }
        };
        // even-model ranks per level-g group (group 0 is always full)
        let s = |g: usize| -> u64 { tree.group_size(0, g) as u64 };
        // gather blob crossing the level-g boundary: one level-(g-1)
        // child group's ranks times their beyond-group destinations
        let gb = |g: usize| -> u64 {
            let frame = if g == 1 {
                crate::comm::hier::GATHER_FRAME_BYTES
            } else {
                crate::comm::hier::HIER_FRAME_BYTES
            } as u64;
            s(g - 1) * ((p as u64) - s(g)) * (b + frame)
        };
        let pair_bytes = |g: usize| -> u64 {
            s(g) * s(g) * (b + crate::comm::hier::HIER_FRAME_BYTES as u64)
        };

        let k1 = s(1);
        let mut software = (k1 - 1) as f64 * self.shm.message_time(b);
        let mut fabric = 0.0f64;
        for g in 1..=depth {
            if groups(g) > 1 {
                // the level-g leader receives its children's gathers and
                // mirrors them on the way down
                let c = (shape[g - 1] as u64).min(groups(g - 1));
                software += 2.0 * (c - 1) as f64 * link(g - 1).message_time(gb(g));
            }
            // aggregated pair posts to the sibling groups of this tier
            let sib = if g == depth {
                groups(g)
            } else {
                (shape[g] as u64).min(groups(g))
            };
            if sib > 1 {
                software += (sib - 1) as f64 * link(g).message_time(pair_bytes(g));
            }
            // ONE up-forward of everything bound beyond the parent
            if g < depth && groups(g + 1) > 1 {
                software += link(g).message_time(gb(g + 1));
            }

            // fabric occupancy of this tier: exact closed-form counts
            let pair_cnt = tree.pair_messages_at_level(g);
            let gather_cnt = tree.gather_messages_at_level(g);
            if pair_cnt + gather_cnt > 0 {
                let lg = link(g);
                let msgs = pair_cnt + 2 * gather_cnt;
                let gather_bytes = if gather_cnt > 0 {
                    2 * gather_cnt * gb(g + 1)
                } else {
                    0
                };
                let bytes = pair_cnt * pair_bytes(g) + gather_bytes;
                let bisection_bps = lg.beta_bps * (groups(g) as f64 / 2.0).max(1.0);
                fabric += msgs as f64 * lg.fabric_msg_cost_s
                    + bytes as f64 / bisection_bps;
            }
        }
        CommBreakdown { software, fabric }
    }

    /// Per-link-level messages of one tree exchange (index 0 =
    /// intra-board) — the exact ragged-aware closed form the live
    /// transport's accounting sums to
    /// ([`crate::comm::topology::TopologyTree::level_message_counts`]).
    pub fn tree_level_messages(&self, p: u32, shape: &[u32]) -> Vec<u64> {
        crate::comm::topology::TopologyTree::new(p.max(1), shape).level_message_counts()
    }

    /// Split an explicit per-pair traffic matrix `bytes[src][dst]` by
    /// the tree's link levels (index 0 = intra-board): the byte-side
    /// counterpart of [`Self::tree_level_messages`], and the pricing
    /// view of the placement study — a comm-aware placement moves bytes
    /// from the high (expensive) levels down to level 0 without
    /// changing the total. The self slot is never counted.
    pub fn tree_level_bytes(&self, bytes: &[Vec<u64>], shape: &[u32]) -> Vec<u64> {
        let p = bytes.len() as u32;
        let tree = crate::comm::topology::TopologyTree::new(p.max(1), shape);
        let mut lv = vec![0u64; tree.depth() + 1];
        for (src, row) in bytes.iter().enumerate() {
            assert_eq!(row.len() as u32, p, "traffic matrix must be square");
            for (dst, &b) in row.iter().enumerate() {
                if src != dst && b > 0 {
                    lv[tree.link_level(src as u32, dst as u32)] += b;
                }
            }
        }
        lv
    }

    /// Fabric messages (link levels >= 1) of one tree exchange.
    pub fn tree_fabric_messages(&self, p: u32, shape: &[u32]) -> u64 {
        crate::comm::topology::TopologyTree::new(p.max(1), shape)
            .fabric_messages_per_exchange()
    }

    /// Total messages of one hierarchical exchange (direct intra-node +
    /// gathers + aggregated inter-node), ragged last node included —
    /// delegates to the closed form the live transport's accounting
    /// matches exactly
    /// ([`crate::comm::topology::NodeMap::total_messages_per_exchange`]).
    pub fn hierarchical_messages(&self, p: u32) -> u64 {
        crate::comm::topology::NodeMap::new(p.max(1), self.ranks_per_node)
            .total_messages_per_exchange()
    }

    /// Inter-node (fabric) messages of one hierarchical exchange:
    /// `N(N−1)` aggregated node-pair messages, versus the flat
    /// exchange's `P(P−1)` ([`Self::total_messages`]).
    pub fn hierarchical_inter_messages(&self, p: u32) -> u64 {
        crate::comm::topology::NodeMap::new(p.max(1), self.ranks_per_node)
            .inter_messages_per_exchange()
    }

    /// Exchange where each (src, dst) pair is active with probability
    /// `coverage` — the destination-filtered routing of
    /// [`crate::comm::routing`], where a pair only puts bytes on the
    /// wire when the source rank hosts a neuron projecting into the
    /// destination. `coverage = 1` reproduces [`Self::exchange_time`]
    /// (dense connectivity degenerates to broadcast); lower coverage
    /// thins both the per-rank software term and the fabric's message
    /// and byte load.
    pub fn exchange_time_filtered(
        &self,
        p: u32,
        bytes_per_msg: u64,
        coverage: f64,
    ) -> CommBreakdown {
        if p <= 1 {
            return CommBreakdown::default();
        }
        let coverage = coverage.clamp(0.0, 1.0);
        let (remote, local) = self.peers(p);
        let software = coverage
            * (remote as f64 * self.net.message_time(bytes_per_msg)
                + local as f64 * self.shm.message_time(bytes_per_msg));
        let internode_msgs = coverage * (p as u64 * remote as u64) as f64;
        let internode_bytes = internode_msgs * bytes_per_msg as f64;
        let bisection_bps = self.net.beta_bps * (self.nodes(p) as f64 / 2.0).max(1.0);
        let fabric = internode_msgs * self.net.fabric_msg_cost_s
            + internode_bytes / bisection_bps;
        CommBreakdown { software, fabric }
    }

    /// Price an explicit per-pair traffic matrix `bytes[src][dst]` (the
    /// run-total or per-step matrix accumulated by
    /// [`crate::comm::transport::ExchangeStats::per_dst_bytes`]). Ranks
    /// are packed onto nodes in index order, `ranks_per_node` at a time.
    /// A pair with zero bytes is treated as statically dead (the filter
    /// proved no synapse crosses it) and sends no envelope; the self
    /// slot is never priced.
    pub fn exchange_time_matrix(&self, bytes: &[Vec<u64>]) -> CommBreakdown {
        let p = bytes.len() as u32;
        if p <= 1 {
            return CommBreakdown::default();
        }
        let node_of = |r: u32| r / self.ranks_per_node;
        let mut software = 0.0f64;
        let mut internode_msgs = 0u64;
        let mut internode_bytes = 0u64;
        for (src, row) in bytes.iter().enumerate() {
            assert_eq!(row.len() as u32, p, "traffic matrix must be square");
            let mut t = 0.0;
            for (dst, &b) in row.iter().enumerate() {
                if dst == src || b == 0 {
                    continue;
                }
                if node_of(src as u32) == node_of(dst as u32) {
                    t += self.shm.message_time(b);
                } else {
                    t += self.net.message_time(b);
                    internode_msgs += 1;
                    internode_bytes += b;
                }
            }
            software = software.max(t);
        }
        let bisection_bps = self.net.beta_bps * (self.nodes(p) as f64 / 2.0).max(1.0);
        let fabric = internode_msgs as f64 * self.net.fabric_msg_cost_s
            + internode_bytes as f64 / bisection_bps;
        CommBreakdown { software, fabric }
    }

    /// Exchange limited to `peers` neighbor ranks (spatially-mapped
    /// networks: the reduced process-adjacency matrix of the paper's
    /// Fig 1 / [9]). Traffic stays neighbor-local, so the global fabric
    /// term collapses to per-NIC serialization.
    pub fn exchange_time_neighbors(
        &self,
        p: u32,
        bytes_per_msg: u64,
        peers: u32,
    ) -> CommBreakdown {
        if p <= 1 {
            return CommBreakdown::default();
        }
        let peers = peers.min(p - 1);
        let (remote_all, local_all) = self.peers(p);
        let local = peers.min(local_all);
        let remote = (peers - local).min(remote_all);
        let software = remote as f64 * self.net.message_time(bytes_per_msg)
            + local as f64 * self.shm.message_time(bytes_per_msg);
        // per-NIC serialization: each node's port carries its ranks' msgs
        let nic_msgs = (self.ranks_per_node.min(p) as u64) * remote as u64;
        let fabric = nic_msgs as f64 * self.net.fabric_msg_cost_s
            + (nic_msgs * bytes_per_msg) as f64 / self.net.beta_bps;
        CommBreakdown { software, fabric }
    }

    /// Barrier cost: dissemination barrier over the slowest link class in
    /// the job (log2 P rounds).
    pub fn barrier_time(&self, p: u32) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rounds = (p as f64).log2().ceil();
        let link = if p <= self.ranks_per_node { &self.shm } else { &self.net };
        rounds * (link.alpha_s + link.cpu_overhead_s)
    }

    /// Total messages per exchange (the paper: "increases with the square
    /// of the number of processes").
    pub fn total_messages(&self, p: u32) -> u64 {
        p as u64 * (p as u64 - 1)
    }

    /// Inter-node messages of one *flat* exchange in the model's view:
    /// only off-node pairs cross the fabric, `P·(P−k)` for `k` ranks per
    /// node. (The live in-process flat transport is topology-blind and
    /// reports all `P(P−1)` peer messages as inter-node; the model
    /// credits it the shared-memory pairs.)
    pub fn flat_inter_messages(&self, p: u32) -> u64 {
        if p <= 1 {
            return 0;
        }
        let k = self.ranks_per_node.min(p) as u64;
        p as u64 * (p as u64 - k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::presets::{ETH1G, IB};

    #[test]
    fn single_rank_is_free() {
        let m = AllToAllModel::new(IB, 16);
        assert_eq!(m.exchange_time(1, 100).total(), 0.0);
        assert_eq!(m.barrier_time(1), 0.0);
    }

    #[test]
    fn message_count_is_quadratic() {
        let m = AllToAllModel::new(IB, 16);
        assert_eq!(m.total_messages(4), 12);
        assert_eq!(m.total_messages(256), 256 * 255);
    }

    #[test]
    fn latency_wall_grows_superlinearly() {
        // Doubling P beyond one node must more than double comm time:
        // the paper's latency wall.
        let m = AllToAllModel::new(IB, 16);
        let b = 25; // ~2 spikes/rank/step at the real-time point
        let t32 = m.exchange_time(32, b).total();
        let t64 = m.exchange_time(64, b).total();
        let t256 = m.exchange_time(256, b).total();
        assert!(t64 > 2.0 * t32, "t32={t32} t64={t64}");
        assert!(t256 > 10.0 * t32, "t32={t32} t256={t256}");
    }

    #[test]
    fn intra_node_jobs_avoid_the_fabric() {
        let m = AllToAllModel::new(ETH1G, 16);
        let t = m.exchange_time(8, 100);
        assert_eq!(t.fabric, 0.0);
        assert!(t.software > 0.0);
    }

    #[test]
    fn eth_slower_than_ib_at_scale() {
        let ib = AllToAllModel::new(IB, 16);
        let eth = AllToAllModel::new(ETH1G, 16);
        for p in [32u32, 64] {
            assert!(
                eth.exchange_time(p, 25).total() > 2.0 * ib.exchange_time(p, 25).total(),
                "p={p}"
            );
        }
    }

    #[test]
    fn paper_anchor_magnitudes() {
        // DESIGN.md §8 sanity anchors, N20K@3.2 Hz (~2 spikes -> 25 B msgs):
        // IB 32p ≈ 0.2-0.4 ms/step; IB 256p ≈ 15-30 ms/step.
        let m = AllToAllModel::new(IB, 16);
        let t32 = m.exchange_time(32, 25).total();
        let t256 = m.exchange_time(256, 25).total();
        assert!((1.5e-4..6e-4).contains(&t32), "t32={t32}");
        assert!((1.0e-2..4.0e-2).contains(&t256), "t256={t256}");
    }

    #[test]
    fn epoch_batching_amortizes_the_latency_wall() {
        // 16 steps of 25 B batched into one 400 B (+framing) exchange:
        // near real time the α term dominates, so one batched window
        // must cost far less than 16 per-step exchanges.
        let m = AllToAllModel::new(IB, 16);
        for p in [32u32, 64, 256] {
            let batched_window = m.exchange_time_epoch(p, 25, 16).total();
            let per_step_window = 16.0 * m.exchange_time(p, 25).total();
            assert!(
                batched_window < 0.25 * per_step_window,
                "p={p}: batched {batched_window} vs per-step {per_step_window}"
            );
        }
    }

    #[test]
    fn epoch_of_one_is_the_flat_exchange() {
        let m = AllToAllModel::new(IB, 16);
        assert_eq!(m.exchange_time(64, 25), m.exchange_time_epoch(64, 25, 1));
        assert_eq!(m.exchange_time_epoch(1, 25, 16).total(), 0.0);
        // payload conservation: a window carries the window's bytes
        // (plus headers), so batching trades latency, not bandwidth
        let eth = AllToAllModel::new(ETH1G, 16);
        let one = eth.exchange_time_epoch(64, 1_000_000, 4).total();
        let four = 4.0 * eth.exchange_time(64, 1_000_000).total();
        // at megabyte payloads both regimes are bandwidth-bound: no 4x win
        assert!(one > 0.5 * four, "bandwidth-bound: {one} vs {four}");
    }

    #[test]
    fn neighbor_exchange_scales_far_better() {
        // the paper's point: spatial mapping removes the latency wall
        let m = AllToAllModel::new(IB, 16);
        let all = m.exchange_time(1024, 200).total();
        let nbr = m.exchange_time_neighbors(1024, 200, 40).total();
        assert!(nbr < all / 20.0, "all={all} nbr={nbr}");
        // degenerate cases
        assert_eq!(m.exchange_time_neighbors(1, 100, 8).total(), 0.0);
        let small = m.exchange_time_neighbors(4, 100, 64);
        assert!(small.total() > 0.0);
    }

    #[test]
    fn filtered_full_coverage_matches_homogeneous() {
        let m = AllToAllModel::new(IB, 16);
        for p in [4u32, 32, 256] {
            let a = m.exchange_time(p, 25);
            let b = m.exchange_time_filtered(p, 25, 1.0);
            assert!((a.total() - b.total()).abs() < 1e-12 * a.total().max(1e-30));
        }
        assert_eq!(m.exchange_time_filtered(1, 25, 0.5).total(), 0.0);
    }

    #[test]
    fn filtered_coverage_scales_cost_down() {
        let m = AllToAllModel::new(IB, 16);
        let full = m.exchange_time(64, 25).total();
        let half = m.exchange_time_filtered(64, 25, 0.5).total();
        let tenth = m.exchange_time_filtered(64, 25, 0.1).total();
        assert!(half < full && tenth < half, "{full} {half} {tenth}");
        // both terms thin with coverage, so cost is ~linear in it
        assert!((half / full - 0.5).abs() < 0.05, "half/full={}", half / full);
    }

    #[test]
    fn matrix_pricing_matches_homogeneous_exchange() {
        let m = AllToAllModel::new(IB, 16);
        let p = 32usize;
        let b = 25u64;
        let matrix: Vec<Vec<u64>> = (0..p)
            .map(|src| (0..p).map(|dst| if src == dst { 0 } else { b }).collect())
            .collect();
        let got = m.exchange_time_matrix(&matrix);
        let want = m.exchange_time(p as u32, b);
        assert!(
            (got.software - want.software).abs() < 1e-9 * want.software,
            "software {} vs {}",
            got.software,
            want.software
        );
        assert!(
            (got.fabric - want.fabric).abs() < 1e-9 * want.fabric,
            "fabric {} vs {}",
            got.fabric,
            want.fabric
        );
    }

    #[test]
    fn matrix_pricing_skips_dead_pairs() {
        let m = AllToAllModel::new(IB, 4);
        // 8 ranks on 2 nodes; only rank 0 -> rank 7 carries traffic.
        let mut matrix = vec![vec![0u64; 8]; 8];
        matrix[0][7] = 1000;
        let t = m.exchange_time_matrix(&matrix);
        assert!(t.software > 0.0 && t.fabric > 0.0);
        let full: Vec<Vec<u64>> = (0..8)
            .map(|src| (0..8).map(|dst| if src == dst { 0 } else { 1000 }).collect())
            .collect();
        assert!(t.total() < m.exchange_time_matrix(&full).total() / 4.0);
        // degenerate: single rank
        assert_eq!(m.exchange_time_matrix(&[vec![0]]).total(), 0.0);
    }

    #[test]
    fn hierarchical_exchange_beats_flat_at_scale() {
        // The tentpole claim, priced: near real time the flat exchange
        // pays P(P-1) per-message costs; node-leader aggregation pays
        // N(N-1) bigger ones. At spike-sized payloads the win is large.
        let m = AllToAllModel::new(IB, 16);
        for p in [64u32, 256] {
            let flat = m.exchange_time(p, 25).total();
            let hier = m.exchange_time_hierarchical(p, 25).total();
            assert!(
                hier < flat / 4.0,
                "p={p}: hier {hier} vs flat {flat}"
            );
        }
    }

    #[test]
    fn hierarchical_degenerates_inside_one_node() {
        let m = AllToAllModel::new(IB, 16);
        assert_eq!(m.exchange_time_hierarchical(1, 100).total(), 0.0);
        // p <= ranks_per_node: no leaders, no fabric — the flat
        // node-local exchange
        assert_eq!(m.exchange_time_hierarchical(8, 100), m.exchange_time(8, 100));
        assert_eq!(m.exchange_time_hierarchical(8, 100).fabric, 0.0);
    }

    #[test]
    fn hierarchical_conserves_internode_payload() {
        // Aggregation trades message count, not bandwidth: at
        // megabyte payloads both regimes are serialization-bound on the
        // same inter-node byte volume (modulo the 12 B frames), so the
        // fabric terms converge.
        let m = AllToAllModel::new(IB, 16);
        let flat = m.exchange_time(64, 1_000_000).fabric;
        let hier = m.exchange_time_hierarchical(64, 1_000_000).fabric;
        let ratio = hier / flat;
        assert!((0.95..1.05).contains(&ratio), "fabric ratio {ratio}");
    }

    #[test]
    fn hierarchical_message_counts_match_topology_closed_form() {
        let m = AllToAllModel::new(IB, 4);
        // 8 ranks on 2 nodes of 4: 2*4*3 direct + 2*3 gathers + 2 inter
        assert_eq!(m.hierarchical_messages(8), 24 + 6 + 2);
        assert_eq!(m.hierarchical_inter_messages(8), 2);
        // flat comparison: the P(P-1) cliff
        assert_eq!(m.total_messages(8), 56);
        // one node: no gathers, no inter
        assert_eq!(m.hierarchical_messages(4), 12);
        assert_eq!(m.hierarchical_inter_messages(4), 0);
        // one rank per node: inter equals the flat count
        let m1 = AllToAllModel::new(IB, 1);
        assert_eq!(m1.hierarchical_inter_messages(6), 30);
    }

    #[test]
    fn one_level_tree_matches_hierarchical_pricing() {
        // tree:<k> with default links IS the two-level node-leader
        // exchange — same software lap, same fabric term.
        let m = AllToAllModel::new(IB, 16);
        for p in [2u32, 8, 16, 32, 64, 256, 300] {
            for b in [0u64, 25, 1000] {
                let tree = m.exchange_time_tree(p, b, &[16], &[]);
                let hier = m.exchange_time_hierarchical(p, b);
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * b.abs().max(1e-30);
                assert!(close(tree.software, hier.software), "p={p} b={b}");
                assert!(close(tree.fabric, hier.fabric), "p={p} b={b}");
            }
        }
        assert_eq!(m.exchange_time_tree(1, 25, &[16], &[]).total(), 0.0);
    }

    #[test]
    fn tree_message_counts_match_topology_closed_form() {
        let m = AllToAllModel::new(IB, 2);
        // 10 ranks as tree:2,2 (ragged chassis): levels by hand
        assert_eq!(m.tree_level_messages(10, &[2, 2]), vec![15, 6, 6]);
        assert_eq!(m.tree_fabric_messages(10, &[2, 2]), 12);
        // depth 1 equals the NodeMap closed form
        assert_eq!(
            m.tree_fabric_messages(8, &[2]),
            m.hierarchical_inter_messages(8)
        );
    }

    #[test]
    fn tree_level_bytes_splits_the_traffic_matrix() {
        let m = AllToAllModel::new(IB, 2);
        // 4 ranks as tree:2 — boards {0,1}, {2,3}
        let bytes = vec![
            vec![99, 10, 20, 30], // self slot ignored
            vec![5, 0, 7, 0],
            vec![0, 0, 0, 11],
            vec![1, 2, 3, 0],
        ];
        let lv = m.tree_level_bytes(&bytes, &[2]);
        assert_eq!(lv, vec![10 + 5 + 11 + 3, 20 + 30 + 7 + 1 + 2]);
        // conservation: levels sum to the off-diagonal total
        let off: u64 = bytes
            .iter()
            .enumerate()
            .flat_map(|(s, row)| {
                row.iter().enumerate().filter(move |&(d, _)| d != s).map(|(_, &b)| b)
            })
            .sum();
        assert_eq!(lv.iter().sum::<u64>(), off);
        // one board holding every rank: everything is level 0
        assert_eq!(m.tree_level_bytes(&bytes, &[4]), vec![off, 0]);
    }

    #[test]
    fn deeper_tree_wins_when_the_top_tier_is_expensive() {
        // The tentpole's pricing claim: once the top tier is slow
        // relative to the tiers below, adding a chassis level between
        // board and rack collapses the expensive-link message count
        // (240 board pairs -> 12 chassis pairs at P=256) and wins
        // end-to-end, despite the extra gather/scatter hops.
        let m = AllToAllModel::new(IB, 16);
        let rack = LinkModel {
            alpha_s: IB.alpha_s * 10.0,
            fabric_msg_cost_s: IB.fabric_msg_cost_s * 10.0,
            ..IB
        };
        let p = 256;
        let two = m.exchange_time_tree(p, 25, &[16], &[rack]).total();
        let three = m.exchange_time_tree(p, 25, &[16, 4], &[IB, rack]).total();
        assert!(three < two, "three-tier {three} vs two-tier {two}");
        // inside one chassis the extra tier never touches the rack link
        let small_two = m.exchange_time_tree(32, 25, &[16], &[rack]).total();
        let small_three = m.exchange_time_tree(32, 25, &[16, 4], &[IB, rack]).total();
        assert!(small_three < small_two);
    }

    #[test]
    fn tree_with_uniform_links_adds_hops_for_nothing() {
        // With a SINGLE uniform link class the deeper tree only adds
        // store-and-forward hops on the same fabric, so it must not
        // beat the two-level split — per-level pricing is what makes
        // depth worthwhile, and this pins the null case.
        let m = AllToAllModel::new(IB, 16);
        let two = m.exchange_time_tree(256, 25, &[16], &[IB]).total();
        let three = m.exchange_time_tree(256, 25, &[16, 4], &[IB, IB]).total();
        assert!(three > two, "uniform links: three {three} vs two {two}");
    }

    #[test]
    fn barrier_is_logarithmic() {
        let m = AllToAllModel::new(IB, 16);
        // within the network regime (p > ranks_per_node) growth is log2
        assert!(m.barrier_time(256) < 2.0 * m.barrier_time(32));
        assert!(m.barrier_time(2) > 0.0);
    }
}
