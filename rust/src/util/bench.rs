//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline). Provides warmup, calibrated iteration counts, and robust
//! statistics; used by the `benches/` targets (`harness = false`).

use std::time::{Duration, Instant};

/// Result statistics for one benchmark case (all values in seconds/iter).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u64,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub p95: f64,
    pub stddev: f64,
    /// Optional throughput denominator (elements processed per iteration).
    pub elements: Option<f64>,
}

impl Stats {
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e / self.mean)
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Melem/s", t / 1e6),
            Some(t) => format!("  {:8.2} elem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12}/iter  (median {}, min {}, p95 {}, sd {:.1}%, n={}){}",
            self.name,
            crate::util::units::fmt_seconds(self.mean),
            crate::util::units::fmt_seconds(self.median),
            crate::util::units::fmt_seconds(self.min),
            crate::util::units::fmt_seconds(self.p95),
            if self.mean > 0.0 { 100.0 * self.stddev / self.mean } else { 0.0 },
            self.iters,
            tp
        )
    }
}

/// A benchmark runner with a fixed time budget per case.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1500),
            max_samples: 2000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn fast() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            max_samples: 200,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `f` should perform one logical iteration and
    /// return a value that is consumed with `black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Stats {
        self.bench_with_elements(name, None, &mut f)
    }

    /// Same, reporting throughput as `elements / iter_time`.
    pub fn bench_elems<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elements: f64,
        mut f: F,
    ) -> &Stats {
        self.bench_with_elements(name, Some(elements), &mut f)
    }

    fn bench_with_elements<T>(
        &mut self,
        name: &str,
        elements: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Stats {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut wcount = 0u64;
        while wstart.elapsed() < self.warmup || wcount == 0 {
            black_box(f());
            wcount += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / wcount as f64;

        // Batch iterations so each timed sample is >= ~50us.
        let batch = (5e-5 / per_iter.max(1e-12)).ceil().max(1.0) as u64;
        let mut samples: Vec<f64> = Vec::new();
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
        }

        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let median = samples[n / 2];
        let min = samples[0];
        let p95 = samples[((n as f64 * 0.95) as usize).min(n - 1)];
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        let stats = Stats {
            name: name.to_string(),
            iters: n as u64 * batch,
            mean,
            median,
            min,
            p95,
            stddev: var.sqrt(),
            elements,
        };
        println!("{}", stats.report_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline(always)]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
