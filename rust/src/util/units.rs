//! Small unit helpers: durations, byte counts, engineering formatting.

/// Seconds -> human string ("1.23 ms", "4.5 s").
pub fn fmt_seconds(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", fmt_seconds(-s));
    }
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// Bytes -> human string.
pub fn fmt_bytes(b: f64) -> String {
    const K: f64 = 1024.0;
    if b < K {
        format!("{b:.0} B")
    } else if b < K * K {
        format!("{:.1} KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1} MiB", b / (K * K))
    } else {
        format!("{:.2} GiB", b / (K * K * K))
    }
}

/// Count -> engineering notation ("2.30e+07" like the paper's tables).
pub fn fmt_sci(x: f64) -> String {
    format!("{x:.2E}")
}

/// Percentage with one decimal, paper-table style.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seconds_ranges() {
        assert_eq!(fmt_seconds(2e-9), "2.0 ns");
        assert_eq!(fmt_seconds(3.5e-5), "35.00 us");
        assert_eq!(fmt_seconds(0.012), "12.00 ms");
        assert_eq!(fmt_seconds(9.15), "9.15 s");
        assert_eq!(fmt_seconds(600.0), "10.0 min");
    }

    #[test]
    fn bytes_ranges() {
        assert_eq!(fmt_bytes(12.0), "12 B");
        assert_eq!(fmt_bytes(2048.0), "2.0 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.0 MiB");
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(fmt_sci(2.30e7), "2.30E7");
    }
}
