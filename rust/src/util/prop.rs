//! Tiny property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`SplitMix64`]; the harness runs
//! it for many seeds and, on failure, reports the offending seed so the
//! case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the xla rpath in this image)
//! use dpsnn::util::prop::forall;
//! forall("sum is commutative", 200, |rng| {
//!     let a = rng.next_below(1000) as u64;
//!     let b = rng.next_below(1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::SplitMix64;

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn forall<F: Fn(&mut SplitMix64) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    // Honour DPSNN_PROP_SEED to replay a single failing case.
    if let Ok(seed) = std::env::var("DPSNN_PROP_SEED") {
        let seed: u64 = seed.parse().expect("DPSNN_PROP_SEED must be u64");
        let mut rng = SplitMix64::new(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = crate::util::rng::mix64(0xDEADBEEF ^ case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = SplitMix64::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at case {case} \
                 (replay with DPSNN_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall("true", 50, |rng| {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            forall("always false", 3, |_| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("DPSNN_PROP_SEED="), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }
}
