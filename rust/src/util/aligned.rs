//! Cache-line-aligned f32 storage for the SoA compute hot path.
//!
//! The LIF+SFA update streams six f32 arrays per step; aligning each to a
//! 64 B cache line (and padding lengths up to whole lines) gives the
//! autovectorizer aligned loads/stores and keeps the per-chunk slices of
//! the threaded update from sharing lines across chunk boundaries (see
//! [`crate::util::pool::CHUNK_ALIGN`]).

use std::ops::{Deref, DerefMut};

/// f32 lanes per 64 B cache line.
pub const LANES_PER_LINE: usize = 16;

// The lanes are only ever read through the `Deref` pointer cast, never
// through the field itself — allow(dead_code) keeps rustc's unread-field
// lint quiet about that.
#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct Line(#[allow(dead_code)] [f32; LANES_PER_LINE]);

/// A contiguous `[f32]` whose first element sits on a 64 B boundary and
/// whose backing allocation is padded to whole cache lines (the pad lanes
/// are zero and stay outside the `Deref` view).
#[derive(Clone)]
pub struct AlignedF32 {
    buf: Vec<Line>,
    len: usize,
}

impl AlignedF32 {
    pub fn zeroed(len: usize) -> Self {
        let lines = len.div_ceil(LANES_PER_LINE);
        Self { buf: vec![Line([0.0; LANES_PER_LINE]); lines], len }
    }

    pub fn from_slice(xs: &[f32]) -> Self {
        let mut a = Self::zeroed(xs.len());
        a.copy_from_slice(xs);
        a
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Deref for AlignedF32 {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: the Vec<Line> allocation holds at least `len` contiguous
        // f32s (lines are plain [f32; 16] with no padding between them).
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr() as *const f32, self.len) }
    }
}

impl DerefMut for AlignedF32 {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as above, and we hold &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.buf.as_mut_ptr() as *mut f32, self.len) }
    }
}

impl std::fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&**self, f)
    }
}

impl PartialEq for AlignedF32 {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_and_sized() {
        for n in [0usize, 1, 15, 16, 17, 100, 4096] {
            let a = AlignedF32::zeroed(n);
            assert_eq!(a.len(), n);
            assert_eq!(a.as_ptr() as usize % 64, 0, "n={n}");
            assert!(a.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn round_trips_a_slice() {
        let xs: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let mut a = AlignedF32::from_slice(&xs);
        assert_eq!(&*a, &xs[..]);
        a[36] = -1.0;
        assert_eq!(a[36], -1.0);
        let b = a.clone();
        assert_eq!(a, b);
    }
}
