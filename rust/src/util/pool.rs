//! Deterministic intra-rank compute threading (`--compute-threads N`).
//!
//! [`ComputePool`] splits an index space into a **fixed** number of chunks
//! (= the requested thread count) and executes one closure call per chunk.
//! Determinism is by construction, not by luck:
//!
//! * chunk boundaries are a pure function of `(n, chunks)` — they never
//!   depend on how many OS workers actually run or how they are scheduled;
//! * every chunk writes only its own output region (disjoint state slices,
//!   a private spike vector, a disjoint delay-ring target range), so no
//!   accumulator ever sees adds from two chunks;
//! * per-chunk outputs are reduced in ascending chunk order by the caller.
//!
//! Under those rules the result is bitwise identical for every worker
//! count — the pool clamps *workers* to the host parallelism but never
//! changes the *chunk* geometry, so `--compute-threads 4` computes the
//! same raster on a 1-core box as on a 64-core one.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Chunk starts are multiples of this many elements, so no two chunks
/// touch the same 64 B cache line of any state array (f32 = 16 lanes per
/// line, the u8 fired-mask = 64).
pub const CHUNK_ALIGN: usize = 64;

/// The fixed split of `0..n` into `chunks` aligned ranges: every chunk is
/// `ceil(n / chunks)` rounded up to [`CHUNK_ALIGN`] elements wide, except
/// the tail (later chunks may be empty). Pure in `(chunks, c, n)`.
pub fn chunk_range(chunks: usize, c: usize, n: usize) -> std::ops::Range<usize> {
    debug_assert!(c < chunks);
    let per = n.div_ceil(chunks).div_ceil(CHUNK_ALIGN).max(1) * CHUNK_ALIGN;
    let lo = (c * per).min(n);
    let hi = ((c + 1) * per).min(n);
    lo..hi
}

/// A borrowed job, lifetime-erased for the worker channels. Sound because
/// [`ComputePool::run`] blocks until every worker has signalled completion
/// before returning — the borrow outlives every dereference.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    chunks: usize,
    stride: usize,
}
// SAFETY: the pointee is Sync and outlives the job (see above).
unsafe impl Send for Job {}

pub struct ComputePool {
    /// Fixed chunk count (= requested threads); the determinism contract.
    chunks: usize,
    /// Executors actually running chunks: the caller + the workers.
    /// Clamped to the host parallelism so oversubscription never turns
    /// into context-switch thrash (chunk geometry is unaffected).
    executors: usize,
    senders: Vec<Sender<Job>>,
    done_rx: Receiver<()>,
    handles: Vec<JoinHandle<()>>,
}

impl ComputePool {
    /// A pool computing in `threads` fixed chunks (0 is treated as 1).
    pub fn new(threads: usize) -> Self {
        let chunks = threads.max(1);
        let host = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let executors = chunks.min(host);
        let (done_tx, done_rx) = channel();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for i in 1..executors {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("compute-{i}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // SAFETY: run() keeps the closure alive until every
                        // worker has sent its done token.
                        let f = unsafe { &*job.f };
                        let mut c = i;
                        while c < job.chunks {
                            f(c);
                            c += job.stride;
                        }
                        if done.send(()).is_err() {
                            return;
                        }
                    }
                })
                .expect("spawn compute worker");
            senders.push(tx);
            handles.push(h);
        }
        Self { chunks, executors, senders, done_rx, handles }
    }

    /// The fixed chunk count (what determinism depends on).
    pub fn chunks(&self) -> usize {
        self.chunks
    }

    /// Executors actually running (caller + spawned workers).
    pub fn executors(&self) -> usize {
        self.executors
    }

    /// [`chunk_range`] with this pool's chunk count.
    pub fn chunk_range(&self, c: usize, n: usize) -> std::ops::Range<usize> {
        chunk_range(self.chunks, c, n)
    }

    /// Execute `f(c)` once for every chunk `c in 0..chunks()`, spread over
    /// the executors (worker `i` runs chunks `i, i+E, ...`; the caller
    /// runs the `0, E, ...` series). Blocks until all chunks are done.
    ///
    /// `f` must confine each chunk's writes to that chunk's own output
    /// region; which executor runs a chunk is not deterministic, only the
    /// chunk geometry is.
    pub fn run(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.senders.is_empty() {
            for c in 0..self.chunks {
                f(c);
            }
            return;
        }
        // lifetime-erase the borrow; run() outlives every use (see Job)
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        let job = Job { f: f_static as *const _, chunks: self.chunks, stride: self.executors };
        for tx in &self.senders {
            tx.send(job).expect("compute worker died");
        }
        let mut c = 0;
        while c < self.chunks {
            f(c);
            c += self.executors;
        }
        for _ in &self.senders {
            self.done_rx.recv().expect("compute worker died");
        }
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        self.senders.clear(); // closes the channels; workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A raw pointer a chunk closure may share across threads. The *user*
/// guarantees disjoint access per chunk; the wrapper only silences the
/// auto-trait checks that can't see that.
#[derive(Clone, Copy)]
pub struct SyncPtr<T>(pub *mut T);
unsafe impl<T> Send for SyncPtr<T> {}
unsafe impl<T> Sync for SyncPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_are_aligned_and_cover() {
        for chunks in [1usize, 2, 3, 4, 8] {
            for n in [0usize, 1, 63, 64, 65, 300, 1000, 20480] {
                let mut next = 0usize;
                for c in 0..chunks {
                    let r = chunk_range(chunks, c, n);
                    assert_eq!(r.start, next, "chunks={chunks} n={n} c={c}");
                    assert!(r.start % CHUNK_ALIGN == 0 || r.start == n);
                    next = r.end;
                }
                assert_eq!(next, n, "chunks={chunks} n={n}");
            }
        }
    }

    #[test]
    fn runs_every_chunk_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = ComputePool::new(threads);
            assert_eq!(pool.chunks(), threads);
            let hits: Vec<std::sync::atomic::AtomicU32> =
                (0..threads).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
            for _ in 0..50 {
                pool.run(&|c| {
                    hits[c].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                });
            }
            for (c, h) in hits.iter().enumerate() {
                assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 50, "chunk {c}");
            }
        }
    }

    #[test]
    fn chunked_writes_match_sequential() {
        let n = 300usize;
        let seq: Vec<f32> = (0..n).map(|j| (j * j) as f32).collect();
        for threads in [1usize, 2, 4] {
            let pool = ComputePool::new(threads);
            let mut out = vec![0.0f32; n];
            let p = SyncPtr(out.as_mut_ptr());
            // NB: closures must not capture &pool (the pool itself is not
            // Sync); capture the chunk count and use the free fn.
            let chunks = pool.chunks();
            pool.run(&|c| {
                let r = chunk_range(chunks, c, n);
                for j in r {
                    // SAFETY: chunks are disjoint index ranges.
                    unsafe { *p.0.add(j) = (j * j) as f32 };
                }
            });
            assert_eq!(out, seq, "threads={threads}");
        }
    }
}
