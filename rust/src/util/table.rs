//! Plain-text table rendering for harness output (paper-style tables)
//! plus CSV emission for plots.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let sep: String = w
            .iter()
            .map(|n| "-".repeat(n + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV beside other harness outputs; creates parent dirs.
    pub fn write_csv(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Render an ASCII scatter/line chart of (x, y) series — a quick visual
/// check of figure shapes directly in the terminal.
pub fn ascii_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    logx: bool,
    logy: bool,
    width: usize,
    height: usize,
) -> String {
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let tx = |x: f64| if logx { x.max(1e-300).log10() } else { x };
    let ty = |y: f64| if logy { y.max(1e-300).log10() } else { y };
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|&(x, y)| (tx(x), ty(y))))
        .collect();
    if all.is_empty() {
        return format!("{title}: <empty>\n");
    }
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let gx = (((tx(x) - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let gy = (((ty(y) - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let gy = height - 1 - gy.min(height - 1);
            grid[gy][gx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}\n");
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("  +{}\n", "-".repeat(width)));
    let legend = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", marks[i % marks.len()], name))
        .collect::<Vec<_>>()
        .join("   ");
    out.push_str(&format!("   {legend}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("## T"));
        assert!(r.lines().count() >= 4);
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"x\"\"y\",z"));
    }

    #[test]
    fn chart_renders_points() {
        let s = ascii_chart(
            "demo",
            &[("one", vec![(1.0, 1.0), (2.0, 2.0)])],
            false,
            false,
            20,
            5,
        );
        assert!(s.contains('*'));
        assert!(s.contains("one"));
    }
}
