//! Minimal TOML-subset parser for run configuration files.
//!
//! Supported: `[table.subtable]` headers, `key = value` with string,
//! integer, float, boolean and flat arrays of those; `#` comments.
//! This covers every config file shipped in `configs/` — it is not a
//! general TOML implementation.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted table path -> key -> value.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, table: &str, key: &str) -> Option<&Value> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    pub fn str_or(&self, table: &str, key: &str, default: &str) -> String {
        self.get(table, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, table: &str, key: &str, default: f64) -> f64 {
        self.get(table, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn i64_or(&self, table: &str, key: &str, default: i64) -> i64 {
        self.get(table, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn bool_or(&self, table: &str, key: &str, default: bool) -> bool {
        self.get(table, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn parse_scalar(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if t.starts_with('"') {
        let inner = t
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .ok_or_else(|| anyhow!("unterminated string: {t}"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = t.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {t:?}")
}

fn parse_value(tok: &str) -> Result<Value> {
    let t = tok.trim();
    if let Some(body) = t.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array: {t}"))?;
        let mut items = Vec::new();
        if !body.trim().is_empty() {
            // split on commas not inside strings
            let mut depth_str = false;
            let mut cur = String::new();
            for c in body.chars() {
                match c {
                    '"' => {
                        depth_str = !depth_str;
                        cur.push(c);
                    }
                    ',' if !depth_str => {
                        items.push(parse_scalar(&cur)?);
                        cur.clear();
                    }
                    _ => cur.push(c),
                }
            }
            if !cur.trim().is_empty() {
                items.push(parse_scalar(&cur)?);
            }
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t)
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut table = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix('[') {
            let name = h
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad table header {raw:?}", lineno + 1))?;
            table = name.trim().to_string();
            doc.tables.entry(table.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value, got {raw:?}", lineno + 1))?;
        let value = parse_value(v)
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.tables
            .entry(table.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

pub fn parse_file(path: &std::path::Path) -> Result<Doc> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
            # run config
            title = "demo"
            [network]
            neurons = 20_480
            rate_hz = 3.2          # target
            exc = true
            sizes = [1, 2, 3]
            [run.platform]
            name = "xeon-ib"
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "title", ""), "demo");
        assert_eq!(doc.i64_or("network", "neurons", 0), 20480);
        assert!((doc.f64_or("network", "rate_hz", 0.0) - 3.2).abs() < 1e-12);
        assert!(doc.bool_or("network", "exc", false));
        assert_eq!(doc.str_or("run.platform", "name", ""), "xeon-ib");
        match doc.get("network", "sizes").unwrap() {
            Value::Array(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let doc = parse("s = \"a # not comment \\\" q\"").unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a # not comment \" q");
    }

    #[test]
    fn errors_are_reported_with_line() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @?").is_err());
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(doc.get("", "b"), Some(&Value::Float(3.5)));
        assert_eq!(doc.f64_or("", "a", 0.0), 3.0);
    }
}
