//! Deterministic RNG: a SplitMix64 stream generator plus a *counter-based*
//! (stateless) generator used for partition-independent network and
//! stimulus construction.
//!
//! Counter-based draws are keyed by `(seed, a, b, k)` tuples, so any rank
//! can regenerate exactly the draw for, e.g., synapse `k` of neuron `a`
//! without coordination — this is what makes connectivity and Poisson
//! stimulus identical regardless of how many processes the network is
//! partitioned over (see DESIGN.md §7 and the determinism tests).

/// SplitMix64 finalizer: a high-quality 64-bit mix function.
#[inline(always)]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless counter-based draw keyed by up to four values.
#[inline(always)]
pub fn hash4(seed: u64, a: u64, b: u64, k: u64) -> u64 {
    // Feed each key through the mixer so nearby keys decorrelate.
    let mut h = mix64(seed ^ 0xD6E8FEB86659FD93);
    h = mix64(h ^ a.wrapping_mul(0xA24BAED4963EE407));
    h = mix64(h ^ b.wrapping_mul(0x9FB21C651E98DF25));
    mix64(h ^ k)
}

/// Faster two-round keyed hash for per-(cell, step) draws on the hot
/// path (EXPERIMENTS.md §Perf): each round is a full-avalanche mix64, and
/// both keys enter through distinct odd multipliers, so consecutive
/// gids/steps decorrelate. Not a drop-in for [`hash4`] — different stream.
#[inline(always)]
pub fn hash2_fast(seed: u64, a: u64, b: u64) -> u64 {
    mix64(
        mix64(seed ^ a.wrapping_mul(0xA24BAED4963EE407))
            ^ b.wrapping_mul(0x9FB21C651E98DF25),
    )
}

/// A small, fast, seedable sequential RNG (SplitMix64).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream for a labelled purpose.
    pub fn derive(&self, label: u64) -> Self {
        Self { state: mix64(self.state ^ mix64(label)) }
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline(always)]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline(always)]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut m = (self.next_u64() as u32 as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                m = (self.next_u64() as u32 as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline(always)]
    pub fn next_range(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.next_below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Poisson sample, Knuth's method for small lambda, normal
    /// approximation above 30 (adequate for stimulus modelling).
    pub fn next_poisson(&mut self, lambda: f64) -> u32 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = lambda + lambda.sqrt() * self.next_normal();
            return x.max(0.0).round() as u32;
        }
        let l = (-lambda).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
}

/// Counter-based RNG view: a tiny SplitMix64 seeded from a key tuple,
/// for when a few correlated draws are needed per key.
#[inline(always)]
pub fn keyed(seed: u64, a: u64, b: u64, k: u64) -> SplitMix64 {
    SplitMix64::new(hash4(seed, a, b, k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_bijective_sample() {
        // distinct inputs -> distinct outputs on a sample
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn hash2_fast_uniformity_and_sensitivity() {
        // consecutive keys (the hot-path access pattern) must produce
        // uniform-looking outputs: check bit balance over a gid sweep
        let mut ones = [0u32; 64];
        let n = 20_000u64;
        for gid in 0..n {
            let h = hash2_fast(7, gid, 1234);
            for (bit, slot) in ones.iter_mut().enumerate() {
                *slot += ((h >> bit) & 1) as u32;
            }
        }
        for (bit, &c) in ones.iter().enumerate() {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.02, "bit {bit}: {frac}");
        }
        assert_ne!(hash2_fast(1, 2, 3), hash2_fast(2, 2, 3));
        assert_ne!(hash2_fast(1, 2, 3), hash2_fast(1, 3, 3));
        assert_ne!(hash2_fast(1, 2, 3), hash2_fast(1, 2, 4));
    }

    #[test]
    fn hash4_sensitive_to_each_key() {
        let h = hash4(1, 2, 3, 4);
        assert_ne!(h, hash4(2, 2, 3, 4));
        assert_ne!(h, hash4(1, 3, 3, 4));
        assert_ne!(h, hash4(1, 2, 4, 4));
        assert_ne!(h, hash4(1, 2, 3, 5));
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = SplitMix64::new(42);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn poisson_mean_and_variance() {
        let mut r = SplitMix64::new(3);
        for &lambda in &[0.5, 1.2, 4.0, 50.0] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| r.next_poisson(lambda) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < 0.05 * lambda.max(1.0), "mean {mean} vs {lambda}");
            assert!((var - lambda).abs() < 0.1 * lambda.max(1.0), "var {var} vs {lambda}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn derive_gives_independent_streams() {
        let base = SplitMix64::new(5);
        let mut a = base.derive(1);
        let mut b = base.derive(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
