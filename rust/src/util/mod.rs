//! Self-contained utilities.
//!
//! The build environment is offline and only the `xla` crate's dependency
//! closure is available, so the pieces a crate would normally pull from
//! crates.io (CLI parsing, config parsing, RNG, bench/property harnesses)
//! are implemented here.

pub mod rng;
pub mod units;
pub mod aligned;
pub mod pool;
pub mod cli;
pub mod tomlmini;
pub mod bench;
pub mod prop;
pub mod sha256;
pub mod table;
