//! Minimal command-line parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--key value | --flag]`.
//! Values may also be attached as `--key=value`.

use std::collections::BTreeMap;
use std::str::FromStr;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow!("invalid value for --{key}: {v:?} ({e})")),
        }
    }

    /// Typed required option.
    pub fn require<T: FromStr>(&self, key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let v = self
            .options
            .get(key)
            .with_context(|| format!("missing required option --{key}"))?;
        v.parse::<T>()
            .map_err(|e| anyhow!("invalid value for --{key}: {v:?} ({e})"))
    }

    /// Reject unknown options/flags (catch typos early).
    pub fn check_known(&self, known_opts: &[&str], known_flags: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known_opts.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known_opts.join(", "));
            }
        }
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known_flags.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse("run net.toml --procs 8 --backend native --verbose");
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.positional, vec!["run", "net.toml"]);
        assert_eq!(a.get("procs"), Some("8"));
        assert_eq!(a.get("backend"), Some("native"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("repro fig2 --procs=32");
        assert_eq!(a.get_or("procs", 0u32).unwrap(), 32);
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = parse("x --n 10");
        assert_eq!(a.get_or("n", 5u32).unwrap(), 10);
        assert_eq!(a.get_or("m", 5u32).unwrap(), 5);
        assert!(a.require::<u32>("missing").is_err());
        let b = parse("x --n ten");
        assert!(b.get_or("n", 5u32).is_err());
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("x --fast --n 3");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_or("n", 0u32).unwrap(), 3);
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("x --typo 1");
        assert!(a.check_known(&["n"], &[]).is_err());
        assert!(a.check_known(&["typo"], &[]).is_ok());
    }
}
