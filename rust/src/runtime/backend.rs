//! Pluggable neuron-dynamics backends.
//!
//! * [`NativeBackend`] — pure-rust LIF+SFA, the always-available baseline.
//! * [`XlaBackend`] — the AOT-compiled JAX/Pallas artifact via PJRT.
//!
//! Both implement [`NeuronBackend`] and advance the same state with the
//! same arithmetic; the integration tests assert their spike rasters
//! agree on driven networks.

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use crate::config::{Backend, NetworkParams};
use crate::model::neuron::{step_native, StepParams};
use crate::model::population::PopulationState;

use super::client::XlaRuntime;

/// A stateful population integrator: one call = one 1 ms network step.
pub trait NeuronBackend {
    /// Advance one step with the given synaptic and external input
    /// currents (length = population size). Appends the local indices of
    /// neurons that fired to `spiked` and returns the spike count.
    fn step(
        &mut self,
        i_syn: &[f32],
        i_ext: &[f32],
        spiked: &mut Vec<u32>,
    ) -> Result<usize>;

    /// Population size.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current state vectors (v, w, rf) — diagnostics and tests.
    fn state(&self) -> (&[f32], &[f32], &[f32]);

    fn name(&self) -> &'static str;
}

/// Pure-rust backend owning the population state.
pub struct NativeBackend {
    params: StepParams,
    pop: PopulationState,
    /// Fired-flag scratch for the vectorized two-pass update (§Perf).
    mask: Vec<u8>,
}

impl NativeBackend {
    pub fn new(net: &NetworkParams, pop: PopulationState) -> Self {
        let mask = vec![0u8; pop.len()];
        Self { params: StepParams::from_network(net), pop, mask }
    }
}

impl NeuronBackend for NativeBackend {
    fn step(&mut self, i_syn: &[f32], i_ext: &[f32], spiked: &mut Vec<u32>) -> Result<usize> {
        // §Perf iteration log: the two-pass masked variant
        // (`step_native_masked` + `collect_fired`) measured 15% slower
        // end-to-end than this fused loop (the mask store+scan costs more
        // than the rare in-loop push); reverted to the fused form.
        let _ = &self.mask;
        Ok(step_native(
            &self.params,
            &mut self.pop.v,
            &mut self.pop.w,
            &mut self.pop.rf,
            i_syn,
            i_ext,
            &self.pop.sfa_inc,
            spiked,
        ))
    }

    fn len(&self) -> usize {
        self.pop.len()
    }

    fn state(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.pop.v, &self.pop.w, &self.pop.rf)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend (packed ABI v2, EXPERIMENTS.md §Perf): state travels as
/// one f32[3r] buffer (v|w|rf) and the step result as one f32[4r]
/// (v|w|rf|spiked) read back with a single raw copy. The pad region
/// [n, rung) holds inert neurons (v = v_reset, zero input, sfa_inc = 0)
/// which can never reach threshold.
pub struct XlaBackend {
    exe: Rc<xla::PjRtLoadedExecutable>,
    n: usize,
    rung: usize,
    params_buf: xla::PjRtBuffer,
    sfa_buf: xla::PjRtBuffer,
    /// Host mirror of the packed state (3 * rung).
    state: Vec<f32>,
    /// Packed step output (4 * rung).
    out: Vec<f32>,
    isyn_pad: Vec<f32>,
    iext_pad: Vec<f32>,
    rt: XlaRuntime,
}

impl XlaBackend {
    pub fn new(
        net: &NetworkParams,
        pop: PopulationState,
        artifacts_dir: &Path,
    ) -> Result<Self> {
        let mut rt = XlaRuntime::new(artifacts_dir)?;
        let n = pop.len();
        let (rung, exe) = rt.executable_for(n as u32)?;
        let rung = rung as usize;
        let params = StepParams::from_network(net);
        let params_buf = rt.upload(&params.to_abi())?;
        let mut state = Vec::with_capacity(3 * rung);
        let mut pad = |src: &[f32], fill: f32| {
            state.extend_from_slice(src);
            state.resize(state.len() + (rung - src.len()), fill);
        };
        pad(&pop.v, params.v_reset);
        pad(&pop.w, 0.0);
        pad(&pop.rf, 0.0);
        let mut sfa = pop.sfa_inc.clone();
        sfa.resize(rung, 0.0);
        let sfa_buf = rt.upload(&sfa)?;
        Ok(Self {
            exe,
            n,
            rung,
            params_buf,
            sfa_buf,
            state,
            out: vec![0.0; 4 * rung],
            isyn_pad: vec![0.0; rung],
            iext_pad: vec![0.0; rung],
            rt,
        })
    }

    pub fn rung(&self) -> usize {
        self.rung
    }
}

impl NeuronBackend for XlaBackend {
    fn step(&mut self, i_syn: &[f32], i_ext: &[f32], spiked: &mut Vec<u32>) -> Result<usize> {
        debug_assert_eq!(i_syn.len(), self.n);
        self.isyn_pad[..self.n].copy_from_slice(i_syn);
        self.iext_pad[..self.n].copy_from_slice(i_ext);
        self.rt.run_step_packed(
            &self.exe,
            &self.params_buf,
            &self.state,
            &self.isyn_pad,
            &self.iext_pad,
            &self.sfa_buf,
            &mut self.out,
        )?;
        // out = [v' | w' | rf' | spiked]: the first 3r become next state
        self.state.copy_from_slice(&self.out[..3 * self.rung]);
        let sp = &self.out[3 * self.rung..];
        let before = spiked.len();
        for (j, &s) in sp[..self.n].iter().enumerate() {
            if s > 0.5 {
                spiked.push(j as u32);
            }
        }
        debug_assert!(
            sp[self.n..].iter().all(|&s| s < 0.5),
            "inert pad neuron fired"
        );
        Ok(spiked.len() - before)
    }

    fn len(&self) -> usize {
        self.n
    }

    fn state(&self) -> (&[f32], &[f32], &[f32]) {
        let r = self.rung;
        (
            &self.state[..self.n],
            &self.state[r..r + self.n],
            &self.state[2 * r..2 * r + self.n],
        )
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Construct the backend selected by the run config.
pub fn make_backend(
    which: Backend,
    net: &NetworkParams,
    pop: PopulationState,
    artifacts_dir: &Path,
) -> Result<Box<dyn NeuronBackend>> {
    Ok(match which {
        Backend::Native => Box::new(NativeBackend::new(net, pop)),
        Backend::Xla => Box::new(XlaBackend::new(net, pop, artifacts_dir)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_steps_and_reports_state() {
        let net = NetworkParams::tiny(64);
        let pop = PopulationState::init(&net, 1, 0, 64);
        let mut b = NativeBackend::new(&net, pop);
        let zeros = vec![0.0f32; 64];
        let big = vec![100.0f32; 64];
        let mut spiked = Vec::new();
        let n = b.step(&big, &zeros, &mut spiked).unwrap();
        assert_eq!(n, 64, "all neurons driven far above threshold must fire");
        let (v, _, rf) = b.state();
        assert!(v.iter().all(|&x| x == 0.0));
        assert!(rf.iter().all(|&x| x == 2.0));
        // refractory: nothing fires next step
        spiked.clear();
        let n = b.step(&big, &zeros, &mut spiked).unwrap();
        assert_eq!(n, 0);
    }
}
