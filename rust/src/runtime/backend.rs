//! Pluggable neuron-dynamics backends.
//!
//! * [`NativeBackend`] — pure-rust LIF+SFA, the always-available baseline.
//! * [`XlaBackend`] — the AOT-compiled JAX/Pallas artifact via PJRT.
//!
//! Both implement [`NeuronBackend`] and advance the same state with the
//! same arithmetic; the integration tests assert their spike rasters
//! agree on driven networks.
//!
//! The external-input buffer is owned by the backend (it is part of the
//! SoA state block for the native path and the padded ABI staging buffer
//! for XLA): the engine fills it in place via
//! [`NeuronBackend::i_ext_mut`] — chunked across the compute pool — then
//! calls [`NeuronBackend::step`], so no per-step copy sits between the
//! Poisson fill and the update kernel.

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use crate::config::{Backend, NetworkParams};
use crate::model::neuron::{collect_fired_offset, step_native_masked, StepParams};
use crate::model::population::PopulationSoA;
use crate::util::pool::{ComputePool, SyncPtr};

use super::client::XlaRuntime;
// Offline stand-in for the PJRT bindings (see xla_stub module docs).
use super::xla_stub as xla;

/// A stateful population integrator: one call = one 1 ms network step.
pub trait NeuronBackend {
    /// The external-input buffer for the step about to run (length =
    /// population size). The engine overwrites it every step before
    /// calling [`Self::step`].
    fn i_ext_mut(&mut self) -> &mut [f32];

    /// Advance one step with the given synaptic input current (length =
    /// population size); the external input is whatever the caller left
    /// in [`Self::i_ext_mut`]. Appends the local indices of neurons that
    /// fired to `spiked` (ascending) and returns the spike count.
    fn step(&mut self, i_syn: &[f32], spiked: &mut Vec<u32>) -> Result<usize>;

    /// Population size.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current state vectors (v, w, rf) — diagnostics and tests.
    fn state(&self) -> (&[f32], &[f32], &[f32]);

    fn name(&self) -> &'static str;
}

/// Pure-rust backend owning the population state.
///
/// The update is the branchless masked kernel (`step_native_masked` +
/// `collect_fired`), mirroring `python/compile/kernels/lif_sfa.py` op for
/// op so the state loop autovectorizes; the scalar push-variant
/// `step_native` survives only as the test oracle
/// (`masked_matches_push_variant`). Under `--compute-threads N` the
/// population splits into the pool's fixed chunks: each chunk updates its
/// disjoint SoA slices and collects spikes into its own vector, and the
/// vectors concatenate in ascending chunk order — the exact sequence the
/// single-chunk scan produces.
pub struct NativeBackend {
    params: StepParams,
    pop: PopulationSoA,
    /// Fired-flag scratch for the vectorized two-pass update (§Perf).
    mask: Vec<u8>,
    pool: Rc<ComputePool>,
    /// Per-chunk spike vectors, reduced in chunk order after each step.
    spiked_chunks: Vec<Vec<u32>>,
}

impl NativeBackend {
    pub fn new(net: &NetworkParams, pop: PopulationSoA) -> Self {
        Self::with_pool(net, pop, Rc::new(ComputePool::new(1)))
    }

    pub fn with_pool(net: &NetworkParams, pop: PopulationSoA, pool: Rc<ComputePool>) -> Self {
        let mask = vec![0u8; pop.len()];
        let spiked_chunks = vec![Vec::new(); pool.chunks()];
        Self { params: StepParams::from_network(net), pop, mask, pool, spiked_chunks }
    }
}

impl NeuronBackend for NativeBackend {
    fn i_ext_mut(&mut self) -> &mut [f32] {
        &mut self.pop.i_ext
    }

    fn step(&mut self, i_syn: &[f32], spiked: &mut Vec<u32>) -> Result<usize> {
        let n = self.pop.len();
        debug_assert_eq!(i_syn.len(), n);
        let p = self.params;
        if self.pool.chunks() == 1 {
            step_native_masked(
                &p,
                &mut self.pop.v,
                &mut self.pop.w,
                &mut self.pop.rf,
                i_syn,
                &self.pop.i_ext,
                &self.pop.sfa_inc,
                &mut self.mask,
            );
            return Ok(collect_fired_offset(&self.mask, 0, spiked));
        }
        // Chunked: disjoint 64-element-aligned slices per chunk (the SoA
        // arrays and the mask never share a cache line across chunks).
        // The closure captures the chunk count, not the pool (not Sync).
        let chunks = self.pool.chunks();
        let v = SyncPtr(self.pop.v.as_mut_ptr());
        let w = SyncPtr(self.pop.w.as_mut_ptr());
        let rf = SyncPtr(self.pop.rf.as_mut_ptr());
        let mask = SyncPtr(self.mask.as_mut_ptr());
        let out = SyncPtr(self.spiked_chunks.as_mut_ptr());
        let i_ext: &[f32] = &self.pop.i_ext;
        let sfa: &[f32] = &self.pop.sfa_inc;
        self.pool.run(&|c| {
            let r = crate::util::pool::chunk_range(chunks, c, n);
            // SAFETY: chunk ranges are disjoint, so each raw slice and the
            // per-chunk output vector have exactly one accessor.
            let sp = unsafe { &mut *out.0.add(c) };
            sp.clear();
            if r.is_empty() {
                return;
            }
            let (lo, len) = (r.start, r.len());
            unsafe {
                step_native_masked(
                    &p,
                    std::slice::from_raw_parts_mut(v.0.add(lo), len),
                    std::slice::from_raw_parts_mut(w.0.add(lo), len),
                    std::slice::from_raw_parts_mut(rf.0.add(lo), len),
                    &i_syn[r.clone()],
                    &i_ext[r.clone()],
                    &sfa[r.clone()],
                    std::slice::from_raw_parts_mut(mask.0.add(lo), len),
                );
                collect_fired_offset(
                    std::slice::from_raw_parts(mask.0.add(lo), len),
                    lo as u32,
                    sp,
                );
            }
        });
        let before = spiked.len();
        for sp in &self.spiked_chunks {
            spiked.extend_from_slice(sp);
        }
        Ok(spiked.len() - before)
    }

    fn len(&self) -> usize {
        self.pop.len()
    }

    fn state(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.pop.v, &self.pop.w, &self.pop.rf)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend (packed ABI v2, EXPERIMENTS.md §Perf): state travels as
/// one f32[3r] buffer (v|w|rf) and the step result as one f32[4r]
/// (v|w|rf|spiked) read back with a single raw copy. The pad region
/// [n, rung) holds inert neurons (v = v_reset, zero input, sfa_inc = 0)
/// which can never reach threshold.
pub struct XlaBackend {
    exe: Rc<xla::PjRtLoadedExecutable>,
    n: usize,
    rung: usize,
    params_buf: xla::PjRtBuffer,
    sfa_buf: xla::PjRtBuffer,
    /// Host mirror of the packed state (3 * rung).
    state: Vec<f32>,
    /// Packed step output (4 * rung).
    out: Vec<f32>,
    isyn_pad: Vec<f32>,
    /// Doubles as the engine-filled i_ext buffer: the first n lanes are
    /// [`NeuronBackend::i_ext_mut`], the pad stays zero.
    iext_pad: Vec<f32>,
    rt: XlaRuntime,
}

impl XlaBackend {
    pub fn new(net: &NetworkParams, pop: PopulationSoA, artifacts_dir: &Path) -> Result<Self> {
        let mut rt = XlaRuntime::new(artifacts_dir)?;
        let n = pop.len();
        let (rung, exe) = rt.executable_for(n as u32)?;
        let rung = rung as usize;
        let params = StepParams::from_network(net);
        let params_buf = rt.upload(&params.to_abi())?;
        let mut state = Vec::with_capacity(3 * rung);
        let mut pad = |src: &[f32], fill: f32| {
            state.extend_from_slice(src);
            state.resize(state.len() + (rung - src.len()), fill);
        };
        pad(&pop.v, params.v_reset);
        pad(&pop.w, 0.0);
        pad(&pop.rf, 0.0);
        let mut sfa = pop.sfa_inc.to_vec();
        sfa.resize(rung, 0.0);
        let sfa_buf = rt.upload(&sfa)?;
        Ok(Self {
            exe,
            n,
            rung,
            params_buf,
            sfa_buf,
            state,
            out: vec![0.0; 4 * rung],
            isyn_pad: vec![0.0; rung],
            iext_pad: vec![0.0; rung],
            rt,
        })
    }

    pub fn rung(&self) -> usize {
        self.rung
    }
}

impl NeuronBackend for XlaBackend {
    fn i_ext_mut(&mut self) -> &mut [f32] {
        &mut self.iext_pad[..self.n]
    }

    fn step(&mut self, i_syn: &[f32], spiked: &mut Vec<u32>) -> Result<usize> {
        debug_assert_eq!(i_syn.len(), self.n);
        self.isyn_pad[..self.n].copy_from_slice(i_syn);
        self.rt.run_step_packed(
            &self.exe,
            &self.params_buf,
            &self.state,
            &self.isyn_pad,
            &self.iext_pad,
            &self.sfa_buf,
            &mut self.out,
        )?;
        // out = [v' | w' | rf' | spiked]: the first 3r become next state
        self.state.copy_from_slice(&self.out[..3 * self.rung]);
        let sp = &self.out[3 * self.rung..];
        let before = spiked.len();
        for (j, &s) in sp[..self.n].iter().enumerate() {
            if s > 0.5 {
                spiked.push(j as u32);
            }
        }
        debug_assert!(
            sp[self.n..].iter().all(|&s| s < 0.5),
            "inert pad neuron fired"
        );
        Ok(spiked.len() - before)
    }

    fn len(&self) -> usize {
        self.n
    }

    fn state(&self) -> (&[f32], &[f32], &[f32]) {
        let r = self.rung;
        (
            &self.state[..self.n],
            &self.state[r..r + self.n],
            &self.state[2 * r..2 * r + self.n],
        )
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Construct the backend selected by the run config. The pool carries the
/// `--compute-threads` chunking; the XLA path steps as one kernel launch
/// and ignores it.
pub fn make_backend(
    which: Backend,
    net: &NetworkParams,
    pop: PopulationSoA,
    artifacts_dir: &Path,
    pool: Rc<ComputePool>,
) -> Result<Box<dyn NeuronBackend>> {
    Ok(match which {
        Backend::Native => Box::new(NativeBackend::with_pool(net, pop, pool)),
        Backend::Xla => Box::new(XlaBackend::new(net, pop, artifacts_dir)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_steps_and_reports_state() {
        let net = NetworkParams::tiny(64);
        let pop = PopulationSoA::init(&net, 1, 0, 64);
        let mut b = NativeBackend::new(&net, pop);
        let big = vec![100.0f32; 64];
        let mut spiked = Vec::new();
        b.i_ext_mut().iter_mut().for_each(|x| *x = 0.0);
        let n = b.step(&big, &mut spiked).unwrap();
        assert_eq!(n, 64, "all neurons driven far above threshold must fire");
        let (v, _, rf) = b.state();
        assert!(v.iter().all(|&x| x == 0.0));
        assert!(rf.iter().all(|&x| x == 2.0));
        // refractory: nothing fires next step
        spiked.clear();
        let n = b.step(&big, &mut spiked).unwrap();
        assert_eq!(n, 0);
    }

    #[test]
    fn chunked_step_matches_single_chunk_bitwise() {
        let net = NetworkParams::tiny(200);
        let drive = |b: &mut dyn NeuronBackend, t: u32| {
            for (j, x) in b.i_ext_mut().iter_mut().enumerate() {
                *x = ((j as u32 ^ t) % 7) as f32;
            }
        };
        let i_syn: Vec<f32> = (0..200).map(|j| (j % 11) as f32 * 0.5).collect();
        for threads in [2usize, 3, 4] {
            let pool = Rc::new(ComputePool::new(threads));
            let mut b = NativeBackend::with_pool(&net, PopulationSoA::init(&net, 5, 0, 200), pool);
            let mut sp_ref = Vec::new();
            let mut sp = Vec::new();
            let mut reference = NativeBackend::new(&net, PopulationSoA::init(&net, 5, 0, 200));
            for t in 0..50 {
                sp_ref.clear();
                sp.clear();
                drive(&mut reference, t);
                drive(&mut b, t);
                reference.step(&i_syn, &mut sp_ref).unwrap();
                b.step(&i_syn, &mut sp).unwrap();
                assert_eq!(sp_ref, sp, "threads={threads} t={t}");
            }
            let (v1, w1, rf1) = reference.state();
            let (v2, w2, rf2) = b.state();
            assert_eq!(v1, v2, "threads={threads}");
            assert_eq!(w1, w2);
            assert_eq!(rf1, rf2);
        }
    }
}
