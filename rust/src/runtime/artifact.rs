//! Artifact discovery: the AOT size ladder emitted by `python/compile/aot.py`.
//!
//! `make artifacts` writes `artifacts/lif_sfa_<n>.hlo.txt` for a ladder of
//! population sizes; a rank population of size `n` runs on the smallest
//! rung >= n, padded with inert neurons (zero input, v at rest — they can
//! never cross threshold, see the padding tests in `runtime::backend`).
//!
//! Errors are typed ([`ArtifactError`]) rather than bare `anyhow!` strings:
//! the resident server ([`crate::runtime::server`]) must be able to fail a
//! single job on a bad artifact dir while continuing to serve every other
//! job, so these errors travel through job results instead of tearing the
//! process down.

use std::fmt;
use std::path::{Path, PathBuf};

/// Why the artifact registry could not satisfy a request. Each variant
/// degrades exactly one job (or one scan); none is fatal to a server.
#[derive(Debug)]
pub enum ArtifactError {
    /// The artifacts directory could not be opened at all.
    DirUnreadable { dir: PathBuf, source: std::io::Error },
    /// A directory entry failed to read mid-scan.
    Entry { dir: PathBuf, source: std::io::Error },
    /// The directory exists but holds no `lif_sfa_<n>.hlo.txt` rungs.
    NoArtifacts { dir: PathBuf },
    /// The requested population exceeds the largest compiled rung.
    RungTooLarge { n: u32, largest: u32 },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::DirUnreadable { dir, source } => write!(
                f,
                "artifacts dir {} unreadable (run `make artifacts`): {source}",
                dir.display()
            ),
            Self::Entry { dir, source } => {
                write!(f, "reading entry in artifacts dir {}: {source}", dir.display())
            }
            Self::NoArtifacts { dir } => write!(
                f,
                "no lif_sfa_*.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            ),
            Self::RungTooLarge { n, largest } => write!(
                f,
                "population {n} exceeds the largest artifact rung {largest} — \
                 re-run aot.py with a larger --sizes ladder"
            ),
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::DirUnreadable { source, .. } | Self::Entry { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    /// Sorted ascending rung sizes.
    sizes: Vec<u32>,
}

impl ArtifactRegistry {
    /// Scan `dir` for `lif_sfa_<n>.hlo.txt` files.
    pub fn scan(dir: &Path) -> Result<Self, ArtifactError> {
        let mut sizes = Vec::new();
        let entries = std::fs::read_dir(dir).map_err(|source| ArtifactError::DirUnreadable {
            dir: dir.to_path_buf(),
            source,
        })?;
        for e in entries {
            let name = e
                .map_err(|source| ArtifactError::Entry { dir: dir.to_path_buf(), source })?
                .file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name
                .strip_prefix("lif_sfa_")
                .and_then(|s| s.strip_suffix(".hlo.txt"))
            {
                if let Ok(n) = num.parse::<u32>() {
                    sizes.push(n);
                }
            }
        }
        if sizes.is_empty() {
            return Err(ArtifactError::NoArtifacts { dir: dir.to_path_buf() });
        }
        sizes.sort_unstable();
        Ok(Self { dir: dir.to_path_buf(), sizes })
    }

    pub fn sizes(&self) -> &[u32] {
        &self.sizes
    }

    /// Smallest rung that fits a population of `n`.
    pub fn rung_for(&self, n: u32) -> Result<u32, ArtifactError> {
        match self.sizes.iter().find(|&&s| s >= n) {
            Some(&s) => Ok(s),
            None => Err(ArtifactError::RungTooLarge {
                n,
                largest: self.sizes.last().copied().unwrap_or(0),
            }),
        }
    }

    pub fn path_for_rung(&self, rung: u32) -> PathBuf {
        self.dir.join(format!("lif_sfa_{rung}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_registry(sizes: &[u32]) -> (tempdir::TempDir, ArtifactRegistry) {
        let td = tempdir::TempDir::new();
        for s in sizes {
            std::fs::write(td.path().join(format!("lif_sfa_{s}.hlo.txt")), "x").unwrap();
        }
        // decoys that must be ignored
        std::fs::write(td.path().join("manifest.json"), "{}").unwrap();
        std::fs::write(td.path().join("lif_sfa_bad.hlo.txt"), "x").unwrap();
        let r = ArtifactRegistry::scan(td.path()).unwrap();
        (td, r)
    }

    #[test]
    fn scans_and_sorts() {
        let (_td, r) = fake_registry(&[2048, 256, 8192]);
        assert_eq!(r.sizes(), &[256, 2048, 8192]);
    }

    #[test]
    fn rung_selection() {
        let (_td, r) = fake_registry(&[256, 2048, 8192]);
        assert_eq!(r.rung_for(1).unwrap(), 256);
        assert_eq!(r.rung_for(256).unwrap(), 256);
        assert_eq!(r.rung_for(257).unwrap(), 2048);
        assert_eq!(r.rung_for(8192).unwrap(), 8192);
        match r.rung_for(8193) {
            Err(ArtifactError::RungTooLarge { n: 8193, largest: 8192 }) => {}
            other => panic!("expected RungTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn empty_dir_errors() {
        let td = tempdir::TempDir::new();
        match ArtifactRegistry::scan(td.path()) {
            Err(ArtifactError::NoArtifacts { dir }) => assert_eq!(dir, td.path()),
            other => panic!("expected NoArtifacts, got {other:?}"),
        }
    }

    #[test]
    fn missing_dir_errors() {
        let td = tempdir::TempDir::new();
        let missing = td.path().join("does-not-exist");
        match ArtifactRegistry::scan(&missing) {
            Err(ArtifactError::DirUnreadable { dir, .. }) => assert_eq!(dir, missing),
            other => panic!("expected DirUnreadable, got {other:?}"),
        }
    }

    #[test]
    fn errors_display_and_chain() {
        let err = ArtifactError::RungTooLarge { n: 10, largest: 8 };
        let msg = err.to_string();
        assert!(msg.contains("10") && msg.contains('8'), "{msg}");
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err = ArtifactError::DirUnreadable { dir: PathBuf::from("x"), source: io };
        assert!(std::error::Error::source(&err).is_some());
    }

    /// Minimal tempdir (std-only; the tempfile crate is unavailable).
    mod tempdir {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);

        pub struct TempDir(std::path::PathBuf);
        impl TempDir {
            pub fn new() -> Self {
                let p = std::env::temp_dir().join(format!(
                    "dpsnn-test-{}-{}",
                    std::process::id(),
                    COUNTER.fetch_add(1, Ordering::Relaxed)
                ));
                std::fs::create_dir_all(&p).unwrap();
                Self(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }
}
