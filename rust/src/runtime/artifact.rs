//! Artifact discovery: the AOT size ladder emitted by `python/compile/aot.py`.
//!
//! `make artifacts` writes `artifacts/lif_sfa_<n>.hlo.txt` for a ladder of
//! population sizes; a rank population of size `n` runs on the smallest
//! rung >= n, padded with inert neurons (zero input, v at rest — they can
//! never cross threshold, see the padding tests in `runtime::backend`).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    /// Sorted ascending rung sizes.
    sizes: Vec<u32>,
}

impl ArtifactRegistry {
    /// Scan `dir` for `lif_sfa_<n>.hlo.txt` files.
    pub fn scan(dir: &Path) -> Result<Self> {
        let mut sizes = Vec::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("artifacts dir {} (run `make artifacts`)", dir.display()))?;
        for e in entries {
            let name = e?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name
                .strip_prefix("lif_sfa_")
                .and_then(|s| s.strip_suffix(".hlo.txt"))
            {
                if let Ok(n) = num.parse::<u32>() {
                    sizes.push(n);
                }
            }
        }
        if sizes.is_empty() {
            bail!(
                "no lif_sfa_*.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            );
        }
        sizes.sort_unstable();
        Ok(Self { dir: dir.to_path_buf(), sizes })
    }

    pub fn sizes(&self) -> &[u32] {
        &self.sizes
    }

    /// Smallest rung that fits a population of `n`.
    pub fn rung_for(&self, n: u32) -> Result<u32> {
        match self.sizes.iter().find(|&&s| s >= n) {
            Some(&s) => Ok(s),
            None => bail!(
                "population {n} exceeds the largest artifact rung {} — \
                 re-run aot.py with a larger --sizes ladder",
                self.sizes.last().unwrap()
            ),
        }
    }

    pub fn path_for_rung(&self, rung: u32) -> PathBuf {
        self.dir.join(format!("lif_sfa_{rung}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_registry(sizes: &[u32]) -> (tempdir::TempDir, ArtifactRegistry) {
        let td = tempdir::TempDir::new();
        for s in sizes {
            std::fs::write(td.path().join(format!("lif_sfa_{s}.hlo.txt")), "x").unwrap();
        }
        // decoys that must be ignored
        std::fs::write(td.path().join("manifest.json"), "{}").unwrap();
        std::fs::write(td.path().join("lif_sfa_bad.hlo.txt"), "x").unwrap();
        let r = ArtifactRegistry::scan(td.path()).unwrap();
        (td, r)
    }

    #[test]
    fn scans_and_sorts() {
        let (_td, r) = fake_registry(&[2048, 256, 8192]);
        assert_eq!(r.sizes(), &[256, 2048, 8192]);
    }

    #[test]
    fn rung_selection() {
        let (_td, r) = fake_registry(&[256, 2048, 8192]);
        assert_eq!(r.rung_for(1).unwrap(), 256);
        assert_eq!(r.rung_for(256).unwrap(), 256);
        assert_eq!(r.rung_for(257).unwrap(), 2048);
        assert_eq!(r.rung_for(8192).unwrap(), 8192);
        assert!(r.rung_for(8193).is_err());
    }

    #[test]
    fn empty_dir_errors() {
        let td = tempdir::TempDir::new();
        assert!(ArtifactRegistry::scan(td.path()).is_err());
    }

    /// Minimal tempdir (std-only; the tempfile crate is unavailable).
    mod tempdir {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);

        pub struct TempDir(std::path::PathBuf);
        impl TempDir {
            pub fn new() -> Self {
                let p = std::env::temp_dir().join(format!(
                    "dpsnn-test-{}-{}",
                    std::process::id(),
                    COUNTER.fetch_add(1, Ordering::Relaxed)
                ));
                std::fs::create_dir_all(&p).unwrap();
                Self(p)
            }
            pub fn path(&self) -> &std::path::Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }
}
