//! The resident multi-tenant simulation server.
//!
//! One process, many simulation jobs: callers [`submit`](SimServer::submit)
//! named [`JobSpec`] payloads and get back a [`JobHandle`] streaming
//! [`JobEvent`]s (queued → started → progress → finished/failed). The
//! point, per the paper's J/synaptic-event accounting, is to amortize
//! every per-run fixed cost that N cold CLI invocations would pay N
//! times:
//!
//! * **plan cache** — `auto` axes are resolved through the analytic
//!   planner once per distinct config and the resolved config reused;
//! * **placement cache** — [`Partition::allocate`] (greedy-comms walks
//!   the whole connectome) runs once per distinct
//!   (network, seed, procs, policy, topology) and the resulting
//!   [`Partition`] is shared as an `Arc` with every matching job;
//! * **connectome cache** — the [`ConnectivityParams`] procedural
//!   parameter set, keyed by (network, seed);
//! * **artifact cache** — one [`ArtifactRegistry`] scan per artifacts
//!   dir, with a fail-fast rung check before any rank thread spawns
//!   (the compiled PJRT executable itself is per rank thread by
//!   constraint: `PjRtClient` holds an `Rc` and is not `Send`);
//! * **job batching** — queued jobs with byte-identical configs run the
//!   engine once and share the (cloned) result.
//!
//! Scheduling: jobs queue until their rank demand fits the server's
//! free-rank budget; among the fitting jobs the scheduler starts the one
//! with the smallest predicted wall clock, priced with the same simnet
//! closed forms the autotuner uses ([`Planner::price`] × steps) —
//! shortest-job-first keeps the queue latency of small jobs from hiding
//! behind long ones, and FIFO order breaks ties. Every job gets its own
//! result channel and its own [`RunResult`]; nothing RNG-dependent is
//! shared unless the *entire* config (seed included — the cache key
//! hashes every field) matches.
//!
//! Isolation contract: a job run through this server produces a raster
//! bitwise identical to the same config run solo through
//! [`coordinator::run`] — enforced by `rust/tests/server_props.rs` and
//! the golden corpus in `rust/tests/golden_rasters.rs`. Per-job errors
//! (bad artifacts dir, failed validation at run time) fail that job's
//! handle and leave the server serving.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use crate::comm::topology::TopologyTree;
use crate::config::{Backend, JobSpec, Mode, RunConfig, ServeOptions};
use crate::coordinator::live::{run_live_prepared, PreparedParts, ProgressObserver};
use crate::coordinator::{OnlineReplanner, RunResult};
use crate::engine::partition::{AllocContext, Partition};
use crate::model::connectivity::ConnectivityParams;
use crate::simnet::autotune::Planner;

use super::artifact::ArtifactRegistry;

/// FNV-1a over the `Debug` rendering of a config. `RunConfig` derives
/// `Debug` recursively over every field — network, seed, procs, every
/// exchange axis — so two configs share a key iff they are
/// byte-identical settings. Seed inclusion is what makes cache reuse
/// RNG-safe by construction.
pub fn config_key(cfg: &RunConfig) -> u64 {
    fnv1a(format!("{cfg:?}").as_bytes())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Placement cache key: exactly the inputs [`Partition::allocate`]
/// reads — the network (connectome shape), the seed (the connectome
/// draw), procs, policy, and topology (greedy-comms prices links
/// through the tree).
fn placement_key(cfg: &RunConfig) -> u64 {
    fnv1a(
        format!(
            "{:?}|{}|{}|{}|{}",
            cfg.net, cfg.seed, cfg.procs, cfg.partition, cfg.topology
        )
        .as_bytes(),
    )
}

/// Connectome cache key: the two inputs of
/// [`ConnectivityParams::from_network`].
fn connectome_key(cfg: &RunConfig) -> u64 {
    fnv1a(format!("{:?}|{}", cfg.net, cfg.seed).as_bytes())
}

/// Everything a job's lifetime reports back, in order. `Finished` and
/// `Failed` are terminal; exactly one of them arrives per job.
#[derive(Debug)]
pub enum JobEvent {
    Queued,
    Started,
    /// Coarse step progress from rank 0 (a handful per run).
    Progress { step: u32, steps: u32 },
    Finished(Box<RunResult>),
    Failed(String),
}

/// Caller's end of one submitted job.
pub struct JobHandle {
    pub id: u64,
    pub name: String,
    events: Receiver<JobEvent>,
}

impl JobHandle {
    /// Incremental event stream (blocks on `recv`, iterable).
    pub fn events(&self) -> &Receiver<JobEvent> {
        &self.events
    }

    /// Drain events until the job terminates; Err on failure or if the
    /// server dropped the job.
    pub fn wait(self) -> Result<RunResult> {
        loop {
            match self.events.recv() {
                Ok(JobEvent::Finished(r)) => return Ok(*r),
                Ok(JobEvent::Failed(msg)) => bail!("job '{}' failed: {msg}", self.name),
                Ok(_) => continue,
                Err(_) => bail!("server dropped job '{}' without a result", self.name),
            }
        }
    }
}

/// Snapshot of the shared-cache counters (see [`SimServer::cache_stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub placement_hits: u64,
    pub placement_misses: u64,
    pub connectome_hits: u64,
    pub connectome_misses: u64,
    pub artifact_hits: u64,
    pub artifact_misses: u64,
    /// Jobs that rode another identical job's engine pass.
    pub batched_jobs: u64,
}

#[derive(Default)]
struct SharedCaches {
    /// Pre-resolution config key → fully resolved config (auto axes
    /// priced through the planner once).
    resolved: Mutex<HashMap<u64, RunConfig>>,
    placements: Mutex<HashMap<u64, Arc<Partition>>>,
    connectomes: Mutex<HashMap<u64, ConnectivityParams>>,
    /// Artifacts-dir path → registry scan. Only successful scans are
    /// cached, so fixing a dir between jobs works without a restart.
    artifacts: Mutex<HashMap<String, ArtifactRegistry>>,
    plan_hits: AtomicU64,
    plan_misses: AtomicU64,
    placement_hits: AtomicU64,
    placement_misses: AtomicU64,
    connectome_hits: AtomicU64,
    connectome_misses: AtomicU64,
    artifact_hits: AtomicU64,
    artifact_misses: AtomicU64,
    batched_jobs: AtomicU64,
}

struct QueuedJob {
    id: u64,
    name: String,
    /// Fully resolved config (no `auto` axes left).
    cfg: RunConfig,
    /// Batching identity: [`config_key`] of the resolved config.
    key: u64,
    /// Simnet-priced predicted wall clock, the scheduling cost.
    predicted_wall_s: f64,
    tx: Sender<JobEvent>,
}

struct SchedState {
    queue: Vec<QueuedJob>,
    free_ranks: u32,
    running_jobs: u32,
    shutting_down: bool,
}

struct ServerInner {
    total_ranks: u32,
    state: Mutex<SchedState>,
    cv: Condvar,
    caches: SharedCaches,
    next_id: AtomicU64,
}

/// The resident server. Create with [`SimServer::start`], feed it with
/// [`submit`](SimServer::submit); dropping it drains the queue and
/// joins the scheduler.
pub struct SimServer {
    inner: Arc<ServerInner>,
    scheduler: Option<std::thread::JoinHandle<()>>,
}

impl SimServer {
    pub fn start(opts: ServeOptions) -> Self {
        let total = opts.total_ranks.max(1);
        let inner = Arc::new(ServerInner {
            total_ranks: total,
            state: Mutex::new(SchedState {
                queue: Vec::new(),
                free_ranks: total,
                running_jobs: 0,
                shutting_down: false,
            }),
            cv: Condvar::new(),
            caches: SharedCaches::default(),
            next_id: AtomicU64::new(1),
        });
        let sched_inner = inner.clone();
        let scheduler = std::thread::spawn(move || scheduler_loop(sched_inner));
        Self { inner, scheduler: Some(scheduler) }
    }

    /// Validate, resolve, price and enqueue one job. Submission errors
    /// (invalid config, rank demand over the server budget) surface
    /// here; anything that can fail *per run* (artifacts, backend)
    /// fails the job's handle instead.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        let JobSpec { name, cfg } = spec;
        cfg.validate().with_context(|| format!("job '{name}'"))?;

        // Plan cache: resolve `auto` axes once per distinct config.
        let pre_key = config_key(&cfg);
        let resolved = {
            let cached = self.inner.caches.resolved.lock().unwrap().get(&pre_key).cloned();
            match cached {
                Some(r) => {
                    self.inner.caches.plan_hits.fetch_add(1, Ordering::Relaxed);
                    r
                }
                None => {
                    self.inner.caches.plan_misses.fetch_add(1, Ordering::Relaxed);
                    let (r, _plan) = crate::simnet::autotune::resolve(&cfg)
                        .with_context(|| format!("job '{name}': resolving auto axes"))?;
                    self.inner
                        .caches
                        .resolved
                        .lock()
                        .unwrap()
                        .insert(pre_key, r.clone());
                    r
                }
            }
        };
        if resolved.procs > self.inner.total_ranks {
            bail!(
                "job '{name}' wants {} ranks but the server budget is {}",
                resolved.procs,
                self.inner.total_ranks
            );
        }

        // Price the job with the same closed forms the autotuner uses:
        // per-step cost of the resolved (topology, cadence) × steps.
        // Pricing is a scheduling hint only, so an unpriceable platform
        // falls back to FIFO (0.0) rather than rejecting the job.
        let predicted_wall_s = Planner::from_config(&resolved)
            .map(|pl| {
                let epoch = resolved
                    .exchange_every
                    .epoch_steps(resolved.net.delay_min_steps);
                pl.price(&resolved.topology, epoch).total() * resolved.steps() as f64
            })
            .unwrap_or(0.0);

        let (tx, rx) = channel();
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let key = config_key(&resolved);
        {
            let mut st = self.inner.state.lock().unwrap();
            if st.shutting_down {
                bail!("server is shutting down; job '{name}' rejected");
            }
            let _ = tx.send(JobEvent::Queued);
            st.queue.push(QueuedJob {
                id,
                name: name.clone(),
                cfg: resolved,
                key,
                predicted_wall_s,
                tx,
            });
        }
        self.inner.cv.notify_all();
        Ok(JobHandle { id, name, events: rx })
    }

    pub fn cache_stats(&self) -> CacheStats {
        let c = &self.inner.caches;
        CacheStats {
            plan_hits: c.plan_hits.load(Ordering::Relaxed),
            plan_misses: c.plan_misses.load(Ordering::Relaxed),
            placement_hits: c.placement_hits.load(Ordering::Relaxed),
            placement_misses: c.placement_misses.load(Ordering::Relaxed),
            connectome_hits: c.connectome_hits.load(Ordering::Relaxed),
            connectome_misses: c.connectome_misses.load(Ordering::Relaxed),
            artifact_hits: c.artifact_hits.load(Ordering::Relaxed),
            artifact_misses: c.artifact_misses.load(Ordering::Relaxed),
            batched_jobs: c.batched_jobs.load(Ordering::Relaxed),
        }
    }

    pub fn total_ranks(&self) -> u32 {
        self.inner.total_ranks
    }

    /// Drain the queue, wait for in-flight jobs, stop the scheduler.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutting_down = true;
        }
        self.inner.cv.notify_all();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SimServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Pick the queued job to start next: smallest predicted wall clock
/// among those whose rank demand fits the free budget; earliest
/// submission breaks ties. Returns a queue index.
fn pick_next(st: &SchedState) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, j) in st.queue.iter().enumerate() {
        if j.cfg.procs > st.free_ranks {
            continue;
        }
        match best {
            Some(b) if st.queue[b].predicted_wall_s <= j.predicted_wall_s => {}
            _ => best = Some(i),
        }
    }
    best
}

fn scheduler_loop(inner: Arc<ServerInner>) {
    loop {
        // Pick the next job (plus batch passengers) under the lock.
        let (job, passengers) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutting_down && st.queue.is_empty() && st.running_jobs == 0 {
                    return;
                }
                if let Some(i) = pick_next(&st) {
                    let job = st.queue.remove(i);
                    // Batch passengers: byte-identical configs run the
                    // engine once. Collected back-to-front so removal
                    // indices stay valid.
                    let mut passengers = Vec::new();
                    let mut k = st.queue.len();
                    while k > 0 {
                        k -= 1;
                        if st.queue[k].key == job.key {
                            passengers.push(st.queue.remove(k));
                        }
                    }
                    passengers.reverse(); // restore submission order
                    st.free_ranks -= job.cfg.procs;
                    st.running_jobs += 1;
                    break (job, passengers);
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        let worker_inner = inner.clone();
        std::thread::spawn(move || {
            run_job(&worker_inner, job, passengers);
            let mut st = worker_inner.state.lock().unwrap();
            // free_ranks is recomputed from the job the worker owned —
            // the job struct was moved into run_job, so the count rides
            // through the closure instead.
            st.running_jobs -= 1;
            drop(st);
            worker_inner.cv.notify_all();
        });
    }
}

/// Execute one job (and its batch passengers) to terminal events, then
/// return the ranks to the budget.
fn run_job(inner: &Arc<ServerInner>, job: QueuedJob, passengers: Vec<QueuedJob>) {
    let procs = job.cfg.procs;
    let _ = job.tx.send(JobEvent::Started);
    for p in &passengers {
        let _ = p.tx.send(JobEvent::Started);
    }
    if !passengers.is_empty() {
        inner
            .caches
            .batched_jobs
            .fetch_add(passengers.len() as u64, Ordering::Relaxed);
    }

    // Progress fan-out to the job and every passenger. Senders sit
    // behind a Mutex so the observer closure is Sync.
    let all_tx: Vec<Sender<JobEvent>> =
        std::iter::once(job.tx.clone()).chain(passengers.iter().map(|p| p.tx.clone())).collect();
    let progress_tx = Mutex::new(all_tx);
    let observer: ProgressObserver = Arc::new(move |step, steps| {
        for tx in progress_tx.lock().unwrap().iter() {
            let _ = tx.send(JobEvent::Progress { step, steps });
        }
    });

    match execute(inner, &job.cfg, observer) {
        Ok(result) => {
            for p in &passengers {
                let _ = p.tx.send(JobEvent::Finished(Box::new(result.clone())));
            }
            let _ = job.tx.send(JobEvent::Finished(Box::new(result)));
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in &passengers {
                let _ = p.tx.send(JobEvent::Failed(msg.clone()));
            }
            let _ = job.tx.send(JobEvent::Failed(msg));
        }
    }

    let mut st = inner.state.lock().unwrap();
    st.free_ranks += procs;
    drop(st);
    inner.cv.notify_all();
}

/// One engine pass for a resolved config, drawing on the shared caches.
/// Every error here degrades this job only.
fn execute(
    inner: &Arc<ServerInner>,
    cfg: &RunConfig,
    observer: ProgressObserver,
) -> Result<RunResult> {
    // Fail fast on the artifact ladder before spawning rank threads:
    // the scan is cached per dir, and the rung check prices the largest
    // rank population this placement produces.
    if matches!(cfg.backend, Backend::Xla) {
        let registry = registry_for(inner, &cfg.artifacts_dir)?;
        let part = placement_for(inner, cfg);
        let largest = (0..part.n_ranks())
            .map(|r| part.owned(r).len())
            .max()
            .unwrap_or(0);
        registry.rung_for(largest)?;
    }
    match cfg.mode {
        Mode::Live => {
            let replanner = if cfg.auto.exchange_every || cfg.auto.leader_rotation {
                Some(Arc::new(OnlineReplanner::from_config(cfg)?))
            } else {
                None
            };
            let parts = PreparedParts {
                partition: Some(placement_for(inner, cfg)),
                progress: Some(observer),
            };
            run_live_prepared(cfg, replanner, parts)
        }
        // Modeled runs replay closed forms — milliseconds, no progress.
        Mode::Modeled => crate::coordinator::modeled::run_modeled(cfg),
    }
}

/// Shared placement, allocated at most once per [`placement_key`].
fn placement_for(inner: &Arc<ServerInner>, cfg: &RunConfig) -> Arc<Partition> {
    let key = placement_key(cfg);
    if let Some(p) = inner.caches.placements.lock().unwrap().get(&key) {
        inner.caches.placement_hits.fetch_add(1, Ordering::Relaxed);
        return p.clone();
    }
    inner.caches.placement_misses.fetch_add(1, Ordering::Relaxed);
    // Allocate outside the lock (greedy-comms walks the connectome);
    // a racing duplicate allocation is deterministic-identical, and
    // the first insert wins.
    let cp = connectome_for(inner, cfg);
    let tree = cfg
        .topology
        .tree()
        .map(|shape| TopologyTree::new(cfg.procs, shape.levels()));
    let ctx = AllocContext { connectivity: Some(&cp), tree: tree.as_ref() };
    let part = Arc::new(Partition::allocate(
        cfg.partition,
        cfg.net.n_neurons,
        cfg.procs,
        &ctx,
    ));
    inner
        .caches
        .placements
        .lock()
        .unwrap()
        .entry(key)
        .or_insert(part)
        .clone()
}

/// Shared procedural-connectome parameter set, derived at most once per
/// (network, seed).
fn connectome_for(inner: &Arc<ServerInner>, cfg: &RunConfig) -> ConnectivityParams {
    let key = connectome_key(cfg);
    if let Some(cp) = inner.caches.connectomes.lock().unwrap().get(&key) {
        inner.caches.connectome_hits.fetch_add(1, Ordering::Relaxed);
        return *cp;
    }
    inner.caches.connectome_misses.fetch_add(1, Ordering::Relaxed);
    let cp = ConnectivityParams::from_network(&cfg.net, cfg.seed);
    *inner
        .caches
        .connectomes
        .lock()
        .unwrap()
        .entry(key)
        .or_insert(cp)
}

/// Shared artifact-registry scan per artifacts dir (successful scans
/// only, so a dir fixed between jobs is rescanned).
fn registry_for(inner: &Arc<ServerInner>, dir: &str) -> Result<ArtifactRegistry> {
    if let Some(r) = inner.caches.artifacts.lock().unwrap().get(dir) {
        inner.caches.artifact_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(r.clone());
    }
    inner.caches.artifact_misses.fetch_add(1, Ordering::Relaxed);
    let r = ArtifactRegistry::scan(std::path::Path::new(dir))?;
    inner
        .caches
        .artifacts
        .lock()
        .unwrap()
        .insert(dir.to_string(), r.clone());
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;

    fn tiny_cfg(seed: u64) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(512);
        cfg.procs = 2;
        cfg.sim_seconds = 0.05;
        cfg.seed = seed;
        cfg
    }

    #[test]
    fn config_key_separates_seeds() {
        let a = config_key(&tiny_cfg(1));
        let b = config_key(&tiny_cfg(2));
        assert_ne!(a, b, "seed must be part of the cache identity");
        assert_eq!(a, config_key(&tiny_cfg(1)));
    }

    #[test]
    fn placement_key_ignores_non_placement_axes() {
        let mut a = tiny_cfg(1);
        let mut b = tiny_cfg(1);
        a.exchange_every = crate::config::ExchangeCadence::Step;
        b.exchange_every = crate::config::ExchangeCadence::MinDelay;
        assert_eq!(placement_key(&a), placement_key(&b));
        b.seed = 2;
        assert_ne!(placement_key(&a), placement_key(&b));
    }

    #[test]
    fn submit_run_and_wait() {
        let server = SimServer::start(ServeOptions { total_ranks: 4 });
        let h = server
            .submit(JobSpec::new("t", tiny_cfg(3)))
            .unwrap();
        let r = h.wait().unwrap();
        assert_eq!(r.procs, 2);
        assert!(r.total_spikes > 0);
    }

    #[test]
    fn oversized_job_rejected_at_submit() {
        let server = SimServer::start(ServeOptions { total_ranks: 1 });
        let err = server.submit(JobSpec::new("big", tiny_cfg(1))).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn identical_jobs_batch_and_distinct_seeds_do_not() {
        let server = SimServer::start(ServeOptions { total_ranks: 2 });
        // Same config twice: one engine pass, identical results.
        let h1 = server.submit(JobSpec::new("a", tiny_cfg(7))).unwrap();
        let h2 = server.submit(JobSpec::new("b", tiny_cfg(7))).unwrap();
        // Different seed: never batched with the others.
        let h3 = server.submit(JobSpec::new("c", tiny_cfg(8))).unwrap();
        let r1 = h1.wait().unwrap();
        let r2 = h2.wait().unwrap();
        let r3 = h3.wait().unwrap();
        assert_eq!(r1.pop_counts, r2.pop_counts);
        assert_ne!(
            r1.pop_counts, r3.pop_counts,
            "distinct seeds must not share RNG-dependent state"
        );
    }

    #[test]
    fn bad_artifacts_dir_fails_one_job_not_the_server() {
        let server = SimServer::start(ServeOptions { total_ranks: 2 });
        let mut bad = tiny_cfg(1);
        bad.backend = Backend::Xla;
        bad.artifacts_dir = "/nonexistent/dpsnn-artifacts".to_string();
        let h = server.submit(JobSpec::new("xla", bad)).unwrap();
        assert!(h.wait().is_err());
        // The server keeps serving.
        let ok = server.submit(JobSpec::new("native", tiny_cfg(2))).unwrap();
        assert!(ok.wait().is_ok());
    }
}
