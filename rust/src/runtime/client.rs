//! The PJRT CPU runtime: compile HLO-text artifacts once, execute them on
//! the hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos with 64-bit instruction ids).
//!
//! `PjRtClient` holds an `Rc` internally and is not `Send`; a live run
//! with the Xla backend therefore constructs one `XlaRuntime` *per rank
//! thread* (see `coordinator::live`).

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::artifact::ArtifactRegistry;
// The real `xla` bindings cannot be vendored offline; the stub mirrors
// their API and reports the runtime as unavailable (see xla_stub docs).
use super::xla_stub as xla;

pub struct XlaRuntime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    /// Compiled executables keyed by rung size.
    cache: HashMap<u32, Rc<xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::scan(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, registry, cache: HashMap::new() })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Compile (or fetch from cache) the executable for a population of
    /// `n` neurons. Returns (rung size, executable).
    pub fn executable_for(
        &mut self,
        n: u32,
    ) -> Result<(u32, Rc<xla::PjRtLoadedExecutable>)> {
        let rung = self.registry.rung_for(n)?;
        if let Some(exe) = self.cache.get(&rung) {
            return Ok((rung, exe.clone()));
        }
        let path = self.registry.path_for_rung(rung);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact rung {rung}"))?;
        let exe = Rc::new(exe);
        self.cache.insert(rung, exe.clone());
        Ok((rung, exe))
    }

    /// Upload a host vector as a device buffer (f32, rank 1).
    pub fn upload(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, &[data.len()], None)
            .context("uploading buffer")
    }

    /// One population step through the packed-ABI artifact
    /// (`aot.py` manifest v2, EXPERIMENTS.md §Perf):
    ///
    /// inputs  `params[8], state[3r] = v|w|rf, i_syn[r], i_ext[r], sfa[r]`
    /// output  `packed[4r] = v|w|rf|spiked`, read with a single raw copy.
    pub fn run_step_packed(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        params: &xla::PjRtBuffer,
        state: &[f32],
        i_syn: &[f32],
        i_ext: &[f32],
        sfa_inc: &xla::PjRtBuffer,
        out: &mut [f32],
    ) -> Result<()> {
        debug_assert_eq!(state.len() * 4, out.len() * 3);
        let bstate = self.upload(state)?;
        let bisyn = self.upload(i_syn)?;
        let biext = self.upload(i_ext)?;
        let inputs: [&xla::PjRtBuffer; 5] = [params, &bstate, &bisyn, &biext, sfa_inc];
        let result = exe.execute_b::<&xla::PjRtBuffer>(&inputs)?;
        // CopyRawToHost is unimplemented on the TFRT CPU client, so the
        // packed array comes back through one literal (still a single
        // copy and no tuple unwrapping). An empty result shape would be
        // a broken artifact, not a programming error here — surface it
        // as a job failure rather than an index panic.
        let lit = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("executable returned no output buffers"))?
            .to_literal_sync()
            .context("reading packed step output")?;
        let vals = lit.to_vec::<f32>()?;
        anyhow::ensure!(
            vals.len() == out.len(),
            "packed output length {} != expected {}",
            vals.len(),
            out.len()
        );
        out.copy_from_slice(&vals);
        Ok(())
    }
}
