//! API-compatible stand-in for the `xla` PJRT bindings.
//!
//! The real bindings (`xla_extension`) link libxla and cannot be vendored
//! into an offline build, so the crate ships this stub instead: it mirrors
//! exactly the surface `runtime::client` and `runtime::backend` consume,
//! and every entry point that would touch PJRT reports the runtime as
//! unavailable. Selecting `--backend xla` therefore fails fast with a
//! clear error instead of failing to link, and everything else (the
//! native SoA backend, all tests, all benches) builds and runs without
//! the dependency. Swapping in the real crate is a one-line change at
//! each `use super::xla_stub as xla;` site.
//!
//! [`AVAILABLE`] lets tests and callers gate XLA-only paths (see
//! `rust/tests/backend_parity.rs`).

use std::path::Path;

/// `false` in stub builds: no PJRT runtime is linked. The parity tests
/// and any `--backend xla` caller check this before expecting the XLA
/// path to work.
pub const AVAILABLE: bool = false;

/// The error every stubbed entry point returns.
#[derive(Debug, Clone, Copy)]
pub struct Unavailable;

impl std::fmt::Display for Unavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT runtime unavailable: this build stubs out the xla bindings \
             (offline build without libxla); use --backend native"
        )
    }
}

impl std::error::Error for Unavailable {}

/// Stub of `xla::PjRtClient`. The real client is created per rank thread
/// (it is not `Send`); the stub's constructor always errors.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Unavailable> {
        Err(Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub of a compiled-and-loaded PJRT executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub of a host literal read back from the device.
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub of an XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(!AVAILABLE);
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("--backend native"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        // The error converts into anyhow::Error (client.rs relies on `?`).
        let anyhow_err: anyhow::Error = Unavailable.into();
        assert!(anyhow_err.to_string().contains("PJRT runtime unavailable"));
    }
}
