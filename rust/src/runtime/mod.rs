//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and run
//! them from the rust hot path, plus the pluggable neuron-dynamics backend
//! abstraction (native rust vs XLA executable).
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! *only* consumer of its output.

pub mod artifact;
pub mod backend;
pub mod client;

pub use artifact::ArtifactRegistry;
pub use backend::{make_backend, NativeBackend, NeuronBackend};
pub use client::XlaRuntime;
