//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and run
//! them from the rust hot path, plus the pluggable neuron-dynamics backend
//! abstraction (native rust vs XLA executable).
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! *only* consumer of its output.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod server;
pub mod xla_stub;

pub use artifact::{ArtifactError, ArtifactRegistry};
pub use backend::{make_backend, NativeBackend, NeuronBackend};
pub use client::XlaRuntime;
pub use server::{CacheStats, JobEvent, JobHandle, SimServer};

/// Whether this build links a real PJRT runtime. `false` means the
/// offline [`xla_stub`] is in place: `--backend xla` fails fast with a
/// clear error and the XLA parity tests skip themselves.
pub fn xla_available() -> bool {
    xla_stub::AVAILABLE
}
