//! Whole-setup instantaneous power model.
//!
//! `P(t) = baseline + Σ_nodes node_power(active cores, utilization)
//!        + Σ_nics nic_active`
//!
//! where `utilization` is the computation fraction of wall-clock from the
//! timing model — the coupling that reproduces the paper's observation
//! that 64-process runs draw *less* than 2× the 32-process runs (cores
//! blocked on the interconnect draw less than busy cores).

use crate::platform::presets::PlatformModel;
use crate::simnet::link::LinkModel;

#[derive(Debug, Clone)]
pub struct PowerModel {
    pub platform: PlatformModel,
    pub interconnect: LinkModel,
}

impl PowerModel {
    pub fn new(platform: PlatformModel, interconnect: LinkModel) -> Self {
        Self { platform, interconnect }
    }

    /// Nodes engaged by `p` ranks.
    pub fn nodes(&self, p: u32) -> u32 {
        self.platform.node.nodes_for(p)
    }

    /// Above-baseline draw while *running* with `p` ranks at computation
    /// fraction `u` (0..=1).
    pub fn running_power_w(&self, p: u32, u: f64) -> f64 {
        let node_w = self.platform.node.cluster_power_w(p, u);
        // NICs are engaged only when the job spans nodes.
        let nic_w = if self.nodes(p) > 1 {
            self.nodes(p) as f64
                * self.interconnect.nic_active_w
                * self.platform.nic_power_scale
        } else {
            0.0
        };
        node_w + nic_w
    }

    /// Absolute draw (what the multimeter reads) while running.
    pub fn absolute_running_power_w(&self, p: u32, u: f64) -> f64 {
        self.platform.baseline_w + self.running_power_w(p, u)
    }

    /// Energy-to-solution above baseline (J) for a run of `wall_s`
    /// seconds — the paper's metric ("the meter reading subtracted from a
    /// baseline").
    pub fn energy_to_solution_j(&self, p: u32, u: f64, wall_s: f64) -> f64 {
        self.running_power_w(p, u) * wall_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::platform_by_name;
    use crate::simnet::presets::{ETH1G, IB};

    fn westmere_ib() -> PowerModel {
        PowerModel::new(platform_by_name("westmere").unwrap(), IB)
    }

    #[test]
    fn single_node_has_no_nic_power() {
        let m = westmere_ib();
        assert_eq!(m.running_power_w(16, 1.0), m.platform.node.busy_power_w(16));
    }

    #[test]
    fn table2_busy_anchors_reproduced() {
        let m = westmere_ib();
        for (p, w) in [(1u32, 48.0), (2, 62.0), (4, 92.0), (8, 124.0), (16, 166.0)] {
            let got = m.running_power_w(p, 1.0);
            assert!((got - w).abs() < 1.0, "p={p}: {got} vs {w}");
        }
    }

    #[test]
    fn ib_draws_less_than_eth_multi_node() {
        let ib = westmere_ib();
        let eth = PowerModel::new(platform_by_name("westmere").unwrap(), ETH1G);
        for p in [32u32, 64] {
            let d = eth.running_power_w(p, 0.3) - ib.running_power_w(p, 0.3);
            assert!(d > 10.0, "p={p}: ETH should draw >10 W more, got {d}");
        }
    }

    #[test]
    fn blocked_cores_reduce_draw() {
        let m = westmere_ib();
        // 64 ranks mostly blocked on comm: well under 2x the 32-rank busy draw
        let p64_blocked = m.running_power_w(64, 0.08);
        let p32_busy = m.running_power_w(32, 0.8);
        assert!(
            p64_blocked < 1.8 * p32_busy,
            "64p blocked {p64_blocked} vs 32p busy {p32_busy}"
        );
    }

    #[test]
    fn energy_scales_with_time() {
        let m = westmere_ib();
        let e = m.energy_to_solution_j(8, 1.0, 25.3);
        // Table II, 8 cores: 124 W x 25.3 s = 3137 J
        assert!((e - 3137.2).abs() < 20.0, "e={e}");
    }
}
