//! Power and energy substrate: the whole-setup power model, the simulated
//! digital multimeter (the paper's GW Instek GDM-8351), and power-trace
//! handling with baseline subtraction and energy integration.

pub mod model;
pub mod meter;
pub mod trace;

pub use meter::{Multimeter, MeterMode};
pub use model::PowerModel;
pub use trace::PowerTrace;
