//! Simulated digital multimeter (GW Instek GDM-8351 stand-in).
//!
//! The paper samples AC/DC current with one meter: DC downstream a single
//! board's 19 V supply (clean), AC at the mains strip for multi-board and
//! server measurements (noisier, transformer draw inflates the baseline).
//! This module reproduces those measurement conditions so the fig7/fig8
//! harnesses generate traces with the same texture: a 5 s idle plateau, a
//! steep knee at simulation start, the run plateau, and the final drop.

use crate::util::rng::SplitMix64;

use super::trace::PowerTrace;

/// AC (mains, noisy) vs DC (supply output, clean) sampling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeterMode {
    Ac,
    Dc,
}

#[derive(Debug, Clone)]
pub struct Multimeter {
    pub mode: MeterMode,
    /// Samples per second (the GDM-8351 over USB logs a few Hz).
    pub sample_hz: f64,
    seed: u64,
}

impl Multimeter {
    pub fn new(mode: MeterMode, sample_hz: f64, seed: u64) -> Self {
        assert!(sample_hz > 0.0);
        Self { mode, sample_hz, seed }
    }

    /// Gaussian reading noise (1σ) in watts for a given true draw.
    fn noise_sigma_w(&self, true_w: f64) -> f64 {
        match self.mode {
            // AC at the strip: transformer ripple + PF wander, ~1.5% + 1.5 W
            MeterMode::Ac => 0.015 * true_w + 1.5,
            // DC at the supply output: tight, ~0.3% + 0.05 W
            MeterMode::Dc => 0.003 * true_w + 0.05,
        }
    }

    /// Sample a run profile into a trace.
    ///
    /// `phases` is a list of (duration_s, true_power_w) segments, e.g.
    /// `[(5.0, baseline), (wall, baseline+run), (3.0, baseline)]`.
    pub fn sample(&self, phases: &[(f64, f64)]) -> PowerTrace {
        let mut rng = SplitMix64::new(self.seed);
        let mut trace = PowerTrace::default();
        let dt = 1.0 / self.sample_hz;
        let mut t = 0.0;
        for &(dur, w) in phases {
            let end = t + dur;
            while t < end {
                let sigma = self.noise_sigma_w(w);
                let reading = w + sigma * rng.next_normal();
                trace.push(t, reading.max(0.0));
                t += dt;
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phases() -> Vec<(f64, f64)> {
        vec![(5.0, 564.0), (20.0, 564.0 + 166.0), (3.0, 564.0)]
    }

    #[test]
    fn trace_has_knee_and_drop() {
        let m = Multimeter::new(MeterMode::Ac, 4.0, 1);
        let tr = m.sample(&phases());
        let base = tr.infer_baseline_w(5.0);
        assert!((base - 564.0).abs() < 8.0, "baseline {base}");
        // run plateau clearly above baseline
        let mid: f64 = tr
            .w
            .iter()
            .zip(&tr.t_s)
            .filter(|(_, &t)| t > 8.0 && t < 22.0)
            .map(|(&w, _)| w)
            .sum::<f64>()
            / tr.t_s.iter().filter(|&&t| t > 8.0 && t < 22.0).count() as f64;
        assert!((mid - 730.0).abs() < 10.0, "plateau {mid}");
    }

    #[test]
    fn energy_integrates_to_power_times_time() {
        let m = Multimeter::new(MeterMode::Dc, 10.0, 2);
        let tr = m.sample(&phases());
        let e = tr.energy_above_j(564.0);
        assert!((e - 166.0 * 20.0).abs() < 120.0, "e={e}");
    }

    #[test]
    fn ac_noisier_than_dc() {
        let sig = |mode| {
            let m = Multimeter::new(mode, 50.0, 3);
            let tr = m.sample(&[(10.0, 600.0)]);
            let mean = tr.w.iter().sum::<f64>() / tr.len() as f64;
            (tr.w.iter().map(|w| (w - mean).powi(2)).sum::<f64>() / tr.len() as f64).sqrt()
        };
        assert!(sig(MeterMode::Ac) > 3.0 * sig(MeterMode::Dc));
    }
}
