//! Power traces: time series of meter readings with baseline handling
//! and energy integration (trapezoidal).

/// A sampled power time series (seconds, watts).
#[derive(Debug, Clone, Default)]
pub struct PowerTrace {
    pub t_s: Vec<f64>,
    pub w: Vec<f64>,
}

impl PowerTrace {
    pub fn push(&mut self, t_s: f64, w: f64) {
        debug_assert!(self.t_s.last().map_or(true, |&last| t_s >= last));
        self.t_s.push(t_s);
        self.w.push(w);
    }

    pub fn len(&self) -> usize {
        self.t_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t_s.is_empty()
    }

    /// Infer the baseline from the initial idle plateau (the paper
    /// inserts 5 s of artificial pause before the run): mean of samples
    /// in [0, plateau_s).
    pub fn infer_baseline_w(&self, plateau_s: f64) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (&t, &w) in self.t_s.iter().zip(&self.w) {
            if t < plateau_s {
                sum += w;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Trapezoidal integral of (W - baseline) over the whole trace (J).
    pub fn energy_above_j(&self, baseline_w: f64) -> f64 {
        let mut e = 0.0;
        for i in 1..self.len() {
            let dt = self.t_s[i] - self.t_s[i - 1];
            let w = 0.5 * (self.w[i] + self.w[i - 1]) - baseline_w;
            e += w * dt;
        }
        e
    }

    /// Peak reading.
    pub fn peak_w(&self) -> f64 {
        self.w.iter().cloned().fold(0.0, f64::max)
    }

    /// CSV (t_s,watts) for plotting.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t_s,watts\n");
        for (&t, &w) in self.t_s.iter().zip(&self.w) {
            s.push_str(&format!("{t:.3},{w:.3}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_trace() -> PowerTrace {
        // 5 s at 100 W, then 10 s at 150 W, sampled at 2 Hz
        let mut tr = PowerTrace::default();
        let mut t = 0.0;
        while t < 15.0 {
            tr.push(t, if t < 5.0 { 100.0 } else { 150.0 });
            t += 0.5;
        }
        tr
    }

    #[test]
    fn baseline_from_plateau() {
        let tr = square_trace();
        assert_eq!(tr.infer_baseline_w(5.0), 100.0);
    }

    #[test]
    fn energy_above_baseline() {
        let tr = square_trace();
        let e = tr.energy_above_j(100.0);
        // 50 W x ~10 s, trapezoid smears one 0.5 s edge sample
        assert!((e - 500.0).abs() < 30.0, "e={e}");
    }

    #[test]
    fn peak() {
        assert_eq!(square_trace().peak_w(), 150.0);
    }

    #[test]
    fn csv_shape() {
        let csv = square_trace().to_csv();
        assert!(csv.starts_with("t_s,watts\n"));
        assert_eq!(csv.lines().count(), 31);
    }
}
