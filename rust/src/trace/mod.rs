//! Workload traces: per-step spike/byte statistics that drive the modeled
//! timing and power replay. Traces come from two sources:
//!
//! * **recorded** — a live run writes its actual per-step spike counts;
//! * **analytic** — for configurations too big to run live (the paper's
//!   320K/1280K networks, 256-process jobs, Fig 1's billions of
//!   synapses), generated from the network's statistical description.

pub mod workload;
pub mod analytic;

pub use analytic::AnalyticWorkload;
pub use workload::WorkloadTrace;
