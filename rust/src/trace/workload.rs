//! The workload trace format.
//!
//! With the paper's homogeneous connectivity, one number per (step, rank)
//! — the spike count — determines the whole communication matrix of that
//! step (every rank broadcasts its spikes to all others at 12 B each),
//! and with the per-neuron statistics it determines the computation load.

use anyhow::{bail, Result};

use crate::comm::aer::SPIKE_WIRE_BYTES;

/// Per-step, per-rank spike counts plus run-level metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadTrace {
    pub n_neurons: u32,
    pub syn_per_neuron: u32,
    pub ext_events_per_neuron_step: f64,
    pub dt_ms: f64,
    pub procs: u32,
    /// spikes[step][rank]
    pub spikes: Vec<Vec<u32>>,
}

impl WorkloadTrace {
    pub fn steps(&self) -> u32 {
        self.spikes.len() as u32
    }

    pub fn sim_seconds(&self) -> f64 {
        self.steps() as f64 * self.dt_ms * 1e-3
    }

    pub fn total_spikes(&self) -> u64 {
        self.spikes
            .iter()
            .map(|row| row.iter().map(|&s| s as u64).sum::<u64>())
            .sum()
    }

    /// Mean firing rate over the run (Hz).
    pub fn mean_rate_hz(&self) -> f64 {
        if self.steps() == 0 {
            return 0.0;
        }
        self.total_spikes() as f64 / self.n_neurons as f64 / self.sim_seconds()
    }

    /// Spikes of the busiest rank at `step` (drives the comp-imbalance
    /// barrier term).
    pub fn max_rank_spikes(&self, step: u32) -> u32 {
        *self.spikes[step as usize].iter().max().unwrap_or(&0)
    }

    /// Mean per-rank spikes at `step`.
    pub fn mean_rank_spikes(&self, step: u32) -> f64 {
        let row = &self.spikes[step as usize];
        if row.is_empty() {
            return 0.0;
        }
        row.iter().map(|&s| s as f64).sum::<f64>() / row.len() as f64
    }

    /// Wire bytes rank `r` sends to each other rank at `step`.
    pub fn bytes_per_msg(&self, step: u32, r: u32) -> u64 {
        self.spikes[step as usize][r as usize] as u64 * SPIKE_WIRE_BYTES as u64
    }

    /// Total recurrent synaptic events triggered by step `step`
    /// (every spike fans out to syn_per_neuron targets network-wide).
    pub fn syn_events(&self, step: u32) -> u64 {
        self.spikes[step as usize]
            .iter()
            .map(|&s| s as u64 * self.syn_per_neuron as u64)
            .sum()
    }

    /// Serialize to a simple CSV: one metadata header line, then one line
    /// per step with per-rank spike counts.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut s = format!(
            "# dpsnn-trace v1 neurons={} syn_per_neuron={} ext={} dt_ms={} procs={}\n",
            self.n_neurons,
            self.syn_per_neuron,
            self.ext_events_per_neuron_step,
            self.dt_ms,
            self.procs
        );
        for row in &self.spikes {
            let line: Vec<String> = row.iter().map(|c| c.to_string()).collect();
            s.push_str(&line.join(","));
            s.push('\n');
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, s)?;
        Ok(())
    }

    /// Load a trace written by [`WorkloadTrace::save`].
    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace"))?;
        if !header.starts_with("# dpsnn-trace v1") {
            bail!("not a dpsnn trace file: {header:?}");
        }
        let field = |name: &str| -> Result<f64> {
            header
                .split_whitespace()
                .find_map(|tok| tok.strip_prefix(&format!("{name}=")))
                .ok_or_else(|| anyhow::anyhow!("missing {name} in trace header"))?
                .parse::<f64>()
                .map_err(Into::into)
        };
        let mut trace = WorkloadTrace {
            n_neurons: field("neurons")? as u32,
            syn_per_neuron: field("syn_per_neuron")? as u32,
            ext_events_per_neuron_step: field("ext")?,
            dt_ms: field("dt_ms")?,
            procs: field("procs")? as u32,
            spikes: Vec::new(),
        };
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let row: Result<Vec<u32>, _> =
                line.split(',').map(|c| c.trim().parse::<u32>()).collect();
            let row = row.map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 2))?;
            if row.len() != trace.procs as usize {
                bail!("trace line {}: {} cells, expected {}", i + 2, row.len(), trace.procs);
            }
            trace.spikes.push(row);
        }
        Ok(trace)
    }

    /// Re-bin a trace onto a different process count, preserving per-step
    /// totals (used to replay a recorded trace at other P, exploiting the
    /// partition-independence of the network itself).
    pub fn rebin(&self, procs: u32) -> Result<WorkloadTrace> {
        if procs == 0 || procs > self.n_neurons {
            bail!("cannot rebin onto {procs} ranks");
        }
        let mut out = WorkloadTrace {
            n_neurons: self.n_neurons,
            syn_per_neuron: self.syn_per_neuron,
            ext_events_per_neuron_step: self.ext_events_per_neuron_step,
            dt_ms: self.dt_ms,
            procs,
            spikes: Vec::with_capacity(self.spikes.len()),
        };
        for row in &self.spikes {
            let total: u64 = row.iter().map(|&s| s as u64).sum();
            // spread evenly (the network is homogeneous); remainder to
            // the first ranks
            let base = (total / procs as u64) as u32;
            let rem = (total % procs as u64) as u32;
            let mut new_row = vec![base; procs as usize];
            for slot in new_row.iter_mut().take(rem as usize) {
                *slot += 1;
            }
            out.spikes.push(new_row);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> WorkloadTrace {
        WorkloadTrace {
            n_neurons: 1000,
            syn_per_neuron: 100,
            ext_events_per_neuron_step: 1.2,
            dt_ms: 1.0,
            procs: 4,
            spikes: vec![vec![1, 2, 3, 4], vec![0, 0, 0, 0], vec![5, 5, 5, 5]],
        }
    }

    #[test]
    fn totals_and_rate() {
        let t = trace();
        assert_eq!(t.total_spikes(), 30);
        assert_eq!(t.steps(), 3);
        // 30 spikes / 1000 neurons / 0.003 s = 10 Hz
        assert!((t.mean_rate_hz() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn per_step_views() {
        let t = trace();
        assert_eq!(t.max_rank_spikes(0), 4);
        assert_eq!(t.mean_rank_spikes(2), 5.0);
        assert_eq!(t.bytes_per_msg(0, 3), 48);
        assert_eq!(t.syn_events(0), 1000);
    }

    #[test]
    fn save_load_round_trip() {
        let t = trace();
        let path = std::env::temp_dir().join(format!(
            "dpsnn-trace-test-{}.csv",
            std::process::id()
        ));
        t.save(&path).unwrap();
        let back = WorkloadTrace::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(t, back);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join(format!(
            "dpsnn-trace-bad-{}.csv",
            std::process::id()
        ));
        std::fs::write(&path, "not a trace\n1,2\n").unwrap();
        assert!(WorkloadTrace::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rebin_preserves_totals() {
        let t = trace();
        for p in [1u32, 2, 8, 40] {
            let r = t.rebin(p).unwrap();
            assert_eq!(r.procs, p);
            for s in 0..t.steps() {
                let a: u64 = t.spikes[s as usize].iter().map(|&x| x as u64).sum();
                let b: u64 = r.spikes[s as usize].iter().map(|&x| x as u64).sum();
                assert_eq!(a, b, "step {s} p {p}");
            }
        }
        assert!(t.rebin(0).is_err());
        assert!(t.rebin(2000).is_err());
    }
}
