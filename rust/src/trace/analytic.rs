//! Analytic workload generation for configurations too large to run live.
//!
//! The paper's networks settle into an asynchronous-irregular regime at a
//! stable mean rate (~3.2 Hz) after an initial transient. Per step, each
//! rank's spike count is then Poisson(n_local * rate * dt); the transient
//! is modeled as a brief rate ramp. This reproduces the statistics the
//! timing/power models care about (mean load, per-rank fluctuations that
//! feed the barrier-imbalance term) without materializing billions of
//! synapses.

use crate::config::NetworkParams;
use crate::util::rng::SplitMix64;

use super::workload::WorkloadTrace;

#[derive(Debug, Clone)]
pub struct AnalyticWorkload {
    pub net: NetworkParams,
    /// Steady-state mean firing rate (paper: ~3.2 Hz).
    pub rate_hz: f64,
    /// Transient: initial rate multiplier decaying to 1 with this time
    /// constant (ms). The settling burst is visible in Fig 7/8 knees.
    pub transient_gain: f64,
    pub transient_tau_ms: f64,
    pub seed: u64,
}

impl AnalyticWorkload {
    pub fn paper_regime(net: NetworkParams, seed: u64) -> Self {
        Self {
            net,
            rate_hz: 3.2,
            transient_gain: 2.0,
            transient_tau_ms: 150.0,
            seed,
        }
    }

    /// Instantaneous rate at a step (Hz).
    pub fn rate_at(&self, step: u32) -> f64 {
        let t_ms = step as f64 * self.net.dt_ms;
        let boost = (self.transient_gain - 1.0) * (-t_ms / self.transient_tau_ms).exp();
        self.rate_hz * (1.0 + boost)
    }

    /// Generate the trace for `procs` ranks over `sim_seconds`.
    pub fn generate(&self, procs: u32, sim_seconds: f64) -> WorkloadTrace {
        let steps = self.net.steps_for_seconds(sim_seconds);
        let mut rng = SplitMix64::new(self.seed ^ 0xA11A);
        let n = self.net.n_neurons as f64;
        let mut spikes = Vec::with_capacity(steps as usize);
        for t in 0..steps {
            let lambda_net = n * self.rate_at(t) * self.net.dt_ms * 1e-3;
            let lambda_rank = lambda_net / procs as f64;
            let row: Vec<u32> = (0..procs)
                .map(|_| rng.next_poisson(lambda_rank))
                .collect();
            spikes.push(row);
        }
        WorkloadTrace {
            n_neurons: self.net.n_neurons,
            syn_per_neuron: self.net.syn_per_neuron,
            ext_events_per_neuron_step: self.net.ext_lambda_per_step(),
            dt_ms: self.net.dt_ms,
            procs,
            spikes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_rate_is_target() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 1);
        let tr = w.generate(8, 5.0);
        // whole-run mean includes the transient, so slightly above 3.2 Hz
        let r = tr.mean_rate_hz();
        assert!((3.1..3.7).contains(&r), "rate {r}");
        assert_eq!(tr.steps(), 5000);
        assert_eq!(tr.procs, 8);
    }

    #[test]
    fn transient_decays() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 1);
        assert!(w.rate_at(0) > 1.8 * w.rate_hz);
        assert!((w.rate_at(3000) - w.rate_hz).abs() < 0.01);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::tiny(1024), 9);
        assert_eq!(w.generate(4, 1.0), w.generate(4, 1.0));
    }

    #[test]
    fn per_rank_fluctuations_exist() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 2);
        let tr = w.generate(16, 1.0);
        let any_unequal = (0..tr.steps())
            .any(|s| tr.max_rank_spikes(s) as f64 > tr.mean_rank_spikes(s));
        assert!(any_unequal, "Poisson fluctuations must differentiate ranks");
    }
}
