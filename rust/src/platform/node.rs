//! Per-node power model.
//!
//! Above-baseline node draw is a calibrated piecewise-linear curve in the
//! number of active cores (the paper's own Tables II/III readings are the
//! anchors), scaled by core *utilization*: a core idle-waiting on
//! communication draws only a fraction of its busy power. This coupling
//! is what reproduces the paper's 64-process rows, where power per node
//! *drops* because cores spend >90% of the step blocked on the
//! interconnect.

use super::cpu::CoreModel;

#[derive(Debug, Clone)]
pub struct NodeModel {
    pub name: &'static str,
    pub core: CoreModel,
    /// Schedulable cores per node (as used in the paper's runs).
    pub cores_per_node: u32,
    /// Above-baseline draw anchors: (active cores, watts) at full
    /// utilization, ascending; interpolated/extrapolated linearly.
    pub power_anchors_w: Vec<(u32, f64)>,
    /// Fraction of busy power an active-but-waiting core still draws.
    pub idle_draw_frac: f64,
}

impl NodeModel {
    /// Above-baseline draw at full utilization for `k` active cores.
    pub fn busy_power_w(&self, k: u32) -> f64 {
        if k == 0 {
            return 0.0;
        }
        let a = &self.power_anchors_w;
        debug_assert!(!a.is_empty());
        if k <= a[0].0 {
            return a[0].1 * k as f64 / a[0].0 as f64;
        }
        for win in a.windows(2) {
            let ((k0, w0), (k1, w1)) = (win[0], win[1]);
            if k <= k1 {
                let t = (k - k0) as f64 / (k1 - k0) as f64;
                return w0 + t * (w1 - w0);
            }
        }
        // extrapolate with the last segment's slope
        let ((k0, w0), (k1, w1)) = (a[a.len() - 2], a[a.len() - 1]);
        let slope = (w1 - w0) / (k1 - k0) as f64;
        w1 + slope * (k - k1) as f64
    }

    /// Above-baseline draw for `k` active cores at utilization `u` (the
    /// computation fraction of wall-clock, 0..=1).
    pub fn power_w(&self, k: u32, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        self.busy_power_w(k) * (self.idle_draw_frac + (1.0 - self.idle_draw_frac) * u)
    }

    /// Nodes needed to host `p` ranks.
    pub fn nodes_for(&self, p: u32) -> u32 {
        p.div_ceil(self.cores_per_node)
    }

    /// Active cores on each node when running `p` ranks (last node may be
    /// partially filled); returns (full nodes, cores on last node).
    pub fn occupancy(&self, p: u32) -> (u32, u32) {
        let full = p / self.cores_per_node;
        let rem = p % self.cores_per_node;
        (full, rem)
    }

    /// Total above-baseline draw for `p` ranks at utilization `u`,
    /// excluding NICs.
    pub fn cluster_power_w(&self, p: u32, u: f64) -> f64 {
        let (full, rem) = self.occupancy(p);
        let mut w = full as f64 * self.power_w(self.cores_per_node, u);
        if rem > 0 {
            w += self.power_w(rem, u);
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use crate::platform::presets;

    #[test]
    fn westmere_curve_hits_table2_anchors() {
        let n = presets::westmere_node();
        // Table II at full utilization (computation-dominated rows)
        for (k, w) in [(1u32, 48.0), (2, 62.0), (4, 92.0), (8, 124.0), (16, 166.0)] {
            let got = n.busy_power_w(k);
            assert!(
                (got - w).abs() < 1.0,
                "k={k}: got {got}, Table II says {w}"
            );
        }
    }

    #[test]
    fn interpolation_between_anchors() {
        let n = presets::westmere_node();
        let w3 = n.busy_power_w(3);
        assert!(w3 > n.busy_power_w(2) && w3 < n.busy_power_w(4));
    }

    #[test]
    fn waiting_cores_draw_less() {
        let n = presets::westmere_node();
        assert!(n.power_w(16, 0.1) < n.busy_power_w(16));
        assert!(n.power_w(16, 1.0) == n.busy_power_w(16));
        assert!(n.power_w(16, 0.0) >= 0.5 * n.busy_power_w(16)); // still warm
    }

    #[test]
    fn multi_node_occupancy() {
        let n = presets::westmere_node();
        assert_eq!(n.nodes_for(16), 1);
        assert_eq!(n.nodes_for(17), 2);
        assert_eq!(n.occupancy(40), (2, 8));
        let w = n.cluster_power_w(32, 1.0);
        assert!((w - 2.0 * n.busy_power_w(16)).abs() < 1e-9);
    }

    #[test]
    fn jetson_curve_hits_table3_anchors() {
        let n = presets::jetson_node();
        for (k, w) in [(1u32, 2.2), (2, 3.4), (4, 6.0)] {
            let got = n.busy_power_w(k);
            assert!((got - w).abs() < 0.1, "k={k}: got {got}, Table III says {w}");
        }
    }
}
