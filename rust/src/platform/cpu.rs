//! Per-core compute-rate model.
//!
//! DPSNN's computation phase is dominated by three memory-bound loops
//! (paper §II): neuron state updates, recurrent synaptic-event delivery
//! (delay queues + synapse lists) and external-stimulus events. Each core
//! class is characterized by sustained event rates for the three, scaled
//! from the Westmere anchor (150.9 s for 10 s of the 20480N network on
//! one core — Table II row 1).

/// Sustained per-core processing rates (events/second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreModel {
    pub name: &'static str,
    /// Neuron state updates per second.
    pub r_nrn: f64,
    /// Recurrent synaptic events per second.
    pub r_syn: f64,
    /// External (Poisson) events per second.
    pub r_ext: f64,
}

/// Anchor: Intel Xeon X5660/E5620 (Westmere, 32 nm) single core.
/// 10 s of 20480N = 2.048e8 neuron updates + 7.37e8 synaptic events
/// + 2.46e8 external events in 150.9 s.
pub const WESTMERE: CoreModel = CoreModel {
    name: "westmere",
    r_nrn: 4.0e6,
    r_syn: 10.0e6,
    r_ext: 8.0e6,
};

impl CoreModel {
    /// A core `factor`× the speed of this one.
    pub const fn scaled(self, name: &'static str, factor: f64) -> CoreModel {
        CoreModel {
            name,
            r_nrn: self.r_nrn * factor,
            r_syn: self.r_syn * factor,
            r_ext: self.r_ext * factor,
        }
    }

    /// Seconds to process the given event counts.
    #[inline]
    pub fn comp_time(&self, nrn_updates: f64, syn_events: f64, ext_events: f64) -> f64 {
        nrn_updates / self.r_nrn + syn_events / self.r_syn + ext_events / self.r_ext
    }

    /// Overall speed factor vs the Westmere anchor (geometric mean of
    /// the three rates).
    pub fn speed_vs_westmere(&self) -> f64 {
        let g = |a: f64, b: f64| a / b;
        (g(self.r_nrn, WESTMERE.r_nrn)
            * g(self.r_syn, WESTMERE.r_syn)
            * g(self.r_ext, WESTMERE.r_ext))
        .cbrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The calibration workload: 10 s of the paper's 20480N network.
    fn n20k_10s() -> (f64, f64, f64) {
        let n = 20480.0;
        let steps = 10_000.0;
        let rate = 3.2;
        let syn = n * 1125.0 * rate * 10.0;
        let ext = n * 400.0 * 3.0 * 10.0;
        (n * steps, syn, ext)
    }

    #[test]
    fn westmere_anchor_reproduces_table2_row1() {
        let (nrn, syn, ext) = n20k_10s();
        let t = WESTMERE.comp_time(nrn, syn, ext);
        // Table II, 1 core: 150.9 s. Within 10%.
        assert!((t - 150.9).abs() / 150.9 < 0.10, "t={t}");
    }

    #[test]
    fn scaling_factor_applies() {
        let fast = WESTMERE.scaled("fast", 2.0);
        let (nrn, syn, ext) = n20k_10s();
        let ratio = WESTMERE.comp_time(nrn, syn, ext) / fast.comp_time(nrn, syn, ext);
        assert!((ratio - 2.0).abs() < 1e-9);
        assert!((fast.speed_vs_westmere() - 2.0).abs() < 1e-9);
    }
}
