//! Named platform presets for the paper's testbeds.

use anyhow::{bail, Result};

pub use super::cpu::WESTMERE;
use super::cpu::CoreModel;
use super::node::NodeModel;
use crate::simnet::alltoall_model::AllToAllModel;
use crate::simnet::link::LinkModel;

/// A complete modeled platform: node type + whole-setup power baseline.
#[derive(Debug, Clone)]
pub struct PlatformModel {
    pub name: &'static str,
    pub node: NodeModel,
    /// The measured idle plateau the paper subtracts (564 W server rack,
    /// 49.2 W two-Jetson AC setup, ...).
    pub baseline_w: f64,
    /// Default interconnect preset name for this platform.
    pub default_interconnect: &'static str,
    /// Scale on the interconnect's active NIC power: server-class NIC
    /// cards draw their full figure; the SoC boards' on-chip GbE MACs
    /// draw a small fraction of it.
    pub nic_power_scale: f64,
    /// Latency (and per-message fabric cost) multiplier per fabric tier
    /// above the board link, for `--topology tree:` pricing: chassis
    /// and rack links cross more switch stages than the board
    /// backplane. The ExaNeSt-class unified fabrics derate gently;
    /// commodity cluster tiers roughly double per stage.
    pub tier_latency_mul: f64,
    /// Bandwidth divisor per fabric tier above the board link.
    pub tier_bandwidth_div: f64,
}

impl PlatformModel {
    /// *The* ranks-per-node notion for this platform: its schedulable
    /// cores per node. Both the energy model's node occupancy
    /// ([`NodeModel::nodes_for`]) and the interconnect model's packing
    /// ([`AllToAllModel::ranks_per_node`]) derive from this one field,
    /// so modeled energy and modeled communication time cannot silently
    /// disagree about how ranks fill nodes.
    pub fn ranks_per_node(&self) -> u32 {
        self.node.cores_per_node
    }

    /// Interconnect model packed with this platform's ranks-per-node —
    /// the sanctioned way to build an [`AllToAllModel`] for a named
    /// platform (preset agreement is asserted in this module's tests).
    pub fn comm_model(&self, link: LinkModel) -> AllToAllModel {
        AllToAllModel::new(link, self.ranks_per_node())
    }

    /// Per-level fabric links for an L-level `tree:` topology: link
    /// level 1 (board-to-board) is `base` unchanged; each tier above
    /// multiplies latency and per-message fabric cost by
    /// `tier_latency_mul` and divides bandwidth by
    /// `tier_bandwidth_div`. Feed the result to
    /// [`AllToAllModel::exchange_time_tree`].
    pub fn tree_links(&self, base: LinkModel, levels: usize) -> Vec<LinkModel> {
        (0..levels)
            .map(|t| {
                let lat = self.tier_latency_mul.powi(t as i32);
                let bw = self.tier_bandwidth_div.powi(t as i32);
                LinkModel {
                    alpha_s: base.alpha_s * lat,
                    beta_bps: base.beta_bps / bw,
                    fabric_msg_cost_s: base.fabric_msg_cost_s * lat,
                    ..base
                }
            })
            .collect()
    }
}

/// Xeon E5-2630 v2 (Ivy Bridge, 2.6 GHz) — the scaling cluster of
/// Figs 1–3 / Table I. Per-core ~1.25× the Westmere anchor
/// (Table I 4-proc computation share vs Table II 4-core row).
pub const XEON_E5_2630V2: CoreModel = WESTMERE.scaled("xeon-e5-2630v2", 1.25);

/// Cortex-A53 @ 1.5 GHz on the Trenz TE0808 (ExaNeSt prototype):
/// "Intel cores are about ten times faster than the ARMs on the Trenz".
pub const TRENZ_A53: CoreModel = XEON_E5_2630V2.scaled("trenz-a53", 0.1);

/// Cortex-A57 @ 2 GHz on the Jetson TX1: "about 5 times faster".
pub const JETSON_A57: CoreModel = XEON_E5_2630V2.scaled("jetson-a57", 0.2);

pub fn xeon_node() -> NodeModel {
    NodeModel {
        name: "xeon-e5",
        core: XEON_E5_2630V2,
        // dual-socket hexa-core E5-2630 v2
        cores_per_node: 12,
        // same server class as the Westmere power testbed
        power_anchors_w: westmere_anchors(),
        idle_draw_frac: 0.8,
    }
}

/// The power-measurement servers (SuperMicro X8DTG-D, X5660+E5620).
pub fn westmere_node() -> NodeModel {
    NodeModel {
        name: "westmere",
        core: WESTMERE,
        cores_per_node: 16,
        power_anchors_w: westmere_anchors(),
        idle_draw_frac: 0.8,
    }
}

fn westmere_anchors() -> Vec<(u32, f64)> {
    // Table II above-baseline readings, computation-dominated rows.
    vec![(1, 48.0), (2, 62.0), (4, 92.0), (8, 124.0), (16, 166.0)]
}

pub fn trenz_node() -> NodeModel {
    NodeModel {
        name: "trenz",
        core: TRENZ_A53,
        cores_per_node: 4,
        // Zynq US+ board: no per-core table in the paper; scaled from the
        // Jetson curve to the Zynq's ~5 W active envelope.
        power_anchors_w: vec![(1, 1.6), (2, 2.6), (4, 4.5)],
        idle_draw_frac: 0.6,
    }
}

pub fn jetson_node() -> NodeModel {
    NodeModel {
        name: "jetson",
        core: JETSON_A57,
        // the paper drives 4 cores per board (8 cores = 2 boards)
        cores_per_node: 4,
        power_anchors_w: vec![(1, 2.2), (2, 3.4), (4, 6.0)],
        idle_draw_frac: 0.6,
    }
}

pub fn platform_by_name(name: &str) -> Result<PlatformModel> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "xeon" | "intel" | "xeon-ib" => PlatformModel {
            name: "xeon",
            node: xeon_node(),
            baseline_w: 564.0,
            default_interconnect: "ib",
            nic_power_scale: 1.0,
            tier_latency_mul: 2.0,
            tier_bandwidth_div: 1.5,
        },
        "xeon-eth" => PlatformModel {
            name: "xeon-eth",
            node: xeon_node(),
            baseline_w: 564.0,
            default_interconnect: "eth1g",
            nic_power_scale: 1.0,
            tier_latency_mul: 2.0,
            tier_bandwidth_div: 1.5,
        },
        "westmere" => PlatformModel {
            name: "westmere",
            node: westmere_node(),
            baseline_w: 564.0,
            default_interconnect: "ib",
            nic_power_scale: 1.0,
            tier_latency_mul: 2.0,
            tier_bandwidth_div: 1.5,
        },
        "westmere-eth" => PlatformModel {
            name: "westmere-eth",
            node: westmere_node(),
            baseline_w: 564.0,
            default_interconnect: "eth1g",
            nic_power_scale: 1.0,
            tier_latency_mul: 2.0,
            tier_bandwidth_div: 1.5,
        },
        "trenz" | "exanest" => PlatformModel {
            name: "trenz",
            node: trenz_node(),
            baseline_w: 20.0,
            default_interconnect: "eth1g",
            nic_power_scale: 0.06,
            // ExaNeSt's unified multi-tier fabric derates gently
            tier_latency_mul: 1.4,
            tier_bandwidth_div: 1.2,
        },
        "jetson" | "arm" => PlatformModel {
            name: "jetson",
            node: jetson_node(),
            baseline_w: 49.2,
            default_interconnect: "eth1g",
            nic_power_scale: 0.06,
            tier_latency_mul: 2.0,
            tier_bandwidth_div: 1.5,
        },
        other => bail!(
            "unknown platform {other:?} \
             (xeon|xeon-eth|westmere|westmere-eth|trenz|jetson)"
        ),
    })
}

pub fn all_names() -> &'static [&'static str] {
    &["xeon", "xeon-eth", "westmere", "westmere-eth", "trenz", "jetson"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speed_ratios_match_paper_statements() {
        // Intel ~10x Trenz, ~5x Jetson (paper §III)
        let intel = XEON_E5_2630V2.speed_vs_westmere();
        let trenz = TRENZ_A53.speed_vs_westmere();
        let jetson = JETSON_A57.speed_vs_westmere();
        assert!((intel / trenz - 10.0).abs() < 0.5);
        assert!((intel / jetson - 5.0).abs() < 0.25);
    }

    #[test]
    fn lookup_all_names() {
        for n in all_names() {
            platform_by_name(n).unwrap();
        }
        assert!(platform_by_name("sparc").is_err());
    }

    #[test]
    fn comm_model_agrees_with_node_packing() {
        // The unification contract: one ranks-per-node per platform —
        // the interconnect model's packing and the power model's node
        // occupancy must agree for every preset.
        for name in all_names() {
            let p = platform_by_name(name).unwrap();
            let link = crate::simnet::presets::interconnect_by_name(p.default_interconnect)
                .unwrap();
            let m = p.comm_model(link);
            assert_eq!(m.ranks_per_node, p.node.cores_per_node, "{name}");
            assert_eq!(m.ranks_per_node, p.ranks_per_node(), "{name}");
            for procs in [1u32, 7, 16, 33, 256] {
                assert_eq!(
                    m.nodes(procs),
                    p.node.nodes_for(procs),
                    "{name}: node counts diverge at {procs} procs"
                );
            }
        }
    }

    #[test]
    fn baselines_match_paper() {
        assert_eq!(platform_by_name("westmere").unwrap().baseline_w, 564.0);
        assert_eq!(platform_by_name("jetson").unwrap().baseline_w, 49.2);
    }

    #[test]
    fn tree_links_derate_per_tier() {
        for name in all_names() {
            let p = platform_by_name(name).unwrap();
            let base =
                crate::simnet::presets::interconnect_by_name(p.default_interconnect).unwrap();
            let links = p.tree_links(base, 3);
            assert_eq!(links.len(), 3);
            // the board tier is the base link untouched
            assert_eq!(links[0].alpha_s, base.alpha_s, "{name}");
            assert_eq!(links[0].beta_bps, base.beta_bps, "{name}");
            // every tier above is strictly slower in latency and
            // no faster in bandwidth
            for t in 1..links.len() {
                assert!(links[t].alpha_s > links[t - 1].alpha_s, "{name} tier {t}");
                assert!(links[t].beta_bps <= links[t - 1].beta_bps, "{name} tier {t}");
                assert!(
                    links[t].fabric_msg_cost_s >= links[t - 1].fabric_msg_cost_s,
                    "{name} tier {t}"
                );
            }
            // the ExaNeSt prototype's unified fabric derates most gently
            let trenz = platform_by_name("trenz").unwrap();
            assert!(trenz.tier_latency_mul <= p.tier_latency_mul, "{name}");
        }
    }
}
