//! Platform models: per-core compute rates and per-node power curves for
//! the paper's three testbeds (Intel Xeon servers, Trenz/ExaNeSt A53
//! boards, NVIDIA Jetson TX1) — the substitution for hardware we do not
//! have (DESIGN.md §2).
//!
//! Calibration uses only *anchor* measurements from the paper (per-core
//! speed ratios from the computation-dominated 1–4-process runs; the
//! power-vs-active-cores curve of Tables II/III); every figure and table
//! is then regenerated from the models.

pub mod cpu;
pub mod node;
pub mod presets;
pub mod hetero;

pub use cpu::CoreModel;
pub use node::NodeModel;
pub use presets::{platform_by_name, PlatformModel};
