//! Heterogeneous partitions: the paper's MPI "heterogeneous mode".
//!
//! The Trenz and Jetson scaling tests embed the ARM partition in a "bath"
//! of Intel processes: one MPI job, distinct executables per architecture,
//! neurons distributed so the (faster) Intel ranks do not slow the ARM
//! ranks down. We model this as a weighted partition: each rank's share of
//! neurons is proportional to its core speed, which equalizes per-step
//! computation time across architectures.

use crate::engine::partition::Partition;

use super::cpu::CoreModel;

/// One architecture group in a heterogeneous job.
#[derive(Debug, Clone)]
pub struct RankGroup {
    pub core: CoreModel,
    pub ranks: u32,
    /// Ranks per node for this group's boards/servers.
    pub ranks_per_node: u32,
}

/// A heterogeneous cluster: ordered groups; ranks are numbered group by
/// group.
#[derive(Debug, Clone)]
pub struct HeteroCluster {
    pub groups: Vec<RankGroup>,
}

impl HeteroCluster {
    pub fn new(groups: Vec<RankGroup>) -> Self {
        assert!(!groups.is_empty());
        Self { groups }
    }

    /// Homogeneous helper.
    pub fn homogeneous(core: CoreModel, ranks: u32, ranks_per_node: u32) -> Self {
        Self::new(vec![RankGroup { core, ranks, ranks_per_node }])
    }

    pub fn total_ranks(&self) -> u32 {
        self.groups.iter().map(|g| g.ranks).sum()
    }

    /// Speed weight of every rank, in rank order.
    pub fn weights(&self) -> Vec<f64> {
        let mut w = Vec::with_capacity(self.total_ranks() as usize);
        for g in &self.groups {
            let s = g.core.speed_vs_westmere();
            w.extend(std::iter::repeat(s).take(g.ranks as usize));
        }
        w
    }

    /// Speed-weighted neuron partition over all ranks.
    pub fn partition(&self, n_neurons: u32) -> Partition {
        Partition::weighted(n_neurons, &self.weights())
    }

    /// The core model of rank `r`.
    pub fn core_of(&self, mut r: u32) -> &CoreModel {
        for g in &self.groups {
            if r < g.ranks {
                return &g.core;
            }
            r -= g.ranks;
        }
        panic!("rank out of range");
    }

    /// Per-step computation time of rank `r` given its share of the
    /// network workload (events already scaled to the rank's neurons).
    pub fn rank_comp_time(
        &self,
        r: u32,
        nrn_updates: f64,
        syn_events: f64,
        ext_events: f64,
    ) -> f64 {
        self.core_of(r).comp_time(nrn_updates, syn_events, ext_events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::presets::{JETSON_A57, TRENZ_A53, XEON_E5_2630V2};

    #[test]
    fn weighted_partition_equalizes_comp_time() {
        // 4 ARM + 4 Intel ranks over 22k neurons: Intel ranks get ~10x
        // the neurons, so per-rank comp time is ~equal.
        let hc = HeteroCluster::new(vec![
            RankGroup { core: TRENZ_A53, ranks: 4, ranks_per_node: 4 },
            RankGroup { core: XEON_E5_2630V2, ranks: 4, ranks_per_node: 16 },
        ]);
        let part = hc.partition(22_000);
        let sizes = part.sizes();
        let arm_mean: f64 = sizes[..4].iter().map(|&s| s as f64).sum::<f64>() / 4.0;
        let intel_mean: f64 = sizes[4..].iter().map(|&s| s as f64).sum::<f64>() / 4.0;
        assert!(
            (intel_mean / arm_mean - 10.0).abs() < 1.0,
            "arm {arm_mean} intel {intel_mean}"
        );
        // comp time per rank within 25% of each other
        let t = |r: u32| {
            let share = part.size(r) as f64;
            hc.rank_comp_time(r, share, share * 1125.0 * 0.0032, share * 1.2)
        };
        let t_arm = t(0);
        let t_intel = t(4);
        assert!(
            (t_arm / t_intel - 1.0).abs() < 0.25,
            "arm {t_arm} intel {t_intel}"
        );
    }

    #[test]
    fn core_of_maps_groups() {
        let hc = HeteroCluster::new(vec![
            RankGroup { core: JETSON_A57, ranks: 2, ranks_per_node: 4 },
            RankGroup { core: XEON_E5_2630V2, ranks: 3, ranks_per_node: 16 },
        ]);
        assert_eq!(hc.core_of(0).name, "jetson-a57");
        assert_eq!(hc.core_of(1).name, "jetson-a57");
        assert_eq!(hc.core_of(2).name, "xeon-e5-2630v2");
        assert_eq!(hc.core_of(4).name, "xeon-e5-2630v2");
        assert_eq!(hc.total_ranks(), 5);
    }
}
