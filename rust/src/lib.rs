//! # dpsnn — Distributed Plastic Spiking Neural Network, real-time study
//!
//! A Rust + JAX + Pallas reproduction of *"Real-time cortical simulations:
//! energy and interconnect scaling on distributed systems"* (Simula et al.,
//! EMPDP 2019, DOI 10.1109/EMPDP.2019.8671627).
//!
//! The crate rebuilds the paper's DPSNN mini-application benchmark and the
//! measurement substrate around it:
//!
//! * [`model`] — LIF neurons with Spike-Frequency Adaptation, seeded
//!   partition-independent connectivity, Poisson external stimulus.
//! * [`engine`] — the per-rank simulation engine: delay rings, the 1 ms
//!   hybrid event/time-driven step.
//! * [`comm`] — AER spike wire format (12 B/spike), message packing, the
//!   all-to-all transport and barrier used by live runs, and
//!   destination-filtered spike routing: because connectivity is a pure
//!   function of `(seed, source, k)`, each rank precomputes which
//!   destination ranks its neurons project to and sends a spike only
//!   where a postsynaptic target lives. The filter degenerates to
//!   broadcast under dense connectivity at small P (`M >> P` puts a
//!   target on every rank) but always removes the transport loopback,
//!   and at large P or sparse connectivity it removes whole rank pairs
//!   — while keeping the spike raster bitwise identical for every
//!   process count. Orthogonally, the exchange *cadence*
//!   ([`config::ExchangeCadence`]) batches up to `delay_min_steps`
//!   steps of spikes into one collective — a spike emitted at step `t`
//!   cannot act before `t + delay_min_steps`, so the per-message
//!   latency is amortized over the whole window and the raster is
//!   again bitwise identical. A third orthogonal axis, the transport
//!   *topology* ([`config::Topology`]), groups ranks into an L-level
//!   tree of boards, chassis and racks whose per-group leaders
//!   aggregate all boundary-crossing traffic into one source-tagged
//!   message per sibling-group pair at every tier (`comm::hier`),
//!   collapsing the fabric message count from `P(P−1)` to
//!   `N(N−1)`-per-tier per exchange — again with a bitwise-identical
//!   raster, under either leader-rotation policy
//!   ([`config::LeaderRotation`]).
//! * [`simnet`] — interconnect models (InfiniBand, Ethernet, GbE) used by
//!   the modeled/timing mode.
//! * [`platform`] — CPU/node models of the paper's three testbeds
//!   (Intel Xeon, Trenz/ExaNeSt A53, Jetson TX1).
//! * [`power`] — power model + simulated multimeter, energy-to-solution.
//! * [`timing`] — discrete-event replay producing wall-clock and
//!   comp/comm/barrier decompositions on modeled platforms.
//! * [`runtime`] — PJRT runtime loading AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); Python never runs at simulation time.
//! * [`coordinator`] — run orchestration: live multi-threaded runs and
//!   modeled platform replays.
//! * [`harness`] — one reproduction harness per paper figure/table.

pub mod config;
pub mod util;
pub mod model;
pub mod engine;
pub mod comm;
pub mod simnet;
pub mod platform;
pub mod power;
pub mod profiling;
pub mod timing;
pub mod trace;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod metrics;
pub mod stats;
