//! Two-level hierarchical transport: node-leader aggregation for the
//! inter-node spike exchange (`--topology nodes:<k>`).
//!
//! The flat [`super::local::LocalCluster`] puts every rank pair on the
//! same mailbox fabric, so one exchange costs `P(P−1)` messages — the
//! quadratic cliff the paper's latency wall is made of. Real systems
//! dodge it with the fabric's hierarchy: ranks sharing a node exchange
//! through shared memory, and only node *leaders* talk across the
//! network, concatenating their node's traffic into one message per node
//! pair (SpiNNaker's multicast tree, NEST's node-local exchange). This
//! transport reproduces that protocol in-process, per exchange:
//!
//! 1. **intra-node** — each rank posts its payload for same-node peers
//!    straight into the shared mailbox matrix (one hop, as before);
//! 2. **gather** — each non-leader frames its whole off-node payload as
//!    `(dst: u32, len: u32, bytes)` runs and posts ONE blob to its node
//!    leader (leaders frame their own payload in place);
//! 3. **aggregate + exchange** — each leader re-frames the node's blobs
//!    as `(src: u32, dst: u32, len: u32, bytes)` runs, binned per
//!    destination node, and posts ONE aggregated message per other node:
//!    `N(N−1)` fabric messages instead of `P(P−1)`;
//! 4. **scatter** — each leader unpacks the aggregated messages
//!    addressed to its node into the `(src, dst)` mailbox slots.
//!
//! Because the source tag travels with every sub-buffer, the collected
//! incoming column is byte-identical to the flat transport's — same
//! buffers, same source indexing — so the coordinator's source-ordered
//! delivery (and therefore the spike raster) is bitwise unchanged.
//! Message/byte accounting per rank is specified on
//! [`ExchangeStats`](super::transport::ExchangeStats); summed over ranks
//! it equals [`NodeMap::total_messages_per_exchange`] exactly.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::barrier::SenseBarrier;
use super::topology::NodeMap;
use super::transport::{ExchangeStats, Transport};

/// Framing bytes per destination run in a gather blob (`dst` + `len`).
pub const GATHER_FRAME_BYTES: usize = 8;

/// Framing bytes per (src, dst) sub-buffer in an aggregated inter-node
/// message (`src` + `dst` + `len`).
pub const HIER_FRAME_BYTES: usize = 12;

/// Shared state for one simulated cluster of `p` ranks grouped into
/// virtual nodes of `ranks_per_node`.
pub struct HierCluster {
    map: NodeMap,
    /// mailbox[src][dst]: final (source → destination) payloads — the
    /// same matrix the flat transport uses, but inter-node slots are
    /// filled by the destination node's leader during scatter.
    mailboxes: Vec<Vec<Mutex<Vec<u8>>>>,
    /// gather[src]: the framed off-node payload rank `src` posted for
    /// its node leader this exchange.
    gather: Vec<Mutex<Vec<u8>>>,
    /// internode[src_node][dst_node]: the aggregated node-pair message.
    internode: Vec<Vec<Mutex<Vec<u8>>>>,
    barrier: SenseBarrier,
}

impl HierCluster {
    pub fn new(p: u32, ranks_per_node: u32) -> Arc<Self> {
        let map = NodeMap::new(p, ranks_per_node);
        let n = map.n_nodes();
        Arc::new(Self {
            map,
            mailboxes: (0..p)
                .map(|_| (0..p).map(|_| Mutex::new(Vec::new())).collect())
                .collect(),
            gather: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
            internode: (0..n)
                .map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect())
                .collect(),
            barrier: SenseBarrier::new(p),
        })
    }

    pub fn node_map(&self) -> &NodeMap {
        &self.map
    }

    /// Post `payload` into the `(src, dst)` mailbox slot.
    fn post(&self, src: u32, dst: u32, payload: &[u8]) {
        let mut slot = self.mailboxes[src as usize][dst as usize].lock().unwrap();
        slot.clear();
        slot.extend_from_slice(payload);
    }

    /// Leader only: merge the node's gather blobs into one aggregated
    /// message per other node and post them. Returns (messages, bytes).
    fn aggregate_and_send(&self, my_node: u32) -> (u64, u64) {
        let n = self.map.n_nodes() as usize;
        let mut bins: Vec<Vec<u8>> = vec![Vec::new(); n];
        for src in self.map.ranks_of(my_node) {
            let blob = self.gather[src as usize].lock().unwrap();
            let mut off = 0usize;
            while off < blob.len() {
                let dst = u32::from_le_bytes(blob[off..off + 4].try_into().unwrap());
                let len = u32::from_le_bytes(blob[off + 4..off + 8].try_into().unwrap()) as usize;
                off += GATHER_FRAME_BYTES;
                let bin = &mut bins[self.map.node_of(dst) as usize];
                bin.extend_from_slice(&src.to_le_bytes());
                bin.extend_from_slice(&dst.to_le_bytes());
                bin.extend_from_slice(&(len as u32).to_le_bytes());
                bin.extend_from_slice(&blob[off..off + len]);
                off += len;
            }
        }
        let (mut msgs, mut bytes) = (0u64, 0u64);
        for (node, bin) in bins.iter_mut().enumerate() {
            if node as u32 == my_node {
                debug_assert!(bin.is_empty(), "gather blobs hold off-node runs only");
                continue;
            }
            msgs += 1;
            bytes += bin.len() as u64;
            *self.internode[my_node as usize][node].lock().unwrap() = std::mem::take(bin);
        }
        (msgs, bytes)
    }

    /// Leader only: unpack the aggregated messages addressed to this
    /// node into the `(src, dst)` mailbox slots.
    fn scatter(&self, my_node: u32) {
        for src_node in 0..self.map.n_nodes() {
            if src_node == my_node {
                continue;
            }
            let msg = std::mem::take(
                &mut *self.internode[src_node as usize][my_node as usize].lock().unwrap(),
            );
            let mut off = 0usize;
            while off < msg.len() {
                let src = u32::from_le_bytes(msg[off..off + 4].try_into().unwrap());
                let dst = u32::from_le_bytes(msg[off + 4..off + 8].try_into().unwrap());
                let len = u32::from_le_bytes(msg[off + 8..off + 12].try_into().unwrap()) as usize;
                off += HIER_FRAME_BYTES;
                debug_assert_eq!(self.map.node_of(src), src_node);
                debug_assert_eq!(self.map.node_of(dst), my_node);
                self.post(src, dst, &msg[off..off + len]);
                off += len;
            }
        }
    }
}

impl Transport for Arc<HierCluster> {
    fn n_ranks(&self) -> u32 {
        self.map.n_ranks()
    }

    fn alltoall(
        &self,
        rank: u32,
        outgoing: &[Vec<u8>],
    ) -> Result<(Vec<Vec<u8>>, ExchangeStats)> {
        let p = self.map.n_ranks();
        assert_eq!(outgoing.len() as u32, p, "need one buffer per rank");
        let my_node = self.map.node_of(rank);
        let n_nodes = self.map.n_nodes();
        let mut stats = ExchangeStats {
            per_dst_bytes: outgoing.iter().map(|b| b.len() as u64).collect(),
            ..ExchangeStats::default()
        };

        // Phase 1a: loopback + direct intra-node posts.
        self.post(rank, rank, &outgoing[rank as usize]);
        for dst in self.map.ranks_of(my_node) {
            if dst == rank {
                continue;
            }
            let payload = &outgoing[dst as usize];
            self.post(rank, dst, payload);
            stats.bytes_sent += payload.len() as u64;
            stats.intra_messages += 1;
            stats.intra_bytes += payload.len() as u64;
        }
        // Phase 1b: frame the off-node payload as one gather blob. Every
        // off-node destination gets a run (envelopes are transmitted even
        // when empty, like the flat transport's P−1 messages). Leaders
        // frame in place; non-leaders pay one intra-node gather message.
        if n_nodes > 1 {
            let mut blob = Vec::new();
            for dst in 0..p {
                if self.map.node_of(dst) == my_node {
                    continue;
                }
                let payload = &outgoing[dst as usize];
                blob.extend_from_slice(&dst.to_le_bytes());
                blob.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                blob.extend_from_slice(payload);
            }
            if !self.map.is_leader(rank) {
                stats.bytes_sent += blob.len() as u64;
                stats.intra_messages += 1;
                stats.intra_bytes += blob.len() as u64;
            }
            *self.gather[rank as usize].lock().unwrap() = blob;
        }
        self.barrier.wait();

        if n_nodes > 1 {
            // Phase 2: leaders aggregate the node's blobs into one
            // framed message per other node — the N(N−1) fabric hop.
            if self.map.is_leader(rank) {
                let (msgs, bytes) = self.aggregate_and_send(my_node);
                stats.inter_messages += msgs;
                stats.inter_bytes += bytes;
                stats.bytes_sent += bytes;
            }
            self.barrier.wait();
            // Phase 3: leaders scatter the incoming aggregates into the
            // (src, dst) mailbox slots of their node.
            if self.map.is_leader(rank) {
                self.scatter(my_node);
            }
            self.barrier.wait();
        }
        stats.messages = stats.intra_messages + stats.inter_messages;

        // Phase 4: collect the column addressed to this rank — identical
        // in content and source indexing to the flat transport's.
        let mut incoming = Vec::with_capacity(p as usize);
        for src in 0..p as usize {
            let mut slot = self.mailboxes[src][rank as usize].lock().unwrap();
            incoming.push(std::mem::take(&mut *slot));
        }
        stats.bytes_recv = incoming.iter().map(|b| b.len() as u64).sum();
        // Phase 5: everyone must finish reading before the next post.
        self.barrier.wait();
        Ok((incoming, stats))
    }

    fn barrier(&self, _rank: u32) {
        self.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive one exchange round on `p` threads with
    /// `payload(src, dst)` buffers and return the per-rank stats after
    /// asserting every rank received exactly `payload(src, rank)`.
    fn exchange_round(
        p: u32,
        ranks_per_node: u32,
        rounds: u32,
        payload: fn(u32, u32, u32) -> Vec<u8>,
    ) -> Vec<ExchangeStats> {
        let cluster = HierCluster::new(p, ranks_per_node);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || -> ExchangeStats {
                let mut last = ExchangeStats::default();
                for round in 0..rounds {
                    let outgoing: Vec<Vec<u8>> =
                        (0..p).map(|dst| payload(rank, dst, round)).collect();
                    let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                    for (src, buf) in incoming.iter().enumerate() {
                        assert_eq!(
                            buf,
                            &payload(src as u32, rank, round),
                            "rank {rank} from {src} round {round}"
                        );
                    }
                    last = stats;
                }
                last
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn tagged(src: u32, dst: u32, round: u32) -> Vec<u8> {
        format!("r{src}->d{dst}@{round}").into_bytes()
    }

    #[test]
    fn routes_every_pair_across_nodes() {
        // 6 ranks on 3 nodes of 2: multi-node, leaders and followers.
        let stats = exchange_round(6, 2, 20, tagged);
        for (rank, s) in stats.iter().enumerate() {
            let leader = rank % 2 == 0;
            // 1 direct intra post + (gather | 2 aggregated messages)
            assert_eq!(s.intra_messages, if leader { 1 } else { 2 }, "rank {rank}");
            assert_eq!(s.inter_messages, if leader { 2 } else { 0 }, "rank {rank}");
            assert_eq!(s.messages, 3, "rank {rank}");
        }
    }

    #[test]
    fn ragged_last_node_routes_correctly() {
        // 5 ranks on nodes of 2 -> sizes (2, 2, 1); rank 4 is a solo
        // leader with no intra-node peers.
        let stats = exchange_round(5, 2, 10, tagged);
        assert_eq!(stats[4].intra_messages, 0);
        assert_eq!(stats[4].inter_messages, 2);
        assert_eq!(stats[1].intra_messages, 2, "direct post + gather");
        assert_eq!(stats[1].inter_messages, 0);
    }

    #[test]
    fn single_node_degenerates_to_flat_intra_exchange() {
        let stats = exchange_round(4, 8, 5, tagged);
        for s in &stats {
            assert_eq!(s.intra_messages, 3);
            assert_eq!(s.inter_messages, 0);
            assert_eq!(s.messages, 3);
            assert_eq!(s.intra_bytes, s.bytes_sent);
        }
    }

    #[test]
    fn message_accounting_matches_closed_form() {
        // The satellite contract: summed over ranks, one exchange's
        // message count equals NodeMap's closed form for every (P, k) —
        // even splits, ragged splits, solo nodes, single node.
        for &(p, k) in &[(1u32, 1u32), (2, 1), (4, 2), (6, 4), (8, 3), (8, 4), (9, 4), (5, 8)] {
            let stats = exchange_round(p, k, 2, |s, d, _| vec![s as u8, d as u8]);
            let map = NodeMap::new(p, k);
            let total: u64 = stats.iter().map(|s| s.messages).sum();
            assert_eq!(total, map.total_messages_per_exchange(), "p={p} k={k}");
            let inter: u64 = stats.iter().map(|s| s.inter_messages).sum();
            let expect_inter = if map.n_nodes() > 1 {
                map.inter_messages_per_exchange()
            } else {
                0
            };
            assert_eq!(inter, expect_inter, "p={p} k={k}");
            for s in &stats {
                assert_eq!(s.messages, s.intra_messages + s.inter_messages);
            }
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        // 4 ranks, 2 nodes of 2, every payload exactly 3 bytes.
        let stats = exchange_round(4, 2, 3, |s, d, _| vec![s as u8, d as u8, 0]);
        for (rank, s) in stats.iter().enumerate() {
            // everyone receives 4 payloads of 3 bytes (loopback included)
            assert_eq!(s.bytes_recv, 12, "rank {rank}");
            assert_eq!(s.per_dst_bytes, vec![3, 3, 3, 3]);
            // direct intra post: 3 B. Gather blob: 2 off-node runs of
            // (8 B frame + 3 B payload) = 22 B.
            let blob = 2 * (GATHER_FRAME_BYTES as u64 + 3);
            if rank % 2 == 0 {
                // leader: 3 B intra + one aggregated message of 4
                // (src,dst) sub-buffers: 4 * (12 B frame + 3 B) = 60 B
                let aggregate = 4 * (HIER_FRAME_BYTES as u64 + 3);
                assert_eq!(s.intra_bytes, 3, "rank {rank}");
                assert_eq!(s.inter_bytes, aggregate, "rank {rank}");
                assert_eq!(s.bytes_sent, 3 + aggregate, "rank {rank}");
            } else {
                assert_eq!(s.intra_bytes, 3 + blob, "rank {rank}");
                assert_eq!(s.inter_bytes, 0, "rank {rank}");
                assert_eq!(s.bytes_sent, 3 + blob, "rank {rank}");
            }
        }
    }

    #[test]
    fn empty_payloads_still_synchronize() {
        let stats = exchange_round(6, 3, 4, |_, _, _| Vec::new());
        for s in &stats {
            assert_eq!(s.bytes_recv, 0);
            // envelopes still move: framing bytes on gather/aggregate
            assert!(s.messages > 0);
        }
    }

    #[test]
    fn single_rank_round_trips() {
        let cluster = HierCluster::new(1, 4);
        let (incoming, stats) = cluster.alltoall(0, &[b"self".to_vec()]).unwrap();
        assert_eq!(incoming[0], b"self");
        assert_eq!(stats.messages, 0);
        assert_eq!(stats.bytes_sent, 0);
        assert_eq!(stats.bytes_recv, 4);
    }
}
