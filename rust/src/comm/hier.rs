//! L-level hierarchical transport: leader aggregation at every tier of
//! the fabric (`--topology tree:<k1>,<k2>,...`; `nodes:<k>` is sugar
//! for the one-level tree).
//!
//! The flat [`super::local::LocalCluster`] puts every rank pair on the
//! same mailbox fabric, so one exchange costs `P(P−1)` messages — the
//! quadratic cliff the paper's latency wall is made of. Real systems
//! dodge it with the fabric's hierarchy: the paper's ExaNeSt/EuroExa
//! context is explicitly multi-tier (board → chassis → rack), and where
//! a message crosses the hierarchy determines its latency and Joule
//! cost. This transport reproduces the tiered protocol in-process over
//! a [`TopologyTree`], per exchange:
//!
//! 1. **intra-board** — each rank posts its payload for same-board
//!    peers straight into the shared mailbox matrix (one hop);
//! 2. **gather** — each rank frames its whole off-board payload as
//!    `(dst: u32, len: u32, bytes)` runs and posts ONE blob to its
//!    board leader (the leader frames its own payload in place);
//! 3. **aggregate upward, level by level** — each level-`g` group
//!    leader re-frames its group's outward traffic as
//!    `(src: u32, dst: u32, len: u32, bytes)` runs, posts ONE
//!    aggregated message to each *sibling* level-`g` group's leader
//!    (sibling = same level-`g+1` parent), and forwards everything
//!    that must travel beyond the parent as ONE blob to the parent's
//!    leader — so a rack pair exchanges ONE message regardless of how
//!    many ranks it contains;
//! 4. **scatter downward** — each leader unpacks the aggregated
//!    messages addressed into its subtree, forwarding per-child blobs
//!    down to the child leaders until board leaders post the
//!    `(src, dst)` mailbox slots.
//!
//! Because the source tag travels with every sub-buffer, the collected
//! incoming column is byte-identical to the flat transport's — same
//! buffers, same source indexing — so the coordinator's source-ordered
//! delivery (and therefore the spike raster) is bitwise unchanged, for
//! every tree shape and leader-rotation policy.
//!
//! **Leadership** is decided per exchange by the
//! [`LeaderRotation`](crate::config::LeaderRotation) policy: `fixed`
//! pins each group's first rank, `round-robin` walks leadership through
//! the group so the aggregation CPU cost is not pinned to rank 0 of
//! each group. Rotation changes *who* relays, never *what* travels:
//! message counts per link level, summed over ranks, equal
//! [`TopologyTree::messages_at_level`] exactly under either policy
//! (per-rank attribution shifts with the rotation, as intended).
//! Message/byte accounting per rank is specified on
//! [`ExchangeStats`](super::transport::ExchangeStats).

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::LeaderRotation;

use super::barrier::SenseBarrier;
use super::topology::TopologyTree;
use super::transport::{ExchangeStats, Transport};

/// Framing bytes per destination run in a rank's gather blob
/// (`dst` + `len`; the source is the posting rank).
pub const GATHER_FRAME_BYTES: usize = 8;

/// Framing bytes per (src, dst) sub-buffer in an aggregated message
/// (`src` + `dst` + `len`).
pub const HIER_FRAME_BYTES: usize = 12;

/// One parsed `(src, dst, payload)` run inside an aggregated blob.
struct Run<'a> {
    src: u32,
    dst: u32,
    payload: &'a [u8],
}

/// Iterate the `(dst, len)`-framed runs of a rank's gather blob (the
/// source is the posting rank).
fn each_gather_run<'a>(src: u32, blob: &'a [u8], mut f: impl FnMut(Run<'a>)) {
    let mut off = 0usize;
    while off < blob.len() {
        let dst = u32::from_le_bytes(blob[off..off + 4].try_into().unwrap());
        let len = u32::from_le_bytes(blob[off + 4..off + 8].try_into().unwrap()) as usize;
        off += GATHER_FRAME_BYTES;
        f(Run {
            src,
            dst,
            payload: &blob[off..off + len],
        });
        off += len;
    }
}

/// Iterate the `(src, dst, len)`-framed runs of an aggregated blob.
fn each_run<'a>(blob: &'a [u8], mut f: impl FnMut(Run<'a>)) {
    let mut off = 0usize;
    while off < blob.len() {
        let src = u32::from_le_bytes(blob[off..off + 4].try_into().unwrap());
        let dst = u32::from_le_bytes(blob[off + 4..off + 8].try_into().unwrap());
        let len = u32::from_le_bytes(blob[off + 8..off + 12].try_into().unwrap()) as usize;
        off += HIER_FRAME_BYTES;
        f(Run {
            src,
            dst,
            payload: &blob[off..off + len],
        });
        off += len;
    }
}

/// Wire encoding of the rotation policy in the cluster's atomic cell.
fn rotation_code(rotation: LeaderRotation) -> u8 {
    match rotation {
        LeaderRotation::Fixed => 0,
        LeaderRotation::RoundRobin => 1,
    }
}

/// Append one `(src, dst, len, payload)` run to an aggregated blob.
fn push_run(bin: &mut Vec<u8>, src: u32, dst: u32, payload: &[u8]) {
    bin.extend_from_slice(&src.to_le_bytes());
    bin.extend_from_slice(&dst.to_le_bytes());
    bin.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    bin.extend_from_slice(payload);
}

/// Shared state for one simulated cluster of `p` ranks grouped into an
/// L-level topology tree.
pub struct HierCluster {
    tree: TopologyTree,
    /// Leader-rotation policy in force, swappable between exchanges
    /// ([`Transport::set_rotation`]): the self-tuning runtime flips it
    /// at window boundaries where every rank stores the same value, so
    /// the relaxed atomic is only ever raced by identical writes.
    rotation: AtomicU8,
    /// mailbox[src][dst]: final (source → destination) payloads — the
    /// same matrix the flat transport uses, but cross-board slots are
    /// filled by the destination board's leader during scatter.
    mailboxes: Vec<Vec<Mutex<Vec<u8>>>>,
    /// gather0[rank]: the `(dst, len)`-framed off-board payload each
    /// rank posted for its board leader this exchange.
    gather0: Vec<Mutex<Vec<u8>>>,
    /// pair[g-1][src_group][dst_group]: the aggregated message between
    /// sibling level-`g` groups, for `g` in `1..=L`.
    pair: Vec<Vec<Vec<Mutex<Vec<u8>>>>>,
    /// up[g-1][group]: the blob a level-`g` group leader forwards to
    /// its level-`g+1` leader (traffic beyond the parent), `g` in
    /// `1..L`.
    up: Vec<Vec<Mutex<Vec<u8>>>>,
    /// down[g-1][group]: the entries addressed into level-`g` `group`
    /// that its level-`g+1` leader forwarded down, `g` in `1..L`.
    down: Vec<Vec<Mutex<Vec<u8>>>>,
    /// Per-rank exchange counters driving the leader rotation; all
    /// ranks advance in lockstep (one call per collective), so every
    /// rank derives the same leaders for a given exchange.
    counters: Vec<AtomicU64>,
    barrier: SenseBarrier,
}

impl HierCluster {
    /// Two-level node-leader cluster (`--topology nodes:<k>`) with
    /// fixed leaders — sugar for the one-level tree.
    pub fn new(p: u32, ranks_per_node: u32) -> Arc<Self> {
        Self::with_tree(p, &[ranks_per_node], LeaderRotation::Fixed)
    }

    /// L-level cluster over the given tree shape and rotation policy.
    pub fn with_tree(p: u32, shape: &[u32], rotation: LeaderRotation) -> Arc<Self> {
        let tree = TopologyTree::new(p, shape);
        let depth = tree.depth();
        let pair = (1..=depth)
            .map(|g| {
                let n = tree.n_groups(g) as usize;
                (0..n)
                    .map(|_| (0..n).map(|_| Mutex::new(Vec::new())).collect())
                    .collect()
            })
            .collect();
        let leader_slots = |g: usize| -> Vec<Mutex<Vec<u8>>> {
            (0..tree.n_groups(g)).map(|_| Mutex::new(Vec::new())).collect()
        };
        let up = (1..depth).map(leader_slots).collect();
        let down = (1..depth).map(leader_slots).collect();
        Arc::new(Self {
            tree,
            rotation: AtomicU8::new(rotation_code(rotation)),
            mailboxes: (0..p)
                .map(|_| (0..p).map(|_| Mutex::new(Vec::new())).collect())
                .collect(),
            gather0: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
            pair,
            up,
            down,
            counters: (0..p).map(|_| AtomicU64::new(0)).collect(),
            barrier: SenseBarrier::new(p),
        })
    }

    pub fn topology_tree(&self) -> &TopologyTree {
        &self.tree
    }

    /// The rotation policy in force for the next exchange.
    pub fn rotation(&self) -> LeaderRotation {
        match self.rotation.load(Ordering::Relaxed) {
            0 => LeaderRotation::Fixed,
            _ => LeaderRotation::RoundRobin,
        }
    }

    /// Post `payload` into the `(src, dst)` mailbox slot.
    fn post(&self, src: u32, dst: u32, payload: &[u8]) {
        let mut slot = self.mailboxes[src as usize][dst as usize].lock().unwrap();
        slot.clear();
        slot.extend_from_slice(payload);
    }

    /// Sibling level-`g` groups of `group` (its level-`g+1` parent's
    /// children; the whole tier for `g = L`), `group` included.
    fn siblings_of(&self, group: u32, g: usize) -> std::ops::Range<u32> {
        if g == self.tree.depth() {
            0..self.tree.n_groups(g)
        } else {
            self.tree.children_of(self.tree.parent_of(group, g), g + 1)
        }
    }

    /// Upward phase `g`: the leader of each level-`g` group merges its
    /// children's blobs, posts ONE aggregated message per sibling group
    /// and forwards the beyond-parent remainder up. Counts the posted
    /// messages/bytes on link level `g` into `stats`.
    fn aggregate_up(&self, rank: u32, g: usize, exchange: u64, stats: &mut ExchangeStats) {
        let tree = &self.tree;
        let depth = tree.depth();
        let rotation = self.rotation();
        if tree.n_groups(g) <= 1 || !tree.is_leader(rank, g, rotation, exchange) {
            return;
        }
        let gidx = tree.group_of(rank, g);
        // Stream the children's blobs straight into the destination
        // bins (sibling pairs) or the up blob (beyond the parent) —
        // one parse, one copy, no intermediate run list on the hot
        // exchange path.
        let mut bins: Vec<Vec<u8>> = vec![Vec::new(); tree.n_groups(g) as usize];
        let mut up_bin: Vec<u8> = Vec::new();
        {
            let mut route = |src: u32, dst: u32, payload: &[u8]| {
                let dg = tree.group_of(dst, g);
                debug_assert_ne!(dg, gidx, "upward runs must leave the group");
                let sibling =
                    g == depth || tree.parent_of(dg, g) == tree.parent_of(gidx, g);
                if sibling {
                    push_run(&mut bins[dg as usize], src, dst, payload);
                } else {
                    push_run(&mut up_bin, src, dst, payload);
                }
            };
            if g == 1 {
                for m in tree.ranks_of(gidx, 1) {
                    let blob =
                        std::mem::take(&mut *self.gather0[m as usize].lock().unwrap());
                    each_gather_run(m, &blob, |r| route(r.src, r.dst, r.payload));
                }
            } else {
                for c in tree.children_of(gidx, g) {
                    let blob =
                        std::mem::take(&mut *self.up[g - 2][c as usize].lock().unwrap());
                    each_run(&blob, |r| route(r.src, r.dst, r.payload));
                }
            }
        }
        // ONE aggregated message per ordered sibling pair — envelopes
        // travel even when empty, like every synchronous collective.
        for d in self.siblings_of(gidx, g) {
            if d == gidx {
                continue;
            }
            let bin = std::mem::take(&mut bins[d as usize]);
            stats.level_messages[g] += 1;
            stats.level_bytes[g] += bin.len() as u64;
            *self.pair[g - 1][gidx as usize][d as usize].lock().unwrap() = bin;
        }
        // Forward the beyond-parent remainder to the parent's leader
        // (kept in place, uncounted, when this rank leads the parent
        // too — the same "frames in place" rule the rank gather uses).
        if g < depth && tree.n_groups(g + 1) > 1 {
            if !tree.is_leader(rank, g + 1, rotation, exchange) {
                stats.level_messages[g] += 1;
                stats.level_bytes[g] += up_bin.len() as u64;
            }
            *self.up[g - 1][gidx as usize].lock().unwrap() = up_bin;
        } else {
            debug_assert!(up_bin.is_empty(), "no tier above to route to");
        }
    }

    /// Downward phase `g`: the leader of each level-`g` group unpacks
    /// the sibling pair messages (plus whatever its parent forwarded
    /// down) and pushes each run one hop closer to its destination.
    /// Scatter hops are not accounted as messages (see
    /// [`TopologyTree`]).
    fn scatter_down(&self, rank: u32, g: usize, exchange: u64) {
        let tree = &self.tree;
        let depth = tree.depth();
        if tree.n_groups(g) <= 1 || !tree.is_leader(rank, g, self.rotation(), exchange) {
            return;
        }
        let gidx = tree.group_of(rank, g);
        let mut blobs: Vec<Vec<u8>> = Vec::new();
        for s in self.siblings_of(gidx, g) {
            if s == gidx {
                continue;
            }
            blobs.push(std::mem::take(
                &mut *self.pair[g - 1][s as usize][gidx as usize].lock().unwrap(),
            ));
        }
        if g < depth && tree.n_groups(g + 1) > 1 {
            blobs.push(std::mem::take(
                &mut *self.down[g - 1][gidx as usize].lock().unwrap(),
            ));
        }
        if g == 1 {
            // Final hop: the board leader fills the (src, dst) mailbox
            // slots of its board.
            for blob in &blobs {
                each_run(blob, |r| {
                    debug_assert_eq!(tree.group_of(r.dst, 1), gidx);
                    self.post(r.src, r.dst, r.payload);
                });
            }
        } else {
            let children = tree.children_of(gidx, g);
            let base = children.start as usize;
            let mut down_bins: Vec<Vec<u8>> =
                vec![Vec::new(); (children.end - children.start) as usize];
            for blob in &blobs {
                each_run(blob, |r| {
                    let child = tree.group_of(r.dst, g - 1);
                    debug_assert_eq!(tree.group_of(r.dst, g), gidx);
                    push_run(&mut down_bins[child as usize - base], r.src, r.dst, r.payload);
                });
            }
            // Write every child slot (even empty) so no stale blob from
            // a previous exchange survives.
            for (i, bin) in down_bins.iter_mut().enumerate() {
                *self.down[g - 2][base + i].lock().unwrap() = std::mem::take(bin);
            }
        }
    }
}

impl Transport for Arc<HierCluster> {
    fn n_ranks(&self) -> u32 {
        self.tree.n_ranks()
    }

    fn alltoall(
        &self,
        rank: u32,
        outgoing: &[Vec<u8>],
    ) -> Result<(Vec<Vec<u8>>, ExchangeStats)> {
        let tree = &self.tree;
        let p = tree.n_ranks();
        assert_eq!(outgoing.len() as u32, p, "need one buffer per rank");
        let depth = tree.depth();
        let exchange = self.counters[rank as usize].fetch_add(1, Ordering::Relaxed);
        let my_board = tree.group_of(rank, 1);
        let n_boards = tree.n_groups(1);
        let mut stats = ExchangeStats {
            per_dst_bytes: outgoing.iter().map(|b| b.len() as u64).collect(),
            level_messages: vec![0; depth + 1],
            level_bytes: vec![0; depth + 1],
            ..ExchangeStats::default()
        };

        // Phase 0a: loopback + direct intra-board posts (link level 0).
        self.post(rank, rank, &outgoing[rank as usize]);
        for dst in tree.ranks_of(my_board, 1) {
            if dst == rank {
                continue;
            }
            let payload = &outgoing[dst as usize];
            self.post(rank, dst, payload);
            stats.level_messages[0] += 1;
            stats.level_bytes[0] += payload.len() as u64;
        }
        // Phase 0b: frame the whole off-board payload as one gather
        // blob. Every off-board destination gets a run (envelopes are
        // transmitted even when empty, like the flat transport's P−1
        // messages). The board leader frames in place; everyone else
        // pays one board-local gather message.
        if n_boards > 1 {
            let mut blob = Vec::new();
            for dst in 0..p {
                if tree.group_of(dst, 1) == my_board {
                    continue;
                }
                let payload = &outgoing[dst as usize];
                blob.extend_from_slice(&dst.to_le_bytes());
                blob.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                blob.extend_from_slice(payload);
            }
            if !tree.is_leader(rank, 1, self.rotation(), exchange) {
                stats.level_messages[0] += 1;
                stats.level_bytes[0] += blob.len() as u64;
            }
            *self.gather0[rank as usize].lock().unwrap() = blob;
        }
        self.barrier.wait();

        // Group counts are non-increasing with level, so the levels
        // with more than one group (the only ones whose phases do any
        // work) form a prefix. Skip the degenerate upper tiers AND
        // their barriers — `active` is a pure function of (p, shape),
        // identical on every rank, so the barrier sequence still
        // matches. A single-board cluster does no up/down phase at
        // all, exactly like the flat intra-node exchange.
        let active = (1..=depth).take_while(|&g| tree.n_groups(g) > 1).count();
        // Upward: aggregate at every level boundary, boards first.
        for g in 1..=active {
            self.aggregate_up(rank, g, exchange, &mut stats);
            self.barrier.wait();
        }
        // Downward: scatter from the top tier back to the mailboxes.
        for g in (1..=active).rev() {
            self.scatter_down(rank, g, exchange);
            self.barrier.wait();
        }

        stats.intra_messages = stats.level_messages[0];
        stats.intra_bytes = stats.level_bytes[0];
        stats.inter_messages = stats.level_messages[1..].iter().sum();
        stats.inter_bytes = stats.level_bytes[1..].iter().sum();
        stats.messages = stats.intra_messages + stats.inter_messages;
        stats.bytes_sent = stats.level_bytes.iter().sum();

        // Collect the column addressed to this rank — identical in
        // content and source indexing to the flat transport's.
        let mut incoming = Vec::with_capacity(p as usize);
        for src in 0..p as usize {
            let mut slot = self.mailboxes[src][rank as usize].lock().unwrap();
            incoming.push(std::mem::take(&mut *slot));
        }
        stats.bytes_recv = incoming.iter().map(|b| b.len() as u64).sum();
        // Everyone must finish reading before the next post.
        self.barrier.wait();
        Ok((incoming, stats))
    }

    fn barrier(&self, _rank: u32) {
        self.barrier.wait();
    }

    /// Atomically swap the rotation policy. Safe between collectives:
    /// `alltoall` reads the policy only before its final barrier, so
    /// once any rank has returned from an exchange every rank is done
    /// reading it for that exchange — and the self-tuning runtime has
    /// every rank store the same value before the next one.
    fn set_rotation(&self, rotation: LeaderRotation) {
        self.rotation.store(rotation_code(rotation), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive `rounds` exchange rounds on `p` threads over `shape` with
    /// `payload(src, dst, round)` buffers, asserting every rank
    /// receives exactly `payload(src, rank, round)` each round.
    /// Returns the per-rank stats of the LAST round.
    fn tree_round(
        p: u32,
        shape: &[u32],
        rotation: LeaderRotation,
        rounds: u32,
        payload: fn(u32, u32, u32) -> Vec<u8>,
    ) -> Vec<ExchangeStats> {
        let cluster = HierCluster::with_tree(p, shape, rotation);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || -> ExchangeStats {
                let mut last = ExchangeStats::default();
                for round in 0..rounds {
                    let outgoing: Vec<Vec<u8>> =
                        (0..p).map(|dst| payload(rank, dst, round)).collect();
                    let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                    for (src, buf) in incoming.iter().enumerate() {
                        assert_eq!(
                            buf,
                            &payload(src as u32, rank, round),
                            "rank {rank} from {src} round {round}"
                        );
                    }
                    last = stats;
                }
                last
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Two-level compatibility driver (the `nodes:<k>` sugar).
    fn exchange_round(
        p: u32,
        ranks_per_node: u32,
        rounds: u32,
        payload: fn(u32, u32, u32) -> Vec<u8>,
    ) -> Vec<ExchangeStats> {
        tree_round(p, &[ranks_per_node], LeaderRotation::Fixed, rounds, payload)
    }

    fn tagged(src: u32, dst: u32, round: u32) -> Vec<u8> {
        format!("r{src}->d{dst}@{round}").into_bytes()
    }

    #[test]
    fn routes_every_pair_across_nodes() {
        // 6 ranks on 3 nodes of 2: multi-node, leaders and followers.
        let stats = exchange_round(6, 2, 20, tagged);
        for (rank, s) in stats.iter().enumerate() {
            let leader = rank % 2 == 0;
            // 1 direct intra post + (gather | 2 aggregated messages)
            assert_eq!(s.intra_messages, if leader { 1 } else { 2 }, "rank {rank}");
            assert_eq!(s.inter_messages, if leader { 2 } else { 0 }, "rank {rank}");
            assert_eq!(s.messages, 3, "rank {rank}");
            assert_eq!(s.level_messages.len(), 2);
            assert_eq!(s.level_messages[0], s.intra_messages);
            assert_eq!(s.level_messages[1], s.inter_messages);
        }
    }

    #[test]
    fn ragged_last_node_routes_correctly() {
        // 5 ranks on nodes of 2 -> sizes (2, 2, 1); rank 4 is a solo
        // leader with no intra-node peers.
        let stats = exchange_round(5, 2, 10, tagged);
        assert_eq!(stats[4].intra_messages, 0);
        assert_eq!(stats[4].inter_messages, 2);
        assert_eq!(stats[1].intra_messages, 2, "direct post + gather");
        assert_eq!(stats[1].inter_messages, 0);
    }

    #[test]
    fn single_node_degenerates_to_flat_intra_exchange() {
        let stats = exchange_round(4, 8, 5, tagged);
        for s in &stats {
            assert_eq!(s.intra_messages, 3);
            assert_eq!(s.inter_messages, 0);
            assert_eq!(s.messages, 3);
            assert_eq!(s.intra_bytes, s.bytes_sent);
        }
    }

    #[test]
    fn message_accounting_matches_closed_form() {
        // The contract: summed over ranks, one exchange's message count
        // equals the topology closed form for every (P, k) — even
        // splits, ragged splits, solo nodes, single node.
        for &(p, k) in &[(1u32, 1u32), (2, 1), (4, 2), (6, 4), (8, 3), (8, 4), (9, 4), (5, 8)] {
            let stats = exchange_round(p, k, 2, |s, d, _| vec![s as u8, d as u8]);
            let tree = TopologyTree::new(p, &[k]);
            let total: u64 = stats.iter().map(|s| s.messages).sum();
            assert_eq!(total, tree.total_messages_per_exchange(), "p={p} k={k}");
            let inter: u64 = stats.iter().map(|s| s.inter_messages).sum();
            assert_eq!(inter, tree.fabric_messages_per_exchange(), "p={p} k={k}");
            for s in &stats {
                assert_eq!(s.messages, s.intra_messages + s.inter_messages);
            }
        }
    }

    #[test]
    fn byte_accounting_is_exact() {
        // 4 ranks, 2 nodes of 2, every payload exactly 3 bytes.
        let stats = exchange_round(4, 2, 3, |s, d, _| vec![s as u8, d as u8, 0]);
        for (rank, s) in stats.iter().enumerate() {
            // everyone receives 4 payloads of 3 bytes (loopback included)
            assert_eq!(s.bytes_recv, 12, "rank {rank}");
            assert_eq!(s.per_dst_bytes, vec![3, 3, 3, 3]);
            // direct intra post: 3 B. Gather blob: 2 off-node runs of
            // (8 B frame + 3 B payload) = 22 B.
            let blob = 2 * (GATHER_FRAME_BYTES as u64 + 3);
            if rank % 2 == 0 {
                // leader: 3 B intra + one aggregated message of 4
                // (src,dst) sub-buffers: 4 * (12 B frame + 3 B) = 60 B
                let aggregate = 4 * (HIER_FRAME_BYTES as u64 + 3);
                assert_eq!(s.intra_bytes, 3, "rank {rank}");
                assert_eq!(s.inter_bytes, aggregate, "rank {rank}");
                assert_eq!(s.bytes_sent, 3 + aggregate, "rank {rank}");
            } else {
                assert_eq!(s.intra_bytes, 3 + blob, "rank {rank}");
                assert_eq!(s.inter_bytes, 0, "rank {rank}");
                assert_eq!(s.bytes_sent, 3 + blob, "rank {rank}");
            }
        }
    }

    #[test]
    fn empty_payloads_still_synchronize() {
        let stats = exchange_round(6, 3, 4, |_, _, _| Vec::new());
        for s in &stats {
            assert_eq!(s.bytes_recv, 0);
            // envelopes still move: framing bytes on gather/aggregate
            assert!(s.messages > 0);
        }
    }

    #[test]
    fn single_rank_round_trips() {
        let cluster = HierCluster::new(1, 4);
        let (incoming, stats) = cluster.alltoall(0, &[b"self".to_vec()]).unwrap();
        assert_eq!(incoming[0], b"self");
        assert_eq!(stats.messages, 0);
        assert_eq!(stats.bytes_sent, 0);
        assert_eq!(stats.bytes_recv, 4);
    }

    #[test]
    fn three_level_tree_routes_every_pair() {
        // 16 ranks as tree:2,2,2 — boards of 2, chassis of 2 boards,
        // racks of 2 chassis, 2 racks. Every (src, dst) payload must
        // arrive byte-identically through up to three aggregation hops.
        let stats = tree_round(16, &[2, 2, 2], LeaderRotation::Fixed, 6, tagged);
        let tree = TopologyTree::new(16, &[2, 2, 2]);
        for lvl in 0..=3usize {
            let live: u64 = stats.iter().map(|s| s.level_messages[lvl]).sum();
            assert_eq!(live, tree.messages_at_level(lvl), "level {lvl}");
        }
        let total: u64 = stats.iter().map(|s| s.messages).sum();
        assert_eq!(total, tree.total_messages_per_exchange());
        // rank 0 leads board, chassis and rack under fixed rotation:
        // 1 direct + 1 board pair msg + board gather... as the top
        // leader it relays at every level.
        assert!(stats[0].inter_messages > 0);
        // a plain member only pays the board-local hop
        assert_eq!(stats[1].inter_messages, 0);
        assert_eq!(stats[1].level_messages[0], 2, "direct + gather");
    }

    #[test]
    fn ragged_tree_routes_every_pair() {
        // 10 ranks as tree:2,2 — 5 boards, chassis of (2, 2, 1) boards.
        let stats = tree_round(10, &[2, 2], LeaderRotation::Fixed, 5, tagged);
        let tree = TopologyTree::new(10, &[2, 2]);
        for lvl in 0..=2usize {
            let live: u64 = stats.iter().map(|s| s.level_messages[lvl]).sum();
            assert_eq!(live, tree.messages_at_level(lvl), "level {lvl}");
        }
        // 7 ranks as tree:3,2 — boards (3, 3, 1), chassis (2, 1).
        let stats = tree_round(7, &[3, 2], LeaderRotation::Fixed, 5, tagged);
        let tree = TopologyTree::new(7, &[3, 2]);
        let total: u64 = stats.iter().map(|s| s.messages).sum();
        assert_eq!(total, tree.total_messages_per_exchange());
    }

    #[test]
    fn round_robin_rotation_spreads_leader_load() {
        // Under round-robin every board rank must take a leader turn:
        // with 2-rank boards, inter messages alternate between the two
        // members, so after an even number of rounds both have sent
        // some. Totals per exchange still match the closed form.
        let p = 8u32;
        let cluster = HierCluster::with_tree(p, &[2, 2], LeaderRotation::RoundRobin);
        let tree = TopologyTree::new(p, &[2, 2]);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || -> (u64, Vec<u64>) {
                let mut fabric_msgs = 0u64;
                let mut per_level_total = vec![0u64; 3];
                for round in 0..4u32 {
                    let outgoing: Vec<Vec<u8>> =
                        (0..p).map(|dst| tagged(rank, dst, round)).collect();
                    let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                    for (src, buf) in incoming.iter().enumerate() {
                        assert_eq!(buf, &tagged(src as u32, rank, round));
                    }
                    fabric_msgs += stats.inter_messages;
                    for (lvl, &m) in stats.level_messages.iter().enumerate() {
                        per_level_total[lvl] += m;
                    }
                }
                (fabric_msgs, per_level_total)
            }));
        }
        let results: Vec<(u64, Vec<u64>)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // every rank relayed on the fabric at least once over 4 rounds
        for (rank, (fabric, _)) in results.iter().enumerate() {
            assert!(*fabric > 0, "rank {rank} never took a leader turn");
        }
        // per-level totals over 4 exchanges == 4 x closed form
        for lvl in 0..=2usize {
            let live: u64 = results.iter().map(|r| r.1[lvl]).sum();
            assert_eq!(live, 4 * tree.messages_at_level(lvl), "level {lvl}");
        }
    }

    #[test]
    fn rotation_swaps_between_exchanges_without_touching_payloads() {
        // The online re-planner's contract: every rank stores the same
        // policy after an exchange completes, and the next exchange
        // routes identically — only who relays changes. 6 ranks on
        // boards of 2; rounds 0-1 fixed, 2-3 round-robin.
        let p = 6u32;
        let cluster = HierCluster::with_tree(p, &[2], LeaderRotation::Fixed);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || -> Vec<u64> {
                let mut inter = Vec::new();
                for round in 0..4u32 {
                    if round == 2 {
                        t.set_rotation(LeaderRotation::RoundRobin);
                    }
                    let outgoing: Vec<Vec<u8>> =
                        (0..p).map(|dst| tagged(rank, dst, round)).collect();
                    let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                    for (src, buf) in incoming.iter().enumerate() {
                        assert_eq!(buf, &tagged(src as u32, rank, round));
                    }
                    inter.push(stats.inter_messages);
                }
                inter
            }));
        }
        let inter: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let tree = TopologyTree::new(p, &[2]);
        for round in 0..4usize {
            let total: u64 = inter.iter().map(|r| r[round]).sum();
            assert_eq!(total, tree.fabric_messages_per_exchange(), "round {round}");
        }
        // Fixed rounds pin the fabric load to the even (first-of-board)
        // ranks; after the swap, round 3 (exchange counter 3, odd) hands
        // every board's leadership to its odd member.
        for r in (0..p as usize).step_by(2) {
            assert!(inter[r][0] > 0 && inter[r][1] > 0, "rank {r} led under fixed");
            assert_eq!(inter[r][3], 0, "rank {r} must hand off after the swap");
        }
        for r in (1..p as usize).step_by(2) {
            assert_eq!(inter[r][0] + inter[r][1], 0, "rank {r} relayed under fixed");
            assert!(inter[r][3] > 0, "rank {r} must take a leader turn");
        }
        assert_eq!(cluster.rotation(), LeaderRotation::RoundRobin);
    }

    #[test]
    fn rotation_is_invisible_to_payload_routing() {
        // Same shape, both policies: tree_round already asserts every
        // (src, dst, round) payload arrives intact, so this is the
        // "rotation changes who relays, never what travels" contract.
        for rot in [LeaderRotation::Fixed, LeaderRotation::RoundRobin] {
            let stats = tree_round(9, &[2, 2], rot, 5, tagged);
            let tree = TopologyTree::new(9, &[2, 2]);
            let total: u64 = stats.iter().map(|s| s.messages).sum();
            assert_eq!(total, tree.total_messages_per_exchange(), "{rot}");
        }
    }
}
