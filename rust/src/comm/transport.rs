//! The transport abstraction used by live runs.
//!
//! Models MPI's synchronous collective exchange: each rank contributes one
//! outgoing buffer per destination; `alltoall` returns the buffers
//! addressed to the calling rank. A conforming implementation must be a
//! *barrier*: no rank's exchange completes until every rank has
//! contributed (matching the paper's synchronous MPI collectives).
//!
//! Two implementations exist:
//!
//! * [`crate::comm::local::LocalCluster`] — **flat**: every rank pair
//!   crosses one shared mailbox matrix, the transport analogue of MPI
//!   point-to-point over the fabric for every pair.
//! * [`crate::comm::hier::HierCluster`] — **hierarchical**
//!   (`--topology tree:<k1>,<k2>,...`, with `nodes:<k>` as one-level
//!   sugar): ranks are grouped into an L-level tree of boards, chassis
//!   and racks; same-board pairs exchange directly while traffic that
//!   crosses a group boundary is aggregated at per-group leaders into
//!   ONE framed message per ordered sibling-group pair at every level.

use anyhow::Result;

use crate::config::LeaderRotation;

/// Per-call accounting used by the profiler and the workload recorder.
///
/// Byte counts are bytes moved through the transport. Sent
/// bytes exclude the self slot (posting to yourself is not a network
/// send), while received bytes include the loopback block when one was
/// posted: `MPI_Alltoall` copies the self block through the exchange
/// like any other, and the destination-filtered protocol
/// ([`crate::comm::routing`]) saves exactly that copy by delivering
/// local spikes directly.
///
/// # Message-count semantics
///
/// `messages` counts the envelopes this rank put on the transport, and
/// synchronous collectives always transmit envelopes, even empty ones.
/// The split by locality (and the per-topology counts) is:
///
/// * **flat** ([`crate::comm::local::LocalCluster`]) — every rank sends
///   P−1 messages per exchange, all accounted as *inter-node*: the flat
///   transport is topology-blind, so every pair crosses the shared
///   fabric (the `P(P−1)` cliff the paper measures). The per-level
///   columns stay empty — there are no levels to attribute to.
/// * **hierarchical** ([`crate::comm::hier::HierCluster`]) — messages
///   are attributed to the *link level* they cross (see
///   [`crate::comm::topology::TopologyTree`]): level 0 carries the
///   direct same-board posts plus each non-leader's ONE gather message
///   to its board leader; level `g >= 1` carries the leaders' ONE
///   aggregated message per ordered sibling-group pair plus the
///   up-gathers toward the next tier's leaders. Summed over ranks each
///   level equals
///   [`TopologyTree::messages_at_level`](crate::comm::topology::TopologyTree::messages_at_level)
///   exactly; at depth 1 this is the classic
///   `Σ sᵢ(sᵢ−1) + Σ (sᵢ−1) + N(N−1)`
///   ([`crate::comm::topology::NodeMap::total_messages_per_exchange`]).
///
/// Relay bytes are accounted where they are *sent*: a non-leader's
/// gather payload appears in its own `bytes_sent` (level 0) and again
/// in each relaying leader's `bytes_sent` (the level it forwards on) —
/// the hierarchical protocol really does move those bytes once per hop,
/// trading cheap low-tier hops for `P(P−1) → N(N−1)`-style collapses on
/// every fabric tier. Scatter (downward) hops mirror the gathers and
/// are not accounted, matching the closed form. `bytes_recv` stays
/// payload-only: the bytes delivered to this rank's incoming column,
/// regardless of the route they took.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExchangeStats {
    /// Bytes this rank sent (sum over destinations, self excluded;
    /// hierarchical transports include gather/aggregate framing).
    pub bytes_sent: u64,
    /// Bytes delivered to this rank, loopback block included.
    pub bytes_recv: u64,
    /// Messages this rank sent (`intra_messages + inter_messages`; see
    /// the message-count semantics above).
    pub messages: u64,
    /// Messages that stayed inside this rank's node (direct posts to
    /// same-node peers + the gather message to the leader). Zero on the
    /// flat transport, which has no node notion.
    pub intra_messages: u64,
    /// Messages that crossed nodes. The flat transport counts every
    /// peer message here; the hierarchical transport only the leaders'
    /// aggregated node-pair messages.
    pub inter_messages: u64,
    /// Bytes carried by `intra_messages`.
    pub intra_bytes: u64,
    /// Bytes carried by `inter_messages`.
    pub inter_bytes: u64,
    /// Messages this rank sent per link level (length `L + 1` on an
    /// L-level tree transport; index 0 = intra-board, index `g` =
    /// crossing level-`g` group boundaries). `intra_messages` is level
    /// 0, `inter_messages` the sum of levels >= 1. Empty on the flat
    /// transport, which has no levels.
    pub level_messages: Vec<u64>,
    /// Bytes carried per link level (same indexing as
    /// `level_messages`).
    pub level_bytes: Vec<u64>,
    /// Payload bytes posted per destination rank (`per_dst_bytes[d]`,
    /// length P; index `self` is the loopback block). This is the
    /// rank's row of the step's traffic matrix — the quantity the
    /// interconnect model prices pair-by-pair
    /// (`simnet::alltoall_model::AllToAllModel::exchange_time_matrix`) —
    /// and is independent of the transport topology: aggregation changes
    /// the route, never the (source, destination) payload.
    pub per_dst_bytes: Vec<u64>,
}

pub trait Transport: Send {
    /// Number of ranks in the cluster.
    fn n_ranks(&self) -> u32;

    /// Synchronous all-to-all: `outgoing[p]` is this rank's payload for
    /// rank `p` (`outgoing[self]` is returned to self unchanged, matching
    /// MPI_Alltoall semantics). Returns the incoming buffers indexed by
    /// source rank, plus accounting. Implementations must preserve the
    /// (source → payload) mapping exactly — aggregation or re-framing
    /// inside the transport must be invisible to the caller, so the
    /// coordinator's source-ordered delivery contract
    /// ([`crate::engine::rank::RankEngine::deliver`]) survives any
    /// topology.
    fn alltoall(
        &self,
        rank: u32,
        outgoing: &[Vec<u8>],
    ) -> Result<(Vec<Vec<u8>>, ExchangeStats)>;

    /// Synchronization barrier across all ranks.
    fn barrier(&self, rank: u32);

    /// Switch the leader-rotation policy for subsequent exchanges (the
    /// online re-planner flips it at window boundaries). The default is
    /// a no-op: the flat transport has no leaders to rotate. Callers
    /// must only invoke this between collectives — e.g. right after the
    /// per-epoch barrier — and store the same value from every rank, so
    /// every rank derives the same leaders for the next exchange.
    fn set_rotation(&self, _rotation: LeaderRotation) {}
}
