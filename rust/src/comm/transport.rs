//! The transport abstraction used by live runs.
//!
//! Models MPI's synchronous collective exchange: each rank contributes one
//! outgoing buffer per destination; `alltoall` returns the buffers
//! addressed to the calling rank. A conforming implementation must be a
//! *barrier*: no rank's exchange completes until every rank has
//! contributed (matching the paper's synchronous MPI collectives).

use anyhow::Result;

/// Per-call accounting used by the profiler and the workload recorder.
///
/// Byte counts are *payload* bytes moved through the transport. Sent
/// bytes exclude the self slot (posting to yourself is not a network
/// send), while received bytes include the loopback block when one was
/// posted: `MPI_Alltoall` copies the self block through the exchange
/// like any other, and the destination-filtered protocol
/// ([`crate::comm::routing`]) saves exactly that copy by delivering
/// local spikes directly.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExchangeStats {
    /// Bytes this rank sent (sum over destinations, self excluded).
    pub bytes_sent: u64,
    /// Bytes delivered to this rank, loopback block included.
    pub bytes_recv: u64,
    /// Messages this rank sent (= P-1 for all-to-all, even when empty:
    /// synchronous collectives always transmit envelopes).
    pub messages: u64,
    /// Payload bytes posted per destination rank (`per_dst_bytes[d]`,
    /// length P; index `self` is the loopback block). This is the
    /// rank's row of the step's traffic matrix — the quantity the
    /// interconnect model prices pair-by-pair
    /// (`simnet::alltoall_model::AllToAllModel::exchange_time_matrix`).
    pub per_dst_bytes: Vec<u64>,
}

pub trait Transport: Send {
    /// Number of ranks in the cluster.
    fn n_ranks(&self) -> u32;

    /// Synchronous all-to-all: `outgoing[p]` is this rank's payload for
    /// rank `p` (`outgoing[self]` is returned to self unchanged, matching
    /// MPI_Alltoall semantics). Returns the incoming buffers indexed by
    /// source rank, plus accounting.
    fn alltoall(
        &self,
        rank: u32,
        outgoing: &[Vec<u8>],
    ) -> Result<(Vec<Vec<u8>>, ExchangeStats)>;

    /// Synchronization barrier across all ranks.
    fn barrier(&self, rank: u32);
}
