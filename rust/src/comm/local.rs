//! In-process all-to-all transport: P rank threads exchange byte buffers
//! through a shared P×P mailbox matrix with two barrier phases per
//! exchange (post, then collect) — the synchronous-collective semantics
//! of the paper's MPI setup, instrumented for profiling.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::barrier::SenseBarrier;
use super::transport::{ExchangeStats, Transport};

/// Shared state for one simulated cluster of `p` ranks.
pub struct LocalCluster {
    p: u32,
    /// mailbox[src][dst]
    mailboxes: Vec<Vec<Mutex<Vec<u8>>>>,
    barrier: SenseBarrier,
}

impl LocalCluster {
    pub fn new(p: u32) -> Arc<Self> {
        assert!(p >= 1);
        Arc::new(Self {
            p,
            mailboxes: (0..p)
                .map(|_| (0..p).map(|_| Mutex::new(Vec::new())).collect())
                .collect(),
            barrier: SenseBarrier::new(p),
        })
    }
}

impl Transport for Arc<LocalCluster> {
    fn n_ranks(&self) -> u32 {
        self.p
    }

    fn alltoall(
        &self,
        rank: u32,
        outgoing: &[Vec<u8>],
    ) -> Result<(Vec<Vec<u8>>, ExchangeStats)> {
        assert_eq!(outgoing.len() as u32, self.p, "need one buffer per rank");
        let mut stats = ExchangeStats {
            per_dst_bytes: vec![0u64; self.p as usize],
            ..ExchangeStats::default()
        };
        // Phase 1: post all outgoing buffers.
        for (dst, payload) in outgoing.iter().enumerate() {
            let mut slot = self.mailboxes[rank as usize][dst].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(payload);
            stats.per_dst_bytes[dst] = payload.len() as u64;
            if dst as u32 != rank {
                stats.bytes_sent += payload.len() as u64;
                stats.messages += 1;
                // the flat transport is topology-blind: every peer
                // message crosses the shared fabric (see ExchangeStats)
                stats.inter_messages += 1;
                stats.inter_bytes += payload.len() as u64;
            }
        }
        self.barrier.wait();
        // Phase 2: collect the column addressed to this rank.
        let mut incoming = Vec::with_capacity(self.p as usize);
        for src in 0..self.p as usize {
            let mut slot = self.mailboxes[src][rank as usize].lock().unwrap();
            incoming.push(std::mem::take(&mut *slot));
        }
        stats.bytes_recv = incoming.iter().map(|b| b.len() as u64).sum();
        // Phase 3: everyone must finish reading before the next post.
        self.barrier.wait();
        Ok((incoming, stats))
    }

    fn barrier(&self, _rank: u32) {
        self.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alltoall_routes_every_pair() {
        let p = 6u32;
        let cluster = LocalCluster::new(p);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..20u32 {
                    let outgoing: Vec<Vec<u8>> = (0..p)
                        .map(|dst| format!("r{rank}->d{dst}@{round}").into_bytes())
                        .collect();
                    let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                    assert_eq!(stats.messages, (p - 1) as u64);
                    assert_eq!(stats.inter_messages, (p - 1) as u64);
                    assert_eq!(stats.intra_messages, 0, "flat has no node notion");
                    assert_eq!(stats.inter_bytes, stats.bytes_sent);
                    for (src, buf) in incoming.iter().enumerate() {
                        let expect = format!("r{src}->d{rank}@{round}");
                        assert_eq!(buf, expect.as_bytes(), "rank {rank} round {round}");
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn self_message_round_trips() {
        let cluster = LocalCluster::new(1);
        let (incoming, stats) = cluster.alltoall(0, &[b"self".to_vec()]).unwrap();
        assert_eq!(incoming[0], b"self");
        assert_eq!(stats.messages, 0, "self-delivery is not a network message");
        assert_eq!(stats.bytes_sent, 0);
        assert_eq!(stats.bytes_recv, 4, "loopback block is counted on receive");
        assert_eq!(stats.per_dst_bytes, vec![4]);
    }

    #[test]
    fn empty_payloads_still_synchronize() {
        let p = 4u32;
        let cluster = LocalCluster::new(p);
        let mut handles = Vec::new();
        for rank in 0..p {
            let t = cluster.clone();
            handles.push(std::thread::spawn(move || {
                let outgoing = vec![Vec::new(); p as usize];
                let (incoming, stats) = t.alltoall(rank, &outgoing).unwrap();
                assert!(incoming.iter().all(|b| b.is_empty()));
                assert_eq!(stats.bytes_sent, 0);
                assert_eq!(stats.bytes_recv, 0);
                assert_eq!(stats.per_dst_bytes, vec![0u64; p as usize]);
                assert_eq!(stats.messages, (p - 1) as u64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
