//! A reusable sense-reversing barrier.
//!
//! `std::sync::Barrier` would suffice for correctness, but the profiler
//! needs to attribute *time spent waiting* per rank, so this barrier is
//! built on a Mutex+Condvar pair we control and instrument.

use std::sync::{Condvar, Mutex};

pub struct SenseBarrier {
    n: u32,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    arrived: u32,
    generation: u64,
}

impl SenseBarrier {
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self {
            n,
            state: Mutex::new(BarrierState { arrived: 0, generation: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` participants have arrived.
    pub fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == self.n {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return;
        }
        while st.generation == gen {
            st = self.cv.wait(st).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    #[test]
    fn ranks_cannot_overtake_a_phase() {
        let n = 8u32;
        let barrier = Arc::new(SenseBarrier::new(n));
        let phase_count = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for _ in 0..n {
            let b = barrier.clone();
            let c = phase_count.clone();
            handles.push(std::thread::spawn(move || {
                for phase in 0..50u32 {
                    c.fetch_add(1, Ordering::SeqCst);
                    b.wait();
                    // after the barrier, every rank must have bumped the counter
                    let seen = c.load(Ordering::SeqCst);
                    assert!(
                        seen >= (phase + 1) * n,
                        "phase {phase}: counter {seen} < {}",
                        (phase + 1) * n
                    );
                    b.wait();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(phase_count.load(Ordering::SeqCst), 50 * n);
    }

    #[test]
    fn single_rank_never_blocks() {
        let b = SenseBarrier::new(1);
        for _ in 0..100 {
            b.wait();
        }
    }
}
