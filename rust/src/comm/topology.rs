//! Rank→node topology for the live transport.
//!
//! A [`NodeMap`] groups `p` ranks into virtual nodes of `ranks_per_node`
//! consecutive ranks (rank `r` lives on node `r / ranks_per_node`, the
//! same index-order packing [`crate::simnet::alltoall_model::AllToAllModel`]
//! prices), with the first rank of each node acting as the node's
//! **leader** for the hierarchical exchange ([`super::hier::HierCluster`]).
//! The last node may be ragged (fewer than `ranks_per_node` ranks) when
//! `p` is not a multiple of the node size.
//!
//! The map also owns the closed-form message accounting of one
//! hierarchical exchange, so live measurements
//! ([`crate::metrics::comm_volume::CommVolume`]) and the analytic
//! interconnect model agree *exactly* — per exchange:
//!
//! * every rank posts one intra-node message to each same-node peer
//!   (`Σ sᵢ(sᵢ−1)` over node sizes `sᵢ`),
//! * every non-leader posts ONE gather message to its node leader
//!   (`Σ (sᵢ−1)`, only when there is more than one node),
//! * every leader posts ONE aggregated message to each other node's
//!   leader (`N(N−1)` inter-node messages — the paper's `P(P−1)` flat
//!   message count collapsed to node granularity).

use std::ops::Range;

/// Index-order packing of `p` ranks onto nodes of `ranks_per_node`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeMap {
    p: u32,
    ranks_per_node: u32,
}

impl NodeMap {
    pub fn new(p: u32, ranks_per_node: u32) -> Self {
        assert!(p >= 1, "need at least one rank");
        assert!(ranks_per_node >= 1, "need at least one rank per node");
        Self { p, ranks_per_node }
    }

    pub fn n_ranks(&self) -> u32 {
        self.p
    }

    pub fn ranks_per_node(&self) -> u32 {
        self.ranks_per_node
    }

    /// Number of nodes hosting the `p` ranks.
    pub fn n_nodes(&self) -> u32 {
        self.p.div_ceil(self.ranks_per_node)
    }

    /// Node hosting rank `r`.
    pub fn node_of(&self, r: u32) -> u32 {
        debug_assert!(r < self.p);
        r / self.ranks_per_node
    }

    /// Leader rank of `node` (its first rank).
    pub fn leader_of(&self, node: u32) -> u32 {
        debug_assert!(node < self.n_nodes());
        node * self.ranks_per_node
    }

    /// Is rank `r` its node's leader?
    pub fn is_leader(&self, r: u32) -> bool {
        r % self.ranks_per_node == 0
    }

    /// Ranks hosted by `node` (the last node may be ragged).
    pub fn ranks_of(&self, node: u32) -> Range<u32> {
        debug_assert!(node < self.n_nodes());
        let lo = node * self.ranks_per_node;
        lo..(lo + self.ranks_per_node).min(self.p)
    }

    /// Number of ranks on `node`.
    pub fn node_size(&self, node: u32) -> u32 {
        let r = self.ranks_of(node);
        r.end - r.start
    }

    /// Are ranks `a` and `b` hosted by the same node?
    pub fn same_node(&self, a: u32, b: u32) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Inter-node messages of one hierarchical exchange: one aggregated
    /// message per ordered node pair, `N(N−1)` — versus the flat
    /// transport's `P(P−1)`.
    pub fn inter_messages_per_exchange(&self) -> u64 {
        let n = self.n_nodes() as u64;
        n * (n - 1)
    }

    /// Total messages (intra + gather + inter) of one hierarchical
    /// exchange, ragged last node included. This is exactly what the
    /// live [`super::hier::HierCluster`] accounts across ranks per
    /// `alltoall` call, and what the interconnect model predicts
    /// ([`crate::simnet::alltoall_model::AllToAllModel::hierarchical_messages`]).
    pub fn total_messages_per_exchange(&self) -> u64 {
        let n = self.n_nodes();
        let mut total = 0u64;
        for node in 0..n {
            let s = self.node_size(node) as u64;
            // direct intra-node posts between same-node peers
            total += s * (s - 1);
            // one gather message per non-leader (only when there is
            // inter-node traffic to aggregate)
            if n > 1 {
                total += s - 1;
            }
        }
        if n > 1 {
            total += self.inter_messages_per_exchange();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_packing() {
        let m = NodeMap::new(8, 4);
        assert_eq!(m.n_nodes(), 2);
        assert_eq!(m.node_of(0), 0);
        assert_eq!(m.node_of(3), 0);
        assert_eq!(m.node_of(4), 1);
        assert_eq!(m.leader_of(0), 0);
        assert_eq!(m.leader_of(1), 4);
        assert!(m.is_leader(0) && m.is_leader(4));
        assert!(!m.is_leader(1) && !m.is_leader(7));
        assert_eq!(m.ranks_of(1), 4..8);
        assert_eq!(m.node_size(1), 4);
        assert!(m.same_node(1, 3) && !m.same_node(3, 4));
    }

    #[test]
    fn ragged_last_node() {
        let m = NodeMap::new(10, 4);
        assert_eq!(m.n_nodes(), 3);
        assert_eq!(m.ranks_of(2), 8..10);
        assert_eq!(m.node_size(2), 2);
        assert!(m.is_leader(8));
        assert_eq!(m.node_of(9), 2);
    }

    #[test]
    fn degenerate_shapes() {
        // one rank: one node, no messages at all
        let m = NodeMap::new(1, 4);
        assert_eq!(m.n_nodes(), 1);
        assert_eq!(m.total_messages_per_exchange(), 0);
        // everyone on one node: flat all-to-all within the node
        let m = NodeMap::new(6, 8);
        assert_eq!(m.n_nodes(), 1);
        assert_eq!(m.total_messages_per_exchange(), 6 * 5);
        assert_eq!(m.inter_messages_per_exchange(), 0);
        // one rank per node: gathers vanish, inter = flat count
        let m = NodeMap::new(5, 1);
        assert_eq!(m.n_nodes(), 5);
        assert_eq!(m.total_messages_per_exchange(), 5 * 4);
        assert_eq!(m.inter_messages_per_exchange(), 5 * 4);
    }

    #[test]
    fn closed_form_matches_enumeration() {
        // brute-force the protocol's message count and compare
        for p in 1..=12u32 {
            for k in 1..=6u32 {
                let m = NodeMap::new(p, k);
                let n = m.n_nodes();
                let mut count = 0u64;
                for r in 0..p {
                    // direct posts to same-node peers
                    count += (m.node_size(m.node_of(r)) - 1) as u64;
                    // gather to the leader
                    if n > 1 && !m.is_leader(r) {
                        count += 1;
                    }
                    // aggregated messages to other leaders
                    if n > 1 && m.is_leader(r) {
                        count += (n - 1) as u64;
                    }
                }
                assert_eq!(count, m.total_messages_per_exchange(), "p={p} k={k}");
            }
        }
    }

    #[test]
    fn hierarchy_beats_flat_message_count() {
        // the tentpole claim: P(P-1) collapses to ~N(N-1) on the wire
        let m = NodeMap::new(256, 16);
        assert_eq!(m.inter_messages_per_exchange(), 16 * 15);
        let flat = 256u64 * 255;
        assert!(m.inter_messages_per_exchange() * 100 < flat);
    }
}
