//! Rank→group topology for the live transport: where each rank sits in
//! the fabric hierarchy, who leads each group, and the closed-form
//! message accounting the live transport must reproduce exactly.
//!
//! Two views of the same idea live here:
//!
//! * [`NodeMap`] — the two-level special case (`--topology nodes:<k>`):
//!   `p` ranks packed onto virtual nodes of `ranks_per_node` consecutive
//!   ranks, first rank of each node leading. Kept as the simple,
//!   heavily-referenced closed form
//!   ([`crate::simnet::alltoall_model::AllToAllModel`] prices the same
//!   index-order packing).
//! * [`TopologyTree`] — the L-level generalization
//!   (`--topology tree:<k1>,<k2>,...`): level-1 groups (*boards*) of
//!   `k1` ranks, level-2 groups (*chassis*) of `k2` boards, level-3
//!   groups (*racks*) of `k3` chassis, and so on. Any level may be
//!   ragged when sizes don't divide `p`. The tree owns per-**link-level**
//!   message counts (level 0 = intra-board, level `g` = crossing
//!   level-`g` group boundaries inside one level-`g+1` parent) and the
//!   rotation-aware leader choice ([`crate::config::LeaderRotation`])
//!   the live [`super::hier::HierCluster`] follows.
//!
//! Both closed forms are exact contracts: summed over ranks, the live
//! transport's per-exchange accounting
//! ([`crate::metrics::comm_volume::CommVolume`]) equals them for every
//! shape, ragged or not — tested here, in `comm::hier`, and end-to-end
//! in `rust/tests/topology_props.rs`.

use std::ops::Range;

use crate::config::LeaderRotation;

/// Index-order packing of `p` ranks onto nodes of `ranks_per_node`.
///
/// The closed-form message counts of one hierarchical exchange are the
/// contract the live transport satisfies exactly:
///
/// ```
/// use dpsnn::comm::NodeMap;
///
/// // 8 ranks on 2 virtual nodes of 4, per exchange:
/// let m = NodeMap::new(8, 4);
/// assert_eq!(m.n_nodes(), 2);
/// // 2 nodes × 4·3 direct intra-node posts, 2 × 3 gathers to the
/// // leaders, 2·1 aggregated node-pair messages on the fabric —
/// // versus the flat transport's P(P−1) = 56.
/// assert_eq!(m.total_messages_per_exchange(), 24 + 6 + 2);
/// assert_eq!(m.inter_messages_per_exchange(), 2);
///
/// // ragged last node: 10 ranks on nodes of 4 → sizes (4, 4, 2)
/// let r = NodeMap::new(10, 4);
/// assert_eq!(r.n_nodes(), 3);
/// assert_eq!(r.node_size(2), 2);
/// assert_eq!(
///     r.total_messages_per_exchange(),
///     (4 * 3 + 4 * 3 + 2 * 1)    // intra-node posts
///         + (3 + 3 + 1)          // gathers to the three leaders
///         + 3 * 2                // aggregated node-pair messages
/// );
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeMap {
    p: u32,
    ranks_per_node: u32,
}

impl NodeMap {
    pub fn new(p: u32, ranks_per_node: u32) -> Self {
        assert!(p >= 1, "need at least one rank");
        assert!(ranks_per_node >= 1, "need at least one rank per node");
        Self { p, ranks_per_node }
    }

    pub fn n_ranks(&self) -> u32 {
        self.p
    }

    pub fn ranks_per_node(&self) -> u32 {
        self.ranks_per_node
    }

    /// Number of nodes hosting the `p` ranks.
    pub fn n_nodes(&self) -> u32 {
        self.p.div_ceil(self.ranks_per_node)
    }

    /// Node hosting rank `r`.
    pub fn node_of(&self, r: u32) -> u32 {
        debug_assert!(r < self.p);
        r / self.ranks_per_node
    }

    /// Leader rank of `node` (its first rank).
    pub fn leader_of(&self, node: u32) -> u32 {
        debug_assert!(node < self.n_nodes());
        node * self.ranks_per_node
    }

    /// Is rank `r` its node's leader?
    pub fn is_leader(&self, r: u32) -> bool {
        r % self.ranks_per_node == 0
    }

    /// Ranks hosted by `node` (the last node may be ragged).
    pub fn ranks_of(&self, node: u32) -> Range<u32> {
        debug_assert!(node < self.n_nodes());
        let lo = node * self.ranks_per_node;
        lo..(lo + self.ranks_per_node).min(self.p)
    }

    /// Number of ranks on `node`.
    pub fn node_size(&self, node: u32) -> u32 {
        let r = self.ranks_of(node);
        r.end - r.start
    }

    /// Are ranks `a` and `b` hosted by the same node?
    pub fn same_node(&self, a: u32, b: u32) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Inter-node messages of one hierarchical exchange: one aggregated
    /// message per ordered node pair, `N(N−1)` — versus the flat
    /// transport's `P(P−1)`.
    pub fn inter_messages_per_exchange(&self) -> u64 {
        let n = self.n_nodes() as u64;
        n * (n - 1)
    }

    /// Total messages (intra + gather + inter) of one hierarchical
    /// exchange, ragged last node included. This is exactly what the
    /// live [`super::hier::HierCluster`] accounts across ranks per
    /// `alltoall` call, and what the interconnect model predicts
    /// ([`crate::simnet::alltoall_model::AllToAllModel::hierarchical_messages`]).
    pub fn total_messages_per_exchange(&self) -> u64 {
        let n = self.n_nodes();
        let mut total = 0u64;
        for node in 0..n {
            let s = self.node_size(node) as u64;
            // direct intra-node posts between same-node peers
            total += s * (s - 1);
            // one gather message per non-leader (only when there is
            // inter-node traffic to aggregate)
            if n > 1 {
                total += s - 1;
            }
        }
        if n > 1 {
            total += self.inter_messages_per_exchange();
        }
        total
    }
}

/// L-level grouping of `p` ranks (board → chassis → rack ...), the
/// general form behind `--topology tree:<k1>,<k2>,...`.
///
/// *Group levels* run `1..=L` (level 1 = boards of `k1` ranks, level 2
/// = chassis of `k2` boards, ...); level 0 is the rank itself and the
/// whole job is the virtual root above level L. *Link levels* run
/// `0..=L`: a message on link level `g` crosses level-`g` group
/// boundaries while staying inside one level-`g+1` parent (level 0 =
/// shared memory inside a board, level L = the top-tier fabric). Any
/// level may be ragged when the branching factors don't divide `p`.
///
/// One exchange of the protocol in [`super::hier::HierCluster`] puts on
/// link level `g`, per exchange:
///
/// * **pair messages** — ONE aggregated message per ordered pair of
///   sibling level-`g` groups under each level-`g+1` parent
///   (`Σ c(c−1)` over parents; for `g = 0` these are the direct
///   intra-board rank-pair posts, for `g = L` the top-tier group
///   pairs), and
/// * **up-gathers** — ONE message from each level-`g` group leader to
///   its level-`g+1` group leader carrying everything that must travel
///   beyond the parent (`Σ (c−1)` over parents, only when more than
///   one level-`g+1` group exists).
///
/// Scatter hops mirror the gathers on the way down and are *not*
/// accounted as messages — the same convention [`NodeMap`] documents
/// for the two-level case, which this reproduces exactly at depth 1.
///
/// Leadership is hierarchical: a group's leader is always the leader of
/// one of its child groups, chosen by the
/// [`LeaderRotation`](crate::config::LeaderRotation) policy — `fixed`
/// picks the first child at every level (so rank 0 of a board leads
/// board, chassis and rack alike), `round-robin` picks child
/// `exchange % children` so the aggregation CPU cost walks through the
/// group members. Rotation never changes what travels, so these closed
/// forms are rotation-invariant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyTree {
    p: u32,
    /// Branching factors: `shape[l]` = level-`l+1` group size counted
    /// in level-`l` groups (`shape[0]` = ranks per board).
    shape: Vec<u32>,
    /// `strides[g]` = nominal ranks per level-`g` group
    /// (`strides[0] = 1`).
    strides: Vec<u64>,
}

impl TopologyTree {
    pub fn new(p: u32, shape: &[u32]) -> Self {
        assert!(p >= 1, "need at least one rank");
        assert!(!shape.is_empty(), "need at least one tree level");
        assert!(
            shape.iter().all(|&k| k >= 1),
            "branching factors must be at least 1"
        );
        let mut strides = vec![1u64; shape.len() + 1];
        for (l, &k) in shape.iter().enumerate() {
            strides[l + 1] = strides[l].saturating_mul(k as u64);
        }
        Self {
            p,
            shape: shape.to_vec(),
            strides,
        }
    }

    /// Number of grouping levels L (1 = boards only).
    pub fn depth(&self) -> usize {
        self.shape.len()
    }

    pub fn n_ranks(&self) -> u32 {
        self.p
    }

    /// The branching factors, smallest tier first.
    pub fn shape(&self) -> &[u32] {
        &self.shape
    }

    fn n_groups_u64(&self, level: usize) -> u64 {
        (self.p as u64).div_ceil(self.strides[level])
    }

    /// Number of level-`level` groups (level 0 = ranks, so `p`).
    pub fn n_groups(&self, level: usize) -> u32 {
        debug_assert!(level <= self.depth());
        self.n_groups_u64(level) as u32
    }

    /// Level-`level` group hosting rank `r`.
    pub fn group_of(&self, r: u32, level: usize) -> u32 {
        debug_assert!(r < self.p && level <= self.depth());
        ((r as u64) / self.strides[level]) as u32
    }

    /// Ranks of level-`level` group `group` (possibly ragged).
    pub fn ranks_of(&self, group: u32, level: usize) -> Range<u32> {
        debug_assert!(level <= self.depth() && group < self.n_groups(level));
        let lo = (group as u64).saturating_mul(self.strides[level]);
        let hi = lo.saturating_add(self.strides[level]).min(self.p as u64);
        (lo as u32)..(hi as u32)
    }

    /// Number of ranks in level-`level` group `group`.
    pub fn group_size(&self, group: u32, level: usize) -> u32 {
        let r = self.ranks_of(group, level);
        r.end - r.start
    }

    /// Link level a point-to-point payload from rank `a` to rank `b`
    /// traverses: 0 inside a board, `g` when the finest shared group of
    /// `a` and `b` is at level `g` (so `L` = the top-tier fabric).
    /// This is the per-pair view of the per-level message accounting,
    /// and what comm-aware placement
    /// ([`crate::engine::partition::GreedyCommsAllocator`]) prices.
    ///
    /// ```
    /// use dpsnn::comm::TopologyTree;
    ///
    /// // 8 ranks, boards of 2, chassis of 2 boards
    /// let t = TopologyTree::new(8, &[2, 2]);
    /// assert_eq!(t.link_level(0, 1), 0); // same board
    /// assert_eq!(t.link_level(0, 2), 1); // same chassis, other board
    /// assert_eq!(t.link_level(0, 4), 2); // top-tier fabric
    /// assert_eq!(t.link_level(5, 5), 0);
    /// ```
    pub fn link_level(&self, a: u32, b: u32) -> usize {
        debug_assert!(a < self.p && b < self.p);
        for g in 1..=self.depth() {
            if self.group_of(a, g) == self.group_of(b, g) {
                return g - 1;
            }
        }
        self.depth()
    }

    /// Level-`level-1` child groups of `parent` at level `level >= 1`.
    pub fn children_of(&self, parent: u32, level: usize) -> Range<u32> {
        debug_assert!((1..=self.depth()).contains(&level));
        debug_assert!(parent < self.n_groups(level));
        let k = self.shape[level - 1] as u64;
        let lo = (parent as u64) * k;
        let hi = (lo + k).min(self.n_groups_u64(level - 1));
        (lo as u32)..(hi as u32)
    }

    /// Number of level-`level-1` children of `parent` at level `level`.
    pub fn children_count(&self, parent: u32, level: usize) -> u32 {
        let c = self.children_of(parent, level);
        c.end - c.start
    }

    /// Level-`level+1` parent of a level-`level` group (`level < L`).
    pub fn parent_of(&self, group: u32, level: usize) -> u32 {
        debug_assert!(level < self.depth());
        group / self.shape[level]
    }

    /// Leader rank of level-`level` group `group` for exchange number
    /// `exchange` under `rotation`: descend the tree picking the
    /// leading child at every level, so a chassis leader is always one
    /// of its board leaders.
    pub fn leader_of(
        &self,
        group: u32,
        level: usize,
        rotation: LeaderRotation,
        exchange: u64,
    ) -> u32 {
        debug_assert!(level <= self.depth());
        let mut group = group;
        let mut level = level;
        while level > 0 {
            let children = self.children_of(group, level);
            let c = children.end - children.start;
            let pick = match rotation {
                LeaderRotation::Fixed => 0,
                LeaderRotation::RoundRobin => (exchange % c as u64) as u32,
            };
            group = children.start + pick;
            level -= 1;
        }
        group
    }

    /// Is rank `r` the leader of its level-`level` group this exchange?
    pub fn is_leader(
        &self,
        r: u32,
        level: usize,
        rotation: LeaderRotation,
        exchange: u64,
    ) -> bool {
        self.leader_of(self.group_of(r, level), level, rotation, exchange) == r
    }

    /// Pair messages one exchange puts on link level `lvl`: one per
    /// ordered pair of sibling level-`lvl` groups under each
    /// level-`lvl+1` parent (the whole job for `lvl = L`).
    pub fn pair_messages_at_level(&self, lvl: usize) -> u64 {
        let depth = self.depth();
        debug_assert!(lvl <= depth);
        if lvl == depth {
            let c = self.n_groups_u64(depth);
            return c * (c - 1);
        }
        let mut total = 0u64;
        for parent in 0..self.n_groups(lvl + 1) {
            let c = self.children_count(parent, lvl + 1) as u64;
            total += c * (c - 1);
        }
        total
    }

    /// Up-gather messages one exchange puts on link level `lvl`: one
    /// per non-leading level-`lvl` group leader toward its
    /// level-`lvl+1` leader, present only when traffic crosses the
    /// level-`lvl+1` boundary at all.
    pub fn gather_messages_at_level(&self, lvl: usize) -> u64 {
        let depth = self.depth();
        debug_assert!(lvl <= depth);
        if lvl >= depth || self.n_groups(lvl + 1) <= 1 {
            return 0;
        }
        (0..self.n_groups(lvl + 1))
            .map(|parent| self.children_count(parent, lvl + 1) as u64 - 1)
            .sum()
    }

    /// All messages one exchange puts on link level `lvl` (pair
    /// messages + up-gathers).
    pub fn messages_at_level(&self, lvl: usize) -> u64 {
        self.pair_messages_at_level(lvl) + self.gather_messages_at_level(lvl)
    }

    /// Per-link-level message counts of one exchange, length `L + 1`
    /// (index 0 = intra-board) — the exact contract the live
    /// [`super::hier::HierCluster`] accounting sums to.
    pub fn level_message_counts(&self) -> Vec<u64> {
        (0..=self.depth()).map(|g| self.messages_at_level(g)).collect()
    }

    /// Total messages of one exchange across all link levels.
    pub fn total_messages_per_exchange(&self) -> u64 {
        self.level_message_counts().iter().sum()
    }

    /// Messages one exchange puts on the fabric (link levels >= 1,
    /// i.e. everything that leaves a board).
    pub fn fabric_messages_per_exchange(&self) -> u64 {
        (1..=self.depth()).map(|g| self.messages_at_level(g)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_packing() {
        let m = NodeMap::new(8, 4);
        assert_eq!(m.n_nodes(), 2);
        assert_eq!(m.node_of(0), 0);
        assert_eq!(m.node_of(3), 0);
        assert_eq!(m.node_of(4), 1);
        assert_eq!(m.leader_of(0), 0);
        assert_eq!(m.leader_of(1), 4);
        assert!(m.is_leader(0) && m.is_leader(4));
        assert!(!m.is_leader(1) && !m.is_leader(7));
        assert_eq!(m.ranks_of(1), 4..8);
        assert_eq!(m.node_size(1), 4);
        assert!(m.same_node(1, 3) && !m.same_node(3, 4));
    }

    #[test]
    fn ragged_last_node() {
        let m = NodeMap::new(10, 4);
        assert_eq!(m.n_nodes(), 3);
        assert_eq!(m.ranks_of(2), 8..10);
        assert_eq!(m.node_size(2), 2);
        assert!(m.is_leader(8));
        assert_eq!(m.node_of(9), 2);
    }

    #[test]
    fn degenerate_shapes() {
        // one rank: one node, no messages at all
        let m = NodeMap::new(1, 4);
        assert_eq!(m.n_nodes(), 1);
        assert_eq!(m.total_messages_per_exchange(), 0);
        // everyone on one node: flat all-to-all within the node
        let m = NodeMap::new(6, 8);
        assert_eq!(m.n_nodes(), 1);
        assert_eq!(m.total_messages_per_exchange(), 6 * 5);
        assert_eq!(m.inter_messages_per_exchange(), 0);
        // one rank per node: gathers vanish, inter = flat count
        let m = NodeMap::new(5, 1);
        assert_eq!(m.n_nodes(), 5);
        assert_eq!(m.total_messages_per_exchange(), 5 * 4);
        assert_eq!(m.inter_messages_per_exchange(), 5 * 4);
    }

    #[test]
    fn closed_form_matches_enumeration() {
        // brute-force the protocol's message count and compare
        for p in 1..=12u32 {
            for k in 1..=6u32 {
                let m = NodeMap::new(p, k);
                let n = m.n_nodes();
                let mut count = 0u64;
                for r in 0..p {
                    // direct posts to same-node peers
                    count += (m.node_size(m.node_of(r)) - 1) as u64;
                    // gather to the leader
                    if n > 1 && !m.is_leader(r) {
                        count += 1;
                    }
                    // aggregated messages to other leaders
                    if n > 1 && m.is_leader(r) {
                        count += (n - 1) as u64;
                    }
                }
                assert_eq!(count, m.total_messages_per_exchange(), "p={p} k={k}");
            }
        }
    }

    #[test]
    fn hierarchy_beats_flat_message_count() {
        // the tentpole claim: P(P-1) collapses to ~N(N-1) on the wire
        let m = NodeMap::new(256, 16);
        assert_eq!(m.inter_messages_per_exchange(), 16 * 15);
        let flat = 256u64 * 255;
        assert!(m.inter_messages_per_exchange() * 100 < flat);
    }

    #[test]
    fn one_level_tree_matches_nodemap() {
        // the tree at depth 1 IS the NodeMap closed form, ragged or not
        for p in 1..=12u32 {
            for k in 1..=6u32 {
                let tree = TopologyTree::new(p, &[k]);
                let map = NodeMap::new(p, k);
                assert_eq!(tree.n_groups(1), map.n_nodes(), "p={p} k={k}");
                assert_eq!(
                    tree.total_messages_per_exchange(),
                    map.total_messages_per_exchange(),
                    "p={p} k={k}"
                );
                assert_eq!(
                    tree.messages_at_level(1),
                    map.inter_messages_per_exchange(),
                    "p={p} k={k}"
                );
                assert_eq!(
                    tree.fabric_messages_per_exchange(),
                    map.inter_messages_per_exchange(),
                    "p={p} k={k}"
                );
                for r in 0..p {
                    assert_eq!(tree.group_of(r, 1), map.node_of(r));
                    assert_eq!(
                        tree.leader_of(tree.group_of(r, 1), 1, LeaderRotation::Fixed, 0),
                        map.leader_of(map.node_of(r)),
                    );
                }
            }
        }
    }

    #[test]
    fn ragged_two_level_tree_counts_by_hand() {
        // 10 ranks, tree:2,2 — 5 boards of 2, chassis of (2, 2, 1)
        // boards, 3 chassis at the top.
        let t = TopologyTree::new(10, &[2, 2]);
        assert_eq!(t.depth(), 2);
        assert_eq!(t.n_groups(1), 5);
        assert_eq!(t.n_groups(2), 3);
        assert_eq!(t.children_count(2, 2), 1, "ragged chassis has one board");
        assert_eq!(t.ranks_of(2, 2), 8..10);
        // level 0: 5 boards × 2·1 direct + 5 × 1 gathers
        assert_eq!(t.messages_at_level(0), 10 + 5);
        // level 1: board pairs per chassis 2+2+0, gathers 1+1+0
        assert_eq!(t.pair_messages_at_level(1), 4);
        assert_eq!(t.gather_messages_at_level(1), 2);
        // level 2 (top): 3·2 chassis pairs
        assert_eq!(t.messages_at_level(2), 6);
        assert_eq!(t.total_messages_per_exchange(), 15 + 6 + 6);
        assert_eq!(t.fabric_messages_per_exchange(), 6 + 6);
        assert_eq!(t.level_message_counts(), vec![15, 6, 6]);
    }

    #[test]
    fn degenerate_tree_levels_cost_nothing() {
        // one chassis: the top tier vanishes, board pairs remain
        let t = TopologyTree::new(8, &[2, 4]);
        assert_eq!(t.n_groups(2), 1);
        assert_eq!(t.messages_at_level(2), 0);
        assert_eq!(t.gather_messages_at_level(1), 0, "nothing leaves the chassis");
        assert_eq!(t.pair_messages_at_level(1), 4 * 3);
        // single board: nothing leaves shared memory at all
        let t = TopologyTree::new(4, &[8, 2]);
        assert_eq!(t.fabric_messages_per_exchange(), 0);
        assert_eq!(t.total_messages_per_exchange(), 4 * 3);
    }

    #[test]
    fn leaders_descend_the_tree_and_rotate() {
        let t = TopologyTree::new(10, &[2, 2]);
        // fixed: first rank leads at every level
        assert_eq!(t.leader_of(1, 2, LeaderRotation::Fixed, 7), 4);
        assert_eq!(t.leader_of(3, 1, LeaderRotation::Fixed, 7), 6);
        assert!(t.is_leader(0, 2, LeaderRotation::Fixed, 0));
        assert!(!t.is_leader(1, 1, LeaderRotation::Fixed, 0));
        // round-robin at exchange 1: chassis 1 -> board 3 -> rank 7
        assert_eq!(t.leader_of(1, 2, LeaderRotation::RoundRobin, 1), 7);
        // and back to the first rank on even exchanges
        assert_eq!(t.leader_of(1, 2, LeaderRotation::RoundRobin, 2), 4);
        // ragged solo chassis: only one board, rotation cycles its ranks
        assert_eq!(t.leader_of(2, 2, LeaderRotation::RoundRobin, 1), 9);
        assert_eq!(t.leader_of(2, 2, LeaderRotation::RoundRobin, 2), 8);
        // the leader is always a member of its group
        for level in 0..=t.depth() {
            for g in 0..t.n_groups(level) {
                for x in 0..6u64 {
                    for rot in [LeaderRotation::Fixed, LeaderRotation::RoundRobin] {
                        let r = t.leader_of(g, level, rot, x);
                        assert!(t.ranks_of(g, level).contains(&r), "g={g} level={level}");
                    }
                }
            }
        }
        // exactly one leader per group per exchange
        for x in 0..4u64 {
            for level in 1..=t.depth() {
                let leaders: Vec<u32> = (0..t.n_ranks())
                    .filter(|&r| t.is_leader(r, level, LeaderRotation::RoundRobin, x))
                    .collect();
                assert_eq!(leaders.len() as u32, t.n_groups(level), "x={x} level={level}");
            }
        }
    }

    #[test]
    fn link_level_finds_the_finest_shared_group() {
        let t = TopologyTree::new(10, &[2, 2]);
        for a in 0..10u32 {
            assert_eq!(t.link_level(a, a), 0);
            for b in 0..10u32 {
                assert_eq!(t.link_level(a, b), t.link_level(b, a));
                let want = if t.group_of(a, 1) == t.group_of(b, 1) {
                    0
                } else if t.group_of(a, 2) == t.group_of(b, 2) {
                    1
                } else {
                    2
                };
                assert_eq!(t.link_level(a, b), want, "a={a} b={b}");
            }
        }
        // flat-ish tree: everything off-board is level 1
        let t = TopologyTree::new(6, &[2]);
        assert_eq!(t.link_level(0, 1), 0);
        assert_eq!(t.link_level(0, 5), 1);
    }

    #[test]
    fn three_level_tree_counts() {
        // 16 ranks, tree:2,2,2 — 8 boards, 4 chassis, 2 racks.
        let t = TopologyTree::new(16, &[2, 2, 2]);
        assert_eq!(t.depth(), 3);
        assert_eq!(
            t.level_message_counts(),
            vec![
                8 * 2 + 8,     // direct posts + rank gathers
                4 * 2 + 4,     // board pairs per chassis + board gathers
                2 * 2 + 2,     // chassis pairs per rack + chassis gathers
                2,             // rack pair
            ]
        );
        // deeper trees put dramatically fewer messages on the top fabric
        assert_eq!(t.messages_at_level(3), 2);
        assert!(t.fabric_messages_per_exchange() < 16 * 15);
    }
}
