//! Destination-filtered spike routing.
//!
//! The broadcast all-to-all sends every spike to every rank, so per-rank
//! receive volume is O(total spikes) regardless of P — the worst point in
//! the paper's design space (Table I: 91.7% communication share at 256
//! processes). But the connectivity is partition-independent: synapse `k`
//! of source `s` is a pure function of `(seed, s, k)`
//! ([`ConnectivityParams::synapse`]), so every rank can precompute, with
//! no communication, the exact set of *destination ranks* each of its
//! local neurons projects to. A spike then travels only to ranks that own
//! at least one of its postsynaptic targets (the target-aware routing of
//! Kurth et al. 2021 that keeps communication sub-linear in P).
//!
//! The table is a compact per-source-neuron rank bitmap:
//! `ceil(P/64) * 8` bytes per local neuron. With the paper's homogeneous
//! connectivity (M = 1125 targets drawn uniformly) the filter
//! *degenerates to broadcast* whenever `M >> P` — the probability that a
//! source misses all neurons of a rank is `(1 - 1/P)^M ~ e^(-M/P)` — and
//! only starts dropping pairs once P approaches M. It always removes the
//! transport loopback (local spikes are delivered directly, not copied
//! through the self mailbox), and at large P or sparse connectivity it
//! removes whole source→rank pairs.

use crate::engine::partition::Partition;
use crate::model::connectivity::ConnectivityParams;

/// Per-rank routing table: for each *local* source neuron, the bitmap of
/// destination ranks owning at least one of its postsynaptic targets.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    n_ranks: u32,
    n_local: u32,
    /// Bitmap words per source row.
    words_per_src: usize,
    /// `bits[local * words_per_src + w]`, bit `r % 64` of word `r / 64`
    /// set iff the source projects to rank `r`.
    bits: Vec<u64>,
}

impl RoutingTable {
    /// Build the table for the local sources of `rank` (whatever gid set
    /// the placement policy gave it — rows are indexed by the rank's
    /// local numbering). Cost: at most `n_local * M` stateless synapse
    /// draws, with an early exit once a source is known to cover every
    /// rank — for dense connectivity the sweep stops after ~P ln P draws
    /// per source.
    pub fn build(cp: &ConnectivityParams, part: &Partition, rank: u32) -> Self {
        let owned = part.owned(rank);
        let p = part.n_ranks();
        let words_per_src = (p as usize).div_ceil(64);
        let n_local = owned.len();
        let mut bits = vec![0u64; n_local as usize * words_per_src];
        for (local, s) in owned.iter().enumerate() {
            let base = local * words_per_src;
            let row = &mut bits[base..base + words_per_src];
            let mut covered = 0u32;
            for k in 0..cp.m {
                let (tgt, _) = cp.synapse(s, k);
                let dst = part.owner(tgt) as usize;
                let mask = 1u64 << (dst % 64);
                if row[dst / 64] & mask == 0 {
                    row[dst / 64] |= mask;
                    covered += 1;
                    if covered == p {
                        break;
                    }
                }
            }
        }
        Self { n_ranks: p, n_local, words_per_src, bits }
    }

    pub fn n_ranks(&self) -> u32 {
        self.n_ranks
    }

    pub fn n_local(&self) -> u32 {
        self.n_local
    }

    fn row(&self, local: u32) -> &[u64] {
        debug_assert!(local < self.n_local, "local {local} >= {}", self.n_local);
        let base = local as usize * self.words_per_src;
        &self.bits[base..base + self.words_per_src]
    }

    /// Does local source `local` project to any neuron owned by `dst`?
    pub fn sends_to(&self, local: u32, dst: u32) -> bool {
        debug_assert!(dst < self.n_ranks);
        self.row(local)[dst as usize / 64] & (1u64 << (dst % 64)) != 0
    }

    /// Iterate the destination ranks of local source `local`, ascending.
    pub fn dest_ranks(&self, local: u32) -> DestRanks<'_> {
        DestRanks { words: self.row(local), word_idx: 0, current: 0 }
    }

    /// Number of destination ranks of local source `local`.
    pub fn rank_fanout(&self, local: u32) -> u32 {
        self.row(local).iter().map(|w| w.count_ones()).sum()
    }

    /// True when every local source projects to every rank — the dense
    /// regime where per-destination filtering cannot drop anything and
    /// the sender can fall back to one shared encode (minus loopback).
    pub fn degenerates_to_broadcast(&self) -> bool {
        let set: u64 = self.bits.iter().map(|w| w.count_ones() as u64).sum();
        set == self.n_local as u64 * self.n_ranks as u64
    }

    /// Mean destination-rank fan-out over the local sources — P means
    /// the filter has degenerated to broadcast.
    pub fn mean_rank_fanout(&self) -> f64 {
        if self.n_local == 0 {
            return 0.0;
        }
        let set: u64 = self.bits.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / self.n_local as f64
    }

    /// Resident bytes of the bitmap (capacity planning).
    pub fn resident_bytes(&self) -> usize {
        self.bits.len() * 8
    }
}

/// Ascending iterator over the set destination ranks of one source row.
pub struct DestRanks<'a> {
    words: &'a [u64],
    /// Index of the *next* word to load; the word being drained is
    /// `word_idx - 1`.
    word_idx: usize,
    current: u64,
}

impl Iterator for DestRanks<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        loop {
            if self.current != 0 {
                let bit = self.current.trailing_zeros();
                self.current &= self.current - 1;
                return Some((self.word_idx as u32 - 1) * 64 + bit);
            }
            if self.word_idx == self.words.len() {
                return None;
            }
            self.current = self.words[self.word_idx];
            self.word_idx += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::connectivity::IncomingSynapses;

    fn cp(n: u32, m: u32, seed: u64) -> ConnectivityParams {
        ConnectivityParams { seed, n, m, dmin: 1, dmax: 8 }
    }

    #[test]
    fn matches_incoming_synapse_rows_exactly() {
        // sends_to(s, d) must equal "rank d's incoming row for s is
        // non-empty" — the two views are built from the same generator.
        let c = cp(96, 3, 1234);
        for p in [2u32, 4, 7] {
            let part = Partition::even(96, p);
            let incoming: Vec<IncomingSynapses> = (0..p)
                .map(|r| {
                    let (lo, hi) = part.range(r);
                    IncomingSynapses::build(&c, lo, hi)
                })
                .collect();
            for rank in 0..p {
                let table = RoutingTable::build(&c, &part, rank);
                let (lo, hi) = part.range(rank);
                assert_eq!(table.n_local(), hi - lo);
                for s in lo..hi {
                    for dst in 0..p {
                        let has_targets = !incoming[dst as usize].row(s).0.is_empty();
                        assert_eq!(
                            table.sends_to(s - lo, dst),
                            has_targets,
                            "p={p} rank={rank} s={s} dst={dst}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matches_incoming_rows_under_permuted_ownership() {
        // same contract as above, but ownership is scattered by the
        // round-robin placement: rows are in each rank's local numbering
        use crate::config::PartitionPolicy;
        use crate::engine::partition::AllocContext;
        let c = cp(96, 3, 1234);
        let part =
            Partition::allocate(PartitionPolicy::RoundRobin, 96, 4, &AllocContext::empty());
        let incoming: Vec<IncomingSynapses> = (0..4)
            .map(|r| IncomingSynapses::build_owned(&c, part.owned(r)))
            .collect();
        for rank in 0..4 {
            let table = RoutingTable::build(&c, &part, rank);
            assert_eq!(table.n_local(), part.size(rank));
            for (local, s) in part.owned(rank).iter().enumerate() {
                for dst in 0..4 {
                    let has_targets = !incoming[dst as usize].row(s).0.is_empty();
                    assert_eq!(
                        table.sends_to(local as u32, dst),
                        has_targets,
                        "rank={rank} s={s} dst={dst}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_connectivity_degenerates_to_broadcast() {
        // M >> P: every source covers every rank.
        let c = cp(64, 32, 7);
        let part = Partition::even(64, 4);
        let table = RoutingTable::build(&c, &part, 0);
        assert!(table.degenerates_to_broadcast());
        assert_eq!(table.mean_rank_fanout(), 4.0);
        for local in 0..table.n_local() {
            assert_eq!(table.rank_fanout(local), 4);
            let dsts: Vec<u32> = table.dest_ranks(local).collect();
            assert_eq!(dsts, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn sparse_connectivity_filters() {
        // M = 1 target: exactly one destination rank per source.
        let c = cp(256, 1, 99);
        let part = Partition::even(256, 8);
        for rank in 0..8 {
            let table = RoutingTable::build(&c, &part, rank);
            for local in 0..table.n_local() {
                assert_eq!(table.rank_fanout(local), 1);
                let dsts: Vec<u32> = table.dest_ranks(local).collect();
                assert_eq!(dsts.len(), 1);
                let (lo, _) = part.range(rank);
                let (tgt, _) = c.synapse(lo + local, 0);
                assert_eq!(dsts[0], part.owner(tgt));
            }
            assert!((table.mean_rank_fanout() - 1.0).abs() < 1e-12);
            assert!(!table.degenerates_to_broadcast());
        }
    }

    #[test]
    fn iterator_agrees_with_sends_to_across_word_boundaries() {
        // 70 ranks forces a two-word bitmap row.
        let c = cp(140, 5, 5);
        let part = Partition::even(140, 70);
        let table = RoutingTable::build(&c, &part, 3);
        assert_eq!(table.n_ranks(), 70);
        for local in 0..table.n_local() {
            let dsts: Vec<u32> = table.dest_ranks(local).collect();
            assert!(dsts.windows(2).all(|w| w[0] < w[1]), "ascending");
            for dst in 0..70 {
                assert_eq!(table.sends_to(local, dst), dsts.contains(&dst));
            }
            assert_eq!(dsts.len() as u32, table.rank_fanout(local));
        }
    }

    #[test]
    fn resident_bytes_is_compact() {
        let c = cp(1024, 16, 2);
        let part = Partition::even(1024, 8);
        let table = RoutingTable::build(&c, &part, 0);
        // 128 local sources x 1 word x 8 bytes
        assert_eq!(table.resident_bytes(), 128 * 8);
    }
}
