//! AER (Address-Event Representation) wire format.
//!
//! The paper: "Spikes are delivered using the AER representation (spiking
//! neuron ID, emission time); in our case 12 byte per spike are required."
//! We encode exactly that: `u32` neuron id + `f64` emission time in ms,
//! little-endian, 12 bytes per spike.

use anyhow::{bail, Result};

use crate::engine::spike::Spike;

/// Bytes per spike on the wire (paper: 12).
pub const SPIKE_WIRE_BYTES: usize = 12;

/// Append the AER encoding of `spikes` to `buf`.
pub fn encode_spikes(spikes: &[Spike], dt_ms: f64, buf: &mut Vec<u8>) {
    buf.reserve(spikes.len() * SPIKE_WIRE_BYTES);
    for s in spikes {
        buf.extend_from_slice(&s.gid.to_le_bytes());
        buf.extend_from_slice(&s.time_ms(dt_ms).to_le_bytes());
    }
}

/// Decode an AER buffer back into spikes. `dt_ms` must match the encoder.
pub fn decode_spikes(buf: &[u8], dt_ms: f64, out: &mut Vec<Spike>) -> Result<usize> {
    if buf.len() % SPIKE_WIRE_BYTES != 0 {
        bail!(
            "AER buffer length {} is not a multiple of {SPIKE_WIRE_BYTES}",
            buf.len()
        );
    }
    let n = buf.len() / SPIKE_WIRE_BYTES;
    out.reserve(n);
    for chunk in buf.chunks_exact(SPIKE_WIRE_BYTES) {
        let gid = u32::from_le_bytes(chunk[0..4].try_into().unwrap());
        let time_ms = f64::from_le_bytes(chunk[4..12].try_into().unwrap());
        let step = (time_ms / dt_ms).round() as u32;
        out.push(Spike { gid, step });
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn twelve_bytes_per_spike() {
        let mut buf = Vec::new();
        encode_spikes(&[Spike::new(1, 2), Spike::new(3, 4)], 1.0, &mut buf);
        assert_eq!(buf.len(), 24);
    }

    #[test]
    fn round_trip() {
        let spikes: Vec<Spike> = (0..100).map(|i| Spike::new(i * 7, i)).collect();
        let mut buf = Vec::new();
        encode_spikes(&spikes, 1.0, &mut buf);
        let mut back = Vec::new();
        let n = decode_spikes(&buf, 1.0, &mut back).unwrap();
        assert_eq!(n, 100);
        assert_eq!(spikes, back);
    }

    #[test]
    fn bad_length_rejected() {
        let mut out = Vec::new();
        assert!(decode_spikes(&[0u8; 13], 1.0, &mut out).is_err());
    }

    #[test]
    fn property_round_trip_any_dt() {
        forall("aer round trip", 50, |rng| {
            let dt = [0.1, 0.5, 1.0, 2.0][rng.next_below(4) as usize];
            let n = rng.next_below(200) as usize;
            let spikes: Vec<Spike> = (0..n)
                .map(|_| Spike::new(rng.next_u64() as u32, rng.next_below(1_000_000)))
                .collect();
            let mut buf = Vec::new();
            encode_spikes(&spikes, dt, &mut buf);
            assert_eq!(buf.len(), n * SPIKE_WIRE_BYTES);
            let mut back = Vec::new();
            decode_spikes(&buf, dt, &mut back).unwrap();
            assert_eq!(spikes, back);
        });
    }
}
