//! AER (Address-Event Representation) wire format.
//!
//! The paper: "Spikes are delivered using the AER representation (spiking
//! neuron ID, emission time); in our case 12 byte per spike are required."
//! We encode exactly that: `u32` neuron id + `f64` emission time in ms,
//! little-endian, 12 bytes per spike.
//!
//! Two framings ride on the same 12-byte record:
//!
//! * **flat** ([`encode_spikes`] / [`decode_spikes`]) — the paper's wire
//!   format: a bare record sequence, one exchange per network step. The
//!   fidelity harnesses stay on this format.
//! * **epoch-batched** ([`encode_spikes_epoch`] / [`decode_spikes_epoch`])
//!   — per-step run headers (`step: u32`, `count: u32`) over the same
//!   records, so a single exchange carries a whole min-delay window of
//!   steps (see [`crate::config::ExchangeCadence`]). The records alone
//!   would suffice (each carries its emission time); the headers make
//!   run boundaries explicit and give the decoder an integrity
//!   cross-check — every record must agree with its run header — while
//!   leaving the paper's flat format untouched for per-step fidelity.

use anyhow::{bail, Result};

use crate::engine::spike::Spike;

/// Bytes per spike on the wire (paper: 12).
pub const SPIKE_WIRE_BYTES: usize = 12;

/// Bytes of one epoch run header: emission step (`u32`) + record count
/// (`u32`), little-endian.
pub const EPOCH_HEADER_BYTES: usize = 8;

/// Wire overhead of epoch framing for a window of `steps_in_window`
/// steps under a `cadence_steps`-step cadence: one run header per step
/// when framing is on (`cadence_steps > 1`), none on the flat per-step
/// format. Shared by the interconnect model and the timing replay so
/// the framing rule lives in one place. (Upper bound: the encoder only
/// emits headers for steps that actually spiked.)
pub fn epoch_framing_bytes(cadence_steps: u32, steps_in_window: u32) -> u64 {
    if cadence_steps > 1 {
        steps_in_window as u64 * EPOCH_HEADER_BYTES as u64
    } else {
        0
    }
}

/// Append the AER encoding of `spikes` to `buf`.
pub fn encode_spikes(spikes: &[Spike], dt_ms: f64, buf: &mut Vec<u8>) {
    buf.reserve(spikes.len() * SPIKE_WIRE_BYTES);
    for s in spikes {
        buf.extend_from_slice(&s.gid.to_le_bytes());
        buf.extend_from_slice(&s.time_ms(dt_ms).to_le_bytes());
    }
}

/// Decode an AER buffer back into spikes. `dt_ms` must match the encoder.
///
/// Rejects corrupt records — non-finite or negative emission times, and
/// times whose step index overflows `u32` — instead of letting an
/// `as u32` cast silently saturate them onto a valid-looking step.
pub fn decode_spikes(buf: &[u8], dt_ms: f64, out: &mut Vec<Spike>) -> Result<usize> {
    if buf.len() % SPIKE_WIRE_BYTES != 0 {
        bail!(
            "AER buffer length {} is not a multiple of {SPIKE_WIRE_BYTES}",
            buf.len()
        );
    }
    let n = buf.len() / SPIKE_WIRE_BYTES;
    out.reserve(n);
    for chunk in buf.chunks_exact(SPIKE_WIRE_BYTES) {
        let gid = u32::from_le_bytes(chunk[0..4].try_into().unwrap());
        let time_ms = f64::from_le_bytes(chunk[4..12].try_into().unwrap());
        if !time_ms.is_finite() || time_ms < 0.0 {
            bail!("corrupt AER record: time {time_ms} ms (neuron {gid})");
        }
        let step_f = (time_ms / dt_ms).round();
        if step_f > u32::MAX as f64 {
            bail!(
                "corrupt AER record: emission time {time_ms} ms for neuron {gid} \
                 overflows the step counter"
            );
        }
        let step = step_f as u32;
        out.push(Spike { gid, step });
    }
    Ok(n)
}

/// Append the epoch-batched encoding of `spikes` to `buf`: one
/// `(step, count)` run header per emitting step followed by that step's
/// 12-byte records. Steps without spikes occupy no bytes. `spikes` must
/// be grouped by emission step in non-decreasing order — exactly what a
/// sequence of [`crate::engine::rank::RankEngine::integrate`] calls
/// produces when their outputs are concatenated.
pub fn encode_spikes_epoch(spikes: &[Spike], dt_ms: f64, buf: &mut Vec<u8>) {
    let mut i = 0usize;
    while i < spikes.len() {
        let step = spikes[i].step;
        let mut j = i + 1;
        while j < spikes.len() && spikes[j].step == step {
            j += 1;
        }
        debug_assert!(
            j == spikes.len() || spikes[j].step > step,
            "epoch spikes must be sorted by emission step"
        );
        buf.extend_from_slice(&step.to_le_bytes());
        buf.extend_from_slice(&((j - i) as u32).to_le_bytes());
        encode_spikes(&spikes[i..j], dt_ms, buf);
        i = j;
    }
}

/// Decode an epoch-batched buffer produced by [`encode_spikes_epoch`].
/// Validates the framing: run headers must tile the buffer exactly and
/// every record's emission time must agree with its run header.
pub fn decode_spikes_epoch(buf: &[u8], dt_ms: f64, out: &mut Vec<Spike>) -> Result<usize> {
    let mut off = 0usize;
    let mut total = 0usize;
    while off < buf.len() {
        if buf.len() - off < EPOCH_HEADER_BYTES {
            bail!("truncated epoch run header at byte {off}");
        }
        let step = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
        let count = u32::from_le_bytes(buf[off + 4..off + 8].try_into().unwrap()) as usize;
        off += EPOCH_HEADER_BYTES;
        let payload = count.checked_mul(SPIKE_WIRE_BYTES).ok_or_else(|| {
            anyhow::anyhow!("epoch run at step {step}: impossible count {count}")
        })?;
        if buf.len() - off < payload {
            bail!(
                "epoch run at step {step} claims {count} spikes but only {} bytes remain",
                buf.len() - off
            );
        }
        let before = out.len();
        decode_spikes(&buf[off..off + payload], dt_ms, out)?;
        for sp in &out[before..] {
            if sp.step != step {
                bail!(
                    "epoch run header says step {step} but the record for neuron {} \
                     decodes to step {}",
                    sp.gid,
                    sp.step
                );
            }
        }
        off += payload;
        total += count;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn twelve_bytes_per_spike() {
        let mut buf = Vec::new();
        encode_spikes(&[Spike::new(1, 2), Spike::new(3, 4)], 1.0, &mut buf);
        assert_eq!(buf.len(), 24);
    }

    #[test]
    fn round_trip() {
        let spikes: Vec<Spike> = (0..100).map(|i| Spike::new(i * 7, i)).collect();
        let mut buf = Vec::new();
        encode_spikes(&spikes, 1.0, &mut buf);
        let mut back = Vec::new();
        let n = decode_spikes(&buf, 1.0, &mut back).unwrap();
        assert_eq!(n, 100);
        assert_eq!(spikes, back);
    }

    #[test]
    fn bad_length_rejected() {
        let mut out = Vec::new();
        assert!(decode_spikes(&[0u8; 13], 1.0, &mut out).is_err());
    }

    fn raw_record(gid: u32, time_ms: f64) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&gid.to_le_bytes());
        b.extend_from_slice(&time_ms.to_le_bytes());
        b
    }

    #[test]
    fn corrupt_emission_times_rejected() {
        let mut out = Vec::new();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0, -1e300] {
            let buf = raw_record(7, bad);
            assert!(
                decode_spikes(&buf, 1.0, &mut out).is_err(),
                "time {bad} must be rejected"
            );
        }
        // a step index past u32::MAX must not silently truncate
        let buf = raw_record(7, 1e18);
        assert!(decode_spikes(&buf, 1.0, &mut out).is_err());
        assert!(out.is_empty());
        // the largest representable step still round-trips
        let buf = raw_record(7, u32::MAX as f64);
        decode_spikes(&buf, 1.0, &mut out).unwrap();
        assert_eq!(out, vec![Spike::new(7, u32::MAX)]);
    }

    #[test]
    fn epoch_round_trip() {
        // three steps' worth of spikes, one step empty
        let spikes: Vec<Spike> = [(3u32, 10u32), (9, 10), (1, 11), (4, 13), (5, 13)]
            .iter()
            .map(|&(gid, step)| Spike::new(gid, step))
            .collect();
        let mut buf = Vec::new();
        encode_spikes_epoch(&spikes, 1.0, &mut buf);
        // 3 run headers + 5 records
        assert_eq!(buf.len(), 3 * EPOCH_HEADER_BYTES + 5 * SPIKE_WIRE_BYTES);
        let mut back = Vec::new();
        let n = decode_spikes_epoch(&buf, 1.0, &mut back).unwrap();
        assert_eq!(n, 5);
        assert_eq!(back, spikes);
        // the shared framing-overhead rule the cost models price
        assert_eq!(epoch_framing_bytes(1, 1), 0, "flat format has no headers");
        assert_eq!(epoch_framing_bytes(16, 3), 3 * EPOCH_HEADER_BYTES as u64);
    }

    #[test]
    fn epoch_empty_and_single_step() {
        let mut buf = Vec::new();
        encode_spikes_epoch(&[], 1.0, &mut buf);
        assert!(buf.is_empty());
        let mut out = Vec::new();
        assert_eq!(decode_spikes_epoch(&buf, 1.0, &mut out).unwrap(), 0);

        let spikes = vec![Spike::new(0, 42), Spike::new(8, 42)];
        encode_spikes_epoch(&spikes, 0.5, &mut buf);
        assert_eq!(buf.len(), EPOCH_HEADER_BYTES + 2 * SPIKE_WIRE_BYTES);
        decode_spikes_epoch(&buf, 0.5, &mut out).unwrap();
        assert_eq!(out, spikes);
    }

    #[test]
    fn epoch_framing_violations_rejected() {
        let mut out = Vec::new();
        // truncated header
        assert!(decode_spikes_epoch(&[1, 2, 3], 1.0, &mut out).is_err());
        // header claims more records than the buffer holds
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u32.to_le_bytes()); // step
        buf.extend_from_slice(&2u32.to_le_bytes()); // count = 2
        buf.extend_from_slice(&raw_record(1, 5.0)); // ... but only 1 record
        assert!(decode_spikes_epoch(&buf, 1.0, &mut out).is_err());
        // record's emission time disagrees with its run header
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&raw_record(1, 9.0)); // step 9 != header 5
        assert!(decode_spikes_epoch(&buf, 1.0, &mut out).is_err());
    }

    #[test]
    fn property_epoch_round_trip() {
        forall("aer epoch round trip", 50, |rng| {
            let dt = [0.1, 0.5, 1.0, 2.0][rng.next_below(4) as usize];
            let n_steps = 1 + rng.next_below(8);
            let first = rng.next_below(10_000);
            let mut spikes = Vec::new();
            for s in 0..n_steps {
                let count = rng.next_below(20) as usize;
                for _ in 0..count {
                    spikes.push(Spike::new(rng.next_below(4096), first + s));
                }
            }
            let mut buf = Vec::new();
            encode_spikes_epoch(&spikes, dt, &mut buf);
            let mut back = Vec::new();
            let n = decode_spikes_epoch(&buf, dt, &mut back).unwrap();
            assert_eq!(n, spikes.len());
            assert_eq!(back, spikes);
        });
    }

    #[test]
    fn property_round_trip_any_dt() {
        forall("aer round trip", 50, |rng| {
            let dt = [0.1, 0.5, 1.0, 2.0][rng.next_below(4) as usize];
            let n = rng.next_below(200) as usize;
            let spikes: Vec<Spike> = (0..n)
                .map(|_| Spike::new(rng.next_u64() as u32, rng.next_below(1_000_000)))
                .collect();
            let mut buf = Vec::new();
            encode_spikes(&spikes, dt, &mut buf);
            assert_eq!(buf.len(), n * SPIKE_WIRE_BYTES);
            let mut back = Vec::new();
            decode_spikes(&buf, dt, &mut back).unwrap();
            assert_eq!(spikes, back);
        });
    }
}
