//! Inter-process communication: the AER wire format, message packing,
//! the transport abstraction with the in-process all-to-all
//! implementation, and the synchronization barrier.

pub mod aer;
pub mod transport;
pub mod local;
pub mod barrier;

pub use aer::{decode_spikes, encode_spikes, SPIKE_WIRE_BYTES};
pub use local::LocalCluster;
pub use transport::{ExchangeStats, Transport};
