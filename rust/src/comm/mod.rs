//! Inter-process communication: the AER wire format, message packing,
//! the transport abstraction with the in-process all-to-all
//! implementation, the synchronization barrier, and destination-filtered
//! spike routing.
//!
//! Two exchange protocols ride on the same synchronous transport:
//!
//! * **broadcast** — every rank sends its full AER buffer to every other
//!   rank (the paper's baseline); per-rank receive volume is O(total
//!   spikes) regardless of P.
//! * **filtered** ([`routing`]) — each rank precomputes, from the
//!   partition-independent connectivity, which destination ranks each
//!   local neuron actually projects to, and AER-encodes a per-destination
//!   buffer so a rank receives only spikes with at least one local
//!   postsynaptic target. With dense connectivity and small P
//!   (`M >> P`) the pair filter degenerates to broadcast, but local
//!   spikes are still delivered directly instead of looping back through
//!   the transport; at large P or sparse connectivity whole source→rank
//!   pairs disappear from the traffic matrix.
//!
//! Orthogonally to *where* spikes travel, [`crate::config::ExchangeCadence`]
//! controls *how often*: per step (the paper's protocol, flat 12-byte
//! AER stream) or once per min-delay epoch ([`aer::encode_spikes_epoch`]
//! run-header framing), amortizing the per-message latency over
//! `delay_min_steps` network steps with a bitwise-identical raster.
//!
//! A third orthogonal axis is the transport *topology*
//! ([`crate::config::Topology`]): the flat [`local::LocalCluster`] puts
//! every rank pair on the shared fabric (`P(P−1)` messages per
//! exchange), while the hierarchical [`hier::HierCluster`] groups ranks
//! into an L-level tree ([`topology::TopologyTree`]: boards, chassis,
//! racks — [`topology::NodeMap`] is the two-level special case) where
//! same-board spikes move through the board-local mailbox slots and
//! boundary-crossing traffic is gathered at per-group leaders into ONE
//! source-tagged framed message per ordered sibling-group pair at every
//! level — so a rack pair exchanges one message regardless of how many
//! ranks it contains — then scattered back, with a byte-identical
//! incoming column and therefore a bitwise-identical raster. Which rank
//! pays the aggregation CPU cost is the
//! [`crate::config::LeaderRotation`] policy.

pub mod aer;
pub mod transport;
pub mod local;
pub mod hier;
pub mod topology;
pub mod barrier;
pub mod routing;

pub use aer::{
    decode_spikes, decode_spikes_epoch, encode_spikes, encode_spikes_epoch,
    EPOCH_HEADER_BYTES, SPIKE_WIRE_BYTES,
};
pub use hier::{HierCluster, GATHER_FRAME_BYTES, HIER_FRAME_BYTES};
pub use local::LocalCluster;
pub use routing::RoutingTable;
pub use topology::{NodeMap, TopologyTree};
pub use transport::{ExchangeStats, Transport};
