//! A tiny stopwatch for attributing wall-clock to components.

use std::time::{Duration, Instant};

/// Measures consecutive phases: `lap()` returns the time since the last
/// lap (or construction), so a step loop can do
/// `integrate(); comp += sw.lap(); exchange(); comm += sw.lap();`.
#[derive(Debug)]
pub struct Stopwatch {
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { last: Instant::now() }
    }

    #[inline]
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    /// Discard time accumulated since the last lap.
    #[inline]
    pub fn reset(&mut self) {
        self.last = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_are_disjoint() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        let a = sw.lap();
        let b = sw.lap();
        assert!(a >= Duration::from_millis(4), "{a:?}");
        assert!(b < a, "second lap {b:?} should be ~0");
    }
}
