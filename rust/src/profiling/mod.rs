//! Execution profiling: the paper's three-way decomposition of wall-clock
//! time into **Computation**, **Communication** and **Barrier**
//! (synchronization), per rank (Table I, Figs 3/5/6).

pub mod components;
pub mod compute_bench;
pub mod timer;

pub use components::Components;
pub use compute_bench::{run_compute_bench, ComputeBenchReport, ComputeCase};
pub use timer::Stopwatch;
