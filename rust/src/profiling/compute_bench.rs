//! Compute-kernel microbenchmarks shared by `benches/hot_paths.rs` and
//! `dpsnn bench-smoke --compute-out` (the BENCH_compute.json trajectory).
//!
//! Three kernels dominate a rank's step under the paper's profiling:
//! the LIF+SFA neuron update, the Poisson stimulus fill, and synaptic
//! delivery through the CSR rows into the delay ring. Each is measured
//! in two variants:
//!
//! * `scalar` — the pre-SoA reference path (the push-variant
//!   `step_native`, the plain whole-buffer `fill`, the per-synapse
//!   `DelayRing::add` loop), kept as the speedup baseline;
//! * `soa` — the production path (masked SoA update via
//!   [`NativeBackend`], chunked [`ExternalStimulus::fill_chunked`],
//!   run-based [`DelayRing::deliver_row_offset`] / ranged shards), at
//!   each requested `--compute-threads` count.
//!
//! Synaptic delivery adds a third, `procedural`, variant: rows
//! regenerated on the fly from the stateless connectome and delivered
//! through the compressed ring — the compute cost of the O(state)
//! `--connectivity procedural` memory mode.
//!
//! Every case reports elems/sec and `realtime_x`: how many times faster
//! than the real-time line (one `dt_ms` network step per `dt_ms` of wall
//! clock) that kernel alone would run the n-neuron network.

use std::rc::Rc;

use crate::config::NetworkParams;
use crate::engine::delay_queue::{CompressedDelayRing, DelayRing};
use crate::engine::partition::OwnedGids;
use crate::model::connectivity::{ConnectivityParams, IncomingSynapses, ProceduralSynapses};
use crate::model::neuron::{step_native, StepParams};
use crate::model::poisson::ExternalStimulus;
use crate::model::population::PopulationSoA;
use crate::runtime::{NativeBackend, NeuronBackend};
use crate::util::aligned::AlignedF32;
use crate::util::bench::Bench;
use crate::util::pool::{chunk_range, ComputePool};
use crate::util::rng::SplitMix64;

/// One measured (kernel, variant, threads) cell.
#[derive(Debug, Clone)]
pub struct ComputeCase {
    /// "neuron_update" | "poisson_fill" | "synaptic_delivery".
    pub kind: &'static str,
    /// "scalar" (pre-SoA reference) or "soa" (production path).
    pub variant: &'static str,
    pub threads: usize,
    /// Elements processed per iteration (neurons or synaptic events).
    pub elems_per_iter: f64,
    /// Mean seconds per iteration.
    pub secs_per_iter: f64,
    /// Elements one network step must process (the real-time budget
    /// denominator: neurons for update/fill, mean synaptic events for
    /// delivery).
    pub elems_per_step: f64,
}

impl ComputeCase {
    pub fn elems_per_s(&self) -> f64 {
        if self.secs_per_iter > 0.0 {
            self.elems_per_iter / self.secs_per_iter
        } else {
            0.0
        }
    }

    /// Achievable steps/sec over required steps/sec for `step_s`-second
    /// network steps: > 1 means this kernel alone beats real time.
    pub fn realtime_x(&self, step_s: f64) -> f64 {
        if self.elems_per_step > 0.0 {
            self.elems_per_s() / self.elems_per_step * step_s
        } else {
            0.0
        }
    }
}

/// The full compute-bench result set for one network size.
#[derive(Debug, Clone)]
pub struct ComputeBenchReport {
    pub n: u32,
    pub step_ms: f64,
    pub threads: Vec<usize>,
    /// What `available_parallelism` reported on the measuring host —
    /// thread counts above this share cores (recorded so CI floors can
    /// be read in context).
    pub host_parallelism: usize,
    pub cases: Vec<ComputeCase>,
}

impl ComputeBenchReport {
    pub fn case(&self, kind: &str, variant: &str, threads: usize) -> Option<&ComputeCase> {
        self.cases
            .iter()
            .find(|c| c.kind == kind && c.variant == variant && c.threads == threads)
    }

    /// Best SoA-path throughput over the scalar baseline for one kernel.
    pub fn speedup_vs_scalar(&self, kind: &str) -> Option<f64> {
        let scalar = self.case(kind, "scalar", 1)?.elems_per_s();
        let best = self
            .cases
            .iter()
            .filter(|c| c.kind == kind && c.variant == "soa")
            .map(|c| c.elems_per_s())
            .fold(0.0f64, f64::max);
        if scalar > 0.0 {
            Some(best / scalar)
        } else {
            None
        }
    }

    pub fn to_json(&self) -> String {
        let step_s = self.step_ms * 1e-3;
        let mut cases = String::new();
        for (i, c) in self.cases.iter().enumerate() {
            if i > 0 {
                cases.push_str(",\n");
            }
            cases.push_str(&format!(
                concat!(
                    "    {{\"kind\": \"{}\", \"variant\": \"{}\", \"threads\": {}, ",
                    "\"elems_per_iter\": {}, \"secs_per_iter\": {:.9}, ",
                    "\"elems_per_s\": {:.1}, \"realtime_x\": {:.3}}}"
                ),
                c.kind,
                c.variant,
                c.threads,
                c.elems_per_iter,
                c.secs_per_iter,
                c.elems_per_s(),
                c.realtime_x(step_s),
            ));
        }
        let speedup = |k: &str| self.speedup_vs_scalar(k).unwrap_or(0.0);
        format!(
            concat!(
                "{{\n",
                "  \"benchmark\": \"compute\",\n",
                "  \"n\": {},\n",
                "  \"step_ms\": {},\n",
                "  \"host_parallelism\": {},\n",
                "  \"threads\": [{}],\n",
                "  \"cases\": [\n{}\n  ],\n",
                "  \"speedup_vs_scalar\": {{\"neuron_update\": {:.3}, ",
                "\"poisson_fill\": {:.3}, \"synaptic_delivery\": {:.3}}}\n",
                "}}\n"
            ),
            self.n,
            self.step_ms,
            self.host_parallelism,
            self.threads
                .iter()
                .map(|t| t.to_string())
                .collect::<Vec<_>>()
                .join(", "),
            cases,
            speedup("neuron_update"),
            speedup("poisson_fill"),
            speedup("synaptic_delivery"),
        )
    }
}

fn driven_pop(n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    // Same mixed drive the historical hot_paths bench used: random v,
    // light adaptation, random synaptic input, uniform external input.
    let mut rng = SplitMix64::new(1);
    let v: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 * 19.0).collect();
    let w = vec![0.1f32; n];
    let i_syn: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 * 2.0).collect();
    let i_ext = vec![1.0f32; n];
    let sfa = vec![0.12f32; n];
    (v, w, i_syn, i_ext, sfa)
}

/// Run the three compute kernels at network size `n` for each thread
/// count in `threads` (the scalar baselines always run single-threaded).
/// Prints one report line per case via `b` and returns the structured
/// report.
pub fn run_compute_bench(b: &mut Bench, n: u32, threads: &[usize]) -> ComputeBenchReport {
    let net = NetworkParams::paper(n);
    let nn = n as usize;
    let host = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let mut cases = Vec::new();

    // -- neuron update ---------------------------------------------------
    let params = StepParams::from_network(&net);
    let (v0, w0, i_syn, i_ext, sfa) = driven_pop(nn);
    {
        let (mut v, mut w) = (v0.clone(), w0.clone());
        let mut rf = vec![0.0f32; nn];
        let mut spiked = Vec::with_capacity(nn);
        let st = b.bench_elems(&format!("neuron_update n={n} scalar"), nn as f64, || {
            spiked.clear();
            step_native(&params, &mut v, &mut w, &mut rf, &i_syn, &i_ext, &sfa, &mut spiked)
        });
        cases.push(ComputeCase {
            kind: "neuron_update",
            variant: "scalar",
            threads: 1,
            elems_per_iter: nn as f64,
            secs_per_iter: st.mean,
            elems_per_step: nn as f64,
        });
    }
    for &t in threads {
        let pop = PopulationSoA {
            gid0: 0,
            v: AlignedF32::from_slice(&v0),
            w: AlignedF32::from_slice(&w0),
            rf: AlignedF32::zeroed(nn),
            sfa_inc: AlignedF32::from_slice(&sfa),
            i_ext: AlignedF32::from_slice(&i_ext),
        };
        let pool = Rc::new(ComputePool::new(t));
        let mut be = NativeBackend::with_pool(&net, pop, pool);
        let mut spiked = Vec::with_capacity(nn);
        let st = b.bench_elems(&format!("neuron_update n={n} soa t={t}"), nn as f64, || {
            spiked.clear();
            be.step(&i_syn, &mut spiked).unwrap()
        });
        cases.push(ComputeCase {
            kind: "neuron_update",
            variant: "soa",
            threads: t,
            elems_per_iter: nn as f64,
            secs_per_iter: st.mean,
            elems_per_step: nn as f64,
        });
    }

    // -- poisson fill ----------------------------------------------------
    let stim = ExternalStimulus::new(&net, 5);
    {
        let mut buf = vec![0.0f32; nn];
        let mut step = 0u32;
        let st = b.bench_elems(&format!("poisson_fill n={n} scalar"), nn as f64, || {
            step = step.wrapping_add(1);
            stim.fill(step, 0, &mut buf)
        });
        cases.push(ComputeCase {
            kind: "poisson_fill",
            variant: "scalar",
            threads: 1,
            elems_per_iter: nn as f64,
            secs_per_iter: st.mean,
            elems_per_step: nn as f64,
        });
    }
    for &t in threads {
        let pool = ComputePool::new(t);
        let segs = [(0usize, 0u32, nn)];
        let mut scratch = Vec::new();
        let mut buf = vec![0.0f32; nn];
        let mut step = 0u32;
        let st = b.bench_elems(&format!("poisson_fill n={n} soa t={t}"), nn as f64, || {
            step = step.wrapping_add(1);
            stim.fill_chunked(step, &segs, &pool, &mut scratch, &mut buf)
        });
        cases.push(ComputeCase {
            kind: "poisson_fill",
            variant: "soa",
            threads: t,
            elems_per_iter: nn as f64,
            secs_per_iter: st.mean,
            elems_per_step: nn as f64,
        });
    }

    // -- synaptic delivery -----------------------------------------------
    // One step's worth of spikes at ~3.2 Hz through the full incoming
    // rows of a single rank owning the whole network.
    let cp = ConnectivityParams::from_network(&net, 7);
    let inc = IncomingSynapses::build(&cp, 0, n);
    let mut rng = SplitMix64::new(3);
    let n_spikes = (nn as f64 * 3.2e-3).ceil() as usize;
    let spikes: Vec<u32> = (0..n_spikes).map(|_| rng.next_below(n)).collect();
    let events: usize = spikes.iter().map(|&s| inc.row(s).0.len()).sum();
    {
        let mut ring = DelayRing::new(nn, net.delay_max_steps);
        let st = b.bench_elems(
            &format!("synaptic_delivery {n_spikes} spikes scalar"),
            events as f64,
            || {
                for &s in &spikes {
                    let (tgts, delays) = inc.row(s);
                    for (&tg, &d) in tgts.iter().zip(delays) {
                        ring.add(d, tg, 0.4);
                    }
                }
                ring.advance();
            },
        );
        cases.push(ComputeCase {
            kind: "synaptic_delivery",
            variant: "scalar",
            threads: 1,
            elems_per_iter: events as f64,
            secs_per_iter: st.mean,
            elems_per_step: events as f64,
        });
    }
    {
        // procedural variant: regenerate each firing source's row from
        // the stateless connectome (no CSR table resident) and deliver
        // through the compressed ring — prices the compute the
        // O(state) memory mode trades for the table's DRAM.
        let proc_syn = ProceduralSynapses::new(cp, OwnedGids::contiguous(0, n));
        let mut ring = CompressedDelayRing::new(nn, net.delay_max_steps, 1);
        let (mut tgt, mut dl) = (Vec::new(), Vec::new());
        let mut scratch: Vec<(u8, u32)> = Vec::new();
        let st = b.bench_elems(
            &format!("synaptic_delivery {n_spikes} spikes procedural"),
            events as f64,
            || {
                for &s in &spikes {
                    tgt.clear();
                    dl.clear();
                    proc_syn.row_into(s, &mut tgt, &mut dl, &mut scratch);
                    ring.deliver_row_offset(&tgt, &dl, 0.4, 0);
                }
                ring.advance();
            },
        );
        cases.push(ComputeCase {
            kind: "synaptic_delivery",
            variant: "procedural",
            threads: 1,
            elems_per_iter: events as f64,
            secs_per_iter: st.mean,
            elems_per_step: events as f64,
        });
    }
    for &t in threads {
        let pool = ComputePool::new(t);
        let chunks = pool.chunks();
        let mut ring = DelayRing::new(nn, net.delay_max_steps);
        let st = b.bench_elems(
            &format!("synaptic_delivery {n_spikes} spikes soa t={t}"),
            events as f64,
            || {
                if chunks == 1 {
                    for &s in &spikes {
                        let (tgts, delays) = inc.row(s);
                        ring.deliver_row_offset(tgts, delays, 0.4, 0);
                    }
                } else {
                    let shard = ring.shard();
                    pool.run(&|c| {
                        let r = chunk_range(chunks, c, nn);
                        if r.is_empty() {
                            return;
                        }
                        for &s in &spikes {
                            let (tgts, delays) = inc.row(s);
                            // SAFETY: disjoint target ranges per chunk;
                            // rows build-validated; back = 0 < delay.
                            unsafe {
                                shard.deliver_row_offset_ranged(
                                    tgts,
                                    delays,
                                    0.4,
                                    0,
                                    r.start as u32,
                                    r.end as u32,
                                )
                            };
                        }
                    });
                }
                ring.advance();
            },
        );
        cases.push(ComputeCase {
            kind: "synaptic_delivery",
            variant: "soa",
            threads: t,
            elems_per_iter: events as f64,
            secs_per_iter: st.mean,
            elems_per_step: events as f64,
        });
    }

    ComputeBenchReport {
        n,
        step_ms: net.dt_ms,
        threads: threads.to_vec(),
        host_parallelism: host,
        cases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math_and_json_shape() {
        let report = ComputeBenchReport {
            n: 20_480,
            step_ms: 1.0,
            threads: vec![1, 2],
            host_parallelism: 4,
            cases: vec![
                ComputeCase {
                    kind: "neuron_update",
                    variant: "scalar",
                    threads: 1,
                    elems_per_iter: 20_480.0,
                    secs_per_iter: 20.48e-6, // 1 Gelem/s
                    elems_per_step: 20_480.0,
                },
                ComputeCase {
                    kind: "neuron_update",
                    variant: "soa",
                    threads: 2,
                    elems_per_iter: 20_480.0,
                    secs_per_iter: 5.12e-6, // 4 Gelem/s
                    elems_per_step: 20_480.0,
                },
            ],
        };
        let c = report.case("neuron_update", "soa", 2).unwrap();
        assert!((c.elems_per_s() - 4e9).abs() / 4e9 < 1e-9);
        // 4e9 elems/s over 20480 elems/step = ~195k steps/s vs 1000 needed
        assert!((c.realtime_x(1e-3) - 195.3125).abs() < 1e-6);
        assert!((report.speedup_vs_scalar("neuron_update").unwrap() - 4.0).abs() < 1e-9);
        let json = report.to_json();
        assert!(json.contains("\"benchmark\": \"compute\""));
        assert!(json.contains("\"speedup_vs_scalar\""));
        assert!(json.contains("\"kind\": \"neuron_update\""));
        assert!(json.contains("\"threads\": [1, 2]"));
    }

    #[test]
    fn smoke_runs_tiny() {
        // A minimal end-to-end pass of all three kernels (tiny n, fast
        // bench budget) — checks the harness wiring, not performance.
        let mut b = Bench::fast();
        b.warmup = std::time::Duration::from_millis(1);
        b.measure = std::time::Duration::from_millis(5);
        b.max_samples = 3;
        let report = run_compute_bench(&mut b, 2048, &[1, 2]);
        // 3 scalar baselines + 1 procedural delivery + 3 SoA kernels
        // per thread count
        assert_eq!(report.cases.len(), 4 + 3 * report.threads.len());
        assert!(report.cases.iter().all(|c| c.secs_per_iter > 0.0));
        assert!(
            report.case("synaptic_delivery", "procedural", 1).is_some(),
            "procedural row-regeneration case missing"
        );
        let json = report.to_json();
        assert!(json.contains("\"n\": 2048"));
    }
}
