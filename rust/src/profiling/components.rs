//! The comp/comm/barrier decomposition.

use std::time::Duration;

/// Accumulated wall-clock per execution component (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Components {
    pub computation: f64,
    pub communication: f64,
    pub barrier: f64,
}

impl Components {
    pub fn total(&self) -> f64 {
        self.computation + self.communication + self.barrier
    }

    /// Fractions (comp, comm, barrier); zeros if nothing was recorded.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total();
        if t <= 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            self.computation / t,
            self.communication / t,
            self.barrier / t,
        )
    }

    pub fn add_computation(&mut self, d: Duration) {
        self.computation += d.as_secs_f64();
    }

    pub fn add_communication(&mut self, d: Duration) {
        self.communication += d.as_secs_f64();
    }

    pub fn add_barrier(&mut self, d: Duration) {
        self.barrier += d.as_secs_f64();
    }

    /// Element-wise sum (aggregate over ranks).
    pub fn merged(items: &[Components]) -> Components {
        let mut out = Components::default();
        for c in items {
            out.computation += c.computation;
            out.communication += c.communication;
            out.barrier += c.barrier;
        }
        out
    }

    /// Paper-style row: "97.6% / 0.6% / 1.3%".
    pub fn percent_row(&self) -> (String, String, String) {
        let (a, b, c) = self.fractions();
        (
            crate::util::units::fmt_pct(a),
            crate::util::units::fmt_pct(b),
            crate::util::units::fmt_pct(c),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let c = Components { computation: 3.0, communication: 1.0, barrier: 1.0 };
        let (a, b, d) = c.fractions();
        assert!((a + b + d - 1.0).abs() < 1e-12);
        assert!((a - 0.6).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(Components::default().fractions(), (0.0, 0.0, 0.0));
    }

    #[test]
    fn merge_adds() {
        let a = Components { computation: 1.0, communication: 2.0, barrier: 3.0 };
        let m = Components::merged(&[a, a]);
        assert_eq!(m.computation, 2.0);
        assert_eq!(m.barrier, 6.0);
    }
}
