//! Neuron→process placement: partitions and pluggable allocator policies.
//!
//! The paper distributes neurons evenly among processes in index order;
//! the heterogeneous Intel+ARM runs additionally weight the shares by
//! per-core speed (`weighted`), mirroring DPSNN's MPI "heterogeneous
//! mode" partitioning. This module generalizes that single hard-coded
//! layout into a *placement layer*: an [`Allocator`] policy assigns
//! fixed-size contiguous *placement blocks* of gids to ranks, and the
//! resulting [`Partition`] may give a rank any union of blocks — not
//! just one contiguous range.
//!
//! Three policies implement the trait (selected by
//! [`crate::config::PartitionPolicy`], CLI `--partition`):
//!
//! * [`IndexAllocator`] (`index`) — consecutive blocks per rank; exactly
//!   reproduces the historical [`Partition::even`] split.
//! * [`RoundRobinAllocator`] (`round-robin`) — block `b` goes to rank
//!   `b % p`, deliberately scattering neighbouring gids across the
//!   whole machine (the placement *worst case* for locality).
//! * [`GreedyCommsAllocator`] (`greedy-comms`) — weighs the
//!   partition-independent connectome
//!   ([`crate::model::connectivity::ConnectivityParams`]) against the
//!   topology tree's link levels
//!   ([`crate::comm::topology::TopologyTree::link_level`]) and packs
//!   strongly-coupled blocks onto the same rank / board / chassis:
//!   greedy constructive placement followed by deterministic
//!   first-improvement block-swap refinement.
//!
//! Everything downstream (population init, incoming synapses, routing
//! bitmaps, delay-ring delivery) works on the per-rank [`OwnedGids`]
//! interval set, so rasters stay *bitwise identical* across policies —
//! ownership is a pure permutation and the network itself is a pure
//! function of gid (see DESIGN.md §7).

use crate::comm::topology::TopologyTree;
use crate::config::PartitionPolicy;
use crate::model::connectivity::ConnectivityParams;

/// Hard cap on placement blocks per rank (allocation atoms stay coarse
/// enough that the greedy refinement's O(B³) sweeps remain cheap).
pub const MAX_BLOCKS_PER_RANK: u32 = 32;

/// Minimum neurons per placement block (finer atoms than this exploit
/// pure sampling noise of the random connectome).
pub const MIN_BLOCK_NEURONS: u32 = 8;

/// Cap on greedy-comms refinement sweeps (each sweep strictly decreases
/// the integer objective, so convergence is typically well under this).
pub const GREEDY_REFINE_SWEEPS: usize = 20;

/// Relative cost of a link crossing tree level `g` in the greedy-comms
/// objective: `LINK_COST_BASE^g` (intra-board = 1, each fabric tier
/// another factor — same spirit as the interconnect model's per-tier
/// latency hierarchy).
pub const LINK_COST_BASE: i64 = 16;

/// The ascending, disjoint, coalesced gid intervals owned by one rank,
/// with prefix offsets for O(log k) local↔global index mapping.
///
/// ```
/// use dpsnn::engine::partition::OwnedGids;
///
/// let o = OwnedGids::from_intervals(vec![(10, 12), (40, 43)]);
/// assert_eq!(o.len(), 5);
/// assert_eq!(o.iter().collect::<Vec<_>>(), vec![10, 11, 40, 41, 42]);
/// assert_eq!(o.gid_of(2), 40);
/// assert_eq!(o.local_of(41), 3);
/// assert_eq!(o.try_local_of(12), None);
/// assert!(!o.is_contiguous());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OwnedGids {
    /// Ascending, disjoint `[lo, hi)` intervals; adjacent intervals are
    /// always coalesced, so contiguity ⇔ `intervals.len() <= 1`.
    intervals: Vec<(u32, u32)>,
    /// `offsets[i]` = owned gids preceding `intervals[i]`; one terminal
    /// entry equal to `len()`.
    offsets: Vec<u32>,
}

impl OwnedGids {
    /// The single contiguous range `[lo, hi)`.
    pub fn contiguous(lo: u32, hi: u32) -> Self {
        assert!(lo < hi, "empty or inverted range [{lo},{hi})");
        Self { intervals: vec![(lo, hi)], offsets: vec![0, hi - lo] }
    }

    /// Build from ascending, disjoint `[lo, hi)` intervals (adjacent
    /// ones are coalesced).
    pub fn from_intervals(intervals: Vec<(u32, u32)>) -> Self {
        assert!(!intervals.is_empty(), "a rank must own at least one gid");
        let mut merged: Vec<(u32, u32)> = Vec::with_capacity(intervals.len());
        for (lo, hi) in intervals {
            assert!(lo < hi, "empty or inverted interval [{lo},{hi})");
            match merged.last_mut() {
                Some(last) if last.1 == lo => last.1 = hi,
                Some(last) => {
                    assert!(last.1 < lo, "intervals not ascending/disjoint");
                    merged.push((lo, hi));
                }
                None => merged.push((lo, hi)),
            }
        }
        let mut offsets = Vec::with_capacity(merged.len() + 1);
        let mut acc = 0u32;
        offsets.push(0);
        for &(lo, hi) in &merged {
            acc += hi - lo;
            offsets.push(acc);
        }
        Self { intervals: merged, offsets }
    }

    /// Number of owned gids.
    pub fn len(&self) -> u32 {
        *self.offsets.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The coalesced `[lo, hi)` intervals, ascending.
    pub fn intervals(&self) -> &[(u32, u32)] {
        &self.intervals
    }

    /// Does this rank own a single contiguous range?
    pub fn is_contiguous(&self) -> bool {
        self.intervals.len() <= 1
    }

    /// Smallest owned gid.
    pub fn first(&self) -> u32 {
        self.intervals[0].0
    }

    /// Local index → global id.
    ///
    /// # Panics
    /// Panics when `local >= len()`.
    pub fn gid_of(&self, local: u32) -> u32 {
        assert!(local < self.len(), "local index {local} out of range");
        let i = self.offsets.partition_point(|&o| o <= local) - 1;
        self.intervals[i].0 + (local - self.offsets[i])
    }

    /// Global id → local index, `None` when not owned.
    pub fn try_local_of(&self, gid: u32) -> Option<u32> {
        let i = self.intervals.partition_point(|&(lo, _)| lo <= gid);
        if i == 0 {
            return None;
        }
        let (lo, hi) = self.intervals[i - 1];
        (gid < hi).then(|| self.offsets[i - 1] + (gid - lo))
    }

    /// Global id → local index.
    ///
    /// # Panics
    /// Panics when `gid` is not owned — delivering to (or emitting
    /// from) a non-resident neuron is a protocol violation.
    pub fn local_of(&self, gid: u32) -> u32 {
        self.try_local_of(gid)
            .unwrap_or_else(|| panic!("gid {gid} is not owned by this rank"))
    }

    pub fn contains(&self, gid: u32) -> bool {
        self.try_local_of(gid).is_some()
    }

    /// All owned gids in ascending (= local index) order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.intervals.iter().flat_map(|&(lo, hi)| lo..hi)
    }
}

/// The placement atoms every [`Allocator`] works over: `n` gids cut
/// into `p * blocks_per_rank` equal contiguous blocks on the floor grid
/// `bounds[b] = ⌊b·n/B⌋`, so every policy hands each rank exactly
/// `blocks_per_rank` atoms (perfect neuron balance to within the grid)
/// and the `index` policy composes to the historical even split.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockGrid {
    n: u32,
    p: u32,
    blocks_per_rank: u32,
    /// `bounds[b] = ⌊b·n/B⌋`; block `b` covers `[bounds[b], bounds[b+1])`.
    bounds: Vec<u32>,
}

impl BlockGrid {
    pub fn new(n: u32, p: u32) -> Self {
        assert!(p >= 1 && n >= p, "cannot split {n} neurons over {p} ranks");
        let blocks_per_rank =
            ((n / p) / MIN_BLOCK_NEURONS).clamp(1, MAX_BLOCKS_PER_RANK);
        let b = p * blocks_per_rank;
        let bounds = (0..=b)
            .map(|i| ((i as u64 * n as u64) / b as u64) as u32)
            .collect();
        Self { n, p, blocks_per_rank, bounds }
    }

    pub fn n_total(&self) -> u32 {
        self.n
    }

    pub fn n_ranks(&self) -> u32 {
        self.p
    }

    pub fn blocks_per_rank(&self) -> u32 {
        self.blocks_per_rank
    }

    pub fn n_blocks(&self) -> u32 {
        self.p * self.blocks_per_rank
    }

    /// Gid range `[lo, hi)` of block `b`.
    pub fn block_range(&self, b: u32) -> (u32, u32) {
        (self.bounds[b as usize], self.bounds[b as usize + 1])
    }

    /// Block containing `gid`: closed form of the floor grid,
    /// `⌊((gid+1)·B − 1)/n⌋` = the largest `b` with `bounds[b] <= gid`.
    #[inline]
    pub fn block_of(&self, gid: u32) -> u32 {
        debug_assert!(gid < self.n);
        (((gid as u64 + 1) * self.n_blocks() as u64 - 1) / self.n as u64) as u32
    }
}

/// Read-only inputs a placement policy may consult. `index` and
/// `round-robin` ignore both; `greedy-comms` requires `connectivity`
/// and treats a missing `tree` as a flat topology (uniform off-rank
/// link cost).
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocContext<'a> {
    /// Partition-independent connectome (affinity source).
    pub connectivity: Option<&'a ConnectivityParams>,
    /// Topology tree the run exchanges over (link-level costs).
    pub tree: Option<&'a TopologyTree>,
}

impl AllocContext<'static> {
    /// No connectivity, no tree — enough for `index` and `round-robin`.
    pub fn empty() -> Self {
        Self { connectivity: None, tree: None }
    }
}

/// A neuron→rank placement policy over a [`BlockGrid`]: returns the
/// owning rank of every block (`assignment[b] < grid.n_ranks()`), with
/// exactly `grid.blocks_per_rank()` blocks per rank. Implementations
/// must be deterministic — placement is part of the reproducible run
/// configuration, not a tuning knob that may drift between runs.
pub trait Allocator {
    fn assign(&self, grid: &BlockGrid, ctx: &AllocContext<'_>) -> Vec<u32>;
}

/// Consecutive blocks per rank: block `b` → rank `b / blocks_per_rank`.
/// Composes with the floor grid to exactly the historical
/// [`Partition::even`] contiguous split.
pub struct IndexAllocator;

impl Allocator for IndexAllocator {
    fn assign(&self, grid: &BlockGrid, _ctx: &AllocContext<'_>) -> Vec<u32> {
        (0..grid.n_blocks()).map(|b| b / grid.blocks_per_rank()).collect()
    }
}

/// Block `b` → rank `b % p`: neighbouring blocks land on different
/// ranks, maximally scattering any locality the connectome has.
pub struct RoundRobinAllocator;

impl Allocator for RoundRobinAllocator {
    fn assign(&self, grid: &BlockGrid, _ctx: &AllocContext<'_>) -> Vec<u32> {
        (0..grid.n_blocks()).map(|b| b % grid.n_ranks()).collect()
    }
}

/// Comm-aware placement: minimize
/// `Σ_{block pairs} affinity(i,j) · link_cost(rank_i, rank_j)` where
/// affinity is the symmetric synapse count between blocks (one
/// partition-independent n×m sweep of the connectome) and `link_cost`
/// is 0 on the same rank, else [`LINK_COST_BASE`]`^link_level` from the
/// topology tree (uniform off-rank cost when no tree is given).
///
/// Two deterministic stages: a capacity-constrained greedy construction
/// (blocks in descending total-affinity order, each placed on the open
/// rank of least marginal cost), then first-improvement block-swap
/// sweeps (at most [`GREEDY_REFINE_SWEEPS`]; each accepted swap
/// strictly decreases the integer objective).
pub struct GreedyCommsAllocator;

impl GreedyCommsAllocator {
    /// `p × p` symmetric link-cost matrix for the greedy objective.
    fn link_costs(p: usize, tree: Option<&TopologyTree>) -> Vec<i64> {
        let mut w = vec![0i64; p * p];
        for a in 0..p {
            for b in 0..p {
                if a == b {
                    continue;
                }
                w[a * p + b] = match tree {
                    Some(t) => {
                        LINK_COST_BASE.pow(t.link_level(a as u32, b as u32) as u32)
                    }
                    None => 1,
                };
            }
        }
        w
    }

    /// Symmetric block-pair affinity from one n×m connectome sweep.
    fn affinity(grid: &BlockGrid, cp: &ConnectivityParams) -> Vec<i64> {
        let nb = grid.n_blocks() as usize;
        let mut aff = vec![0i64; nb * nb];
        for s in 0..cp.n {
            let sb = grid.block_of(s) as usize;
            for k in 0..cp.m {
                let (t, _) = cp.synapse(s, k);
                let tb = grid.block_of(t) as usize;
                aff[sb * nb + tb] += 1;
                aff[tb * nb + sb] += 1;
            }
        }
        aff
    }
}

impl Allocator for GreedyCommsAllocator {
    fn assign(&self, grid: &BlockGrid, ctx: &AllocContext<'_>) -> Vec<u32> {
        let cp = ctx
            .connectivity
            .expect("greedy-comms placement needs ConnectivityParams in the AllocContext");
        assert_eq!(cp.n, grid.n_total(), "connectome/grid size mismatch");
        let nb = grid.n_blocks() as usize;
        let p = grid.n_ranks() as usize;
        let cap = grid.blocks_per_rank() as usize;
        let aff = Self::affinity(grid, cp);
        let w = Self::link_costs(p, ctx.tree);

        // Greedy construction: heaviest blocks first, each onto the
        // open rank with least marginal cost (ties → lowest rank).
        let totals: Vec<i64> =
            (0..nb).map(|i| aff[i * nb..(i + 1) * nb].iter().sum()).collect();
        let mut order: Vec<usize> = (0..nb).collect();
        order.sort_by(|&x, &y| totals[y].cmp(&totals[x]).then(x.cmp(&y)));
        const UNASSIGNED: u32 = u32::MAX;
        let mut rank_of = vec![UNASSIGNED; nb];
        let mut load = vec![0usize; p];
        for &i in &order {
            let mut best_rank = usize::MAX;
            let mut best_cost = i64::MAX;
            for r in 0..p {
                if load[r] >= cap {
                    continue;
                }
                let mut cost = 0i64;
                for j in 0..nb {
                    let rj = rank_of[j];
                    if rj != UNASSIGNED {
                        cost += aff[i * nb + j] * w[r * p + rj as usize];
                    }
                }
                if cost < best_cost {
                    best_cost = cost;
                    best_rank = r;
                }
            }
            rank_of[i] = best_rank as u32;
            load[best_rank] += 1;
        }

        // Swap refinement: for each block, the best strictly-improving
        // swap partner this sweep (exact integer delta; ties → lowest
        // partner index). Capacities are preserved by construction.
        for _sweep in 0..GREEDY_REFINE_SWEEPS {
            let mut improved = false;
            for i in 0..nb {
                let a = rank_of[i] as usize;
                // a1[r] = Σ_x aff[i][x] · (w[r][r_x] − w[a][r_x])
                let mut a1 = vec![0i64; p];
                for x in 0..nb {
                    let av = aff[i * nb + x];
                    if av != 0 {
                        let rx = rank_of[x] as usize;
                        let base = w[a * p + rx];
                        for (r, slot) in a1.iter_mut().enumerate() {
                            *slot += av * (w[r * p + rx] - base);
                        }
                    }
                }
                let mut best_j = usize::MAX;
                let mut best_delta = 0i64;
                for j in 0..nb {
                    let b = rank_of[j] as usize;
                    if b == a {
                        continue;
                    }
                    // Δ = Σ_x (aff[i,x]−aff[j,x])·(w[b,r_x]−w[a,r_x])
                    //     − w[a,b]·(aff[i,i]+aff[j,j]−2·aff[i,j])
                    let mut dot = 0i64;
                    for x in 0..nb {
                        let av = aff[j * nb + x];
                        if av != 0 {
                            let rx = rank_of[x] as usize;
                            dot += av * (w[b * p + rx] - w[a * p + rx]);
                        }
                    }
                    let corr = w[a * p + b]
                        * (aff[i * nb + i] + aff[j * nb + j] - 2 * aff[i * nb + j]);
                    let delta = a1[b] - dot - corr;
                    if delta < best_delta {
                        best_delta = delta;
                        best_j = j;
                    }
                }
                if best_j != usize::MAX {
                    rank_of.swap(i, best_j);
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
        rank_of
    }
}

/// A neuron→rank placement: per-rank [`OwnedGids`] plus a compact
/// atom table (`atom_bounds` + `atom_rank`) for O(log atoms) ownership
/// lookup. Constructed either contiguously ([`Partition::even`],
/// [`Partition::weighted`]) or through an [`Allocator`] policy
/// ([`Partition::allocate`]).
///
/// Two partitions compare equal iff they give every rank the same gids
/// — the atom granularity they were built over is irrelevant:
///
/// ```
/// use dpsnn::config::PartitionPolicy;
/// use dpsnn::engine::partition::{AllocContext, Partition};
///
/// let even = Partition::even(100, 4);
/// assert_eq!(even.range(1), (25, 50));
/// assert_eq!(even.owner(37), 1);
/// assert_eq!(even.sizes(), vec![25, 25, 25, 25]);
///
/// // `index` placement reproduces the contiguous even split exactly.
/// let ctx = AllocContext::empty();
/// let index = Partition::allocate(PartitionPolicy::Index, 100, 4, &ctx);
/// assert_eq!(index, even);
///
/// // `round-robin` scatters ownership; totals are preserved.
/// let rr = Partition::allocate(PartitionPolicy::RoundRobin, 100, 4, &ctx);
/// assert_eq!(rr.sizes().iter().sum::<u32>(), 100);
/// assert!(!rr.owned(0).is_contiguous());
/// assert_eq!(rr.owned(0).local_of(rr.owned(0).gid_of(3)), 3);
/// ```
#[derive(Debug, Clone)]
pub struct Partition {
    n: u32,
    /// Atom boundaries, strictly ascending, `atom_bounds[0] = 0` and
    /// `atom_bounds[last] = n`; atom `a` covers
    /// `[atom_bounds[a], atom_bounds[a+1])`.
    atom_bounds: Vec<u32>,
    /// Owning rank of each atom.
    atom_rank: Vec<u32>,
    /// Per-rank owned gid sets.
    owned: Vec<OwnedGids>,
}

impl PartialEq for Partition {
    /// Ownership equality: same `n` and the same gids on every rank.
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n && self.owned == other.owned
    }
}

impl Eq for Partition {}

impl Partition {
    fn from_atoms(n: u32, atom_bounds: Vec<u32>, atom_rank: Vec<u32>, p: u32) -> Self {
        debug_assert_eq!(atom_bounds.len(), atom_rank.len() + 1);
        let mut per_rank: Vec<Vec<(u32, u32)>> = vec![Vec::new(); p as usize];
        for (a, &r) in atom_rank.iter().enumerate() {
            assert!(r < p, "atom {a} assigned to rank {r} >= {p}");
            per_rank[r as usize].push((atom_bounds[a], atom_bounds[a + 1]));
        }
        let owned: Vec<OwnedGids> = per_rank
            .into_iter()
            .enumerate()
            .map(|(r, iv)| {
                assert!(!iv.is_empty(), "rank {r} received no placement blocks");
                OwnedGids::from_intervals(iv)
            })
            .collect();
        debug_assert_eq!(owned.iter().map(|o| o.len() as u64).sum::<u64>(), n as u64);
        Self { n, atom_bounds, atom_rank, owned }
    }

    /// Even contiguous split (remainder spread over the first ranks).
    pub fn even(n: u32, p: u32) -> Self {
        assert!(p >= 1 && n >= p, "cannot split {n} neurons over {p} ranks");
        let bounds: Vec<u32> = (0..=p)
            .map(|r| ((r as u64 * n as u64) / p as u64) as u32)
            .collect();
        let ranks = (0..p).collect();
        Self::from_atoms(n, bounds, ranks, p)
    }

    /// Contiguous split proportional to `weights` (e.g. relative core
    /// speeds), each rank receiving at least one neuron.
    pub fn weighted(n: u32, weights: &[f64]) -> Self {
        let p = weights.len() as u32;
        assert!(p >= 1 && n >= p);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        let mut bounds = Vec::with_capacity(p as usize + 1);
        bounds.push(0u32);
        let mut acc = 0.0;
        for (r, w) in weights.iter().enumerate() {
            acc += w;
            let mut b = ((acc / total) * n as f64).round() as u32;
            let prev = *bounds.last().unwrap();
            // keep at least 1 neuron per rank and leave room for the rest
            let remaining_ranks = (p as usize - r - 1) as u32;
            b = b.max(prev + 1).min(n - remaining_ranks);
            bounds.push(b);
        }
        *bounds.last_mut().unwrap() = n;
        let ranks = (0..p).collect();
        Self::from_atoms(n, bounds, ranks, p)
    }

    /// Build from a block grid and an allocator's block→rank assignment.
    pub fn from_blocks(grid: &BlockGrid, assignment: &[u32]) -> Self {
        assert_eq!(assignment.len(), grid.n_blocks() as usize);
        Self::from_atoms(
            grid.n_total(),
            grid.bounds.clone(),
            assignment.to_vec(),
            grid.n_ranks(),
        )
    }

    /// Place `n` neurons onto `p` ranks under `policy` (the CLI
    /// `--partition` entry point). `greedy-comms` requires
    /// `ctx.connectivity`; a missing `ctx.tree` means flat link costs.
    pub fn allocate(
        policy: PartitionPolicy,
        n: u32,
        p: u32,
        ctx: &AllocContext<'_>,
    ) -> Self {
        let grid = BlockGrid::new(n, p);
        let assignment = match policy {
            PartitionPolicy::Index => IndexAllocator.assign(&grid, ctx),
            PartitionPolicy::RoundRobin => RoundRobinAllocator.assign(&grid, ctx),
            PartitionPolicy::GreedyComms => GreedyCommsAllocator.assign(&grid, ctx),
        };
        Self::from_blocks(&grid, &assignment)
    }

    pub fn n_ranks(&self) -> u32 {
        self.owned.len() as u32
    }

    pub fn n_total(&self) -> u32 {
        self.n
    }

    /// The gids owned by rank `r`.
    pub fn owned(&self, r: u32) -> &OwnedGids {
        &self.owned[r as usize]
    }

    /// Global id range of rank `r` — only meaningful for contiguous
    /// placements (`even`, `weighted`, `index`).
    ///
    /// # Panics
    /// Panics when rank `r` owns a non-contiguous gid set; use
    /// [`Partition::owned`] there instead.
    pub fn range(&self, r: u32) -> (u32, u32) {
        let o = &self.owned[r as usize];
        assert!(
            o.is_contiguous(),
            "rank {r} owns non-contiguous gids under this placement; use owned()"
        );
        o.intervals()[0]
    }

    pub fn size(&self, r: u32) -> u32 {
        self.owned[r as usize].len()
    }

    /// Which rank owns neuron `gid` (binary search over atoms).
    ///
    /// # Panics
    /// Panics when `gid >= n_total()`, in release builds too — asking
    /// for the owner of a gid outside the network is a protocol
    /// violation. Use [`Partition::try_owner`] for a checked lookup.
    pub fn owner(&self, gid: u32) -> u32 {
        assert!(
            gid < self.n,
            "gid {gid} out of range: partition covers [0, {})",
            self.n
        );
        let atom = match self.atom_bounds.binary_search(&gid) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        self.atom_rank[atom]
    }

    /// Checked owner lookup: `None` when `gid >= n_total()`.
    pub fn try_owner(&self, gid: u32) -> Option<u32> {
        (gid < self.n).then(|| self.owner(gid))
    }

    pub fn sizes(&self) -> Vec<u32> {
        (0..self.n_ranks()).map(|r| self.size(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn even_split_covers_everything() {
        let p = Partition::even(100, 7);
        assert_eq!(p.n_ranks(), 7);
        let total: u32 = p.sizes().iter().sum();
        assert_eq!(total, 100);
        // sizes differ by at most one
        let sizes = p.sizes();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }

    #[test]
    fn owner_is_consistent_with_range() {
        let p = Partition::even(97, 5);
        for gid in 0..97 {
            let r = p.owner(gid);
            let (lo, hi) = p.range(r);
            assert!(gid >= lo && gid < hi, "gid {gid} rank {r} range {lo}..{hi}");
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn owner_panics_past_the_boundary_gid() {
        // plain assert!, not debug_assert! — must fire in release too
        Partition::even(100, 4).owner(100);
    }

    #[test]
    fn try_owner_is_checked_at_the_boundary() {
        let p = Partition::even(100, 4);
        assert_eq!(p.try_owner(99), Some(3));
        assert_eq!(p.try_owner(100), None);
        assert_eq!(p.try_owner(u32::MAX), None);
    }

    #[test]
    fn weighted_respects_ratios() {
        // Intel ~10x faster than Trenz ARM: 2 intel + 2 arm ranks
        let p = Partition::weighted(2200, &[10.0, 10.0, 1.0, 1.0]);
        let s = p.sizes();
        assert_eq!(s.iter().sum::<u32>(), 2200);
        assert!(s[0] > 900 && s[0] < 1100, "{s:?}");
        assert!(s[2] > 50 && s[2] < 150, "{s:?}");
    }

    #[test]
    fn weighted_always_gives_everyone_at_least_one() {
        let p = Partition::weighted(10, &[1000.0, 0.001, 0.001, 1000.0]);
        assert!(p.sizes().iter().all(|&s| s >= 1), "{:?}", p.sizes());
        assert_eq!(p.sizes().iter().sum::<u32>(), 10);
    }

    #[test]
    fn property_even_and_weighted_cover_exactly() {
        forall("partition covers", 100, |rng| {
            let p = 1 + rng.next_below(16);
            let n = p + rng.next_below(1000);
            let part = Partition::even(n, p);
            assert_eq!(part.sizes().iter().sum::<u32>(), n);
            for gid in (0..n).step_by(7) {
                let r = part.owner(gid);
                let (lo, hi) = part.range(r);
                assert!(gid >= lo && gid < hi);
            }
            let weights: Vec<f64> =
                (0..p).map(|_| 0.1 + rng.next_f64() * 10.0).collect();
            let wp = Partition::weighted(n, &weights);
            assert_eq!(wp.sizes().iter().sum::<u32>(), n);
            assert!(wp.sizes().iter().all(|&s| s >= 1));
        });
    }

    #[test]
    fn block_grid_closed_form_matches_bounds() {
        forall("block_of closed form", 50, |rng| {
            let p = 1 + rng.next_below(12);
            let n = p + rng.next_below(3000);
            let grid = BlockGrid::new(n, p);
            assert_eq!(grid.n_blocks(), grid.n_ranks() * grid.blocks_per_rank());
            for b in 0..grid.n_blocks() {
                let (lo, hi) = grid.block_range(b);
                assert!(lo < hi, "empty block {b}");
                assert_eq!(grid.block_of(lo), b);
                assert_eq!(grid.block_of(hi - 1), b);
            }
        });
    }

    #[test]
    fn index_allocation_reproduces_even_exactly() {
        for (n, p) in [(100u32, 4u32), (97, 5), (2048, 8), (20_480, 8), (33, 33)] {
            let idx = Partition::allocate(
                PartitionPolicy::Index,
                n,
                p,
                &AllocContext::empty(),
            );
            let even = Partition::even(n, p);
            assert_eq!(idx, even, "n={n} p={p}");
            for gid in (0..n).step_by(13) {
                assert_eq!(idx.owner(gid), even.owner(gid));
            }
        }
    }

    #[test]
    fn round_robin_scatters_and_covers() {
        let p = Partition::allocate(
            PartitionPolicy::RoundRobin,
            1024,
            8,
            &AllocContext::empty(),
        );
        assert_eq!(p.sizes().iter().sum::<u32>(), 1024);
        assert!(p.sizes().iter().all(|&s| s >= 1));
        assert!(!p.owned(0).is_contiguous());
        // every gid owned exactly once
        let mut counts = vec![0u32; 1024];
        for r in 0..8 {
            for gid in p.owned(r).iter() {
                counts[gid as usize] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn owned_gids_lookups_roundtrip() {
        let p = Partition::allocate(
            PartitionPolicy::RoundRobin,
            500,
            4,
            &AllocContext::empty(),
        );
        for r in 0..4 {
            let o = p.owned(r);
            for (local, gid) in o.iter().enumerate() {
                assert_eq!(o.gid_of(local as u32), gid);
                assert_eq!(o.local_of(gid), local as u32);
                assert_eq!(p.owner(gid), r);
            }
            // a gid owned by someone else is not resident here
            let foreign = p.owned((r + 1) % 4).first();
            assert_eq!(o.try_local_of(foreign), None);
        }
    }

    /// The greedy objective on a concrete assignment (test oracle).
    fn weighted_cost(
        grid: &BlockGrid,
        cp: &ConnectivityParams,
        tree: Option<&TopologyTree>,
        assignment: &[u32],
    ) -> i64 {
        let nb = grid.n_blocks() as usize;
        let p = grid.n_ranks() as usize;
        let aff = GreedyCommsAllocator::affinity(grid, cp);
        let w = GreedyCommsAllocator::link_costs(p, tree);
        let mut cost = 0i64;
        for i in 0..nb {
            for j in 0..nb {
                cost += aff[i * nb + j]
                    * w[assignment[i] as usize * p + assignment[j] as usize];
            }
        }
        cost / 2
    }

    #[test]
    fn greedy_comms_covers_and_beats_index_on_its_objective() {
        let cp = ConnectivityParams { seed: 7, n: 512, m: 4, dmin: 1, dmax: 4 };
        let tree = TopologyTree::new(4, &[2]);
        let ctx = AllocContext { connectivity: Some(&cp), tree: Some(&tree) };
        let grid = BlockGrid::new(512, 4);
        let greedy = GreedyCommsAllocator.assign(&grid, &ctx);
        let index = IndexAllocator.assign(&grid, &ctx);
        // capacity respected
        let mut load = vec![0u32; 4];
        for &r in &greedy {
            load[r as usize] += 1;
        }
        assert!(load.iter().all(|&l| l == grid.blocks_per_rank()));
        // the refined placement is no worse than index order on the
        // weighted objective (strictly better for this seed)
        let cg = weighted_cost(&grid, &cp, Some(&tree), &greedy);
        let ci = weighted_cost(&grid, &cp, Some(&tree), &index);
        assert!(cg < ci, "greedy {cg} vs index {ci}");
        // and the partition built from it covers everything
        let part = Partition::from_blocks(&grid, &greedy);
        assert_eq!(part.sizes().iter().sum::<u32>(), 512);
    }

    #[test]
    fn greedy_comms_is_deterministic() {
        let cp = ConnectivityParams { seed: 3, n: 300, m: 3, dmin: 1, dmax: 2 };
        let tree = TopologyTree::new(6, &[2]);
        let ctx = AllocContext { connectivity: Some(&cp), tree: Some(&tree) };
        let a = Partition::allocate(PartitionPolicy::GreedyComms, 300, 6, &ctx);
        let b = Partition::allocate(PartitionPolicy::GreedyComms, 300, 6, &ctx);
        assert_eq!(a, b);
    }

    #[test]
    fn owned_gids_coalesces_adjacent_intervals() {
        let o = OwnedGids::from_intervals(vec![(0, 4), (4, 8), (20, 21)]);
        assert_eq!(o.intervals(), &[(0, 8), (20, 21)]);
        assert_eq!(o.len(), 9);
        assert_eq!(o.gid_of(8), 20);
        assert_eq!(o.local_of(20), 8);
        assert!(o.contains(7) && !o.contains(8) && !o.contains(19));
        let c = OwnedGids::contiguous(5, 9);
        assert!(c.is_contiguous());
        assert_eq!(c.first(), 5);
        assert_eq!(c.len(), 4);
    }
}
