//! Neuron-to-process partitioning.
//!
//! The paper distributes neurons evenly among processes; the heterogeneous
//! Intel+ARM runs additionally weight the shares by per-core speed
//! (`weighted`), mirroring DPSNN's MPI "heterogeneous mode" partitioning.

/// Contiguous block partition of `n` neurons over `p` ranks.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// Block boundaries: rank r owns [bounds[r], bounds[r+1]).
    bounds: Vec<u32>,
}

impl Partition {
    /// Even split (remainder spread over the first ranks).
    pub fn even(n: u32, p: u32) -> Self {
        assert!(p >= 1 && n >= p, "cannot split {n} neurons over {p} ranks");
        let bounds = (0..=p)
            .map(|r| ((r as u64 * n as u64) / p as u64) as u32)
            .collect();
        Self { bounds }
    }

    /// Split proportional to `weights` (e.g. relative core speeds), each
    /// rank receiving at least one neuron.
    pub fn weighted(n: u32, weights: &[f64]) -> Self {
        let p = weights.len() as u32;
        assert!(p >= 1 && n >= p);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        let mut bounds = Vec::with_capacity(p as usize + 1);
        bounds.push(0u32);
        let mut acc = 0.0;
        for (r, w) in weights.iter().enumerate() {
            acc += w;
            let mut b = ((acc / total) * n as f64).round() as u32;
            let prev = *bounds.last().unwrap();
            // keep at least 1 neuron per rank and leave room for the rest
            let remaining_ranks = (p as usize - r - 1) as u32;
            b = b.max(prev + 1).min(n - remaining_ranks);
            bounds.push(b);
        }
        *bounds.last_mut().unwrap() = n;
        Self { bounds }
    }

    pub fn n_ranks(&self) -> u32 {
        (self.bounds.len() - 1) as u32
    }

    pub fn n_total(&self) -> u32 {
        *self.bounds.last().unwrap()
    }

    /// Global id range owned by rank `r`.
    pub fn range(&self, r: u32) -> (u32, u32) {
        (self.bounds[r as usize], self.bounds[r as usize + 1])
    }

    pub fn size(&self, r: u32) -> u32 {
        let (lo, hi) = self.range(r);
        hi - lo
    }

    /// Which rank owns neuron `gid` (binary search).
    pub fn owner(&self, gid: u32) -> u32 {
        debug_assert!(gid < self.n_total());
        match self.bounds.binary_search(&gid) {
            Ok(i) => {
                // gid is exactly a boundary: it belongs to the block starting here,
                // unless this is the terminal bound.
                (i as u32).min(self.n_ranks() - 1)
            }
            Err(i) => (i - 1) as u32,
        }
    }

    pub fn sizes(&self) -> Vec<u32> {
        (0..self.n_ranks()).map(|r| self.size(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn even_split_covers_everything() {
        let p = Partition::even(100, 7);
        assert_eq!(p.n_ranks(), 7);
        let total: u32 = p.sizes().iter().sum();
        assert_eq!(total, 100);
        // sizes differ by at most one
        let sizes = p.sizes();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }

    #[test]
    fn owner_is_consistent_with_range() {
        let p = Partition::even(97, 5);
        for gid in 0..97 {
            let r = p.owner(gid);
            let (lo, hi) = p.range(r);
            assert!(gid >= lo && gid < hi, "gid {gid} rank {r} range {lo}..{hi}");
        }
    }

    #[test]
    fn weighted_respects_ratios() {
        // Intel ~10x faster than Trenz ARM: 2 intel + 2 arm ranks
        let p = Partition::weighted(2200, &[10.0, 10.0, 1.0, 1.0]);
        let s = p.sizes();
        assert_eq!(s.iter().sum::<u32>(), 2200);
        assert!(s[0] > 900 && s[0] < 1100, "{s:?}");
        assert!(s[2] > 50 && s[2] < 150, "{s:?}");
    }

    #[test]
    fn weighted_always_gives_everyone_at_least_one() {
        let p = Partition::weighted(10, &[1000.0, 0.001, 0.001, 1000.0]);
        assert!(p.sizes().iter().all(|&s| s >= 1), "{:?}", p.sizes());
        assert_eq!(p.sizes().iter().sum::<u32>(), 10);
    }

    #[test]
    fn property_even_and_weighted_cover_exactly() {
        forall("partition covers", 100, |rng| {
            let p = 1 + rng.next_below(16);
            let n = p + rng.next_below(1000);
            let part = Partition::even(n, p);
            assert_eq!(part.sizes().iter().sum::<u32>(), n);
            for gid in (0..n).step_by(7) {
                let r = part.owner(gid);
                let (lo, hi) = part.range(r);
                assert!(gid >= lo && gid < hi);
            }
            let weights: Vec<f64> =
                (0..p).map(|_| 0.1 + rng.next_f64() * 10.0).collect();
            let wp = Partition::weighted(n, &weights);
            assert_eq!(wp.sizes().iter().sum::<u32>(), n);
            assert!(wp.sizes().iter().all(|&s| s >= 1));
        });
    }
}
