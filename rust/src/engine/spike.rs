//! Spike events in AER (Address-Event Representation).

/// One spike: the emitting neuron's global id and its emission step.
/// On the wire this is the paper's 12-byte AER payload
/// (see [`crate::comm::aer`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Spike {
    pub gid: u32,
    pub step: u32,
}

impl Spike {
    pub fn new(gid: u32, step: u32) -> Self {
        Self { gid, step }
    }

    /// Emission time in milliseconds given the network step size.
    pub fn time_ms(&self, dt_ms: f64) -> f64 {
        self.step as f64 * dt_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversion() {
        let s = Spike::new(7, 250);
        assert_eq!(s.time_ms(1.0), 250.0);
        assert_eq!(s.time_ms(0.5), 125.0);
    }
}
