//! Axonal delay ring: the time-delay queues of DPSNN.
//!
//! Because the paper's synapses inject *instantaneous* post-synaptic
//! currents, delivering a spike along a synapse with delay `d` is exactly
//! "add the synaptic weight to the target's input current at step t+d".
//! The ring therefore holds one dense per-neuron accumulator per future
//! step — allocation-free in steady state, and the accumulation order
//! cannot change the result because weights live on the exact 2^-10 grid
//! (see `config::network::WEIGHT_QUANTUM`).
//!
//! **Hot path** (EXPERIMENTS.md §Perf): storage is one flat cache-aligned
//! `depth * stride` array (`stride` = n padded to a whole cache line, so
//! every slot row starts on a 64 B boundary). Rows are stored delay-major
//! with ascending targets inside each delay run (see
//! `IncomingSynapses::build`), so [`DelayRing::deliver_row_offset`] scans
//! each run once, computes the slot base once, and the inner
//! weight-accumulate walks ascending offsets of a single slot row —
//! unit-direction, branch-free, unchecked (safety: targets and delays are
//! validated at construction by
//! [`crate::model::connectivity::IncomingSynapses`]).
//! [`DelayRing::deliver_row_offset`] shifts delivery `back` steps toward
//! the present — the epoch-batched exchange delivers a whole min-delay
//! window of buffered spikes at once, each landing in the slot per-step
//! delivery would have used.
//!
//! For `--compute-threads N`, [`DelayRing::shard`] hands out a raw view
//! that can deliver the *same* rows restricted to a target sub-range
//! ([`RingShard::deliver_row_offset_ranged`]): each worker walks every
//! spike's row but writes only its own targets, so every accumulator
//! receives exactly the per-step add sequence regardless of the thread
//! count — bitwise determinism by construction.

use crate::util::aligned::{AlignedF32, LANES_PER_LINE};

/// Ring of `depth` future input-current accumulators over `n` local neurons.
#[derive(Debug, Clone)]
pub struct DelayRing {
    /// slot-major flat storage: slots[s * stride + j]; the pad lanes
    /// [n, stride) of each slot stay zero forever.
    flat: AlignedF32,
    n: usize,
    /// Slot row pitch: n rounded up to a whole 64 B cache line.
    stride: usize,
    depth: usize,
    /// Slot index holding "the step currently being integrated".
    cur: usize,
}

impl DelayRing {
    /// `max_delay` is the largest delay in steps the ring must hold;
    /// slot for delay d = (cur + d) mod (max_delay + 1).
    pub fn new(n: usize, max_delay: u32) -> Self {
        let depth = max_delay as usize + 1;
        let stride = n.div_ceil(LANES_PER_LINE).max(1) * LANES_PER_LINE;
        Self { flat: AlignedF32::zeroed(depth * stride), n, stride, depth, cur: 0 }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Accumulate `w` onto local neuron `tgt` to arrive `delay` steps from
    /// the step currently being integrated. `delay` must be in
    /// [1, max_delay].
    #[inline(always)]
    pub fn add(&mut self, delay: u8, tgt: u32, w: f32) {
        debug_assert!(
            (1..self.depth).contains(&(delay as usize)),
            "delay {delay} out of range 1..={}",
            self.depth - 1
        );
        debug_assert!((tgt as usize) < self.n);
        let mut slot = self.cur + delay as usize;
        if slot >= self.depth {
            slot -= self.depth;
        }
        self.flat[slot * self.stride + tgt as usize] += w;
    }

    /// Deliver one spike's whole fan-out: add `w` at `(delay, tgt)` for
    /// every synapse in the row. The caller guarantees (and
    /// `IncomingSynapses` construction enforces) `tgt < n` and
    /// `1 <= delay <= max_delay`.
    #[inline]
    pub fn deliver_row(&mut self, tgts: &[u32], delays: &[u8], w: f32) {
        self.deliver_row_offset(tgts, delays, w, 0);
    }

    /// [`Self::deliver_row`] for a spike emitted `back` steps before the
    /// step currently being integrated — the epoch-batched exchange,
    /// where spikes buffered over a min-delay window are all delivered
    /// at the epoch boundary. Each synapse lands at effective delay
    /// `d - back` (the `d + (t_emit - t_now)` slot), i.e. in the same
    /// absolute step as per-step delivery would have put it, so the
    /// raster is bitwise identical across exchange cadences. The caller
    /// guarantees `back < d` for every delay in the row; epochs never
    /// exceed `delay_min_steps`, which keeps every effective delay in
    /// `[1, max_delay]`.
    #[inline]
    pub fn deliver_row_offset(&mut self, tgts: &[u32], delays: &[u8], w: f32, back: u32) {
        // SAFETY: full target range — one writer, no concurrent shards.
        unsafe { self.shard().deliver_row_offset_ranged(tgts, delays, w, back, 0, self.n as u32) }
    }

    /// A raw, range-restrictable delivery view for the threaded path.
    /// Shards alias the ring's storage; see the safety contract on
    /// [`RingShard::deliver_row_offset_ranged`].
    pub fn shard(&mut self) -> RingShard {
        RingShard {
            flat: self.flat.as_mut_ptr(),
            stride: self.stride,
            depth: self.depth,
            cur: self.cur,
        }
    }

    /// Borrow the accumulator for the current step (the `i_syn` input of
    /// the neuron update). 64 B-aligned (slot rows sit on the line grid).
    pub fn current(&self) -> &[f32] {
        &self.flat[self.cur * self.stride..self.cur * self.stride + self.n]
    }

    /// Finish the current step: zero its slot and advance the ring.
    pub fn advance(&mut self) {
        let a = self.cur * self.stride;
        self.flat[a..a + self.n].iter_mut().for_each(|x| *x = 0.0);
        self.cur += 1;
        if self.cur == self.depth {
            self.cur = 0;
        }
    }

    /// Sum of everything still queued (test/diagnostic invariant helper).
    /// The pad lanes are permanently zero, so summing the whole flat
    /// array still counts each queued weight exactly once.
    pub fn queued_total(&self) -> f64 {
        self.flat.iter().map(|&x| x as f64).sum()
    }

    /// Resident bytes of the dense ring: `depth * stride` f32 slots.
    /// O(n * depth) — the closed form
    /// `metrics::memory::dense_ring_bytes` pins.
    pub fn resident_bytes(&self) -> usize {
        self.depth * self.stride * 4
    }
}

/// A copyable raw view of one [`DelayRing`]'s storage at a fixed step,
/// used by the `--compute-threads` delivery: every worker walks the same
/// spike rows through the same shard, restricted to a disjoint target
/// range.
#[derive(Clone, Copy)]
pub struct RingShard {
    flat: *mut f32,
    stride: usize,
    depth: usize,
    cur: usize,
}

// SAFETY: the shard itself is just a pointer + geometry; the aliasing
// discipline is the deliver contract below (disjoint target ranges).
unsafe impl Send for RingShard {}
unsafe impl Sync for RingShard {}

impl RingShard {
    /// [`DelayRing::deliver_row_offset`] restricted to targets in
    /// `[lo, hi)`. Rows are delay-major with ascending targets within
    /// each delay run, so the run's sub-range is found by binary search
    /// and the accumulate stays a unit-direction walk of one slot row.
    ///
    /// Writing only `[lo, hi)` means an accumulator owned by one chunk
    /// receives exactly the adds (in exactly the spike order) that the
    /// unranged single-thread delivery performs — the raster is bitwise
    /// identical for every chunk count.
    ///
    /// # Safety
    ///
    /// * The parent ring must outlive the shard and not be advanced,
    ///   resized or dropped while shards are live.
    /// * Concurrent callers must use pairwise-disjoint `[lo, hi)` ranges
    ///   (each f32 accumulator has exactly one writer).
    /// * As for the unranged path: `tgt < n`, `1 <= delay <= max_delay`,
    ///   `back < delay`, and within each equal-delay run targets ascend
    ///   (all guaranteed by `IncomingSynapses` construction).
    pub unsafe fn deliver_row_offset_ranged(
        &self,
        tgts: &[u32],
        delays: &[u8],
        w: f32,
        back: u32,
        lo: u32,
        hi: u32,
    ) {
        debug_assert_eq!(tgts.len(), delays.len());
        let m = tgts.len();
        let back = back as usize;
        let mut i = 0usize;
        while i < m {
            let d = delays[i];
            debug_assert!((1..self.depth).contains(&(d as usize)));
            debug_assert!(
                (d as usize) > back,
                "offset {back} >= delay {d}: spike delivered past its arrival step"
            );
            // one delay run: [i, j) with equal delay and ascending targets
            let mut j = i + 1;
            while j < m && delays[j] == d {
                debug_assert!(tgts[j - 1] <= tgts[j], "targets must ascend within a run");
                j += 1;
            }
            let mut slot = self.cur + d as usize - back;
            if slot >= self.depth {
                slot -= self.depth;
            }
            let base = slot * self.stride;
            let run = &tgts[i..j];
            let a = run.partition_point(|&t| t < lo);
            let b = run.partition_point(|&t| t < hi);
            for &t in &run[a..b] {
                // SAFETY (fn contract): slot < depth and t < n <= stride
                // (validated at build; see connectivity tests), so the
                // index is within flat's length; the disjoint-range
                // contract makes it data-race free.
                *self.flat.add(base + t as usize) += w;
            }
            i = j;
        }
    }
}

/// Memory-lean companion to [`DelayRing`] for the procedural
/// connectivity mode: instead of a dense `depth * n` accumulator grid,
/// it keeps ONE dense row (the step currently being integrated) plus a
/// compressed `(target, weight)` bucket per future slot. Resident bytes
/// are O(n + in-flight events), not O(n * depth) — at the paper's 3.2 Hz
/// regime the in-flight population is a small multiple of the per-epoch
/// synaptic events, so the ring shrinks by roughly the delay depth.
///
/// Determinism: buckets are split per compute chunk
/// (`buckets[slot * chunks + chunk]`), each chunk's delivery worker
/// appends only to its own bucket, and [`Self::advance`] drains the
/// incoming slot's buckets chunk-ascending in append order. Every target
/// lives in exactly one chunk, so its accumulator receives exactly the
/// add sequence the dense ring's ranged delivery performs — the raster
/// stays bitwise identical across ring kinds and chunk counts (and the
/// exact 2^-10 weight grid makes the sums order-independent anyway).
#[derive(Debug, Clone)]
pub struct CompressedDelayRing {
    /// The current step's dense accumulator row (stride-padded so the
    /// neuron update reads a 64 B-aligned slice, like [`DelayRing`]).
    current: AlignedF32,
    n: usize,
    stride: usize,
    depth: usize,
    cur: usize,
    chunks: usize,
    /// Pending arrivals per `[slot * chunks + chunk]`, in append order.
    buckets: Vec<Vec<(u32, f32)>>,
}

impl CompressedDelayRing {
    /// `max_delay` as for [`DelayRing::new`]; `chunks` is the delivery
    /// chunk count (the `--compute-threads` geometry) the bucket split
    /// mirrors.
    pub fn new(n: usize, max_delay: u32, chunks: usize) -> Self {
        assert!(chunks >= 1, "need at least one delivery chunk");
        let depth = max_delay as usize + 1;
        let stride = n.div_ceil(LANES_PER_LINE).max(1) * LANES_PER_LINE;
        Self {
            current: AlignedF32::zeroed(stride),
            n,
            stride,
            depth,
            cur: 0,
            chunks,
            buckets: vec![Vec::new(); depth * chunks],
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn chunks(&self) -> usize {
        self.chunks
    }

    /// Queue `w` onto local neuron `tgt`, `delay` steps from the step
    /// currently being integrated, through chunk 0's bucket (the
    /// single-chunk convenience mirroring [`DelayRing::add`]; the
    /// threaded path appends through [`CompressedRingShard`] instead).
    #[inline]
    pub fn add(&mut self, delay: u8, tgt: u32, w: f32) {
        debug_assert!(
            (1..self.depth).contains(&(delay as usize)),
            "delay {delay} out of range 1..={}",
            self.depth - 1
        );
        debug_assert!((tgt as usize) < self.n);
        let mut slot = self.cur + delay as usize;
        if slot >= self.depth {
            slot -= self.depth;
        }
        self.buckets[slot * self.chunks].push((tgt, w));
    }

    /// [`DelayRing::deliver_row_offset`] on the compressed store: the
    /// whole target range through chunk 0's buckets (one writer).
    #[inline]
    pub fn deliver_row_offset(&mut self, tgts: &[u32], delays: &[u8], w: f32, back: u32) {
        let n = self.n as u32;
        // SAFETY: full target range, chunk 0, no concurrent shards.
        unsafe {
            self.shard()
                .deliver_row_offset_ranged(tgts, delays, w, back, 0, n, 0)
        }
    }

    /// A raw, range-restrictable delivery view for the threaded path;
    /// see the safety contract on
    /// [`CompressedRingShard::deliver_row_offset_ranged`].
    pub fn shard(&mut self) -> CompressedRingShard {
        CompressedRingShard {
            buckets: self.buckets.as_mut_ptr(),
            chunks: self.chunks,
            depth: self.depth,
            cur: self.cur,
        }
    }

    /// Borrow the accumulator for the current step.
    pub fn current(&self) -> &[f32] {
        &self.current[..self.n]
    }

    /// Finish the current step: zero the dense row, advance the ring,
    /// and drain the incoming slot's buckets (chunk-ascending, append
    /// order) into the dense row. Effective delays are always >= 1, so
    /// no bucket of the slot being vacated can still receive appends.
    pub fn advance(&mut self) {
        self.current[..self.n].iter_mut().for_each(|x| *x = 0.0);
        self.cur += 1;
        if self.cur == self.depth {
            self.cur = 0;
        }
        let base = self.cur * self.chunks;
        for c in 0..self.chunks {
            // take/put-back instead of split borrows: buckets and the
            // dense row live in different fields, but the loop reads one
            // and writes the other, so move the Vec out for the drain.
            let mut bucket = std::mem::take(&mut self.buckets[base + c]);
            let drained = bucket.len();
            for &(t, w) in &bucket {
                self.current[t as usize] += w;
            }
            bucket.clear();
            // Keep capacity warm for steady-state reuse, but decay a
            // burst's peak: capacity tracks ~2x the slot's recent load,
            // so a synchronization transient cannot pin its high-water
            // mark for the rest of the run (values are untouched —
            // capacity never affects the raster).
            if bucket.capacity() > 1024 && bucket.capacity() > 2 * drained {
                bucket.shrink_to((2 * drained).max(1024));
            }
            self.buckets[base + c] = bucket;
        }
    }

    /// Sum of everything still queued (current row + all buckets).
    pub fn queued_total(&self) -> f64 {
        let cur: f64 = self.current[..self.n].iter().map(|&x| x as f64).sum();
        let pending: f64 = self
            .buckets
            .iter()
            .flat_map(|b| b.iter())
            .map(|&(_, w)| w as f64)
            .sum();
        cur + pending
    }

    /// Resident bytes: the dense current row, the bucket headers, and
    /// the bucket capacities. O(n + in-flight events) — the closed form
    /// `metrics::memory::compressed_ring_bytes_idle` is the floor.
    pub fn resident_bytes(&self) -> usize {
        self.stride * 4
            + self.buckets.len() * std::mem::size_of::<Vec<(u32, f32)>>()
            + self
                .buckets
                .iter()
                .map(|b| b.capacity() * std::mem::size_of::<(u32, f32)>())
                .sum::<usize>()
    }
}

/// [`RingShard`]'s counterpart for [`CompressedDelayRing`]: a copyable
/// raw view the `--compute-threads` workers append through, each into
/// its own per-chunk bucket.
#[derive(Clone, Copy)]
pub struct CompressedRingShard {
    buckets: *mut Vec<(u32, f32)>,
    chunks: usize,
    depth: usize,
    cur: usize,
}

// SAFETY: pointer + geometry; the aliasing discipline is the deliver
// contract below (each concurrent caller uses a distinct chunk index).
unsafe impl Send for CompressedRingShard {}
unsafe impl Sync for CompressedRingShard {}

impl CompressedRingShard {
    /// Queue one spike row's arrivals for targets in `[lo, hi)` into
    /// `chunk`'s buckets. Same row-walk and slot arithmetic as
    /// [`RingShard::deliver_row_offset_ranged`]; the weight lands in a
    /// bucket instead of a dense slot row.
    ///
    /// # Safety
    ///
    /// * The parent ring must outlive the shard and not be advanced,
    ///   resized or dropped while shards are live.
    /// * Concurrent callers must use pairwise-distinct `chunk` indices
    ///   (each bucket Vec has exactly one writer), and `[lo, hi)` ranges
    ///   consistent with the ring's chunk geometry so each target is
    ///   appended by exactly one chunk.
    /// * As for the dense path: `tgt < n`, `1 <= delay <= max_delay`,
    ///   `back < delay`, ascending targets within each equal-delay run.
    pub unsafe fn deliver_row_offset_ranged(
        &self,
        tgts: &[u32],
        delays: &[u8],
        w: f32,
        back: u32,
        lo: u32,
        hi: u32,
        chunk: usize,
    ) {
        debug_assert_eq!(tgts.len(), delays.len());
        debug_assert!(chunk < self.chunks);
        let m = tgts.len();
        let back = back as usize;
        let mut i = 0usize;
        while i < m {
            let d = delays[i];
            debug_assert!((1..self.depth).contains(&(d as usize)));
            debug_assert!(
                (d as usize) > back,
                "offset {back} >= delay {d}: spike delivered past its arrival step"
            );
            let mut j = i + 1;
            while j < m && delays[j] == d {
                debug_assert!(tgts[j - 1] <= tgts[j], "targets must ascend within a run");
                j += 1;
            }
            let mut slot = self.cur + d as usize - back;
            if slot >= self.depth {
                slot -= self.depth;
            }
            let run = &tgts[i..j];
            let a = run.partition_point(|&t| t < lo);
            let b = run.partition_point(|&t| t < hi);
            if a < b {
                // SAFETY (fn contract): slot < depth and chunk < chunks,
                // so the bucket index is in bounds; the distinct-chunk
                // contract makes the &mut Vec exclusive.
                let bucket = &mut *self.buckets.add(slot * self.chunks + chunk);
                for &t in &run[a..b] {
                    bucket.push((t, w));
                }
            }
            i = j;
        }
    }
}

    #[test]
    fn delivers_at_the_right_step() {
        let mut r = DelayRing::new(4, 3);
        r.add(1, 0, 1.0);
        r.add(2, 1, 2.0);
        r.add(3, 2, 4.0);
        assert_eq!(r.current(), &[0.0, 0.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[1.0, 0.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 2.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 0.0, 4.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulates_multiple_arrivals() {
        let mut r = DelayRing::new(2, 4);
        r.add(2, 0, 0.5);
        r.add(2, 0, 0.25);
        r.advance();
        r.advance();
        assert_eq!(r.current()[0], 0.75);
    }

    #[test]
    fn deliver_row_matches_add() {
        let tgts = [0u32, 3, 3, 7, 1];
        let delays = [1u8, 2, 2, 3, 4];
        let mut a = DelayRing::new(8, 6);
        let mut b = DelayRing::new(8, 6);
        a.deliver_row(&tgts, &delays, 0.5);
        for (&t, &d) in tgts.iter().zip(&delays) {
            b.add(d, t, 0.5);
        }
        for _ in 0..7 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn offset_delivery_matches_per_step_delivery() {
        // Epoch-batched semantics: delivering at t_now = t_emit + back
        // with deliver_row_offset lands in the same absolute slots as
        // per-step delivery at t_emit.
        let tgts = [0u32, 2, 2, 5];
        let delays = [3u8, 3, 4, 6];
        let mut per_step = DelayRing::new(6, 8);
        let mut batched = DelayRing::new(6, 8);
        // per-step: deliver at emission time, then run two steps
        per_step.deliver_row(&tgts, &delays, 0.25);
        per_step.advance();
        per_step.advance();
        // batched: the ring runs two steps ahead, then delivers with back=2
        batched.advance();
        batched.advance();
        batched.deliver_row_offset(&tgts, &delays, 0.25, 2);
        for _ in 0..9 {
            assert_eq!(per_step.current(), batched.current());
            per_step.advance();
            batched.advance();
        }
    }

    #[test]
    fn offset_zero_is_plain_delivery() {
        let tgts = [1u32, 3];
        let delays = [2u8, 5];
        let mut a = DelayRing::new(4, 6);
        let mut b = DelayRing::new(4, 6);
        a.deliver_row(&tgts, &delays, 1.5);
        b.deliver_row_offset(&tgts, &delays, 1.5, 0);
        for _ in 0..7 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn offset_delivery_can_hit_the_next_step() {
        // back == d - 1: the spike lands in the very next slot.
        let mut r = DelayRing::new(1, 4);
        r.advance(); // t_now = 1
        r.deliver_row_offset(&[0], &[2], 1.0, 1); // emitted at t=0, d=2 -> step 2
        r.advance(); // now integrating step 2
        assert_eq!(r.current()[0], 1.0);
    }

    #[test]
    fn slot_reuse_after_wrap() {
        let mut r = DelayRing::new(1, 2);
        for round in 0..10 {
            r.add(1, 0, 1.0);
            r.advance();
            assert_eq!(r.current()[0], 1.0, "round {round}");
            r.advance(); // consume without new adds
        }
    }

    #[test]
    fn max_delay_wraps_correctly() {
        let mut r = DelayRing::new(1, 16);
        r.add(16, 0, 3.0);
        for _ in 0..16 {
            assert_eq!(r.current()[0], 0.0);
            r.advance();
        }
        assert_eq!(r.current()[0], 3.0);
    }

    #[test]
    fn ranged_shards_partition_the_unranged_delivery() {
        // Delivering one row through disjoint target ranges must equal the
        // unranged delivery, for any split point (including empty sides).
        let tgts = [0u32, 1, 4, 4, 7, 2, 5];
        let delays = [2u8, 2, 2, 2, 2, 5, 5];
        for split in 0..=8u32 {
            let mut whole = DelayRing::new(8, 6);
            let mut parts = DelayRing::new(8, 6);
            whole.deliver_row_offset(&tgts, &delays, 0.5, 1);
            let shard = parts.shard();
            // SAFETY: [0,split) and [split,8) are disjoint.
            unsafe {
                shard.deliver_row_offset_ranged(&tgts, &delays, 0.5, 1, 0, split);
                shard.deliver_row_offset_ranged(&tgts, &delays, 0.5, 1, split, 8);
            }
            for _ in 0..7 {
                assert_eq!(whole.current(), parts.current(), "split={split}");
                whole.advance();
                parts.advance();
            }
        }
    }

    #[test]
    fn compressed_ring_matches_dense_step_for_step() {
        // Same adds, same advances: current() must agree bitwise.
        forall("compressed ring equals dense ring", 50, |rng| {
            let n = 1 + rng.next_below(8) as usize;
            let maxd = 1 + rng.next_below(16);
            let mut dense = DelayRing::new(n, maxd);
            let mut comp = CompressedDelayRing::new(n, maxd, 1);
            for _ in 0..50 {
                for _ in 0..rng.next_below(5) {
                    let d = 1 + rng.next_below(maxd) as u8;
                    let t = rng.next_below(n as u32);
                    let w = (rng.next_below(8) as f32) / 8.0;
                    dense.add(d, t, w);
                    comp.add(d, t, w);
                }
                assert_eq!(dense.current(), comp.current());
                assert_eq!(dense.queued_total(), comp.queued_total());
                dense.advance();
                comp.advance();
            }
        });
    }

    #[test]
    fn compressed_row_delivery_matches_dense() {
        let tgts = [0u32, 2, 2, 5, 1, 4];
        let delays = [3u8, 3, 4, 6, 6, 6];
        for back in [0u32, 1, 2] {
            let mut dense = DelayRing::new(6, 8);
            let mut comp = CompressedDelayRing::new(6, 8, 1);
            for _ in 0..back {
                dense.advance();
                comp.advance();
            }
            dense.deliver_row_offset(&tgts, &delays, 0.25, back);
            comp.deliver_row_offset(&tgts, &delays, 0.25, back);
            for _ in 0..9 {
                assert_eq!(dense.current(), comp.current(), "back={back}");
                dense.advance();
                comp.advance();
            }
        }
    }

    #[test]
    fn compressed_chunked_shards_match_dense_delivery() {
        // Chunked bucket appends + drain must equal the dense unranged
        // delivery for any split point (the threaded-procedural path).
        let tgts = [0u32, 1, 4, 4, 7, 2, 5];
        let delays = [2u8, 2, 2, 2, 2, 5, 5];
        for split in 0..=8u32 {
            let mut dense = DelayRing::new(8, 6);
            let mut comp = CompressedDelayRing::new(8, 6, 2);
            dense.deliver_row_offset(&tgts, &delays, 0.5, 1);
            let shard = comp.shard();
            // SAFETY: chunk indices are distinct and ranges disjoint.
            unsafe {
                shard.deliver_row_offset_ranged(&tgts, &delays, 0.5, 1, 0, split, 0);
                shard.deliver_row_offset_ranged(&tgts, &delays, 0.5, 1, split, 8, 1);
            }
            for _ in 0..7 {
                assert_eq!(dense.current(), comp.current(), "split={split}");
                dense.advance();
                comp.advance();
            }
        }
    }

    #[test]
    fn compressed_ring_is_memory_lean() {
        // A deep, wide, idle ring: the dense grid pays depth * n floats,
        // the compressed ring pays one row + empty buckets.
        let dense = DelayRing::new(100_000, 16);
        let comp = CompressedDelayRing::new(100_000, 16, 4);
        assert!(dense.resident_bytes() >= 17 * 100_000 * 4);
        assert!(
            comp.resident_bytes() < dense.resident_bytes() / 10,
            "compressed {} B vs dense {} B",
            comp.resident_bytes(),
            dense.resident_bytes()
        );
        assert_eq!(comp.depth(), dense.depth());
        assert_eq!(comp.n(), dense.n());
        assert_eq!(comp.chunks(), 4);
    }

    #[test]
    fn property_conservation() {
        // Everything added is seen exactly once at current() across advances.
        forall("delay ring conserves mass", 50, |rng| {
            let n = 1 + rng.next_below(8) as usize;
            let maxd = 1 + rng.next_below(16);
            let mut ring = DelayRing::new(n, maxd);
            let mut injected = 0.0f64;
            let mut seen = 0.0f64;
            for _ in 0..50 {
                let adds = rng.next_below(5);
                for _ in 0..adds {
                    let d = 1 + rng.next_below(maxd) as u8;
                    let t = rng.next_below(n as u32);
                    let w = (rng.next_below(8) as f32) / 8.0;
                    ring.add(d, t, w);
                    injected += w as f64;
                }
                seen += ring.current().iter().map(|&x| x as f64).sum::<f64>();
                ring.advance();
            }
            seen += ring.queued_total(); // drain what's still in flight
            assert!(
                (injected - seen).abs() < 1e-9,
                "injected {injected} != seen {seen}"
            );
        });
    }
}
