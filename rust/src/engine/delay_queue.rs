//! Axonal delay ring: the time-delay queues of DPSNN.
//!
//! Because the paper's synapses inject *instantaneous* post-synaptic
//! currents, delivering a spike along a synapse with delay `d` is exactly
//! "add the synaptic weight to the target's input current at step t+d".
//! The ring therefore holds one dense per-neuron accumulator per future
//! step — allocation-free in steady state, and the accumulation order
//! cannot change the result because weights live on the exact 2^-10 grid
//! (see `config::network::WEIGHT_QUANTUM`).
//!
//! **Hot path** (EXPERIMENTS.md §Perf): storage is one flat
//! `depth * n` array; [`DelayRing::deliver_row`] fuses the per-spike
//! fan-out loop with branch-free slot arithmetic and unchecked indexing
//! (safety: targets and delays are validated at construction by
//! [`crate::model::connectivity::IncomingSynapses`]).
//! [`DelayRing::deliver_row_offset`] is the same loop shifted `back`
//! steps toward the present — the epoch-batched exchange delivers a
//! whole min-delay window of buffered spikes at once, each landing in
//! the slot per-step delivery would have used.

/// Ring of `depth` future input-current accumulators over `n` local neurons.
#[derive(Debug, Clone)]
pub struct DelayRing {
    /// slot-major flat storage: slots[s * n + j].
    flat: Vec<f32>,
    n: usize,
    depth: usize,
    /// Slot index holding "the step currently being integrated".
    cur: usize,
}

impl DelayRing {
    /// `max_delay` is the largest delay in steps the ring must hold;
    /// slot for delay d = (cur + d) mod (max_delay + 1).
    pub fn new(n: usize, max_delay: u32) -> Self {
        let depth = max_delay as usize + 1;
        Self { flat: vec![0.0; depth * n], n, depth, cur: 0 }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Accumulate `w` onto local neuron `tgt` to arrive `delay` steps from
    /// the step currently being integrated. `delay` must be in
    /// [1, max_delay].
    #[inline(always)]
    pub fn add(&mut self, delay: u8, tgt: u32, w: f32) {
        debug_assert!(
            (1..self.depth).contains(&(delay as usize)),
            "delay {delay} out of range 1..={}",
            self.depth - 1
        );
        debug_assert!((tgt as usize) < self.n);
        let mut slot = self.cur + delay as usize;
        if slot >= self.depth {
            slot -= self.depth;
        }
        self.flat[slot * self.n + tgt as usize] += w;
    }

    /// Deliver one spike's whole fan-out: add `w` at `(delay, tgt)` for
    /// every synapse in the row. The caller guarantees (and
    /// `IncomingSynapses` construction enforces) `tgt < n` and
    /// `1 <= delay <= max_delay`.
    /// Rows are stored delay-major (see `IncomingSynapses::build`), so
    /// the loop advances the slot base only on delay changes and all
    /// writes of a run land in one slot's accumulator.
    #[inline]
    pub fn deliver_row(&mut self, tgts: &[u32], delays: &[u8], w: f32) {
        self.deliver_row_offset(tgts, delays, w, 0);
    }

    /// [`Self::deliver_row`] for a spike emitted `back` steps before the
    /// step currently being integrated — the epoch-batched exchange,
    /// where spikes buffered over a min-delay window are all delivered
    /// at the epoch boundary. Each synapse lands at effective delay
    /// `d - back` (the `d + (t_emit - t_now)` slot), i.e. in the same
    /// absolute step as per-step delivery would have put it, so the
    /// raster is bitwise identical across exchange cadences. The caller
    /// guarantees `back < d` for every delay in the row; epochs never
    /// exceed `delay_min_steps`, which keeps every effective delay in
    /// `[1, max_delay]`.
    #[inline]
    pub fn deliver_row_offset(&mut self, tgts: &[u32], delays: &[u8], w: f32, back: u32) {
        debug_assert_eq!(tgts.len(), delays.len());
        let n = self.n;
        let depth = self.depth;
        let back = back as usize;
        let cur = self.cur;
        let flat = self.flat.as_mut_ptr();
        let mut last_d = 0u8; // delays are >= 1, so this forces a recompute
        let mut base = 0usize;
        for (&t, &d) in tgts.iter().zip(delays) {
            debug_assert!((t as usize) < n && (1..depth).contains(&(d as usize)));
            debug_assert!(
                (d as usize) > back,
                "offset {back} >= delay {d}: spike delivered past its arrival step"
            );
            if d != last_d {
                let mut slot = cur + d as usize - back;
                if slot >= depth {
                    slot -= depth;
                }
                base = slot * n;
                last_d = d;
            }
            // SAFETY: slot < depth and t < n (validated at build; see
            // connectivity tests), so the index is within flat's length.
            unsafe {
                *flat.add(base + t as usize) += w;
            }
        }
    }

    /// Borrow the accumulator for the current step (the `i_syn` input of
    /// the neuron update).
    pub fn current(&self) -> &[f32] {
        &self.flat[self.cur * self.n..(self.cur + 1) * self.n]
    }

    /// Finish the current step: zero its slot and advance the ring.
    pub fn advance(&mut self) {
        let a = self.cur * self.n;
        self.flat[a..a + self.n].iter_mut().for_each(|x| *x = 0.0);
        self.cur += 1;
        if self.cur == self.depth {
            self.cur = 0;
        }
    }

    /// Sum of everything still queued (test/diagnostic invariant helper).
    pub fn queued_total(&self) -> f64 {
        self.flat.iter().map(|&x| x as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn delivers_at_the_right_step() {
        let mut r = DelayRing::new(4, 3);
        r.add(1, 0, 1.0);
        r.add(2, 1, 2.0);
        r.add(3, 2, 4.0);
        assert_eq!(r.current(), &[0.0, 0.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[1.0, 0.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 2.0, 0.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 0.0, 4.0, 0.0]);
        r.advance();
        assert_eq!(r.current(), &[0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulates_multiple_arrivals() {
        let mut r = DelayRing::new(2, 4);
        r.add(2, 0, 0.5);
        r.add(2, 0, 0.25);
        r.advance();
        r.advance();
        assert_eq!(r.current()[0], 0.75);
    }

    #[test]
    fn deliver_row_matches_add() {
        let tgts = [0u32, 3, 3, 7, 1];
        let delays = [1u8, 2, 2, 3, 4];
        let mut a = DelayRing::new(8, 6);
        let mut b = DelayRing::new(8, 6);
        a.deliver_row(&tgts, &delays, 0.5);
        for (&t, &d) in tgts.iter().zip(&delays) {
            b.add(d, t, 0.5);
        }
        for _ in 0..7 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn offset_delivery_matches_per_step_delivery() {
        // Epoch-batched semantics: delivering at t_now = t_emit + back
        // with deliver_row_offset lands in the same absolute slots as
        // per-step delivery at t_emit.
        let tgts = [0u32, 2, 2, 5];
        let delays = [3u8, 3, 4, 6];
        let mut per_step = DelayRing::new(6, 8);
        let mut batched = DelayRing::new(6, 8);
        // per-step: deliver at emission time, then run two steps
        per_step.deliver_row(&tgts, &delays, 0.25);
        per_step.advance();
        per_step.advance();
        // batched: the ring runs two steps ahead, then delivers with back=2
        batched.advance();
        batched.advance();
        batched.deliver_row_offset(&tgts, &delays, 0.25, 2);
        for _ in 0..9 {
            assert_eq!(per_step.current(), batched.current());
            per_step.advance();
            batched.advance();
        }
    }

    #[test]
    fn offset_zero_is_plain_delivery() {
        let tgts = [1u32, 3];
        let delays = [2u8, 5];
        let mut a = DelayRing::new(4, 6);
        let mut b = DelayRing::new(4, 6);
        a.deliver_row(&tgts, &delays, 1.5);
        b.deliver_row_offset(&tgts, &delays, 1.5, 0);
        for _ in 0..7 {
            assert_eq!(a.current(), b.current());
            a.advance();
            b.advance();
        }
    }

    #[test]
    fn offset_delivery_can_hit_the_next_step() {
        // back == d - 1: the spike lands in the very next slot.
        let mut r = DelayRing::new(1, 4);
        r.advance(); // t_now = 1
        r.deliver_row_offset(&[0], &[2], 1.0, 1); // emitted at t=0, d=2 -> step 2
        r.advance(); // now integrating step 2
        assert_eq!(r.current()[0], 1.0);
    }

    #[test]
    fn slot_reuse_after_wrap() {
        let mut r = DelayRing::new(1, 2);
        for round in 0..10 {
            r.add(1, 0, 1.0);
            r.advance();
            assert_eq!(r.current()[0], 1.0, "round {round}");
            r.advance(); // consume without new adds
        }
    }

    #[test]
    fn max_delay_wraps_correctly() {
        let mut r = DelayRing::new(1, 16);
        r.add(16, 0, 3.0);
        for _ in 0..16 {
            assert_eq!(r.current()[0], 0.0);
            r.advance();
        }
        assert_eq!(r.current()[0], 3.0);
    }

    #[test]
    fn property_conservation() {
        // Everything added is seen exactly once at current() across advances.
        forall("delay ring conserves mass", 50, |rng| {
            let n = 1 + rng.next_below(8) as usize;
            let maxd = 1 + rng.next_below(16);
            let mut ring = DelayRing::new(n, maxd);
            let mut injected = 0.0f64;
            let mut seen = 0.0f64;
            for _ in 0..50 {
                let adds = rng.next_below(5);
                for _ in 0..adds {
                    let d = 1 + rng.next_below(maxd) as u8;
                    let t = rng.next_below(n as u32);
                    let w = (rng.next_below(8) as f32) / 8.0;
                    ring.add(d, t, w);
                    injected += w as f64;
                }
                seen += ring.current().iter().map(|&x| x as f64).sum::<f64>();
                ring.advance();
            }
            seen += ring.queued_total(); // drain what's still in flight
            assert!(
                (injected - seen).abs() < 1e-9,
                "injected {injected} != seen {seen}"
            );
        });
    }
}
