//! The per-rank simulation engine: spikes, delay rings, partitioning and
//! the hybrid event/time-driven 1 ms step, driven per step or in
//! delay epochs of up to `delay_min_steps` steps between exchanges.

pub mod spike;
pub mod delay_queue;
pub mod partition;
pub mod rank;

pub use delay_queue::{CompressedDelayRing, CompressedRingShard, DelayRing, RingShard};
pub use partition::{
    AllocContext, Allocator, BlockGrid, GreedyCommsAllocator, IndexAllocator, OwnedGids,
    Partition, RoundRobinAllocator,
};
pub use rank::{RankEngine, StepOutcome};
pub use spike::Spike;
