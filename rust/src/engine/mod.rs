//! The per-rank simulation engine: spikes, delay rings, partitioning and
//! the hybrid event/time-driven 1 ms step.

pub mod spike;
pub mod delay_queue;
pub mod partition;
pub mod rank;

pub use delay_queue::DelayRing;
pub use partition::Partition;
pub use rank::{RankEngine, StepOutcome};
pub use spike::Spike;
