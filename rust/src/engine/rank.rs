//! The per-rank engine: owns a population slice, its incoming synapses,
//! the delay ring and the external stimulus, and advances them one 1 ms
//! network step at a time.
//!
//! The step protocol (driven by the coordinator) is DPSNN's hybrid
//! event/time-driven scheme, generalized to *delay epochs* of up to
//! `delay_min_steps` consecutive steps between exchanges:
//!
//! 1. [`RankEngine::integrate`] — event-driven neural dynamics for the
//!    current step: external Poisson events + queued synaptic events are
//!    injected and the LIF+SFA update runs (native or XLA backend).
//!    Emitted spikes carry their emission step, so the coordinator can
//!    buffer them across an epoch ([`Spike::step`]).
//! 2. The coordinator exchanges the emitted spikes all-to-all — every
//!    step under the paper's protocol, or once per epoch under
//!    [`crate::config::ExchangeCadence::MinDelay`] — see [`crate::comm`].
//! 3. [`RankEngine::deliver`] — each received spike is expanded through
//!    the local incoming-synapse rows into future delay-ring slots.
//!    Spikes emitted earlier in the epoch land `t_now - t_emit` slots
//!    nearer the present, i.e. in exactly the step per-step delivery
//!    would have used; no spike may be older than `delay_min_steps - 1`
//!    steps (asserted), which is why epochs are capped at the min delay.
//! 4. [`RankEngine::finish_step`] — the ring rotates to the next step.
//!
//! Because delivery only ever *adds* exactly-representable weights into
//! future accumulator slots, batching the exchange changes neither the
//! values nor (observably) the order of any accumulation: the spike
//! raster is bitwise identical across exchange cadences.
//!
//! **Intra-rank threading** (`--compute-threads N`): all three compute
//! phases run over the fixed chunks of a shared
//! [`crate::util::pool::ComputePool`]. The Poisson fill and the neuron
//! update split the owned slice by local index (per-lane pure functions /
//! disjoint state slices); delivery splits by *target* range — every
//! chunk walks every spike's row but only writes its own targets
//! ([`crate::engine::delay_queue::RingShard`]) — so each accumulator sees
//! the same adds in the same spike order under every chunk count, and the
//! raster stays bitwise identical.

use std::rc::Rc;

use anyhow::Result;

use crate::config::{ConnectivityMode, NetworkParams};
use crate::metrics::memory::MemoryUse;
use crate::model::connectivity::{ConnectivityParams, IncomingSynapses, ProceduralSynapses};
use crate::model::poisson::ExternalStimulus;
use crate::runtime::NeuronBackend;
use crate::util::pool::ComputePool;

use super::delay_queue::{CompressedDelayRing, CompressedRingShard, DelayRing, RingShard};
use super::partition::OwnedGids;
use super::spike::Spike;

/// Counters accumulated over a run (the inputs of the paper's
/// synaptic-event cost metric).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepOutcome {
    pub spikes: u64,
    pub syn_events: u64,
    pub ext_events: u64,
}

/// The rank's incoming-synapse store, by [`ConnectivityMode`]:
/// a prebuilt CSR table or the on-demand regenerating generator.
enum SynapseStore {
    Materialized(IncomingSynapses),
    Procedural(ProceduralSynapses),
}

/// The rank's delay state, paired with the synapse store: the dense
/// accumulator grid (materialized) or the bucket-compressed ring
/// (procedural).
enum DelayStore {
    Dense(DelayRing),
    Compressed(CompressedDelayRing),
}

impl DelayStore {
    fn n(&self) -> usize {
        match self {
            DelayStore::Dense(r) => r.n(),
            DelayStore::Compressed(r) => r.n(),
        }
    }

    fn current(&self) -> &[f32] {
        match self {
            DelayStore::Dense(r) => r.current(),
            DelayStore::Compressed(r) => r.current(),
        }
    }

    fn advance(&mut self) {
        match self {
            DelayStore::Dense(r) => r.advance(),
            DelayStore::Compressed(r) => r.advance(),
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            DelayStore::Dense(r) => r.resident_bytes(),
            DelayStore::Compressed(r) => r.resident_bytes(),
        }
    }
}

/// A copyable either-ring delivery view for the threaded path; the
/// chunk index is ignored by the dense shard (disjoint target ranges
/// already make it race-free) and selects the bucket for the
/// compressed one.
#[derive(Clone, Copy)]
enum ShardRef {
    Dense(RingShard),
    Compressed(CompressedRingShard),
}

impl ShardRef {
    /// # Safety
    ///
    /// The union of the two shard contracts:
    /// [`RingShard::deliver_row_offset_ranged`] and
    /// [`CompressedRingShard::deliver_row_offset_ranged`].
    unsafe fn deliver(
        &self,
        tgts: &[u32],
        delays: &[u8],
        w: f32,
        back: u32,
        lo: u32,
        hi: u32,
        chunk: usize,
    ) {
        match self {
            ShardRef::Dense(s) => s.deliver_row_offset_ranged(tgts, delays, w, back, lo, hi),
            ShardRef::Compressed(s) => {
                s.deliver_row_offset_ranged(tgts, delays, w, back, lo, hi, chunk)
            }
        }
    }
}

pub struct RankEngine {
    pub rank: u32,
    /// Owned global ids (any union of intervals a placement policy
    /// produced; local index = ascending-gid order).
    owned: OwnedGids,
    backend: Box<dyn NeuronBackend>,
    synapses: SynapseStore,
    ring: DelayStore,
    stim: ExternalStimulus,
    /// Weight by source type (exc, inh) and the exc/inh boundary gid.
    j_exc: f32,
    j_inh: f32,
    inh_start: u32,
    /// Minimum axonal delay in steps: the widest exchange epoch this
    /// network tolerates, and the bound [`Self::deliver`] enforces on
    /// spike age.
    delay_min: u32,
    /// The `--compute-threads` chunking, shared with the native backend.
    pool: Rc<ComputePool>,
    /// Owned intervals as (local offset, first gid, len) — the map the
    /// chunked gid-keyed Poisson fill needs.
    segs: Vec<(usize, u32, usize)>,
    /// Scratch buffers reused every step (allocation-free hot path).
    ext_scratch: Vec<u64>,
    spiked_local: Vec<u32>,
    /// Procedural-mode scratch: the delivery batch's regenerated rows
    /// packed as a tiny CSR (`csr_ptr[i]..csr_ptr[i+1]` is spike i's
    /// row), plus the per-row sort buffer. Capacity is reused across
    /// epochs, so steady state regenerates without allocating. Empty in
    /// materialized mode.
    csr_ptr: Vec<u32>,
    csr_tgt: Vec<u32>,
    csr_delay: Vec<u8>,
    row_scratch: Vec<(u8, u32)>,
    /// Current network step (increments in finish_step).
    pub step: u32,
    /// Running totals.
    pub totals: StepOutcome,
}

impl RankEngine {
    /// Build the engine for rank `rank` owning the gids in `owned`,
    /// single-threaded (the test/bench-friendly constructor).
    pub fn new(
        net: &NetworkParams,
        seed: u64,
        rank: u32,
        owned: OwnedGids,
        backend: Box<dyn NeuronBackend>,
    ) -> Self {
        Self::with_pool(net, seed, rank, owned, backend, Rc::new(ComputePool::new(1)))
    }

    /// [`Self::new`] with an explicit compute pool (normally the same one
    /// the native backend chunks over). Materialized connectivity — the
    /// historical behaviour every existing call site expects.
    pub fn with_pool(
        net: &NetworkParams,
        seed: u64,
        rank: u32,
        owned: OwnedGids,
        backend: Box<dyn NeuronBackend>,
        pool: Rc<ComputePool>,
    ) -> Self {
        Self::with_pool_mode(
            net,
            seed,
            rank,
            owned,
            backend,
            pool,
            ConnectivityMode::Materialized,
        )
    }

    /// [`Self::with_pool`] with an explicit [`ConnectivityMode`]:
    /// `materialized` builds the CSR table and the dense delay ring up
    /// front; `procedural` keeps only the generator (O(state) memory)
    /// and pairs it with the compressed delay ring whose bucket split
    /// mirrors the pool's chunk geometry. Rasters are bitwise identical
    /// between the modes (tests/connectivity_modes.rs pins the matrix).
    pub fn with_pool_mode(
        net: &NetworkParams,
        seed: u64,
        rank: u32,
        owned: OwnedGids,
        backend: Box<dyn NeuronBackend>,
        pool: Rc<ComputePool>,
        mode: ConnectivityMode,
    ) -> Self {
        assert_eq!(backend.len(), owned.len() as usize);
        let cp = ConnectivityParams::from_network(net, seed);
        let n = owned.len() as usize;
        let (synapses, ring) = match mode {
            ConnectivityMode::Materialized => (
                SynapseStore::Materialized(IncomingSynapses::build_owned(&cp, &owned)),
                DelayStore::Dense(DelayRing::new(n, net.delay_max_steps)),
            ),
            ConnectivityMode::Procedural => (
                SynapseStore::Procedural(ProceduralSynapses::new(cp, owned.clone())),
                DelayStore::Compressed(CompressedDelayRing::new(
                    n,
                    net.delay_max_steps,
                    pool.chunks(),
                )),
            ),
        };
        let mut segs = Vec::with_capacity(owned.intervals().len());
        let mut off = 0usize;
        for &(lo, hi) in owned.intervals() {
            segs.push((off, lo, (hi - lo) as usize));
            off += (hi - lo) as usize;
        }
        Self {
            rank,
            owned,
            backend,
            synapses,
            ring,
            stim: ExternalStimulus::new(net, seed ^ 0xEC5),
            j_exc: net.j_exc,
            j_inh: net.j_inh,
            inh_start: net.inh_start(),
            delay_min: net.delay_min_steps.max(1),
            pool,
            segs,
            ext_scratch: Vec::new(),
            spiked_local: Vec::with_capacity(n / 4 + 8),
            csr_ptr: Vec::new(),
            csr_tgt: Vec::new(),
            csr_delay: Vec::new(),
            row_scratch: Vec::new(),
            step: 0,
            totals: StepOutcome::default(),
        }
    }

    pub fn n_local(&self) -> usize {
        self.backend.len()
    }

    /// The global ids this rank owns.
    pub fn owned(&self) -> &OwnedGids {
        &self.owned
    }

    /// Synapses resident on this rank. Exact for the materialized
    /// table; the procedural store holds none (rows are regenerated per
    /// delivery), so it reports 0 — use the analytic expectation
    /// (`metrics::memory`) for capacity numbers in that mode.
    pub fn n_local_synapses(&self) -> usize {
        match &self.synapses {
            SynapseStore::Materialized(inc) => inc.n_synapses(),
            SynapseStore::Procedural(_) => 0,
        }
    }

    /// Which connectivity mode this engine was built with.
    pub fn connectivity_mode(&self) -> ConnectivityMode {
        match &self.synapses {
            SynapseStore::Materialized(_) => ConnectivityMode::Materialized,
            SynapseStore::Procedural(_) => ConnectivityMode::Procedural,
        }
    }

    /// Measured resident bytes of the scale-dominant stores (the
    /// numbers RunResult/BENCH_memory.json report and the closed forms
    /// in `metrics::memory` predict). The procedural regeneration
    /// scratch (one delivery batch's rows, not the table) is reported
    /// separately: it scales with batch activity, so the O(state) gate
    /// on the persistent store must not see it.
    pub fn memory_use(&self) -> MemoryUse {
        let synapse_bytes = match &self.synapses {
            SynapseStore::Materialized(inc) => inc.resident_bytes() as u64,
            SynapseStore::Procedural(p) => p.resident_bytes() as u64,
        };
        MemoryUse {
            synapse_bytes,
            ring_bytes: self.ring.resident_bytes() as u64,
            scratch_bytes: (self.csr_ptr.capacity() * 4
                + self.csr_tgt.capacity() * 4
                + self.csr_delay.capacity()
                + self.row_scratch.capacity() * std::mem::size_of::<(u8, u32)>())
                as u64,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Phase 1: integrate the current step. Returns the local spikes as
    /// global-id [`Spike`]s via `out` (cleared first).
    pub fn integrate(&mut self, out: &mut Vec<Spike>) -> Result<usize> {
        // The stimulus is keyed by global id ([`Self::segs`] carries the
        // local-offset -> gid map), filled chunked straight into the
        // backend's own buffer.
        self.totals.ext_events += self.stim.fill_chunked(
            self.step,
            &self.segs,
            &self.pool,
            &mut self.ext_scratch,
            self.backend.i_ext_mut(),
        );
        self.spiked_local.clear();
        let n = self.backend.step(self.ring.current(), &mut self.spiked_local)?;
        self.totals.spikes += n as u64;
        out.clear();
        let owned = &self.owned;
        out.extend(
            self.spiked_local
                .iter()
                .map(|&j| Spike::new(owned.gid_of(j), self.step)),
        );
        Ok(n)
    }

    /// Phase 3: deliver received spikes (own + remote) through the local
    /// incoming-synapse rows into the delay ring.
    ///
    /// Spikes may have been emitted up to `delay_min_steps - 1` steps
    /// before the step currently being integrated (the epoch-batched
    /// exchange buffers a whole min-delay window before delivering).
    /// Each one lands at effective delay `d - (t_now - t_emit)` — the
    /// same absolute step per-step delivery would have used — so the
    /// raster is bitwise identical across exchange cadences. Spikes
    /// older than the min-delay window would already have missed their
    /// arrival step; that protocol violation panics rather than
    /// corrupting the ring (the offset delivery indexes unchecked).
    ///
    /// With more than one compute chunk, every chunk walks the whole
    /// spike batch restricted to its own target range: per accumulator
    /// the add sequence is exactly the single-chunk one, so the chunking
    /// never shows in the raster.
    pub fn deliver(&mut self, spikes: &[Spike]) {
        // Protocol check stays sequential (cheap).
        for sp in spikes {
            let back = self.step.wrapping_sub(sp.step);
            assert!(
                back < self.delay_min,
                "spike emitted at step {} delivered at step {} violates the \
                 min-delay window ({} steps)",
                sp.step,
                self.step,
                self.delay_min
            );
        }
        // Procedural mode regenerates the batch's rows ONCE into the
        // scratch CSR (sequentially — the generator sweep is per-spike
        // O(m); chunk workers then share the regenerated rows instead of
        // each redrawing all n*m counters). Row content and order are
        // identical to the materialized table (`ProceduralSynapses::
        // row_into`), so everything downstream is mode-blind.
        if let SynapseStore::Procedural(p) = &self.synapses {
            self.csr_ptr.clear();
            self.csr_tgt.clear();
            self.csr_delay.clear();
            self.csr_ptr.push(0);
            for sp in spikes {
                p.row_into(
                    sp.gid,
                    &mut self.csr_tgt,
                    &mut self.csr_delay,
                    &mut self.row_scratch,
                );
                let len: u32 = self
                    .csr_tgt
                    .len()
                    .try_into()
                    .expect("more than u32::MAX synapses in one delivery batch");
                self.csr_ptr.push(len);
            }
        }
        // Event accounting: the regenerated row length equals the
        // materialized row length by construction, so the syn-event
        // totals agree across modes.
        match &self.synapses {
            SynapseStore::Materialized(inc) => {
                for sp in spikes {
                    self.totals.syn_events += inc.row(sp.gid).0.len() as u64;
                }
            }
            SynapseStore::Procedural(_) => {
                self.totals.syn_events += self.csr_tgt.len() as u64;
            }
        }
        if self.pool.chunks() == 1 {
            for (i, sp) in spikes.iter().enumerate() {
                let back = self.step.wrapping_sub(sp.step);
                let w = if sp.gid < self.inh_start {
                    self.j_exc
                } else {
                    self.j_inh
                };
                let (tgts, delays) = match &self.synapses {
                    SynapseStore::Materialized(inc) => inc.row(sp.gid),
                    SynapseStore::Procedural(_) => {
                        let (a, b) = (self.csr_ptr[i] as usize, self.csr_ptr[i + 1] as usize);
                        (&self.csr_tgt[a..b], &self.csr_delay[a..b])
                    }
                };
                match &mut self.ring {
                    DelayStore::Dense(r) => r.deliver_row_offset(tgts, delays, w, back),
                    DelayStore::Compressed(r) => r.deliver_row_offset(tgts, delays, w, back),
                }
            }
            return;
        }
        let n = self.ring.n();
        let shard = match &mut self.ring {
            DelayStore::Dense(r) => ShardRef::Dense(r.shard()),
            DelayStore::Compressed(r) => ShardRef::Compressed(r.shard()),
        };
        let synapses = &self.synapses;
        let (csr_ptr, csr_tgt, csr_delay) = (&self.csr_ptr, &self.csr_tgt, &self.csr_delay);
        let (j_exc, j_inh, inh_start, step) = (self.j_exc, self.j_inh, self.inh_start, self.step);
        // the closure captures the chunk count, not the pool (not Sync)
        let chunks = self.pool.chunks();
        self.pool.run(&|c| {
            let r = crate::util::pool::chunk_range(chunks, c, n);
            if r.is_empty() {
                return;
            }
            let (lo, hi) = (r.start as u32, r.end as u32);
            for (i, sp) in spikes.iter().enumerate() {
                let back = step.wrapping_sub(sp.step);
                let w = if sp.gid < inh_start { j_exc } else { j_inh };
                let (tgts, delays) = match synapses {
                    SynapseStore::Materialized(inc) => inc.row(sp.gid),
                    SynapseStore::Procedural(_) => {
                        let (a, b) = (csr_ptr[i] as usize, csr_ptr[i + 1] as usize);
                        (&csr_tgt[a..b], &csr_delay[a..b])
                    }
                };
                // SAFETY: chunk target ranges are pairwise disjoint, chunk
                // indices distinct, and the ring outlives this closure
                // (run() blocks); rows are build-validated or regenerated
                // by the same generator (targets < n, delays in range,
                // ascending per delay run), and `back < delay_min <= d`
                // was asserted above.
                unsafe { shard.deliver(tgts, delays, w, back, lo, hi, c) };
            }
        });
    }

    /// Phase 4: rotate the delay ring and advance the step counter.
    pub fn finish_step(&mut self) {
        self.ring.advance();
        self.step += 1;
    }

    /// Mean firing rate so far (Hz), given the network step size.
    pub fn mean_rate_hz(&self, dt_ms: f64) -> f64 {
        if self.step == 0 {
            return 0.0;
        }
        let sim_s = self.step as f64 * dt_ms * 1e-3;
        self.totals.spikes as f64 / self.n_local() as f64 / sim_s
    }

    /// Diagnostics: current membrane state.
    pub fn state(&self) -> (&[f32], &[f32], &[f32]) {
        self.backend.state()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::population::PopulationSoA as PS;
    use crate::runtime::NativeBackend;

    fn engine(net: &NetworkParams, seed: u64, lo: u32, hi: u32) -> RankEngine {
        engine_threaded(net, seed, lo, hi, 1)
    }

    fn engine_threaded(
        net: &NetworkParams,
        seed: u64,
        lo: u32,
        hi: u32,
        threads: usize,
    ) -> RankEngine {
        engine_mode(net, seed, lo, hi, threads, ConnectivityMode::Materialized)
    }

    fn engine_mode(
        net: &NetworkParams,
        seed: u64,
        lo: u32,
        hi: u32,
        threads: usize,
        mode: ConnectivityMode,
    ) -> RankEngine {
        let pop = PS::init(net, seed, lo, hi - lo);
        let pool = Rc::new(ComputePool::new(threads));
        let be = Box::new(NativeBackend::with_pool(net, pop, pool.clone()));
        RankEngine::with_pool_mode(net, seed, 0, OwnedGids::contiguous(lo, hi), be, pool, mode)
    }

    #[test]
    fn single_rank_runs_and_counts() {
        let net = NetworkParams::tiny(256);
        let mut e = engine(&net, 42, 0, 256);
        let mut spikes = Vec::new();
        let mut total = 0usize;
        for _ in 0..100 {
            total += e.integrate(&mut spikes).unwrap();
            let owned: Vec<Spike> = spikes.clone();
            e.deliver(&owned);
            e.finish_step();
        }
        assert_eq!(e.step, 100);
        assert_eq!(e.totals.spikes, total as u64);
        assert!(e.totals.ext_events > 0, "external drive must tick");
        // spikes should have triggered synaptic events
        if total > 0 {
            assert!(e.totals.syn_events > 0);
        }
    }

    #[test]
    fn spikes_carry_global_ids_and_step() {
        let net = NetworkParams::tiny(128);
        let mut e = engine(&net, 9, 64, 128);
        let mut spikes = Vec::new();
        for _ in 0..50 {
            e.integrate(&mut spikes).unwrap();
            for s in &spikes {
                assert!(s.gid >= 64 && s.gid < 128);
                assert_eq!(s.step, e.step);
            }
            e.deliver(&spikes);
            e.finish_step();
        }
    }

    #[test]
    fn threaded_engine_matches_single_thread_bitwise() {
        // Full engine loop under 1/2/4 compute chunks: spike sequences,
        // totals and final state must be identical.
        let net = NetworkParams::tiny(300);
        let mut reference = engine(&net, 42, 0, 300);
        let mut ref_raster = Vec::new();
        let mut spikes = Vec::new();
        for _ in 0..120 {
            reference.integrate(&mut spikes).unwrap();
            ref_raster.push(spikes.clone());
            reference.deliver(&spikes);
            reference.finish_step();
        }
        for threads in [2usize, 4] {
            let mut e = engine_threaded(&net, 42, 0, 300, threads);
            for (t, expect) in ref_raster.iter().enumerate() {
                e.integrate(&mut spikes).unwrap();
                assert_eq!(&spikes, expect, "threads={threads} step={t}");
                e.deliver(&spikes);
                e.finish_step();
            }
            assert_eq!(e.totals, reference.totals, "threads={threads}");
            let (v1, w1, rf1) = reference.state();
            let (v2, w2, rf2) = e.state();
            assert_eq!(v1, v2, "threads={threads}");
            assert_eq!(w1, w2);
            assert_eq!(rf1, rf2);
        }
    }

    #[test]
    fn epoch_batched_delivery_matches_per_step() {
        // Drive two identical engines: one delivers every step, the
        // other buffers a whole min-delay window and delivers at the
        // epoch boundary. Spike trains and totals must match exactly.
        let mut net = NetworkParams::tiny(256);
        net.delay_min_steps = 4;
        let mut a = engine(&net, 11, 0, 256);
        let mut b = engine(&net, 11, 0, 256);
        let mut spikes = Vec::new();
        let mut buffered: Vec<Spike> = Vec::new();
        let mut counts_a = Vec::new();
        let mut counts_b = Vec::new();
        for _ in 0..25 {
            // per-step engine: integrate/deliver/finish each step
            for _ in 0..4 {
                a.integrate(&mut spikes).unwrap();
                counts_a.push(spikes.len());
                a.deliver(&spikes);
                a.finish_step();
            }
            // epoch engine: integrate four steps, deliver once
            buffered.clear();
            for k in 0..4 {
                b.integrate(&mut spikes).unwrap();
                counts_b.push(spikes.len());
                buffered.extend_from_slice(&spikes);
                if k < 3 {
                    b.finish_step();
                }
            }
            b.deliver(&buffered);
            b.finish_step();
        }
        assert_eq!(counts_a, counts_b);
        assert_eq!(a.totals, b.totals);
        assert!(a.totals.spikes > 0, "network must be active");
    }

    #[test]
    #[should_panic(expected = "min-delay window")]
    fn spike_older_than_the_min_delay_window_panics() {
        let net = NetworkParams::tiny(64); // delay_min_steps = 1
        let mut e = engine(&net, 3, 0, 64);
        e.finish_step(); // now at step 1
        e.deliver(&[Spike::new(5, 0)]); // back = 1 >= delay_min = 1
    }

    #[test]
    fn syn_event_count_matches_fanin() {
        // deliver one artificial spike and check the count equals the
        // fan-in the stateless connectome declares — in BOTH modes
        let net = NetworkParams::tiny(64);
        let cp = ConnectivityParams::from_network(&net, 3);
        let row_len = cp.targets_of(5).iter().filter(|&&(t, _)| t < 64).count() as u64;
        for mode in [ConnectivityMode::Materialized, ConnectivityMode::Procedural] {
            let mut e = engine_mode(&net, 3, 0, 64, 1, mode);
            e.deliver(&[Spike::new(5, 0)]);
            assert_eq!(e.totals.syn_events, row_len, "{mode}");
        }
    }

    #[test]
    fn procedural_engine_matches_materialized_bitwise() {
        // Full engine loop in both connectivity modes, single- and
        // multi-chunk: spike sequences, totals and final state must be
        // identical (the in-process half of the equivalence oracle;
        // tests/connectivity_modes.rs runs the cross-rank matrix).
        let net = NetworkParams::tiny(300);
        let mut reference = engine(&net, 42, 0, 300);
        let mut ref_raster = Vec::new();
        let mut spikes = Vec::new();
        for _ in 0..120 {
            reference.integrate(&mut spikes).unwrap();
            ref_raster.push(spikes.clone());
            reference.deliver(&spikes);
            reference.finish_step();
        }
        assert!(reference.totals.spikes > 0, "network must be active");
        for threads in [1usize, 2, 4] {
            let mut e =
                engine_mode(&net, 42, 0, 300, threads, ConnectivityMode::Procedural);
            assert_eq!(e.connectivity_mode(), ConnectivityMode::Procedural);
            for (t, expect) in ref_raster.iter().enumerate() {
                e.integrate(&mut spikes).unwrap();
                assert_eq!(&spikes, expect, "threads={threads} step={t}");
                e.deliver(&spikes);
                e.finish_step();
            }
            assert_eq!(e.totals, reference.totals, "threads={threads}");
            let (v1, w1, rf1) = reference.state();
            let (v2, w2, rf2) = e.state();
            assert_eq!(v1, v2, "threads={threads}");
            assert_eq!(w1, w2);
            assert_eq!(rf1, rf2);
            // memory accounting: the procedural store is state-bound,
            // the materialized one holds the table
            let mem = e.memory_use();
            crate::metrics::memory::assert_procedural_state_bound(&mem, net.syn_per_neuron, 300);
            assert!(mem.synapse_bytes < reference.memory_use().synapse_bytes);
            assert!(mem.ring_bytes > 0 && reference.memory_use().ring_bytes > 0);
        }
        assert_eq!(
            reference.connectivity_mode(),
            ConnectivityMode::Materialized
        );
        assert!(reference.n_local_synapses() > 0);
    }
}
