//! Config-driven run dispatch and the shared result type.

use anyhow::Result;

use crate::config::{
    AutoAxes, ConnectivityMode, ExchangeCadence, LeaderRotation, Mode, PartitionPolicy, Routing,
    RunConfig, Topology,
};
use crate::metrics::comm_volume::CommVolume;
use crate::metrics::memory::MemoryUse;
use crate::profiling::components::Components;

use super::live::ReplanEvent;

/// Energy figures attached to modeled runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// Mean above-baseline draw while running (W).
    pub power_w: f64,
    /// Energy-to-solution above baseline (J).
    pub energy_j: f64,
    /// Paper Table IV metric (µJ / synaptic event).
    pub uj_per_syn_event: f64,
}

/// Outcome of one simulation run, live or modeled.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub mode: Mode,
    pub procs: u32,
    /// Wall-clock (live: measured; modeled: predicted).
    pub wall_s: f64,
    /// Simulated biological time.
    pub sim_s: f64,
    /// Aggregate (rank-mean) execution components.
    pub components: Components,
    /// Per-rank components (live mode).
    pub per_rank: Vec<Components>,
    pub total_spikes: u64,
    pub total_syn_events: u64,
    pub total_ext_events: u64,
    /// Spikes emitted by excitatory sources (gid below the exc/inh
    /// boundary) — with `total_spikes` this gives the per-population
    /// split the placement-invariance checks compare across policies.
    pub total_exc_spikes: u64,
    /// Spikes emitted per rank (live runs; empty for modeled runs).
    /// Placement permutes this vector's values across ranks while its
    /// sum stays `total_spikes`.
    pub rank_spikes: Vec<u64>,
    pub mean_rate_hz: f64,
    /// Whole-population spike counts per step (live runs; used for
    /// rasters/regime analysis).
    pub pop_counts: Vec<u32>,
    /// Modeled-mode energy report.
    pub energy: Option<EnergyReport>,
    /// Per-rank transport volume (live runs; empty for modeled runs).
    pub comm_volume: Vec<CommVolume>,
    /// Spike exchange protocol the run used (live) or priced (modeled).
    pub routing: Routing,
    /// Transport topology the run used (live) or priced (modeled).
    pub topology: Topology,
    /// Placement policy that mapped neurons onto ranks.
    pub partition: PartitionPolicy,
    /// Exchange cadence the run used (post-`auto` resolution; live runs
    /// with an online re-planner start here — see `replans`).
    pub exchange_every: ExchangeCadence,
    /// Leader-rotation policy the run started with (the online
    /// re-planner may swap it at window boundaries — see `replans`).
    pub leader_rotation: LeaderRotation,
    /// Intra-rank compute threads (post-`auto` resolution).
    pub compute_threads: u32,
    /// Synapse/delay-state representation the run used (post-`auto`
    /// resolution through the analytic memory model).
    pub connectivity: ConnectivityMode,
    /// Measured per-rank resident bytes of the synapse + ring stores
    /// (live runs; modeled runs carry the closed-form prediction for
    /// the largest even-split rank).
    pub memory: Vec<MemoryUse>,
    /// Which axes were `auto` on the CLI/TOML — the concrete fields
    /// above always hold the resolved values, so a run is replayable
    /// by passing them back explicitly.
    pub auto: AutoAxes,
    /// Cadence/rotation switches the online re-planner performed (live
    /// runs with `auto` cadence or rotation; empty otherwise).
    pub replans: Vec<ReplanEvent>,
    pub backend: &'static str,
    pub platform: String,
    /// Recorded workload trace (live runs with `record_trace` set).
    pub trace: Option<crate::trace::workload::WorkloadTrace>,
}

impl RunResult {
    /// Soft real-time factor: simulated time / wall time (>= 1 is
    /// real-time, the paper's red line).
    pub fn realtime_factor(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::INFINITY;
        }
        self.sim_s / self.wall_s
    }

    pub fn is_realtime(&self) -> bool {
        self.realtime_factor() >= 1.0
    }

    /// Mean payload bytes received per rank (live runs; 0 if untracked).
    pub fn mean_recv_bytes_per_rank(&self) -> f64 {
        if self.comm_volume.is_empty() {
            return 0.0;
        }
        let total: u64 = self.comm_volume.iter().map(|c| c.bytes_recv).sum();
        total as f64 / self.comm_volume.len() as f64
    }

    /// The heaviest rank's resident synapse + ring bytes (live runs
    /// report measurements, modeled runs the closed-form prediction;
    /// 0 if untracked).
    pub fn max_rank_memory_bytes(&self) -> u64 {
        self.memory.iter().map(|m| m.total()).max().unwrap_or(0)
    }

    /// Mean payload bytes sent per rank (live runs; 0 if untracked).
    pub fn mean_sent_bytes_per_rank(&self) -> f64 {
        if self.comm_volume.is_empty() {
            return 0.0;
        }
        let total: u64 = self.comm_volume.iter().map(|c| c.bytes_sent).sum();
        total as f64 / self.comm_volume.len() as f64
    }

    /// One-paragraph human summary.
    pub fn summary(&self) -> String {
        let (comp, comm, bar) = self.components.fractions();
        let energy = match &self.energy {
            Some(e) => format!(
                "  energy: {:.0} J above baseline ({:.0} W, {:.2} uJ/syn-event)\n",
                e.energy_j,
                e.power_w,
                e.uj_per_syn_event
            ),
            None => String::new(),
        };
        let volume = if !self.comm_volume.is_empty() {
            let inter: u64 = self.comm_volume.iter().map(|c| c.inter_messages).sum();
            format!(
                "  transport [{}, {}, place {}]: recv {:.2} MB/rank, sent {:.2} MB/rank, \
                 {inter} inter-node msgs\n",
                self.routing,
                self.topology,
                self.partition,
                self.mean_recv_bytes_per_rank() / 1e6,
                self.mean_sent_bytes_per_rank() / 1e6,
            )
        } else if self.topology != Topology::Flat {
            // modeled runs track no per-rank volume, but the topology
            // what-if still changed the pricing — say so
            format!(
                "  transport [{}, {}]: hierarchical exchange priced analytically\n",
                self.routing, self.topology,
            )
        } else {
            String::new()
        };
        let memory = if let Some(worst) = self.memory.iter().max_by_key(|m| m.total()) {
            format!(
                "  memory [{}]: max rank resident {:.2} MB \
                 (synapses {:.2} MB, ring {:.2} MB, scratch {:.2} MB)\n",
                self.connectivity,
                worst.total() as f64 / 1e6,
                worst.synapse_bytes as f64 / 1e6,
                worst.ring_bytes as f64 / 1e6,
                worst.scratch_bytes as f64 / 1e6,
            )
        } else {
            String::new()
        };
        let auto = if self.auto.any() {
            format!(
                "  auto [{}]: resolved to topology {}, cadence {}, rotation {}, \
                 {} threads, connectivity {}{}\n",
                self.auto.describe(),
                self.topology,
                self.exchange_every,
                self.leader_rotation,
                self.compute_threads,
                self.connectivity,
                if self.replans.is_empty() {
                    String::new()
                } else {
                    format!(", {} online re-plans", self.replans.len())
                },
            )
        } else {
            String::new()
        };
        format!(
            "{} run [{}] on {}: {} procs\n\
               wall {:.2} s for {:.1} s simulated (x{:.2} real-time{})\n\
               rate {:.2} Hz | spikes {} | syn events {}\n\
               comp {:.1}% | comm {:.1}% | barrier {:.1}%\n{}{}{}{}",
            match self.mode {
                Mode::Live => "live",
                Mode::Modeled => "modeled",
            },
            self.backend,
            self.platform,
            self.procs,
            self.wall_s,
            self.sim_s,
            self.realtime_factor(),
            if self.is_realtime() { ", REAL-TIME" } else { "" },
            self.mean_rate_hz,
            self.total_spikes,
            self.total_syn_events,
            comp * 100.0,
            comm * 100.0,
            bar * 100.0,
            energy,
            volume,
            memory,
            auto
        )
    }
}

/// Run a configuration end to end: validate, resolve every `auto` axis
/// through the analytic planner ([`crate::simnet::autotune::resolve`]),
/// then dispatch the resolved config.
pub fn run(cfg: &RunConfig) -> Result<RunResult> {
    cfg.validate()?;
    let (cfg, _plan) = crate::simnet::autotune::resolve(cfg)?;
    match cfg.mode {
        Mode::Live => super::live::run_live(&cfg),
        Mode::Modeled => super::modeled::run_modeled(&cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realtime_factor() {
        let mut r = RunResult {
            mode: Mode::Live,
            procs: 1,
            wall_s: 5.0,
            sim_s: 10.0,
            components: Components::default(),
            per_rank: vec![],
            total_spikes: 0,
            total_syn_events: 0,
            total_ext_events: 0,
            total_exc_spikes: 0,
            rank_spikes: vec![],
            mean_rate_hz: 0.0,
            pop_counts: vec![],
            energy: None,
            comm_volume: vec![],
            routing: Routing::Filtered,
            topology: Topology::Flat,
            partition: PartitionPolicy::Index,
            exchange_every: ExchangeCadence::Step,
            leader_rotation: LeaderRotation::Fixed,
            compute_threads: 1,
            connectivity: ConnectivityMode::Materialized,
            memory: vec![],
            auto: AutoAxes::default(),
            replans: Vec::new(),
            backend: "native",
            platform: "host".into(),
            trace: None,
        };
        assert!(r.is_realtime());
        assert_eq!(r.realtime_factor(), 2.0);
        r.wall_s = 20.0;
        assert!(!r.is_realtime());
        assert!(r.summary().contains("procs"));
        // no auto axes -> no auto line
        assert!(!r.summary().contains("auto ["));
        // flag an axis and the resolved values are reported
        r.auto.exchange_every = true;
        r.exchange_every = ExchangeCadence::MinDelay;
        let s = r.summary();
        assert!(s.contains("auto [exchange-every]"), "{s}");
        assert!(s.contains("cadence min-delay"), "{s}");
        // memory reporting rides along once a run tracks it
        assert!(!s.contains("memory ["), "untracked runs say nothing: {s}");
        r.connectivity = ConnectivityMode::Procedural;
        r.memory = vec![
            MemoryUse { synapse_bytes: 1_000_000, ring_bytes: 500_000, scratch_bytes: 0 },
            MemoryUse { synapse_bytes: 200, ring_bytes: 100, scratch_bytes: 0 },
        ];
        assert_eq!(r.max_rank_memory_bytes(), 1_500_000);
        let s = r.summary();
        assert!(s.contains("memory [procedural]"), "{s}");
        assert!(s.contains("connectivity procedural"), "{s}");
    }
}
