//! Modeled execution: analytic workload → timing replay → power/energy,
//! on a named platform + interconnect (the stand-in for the paper's
//! clusters and boards).

use anyhow::Result;

use crate::config::{Mode, Routing, RunConfig, Topology};
use crate::metrics::comm_volume::mean_pair_coverage;
use crate::metrics::memory::predicted_memory_use;
use crate::metrics::energy::joules_per_synaptic_event;
use crate::metrics::synevents::SynapticEventCount;
use crate::platform::hetero::HeteroCluster;
use crate::platform::presets::platform_by_name;
use crate::power::model::PowerModel;
use crate::simnet::alltoall_model::AllToAllModel;
use crate::simnet::presets::interconnect_by_name;
use crate::timing::replay::{ModelRun, ModeledOutcome};
use crate::trace::analytic::AnalyticWorkload;
use crate::trace::workload::WorkloadTrace;

use super::orchestrator::{EnergyReport, RunResult};

/// Full modeled pipeline from a run config.
pub fn run_modeled(cfg: &RunConfig) -> Result<RunResult> {
    let workload = AnalyticWorkload::paper_regime(cfg.net.clone(), cfg.seed);
    let trace = workload.generate(cfg.procs, cfg.sim_seconds);
    run_modeled_trace(cfg, &trace)
}

/// Modeled pipeline over an existing trace (recorded or analytic).
pub fn run_modeled_trace(cfg: &RunConfig, trace: &WorkloadTrace) -> Result<RunResult> {
    let platform = platform_by_name(&cfg.platform)?;
    let link = interconnect_by_name(&cfg.interconnect)?;
    // One ranks-per-node notion per run: the platform's packing
    // (PlatformModel::ranks_per_node, shared with the energy model's
    // node occupancy) — unless a nodes:<k> / tree:<...> topology
    // declares a different packing what-if, which then drives
    // contention grouping, intra/inter link split and leader
    // aggregation alike (the tree's board size is its rank packing).
    let mut run = match cfg.topology {
        Topology::Flat => ModelRun::new(
            HeteroCluster::homogeneous(
                platform.node.core,
                cfg.procs,
                platform.ranks_per_node(),
            ),
            platform.comm_model(link),
        ),
        Topology::Nodes(k) => ModelRun::new(
            HeteroCluster::homogeneous(platform.node.core, cfg.procs, k),
            AllToAllModel::new(link, k),
        )
        .with_hierarchical(),
        Topology::Tree(shape) => {
            let k1 = shape.ranks_per_board();
            ModelRun::new(
                HeteroCluster::homogeneous(platform.node.core, cfg.procs, k1),
                AllToAllModel::new(link, k1),
            )
            .with_tree(
                shape.levels().to_vec(),
                platform.tree_links(link, shape.depth()),
            )
        }
    };
    // Exchange cadence: price one collective per epoch instead of one
    // per step (latency amortized over the min-delay window; payload
    // unchanged apart from run-header framing).
    run = run.with_exchange_every(cfg.exchange_every.epoch_steps(cfg.net.delay_min_steps));
    if cfg.routing == Routing::Filtered {
        // Price the destination-filtered traffic matrix: only the
        // covered (source, rank) pairs put bytes on the wire. With the
        // paper's dense connectivity coverage is ~1 (broadcast
        // degeneration), so the paper reproductions are unaffected.
        run = run.with_filter_coverage(mean_pair_coverage(
            trace.n_neurons,
            trace.syn_per_neuron,
            cfg.procs,
        ));
    }
    let outcome = run.replay(trace);

    let ext_events = (trace.n_neurons as f64
        * trace.ext_events_per_neuron_step
        * trace.steps() as f64) as u64;
    let power = PowerModel::new(platform.clone(), link);
    let energy = energy_report(&power, &outcome, ext_events);

    Ok(RunResult {
        mode: Mode::Modeled,
        procs: cfg.procs,
        wall_s: outcome.wall_s,
        sim_s: trace.sim_seconds(),
        components: outcome.components,
        per_rank: Vec::new(),
        total_spikes: outcome.total_spikes,
        total_syn_events: outcome.total_syn_events,
        total_ext_events: (trace.n_neurons as f64
            * trace.ext_events_per_neuron_step
            * trace.steps() as f64) as u64,
        total_exc_spikes: 0,
        rank_spikes: Vec::new(),
        mean_rate_hz: outcome.mean_rate_hz,
        pop_counts: Vec::new(),
        energy: Some(energy),
        comm_volume: Vec::new(),
        routing: cfg.routing,
        topology: cfg.topology,
        partition: cfg.partition,
        exchange_every: cfg.exchange_every,
        leader_rotation: cfg.leader_rotation,
        compute_threads: cfg.compute_threads,
        connectivity: cfg.connectivity,
        // Closed-form prediction for the largest even-split rank —
        // modeled runs materialize nothing.
        memory: vec![predicted_memory_use(
            &cfg.net,
            cfg.net.n_neurons.div_ceil(cfg.procs.max(1)),
            cfg.connectivity,
        )],
        auto: cfg.auto,
        replans: Vec::new(),
        backend: "model",
        platform: format!("{}+{}", platform.name, link.name),
        trace: None,
    })
}

/// Modeled pipeline over an explicit (possibly heterogeneous) cluster —
/// used by the Trenz/Jetson harnesses, where the paper embeds the ARM
/// partition in an Intel "bath" (MPI heterogeneous mode). Energy is not
/// reported for mixed clusters (the paper meters each platform alone).
pub fn run_modeled_cluster(
    cfg: &RunConfig,
    cluster: HeteroCluster,
    ranks_per_node: u32,
) -> Result<RunResult> {
    let link = interconnect_by_name(&cfg.interconnect)?;
    let workload = AnalyticWorkload::paper_regime(cfg.net.clone(), cfg.seed);
    let trace = workload.generate(cluster.total_ranks(), cfg.sim_seconds);
    let run = ModelRun::new(cluster, AllToAllModel::new(link, ranks_per_node));
    let outcome = run.replay(&trace);
    Ok(RunResult {
        mode: Mode::Modeled,
        procs: outcome.procs,
        wall_s: outcome.wall_s,
        sim_s: trace.sim_seconds(),
        components: outcome.components,
        per_rank: Vec::new(),
        total_spikes: outcome.total_spikes,
        total_syn_events: outcome.total_syn_events,
        total_ext_events: (trace.n_neurons as f64
            * trace.ext_events_per_neuron_step
            * trace.steps() as f64) as u64,
        total_exc_spikes: 0,
        rank_spikes: Vec::new(),
        mean_rate_hz: outcome.mean_rate_hz,
        pop_counts: Vec::new(),
        energy: None,
        comm_volume: Vec::new(),
        // Hetero replays keep the paper's baseline exchange.
        routing: Routing::Broadcast,
        topology: Topology::Flat,
        partition: crate::config::PartitionPolicy::Index,
        exchange_every: crate::config::ExchangeCadence::Step,
        leader_rotation: crate::config::LeaderRotation::Fixed,
        compute_threads: cfg.compute_threads,
        connectivity: cfg.connectivity,
        memory: Vec::new(),
        auto: crate::config::AutoAxes::default(),
        replans: Vec::new(),
        backend: "model",
        platform: format!("hetero+{}", link.name),
        trace: None,
    })
}

/// Derive the paper's power/energy figures from a modeled outcome.
pub fn energy_report(
    power: &PowerModel,
    outcome: &ModeledOutcome,
    ext_events: u64,
) -> EnergyReport {
    let w = power.running_power_w(outcome.procs, outcome.utilization);
    let e = w * outcome.wall_s;
    let events = SynapticEventCount::measured(outcome.total_syn_events, ext_events);
    EnergyReport {
        power_w: w,
        energy_j: e,
        uj_per_syn_event: joules_per_synaptic_event(e, &events) * 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;

    fn cfg(platform: &str, interconnect: &str, procs: u32) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::paper_20480();
        cfg.procs = procs;
        cfg.sim_seconds = 10.0;
        cfg.mode = Mode::Modeled;
        cfg.platform = platform.to_string();
        cfg.interconnect = interconnect.to_string();
        cfg
    }

    #[test]
    fn modeled_20480_reaches_realtime_at_32() {
        let r = run_modeled(&cfg("xeon", "ib", 32)).unwrap();
        assert!(
            r.wall_s < 14.0,
            "paper: 9.15 s at 32 procs; modeled {}",
            r.wall_s
        );
        assert!(r.energy.is_some());
    }

    #[test]
    fn modeled_energy_minimum_at_intermediate_p() {
        // Table II: energy minimum at 8 cores on the Westmere platform.
        let energies: Vec<(u32, f64)> = [1u32, 2, 4, 8, 16, 32, 64]
            .iter()
            .map(|&p| {
                let r = run_modeled(&cfg("westmere", "ib", p)).unwrap();
                (p, r.energy.unwrap().energy_j)
            })
            .collect();
        let best = energies
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(
            [4, 8, 16].contains(&best.0),
            "energy minimum should be at intermediate parallelism: {energies:?}"
        );
    }

    #[test]
    fn min_delay_cadence_relieves_the_latency_wall() {
        use crate::config::ExchangeCadence;
        // Table I's worst point: 20480N at 256 procs is >90%
        // communication, nearly all of it per-message latency. One
        // exchange per 16-step window must claw back most of it.
        let mut per_step = cfg("xeon", "ib", 256);
        per_step.net.delay_min_steps = 16;
        let mut batched = per_step.clone();
        batched.exchange_every = ExchangeCadence::MinDelay;
        let a = run_modeled(&per_step).unwrap();
        let b = run_modeled(&batched).unwrap();
        assert!(
            b.wall_s < 0.5 * a.wall_s,
            "batched {} vs per-step {}",
            b.wall_s,
            a.wall_s
        );
        assert_eq!(a.total_spikes, b.total_spikes, "same workload either way");
    }

    #[test]
    fn hierarchical_topology_relieves_the_latency_wall() {
        // The tentpole's modeled what-if: at the paper's worst point
        // (20480N, 256 procs, >90% communication) pricing the
        // node-leader aggregated exchange must claw back most of the
        // wall-clock, because N(N-1) aggregated messages replace the
        // P(P-1) per-pair envelopes.
        let flat = run_modeled(&cfg("xeon", "ib", 256)).unwrap();
        let mut hier_cfg = cfg("xeon", "ib", 256);
        hier_cfg.topology = Topology::Nodes(12); // the xeon node packing
        let hier = run_modeled(&hier_cfg).unwrap();
        assert_eq!(hier.topology, Topology::Nodes(12));
        assert_eq!(flat.total_spikes, hier.total_spikes, "same workload");
        assert!(
            hier.wall_s < 0.5 * flat.wall_s,
            "hier {} vs flat {}",
            hier.wall_s,
            flat.wall_s
        );
    }

    #[test]
    fn tree_topology_prices_per_level_links() {
        // The L-level generalization of the hierarchical what-if: a
        // board → chassis tree with the platform's per-tier link
        // derating still collapses the flat P(P−1) envelope storm at
        // the paper's worst point.
        let flat = run_modeled(&cfg("xeon", "ib", 256)).unwrap();
        let mut tcfg = cfg("xeon", "ib", 256);
        tcfg.topology = "tree:12,4".parse().unwrap();
        let tree = run_modeled(&tcfg).unwrap();
        assert_eq!(tree.topology.tree().unwrap().levels(), &[12, 4]);
        assert_eq!(flat.total_spikes, tree.total_spikes, "same workload");
        assert!(
            tree.wall_s < 0.5 * flat.wall_s,
            "tree {} vs flat {}",
            tree.wall_s,
            flat.wall_s
        );
    }

    #[test]
    fn jetson_slower_but_cheaper_than_intel() {
        // Paper §V: ARM ~3x less energy, ~5x slower (4-core rows).
        let arm = run_modeled(&cfg("jetson", "eth1g", 4)).unwrap();
        let intel = run_modeled(&cfg("westmere", "ib", 4)).unwrap();
        let slow = arm.wall_s / intel.wall_s;
        let cheap = intel.energy.unwrap().energy_j / arm.energy.unwrap().energy_j;
        assert!((3.5..7.0).contains(&slow), "slowdown {slow}");
        assert!((1.8..6.0).contains(&cheap), "energy ratio {cheap}");
    }
}
