//! Run orchestration — the layer-3 coordination logic.
//!
//! * [`live`] — execute P ranks as OS threads against the in-process
//!   all-to-all transport, with per-rank comp/comm/barrier profiling.
//! * [`modeled`] — drive the calibrated platform/interconnect/power models
//!   with a workload trace (the substitution for the paper's hardware).
//! * [`orchestrator`] — config-driven dispatch and result reporting.

pub mod live;
pub mod modeled;
pub mod orchestrator;

pub use live::{
    OnlineReplanner, PreparedParts, ProgressObserver, ReplanEvent, WindowPlan,
};
pub use orchestrator::{run, EnergyReport, RunResult};
