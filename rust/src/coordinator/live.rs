//! Live execution: P ranks as OS threads over the in-process all-to-all
//! transport, with the paper's comp/comm/barrier profiling.
//!
//! The loop is organized around **delay epochs** — windows of
//! `1..=delay_min_steps` consecutive network steps between exchanges
//! ([`crate::config::ExchangeCadence`]). Per rank, per epoch:
//!
//! 1. integrate the epoch's steps, buffering locally-emitted spikes
//!    with their emission step          -> Computation
//! 2. AER-encode + ONE all-to-all exchange for the whole
//!    epoch                            -> Communication
//! 3. decode + deliver into delay rings (each spike lands at
//!    `d + (t_emit - t_now)`, its per-step arrival slot) -> Computation
//! 4. one explicit barrier             -> Barrier/synchronization
//!
//! An epoch of length 1 — [`crate::config::ExchangeCadence::Step`], the
//! default — is
//! exactly the paper's synchronous-collective protocol, down to the
//! flat 12-byte AER stream on the wire; longer epochs frame the stream
//! with per-step run headers ([`crate::comm::aer::encode_spikes_epoch`])
//! and divide the exchange/barrier count by the epoch length. A spike
//! emitted at step `t` cannot be integrated anywhere before
//! `t + delay_min_steps`, so every spike still arrives before the first
//! step it can influence and the raster is unchanged.
//!
//! Phase 2 runs one of two routing protocols (selected by
//! [`RunConfig::routing`](crate::config::RunConfig)):
//!
//! * **broadcast** — each rank clones its full AER buffer to every rank
//!   (the paper's baseline; every rank sees all spikes).
//! * **filtered** — each rank routes a spike only to destination ranks
//!   that own at least one of its postsynaptic targets, using the
//!   precomputed [`RoutingTable`]; its own spikes are delivered directly
//!   and never loop back through the transport.
//!
//! Orthogonally again, the transport *topology*
//! ([`RunConfig::topology`](crate::config::RunConfig)) decides what the
//! exchange puts on the fabric: `flat` drives the shared
//! [`LocalCluster`] mailbox for every rank pair, while
//! `tree:<k1>,<k2>,...` (and its one-level sugar `nodes:<k>`) drives
//! the L-level [`HierCluster`](crate::comm::hier::HierCluster), where
//! same-board spikes take the board-local path and boundary-crossing
//! traffic is aggregated at per-group leaders into one framed message
//! per sibling-group pair at every tier — the leader
//! gather/aggregate/scatter runs inside the transport call, i.e.
//! inside the profiled Communication lap, and which rank pays it is
//! the [`RunConfig::leader_rotation`](crate::config::RunConfig)
//! policy. The incoming column a rank collects is byte-identical
//! either way, so the topology is invisible to delivery.
//!
//! Before any thread starts, the placement layer
//! ([`Partition::allocate`], selected by
//! [`RunConfig::partition`](crate::config::RunConfig)) decides which
//! rank owns which gids: contiguous index blocks, round-robin scatter,
//! or the comm-aware `greedy-comms` policy that reads the stateless
//! connectome and the topology tree to keep strongly-coupled blocks on
//! cheap links.
//!
//! When the run resolved `--exchange-every auto` or `--leader-rotation
//! auto`, an [`OnlineReplanner`] shared by all ranks re-decides both
//! axes at window boundaries from *measured* traffic: each rank reports
//! its posted payload bytes and communication lap **before** the
//! window's closing barrier, so once the barrier passes, the decision —
//! the planner's crossover cadence/rotation rules
//! ([`crate::simnet::autotune`]) applied to the measured per-pair
//! payload — is a pure function of data every rank agrees on, and every
//! rank derives the identical plan for the next window. A regime shift
//! (the paper's quiet AW vs bursty SWA dynamics) therefore re-plans
//! the cadence within one window of the complete shifted measurement,
//! and rotation swaps ride the same boundary through
//! [`Transport::set_rotation`]. Any per-window cadence that divides the
//! min-delay window keeps every spike ahead of the first step it can
//! influence, so re-planning never moves the raster.
//!
//! Because connectivity, stimulus and initial state are pure functions of
//! global neuron ids, and synaptic weights live on an exact f32 grid, the
//! spike raster is **bitwise identical for every process count, both
//! routing protocols, every exchange cadence, both topologies and every
//! placement policy** — a
//! spike dropped by the filter would have met an empty synapse row at
//! the destination anyway, a spike deferred by an epoch still lands in
//! its per-step arrival slot, aggregation re-frames routes without
//! touching payloads, and placement permutes ownership without touching
//! any gid-keyed draw. Tested in `rust/tests/determinism.rs`,
//! `rust/tests/routing_props.rs`, `rust/tests/cadence_props.rs`,
//! `rust/tests/topology_props.rs` and `rust/tests/partition_props.rs`.

use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::comm::aer::{decode_spikes, decode_spikes_epoch, encode_spikes, encode_spikes_epoch};
use crate::comm::hier::HierCluster;
use crate::comm::local::LocalCluster;
use crate::comm::routing::RoutingTable;
use crate::comm::topology::TopologyTree;
use crate::comm::transport::Transport;
use crate::config::{LeaderRotation, Mode, Routing, RunConfig, Topology};
use crate::engine::partition::{AllocContext, Partition};
use crate::engine::rank::RankEngine;
use crate::engine::spike::Spike;
use crate::metrics::comm_volume::CommVolume;
use crate::metrics::memory::MemoryUse;
use crate::model::connectivity::ConnectivityParams;
use crate::model::population::PopulationSoA;
use crate::profiling::components::Components;
use crate::profiling::timer::Stopwatch;
use crate::runtime::make_backend;
use crate::simnet::autotune::Planner;
use crate::util::pool::ComputePool;

use super::orchestrator::RunResult;

/// What each rank thread reports back.
struct RankReport {
    components: Components,
    totals: crate::engine::rank::StepOutcome,
    /// Spikes this rank emitted at each step. Summed across ranks these
    /// reconstruct the whole-population raster without requiring any
    /// rank to *receive* every spike (filtered routing drops the rest).
    step_spikes: Vec<u32>,
    /// Transport bytes/messages this rank moved over the run.
    comm: CommVolume,
    /// Spikes this rank emitted from excitatory sources (gid below the
    /// exc/inh boundary) — a placement-invariant split of the totals.
    exc_spikes: u64,
    /// Measured resident bytes of this rank's synapse + ring stores at
    /// run end (the connectivity mode's memory footprint).
    memory: MemoryUse,
}

/// Cadence + rotation in force for one exchange window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowPlan {
    /// Steps per exchange window (a divisor of the min-delay window, so
    /// the raster is untouched).
    pub epoch_steps: u32,
    /// Leader-rotation policy of the window's collective.
    pub rotation: LeaderRotation,
}

/// One switch the online re-planner performed at a window boundary
/// (recorded in [`RunResult::replans`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplanEvent {
    /// Index of the completed window whose measurements triggered the
    /// switch; the new plan is in force from the next window on.
    pub window: u64,
    /// Epoch length (steps) in force from the next window.
    pub epoch_steps: u32,
    /// Rotation policy in force from the next window.
    pub rotation: LeaderRotation,
    /// Mean payload bytes per ordered rank pair per step measured over
    /// the completed window — the regime signal the switch keyed on.
    pub measured_bytes_per_pair_step: f64,
    /// The planner's predicted seconds for one collective of the
    /// completed window's cadence at the measured payload.
    pub predicted_exchange_s: f64,
    /// Slowest rank's measured communication lap over the completed
    /// window (AER encode + exchange) — prediction vs reality.
    pub measured_exchange_s: f64,
}

/// The live controller behind `--exchange-every auto` and
/// `--leader-rotation auto`: re-applies the analytic planner's
/// crossover rules to *measured* per-window traffic and swaps cadence
/// and rotation at window boundaries.
///
/// Determinism contract: ranks [`report`](Self::report) before the
/// window's closing barrier and read the next
/// [`window_plan`](Self::window_plan) only after it, so the memoized
/// decision is always computed from the complete window and every rank
/// derives the identical plan. Decisions are payload-driven (bytes are
/// bitwise-reproducible across runs, wall-clock laps are not); the
/// measured and predicted exchange times ride along in the
/// [`ReplanEvent`] log for observability only.
pub struct OnlineReplanner {
    planner: Planner,
    topology: Topology,
    procs: u32,
    /// Min-delay window (steps) — the cadence ceiling.
    dmin: u32,
    /// Re-plan the cadence (`--exchange-every auto`)?
    auto_cadence: bool,
    /// Re-plan the rotation (`--leader-rotation auto`)?
    auto_rotation: bool,
    /// Payload threshold (bytes) of the latency–bandwidth crossover the
    /// decisions key on: the planner's value by default, overridable to
    /// inject regime shifts in tests and bench harnesses.
    crossover_bytes: f64,
    state: Mutex<ReplanState>,
}

struct ReplanState {
    /// Ranks that have reported the accumulating window so far.
    reports: u32,
    /// Payload bytes (self slot excluded) all ranks posted this window.
    payload_bytes: u64,
    /// Slowest reported communication lap of this window.
    max_comm_s: f64,
    /// Plan in force for started windows and the next boundary.
    current: WindowPlan,
    events: Vec<ReplanEvent>,
}

impl OnlineReplanner {
    /// Build the controller for a (resolved) live config: the planner's
    /// crossover threshold for the run's topology, starting from the
    /// config's concrete cadence and rotation.
    pub fn from_config(cfg: &RunConfig) -> Result<Self> {
        let planner = Planner::from_config(cfg)?;
        let crossover_bytes = planner.crossover_bytes(&cfg.topology);
        let dmin = cfg.net.delay_min_steps.max(1);
        Ok(Self {
            planner,
            topology: cfg.topology,
            procs: cfg.procs,
            dmin,
            auto_cadence: cfg.auto.exchange_every,
            auto_rotation: cfg.auto.leader_rotation,
            crossover_bytes,
            state: Mutex::new(ReplanState {
                reports: 0,
                payload_bytes: 0,
                max_comm_s: 0.0,
                current: WindowPlan {
                    epoch_steps: cfg.exchange_every.epoch_steps(dmin),
                    rotation: cfg.leader_rotation,
                },
                events: Vec::new(),
            }),
        })
    }

    /// Override the crossover threshold — tests and bench harnesses
    /// inject regime shifts by placing it below or above the real
    /// payload.
    pub fn with_crossover_bytes(mut self, bytes: f64) -> Self {
        self.crossover_bytes = bytes;
        self
    }

    /// The plan in force for the window a rank is about to start. Safe
    /// to read after the previous window's barrier: every rank reported
    /// before it, so the memoized decision is complete.
    pub fn window_plan(&self) -> WindowPlan {
        self.state.lock().unwrap().current
    }

    /// One rank's measurements for the window it just exchanged: the
    /// payload bytes it posted (self slot excluded), the window's step
    /// count and its communication lap. Must be called before the
    /// window's closing barrier; the last report of a window finalizes
    /// the decision for the next one.
    pub fn report(&self, window: u64, payload_bytes: u64, steps: u32, comm_s: f64) {
        let mut st = self.state.lock().unwrap();
        st.payload_bytes += payload_bytes;
        st.max_comm_s = st.max_comm_s.max(comm_s);
        st.reports += 1;
        if st.reports < self.procs {
            return;
        }
        let pairs = u64::from(self.procs) * u64::from(self.procs.saturating_sub(1));
        let b = st.payload_bytes as f64 / (pairs.max(1) * u64::from(steps.max(1))) as f64;
        let next = WindowPlan {
            epoch_steps: if self.auto_cadence {
                self.cadence_for_payload(b)
            } else {
                st.current.epoch_steps
            },
            rotation: if self.auto_rotation {
                self.rotation_for_payload(b)
            } else {
                st.current.rotation
            },
        };
        if next != st.current {
            st.events.push(ReplanEvent {
                window,
                epoch_steps: next.epoch_steps,
                rotation: next.rotation,
                measured_bytes_per_pair_step: b,
                predicted_exchange_s: self.planner.predict_exchange_s(
                    &self.topology,
                    st.current.epoch_steps,
                    b,
                ),
                measured_exchange_s: st.max_comm_s,
            });
            st.current = next;
        }
        st.reports = 0;
        st.payload_bytes = 0;
        st.max_comm_s = 0.0;
    }

    /// Drain the switch log (run_live attaches it to the result).
    pub fn take_events(&self) -> Vec<ReplanEvent> {
        std::mem::take(&mut self.state.lock().unwrap().events)
    }

    /// The planner's crossover cadence rule at a *measured* payload:
    /// the smallest causally-safe epoch whose payload passes the
    /// crossover, or the full min-delay window while latency-bound.
    fn cadence_for_payload(&self, bytes_per_pair_step: f64) -> u32 {
        self.planner
            .cadence_candidates()
            .into_iter()
            .find(|&e| bytes_per_pair_step * e as f64 >= self.crossover_bytes)
            .unwrap_or(self.dmin)
    }

    /// The planner's rotation rule at a *measured* payload: spread the
    /// leader CPU only when there are leaders and the window is
    /// bandwidth-bound.
    fn rotation_for_payload(&self, bytes_per_pair_step: f64) -> LeaderRotation {
        match self.topology.tree() {
            Some(shape)
                if shape.ranks_per_board() >= 2
                    && bytes_per_pair_step * self.dmin as f64 >= self.crossover_bytes =>
            {
                LeaderRotation::RoundRobin
            }
            _ => LeaderRotation::Fixed,
        }
    }
}

pub fn run_live(cfg: &RunConfig) -> Result<RunResult> {
    let replanner = if cfg.auto.exchange_every || cfg.auto.leader_rotation {
        Some(Arc::new(OnlineReplanner::from_config(cfg)?))
    } else {
        None
    };
    run_live_with(cfg, replanner)
}

/// [`run_live`] with an explicit (possibly custom-thresholded) online
/// re-planner — the injected-regime-shift harness the tests and
/// bench-smoke drive.
pub fn run_live_with(
    cfg: &RunConfig,
    replanner: Option<Arc<OnlineReplanner>>,
) -> Result<RunResult> {
    run_live_prepared(cfg, replanner, PreparedParts::default())
}

/// Coarse progress callback: `(steps_done, steps_total)`, invoked from
/// rank 0 at window boundaries a handful of times per run. The resident
/// server streams these to job clients.
pub type ProgressObserver = Arc<dyn Fn(u32, u32) + Send + Sync>;

/// Pre-computed run ingredients a caller may inject. The resident
/// server uses this to share a placement across jobs with identical
/// (net, seed, procs, policy, topology) and to observe progress; solo
/// runs pass `PreparedParts::default()` and compute everything inline.
#[derive(Clone, Default)]
pub struct PreparedParts {
    /// Placement to use instead of allocating one. Must have been
    /// allocated for this config's (policy, n_neurons, procs, topology)
    /// — the server's cache key guarantees that.
    pub partition: Option<Arc<Partition>>,
    pub progress: Option<ProgressObserver>,
}

/// [`run_live_with`] plus injected [`PreparedParts`].
pub fn run_live_prepared(
    cfg: &RunConfig,
    replanner: Option<Arc<OnlineReplanner>>,
    parts: PreparedParts,
) -> Result<RunResult> {
    let p = cfg.procs;
    let steps = cfg.steps();
    // Placement: the allocator policy decides which rank owns which
    // gids. greedy-comms reads the stateless connectome plus the
    // topology tree (flat runs get all-equal link costs). A cached
    // placement (same inputs, allocated once by the server) skips this.
    let part: Arc<Partition> = match parts.partition {
        Some(part) => part,
        None => {
            let cp = ConnectivityParams::from_network(&cfg.net, cfg.seed);
            let tree = cfg
                .topology
                .tree()
                .map(|shape| TopologyTree::new(p, shape.levels()));
            let ctx = AllocContext { connectivity: Some(&cp), tree: tree.as_ref() };
            Arc::new(Partition::allocate(cfg.partition, cfg.net.n_neurons, p, &ctx))
        }
    };
    let progress = parts.progress.as_ref();

    let t0 = std::time::Instant::now();
    let rp = replanner.as_ref();
    let reports: Vec<RankReport> = match cfg.topology {
        Topology::Flat => {
            spawn_ranks(cfg, &part, LocalCluster::new(p), steps, rp, progress)?
        }
        Topology::Nodes(k) => spawn_ranks(
            cfg,
            &part,
            HierCluster::with_tree(p, &[k], cfg.leader_rotation),
            steps,
            rp,
            progress,
        )?,
        Topology::Tree(shape) => spawn_ranks(
            cfg,
            &part,
            HierCluster::with_tree(p, shape.levels(), cfg.leader_rotation),
            steps,
            rp,
            progress,
        )?,
    };
    let wall_s = t0.elapsed().as_secs_f64();

    let per_rank: Vec<Components> = reports.iter().map(|r| r.components).collect();
    let mut mean = Components::merged(&per_rank);
    mean.computation /= p as f64;
    mean.communication /= p as f64;
    mean.barrier /= p as f64;

    let total_spikes: u64 = reports.iter().map(|r| r.totals.spikes).sum();
    let total_syn: u64 = reports.iter().map(|r| r.totals.syn_events).sum();
    let total_ext: u64 = reports.iter().map(|r| r.totals.ext_events).sum();
    let total_exc: u64 = reports.iter().map(|r| r.exc_spikes).sum();
    let rank_spikes: Vec<u64> = reports.iter().map(|r| r.totals.spikes).collect();

    // Whole-population per-step raster: sum of per-rank emission counts.
    let mut pop_counts = vec![0u32; steps as usize];
    for r in &reports {
        for (t, &c) in r.step_spikes.iter().enumerate() {
            pop_counts[t] += c;
        }
    }
    let comm_volume: Vec<CommVolume> = reports.iter().map(|r| r.comm.clone()).collect();
    let memory: Vec<MemoryUse> = reports.iter().map(|r| r.memory).collect();

    let trace = cfg.record_trace.as_ref().map(|_| {
        crate::trace::workload::WorkloadTrace {
            n_neurons: cfg.net.n_neurons,
            syn_per_neuron: cfg.net.syn_per_neuron,
            ext_events_per_neuron_step: cfg.net.ext_lambda_per_step(),
            dt_ms: cfg.net.dt_ms,
            procs: p,
            spikes: (0..steps as usize)
                .map(|t| reports.iter().map(|r| r.step_spikes[t]).collect())
                .collect(),
        }
    });
    if let (Some(t), Some(path)) = (&trace, &cfg.record_trace) {
        t.save(std::path::Path::new(path))?;
    }

    let sim_s = cfg.sim_seconds;
    Ok(RunResult {
        mode: Mode::Live,
        procs: p,
        wall_s,
        sim_s,
        components: mean,
        per_rank,
        total_spikes,
        total_syn_events: total_syn,
        total_ext_events: total_ext,
        total_exc_spikes: total_exc,
        rank_spikes,
        mean_rate_hz: total_spikes as f64 / cfg.net.n_neurons as f64 / sim_s,
        pop_counts,
        energy: None,
        trace,
        comm_volume,
        routing: cfg.routing,
        topology: cfg.topology,
        partition: cfg.partition,
        exchange_every: cfg.exchange_every,
        leader_rotation: cfg.leader_rotation,
        compute_threads: cfg.compute_threads,
        connectivity: cfg.connectivity,
        memory,
        auto: cfg.auto,
        replans: replanner.map(|r| r.take_events()).unwrap_or_default(),
        backend: match cfg.backend {
            crate::config::Backend::Native => "native",
            crate::config::Backend::Xla => "xla",
        },
        platform: "host-live".to_string(),
    })
}

/// Run one rank thread per rank over `transport` and collect reports.
fn spawn_ranks<T: Transport + Clone>(
    cfg: &RunConfig,
    part: &Partition,
    transport: T,
    steps: u32,
    replanner: Option<&Arc<OnlineReplanner>>,
    progress: Option<&ProgressObserver>,
) -> Result<Vec<RankReport>> {
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for rank in 0..cfg.procs {
            let transport = transport.clone();
            let cfg = cfg.clone();
            let part = part.clone();
            let replanner = replanner.cloned();
            let progress = progress.cloned();
            handles.push(scope.spawn(move || -> Result<RankReport> {
                rank_main(
                    rank,
                    &cfg,
                    &part,
                    transport,
                    steps,
                    replanner.as_deref(),
                    progress.as_ref(),
                )
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect::<Result<Vec<_>>>()
    })
}

fn rank_main<T: Transport>(
    rank: u32,
    cfg: &RunConfig,
    part: &Partition,
    transport: T,
    steps: u32,
    replanner: Option<&OnlineReplanner>,
    progress: Option<&ProgressObserver>,
) -> Result<RankReport> {
    let owned = part.owned(rank).clone();
    let pop = PopulationSoA::init_owned(&cfg.net, cfg.seed, &owned);
    // One pool per rank: the backend chunks the neuron update over it and
    // the engine reuses it for the Poisson fill and ranged delivery.
    let pool = std::rc::Rc::new(ComputePool::new(cfg.compute_threads as usize));
    let backend = make_backend(
        cfg.backend,
        &cfg.net,
        pop,
        std::path::Path::new(&cfg.artifacts_dir),
        pool.clone(),
    )
    .with_context(|| format!("rank {rank} backend"))?;
    let mut engine = RankEngine::with_pool_mode(
        &cfg.net,
        cfg.seed,
        rank,
        owned,
        backend,
        pool,
        cfg.connectivity,
    );

    // Setup (outside the profiled loop, like the synapse build): the
    // destination-rank bitmap for this rank's sources.
    let routing = match cfg.routing {
        Routing::Filtered => Some(RoutingTable::build(
            &ConnectivityParams::from_network(&cfg.net, cfg.seed),
            part,
            rank,
        )),
        Routing::Broadcast => None,
    };
    // Dense degeneration fast path: when every local source covers every
    // rank the per-destination buffers would all equal `my_spikes`, so
    // encode once and byte-copy (still skipping the loopback slot)
    // instead of doing P-1 redundant encodes in the profiled comm lap.
    let full_fanout = routing
        .as_ref()
        .is_some_and(|t| t.degenerates_to_broadcast());

    // Exchange cadence: how many steps each communication epoch spans.
    // Validated against delay_min_steps in RunConfig::validate, so every
    // spike still arrives before the first step it can influence. With
    // the online re-planner active this is only window 0's plan — later
    // windows read the shared, deterministically re-planned one.
    let static_plan = WindowPlan {
        epoch_steps: cfg
            .exchange_every
            .epoch_steps(cfg.net.delay_min_steps)
            .min(steps.max(1)),
        rotation: cfg.leader_rotation,
    };

    let p = transport.n_ranks() as usize;
    let mut comp = Components::default();
    let mut comm_vol = CommVolume::default();
    let mut sw = Stopwatch::new();
    let mut my_spikes: Vec<Spike> = Vec::new();
    let mut epoch_spikes: Vec<Spike> = Vec::new();
    let mut wire: Vec<u8> = Vec::new();
    let mut out_bufs: Vec<Vec<u8>> = vec![Vec::new(); p];
    let mut per_dst: Vec<Vec<Spike>> = vec![Vec::new(); p];
    let mut all_spikes: Vec<Spike> = Vec::new();
    let mut step_spikes: Vec<u32> = Vec::with_capacity(steps as usize);
    let inh_start = cfg.net.inh_start();
    let mut exc_spikes = 0u64;

    let mut step = 0u32;
    let mut window = 0u64;
    while step < steps {
        // Every rank derives the identical plan for this window (the
        // previous window's barrier made the re-planner's decision
        // complete before anyone reads it). Framing follows the planned
        // epoch, not the clipped tail length, so encoder and decoder
        // agree on every rank in every window; the paper's flat
        // 12-byte stream needs no run headers when every exchange
        // carries exactly one step.
        let wp = replanner.map_or(static_plan, |r| r.window_plan());
        let framed = wp.epoch_steps > 1;
        let encode: fn(&[Spike], f64, &mut Vec<u8>) = if framed {
            encode_spikes_epoch
        } else {
            encode_spikes
        };
        if replanner.is_some() {
            // Same value from every rank, between collectives — the
            // Transport::set_rotation contract (no-op on flat).
            transport.set_rotation(wp.rotation);
        }
        let len = wp.epoch_steps.min(steps - step);

        // 1. computation: integrate the epoch's steps, buffering local
        // emissions (tagged with their emission step) until the
        // exchange. The ring advances between steps but not after the
        // last one — delivery runs first — so an epoch of length 1 is
        // exactly the paper's per-step protocol.
        sw.reset();
        epoch_spikes.clear();
        for k in 0..len {
            engine.integrate(&mut my_spikes)?;
            step_spikes.push(my_spikes.len() as u32);
            exc_spikes += my_spikes.iter().filter(|s| s.gid < inh_start).count() as u64;
            epoch_spikes.extend_from_slice(&my_spikes);
            if k + 1 < len {
                engine.finish_step();
            }
        }
        comp.add_computation(sw.lap());

        // 2. communication: AER encode + ONE synchronous all-to-all for
        // the whole epoch.
        for buf in out_bufs.iter_mut() {
            buf.clear();
        }
        match &routing {
            Some(_) if full_fanout => {
                wire.clear();
                encode(&epoch_spikes, cfg.net.dt_ms, &mut wire);
                for (dst, buf) in out_bufs.iter_mut().enumerate() {
                    if dst as u32 != rank {
                        buf.extend_from_slice(&wire);
                    }
                }
            }
            Some(table) => {
                for list in per_dst.iter_mut() {
                    list.clear();
                }
                // epoch_spikes is step-ordered, so each per-destination
                // list stays step-ordered — the epoch framing's contract.
                let owned = engine.owned();
                for s in &epoch_spikes {
                    for dst in table.dest_ranks(owned.local_of(s.gid)) {
                        if dst != rank {
                            per_dst[dst as usize].push(*s);
                        }
                    }
                }
                for (dst, list) in per_dst.iter().enumerate() {
                    encode(list, cfg.net.dt_ms, &mut out_bufs[dst]);
                }
            }
            None => {
                wire.clear();
                encode(&epoch_spikes, cfg.net.dt_ms, &mut wire);
                for buf in out_bufs.iter_mut() {
                    buf.extend_from_slice(&wire);
                }
            }
        }
        let (incoming, stats) = transport.alltoall(rank, &out_bufs)?;
        comm_vol.observe(&stats);
        let comm_lap = sw.lap();
        comp.add_communication(comm_lap);
        if let Some(r) = replanner {
            // Report before the closing barrier: the barrier is what
            // publishes every rank's measurements to the boundary
            // decision.
            let payload: u64 = out_bufs
                .iter()
                .enumerate()
                .filter(|&(dst, _)| dst as u32 != rank)
                .map(|(_, b)| b.len() as u64)
                .sum();
            r.report(window, payload, len, comm_lap);
        }

        // 3. computation: decode + deliver through delay rings. Source
        // order is preserved (src 0..P, own spikes in their slot), so the
        // delivered event stream matches broadcast exactly; each spike
        // lands at `d + (t_emit - t_now)`, its per-step arrival slot.
        all_spikes.clear();
        for (src, buf) in incoming.iter().enumerate() {
            if routing.is_some() && src as u32 == rank {
                all_spikes.extend_from_slice(&epoch_spikes);
            } else if framed {
                decode_spikes_epoch(buf, cfg.net.dt_ms, &mut all_spikes)?;
            } else {
                decode_spikes(buf, cfg.net.dt_ms, &mut all_spikes)?;
            }
        }
        engine.deliver(&all_spikes);
        engine.finish_step();
        comp.add_computation(sw.lap());

        // 4. synchronization barrier (one per epoch)
        transport.barrier(rank);
        comp.add_barrier(sw.lap());

        step += len;
        window += 1;
        if let (Some(obs), 0) = (progress, rank) {
            // A handful of callbacks per run: fire when an eighth-of-run
            // boundary is crossed (and always at the end), whatever the
            // epoch length.
            let q = (steps / 8).max(1);
            if step == steps || step / q > (step - len) / q {
                obs(step.min(steps), steps);
            }
        }
        if cfg.progress && rank == 0 && step / 1000 > (step - len) / 1000 {
            eprintln!(
                "  [live] step {}/{} rate so far {:.2} Hz",
                step,
                steps,
                engine.mean_rate_hz(cfg.net.dt_ms)
            );
        }
    }

    Ok(RankReport {
        components: comp,
        totals: engine.totals,
        step_spikes,
        comm: comm_vol,
        exc_spikes,
        memory: engine.memory_use(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;

    fn tiny_cfg(procs: u32) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(512);
        cfg.procs = procs;
        cfg.sim_seconds = 0.2;
        cfg
    }

    #[test]
    fn live_run_completes_and_profiles() {
        let r = run_live(&tiny_cfg(4)).unwrap();
        assert_eq!(r.procs, 4);
        assert_eq!(r.per_rank.len(), 4);
        assert_eq!(r.pop_counts.len(), 200);
        assert!(r.wall_s > 0.0);
        assert!(r.components.total() > 0.0);
        assert!(r.total_spikes > 0, "network should be active");
        // population counts must equal the rank-sum of spikes
        let pop: u64 = r.pop_counts.iter().map(|&c| c as u64).sum();
        assert_eq!(pop, r.total_spikes);
        // filtered routing reports per-rank transport volume
        assert_eq!(r.comm_volume.len(), 4);
        assert!(r.comm_volume.iter().any(|c| c.bytes_sent > 0));
    }

    #[test]
    fn single_rank_matches_multi_rank_spike_totals() {
        let a = run_live(&tiny_cfg(1)).unwrap();
        let b = run_live(&tiny_cfg(4)).unwrap();
        assert_eq!(a.total_spikes, b.total_spikes, "partition independence");
        assert_eq!(a.pop_counts, b.pop_counts);
    }

    #[test]
    fn min_delay_epoch_matches_per_step_bitwise() {
        use crate::config::ExchangeCadence;
        let mut per_step = tiny_cfg(4);
        per_step.net.delay_min_steps = 4;
        let mut batched = per_step.clone();
        batched.exchange_every = ExchangeCadence::MinDelay;
        let a = run_live(&per_step).unwrap();
        let b = run_live(&batched).unwrap();
        assert!(a.total_spikes > 0, "network must be active");
        assert_eq!(a.pop_counts, b.pop_counts, "cadence changed the raster");
        assert_eq!(a.total_syn_events, b.total_syn_events);
        // 200 steps in epochs of 4 -> 50 exchanges instead of 200, with
        // one barrier per exchange.
        let exchanges = |r: &RunResult| r.comm_volume.iter().map(|c| c.exchanges).max().unwrap();
        assert_eq!(exchanges(&a), 200);
        assert_eq!(exchanges(&b), 50);
    }

    #[test]
    fn hierarchical_topology_matches_flat_bitwise() {
        let flat = run_live(&tiny_cfg(4)).unwrap();
        let mut cfg = tiny_cfg(4);
        cfg.topology = Topology::Nodes(2);
        let hier = run_live(&cfg).unwrap();
        assert!(flat.total_spikes > 0, "network must be active");
        assert_eq!(flat.pop_counts, hier.pop_counts, "topology changed the raster");
        assert_eq!(flat.total_syn_events, hier.total_syn_events);
        assert_eq!(hier.topology, Topology::Nodes(2));
        // P=4 flat: 4*3 = 12 inter messages per exchange; nodes:2 -> two
        // virtual nodes, N(N-1) = 2 aggregated messages per exchange.
        let inter = |r: &RunResult| r.comm_volume.iter().map(|c| c.inter_messages).sum::<u64>();
        let exchanges = flat.comm_volume.iter().map(|c| c.exchanges).max().unwrap();
        assert_eq!(inter(&flat), 12 * exchanges);
        assert_eq!(inter(&hier), 2 * exchanges);
        // the node-local traffic moved to intra-node messages instead
        assert!(hier.comm_volume.iter().all(|c| c.intra_messages > 0));
        assert!(flat.comm_volume.iter().all(|c| c.intra_messages == 0));
    }

    #[test]
    fn tree_topology_with_rotation_matches_flat_bitwise() {
        use crate::config::{LeaderRotation, TreeShape};
        let flat = run_live(&tiny_cfg(4)).unwrap();
        let mut cfg = tiny_cfg(4);
        cfg.topology = Topology::Tree(TreeShape::new(&[2, 2]).unwrap());
        cfg.leader_rotation = LeaderRotation::RoundRobin;
        let tree = run_live(&cfg).unwrap();
        assert!(flat.total_spikes > 0, "network must be active");
        assert_eq!(flat.pop_counts, tree.pop_counts, "tree changed the raster");
        assert_eq!(flat.total_syn_events, tree.total_syn_events);
        // P=4 as tree:2,2 -> 2 boards under a single chassis: two
        // board-pair messages per exchange, nothing on the top tier.
        let level = |r: &RunResult, lvl: usize| -> u64 {
            r.comm_volume
                .iter()
                .map(|c| c.level_messages.get(lvl).copied().unwrap_or(0))
                .sum()
        };
        let exchanges = tree.comm_volume.iter().map(|c| c.exchanges).max().unwrap();
        assert_eq!(level(&tree, 1), 2 * exchanges);
        assert_eq!(level(&tree, 2), 0, "single chassis: no top-tier traffic");
    }

    #[test]
    fn placement_policies_agree_bitwise() {
        use crate::config::PartitionPolicy;
        let base = run_live(&tiny_cfg(4)).unwrap();
        assert!(base.total_spikes > 0, "network must be active");
        for policy in [PartitionPolicy::RoundRobin, PartitionPolicy::GreedyComms] {
            let mut cfg = tiny_cfg(4);
            cfg.partition = policy;
            let r = run_live(&cfg).unwrap();
            assert_eq!(base.pop_counts, r.pop_counts, "{policy:?} changed the raster");
            assert_eq!(base.total_syn_events, r.total_syn_events);
            assert_eq!(base.total_exc_spikes, r.total_exc_spikes);
            assert_eq!(r.partition, policy);
            // per-rank totals permute, the whole-population sum doesn't
            assert_eq!(
                r.rank_spikes.iter().sum::<u64>(),
                base.total_spikes
            );
        }
    }

    #[test]
    fn online_replanner_switches_within_one_window_of_a_regime_shift() {
        use crate::config::ExchangeCadence;
        // Synthetic reports, 2 ranks, dmin = 4: quiet AW-class windows
        // keep the full min-delay batch; an injected SWA-class burst
        // must drop the cadence to per-step at the very next boundary
        // (well inside the acceptance budget of 3), and the calm-down
        // must restore batching.
        let mut cfg = tiny_cfg(2);
        cfg.net.delay_min_steps = 4;
        cfg.exchange_every = ExchangeCadence::MinDelay;
        cfg.auto.exchange_every = true;
        let r = OnlineReplanner::from_config(&cfg)
            .unwrap()
            .with_crossover_bytes(1000.0);
        assert_eq!(r.window_plan().epoch_steps, 4);
        // window 0: quiet (25 B/pair-step) -> stay batched
        r.report(0, 100, 4, 1e-6);
        r.report(0, 100, 4, 1e-6);
        assert_eq!(r.window_plan().epoch_steps, 4);
        // window 1: burst (10 kB/pair-step) -> per-step from window 2
        r.report(1, 40_000, 4, 1e-6);
        r.report(1, 40_000, 4, 1e-6);
        assert_eq!(r.window_plan().epoch_steps, 1);
        // window 2: quiet again -> back to min-delay batching
        r.report(2, 25, 1, 1e-6);
        r.report(2, 25, 1, 1e-6);
        assert_eq!(r.window_plan().epoch_steps, 4);
        let events = r.take_events();
        assert_eq!(events.len(), 2, "exactly the two regime switches");
        assert_eq!((events[0].window, events[0].epoch_steps), (1, 1));
        assert_eq!((events[1].window, events[1].epoch_steps), (2, 4));
        assert!(events.iter().all(|e| e.predicted_exchange_s > 0.0));
        assert!(events.iter().all(|e| e.measured_exchange_s > 0.0));
    }

    #[test]
    fn online_replanning_keeps_the_raster_bitwise_identical() {
        use crate::config::{ExchangeCadence, TreeShape};
        // Baseline: static min-delay batching on the flat transport.
        let mut cfg = tiny_cfg(4);
        cfg.net.delay_min_steps = 4;
        cfg.exchange_every = ExchangeCadence::MinDelay;
        let base = run_live(&cfg).unwrap();
        assert!(base.total_spikes > 0, "network must be active");

        // Injected SWA shift: a zero crossover makes every measured
        // window bandwidth-bound, so after window 0 the controller
        // drops the batching to per-step and (on the tree) turns leader
        // rotation on — and the raster must not move.
        let mut swa = cfg.clone();
        swa.topology = Topology::Tree(TreeShape::new(&[2, 2]).unwrap());
        swa.auto.exchange_every = true;
        swa.auto.leader_rotation = true;
        let rp = OnlineReplanner::from_config(&swa)
            .unwrap()
            .with_crossover_bytes(0.0);
        let shifted = run_live_with(&swa, Some(Arc::new(rp))).unwrap();
        assert_eq!(base.pop_counts, shifted.pop_counts, "re-plan moved the raster");
        assert_eq!(base.total_syn_events, shifted.total_syn_events);
        let first = shifted.replans.first().expect("the shift must re-plan");
        assert_eq!(first.window, 0, "switch at the first boundary");
        assert_eq!(first.epoch_steps, 1);
        assert_eq!(first.rotation, LeaderRotation::RoundRobin);

        // The reverse (AW) direction: an infinite crossover pushes a
        // per-step start back to full min-delay batching.
        let mut aw = cfg.clone();
        aw.exchange_every = ExchangeCadence::Step;
        aw.auto.exchange_every = true;
        let rp = OnlineReplanner::from_config(&aw)
            .unwrap()
            .with_crossover_bytes(f64::INFINITY);
        let calmed = run_live_with(&aw, Some(Arc::new(rp))).unwrap();
        assert_eq!(base.pop_counts, calmed.pop_counts, "re-plan moved the raster");
        let first = calmed.replans.first().expect("the calm must re-plan");
        assert_eq!((first.window, first.epoch_steps), (0, 4));
    }

    #[test]
    fn run_result_records_resolved_exchange_axes() {
        use crate::config::ExchangeCadence;
        let mut cfg = tiny_cfg(2);
        cfg.net.delay_min_steps = 4;
        cfg.exchange_every = ExchangeCadence::Every(2);
        cfg.compute_threads = 2;
        let r = run_live(&cfg).unwrap();
        assert_eq!(r.exchange_every, ExchangeCadence::Every(2));
        assert_eq!(r.leader_rotation, cfg.leader_rotation);
        assert_eq!(r.compute_threads, 2);
        assert!(!r.auto.any(), "no axes were auto");
        assert!(r.replans.is_empty(), "no re-planner without auto axes");
    }

    #[test]
    fn broadcast_and_filtered_agree_bitwise() {
        let mut bcast = tiny_cfg(4);
        bcast.routing = Routing::Broadcast;
        let a = run_live(&bcast).unwrap();
        let b = run_live(&tiny_cfg(4)).unwrap();
        assert_eq!(a.pop_counts, b.pop_counts, "rasters must be identical");
        assert_eq!(a.total_syn_events, b.total_syn_events);
        // tiny(512) is dense (M = 128 >> P = 4): the pair filter
        // degenerates to broadcast on the network but still removes the
        // loopback copy on the receive side.
        let recv = |r: &RunResult| -> u64 {
            r.comm_volume.iter().map(|c| c.bytes_recv).sum()
        };
        assert!(recv(&b) < recv(&a), "filtered must receive fewer bytes");
        let sent = |r: &RunResult| -> u64 {
            r.comm_volume.iter().map(|c| c.bytes_sent).sum()
        };
        assert!(sent(&b) <= sent(&a));
    }
}
