//! Live execution: P ranks as OS threads over the in-process all-to-all
//! transport, with the paper's comp/comm/barrier profiling.
//!
//! Step protocol per rank (matching DPSNN's synchronous-collective
//! scheme):
//!
//! 1. integrate local dynamics            -> Computation
//! 2. AER-encode + all-to-all exchange    -> Communication
//! 3. decode + deliver into delay rings   -> Computation
//! 4. explicit barrier                    -> Barrier/synchronization
//!
//! Because connectivity, stimulus and initial state are pure functions of
//! global neuron ids, and synaptic weights live on an exact f32 grid, the
//! spike raster is **bitwise identical for every process count** — tested
//! in `rust/tests/determinism.rs`.

use anyhow::{Context, Result};

use crate::comm::aer::{decode_spikes, encode_spikes};
use crate::comm::local::LocalCluster;
use crate::comm::transport::Transport;
use crate::config::{Mode, RunConfig};
use crate::engine::partition::Partition;
use crate::engine::rank::RankEngine;
use crate::engine::spike::Spike;
use crate::model::population::PopulationState;
use crate::profiling::components::Components;
use crate::profiling::timer::Stopwatch;
use crate::runtime::make_backend;

use super::orchestrator::RunResult;

/// What each rank thread reports back.
struct RankReport {
    components: Components,
    totals: crate::engine::rank::StepOutcome,
    /// Whole-population per-step spike counts (every rank sees all
    /// spikes; only rank 0's copy is kept).
    pop_counts: Option<Vec<u32>>,
    /// Per-step per-rank spike counts (rank 0, when trace recording is on).
    rank_counts: Option<Vec<Vec<u32>>>,
}

pub fn run_live(cfg: &RunConfig) -> Result<RunResult> {
    let p = cfg.procs;
    let steps = cfg.steps();
    let part = Partition::even(cfg.net.n_neurons, p);
    let cluster = LocalCluster::new(p);

    let t0 = std::time::Instant::now();
    let reports: Vec<RankReport> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for rank in 0..p {
            let cluster = cluster.clone();
            let cfg = cfg.clone();
            let part = part.clone();
            handles.push(scope.spawn(move || -> Result<RankReport> {
                rank_main(rank, &cfg, &part, cluster, steps)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall_s = t0.elapsed().as_secs_f64();

    let per_rank: Vec<Components> = reports.iter().map(|r| r.components).collect();
    let mut mean = Components::merged(&per_rank);
    mean.computation /= p as f64;
    mean.communication /= p as f64;
    mean.barrier /= p as f64;

    let total_spikes: u64 = reports.iter().map(|r| r.totals.spikes).sum();
    let total_syn: u64 = reports.iter().map(|r| r.totals.syn_events).sum();
    let total_ext: u64 = reports.iter().map(|r| r.totals.ext_events).sum();
    let mut pop_counts = Vec::new();
    let mut trace = None;
    for r in reports {
        if let Some(c) = r.pop_counts {
            pop_counts = c;
        }
        if let Some(rc) = r.rank_counts {
            trace = Some(crate::trace::workload::WorkloadTrace {
                n_neurons: cfg.net.n_neurons,
                syn_per_neuron: cfg.net.syn_per_neuron,
                ext_events_per_neuron_step: cfg.net.ext_lambda_per_step(),
                dt_ms: cfg.net.dt_ms,
                procs: p,
                spikes: rc,
            });
        }
    }
    if let (Some(t), Some(path)) = (&trace, &cfg.record_trace) {
        t.save(std::path::Path::new(path))?;
    }

    let sim_s = cfg.sim_seconds;
    Ok(RunResult {
        mode: Mode::Live,
        procs: p,
        wall_s,
        sim_s,
        components: mean,
        per_rank,
        total_spikes,
        total_syn_events: total_syn,
        total_ext_events: total_ext,
        mean_rate_hz: total_spikes as f64 / cfg.net.n_neurons as f64 / sim_s,
        pop_counts,
        energy: None,
        trace,
        backend: match cfg.backend {
            crate::config::Backend::Native => "native",
            crate::config::Backend::Xla => "xla",
        },
        platform: "host-live".to_string(),
    })
}

fn rank_main(
    rank: u32,
    cfg: &RunConfig,
    part: &Partition,
    cluster: std::sync::Arc<LocalCluster>,
    steps: u32,
) -> Result<RankReport> {
    let (lo, hi) = part.range(rank);
    let pop = PopulationState::init(&cfg.net, cfg.seed, lo, hi - lo);
    let backend = make_backend(
        cfg.backend,
        &cfg.net,
        pop,
        std::path::Path::new(&cfg.artifacts_dir),
    )
    .with_context(|| format!("rank {rank} backend"))?;
    let mut engine = RankEngine::new(&cfg.net, cfg.seed, rank, lo, hi, backend);

    let mut comp = Components::default();
    let mut sw = Stopwatch::new();
    let mut my_spikes: Vec<Spike> = Vec::new();
    let mut wire: Vec<u8> = Vec::new();
    let mut all_spikes: Vec<Spike> = Vec::new();
    let mut pop_counts: Option<Vec<u32>> =
        (rank == 0).then(|| Vec::with_capacity(steps as usize));
    let mut rank_counts: Option<Vec<Vec<u32>>> = (rank == 0
        && cfg.record_trace.is_some())
    .then(|| Vec::with_capacity(steps as usize));

    for step in 0..steps {
        // 1. computation: integrate
        sw.reset();
        engine.integrate(&mut my_spikes)?;
        comp.add_computation(sw.lap());

        // 2. communication: AER encode + synchronous all-to-all
        wire.clear();
        encode_spikes(&my_spikes, cfg.net.dt_ms, &mut wire);
        let outgoing: Vec<Vec<u8>> = (0..cluster.n_ranks())
            .map(|_| wire.clone())
            .collect();
        let (incoming, _stats) = cluster.alltoall(rank, &outgoing)?;
        comp.add_communication(sw.lap());

        // 3. computation: decode + deliver through delay rings
        all_spikes.clear();
        for buf in &incoming {
            decode_spikes(buf, cfg.net.dt_ms, &mut all_spikes)?;
        }
        engine.deliver(&all_spikes);
        engine.finish_step();
        if let Some(c) = pop_counts.as_mut() {
            c.push(all_spikes.len() as u32);
        }
        if let Some(rc) = rank_counts.as_mut() {
            let mut row = vec![0u32; cluster.n_ranks() as usize];
            for s in &all_spikes {
                row[part.owner(s.gid) as usize] += 1;
            }
            rc.push(row);
        }
        comp.add_computation(sw.lap());

        // 4. synchronization barrier
        cluster.barrier(rank);
        comp.add_barrier(sw.lap());

        if cfg.progress && rank == 0 && (step + 1) % 1000 == 0 {
            eprintln!(
                "  [live] step {}/{} rate so far {:.2} Hz",
                step + 1,
                steps,
                engine.mean_rate_hz(cfg.net.dt_ms)
            );
        }
    }

    Ok(RankReport {
        components: comp,
        totals: engine.totals,
        pop_counts,
        rank_counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;

    fn tiny_cfg(procs: u32) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(512);
        cfg.procs = procs;
        cfg.sim_seconds = 0.2;
        cfg
    }

    #[test]
    fn live_run_completes_and_profiles() {
        let r = run_live(&tiny_cfg(4)).unwrap();
        assert_eq!(r.procs, 4);
        assert_eq!(r.per_rank.len(), 4);
        assert_eq!(r.pop_counts.len(), 200);
        assert!(r.wall_s > 0.0);
        assert!(r.components.total() > 0.0);
        assert!(r.total_spikes > 0, "network should be active");
        // population counts must equal the rank-sum of spikes
        let pop: u64 = r.pop_counts.iter().map(|&c| c as u64).sum();
        assert_eq!(pop, r.total_spikes);
    }

    #[test]
    fn single_rank_matches_multi_rank_spike_totals() {
        let a = run_live(&tiny_cfg(1)).unwrap();
        let b = run_live(&tiny_cfg(4)).unwrap();
        assert_eq!(a.total_spikes, b.total_spikes, "partition independence");
        assert_eq!(a.pop_counts, b.pop_counts);
    }
}
