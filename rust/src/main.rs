//! `dpsnn` — command-line entry point.
//!
//! ```text
//! dpsnn run [config.toml] [--neurons N] [--procs P] [--seconds S]
//!           [--backend native|xla] [--mode live|modeled]
//!           [--platform NAME] [--interconnect NAME] [--seed X] [--progress]
//! dpsnn repro <fig1..fig8|table1..table4|all> [--fast]
//! dpsnn list-platforms
//! dpsnn raster [--neurons N] [--seconds S] [--bin MS]   # regime demo
//! ```

use anyhow::{bail, Result};

use dpsnn::config::{NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::harness;
use dpsnn::stats::rates::RateMonitor;
use dpsnn::stats::regime::classify_regime;
use dpsnn::util::cli::Args;

const USAGE: &str = "\
dpsnn — DPSNN real-time cortical simulation study (EMPDP 2019 reproduction)

USAGE:
  dpsnn run [config.toml] [options]     run one simulation
  dpsnn repro <id|all> [--fast]         regenerate a paper figure/table
  dpsnn replay <trace.csv> [options]    replay a recorded trace on a
                                        modeled platform (see --record-trace)
  dpsnn list-platforms                  show modeled platform presets
  dpsnn raster [options]                live run + population-rate raster

RUN OPTIONS:
  --neurons N        network size (default 20480)
  --procs P          MPI-style rank count (default 1)
  --seconds S        simulated seconds (default 10)
  --backend B        native | xla (default native)
  --mode M           live | modeled (default live)
  --platform NAME    modeled platform preset (default xeon)
  --interconnect IC  ib | eth1g | shm | exanest (default ib)
  --artifacts DIR    AOT artifact directory (default artifacts)
  --seed X           RNG seed
  --progress         print per-second progress
  --record-trace F   write the per-step workload trace to F (live runs)

REPRO IDS:
  fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 table1 table2 table3 table4 all
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("run") => cmd_run(&args),
        Some("repro") => cmd_repro(&args),
        Some("replay") => cmd_replay(&args),
        Some("list-platforms") => cmd_list_platforms(),
        Some("raster") => cmd_raster(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.positional.get(1) {
        Some(path) if path.ends_with(".toml") => {
            RunConfig::from_toml_file(std::path::Path::new(path))?
        }
        _ => RunConfig::default(),
    };
    if let Some(n) = args.get("neurons") {
        cfg.net = NetworkParams::paper(n.parse()?);
    }
    cfg.procs = args.get_or("procs", cfg.procs)?;
    cfg.sim_seconds = args.get_or("seconds", cfg.sim_seconds)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = m.parse()?;
    }
    if let Some(p) = args.get("platform") {
        cfg.platform = p.to_string();
    }
    if let Some(ic) = args.get("interconnect") {
        cfg.interconnect = ic.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    cfg.progress = args.has_flag("progress");
    cfg.record_trace = args.get("record-trace").map(String::from);
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    eprintln!(
        "running {} neurons / {} synapses on {} procs ({:?}, {} backend)...",
        cfg.net.n_neurons,
        cfg.net.total_synapses(),
        cfg.procs,
        cfg.mode,
        cfg.backend
    );
    let result = coordinator::run(&cfg)?;
    println!("{}", result.summary());
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fast = args.has_flag("fast");
    let ids: Vec<&str> = if id == "all" {
        harness::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        eprintln!("== {id} ==");
        let report = harness::run_one(id, fast)?;
        println!("{report}");
    }
    println!(
        "CSV outputs in {}",
        harness::common::results_dir().display()
    );
    Ok(())
}

/// Replay a recorded live trace through the modeled platform pipeline:
/// `dpsnn replay trace.csv --platform westmere --interconnect ib [--procs P]`
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: dpsnn replay <trace.csv> [options]"))?;
    let trace = dpsnn::trace::workload::WorkloadTrace::load(std::path::Path::new(path))?;
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::paper(trace.n_neurons);
    cfg.net.syn_per_neuron = trace.syn_per_neuron;
    cfg.mode = dpsnn::config::Mode::Modeled;
    cfg.platform = args.get_or("platform", "xeon".to_string())?;
    cfg.interconnect = args.get_or("interconnect", "ib".to_string())?;
    cfg.procs = args.get_or("procs", trace.procs)?;
    let trace = if cfg.procs != trace.procs {
        trace.rebin(cfg.procs)?
    } else {
        trace
    };
    cfg.sim_seconds = trace.sim_seconds();
    eprintln!(
        "replaying {} steps x {} ranks ({} spikes, {:.2} Hz) on {}+{}...",
        trace.steps(),
        trace.procs,
        trace.total_spikes(),
        trace.mean_rate_hz(),
        cfg.platform,
        cfg.interconnect
    );
    let r = dpsnn::coordinator::modeled::run_modeled_trace(&cfg, &trace)?;
    println!("{}", r.summary());
    Ok(())
}

fn cmd_list_platforms() -> Result<()> {
    println!("modeled platforms (DESIGN.md §2 hardware substitutions):");
    for name in dpsnn::platform::presets::all_names() {
        let p = dpsnn::platform::presets::platform_by_name(name)?;
        println!(
            "  {:<14} {:<16} {:>2} cores/node  baseline {:>5.1} W  default {}",
            name,
            p.node.core.name,
            p.node.cores_per_node,
            p.baseline_w,
            p.default_interconnect,
        );
    }
    println!("interconnects:");
    for l in dpsnn::simnet::presets::all() {
        println!(
            "  {:<9} alpha {:>6.1} us  beta {:>6.2} Gb/s  nic {:>4.1} W",
            l.name,
            l.alpha_s * 1e6,
            l.beta_bps * 8.0 / 1e9,
            l.nic_active_w,
        );
    }
    Ok(())
}

fn cmd_raster(args: &Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    if args.get("neurons").is_none() {
        cfg.net = NetworkParams::tiny(2048);
    }
    if args.get("seconds").is_none() {
        cfg.sim_seconds = 3.0;
    }
    let bin: usize = args.get_or("bin", 25usize)?;
    let r = coordinator::run(&cfg)?;
    let mut monitor = RateMonitor::new(cfg.net.n_neurons, cfg.net.dt_ms);
    for &c in &r.pop_counts {
        monitor.record(c);
    }
    let series = monitor.rate_series_hz(bin);
    println!(
        "population rate ({} ms bins), mean {:.2} Hz:",
        bin,
        monitor.mean_rate_hz()
    );
    let peak = series.iter().cloned().fold(1e-9, f64::max);
    for (i, &rate) in series.iter().enumerate() {
        let bar = "#".repeat(((rate / peak) * 60.0) as usize);
        println!("{:>6} ms |{bar} {rate:.1}", i * bin);
    }
    println!(
        "regime: {:?}",
        classify_regime(&monitor, 50, monitor.steps() / 5)
    );
    Ok(())
}
