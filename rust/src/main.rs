//! `dpsnn` — command-line entry point.
//!
//! ```text
//! dpsnn run [config.toml] [--neurons N] [--procs P] [--seconds S]
//!           [--backend native|xla] [--mode live|modeled]
//!           [--routing filtered|broadcast] [--exchange-every step|min-delay|N|auto]
//!           [--topology flat|nodes:<k>|tree:<k1>,<k2>,...|auto]
//!           [--partition index|round-robin|greedy-comms]
//!           [--leader-rotation fixed|round-robin|auto]
//!           [--compute-threads N|auto]
//!           [--connectivity materialized|procedural|auto]
//!           [--platform NAME] [--interconnect NAME] [--seed X] [--progress]
//! dpsnn repro <fig1..fig8|table1..table4|all> [--fast]
//! dpsnn bench-smoke [--neurons N] [--procs P] [--seconds S] [--out F]
//! dpsnn serve [job1.toml ...] [--jobs N] [--total-ranks R] [--bench-out F]
//! dpsnn list-platforms
//! dpsnn raster [--neurons N] [--seconds S] [--bin MS]   # regime demo
//! ```

use anyhow::{bail, Result};

use dpsnn::config::{NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::harness;
use dpsnn::stats::rates::RateMonitor;
use dpsnn::stats::regime::classify_regime;
use dpsnn::util::cli::Args;

const USAGE: &str = "\
dpsnn — DPSNN real-time cortical simulation study (EMPDP 2019 reproduction)

USAGE:
  dpsnn run [config.toml] [options]     run one simulation
  dpsnn repro <id|all> [--fast]         regenerate a paper figure/table
  dpsnn replay <trace.csv> [options]    replay a recorded trace on a
                                        modeled platform (see --record-trace);
                                        pass --delay-min to price an
                                        --exchange-every cadence what-if,
                                        --topology nodes:<k> or
                                        tree:<k1>,<k2>,... for a
                                        hierarchical-exchange what-if
                                        (tree tiers priced with the
                                        platform's per-level links)
  dpsnn bench-smoke [options]           tiny live runs: filtered vs broadcast
                                        routing, per-step vs min-delay cadence,
                                        flat vs hierarchical topology; JSON
                                        perf records (CI)
  dpsnn serve [job.toml ...] [options]  resident multi-tenant server: run
                                        many jobs through one process with
                                        shared plan/placement/connectome/
                                        artifact caches and simnet-priced
                                        scheduling, then benchmark against
                                        the same jobs run cold sequentially
  dpsnn list-platforms                  show modeled platform presets
  dpsnn raster [options]                live run + population-rate raster

RUN OPTIONS:
  --neurons N        network size (default 20480)
  --procs P          MPI-style rank count (default 1)
  --seconds S        simulated seconds (default 10)
  --backend B        native | xla (default native)
  --mode M           live | modeled (default live)
  --routing R        filtered | broadcast spike exchange (default filtered)
  --exchange-every C step | min-delay | N | auto — steps per spike
                     exchange (default step; N must not exceed
                     delay_min_steps; auto lets the analytic planner
                     pick the latency-bandwidth crossover cadence, and
                     live runs re-plan it online at window boundaries
                     from measured traffic)
  --topology T       flat | nodes:<k> | tree:<k1>,<k2>,... | auto —
                     transport topology (default flat);
                     tree:<k1>,<k2>,... groups k1 ranks per board, k2
                     boards per chassis, k3 chassis per rack and
                     aggregates boundary-crossing spikes at per-group
                     leaders (ONE framed message per sibling-group pair
                     at every tier); nodes:<k> is sugar for tree:<k>;
                     auto prices flat plus every divisor-chain tree
                     with the platform's closed forms and picks the
                     argmin
  --partition P      index | round-robin | greedy-comms — the placement
                     policy mapping neuron blocks onto ranks (default
                     index, the historical contiguous split);
                     greedy-comms reads the stateless connectome and
                     the topology tree and keeps strongly-coupled
                     blocks on cheap links (the raster is bitwise
                     identical under every policy)
  --leader-rotation R fixed | round-robin | auto — which rank of each
                     group pays the aggregation CPU cost per exchange
                     (default fixed; raster and message counts are
                     identical either way; auto spreads leaders only
                     when the measured regime is bandwidth-bound)
  --compute-threads N intra-rank worker threads for the neuron update,
                     Poisson fill and synaptic delivery (default 1;
                     auto divides the host's parallelism across the P
                     rank threads). The chunk geometry is fixed by the
                     resolved count alone, so the raster is bitwise
                     identical for every N on every host
  --connectivity C   materialized | procedural | auto — synapse-state
                     representation (default materialized): materialized
                     prebuilds the incoming CSR table, procedural
                     regenerates each firing source's row from the
                     stateless connectome at delivery time and swaps
                     the dense delay ring for compressed per-slot
                     event buckets (O(state) resident memory — 100x
                     networks fit where the table cannot build; the
                     raster is bitwise identical either way); auto asks
                     the analytic memory model (2 GiB/rank budget)
  --platform NAME    modeled platform preset (default xeon)
  --interconnect IC  ib | eth1g | shm | exanest (default ib)
  --artifacts DIR    AOT artifact directory (default artifacts)
  --seed X           RNG seed
  --progress         print per-second progress
  --record-trace F   write the per-step workload trace to F (live runs)

BENCH-SMOKE OPTIONS:
  --neurons N / --procs P / --seconds S   workload (default 2048 / 4 / 1)
  --delay-min D      min axonal delay in steps — the epoch the min-delay
                     cadence run batches over (default 8)
  --out F            JSON output path (default BENCH_routing.json)
  --topology T       hierarchical topology to compare against flat
                     (default nodes:2; nodes:<k> or tree:<k1>,...,
                     ideally with procs > k1 so the hierarchy spans
                     >= 2 groups)
  --topology-out F   topology JSON output path (default BENCH_topology.json)
  --platform NAME    power-model platform preset (default xeon)
  --partition P      comm-aware placement policy to compare against the
                     index baseline (default greedy-comms)
  --partition-neurons N / --partition-syn M / --partition-procs P
                     placement workload (default 20480 / 4 / 8): a
                     sparse connectome, because the dense M=1125
                     network degenerates the destination filter to
                     broadcast (pair_coverage ~ 1) and placement could
                     not move a byte
  --partition-seconds S  placement-run simulated seconds (default 0.1)
  --partition-out F  placement JSON output path (default
                     BENCH_partition.json)
  --compute-out F    compute-kernel JSON output path (default
                     BENCH_compute.json): scalar baseline vs SoA path
                     for the neuron update, Poisson fill and synaptic
                     delivery at the paper's 20480N size, 1/2/4
                     compute threads, with elems/sec and the
                     realtime_x margin over the 1 ms step budget
  --autotune-out F   self-tuning JSON output path (default
                     BENCH_autotune.json): per-platform modeled sweep
                     at the paper's 20480N / 32-proc / 16-step point —
                     the planner's all-auto pick vs the best hand-swept
                     topology x cadence combination — plus the online
                     re-planner's injected regime shifts (switch window
                     and raster identity)
  --memory-out F     connectivity-mode memory JSON output path (default
                     BENCH_memory.json): materialized vs procedural
                     live runs (bitwise-identical rasters, measured
                     resident bytes vs the analytic closed forms,
                     O(state) gate on the procedural store) plus the
                     100x acceptance point — 2M neurons on ONE rank,
                     resolved procedural by --connectivity auto, run
                     inside the per-rank budget the materialized table
                     cannot fit

SERVE OPTIONS:
  job.toml ...       job specs (a run config TOML, optionally with a
                     [job] name = \"...\" table); with no files a matrix
                     of --jobs bench-smoke-sized jobs is synthesized
                     with distinct seeds and varied routing / cadence /
                     connectivity regimes
  --jobs N           synthesized job count (default 4)
  --total-ranks R    rank budget shared by in-flight jobs (default: the
                     host's parallelism, at least the largest job)
  --neurons N / --procs P / --seconds S   synthesized workload
                     (default 2048 / 2 / 1)
  --delay-min D      min axonal delay in steps for synthesized jobs
                     (default 8)
  --seed X           base seed; job i uses X+i (default paper seed)
  --bench-out F      JSON output path (default BENCH_server.json):
                     total wall clock + per-job J/synaptic-event and
                     raster SHA-256 for the concurrent server pass vs
                     the same jobs run cold sequentially through the
                     solo CLI path, plus shared-cache hit counters;
                     exits nonzero unless rasters match bitwise and the
                     server pass wins on wall clock

REPRO IDS:
  fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 table1 table2 table3 table4 all
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("run") => cmd_run(&args),
        Some("repro") => cmd_repro(&args),
        Some("replay") => cmd_replay(&args),
        Some("bench-smoke") => cmd_bench_smoke(&args),
        Some("serve") => cmd_serve(&args),
        Some("list-platforms") => cmd_list_platforms(),
        Some("raster") => cmd_raster(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.positional.get(1) {
        Some(path) if path.ends_with(".toml") => {
            RunConfig::from_toml_file(std::path::Path::new(path))?
        }
        _ => RunConfig::default(),
    };
    if let Some(n) = args.get("neurons") {
        cfg.net = NetworkParams::paper(n.parse()?);
    }
    cfg.procs = args.get_or("procs", cfg.procs)?;
    cfg.sim_seconds = args.get_or("seconds", cfg.sim_seconds)?;
    cfg.seed = args.get_or("seed", cfg.seed)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = b.parse()?;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = m.parse()?;
    }
    if let Some(r) = args.get("routing") {
        cfg.routing = r.parse()?;
    }
    // `auto` flags an axis for the planner (resolved in
    // coordinator::run); any other value is an explicit pick.
    if let Some(x) = args.get("exchange-every") {
        if x.eq_ignore_ascii_case("auto") {
            cfg.auto.exchange_every = true;
        } else {
            cfg.exchange_every = x.parse()?;
        }
    }
    if let Some(t) = args.get("topology") {
        if t.eq_ignore_ascii_case("auto") {
            cfg.auto.topology = true;
        } else {
            cfg.topology = t.parse()?;
        }
    }
    if let Some(p) = args.get("partition") {
        cfg.partition = p.parse()?;
    }
    if let Some(r) = args.get("leader-rotation") {
        if r.eq_ignore_ascii_case("auto") {
            cfg.auto.leader_rotation = true;
        } else {
            cfg.leader_rotation = r.parse()?;
        }
    }
    match args.get("compute-threads") {
        Some(t) if t.eq_ignore_ascii_case("auto") => cfg.auto.compute_threads = true,
        Some(t) => cfg.compute_threads = t.parse()?,
        None => {}
    }
    match args.get("connectivity") {
        Some(c) if c.eq_ignore_ascii_case("auto") => cfg.auto.connectivity = true,
        Some(c) => cfg.connectivity = c.parse()?,
        None => {}
    }
    if let Some(p) = args.get("platform") {
        cfg.platform = p.to_string();
    }
    if let Some(ic) = args.get("interconnect") {
        cfg.interconnect = ic.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    cfg.progress = args.has_flag("progress");
    cfg.record_trace = args.get("record-trace").map(String::from);
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    eprintln!(
        "running {} neurons / {} synapses on {} procs ({:?}, {} backend)...",
        cfg.net.n_neurons,
        cfg.net.total_synapses(),
        cfg.procs,
        cfg.mode,
        cfg.backend
    );
    let result = coordinator::run(&cfg)?;
    println!("{}", result.summary());
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fast = args.has_flag("fast");
    let ids: Vec<&str> = if id == "all" {
        harness::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        eprintln!("== {id} ==");
        let report = harness::run_one(id, fast)?;
        println!("{report}");
    }
    println!(
        "CSV outputs in {}",
        harness::common::results_dir().display()
    );
    Ok(())
}

/// Replay a recorded live trace through the modeled platform pipeline:
/// `dpsnn replay trace.csv --platform westmere --interconnect ib [--procs P]`
fn cmd_replay(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: dpsnn replay <trace.csv> [options]"))?;
    let trace = dpsnn::trace::workload::WorkloadTrace::load(std::path::Path::new(path))?;
    let mut cfg = RunConfig::default();
    cfg.net = NetworkParams::paper(trace.n_neurons);
    cfg.net.syn_per_neuron = trace.syn_per_neuron;
    cfg.mode = dpsnn::config::Mode::Modeled;
    // Recorded traces came from the paper-style exchange; price broadcast
    // unless the user asks for the filtered matrix.
    cfg.routing = args.get_or("routing", dpsnn::config::Routing::Broadcast)?;
    // Traces carry no delay metadata, so a cadence what-if needs the
    // recorded network's min-delay window declared explicitly; validate()
    // then rejects epochs the live engine could never run. The window is
    // honored exactly (delay_max stretches with it), never clamped.
    cfg.net.delay_min_steps = args.get_or("delay-min", cfg.net.delay_min_steps)?;
    cfg.net.delay_max_steps = cfg.net.delay_max_steps.max(cfg.net.delay_min_steps);
    cfg.exchange_every =
        args.get_or("exchange-every", dpsnn::config::ExchangeCadence::Step)?;
    // Topology what-if: price the node-leader hierarchical exchange
    // (nodes:<k> also declares the replay's ranks-per-node packing).
    cfg.topology = args.get_or("topology", dpsnn::config::Topology::Flat)?;
    cfg.platform = args.get_or("platform", "xeon".to_string())?;
    cfg.interconnect = args.get_or("interconnect", "ib".to_string())?;
    cfg.procs = args.get_or("procs", trace.procs)?;
    let trace = if cfg.procs != trace.procs {
        trace.rebin(cfg.procs)?
    } else {
        trace
    };
    cfg.sim_seconds = trace.sim_seconds();
    cfg.validate()?;
    eprintln!(
        "replaying {} steps x {} ranks ({} spikes, {:.2} Hz) on {}+{}...",
        trace.steps(),
        trace.procs,
        trace.total_spikes(),
        trace.mean_rate_hz(),
        cfg.platform,
        cfg.interconnect
    );
    let r = dpsnn::coordinator::modeled::run_modeled_trace(&cfg, &trace)?;
    println!("{}", r.summary());
    Ok(())
}

/// CI perf smoke: run a tiny live simulation under both spike-routing
/// protocols, both exchange cadences (per-step vs min-delay epoch
/// batching) and both transport topologies (flat vs node-leader
/// hierarchical) and emit machine-readable `BENCH_routing.json` +
/// `BENCH_topology.json` with wall-clock, barrier/exchange counts,
/// per-rank transport bytes/messages (intra/inter split) and the power
/// model's J/synaptic-event, so successive PRs accumulate a perf
/// trajectory. Also measures the compute kernels (scalar baseline vs
/// the SoA path at 1/2/4 threads) into `BENCH_compute.json`, and the
/// self-tuning runtime into `BENCH_autotune.json`: the planner's
/// all-auto pick vs a hand-swept topology x cadence grid on every
/// platform preset, plus the online re-planner's injected regime
/// shifts.
fn cmd_bench_smoke(args: &Args) -> Result<()> {
    use dpsnn::config::{ExchangeCadence, Mode, Routing, Topology};
    use dpsnn::coordinator::RunResult;
    use dpsnn::metrics::expected_exchanges;

    let neurons: u32 = args.get_or("neurons", 2048u32)?;
    let procs: u32 = args.get_or("procs", 4u32)?;
    let seconds: f64 = args.get_or("seconds", 1.0)?;
    let delay_min: u32 = args.get_or("delay-min", 8u32)?;
    let out = args.get_or("out", "BENCH_routing.json".to_string())?;
    // default nodes:2 keeps the hierarchy non-degenerate (>= 2 virtual
    // nodes) at the default 4-proc workload; CI passes tree:2,2 with 8
    // procs so the multi-tier path is exercised too
    let topology: Topology = args.get_or("topology", Topology::Nodes(2))?;
    // reject a non-hierarchical topology up front, before burning
    // minutes of live benchmark runs on a flag that can't be compared
    let tree_shape = topology.tree().ok_or_else(|| {
        anyhow::anyhow!(
            "bench-smoke --topology must be nodes:<k> or tree:<k1>,..., got {topology}"
        )
    })?;
    let topo_out = args.get_or("topology-out", "BENCH_topology.json".to_string())?;
    let platform_name = args.get_or("platform", "xeon".to_string())?;

    let platform = dpsnn::platform::presets::platform_by_name(&platform_name)?;
    let link = dpsnn::simnet::presets::interconnect_by_name(platform.default_interconnect)?;
    // one ranks-per-node notion: the platform's (asserted against the
    // power model's node occupancy in platform::presets tests)
    let comm_model = platform.comm_model(link);
    let power = dpsnn::power::PowerModel::new(platform, link);

    let run_one =
        |routing: Routing, cadence: ExchangeCadence, topo: Topology| -> Result<RunResult> {
            let mut cfg = RunConfig::default();
            cfg.net = NetworkParams::tiny(neurons);
            // One network for every run: the min-delay cadence batches
            // over this window, and the per-step runs simulate the same
            // physics.
            cfg.net.delay_min_steps = delay_min.clamp(1, cfg.net.delay_max_steps);
            cfg.procs = procs;
            cfg.sim_seconds = seconds;
            cfg.routing = routing;
            cfg.exchange_every = cadence;
            cfg.topology = topo;
            cfg.validate()?;
            eprintln!("[bench-smoke] {routing} routing, {cadence} cadence, {topo} topology...");
            coordinator::run(&cfg)
        };

    let section = |r: &RunResult| -> String {
        let utilization = r.components.fractions().0;
        let energy_j = power.energy_to_solution_j(r.procs, utilization, r.wall_s);
        let events = dpsnn::metrics::SynapticEventCount::measured(
            r.total_syn_events,
            r.total_ext_events,
        );
        let uj = dpsnn::metrics::joules_per_synaptic_event(energy_j, &events) * 1e6;
        // Price the measured traffic matrix (mean bytes per pair per
        // step) on the modeled interconnect: the per-pair path that
        // distinguishes filtered from broadcast exchanges. Ceiling
        // division keeps sporadic pairs alive (>= 1 B/step) — a pair
        // with any run traffic must still pay its per-step envelope,
        // only statically dead pairs price as zero.
        let steps = r.pop_counts.len().max(1) as u64;
        let matrix: Vec<Vec<u64>> = r
            .comm_volume
            .iter()
            .map(|c| c.per_dst_bytes.iter().map(|&b| b.div_ceil(steps)).collect())
            .collect();
        let exchange_s = comm_model.exchange_time_matrix(&matrix).total();
        let u64s = |f: fn(&dpsnn::metrics::CommVolume) -> u64| -> String {
            let cells: Vec<String> =
                r.comm_volume.iter().map(|c| f(c).to_string()).collect();
            format!("[{}]", cells.join(","))
        };
        format!(
            concat!(
                "{{\n",
                "      \"wall_s\": {:.6},\n",
                "      \"realtime_factor\": {:.4},\n",
                "      \"total_spikes\": {},\n",
                "      \"total_syn_events\": {},\n",
                "      \"bytes_sent_per_rank\": {},\n",
                "      \"bytes_recv_per_rank\": {},\n",
                "      \"messages_per_rank\": {},\n",
                "      \"intra_messages_per_rank\": {},\n",
                "      \"inter_messages_per_rank\": {},\n",
                "      \"exchanges_per_rank\": {},\n",
                "      \"barriers_per_rank\": {},\n",
                "      \"modeled_exchange_s_per_step\": {:.9},\n",
                "      \"energy_j_modeled\": {:.3},\n",
                "      \"uj_per_syn_event\": {:.4}\n",
                "    }}"
            ),
            r.wall_s,
            r.realtime_factor(),
            r.total_spikes,
            r.total_syn_events,
            u64s(|c| c.bytes_sent),
            u64s(|c| c.bytes_recv),
            u64s(|c| c.messages),
            u64s(|c| c.intra_messages),
            u64s(|c| c.inter_messages),
            u64s(|c| c.exchanges),
            // one barrier per exchange, by protocol
            u64s(|c| c.exchanges),
            exchange_s,
            energy_j,
            uj,
        )
    };

    let filtered = run_one(Routing::Filtered, ExchangeCadence::Step, Topology::Flat)?;
    let broadcast = run_one(Routing::Broadcast, ExchangeCadence::Step, Topology::Flat)?;
    let batched = run_one(Routing::Filtered, ExchangeCadence::MinDelay, Topology::Flat)?;
    let hier = run_one(Routing::Filtered, ExchangeCadence::Step, topology)?;

    let recv = |r: &RunResult| -> u64 {
        r.comm_volume.iter().map(|c| c.bytes_recv).sum()
    };
    let exchanges = |r: &RunResult| -> u64 {
        r.comm_volume.iter().map(|c| c.exchanges).max().unwrap_or(0)
    };
    let (recv_f, recv_b) = (recv(&filtered), recv(&broadcast));
    anyhow::ensure!(
        filtered.pop_counts == broadcast.pop_counts,
        "routing protocols must produce identical rasters"
    );
    anyhow::ensure!(
        batched.pop_counts == filtered.pop_counts,
        "exchange cadences must produce identical rasters"
    );
    anyhow::ensure!(
        recv_f < recv_b,
        "filtered routing must receive fewer bytes ({recv_f} vs {recv_b})"
    );
    let steps = filtered.pop_counts.len() as u32;
    let epoch = delay_min.clamp(1, NetworkParams::tiny(neurons).delay_max_steps);
    let (x_step, x_batched) = (exchanges(&filtered), exchanges(&batched));
    anyhow::ensure!(
        x_batched == expected_exchanges(steps, epoch),
        "min-delay cadence must exchange once per {epoch}-step epoch \
         ({x_batched} exchanges over {steps} steps)"
    );
    let reduction = 1.0 - recv_f as f64 / recv_b as f64;
    let exchange_reduction = x_step as f64 / x_batched.max(1) as f64;

    let json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"spike_routing_smoke\",\n",
            "  \"neurons\": {},\n",
            "  \"syn_per_neuron\": {},\n",
            "  \"procs\": {},\n",
            "  \"sim_seconds\": {},\n",
            "  \"delay_min_steps\": {},\n",
            "  \"power_platform\": \"{}\",\n",
            "  \"routing\": {{\n",
            "    \"filtered\": {},\n",
            "    \"broadcast\": {}\n",
            "  }},\n",
            "  \"cadence\": {{\n",
            "    \"per_step\": {},\n",
            "    \"min_delay\": {}\n",
            "  }},\n",
            "  \"recv_bytes_reduction_frac\": {:.6},\n",
            "  \"exchange_reduction_factor\": {:.3}\n",
            "}}\n"
        ),
        neurons,
        NetworkParams::tiny(neurons).syn_per_neuron,
        procs,
        seconds,
        epoch,
        platform_name,
        section(&filtered),
        section(&broadcast),
        section(&filtered),
        section(&batched),
        reduction,
        exchange_reduction,
    );
    std::fs::write(&out, &json)?;

    // Topology comparison: the flat per-step filtered run doubles as the
    // baseline; `hier` ran the same workload over node-leader
    // aggregation. Raster identical, inter-node messages collapsed, and
    // the live counts must equal the interconnect model's closed form.
    anyhow::ensure!(
        hier.pop_counts == filtered.pop_counts,
        "transport topologies must produce identical rasters"
    );
    let inter = |r: &RunResult| -> u64 {
        r.comm_volume.iter().map(|c| c.inter_messages).sum()
    };
    let (inter_flat, inter_hier) = (inter(&filtered), inter(&hier));
    anyhow::ensure!(
        inter_hier * 2 <= inter_flat,
        "{topology} must move >= 2x fewer inter-node messages \
         ({inter_hier} vs {inter_flat})"
    );
    let hier_model = dpsnn::simnet::AllToAllModel::new(link, tree_shape.ranks_per_board());
    let x_hier = exchanges(&hier);
    anyhow::ensure!(
        inter_hier == hier_model.tree_fabric_messages(procs, tree_shape.levels()) * x_hier,
        "live inter-node messages ({inter_hier}) must match the model's \
         closed form exactly"
    );
    // Price flat vs hierarchical on the same node packing at the run's
    // mean per-pair payload.
    let pairs = (procs as u64 * (procs as u64).saturating_sub(1)).max(1);
    let sent_total: u64 = filtered.comm_volume.iter().map(|c| c.bytes_sent).sum();
    let mean_pair_bytes = (sent_total / (pairs * steps.max(1) as u64)).max(1);
    let modeled_flat_s = hier_model.exchange_time(procs, mean_pair_bytes).total();
    let modeled_hier_s = hier_model
        .exchange_time_tree(procs, mean_pair_bytes, tree_shape.levels(), &[])
        .total();
    let topo_json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"topology_smoke\",\n",
            "  \"neurons\": {},\n",
            "  \"procs\": {},\n",
            "  \"sim_seconds\": {},\n",
            "  \"topology\": \"{}\",\n",
            "  \"power_platform\": \"{}\",\n",
            "  \"sections\": {{\n",
            "    \"flat\": {},\n",
            "    \"hier\": {}\n",
            "  }},\n",
            "  \"inter_messages_total\": {{ \"flat\": {}, \"hier\": {} }},\n",
            "  \"modeled_exchange_s_per_step\": {{ \"flat\": {:.9}, \"hier\": {:.9} }}\n",
            "}}\n"
        ),
        neurons,
        procs,
        seconds,
        topology,
        platform_name,
        section(&filtered),
        section(&hier),
        inter_flat,
        inter_hier,
        modeled_flat_s,
        modeled_hier_s,
    );
    std::fs::write(&topo_out, &topo_json)?;

    // Placement comparison: a sparse connectome of its own (M =
    // --partition-syn), because with the dense M=1125 network at small
    // P the destination filter degenerates to broadcast
    // (pair_coverage ~ 1) and no placement could move a byte. The
    // three policies simulate bitwise-identical physics, so the
    // per-pair payload matrix is a deterministic function of placement
    // alone — greedy-comms must put strictly fewer payload bytes on
    // the off-board tiers than the index split, and the liveness-based
    // prediction must price the measured per-level split.
    use dpsnn::config::PartitionPolicy;
    use dpsnn::engine::{AllocContext, Partition};

    let pn: u32 = args.get_or("partition-neurons", 20_480u32)?;
    let pm: u32 = args.get_or("partition-syn", 4u32)?;
    let pp: u32 = args.get_or("partition-procs", 8u32)?;
    let pseconds: f64 = args.get_or("partition-seconds", 0.1f64)?;
    let part_out = args.get_or("partition-out", "BENCH_partition.json".to_string())?;
    let challenger: PartitionPolicy =
        args.get_or("partition", PartitionPolicy::GreedyComms)?;

    let part_net = {
        let mut net = NetworkParams::tiny(pn);
        net.syn_per_neuron = pm.max(1);
        net
    };
    let run_part = |policy: PartitionPolicy| -> Result<RunResult> {
        let mut cfg = RunConfig::default();
        cfg.net = part_net.clone();
        cfg.procs = pp;
        cfg.sim_seconds = pseconds;
        cfg.routing = Routing::Filtered;
        cfg.topology = topology;
        cfg.partition = policy;
        cfg.validate()?;
        eprintln!("[bench-smoke] {policy} placement, {topology} topology...");
        coordinator::run(&cfg)
    };
    let index = run_part(PartitionPolicy::Index)?;
    let round_robin = run_part(PartitionPolicy::RoundRobin)?;
    let greedy = run_part(challenger)?;

    // Spike-count/rate invariants: placement permutes ownership, never
    // physics. The whole-population raster and the exc/inh split must
    // be bitwise identical under every policy.
    for (name, r) in [("round-robin", &round_robin), ("greedy", &greedy)] {
        anyhow::ensure!(
            r.pop_counts == index.pop_counts,
            "{name} placement changed the population raster"
        );
        anyhow::ensure!(
            r.total_exc_spikes == index.total_exc_spikes
                && r.total_spikes == index.total_spikes,
            "{name} placement changed the exc/inh spike split"
        );
    }
    anyhow::ensure!(index.total_spikes > 0, "placement bench network is silent");

    // Measured per-level payload split vs the liveness-based prediction.
    let ptree = dpsnn::comm::TopologyTree::new(pp, tree_shape.levels());
    let pcp = dpsnn::model::connectivity::ConnectivityParams::from_network(
        &part_net,
        RunConfig::default().seed,
    );
    let alloc_ctx = AllocContext { connectivity: Some(&pcp), tree: Some(&ptree) };
    let off_board = |lv: &[u64]| -> u64 { lv.iter().skip(1).sum() };
    let part_section = |policy: PartitionPolicy, r: &RunResult| -> Result<String> {
        let measured = dpsnn::metrics::payload_level_bytes(&r.comm_volume, &ptree);
        // The simnet matrix-pricing path must split the same traffic
        // matrix onto the same tiers as the metrics accounting.
        let matrix: Vec<Vec<u64>> =
            r.comm_volume.iter().map(|c| c.per_dst_bytes.clone()).collect();
        anyhow::ensure!(
            hier_model.tree_level_bytes(&matrix, tree_shape.levels()) == measured,
            "{policy}: simnet per-level byte split disagrees with the metrics view"
        );
        let placement = Partition::allocate(policy, pn, pp, &alloc_ctx);
        let predicted = dpsnn::metrics::predicted_payload_level_bytes(
            &pcp,
            &placement,
            &r.rank_spikes,
            &ptree,
        );
        let meas_off = off_board(&measured) as f64;
        let pred_off: f64 = predicted.iter().skip(1).sum();
        anyhow::ensure!(
            (pred_off - meas_off).abs() <= 0.10 * meas_off.max(1.0),
            "{policy}: predicted off-board payload {pred_off:.0} B departs >10% \
             from measured {meas_off:.0} B"
        );
        // Placement never changes the envelope counts: the per-level
        // message totals stay on the tree's closed form.
        let x = r.comm_volume.iter().map(|c| c.exchanges).max().unwrap_or(0);
        let closed: Vec<u64> = ptree
            .level_message_counts()
            .iter()
            .map(|&m| m * x)
            .collect();
        let mut level_msgs = vec![0u64; ptree.depth() + 1];
        for c in &r.comm_volume {
            for (acc, &m) in level_msgs.iter_mut().zip(&c.level_messages) {
                *acc += m;
            }
        }
        anyhow::ensure!(
            level_msgs == closed,
            "{policy}: per-level messages {level_msgs:?} off the closed form {closed:?}"
        );
        let fmt = |v: &[u64]| {
            let cells: Vec<String> = v.iter().map(|b| b.to_string()).collect();
            format!("[{}]", cells.join(","))
        };
        let fmt_f = |v: &[f64]| {
            let cells: Vec<String> = v.iter().map(|b| format!("{b:.0}")).collect();
            format!("[{}]", cells.join(","))
        };
        Ok(format!(
            concat!(
                "{{\n",
                "      \"policy\": \"{}\",\n",
                "      \"total_spikes\": {},\n",
                "      \"exc_spikes\": {},\n",
                "      \"level_bytes_measured\": {},\n",
                "      \"level_bytes_predicted\": {},\n",
                "      \"off_board_bytes\": {},\n",
                "      \"off_board_bytes_per_exchange\": {:.1}\n",
                "    }}"
            ),
            policy,
            r.total_spikes,
            r.total_exc_spikes,
            fmt(&measured),
            fmt_f(&predicted),
            off_board(&measured),
            off_board(&measured) as f64 / x.max(1) as f64,
        ))
    };

    let off_of = |r: &RunResult| -> u64 {
        off_board(&dpsnn::metrics::payload_level_bytes(&r.comm_volume, &ptree))
    };
    let (off_index, off_greedy) = (off_of(&index), off_of(&greedy));
    anyhow::ensure!(
        off_greedy < off_index,
        "{challenger} placement must beat index on off-board payload bytes \
         ({off_greedy} vs {off_index})"
    );
    let delta_frac = 1.0 - off_greedy as f64 / off_index as f64;

    let part_json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"partition_smoke\",\n",
            "  \"neurons\": {},\n",
            "  \"syn_per_neuron\": {},\n",
            "  \"procs\": {},\n",
            "  \"sim_seconds\": {},\n",
            "  \"topology\": \"{}\",\n",
            "  \"sections\": {{\n",
            "    \"index\": {},\n",
            "    \"round_robin\": {},\n",
            "    \"greedy\": {}\n",
            "  }},\n",
            "  \"inter_node_bytes_delta_frac\": {:.6}\n",
            "}}\n"
        ),
        pn,
        part_net.syn_per_neuron,
        pp,
        pseconds,
        topology,
        part_section(PartitionPolicy::Index, &index)?,
        part_section(PartitionPolicy::RoundRobin, &round_robin)?,
        part_section(challenger, &greedy)?,
        delta_frac,
    );
    std::fs::write(&part_out, &part_json)?;

    // Compute-kernel microbenchmarks at the paper's 20480N size (fixed,
    // independent of --neurons, so the BENCH_compute.json trajectory is
    // comparable across PRs): the scalar baseline vs the SoA production
    // path at 1/2/4 compute threads.
    let compute_out = args.get_or("compute-out", "BENCH_compute.json".to_string())?;
    eprintln!("[bench-smoke] compute kernels (scalar vs SoA, 1/2/4 threads)...");
    let mut bench = dpsnn::util::bench::Bench::fast();
    let compute = dpsnn::profiling::run_compute_bench(&mut bench, 20_480, &[1, 2, 4]);
    for c in &compute.cases {
        anyhow::ensure!(
            c.elems_per_s() > 0.0,
            "compute kernel {}/{} t={} measured zero throughput",
            c.kind,
            c.variant,
            c.threads
        );
    }
    std::fs::write(&compute_out, compute.to_json())?;
    let nu_rt = compute
        .case("neuron_update", "soa", 1)
        .map(|c| c.realtime_x(compute.step_ms * 1e-3))
        .unwrap_or(0.0);
    let nu_speedup = compute.speedup_vs_scalar("neuron_update").unwrap_or(0.0);

    // Self-tuning planner: on every platform preset, resolve the
    // all-auto config at the paper's 20480N / 32-proc / 16-step
    // operating point and replay it against the full hand-swept
    // topology x cadence grid (the planner's own candidate set). The
    // pick must land within 10% of the swept best on >= 2 presets.
    let autotune_out = args.get_or("autotune-out", "BENCH_autotune.json".to_string())?;
    eprintln!("[bench-smoke] autotune planner vs hand-swept modeled grid...");
    let tune_net = {
        let mut net = NetworkParams::paper_20480();
        net.delay_min_steps = 16;
        net.delay_max_steps = net.delay_max_steps.max(16);
        net
    };
    let base_tune = |name: &str| -> Result<RunConfig> {
        let p = dpsnn::platform::presets::platform_by_name(name)?;
        let mut cfg = RunConfig::default();
        cfg.net = tune_net.clone();
        cfg.procs = 32;
        cfg.sim_seconds = 2.0;
        cfg.mode = Mode::Modeled;
        cfg.platform = name.to_string();
        cfg.interconnect = p.default_interconnect.to_string();
        Ok(cfg)
    };
    let mut tune_sections: Vec<String> = Vec::new();
    let mut within_10 = 0u32;
    for name in dpsnn::platform::presets::all_names() {
        let base = base_tune(name)?;
        let mut auto_cfg = base.clone();
        auto_cfg.auto.topology = true;
        auto_cfg.auto.exchange_every = true;
        auto_cfg.auto.leader_rotation = true;
        auto_cfg.auto.compute_threads = true;
        let pick = coordinator::run(&auto_cfg)?;
        let planner = dpsnn::simnet::Planner::from_config(&base)?;
        let mut best_wall = f64::INFINITY;
        let mut best_topo = Topology::Flat;
        let mut best_every = 1u32;
        let mut swept = 0u32;
        for topo in planner.candidates() {
            for e in planner.cadence_candidates() {
                let mut c = base.clone();
                c.topology = topo;
                c.exchange_every = if e == 1 {
                    ExchangeCadence::Step
                } else {
                    ExchangeCadence::Every(e)
                };
                let r = coordinator::run(&c)?;
                swept += 1;
                if r.wall_s < best_wall {
                    best_wall = r.wall_s;
                    best_topo = topo;
                    best_every = e;
                }
            }
        }
        let ratio = pick.wall_s / best_wall;
        if ratio <= 1.10 {
            within_10 += 1;
        }
        eprintln!(
            "[bench-smoke]   {name}: pick [{} every {}] {:.3} s vs swept best \
             [{} every {}] {:.3} s over {} configs (ratio {:.3})",
            pick.topology,
            pick.exchange_every,
            pick.wall_s,
            best_topo,
            best_every,
            best_wall,
            swept,
            ratio,
        );
        tune_sections.push(format!(
            concat!(
                "    \"{}\": {{\n",
                "      \"picked_topology\": \"{}\",\n",
                "      \"picked_cadence\": \"{}\",\n",
                "      \"picked_rotation\": \"{}\",\n",
                "      \"picked_threads\": {},\n",
                "      \"pick_wall_s\": {:.6},\n",
                "      \"swept_best_topology\": \"{}\",\n",
                "      \"swept_best_every\": {},\n",
                "      \"swept_best_wall_s\": {:.6},\n",
                "      \"pick_over_best_ratio\": {:.4},\n",
                "      \"configs_swept\": {}\n",
                "    }}"
            ),
            name,
            pick.topology,
            pick.exchange_every,
            pick.leader_rotation,
            pick.compute_threads,
            pick.wall_s,
            best_topo,
            best_every,
            best_wall,
            ratio,
            swept,
        ));
    }
    anyhow::ensure!(
        within_10 >= 2,
        "planner pick within 10% of the swept best on only {within_10} platform \
         presets (need >= 2)"
    );

    // Online re-planner on a real live run: force each side of the
    // latency/bandwidth crossover with an injected threshold and
    // require the cadence switch within 3 windows of the start, with
    // the baseline raster reproduced bitwise.
    eprintln!("[bench-smoke] online re-planner: injected regime shifts...");
    let replan_case = |cadence: ExchangeCadence, crossover: f64| -> Result<RunResult> {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(neurons);
        cfg.net.delay_min_steps = delay_min.clamp(1, cfg.net.delay_max_steps);
        cfg.procs = procs;
        cfg.sim_seconds = seconds;
        cfg.routing = Routing::Filtered;
        cfg.exchange_every = cadence;
        cfg.auto.exchange_every = true;
        cfg.auto.leader_rotation = true;
        cfg.validate()?;
        let rp = dpsnn::coordinator::OnlineReplanner::from_config(&cfg)?
            .with_crossover_bytes(crossover);
        dpsnn::coordinator::live::run_live_with(&cfg, Some(std::sync::Arc::new(rp)))
    };
    // crossover 0 declares every payload bandwidth-bound (the SWA
    // side), infinity declares none (the AW side); each run must cross
    // over from the opposite starting cadence.
    let shift_to_step = replan_case(ExchangeCadence::MinDelay, 0.0)?;
    let shift_to_epoch = replan_case(ExchangeCadence::Step, f64::INFINITY)?;
    for (name, r, want_epoch) in [
        ("to-per-step", &shift_to_step, 1u32),
        ("to-min-delay", &shift_to_epoch, epoch),
    ] {
        anyhow::ensure!(
            r.pop_counts == batched.pop_counts,
            "online re-plan ({name}) changed the raster"
        );
        let first = r
            .replans
            .first()
            .ok_or_else(|| anyhow::anyhow!("online re-plan ({name}) never fired"))?;
        anyhow::ensure!(
            first.window <= 2 && first.epoch_steps == want_epoch,
            "online re-plan ({name}) switched to {}-step windows at window {} \
             (want {want_epoch} within 3 windows)",
            first.epoch_steps,
            first.window
        );
    }

    // All-auto live run, then an exact replay from the resolved axes
    // the result records — the replayability contract behind the
    // `auto` summary line.
    eprintln!("[bench-smoke] all-auto live run vs resolved-explicit replay...");
    let mut auto_live = RunConfig::default();
    auto_live.net = NetworkParams::tiny(neurons);
    auto_live.net.delay_min_steps = delay_min.clamp(1, auto_live.net.delay_max_steps);
    auto_live.procs = procs;
    auto_live.sim_seconds = seconds;
    auto_live.routing = Routing::Filtered;
    auto_live.auto.topology = true;
    auto_live.auto.exchange_every = true;
    auto_live.auto.leader_rotation = true;
    auto_live.auto.compute_threads = true;
    auto_live.validate()?;
    let auto_run = coordinator::run(&auto_live)?;
    anyhow::ensure!(
        auto_run.pop_counts == filtered.pop_counts,
        "all-auto live run changed the raster"
    );
    let mut explicit = auto_live.clone();
    explicit.auto = dpsnn::config::AutoAxes::default();
    explicit.topology = auto_run.topology;
    explicit.exchange_every = auto_run.exchange_every;
    explicit.leader_rotation = auto_run.leader_rotation;
    explicit.compute_threads = auto_run.compute_threads;
    let replayed = coordinator::run(&explicit)?;
    anyhow::ensure!(
        replayed.pop_counts == auto_run.pop_counts,
        "resolved-explicit replay diverged from the all-auto run"
    );

    let tune_json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"autotune_smoke\",\n",
            "  \"neurons\": {},\n",
            "  \"procs\": {},\n",
            "  \"delay_min_steps\": {},\n",
            "  \"sim_seconds\": {},\n",
            "  \"presets_within_10pct\": {},\n",
            "  \"platforms\": {{\n{}\n  }},\n",
            "  \"online\": {{\n",
            "    \"switch_window_to_per_step\": {},\n",
            "    \"switch_window_to_min_delay\": {},\n",
            "    \"all_auto_topology\": \"{}\",\n",
            "    \"all_auto_cadence\": \"{}\",\n",
            "    \"all_auto_threads\": {},\n",
            "    \"raster_identical\": true\n",
            "  }}\n",
            "}}\n"
        ),
        tune_net.n_neurons,
        32,
        tune_net.delay_min_steps,
        2.0,
        within_10,
        tune_sections.join(",\n"),
        shift_to_step.replans[0].window,
        shift_to_epoch.replans[0].window,
        auto_run.topology,
        auto_run.exchange_every,
        auto_run.compute_threads,
    );
    std::fs::write(&autotune_out, &tune_json)?;

    // Connectivity-mode memory benchmark: the same tiny live workload
    // under materialized and procedural synapse state (rasters must be
    // bitwise identical, measured resident bytes must sit on the
    // analytic closed forms), then the 100x acceptance point:
    // 2_000_000 neurons on ONE rank, where the materialized closed
    // form (~11.3 GB) busts the per-rank budget and `--connectivity
    // auto` must therefore run procedurally — in a fraction of the
    // memory the table alone would need.
    use dpsnn::config::ConnectivityMode;
    use dpsnn::metrics::memory as memmodel;
    let memory_out = args.get_or("memory-out", "BENCH_memory.json".to_string())?;
    let run_conn = |mode: ConnectivityMode| -> Result<RunResult> {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::tiny(neurons);
        cfg.net.delay_min_steps = delay_min.clamp(1, cfg.net.delay_max_steps);
        cfg.procs = procs;
        cfg.sim_seconds = seconds;
        cfg.routing = Routing::Filtered;
        cfg.connectivity = mode;
        cfg.validate()?;
        eprintln!("[bench-smoke] {mode} connectivity, {procs} procs...");
        coordinator::run(&cfg)
    };
    let conn_mat = run_conn(ConnectivityMode::Materialized)?;
    let conn_proc = run_conn(ConnectivityMode::Procedural)?;
    anyhow::ensure!(
        conn_mat.pop_counts == conn_proc.pop_counts
            && conn_mat.total_syn_events == conn_proc.total_syn_events,
        "connectivity modes must produce identical rasters"
    );
    let m_tiny = NetworkParams::tiny(neurons).syn_per_neuron;
    let n_local_even = neurons / procs.max(1);
    for (rank, mem) in conn_mat.memory.iter().enumerate() {
        // realized local synapse counts are stochastic around the
        // closed form's m * n_local expectation — 15% covers it easily
        let closed =
            memmodel::materialized_synapse_bytes(neurons, m_tiny, n_local_even) as f64;
        let meas = mem.synapse_bytes as f64;
        anyhow::ensure!(
            (meas - closed).abs() <= 0.15 * closed,
            "rank {rank}: materialized synapse store {meas:.0} B departs >15% \
             from the closed form {closed:.0} B"
        );
    }
    for mem in &conn_proc.memory {
        // panics loudly if the persistent store is not O(state)
        memmodel::assert_procedural_state_bound(mem, m_tiny, n_local_even);
    }
    let sum_syn = |r: &RunResult| -> u64 { r.memory.iter().map(|m| m.synapse_bytes).sum() };
    anyhow::ensure!(
        sum_syn(&conn_proc) * 16 <= sum_syn(&conn_mat),
        "procedural synapse store ({} B) must sit far below the materialized \
         table ({} B)",
        sum_syn(&conn_proc),
        sum_syn(&conn_mat)
    );

    // The 100x acceptance point.
    let big_net = NetworkParams::paper(2_000_000);
    let mat_closed = memmodel::predicted_rank_bytes(
        &big_net,
        big_net.n_neurons,
        ConnectivityMode::Materialized,
    );
    anyhow::ensure!(
        mat_closed > memmodel::DEFAULT_RANK_BUDGET_BYTES,
        "the 100x point must not fit materialized ({mat_closed} B under budget?)"
    );
    eprintln!(
        "[bench-smoke] 100x point: {} neurons on 1 rank, --connectivity auto \
         (materialized closed form {:.2} GB vs {} GiB/rank budget)...",
        big_net.n_neurons,
        mat_closed as f64 / 1e9,
        memmodel::DEFAULT_RANK_BUDGET_BYTES >> 30,
    );
    let mut big = RunConfig::default();
    big.net = big_net.clone();
    big.procs = 1;
    big.sim_seconds = 0.05;
    big.auto.connectivity = true;
    big.validate()?;
    let big_run = coordinator::run(&big)?;
    anyhow::ensure!(
        big_run.connectivity == ConnectivityMode::Procedural,
        "auto must resolve the 100x point to procedural, got {}",
        big_run.connectivity
    );
    anyhow::ensure!(big_run.total_spikes > 0, "the 100x run was silent");
    let big_mem = big_run.memory.first().copied().unwrap_or_default();
    memmodel::assert_procedural_state_bound(
        &big_mem,
        big_net.syn_per_neuron,
        big_net.n_neurons,
    );
    anyhow::ensure!(
        big_mem.total() * 2 < mat_closed,
        "100x procedural run resident {} B is not well under the materialized \
         floor {mat_closed} B",
        big_mem.total()
    );

    let mode_section = |r: &RunResult| -> String {
        let u64s = |f: fn(&dpsnn::metrics::MemoryUse) -> u64| -> String {
            let cells: Vec<String> = r.memory.iter().map(|m| f(m).to_string()).collect();
            format!("[{}]", cells.join(","))
        };
        let total: u64 = r.memory.iter().map(|m| m.total()).sum();
        let syn_expected = neurons as u64 * m_tiny as u64;
        format!(
            concat!(
                "{{\n",
                "      \"connectivity\": \"{}\",\n",
                "      \"wall_s\": {:.6},\n",
                "      \"total_spikes\": {},\n",
                "      \"synapse_bytes_per_rank\": {},\n",
                "      \"ring_bytes_per_rank\": {},\n",
                "      \"scratch_bytes_per_rank\": {},\n",
                "      \"max_rank_total_bytes\": {},\n",
                "      \"bytes_per_neuron\": {:.2},\n",
                "      \"bytes_per_synapse\": {:.4}\n",
                "    }}"
            ),
            r.connectivity,
            r.wall_s,
            r.total_spikes,
            u64s(|m| m.synapse_bytes),
            u64s(|m| m.ring_bytes),
            u64s(|m| m.scratch_bytes),
            r.max_rank_memory_bytes(),
            total as f64 / neurons as f64,
            total as f64 / syn_expected as f64,
        )
    };
    let mem_json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"memory_smoke\",\n",
            "  \"neurons\": {},\n",
            "  \"syn_per_neuron\": {},\n",
            "  \"procs\": {},\n",
            "  \"sim_seconds\": {},\n",
            "  \"raster_identical\": true,\n",
            "  \"modes\": {{\n",
            "    \"materialized\": {},\n",
            "    \"procedural\": {}\n",
            "  }},\n",
            "  \"acceptance_2m\": {{\n",
            "    \"neurons\": {},\n",
            "    \"syn_per_neuron\": {},\n",
            "    \"budget_bytes\": {},\n",
            "    \"materialized_closed_form_bytes\": {},\n",
            "    \"resolved_connectivity\": \"{}\",\n",
            "    \"resident_synapse_bytes\": {},\n",
            "    \"resident_ring_bytes\": {},\n",
            "    \"resident_scratch_bytes\": {},\n",
            "    \"resident_total_bytes\": {},\n",
            "    \"table_over_resident_ratio\": {:.1},\n",
            "    \"total_spikes\": {},\n",
            "    \"wall_s\": {:.6}\n",
            "  }}\n",
            "}}\n"
        ),
        neurons,
        m_tiny,
        procs,
        seconds,
        mode_section(&conn_mat),
        mode_section(&conn_proc),
        big_net.n_neurons,
        big_net.syn_per_neuron,
        memmodel::DEFAULT_RANK_BUDGET_BYTES,
        mat_closed,
        big_run.connectivity,
        big_mem.synapse_bytes,
        big_mem.ring_bytes,
        big_mem.scratch_bytes,
        big_mem.total(),
        mat_closed as f64 / big_mem.total().max(1) as f64,
        big_run.total_spikes,
        big_run.wall_s,
    );
    std::fs::write(&memory_out, &mem_json)?;

    println!("{}", filtered.summary());
    println!(
        "bench-smoke: recv bytes/run {recv_f} (filtered) vs {recv_b} (broadcast), \
         -{:.1}%; exchanges/run {x_step} (per-step) vs {x_batched} (min-delay), \
         {exchange_reduction:.1}x fewer; inter-node msgs/run {inter_flat} (flat) \
         vs {inter_hier} ({topology}); off-board payload {off_index} B (index) \
         vs {off_greedy} B ({challenger}), -{:.2}%; neuron_update {nu_rt:.0}x \
         real time (SoA {nu_speedup:.2}x scalar); planner within 10% of swept \
         best on {within_10}/6 presets, online switch at windows {}/{}; \
         connectivity modes raster-identical, 2M-neuron point ran {} with \
         {:.0} MB resident vs {:.2} GB materialized closed form; wrote \
         {out} + {topo_out} + {part_out} + {compute_out} + {autotune_out} + \
         {memory_out}",
        reduction * 100.0,
        delta_frac * 100.0,
        shift_to_step.replans[0].window,
        shift_to_epoch.replans[0].window,
        big_run.connectivity,
        big_mem.total() as f64 / 1e6,
        mat_closed as f64 / 1e9,
    );
    Ok(())
}

/// The `serve` subcommand: run a set of jobs through one resident
/// [`SimServer`](dpsnn::runtime::SimServer) concurrently, then run the
/// identical jobs cold and sequentially through the solo CLI path
/// ([`coordinator::run`], exactly what `dpsnn run` does per invocation,
/// minus the process spawn — a baseline that *favors* the cold side),
/// and emit the comparison as `BENCH_server.json`. The command exits
/// nonzero unless every server raster is bitwise identical to its solo
/// twin and the concurrent pass wins on total wall clock.
fn cmd_serve(args: &Args) -> Result<()> {
    use dpsnn::config::{ConnectivityMode, ExchangeCadence, JobSpec, Routing, ServeOptions};
    use dpsnn::metrics::JobReport;
    use dpsnn::runtime::{JobEvent, SimServer};

    let jobs_n: u32 = args.get_or("jobs", 4u32)?;
    let neurons: u32 = args.get_or("neurons", 2048u32)?;
    let procs: u32 = args.get_or("procs", 2u32)?;
    let seconds: f64 = args.get_or("seconds", 1.0f64)?;
    let seed: u64 = args.get_or("seed", RunConfig::default().seed)?;
    let delay_min: u32 = args.get_or("delay-min", 8u32)?;
    let bench_out = args.get_or("bench-out", "BENCH_server.json".to_string())?;

    // Job list: explicit TOML specs, or a synthesized matrix of
    // bench-smoke-sized jobs with distinct seeds and varied regimes
    // (routing, cadence, connectivity) so the isolation claim is
    // exercised across cache-relevant axes, not on clones of one job.
    let mut specs: Vec<JobSpec> = Vec::new();
    if args.positional.len() > 1 {
        for path in &args.positional[1..] {
            specs.push(JobSpec::from_toml_file(std::path::Path::new(path))?);
        }
    } else {
        for i in 0..jobs_n {
            let mut cfg = RunConfig::default();
            cfg.net = NetworkParams::tiny(neurons);
            cfg.net.delay_min_steps = delay_min.clamp(1, cfg.net.delay_max_steps);
            cfg.procs = procs;
            cfg.sim_seconds = seconds;
            cfg.seed = seed.wrapping_add(i as u64);
            match i % 4 {
                1 => cfg.routing = Routing::Broadcast,
                2 => cfg.exchange_every = ExchangeCadence::MinDelay,
                3 => cfg.connectivity = ConnectivityMode::Procedural,
                _ => {}
            }
            cfg.validate()?;
            specs.push(JobSpec::new(format!("job{i}"), cfg));
        }
    }
    anyhow::ensure!(!specs.is_empty(), "no jobs to run");
    let largest = specs.iter().map(|s| s.cfg.procs).max().unwrap_or(1);
    let total_ranks: u32 =
        args.get_or("total-ranks", ServeOptions::default().total_ranks.max(largest))?;

    // Concurrent pass through the resident server. This runs FIRST so
    // any OS warm-up (page cache, frequency scaling) benefits the cold
    // baseline, keeping the comparison conservative.
    eprintln!(
        "[serve] {} jobs over a {total_ranks}-rank budget: concurrent server pass...",
        specs.len()
    );
    let server = SimServer::start(ServeOptions { total_ranks });
    let t0 = std::time::Instant::now();
    let handles = specs
        .iter()
        .map(|s| server.submit(s.clone()))
        .collect::<Result<Vec<_>>>()?;
    let mut server_results = Vec::new();
    for h in &handles {
        let result = loop {
            match h.events().recv() {
                Ok(JobEvent::Progress { step, steps }) => {
                    eprintln!("  [{}] {step}/{steps} steps", h.name);
                }
                Ok(JobEvent::Finished(r)) => break *r,
                Ok(JobEvent::Failed(msg)) => bail!("job '{}' failed: {msg}", h.name),
                Ok(_) => {}
                Err(_) => bail!("server dropped job '{}'", h.name),
            }
        };
        server_results.push(result);
    }
    let server_total = t0.elapsed().as_secs_f64();
    let stats = server.cache_stats();
    drop(server);

    // Cold baseline: the same jobs, sequentially, each through the solo
    // CLI run path with nothing shared.
    eprintln!("[serve] cold baseline: same jobs sequentially, nothing shared...");
    let t1 = std::time::Instant::now();
    let mut cold_results = Vec::new();
    for s in &specs {
        cold_results.push(coordinator::run(&s.cfg)?);
    }
    let cold_total = t1.elapsed().as_secs_f64();

    let mut rasters_identical = true;
    let mut server_reports = Vec::new();
    let mut cold_reports = Vec::new();
    for ((spec, sr), cr) in specs.iter().zip(&server_results).zip(&cold_results) {
        rasters_identical &=
            sr.pop_counts == cr.pop_counts && sr.total_spikes == cr.total_spikes;
        server_reports.push(JobReport::from_result(&spec.name, &spec.cfg, sr)?);
        cold_reports.push(JobReport::from_result(&spec.name, &spec.cfg, cr)?);
    }
    let speedup = if server_total > 0.0 { cold_total / server_total } else { 0.0 };

    let jobs_json = |reports: &[JobReport]| -> String {
        let cells: Vec<String> = reports
            .iter()
            .map(|r| format!("      {}", r.to_json("      ")))
            .collect();
        format!("[\n{}\n    ]", cells.join(",\n"))
    };
    let json = format!(
        concat!(
            "{{\n",
            "  \"benchmark\": \"server_smoke\",\n",
            "  \"jobs\": {jobs},\n",
            "  \"total_ranks\": {ranks},\n",
            "  \"server\": {{\n",
            "    \"total_wall_s\": {sw:.6},\n",
            "    \"jobs\": {sj}\n",
            "  }},\n",
            "  \"cold\": {{\n",
            "    \"total_wall_s\": {cw:.6},\n",
            "    \"jobs\": {cj}\n",
            "  }},\n",
            "  \"speedup\": {sp:.4},\n",
            "  \"rasters_identical\": {ri},\n",
            "  \"cache\": {{\n",
            "    \"plan_hits\": {ph}, \"plan_misses\": {pm},\n",
            "    \"placement_hits\": {lh}, \"placement_misses\": {lm},\n",
            "    \"connectome_hits\": {nh}, \"connectome_misses\": {nm},\n",
            "    \"artifact_hits\": {ah}, \"artifact_misses\": {am},\n",
            "    \"batched_jobs\": {bj}\n",
            "  }}\n",
            "}}\n",
        ),
        jobs = specs.len(),
        ranks = total_ranks,
        sw = server_total,
        sj = jobs_json(&server_reports),
        cw = cold_total,
        cj = jobs_json(&cold_reports),
        sp = speedup,
        ri = rasters_identical,
        ph = stats.plan_hits,
        pm = stats.plan_misses,
        lh = stats.placement_hits,
        lm = stats.placement_misses,
        nh = stats.connectome_hits,
        nm = stats.connectome_misses,
        ah = stats.artifact_hits,
        am = stats.artifact_misses,
        bj = stats.batched_jobs,
    );
    std::fs::write(&bench_out, &json)?;
    eprintln!("[serve] wrote {bench_out}");
    eprintln!(
        "[serve] server {server_total:.2} s vs cold {cold_total:.2} s (x{speedup:.2}), \
         rasters identical: {rasters_identical}"
    );

    // The acceptance gates (written into the JSON above first, so a CI
    // failure still uploads the numbers).
    anyhow::ensure!(
        rasters_identical,
        "server-pass rasters diverged from the solo runs — per-job isolation is broken"
    );
    if specs.len() >= 2 {
        anyhow::ensure!(
            server_total < cold_total,
            "resident server ({server_total:.3} s) did not beat {} cold runs ({cold_total:.3} s)",
            specs.len()
        );
    }
    Ok(())
}

fn cmd_list_platforms() -> Result<()> {
    println!("modeled platforms (DESIGN.md §2 hardware substitutions):");
    for name in dpsnn::platform::presets::all_names() {
        let p = dpsnn::platform::presets::platform_by_name(name)?;
        println!(
            "  {:<14} {:<16} {:>2} cores/node  baseline {:>5.1} W  default {}",
            name,
            p.node.core.name,
            p.node.cores_per_node,
            p.baseline_w,
            p.default_interconnect,
        );
    }
    println!("interconnects:");
    for l in dpsnn::simnet::presets::all() {
        println!(
            "  {:<9} alpha {:>6.1} us  beta {:>6.2} Gb/s  nic {:>4.1} W",
            l.name,
            l.alpha_s * 1e6,
            l.beta_bps * 8.0 / 1e9,
            l.nic_active_w,
        );
    }
    Ok(())
}

fn cmd_raster(args: &Args) -> Result<()> {
    let mut cfg = build_config(args)?;
    if args.get("neurons").is_none() {
        cfg.net = NetworkParams::tiny(2048);
    }
    if args.get("seconds").is_none() {
        cfg.sim_seconds = 3.0;
    }
    let bin: usize = args.get_or("bin", 25usize)?;
    let r = coordinator::run(&cfg)?;
    let mut monitor = RateMonitor::new(cfg.net.n_neurons, cfg.net.dt_ms);
    for &c in &r.pop_counts {
        monitor.record(c);
    }
    let series = monitor.rate_series_hz(bin);
    println!(
        "population rate ({} ms bins), mean {:.2} Hz:",
        bin,
        monitor.mean_rate_hz()
    );
    let peak = series.iter().cloned().fold(1e-9, f64::max);
    for (i, &rate) in series.iter().enumerate() {
        let bar = "#".repeat(((rate / peak) * 60.0) as usize);
        println!("{:>6} ms |{bar} {rate:.1}", i * bin);
    }
    println!(
        "regime: {:?}",
        classify_regime(&monitor, 50, monitor.steps() / 5)
    );
    Ok(())
}
