//! Discrete-event timing replay: workload trace × platform × interconnect
//! → wall-clock and the comp/comm/barrier decomposition (the modeled-mode
//! substitution for running on the paper's clusters).

pub mod replay;

pub use replay::{ModelRun, ModeledOutcome};
