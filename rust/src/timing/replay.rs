//! The timing replay engine (cost equations in DESIGN.md §8).
//!
//! Per 1 ms network step, for each rank:
//!
//! ```text
//! T_comp(r) = [ C_nrn·N_r  +  ws·cont·C_syn·SynEv_r  +  cont·C_ext·ExtEv_r
//!               + C_spk·Spikes_step ] / speed(r)
//! T_comm    = all-to-all software + fabric terms (simnet)
//! T_barrier = dissemination + skew (fractions of comp and comm)
//! T_step    = T_comp + T_comm + T_barrier
//! ```
//!
//! Three second-order effects are required to reproduce the paper's own
//! numbers and are calibrated against them (residuals in EXPERIMENTS.md):
//!
//! * **memory contention** (`cont`): ranks sharing a node compete for
//!   memory bandwidth on the random-access synapse walks; visible in
//!   Table II where 16 cores run *slower* than 8 on one node.
//! * **working-set factor** (`ws`): when a rank's synapse lists exceed
//!   the LLC, every synaptic event is a DRAM miss; this is why the 1280K
//!   network runs ~3.5× slower per event than the 20480N one (Table I,
//!   4-process column).
//! * **per-spike overhead** (`C_spk`): every rank touches every network
//!   spike (AER decode + source-row lookup) regardless of P — the
//!   non-scaling component that keeps large-network computation shares
//!   high at 256 processes (Table I: 1280KN still 50% computation).

use crate::comm::aer::{epoch_framing_bytes, SPIKE_WIRE_BYTES};
use crate::platform::hetero::HeteroCluster;
use crate::profiling::components::Components;
use crate::simnet::alltoall_model::AllToAllModel;
use crate::simnet::link::LinkModel;
use crate::trace::workload::WorkloadTrace;

/// Per-spike fixed overhead (decode + row lookup) at Westmere speed, s.
pub const SPIKE_OVERHEAD_S: f64 = 3.0e-6;
/// Cache level the per-rank target accumulator must fit in for the
/// calibrated synaptic-event rate to hold (bytes, ~L2).
const TARGET_CACHE_BYTES: f64 = 131_072.0;

/// Memory-contention multiplier for `p` ranks packed `ranks_per_node`
/// to a node. Calibrated on Table II, where 16 cores on one node run
/// *slower* than 8 (25.3 s -> 26.1 s): quadratic beyond the 4 cores a
/// socket's memory channels feed comfortably. Shared with the autotune
/// planner ([`crate::simnet::autotune`]), whose pricing must mirror
/// [`ModelRun::replay`] exactly for its argmin to match modeled sweeps.
pub fn contention_factor(p: u32, ranks_per_node: u32) -> f64 {
    let k = p.min(ranks_per_node);
    1.0 + 0.012 * (k.saturating_sub(4) as f64).powi(2)
}

/// Working-set multiplier: the synaptic-delivery loop random-writes a
/// per-rank target accumulator of 4*N_r bytes; once it spills the L2
/// every event is a cache miss. Calibrated on Table I's 4-process
/// column (event cost grows ~2.2x from 20480N to 320KN and again to
/// 1280KN). Shared with the autotune planner like
/// [`contention_factor`].
pub fn working_set_factor(n_local: f64) -> f64 {
    let bytes = n_local * 4.0;
    1.0 + 0.9 * (bytes / TARGET_CACHE_BYTES).max(1.0).log2()
}

/// A modeled execution: cluster (possibly heterogeneous) + interconnect.
#[derive(Debug, Clone)]
pub struct ModelRun {
    pub cluster: HeteroCluster,
    pub comm: AllToAllModel,
    /// When set, spikes travel only to this many neighbor ranks
    /// (spatially-mapped connectivity, Fig 1); None = all-to-all.
    pub peers: Option<u32>,
    /// When set, each (src, dst) rank pair is active with this
    /// probability — the destination-filtered routing's expected pair
    /// coverage (`metrics::comm_volume::mean_pair_coverage`). None =
    /// broadcast. Ignored when `peers` is set (the neighbor model
    /// already restricts the traffic matrix).
    pub filter_coverage: Option<f64>,
    /// Steps per communication epoch: 1 reproduces the paper's
    /// exchange-every-step protocol; `delay_min_steps` amortizes the
    /// per-message latency over a whole min-delay window (payload
    /// unchanged apart from run-header framing). This is the
    /// `exchanges_per_second` lever: `1000 / (dt_ms * steps_per_exchange)`
    /// collectives per simulated second instead of the paper's 1000.
    pub steps_per_exchange: u32,
    /// When set, each collective is priced as the node-leader
    /// hierarchical exchange
    /// ([`AllToAllModel::exchange_time_hierarchical`]): `N(N−1)`
    /// aggregated fabric messages per exchange instead of the flat
    /// `P(P−1)`, with node packing taken from the comm model's
    /// `ranks_per_node`. Composes with `filter_coverage` (filtering
    /// thins the aggregated payload, not the node-pair message count)
    /// and is ignored when `peers` is set — the neighbor model already
    /// restricts the traffic matrix.
    pub hierarchical: bool,
    /// When set, each collective is priced as the L-level tree exchange
    /// ([`AllToAllModel::exchange_time_tree`]): branching factors plus
    /// one link per fabric tier (board, chassis, rack...). Takes
    /// precedence over `hierarchical`, composes with `filter_coverage`
    /// like it, and is ignored when `peers` is set.
    pub tree: Option<(Vec<u32>, Vec<LinkModel>)>,
}

/// Replay result.
#[derive(Debug, Clone)]
pub struct ModeledOutcome {
    pub wall_s: f64,
    pub components: Components,
    /// Computation fraction of wall-clock (drives the power model).
    pub utilization: f64,
    pub procs: u32,
    pub total_spikes: u64,
    pub total_syn_events: u64,
    pub mean_rate_hz: f64,
    /// All-to-all collectives the run performed (= barrier count): one
    /// per step at per-step cadence, `ceil(steps / steps_per_exchange)`
    /// under epoch batching.
    pub exchanges: u64,
    /// Messages the run put on the inter-node fabric, summed over
    /// exchanges: `P·(P−k)` per flat exchange (only off-node pairs cross
    /// the fabric in the model's view; coverage-thinned under filtered
    /// routing), `N(N−1)` per hierarchical exchange — aggregated
    /// node-pair envelopes are NOT thinned by filtering, which only
    /// shrinks their payload.
    pub inter_messages: u64,
    /// Per-link-level message totals over the run (index 0 =
    /// intra-board), from the topology tree's closed form × exchanges.
    /// Empty unless the run priced a tree topology
    /// ([`ModelRun::with_tree`]).
    pub level_messages: Vec<u64>,
}

impl ModeledOutcome {
    /// Collectives per simulated second — the paper runs 1000 (one per
    /// 1 ms step); min-delay batching divides that by the epoch length.
    pub fn exchanges_per_second(&self, sim_seconds: f64) -> f64 {
        if sim_seconds <= 0.0 {
            return 0.0;
        }
        self.exchanges as f64 / sim_seconds
    }
}

impl ModelRun {
    pub fn new(cluster: HeteroCluster, comm: AllToAllModel) -> Self {
        Self {
            cluster,
            comm,
            peers: None,
            filter_coverage: None,
            steps_per_exchange: 1,
            hierarchical: false,
            tree: None,
        }
    }

    /// Neighbor-limited variant (spatially-mapped networks).
    pub fn with_peers(mut self, peers: u32) -> Self {
        self.peers = Some(peers);
        self
    }

    /// Destination-filtered variant: price only the covered fraction of
    /// the (src, dst) pair matrix.
    pub fn with_filter_coverage(mut self, coverage: f64) -> Self {
        self.filter_coverage = Some(coverage.clamp(0.0, 1.0));
        self
    }

    /// Epoch-batched variant: one collective per `steps` network steps.
    pub fn with_exchange_every(mut self, steps: u32) -> Self {
        self.steps_per_exchange = steps.max(1);
        self
    }

    /// Hierarchical-topology variant: price each collective as the
    /// node-leader aggregated exchange (`--topology nodes:<k>`).
    pub fn with_hierarchical(mut self) -> Self {
        self.hierarchical = true;
        self
    }

    /// Tree-topology variant: price each collective as the L-level
    /// leader hierarchy (`--topology tree:<k1>,<k2>,...`) with one
    /// fabric link per tier (see
    /// [`crate::platform::presets::PlatformModel::tree_links`]).
    pub fn with_tree(mut self, shape: Vec<u32>, links: Vec<LinkModel>) -> Self {
        self.tree = Some((shape, links));
        self
    }

    /// Memory-contention multiplier for this run's node packing (see
    /// [`contention_factor`]).
    fn contention(&self, p: u32) -> f64 {
        contention_factor(p, self.comm.ranks_per_node)
    }

    /// Working-set multiplier for a rank holding `n_local` neurons (see
    /// [`working_set_factor`]).
    fn working_set(&self, n_local: f64) -> f64 {
        working_set_factor(n_local)
    }

    /// Replay a workload trace through the cost model.
    pub fn replay(&self, trace: &WorkloadTrace) -> ModeledOutcome {
        let p = trace.procs;
        assert_eq!(
            p,
            self.cluster.total_ranks(),
            "trace procs must match cluster ranks"
        );
        let weights = self.cluster.weights();
        let wsum: f64 = weights.iter().sum();
        let n = trace.n_neurons as f64;

        let cont = self.contention(p);
        let epoch = self.steps_per_exchange.max(1);
        // Per-level messages one tree collective costs (tree runs only).
        let level_per_exchange: Option<Vec<u64>> = match (&self.tree, self.peers) {
            (Some((shape, _)), None) if p > 1 => {
                Some(self.comm.tree_level_messages(p, shape))
            }
            _ => None,
        };
        // Fabric messages one collective costs under this run's topology
        // and routing (see ModeledOutcome::inter_messages).
        let inter_per_exchange: u64 = if p <= 1 {
            0
        } else if let Some(levels) = &level_per_exchange {
            levels[1..].iter().sum()
        } else if self.hierarchical && self.peers.is_none() {
            self.comm.hierarchical_inter_messages(p)
        } else {
            let base = self.comm.flat_inter_messages(p);
            match (self.peers, self.filter_coverage) {
                (Some(k), _) => base.min(p as u64 * k.min(p - 1) as u64),
                (None, Some(q)) => (base as f64 * q).round() as u64,
                (None, None) => base,
            }
        };
        let mut comp_s = 0.0;
        let mut comm_s = 0.0;
        let mut barrier_s = 0.0;
        let mut total_syn_events = 0u64;
        let mut exchanges = 0u64;
        let mut inter_messages = 0u64;
        // Payload accumulated since the last collective (mean per-pair
        // bytes) and the number of steps it spans.
        let mut epoch_bytes = 0.0f64;
        let mut epoch_len = 0u32;

        for step in 0..trace.steps() {
            let step_syn_events = trace.syn_events(step) as f64;
            total_syn_events += trace.syn_events(step);
            // With neighbor-limited traffic a rank only sees the spikes
            // of its peer group.
            let recv_frac = match (self.peers, self.filter_coverage) {
                (Some(k), _) if p > 1 => (k.min(p - 1) as f64) / (p - 1) as f64,
                (None, Some(q)) if p > 1 => q,
                _ => 1.0,
            };
            let step_spikes: f64 =
                trace.mean_rank_spikes(step) * p as f64 * recv_frac;

            // Slowest rank's computation this step (weighted shares
            // equalize the scalable part in hetero jobs; the per-spike
            // overhead is identical on every rank).
            let mut comp_max = 0.0f64;
            for (r, w) in weights.iter().enumerate() {
                let share = w / wsum;
                let ws = self.working_set(n * share);
                let core = self.cluster.core_of(r as u32);
                let speed = core.speed_vs_westmere();
                let t = core.comp_time(
                    n * share,
                    step_syn_events * share * ws * cont,
                    n * trace.ext_events_per_neuron_step * share * cont,
                ) + step_spikes * SPIKE_OVERHEAD_S / speed;
                comp_max = comp_max.max(t);
            }

            comp_s += comp_max;
            // OS-jitter skew on computation accumulates every step and is
            // resolved at the epoch's barrier.
            barrier_s += 0.01 * comp_max;

            // Communication: payload accrues every step; the collective
            // (α, CPU and fabric message costs + its barrier) is paid
            // once per epoch. With steps_per_exchange = 1 this is
            // exactly the paper's per-step exchange.
            epoch_bytes += trace.mean_rank_spikes(step) * SPIKE_WIRE_BYTES as f64;
            epoch_len += 1;
            if epoch_len == epoch || step + 1 == trace.steps() {
                let bytes = epoch_bytes.round() as u64 + epoch_framing_bytes(epoch, epoch_len);
                let exch = match (self.peers, &self.tree, self.hierarchical, self.filter_coverage)
                {
                    (Some(k), _, _, _) => self.comm.exchange_time_neighbors(p, bytes, k),
                    (None, Some((shape, links)), _, q) => {
                        // topology tree:<...>: filtering thins the
                        // aggregated payload; the per-level pair
                        // message counts are unchanged
                        let b = (bytes as f64 * q.unwrap_or(1.0)).round() as u64;
                        self.comm.exchange_time_tree(p, b, shape, links)
                    }
                    (None, None, true, q) => {
                        // topology nodes:<k>: filtering thins the
                        // aggregated payload; the N(N-1) node-pair
                        // message count is unchanged
                        let b = (bytes as f64 * q.unwrap_or(1.0)).round() as u64;
                        self.comm.exchange_time_hierarchical(p, b)
                    }
                    (None, None, false, Some(q)) => {
                        self.comm.exchange_time_filtered(p, bytes, q)
                    }
                    (None, None, false, None) => self.comm.exchange_time(p, bytes),
                };
                let comm = exch.total();
                comm_s += comm;
                // Barrier: dissemination rounds + software skew on the
                // collective, once per exchange.
                barrier_s += self.comm.barrier_time(p) + 0.05 * comm;
                exchanges += 1;
                inter_messages += inter_per_exchange;
                epoch_bytes = 0.0;
                epoch_len = 0;
            }
        }

        let wall_s = comp_s + comm_s + barrier_s;
        let components = Components {
            computation: comp_s,
            communication: comm_s,
            barrier: barrier_s,
        };
        ModeledOutcome {
            wall_s,
            components,
            utilization: if wall_s > 0.0 { comp_s / wall_s } else { 0.0 },
            procs: p,
            total_spikes: trace.total_spikes(),
            total_syn_events,
            mean_rate_hz: trace.mean_rate_hz(),
            exchanges,
            inter_messages,
            level_messages: level_per_exchange
                .map(|levels| levels.iter().map(|m| m * exchanges).collect())
                .unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkParams;
    use crate::platform::presets::{WESTMERE, XEON_E5_2630V2};
    use crate::simnet::presets::IB;
    use crate::trace::analytic::AnalyticWorkload;

    fn outcome(
        net: NetworkParams,
        core: crate::platform::CoreModel,
        p: u32,
    ) -> ModeledOutcome {
        let w = AnalyticWorkload::paper_regime(net, 5);
        let trace = w.generate(p, 10.0);
        let run = ModelRun::new(
            HeteroCluster::homogeneous(core, p, 16),
            AllToAllModel::new(IB, 16),
        );
        run.replay(&trace)
    }

    #[test]
    fn one_westmere_core_near_table2_row1() {
        let o = outcome(NetworkParams::paper_20480(), WESTMERE, 1);
        assert!(
            (o.wall_s - 150.9).abs() / 150.9 < 0.20,
            "wall {}, Table II says 150.9",
            o.wall_s
        );
        let (comp, _, _) = o.components.fractions();
        assert!(comp > 0.97, "single rank is computation-only, comp={comp}");
    }

    #[test]
    fn fig2_shape_minimum_near_32_procs() {
        // 20480N on the Xeon cluster: fastest at ~32 procs, slower at 256.
        let net = NetworkParams::paper_20480;
        let walls: Vec<(u32, f64)> = [1u32, 4, 8, 16, 32, 64, 128, 256]
            .iter()
            .map(|&p| (p, outcome(net(), XEON_E5_2630V2, p).wall_s))
            .collect();
        let best = walls
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(
            [16, 32, 64].contains(&best.0),
            "minimum at P={} ({walls:?})",
            best.0
        );
        let w32 = walls.iter().find(|x| x.0 == 32).unwrap().1;
        let w256 = walls.iter().find(|x| x.0 == 256).unwrap().1;
        // real-time-ish at 32 (paper: 9.15 s), blown up at 256 (paper: 237 s)
        assert!(w32 < 15.0, "w32={w32}");
        assert!(w256 > 5.0 * w32, "w256={w256} w32={w32}");
    }

    #[test]
    fn table1_walls_within_2x_of_paper() {
        // Wall-clock anchors from Table I (xeon cluster, IB).
        let cases: &[(fn() -> NetworkParams, u32, f64)] = &[
            (NetworkParams::paper_20480, 4, 31.5),
            (NetworkParams::paper_20480, 32, 9.15),
            (NetworkParams::paper_20480, 256, 237.0),
            (NetworkParams::paper_320k, 4, 893.0),
            (NetworkParams::paper_320k, 256, 441.0),
            (NetworkParams::paper_1280k, 4, 4341.0),
            (NetworkParams::paper_1280k, 256, 561.0),
        ];
        for (net, p, paper_wall) in cases {
            let o = outcome(net(), XEON_E5_2630V2, *p);
            let ratio = o.wall_s / paper_wall;
            assert!(
                (0.4..2.5).contains(&ratio),
                "net {} procs {p}: modeled {:.1}s vs paper {paper_wall}s",
                net().n_neurons,
                o.wall_s
            );
        }
    }

    #[test]
    fn filter_coverage_thins_communication() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 5);
        let trace = w.generate(64, 2.0);
        let base = ModelRun::new(
            HeteroCluster::homogeneous(XEON_E5_2630V2, 64, 16),
            AllToAllModel::new(IB, 16),
        );
        let broadcast = base.replay(&trace);
        let full = base.clone().with_filter_coverage(1.0).replay(&trace);
        let sparse = base.with_filter_coverage(0.2).replay(&trace);
        // full coverage == broadcast (dense degeneration)
        assert!(
            (full.components.communication - broadcast.components.communication).abs()
                < 1e-9 * broadcast.components.communication,
        );
        // 20% coverage must cut the communication term hard
        assert!(
            sparse.components.communication < 0.4 * broadcast.components.communication,
            "sparse {} vs broadcast {}",
            sparse.components.communication,
            broadcast.components.communication
        );
    }

    #[test]
    fn epoch_batching_amortizes_latency() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 5);
        let trace = w.generate(64, 2.0);
        let base = ModelRun::new(
            HeteroCluster::homogeneous(XEON_E5_2630V2, 64, 16),
            AllToAllModel::new(IB, 16),
        );
        let per_step = base.clone().replay(&trace);
        let batched = base.with_exchange_every(16).replay(&trace);
        assert_eq!(per_step.exchanges, 2000, "one collective per 1 ms step");
        assert_eq!(batched.exchanges, 125, "2000 steps / 16-step epochs");
        let eps = per_step.exchanges_per_second(2.0);
        assert!((eps - 1000.0).abs() < 1e-9);
        // identical physics: computation is untouched
        assert_eq!(per_step.total_spikes, batched.total_spikes);
        assert!(
            (per_step.components.computation - batched.components.computation).abs()
                < 1e-12 * per_step.components.computation
        );
        // the spike payloads are tiny, so the per-message α dominates
        // and batching must collapse the communication term
        assert!(
            batched.components.communication < 0.25 * per_step.components.communication,
            "batched {} vs per-step {}",
            batched.components.communication,
            per_step.components.communication
        );
        assert!(batched.wall_s < per_step.wall_s);
    }

    #[test]
    fn hierarchical_topology_collapses_the_message_count() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 5);
        let trace = w.generate(256, 1.0);
        let base = ModelRun::new(
            HeteroCluster::homogeneous(XEON_E5_2630V2, 256, 16),
            AllToAllModel::new(IB, 16),
        );
        let flat = base.clone().replay(&trace);
        let hier = base.with_hierarchical().replay(&trace);
        // identical physics, fewer fabric messages, less wall-clock
        assert_eq!(flat.total_spikes, hier.total_spikes);
        assert_eq!(flat.exchanges, hier.exchanges);
        // flat: 256*(256-16) off-node pairs; hier: 16*15 node pairs
        assert_eq!(flat.inter_messages, 256 * 240 * flat.exchanges);
        assert_eq!(hier.inter_messages, 16 * 15 * hier.exchanges);
        assert!(
            hier.components.communication < 0.5 * flat.components.communication,
            "hier {} vs flat {}",
            hier.components.communication,
            flat.components.communication
        );
        assert!(hier.wall_s < flat.wall_s);
    }

    #[test]
    fn tree_pricing_threads_through_replay() {
        let w = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 5);
        let trace = w.generate(256, 1.0);
        let base = ModelRun::new(
            HeteroCluster::homogeneous(XEON_E5_2630V2, 256, 16),
            AllToAllModel::new(IB, 16),
        );
        // depth-1 tree with the default link reproduces the two-level
        // hierarchical path, message counts and pricing alike
        let hier = base.clone().with_hierarchical().replay(&trace);
        let tree1 = base.clone().with_tree(vec![16], vec![]).replay(&trace);
        assert_eq!(tree1.inter_messages, hier.inter_messages);
        assert!(
            (tree1.components.communication - hier.components.communication).abs()
                < 1e-9 * hier.components.communication,
            "tree {} vs hier {}",
            tree1.components.communication,
            hier.components.communication
        );
        assert_eq!(tree1.level_messages.len(), 2);
        assert_eq!(tree1.level_messages[1], tree1.inter_messages);
        assert!(hier.level_messages.is_empty(), "non-tree runs track no levels");
        // a chassis tier pays off once the top link is derated
        let rack = LinkModel {
            alpha_s: IB.alpha_s * 10.0,
            fabric_msg_cost_s: IB.fabric_msg_cost_s * 10.0,
            ..IB
        };
        let two = base.clone().with_tree(vec![16], vec![rack]).replay(&trace);
        let three = base.with_tree(vec![16, 4], vec![IB, rack]).replay(&trace);
        assert_eq!(two.total_spikes, three.total_spikes, "same workload");
        assert!(
            three.components.communication < two.components.communication,
            "three {} vs two {}",
            three.components.communication,
            two.components.communication
        );
        // 256 ranks as 16 boards x 4 chassis: 4·3 rack-tier messages
        // per exchange instead of 16·15
        assert_eq!(three.level_messages[2], 12 * three.exchanges);
        assert_eq!(two.level_messages[1], 240 * two.exchanges);
    }

    #[test]
    fn comm_share_rises_with_p() {
        let net = NetworkParams::paper_20480;
        let c4 = outcome(net(), XEON_E5_2630V2, 4).components.fractions();
        let c256 = outcome(net(), XEON_E5_2630V2, 256).components.fractions();
        assert!(c4.0 > 0.9, "4 procs computation-dominated: {c4:?}");
        assert!(c256.1 > 0.7, "256 procs communication-dominated: {c256:?}");
    }

    #[test]
    fn big_networks_keep_scaling_longer() {
        // Table I shape: at 256 procs the computation share grows with
        // network size (6.6% / 21.7% / 50% in the paper).
        let f = |net: NetworkParams| outcome(net, XEON_E5_2630V2, 256).components.fractions().0;
        let c20k = f(NetworkParams::paper_20480());
        let c320k = f(NetworkParams::paper_320k());
        let c1280k = f(NetworkParams::paper_1280k());
        assert!(
            c20k < c320k && c320k < c1280k,
            "comp shares must rise with size: {c20k:.3} {c320k:.3} {c1280k:.3}"
        );
        assert!(c1280k > 0.25, "1280K@256 comp share {c1280k}");
    }
}
