//! Table III — "DPSNN time, power and energy to solution on ARM":
//! Jetson TX1 boards, 1–8 cores (8 = two boards over the GbE switch).

use anyhow::Result;

use crate::coordinator::RunResult;
use crate::util::table::Table;

use super::common::{modeled, paper_networks, results_dir, sim_seconds};

/// Paper rows: (cores, wall s, power W, energy J).
pub const PAPER_ROWS: &[(u32, f64, f64, f64)] = &[
    (1, 636.8, 2.2, 1273.6),
    (2, 334.1, 3.4, 1135.9),
    (4, 185.0, 6.0, 1110.0),
    (8, 133.8, 10.0, 1338.0),
];

pub fn model_row(procs: u32, sim_s: f64) -> Result<RunResult> {
    let net = paper_networks()[0].1.clone();
    modeled(net, "jetson", "eth1g", procs, sim_s)
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let scale = 10.0 / sim_s;
    let mut table = Table::new(
        "Table III — ARM (Jetson TX1) time/power/energy (modeled vs paper)",
        &[
            "ARM cores", "time (s)", "paper", "power (W)", "paper",
            "energy (J)", "paper",
        ],
    );
    for &(procs, pt, pp, pe) in PAPER_ROWS {
        let r = model_row(procs, sim_s)?;
        let wall = r.wall_s * scale;
        let power = r.energy.unwrap().power_w;
        table.row(vec![
            procs.to_string(),
            format!("{wall:.1}"),
            format!("{pt:.1}"),
            format!("{power:.1}"),
            format!("{pp:.1}"),
            format!("{:.0}", wall * power),
            format!("{pe:.1}"),
        ]);
    }
    let out = table.render();
    table.write_csv(&results_dir().join("table3.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_rows_match_paper_within_factor() {
        for &(procs, pt, pp, _) in PAPER_ROWS {
            let r = model_row(procs, 1.0).unwrap();
            let wall = r.wall_s * 10.0;
            let power = r.energy.unwrap().power_w;
            assert!(
                (0.5..2.0).contains(&(wall / pt)),
                "cores {procs}: wall {wall:.0} vs paper {pt}"
            );
            assert!(
                (0.5..2.0).contains(&(power / pp)),
                "cores {procs}: power {power:.1} vs paper {pp}"
            );
        }
    }

    #[test]
    fn energy_flat_while_time_drops() {
        // Table III: 1 -> 4 cores cuts time ~3.4x while energy barely moves
        let r1 = model_row(1, 1.0).unwrap();
        let r4 = model_row(4, 1.0).unwrap();
        let t_ratio = r1.wall_s / r4.wall_s;
        let e1 = r1.wall_s * r1.energy.unwrap().power_w;
        let e4 = r4.wall_s * r4.energy.unwrap().power_w;
        assert!(t_ratio > 2.5, "time ratio {t_ratio}");
        assert!((0.6..1.6).contains(&(e4 / e1)), "energy ratio {}", e4 / e1);
    }
}
