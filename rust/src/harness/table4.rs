//! Table IV — "Comparison of energetic efficiencies": µJ per synaptic
//! event for DPSNN on ARM and Intel (each at its energy-optimal point)
//! against the published Compass/TrueNorth simulator figure.

use anyhow::Result;

use crate::metrics::energy::{joules_per_synaptic_event, COMPASS_TRUENORTH_UJ};
use crate::metrics::synevents::SynapticEventCount;
use crate::util::table::Table;

use super::common::{results_dir, sim_seconds};
use super::{table2, table3};

/// Paper values (µJ / synaptic event).
pub const PAPER_ARM_UJ: f64 = 1.1;
pub const PAPER_INTEL_UJ: f64 = 3.4;

/// Best (minimum-energy) modeled point on a platform over a core sweep.
fn best_uj<F>(cores: &[u32], sim_s: f64, model: F) -> Result<(u32, f64)>
where
    F: Fn(u32, f64) -> Result<crate::coordinator::RunResult>,
{
    let mut best: Option<(u32, f64)> = None;
    for &p in cores {
        let r = model(p, sim_s)?;
        let wall10 = r.wall_s * 10.0 / sim_s;
        let e = wall10 * r.energy.unwrap().power_w;
        let events = SynapticEventCount::measured(
            (r.total_syn_events as f64 * 10.0 / sim_s) as u64,
            (r.total_ext_events as f64 * 10.0 / sim_s) as u64,
        );
        let uj = joules_per_synaptic_event(e, &events) * 1e6;
        if best.map_or(true, |(_, b)| uj < b) {
            best = Some((p, uj));
        }
    }
    Ok(best.unwrap())
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let (arm_p, arm_uj) = best_uj(&[1, 2, 4, 8], sim_s, table3::model_row)?;
    let (intel_p, intel_uj) = best_uj(&[1, 2, 4, 8, 16], sim_s, |p, s| {
        table2::model_row(p, "ib", s)
    })?;

    let mut table = Table::new(
        "Table IV — energetic efficiency (uJ / synaptic event)",
        &["system", "modeled", "paper", "at cores"],
    );
    table.row(vec![
        "DPSNN on ARM (Jetson)".into(),
        format!("{arm_uj:.1}"),
        format!("{PAPER_ARM_UJ}"),
        arm_p.to_string(),
    ]);
    table.row(vec![
        "DPSNN on Intel".into(),
        format!("{intel_uj:.1}"),
        format!("{PAPER_INTEL_UJ}"),
        intel_p.to_string(),
    ]);
    table.row(vec![
        "Compass/TrueNorth sim. (published)".into(),
        "-".into(),
        format!("{COMPASS_TRUENORTH_UJ}"),
        "4 (i7 950)".into(),
    ]);
    let out = table.render();
    table.write_csv(&results_dir().join("table4.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_arm_intel_compass() {
        let (_, arm) = best_uj(&[1, 2, 4, 8], 1.0, table3::model_row).unwrap();
        let (_, intel) =
            best_uj(&[1, 2, 4, 8, 16], 1.0, |p, s| table2::model_row(p, "ib", s)).unwrap();
        assert!(
            arm < intel && intel < COMPASS_TRUENORTH_UJ,
            "arm {arm:.2} < intel {intel:.2} < compass {COMPASS_TRUENORTH_UJ}"
        );
        // magnitudes within ~2x of the paper's 1.1 / 3.4
        assert!((0.5..2.2).contains(&(arm / PAPER_ARM_UJ)), "arm {arm}");
        assert!((0.5..2.0).contains(&(intel / PAPER_INTEL_UJ)), "intel {intel}");
    }
}
