//! Fig 6 — "DPSNN analysis of the NVIDIA SoC platform": comp/comm/barrier
//! decomposition on two Jetson TX1 boards (4 used cores each) behind a
//! 1 GbE switch, extended with the Intel bath beyond 8 processes.

use anyhow::Result;

use crate::config::{Mode, NetworkParams, RunConfig};
use crate::coordinator::modeled::run_modeled_cluster;
use crate::coordinator::RunResult;
use crate::platform::hetero::{HeteroCluster, RankGroup};
use crate::platform::presets::{JETSON_A57, XEON_E5_2630V2};
use crate::util::table::{ascii_chart, Table};

use super::common::{results_dir, sim_seconds};

pub const ARM_CORES: u32 = 8; // 2 boards x 4 driven cores

pub fn jetson_cluster(p: u32) -> HeteroCluster {
    if p <= ARM_CORES {
        HeteroCluster::homogeneous(JETSON_A57, p, 4)
    } else {
        HeteroCluster::new(vec![
            RankGroup { core: JETSON_A57, ranks: ARM_CORES, ranks_per_node: 4 },
            RankGroup { core: XEON_E5_2630V2, ranks: p - ARM_CORES, ranks_per_node: 12 },
        ])
    }
}

pub fn run_point(net: NetworkParams, p: u32, sim_s: f64) -> Result<RunResult> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = p;
    cfg.sim_seconds = sim_s;
    cfg.mode = Mode::Modeled;
    cfg.interconnect = "eth1g".into();
    run_modeled_cluster(&cfg, jetson_cluster(p), 4)
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let net = NetworkParams::paper_20480();
    let procs = [1u32, 2, 4, 8, 16, 32];

    let mut table = Table::new(
        "Fig 6 — execution components on Jetson TX1+GbE, 20480N (modeled)",
        &["procs", "wall (s/10s)", "comp %", "comm %", "barrier %"],
    );
    let mut comp_s = Vec::new();
    let mut comm_s = Vec::new();
    let mut barr_s = Vec::new();
    for &p in &procs {
        let r = run_point(net.clone(), p, sim_s)?;
        let (comp, comm, barrier) = r.components.fractions();
        table.row(vec![
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{:.1}", comp * 100.0),
            format!("{:.1}", comm * 100.0),
            format!("{:.1}", barrier * 100.0),
        ]);
        comp_s.push((p as f64, comp * 100.0));
        comm_s.push((p as f64, comm * 100.0));
        barr_s.push((p as f64, barrier * 100.0));
    }
    let mut out = table.render();
    out.push_str(&ascii_chart(
        "Jetson: A57 cores ~2x Trenz A53, same GbE wall",
        &[("comp%", comp_s), ("comm%", comm_s), ("barrier%", barr_s)],
        true,
        false,
        60,
        12,
    ));
    table.write_csv(&results_dir().join("fig6.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::fig4;

    #[test]
    fn jetson_faster_than_trenz_same_p() {
        // A57@2GHz ~ 2x A53@1.5GHz in the paper's speed statements
        let net = NetworkParams::paper_20480();
        let j = run_point(net.clone(), 4, 1.0).unwrap().wall_s;
        let t = fig4::run_point(net, 4, 1.0).unwrap().wall_s;
        let ratio = t / j;
        assert!((1.5..3.0).contains(&ratio), "trenz/jetson = {ratio}");
    }

    #[test]
    fn single_board_is_compute_dominated() {
        let net = NetworkParams::paper_20480();
        let (comp, comm, _) = run_point(net, 4, 1.0)
            .unwrap()
            .components
            .fractions();
        assert!(comp > 0.9 && comm < 0.05, "comp={comp} comm={comm}");
    }
}
