//! Fig 4 — "Strong scaling of a grid simulated on the Trenz platform
//! equipped with GbE interconnect."
//!
//! The ExaNeSt prototype has 4 Trenz boards × 4 Cortex-A53 cores = 16 ARM
//! cores; the paper pushes the sweep to 64 processes with MPI
//! heterogeneous mode, embedding the ARM partition in an Intel "bath"
//! whose faster cores take proportionally more neurons and do not slow
//! the ARM ranks (speed-weighted partitioning, `platform::hetero`).

use anyhow::Result;

use crate::config::{Mode, NetworkParams, RunConfig};
use crate::coordinator::modeled::run_modeled_cluster;
use crate::coordinator::RunResult;
use crate::platform::hetero::{HeteroCluster, RankGroup};
use crate::platform::presets::{TRENZ_A53, XEON_E5_2630V2};
use crate::util::table::{ascii_chart, Table};

use super::common::{results_dir, sim_seconds};

pub const ARM_CORES: u32 = 16;

/// The Trenz sweep cluster at `p` processes (ARM first, Intel bath after).
pub fn trenz_cluster(p: u32) -> HeteroCluster {
    if p <= ARM_CORES {
        HeteroCluster::homogeneous(TRENZ_A53, p, 4)
    } else {
        HeteroCluster::new(vec![
            RankGroup { core: TRENZ_A53, ranks: ARM_CORES, ranks_per_node: 4 },
            RankGroup { core: XEON_E5_2630V2, ranks: p - ARM_CORES, ranks_per_node: 12 },
        ])
    }
}

pub fn run_point(net: NetworkParams, p: u32, sim_s: f64) -> Result<RunResult> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = p;
    cfg.sim_seconds = sim_s;
    cfg.mode = Mode::Modeled;
    cfg.interconnect = "eth1g".into();
    run_modeled_cluster(&cfg, trenz_cluster(p), 4)
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let net = NetworkParams::paper_20480();
    let procs = [1u32, 2, 4, 8, 16, 32, 64];

    let mut table = Table::new(
        "Fig 4 — strong scaling on Trenz (4xA53/board, GbE; >16 procs = Intel bath)",
        &["procs", "wall (s/10s)", "speedup vs 1"],
    );
    let mut series = Vec::new();
    let mut w1 = 0.0;
    for &p in &procs {
        let r = run_point(net.clone(), p, sim_s)?;
        let wall10 = r.wall_s * 10.0 / sim_s;
        if p == 1 {
            w1 = wall10;
        }
        table.row(vec![
            p.to_string(),
            format!("{wall10:.1}"),
            format!("{:.2}", w1 / wall10),
        ]);
        series.push((p as f64, wall10));
    }
    let mut out = table.render();
    out.push_str(&ascii_chart(
        "wall vs procs (log-log); paper: scaling flattens as GbE latency bites",
        &[("20480N", series)],
        true,
        true,
        60,
        12,
    ));
    table.write_csv(&results_dir().join("fig4.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_scales_then_flattens_on_gbe() {
        let net = NetworkParams::paper_20480();
        let w = |p: u32| run_point(net.clone(), p, 1.0).unwrap().wall_s;
        let w1 = w(1);
        let w16 = w(16);
        assert!(w16 < w1 / 6.0, "useful scaling to 16: {w1} -> {w16}");
        // GbE all-to-all latency keeps 64 procs from another 4x
        let w64 = w(64);
        assert!(w64 > w16 / 3.0, "GbE flattens the curve: w16={w16} w64={w64}");
    }

    #[test]
    fn hetero_bath_does_not_slow_arm() {
        // 17th rank is Intel: adding it must not increase wall by more
        // than the extra comm cost of one more rank
        let net = NetworkParams::paper_20480();
        let w16 = run_point(net.clone(), 16, 1.0).unwrap().wall_s;
        let w24 = run_point(net, 24, 1.0).unwrap().wall_s;
        assert!(w24 < w16 * 1.5, "w16={w16} w24={w24}");
    }
}
