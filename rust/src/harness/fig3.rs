//! Fig 3 — "DPSNN analysis of the Intel-based platform": the
//! computation / communication / barrier percentage decomposition vs
//! process count for the 20480N network.

use anyhow::Result;

use crate::util::table::{ascii_chart, Table};

use super::common::{modeled, paper_networks, results_dir, sim_seconds};

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let net = paper_networks()[0].1.clone();
    let procs = [1u32, 2, 4, 8, 16, 32, 64, 128, 256];

    let mut table = Table::new(
        "Fig 3 — execution components on Intel+IB, 20480N (modeled)",
        &["procs", "wall (s/10s)", "comp %", "comm %", "barrier %"],
    );
    let mut comp_series = Vec::new();
    let mut comm_series = Vec::new();
    let mut barr_series = Vec::new();
    for &p in &procs {
        let r = modeled(net.clone(), "xeon", "ib", p, sim_s)?;
        let (comp, comm, barrier) = r.components.fractions();
        table.row(vec![
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{:.1}", comp * 100.0),
            format!("{:.1}", comm * 100.0),
            format!("{:.1}", barrier * 100.0),
        ]);
        comp_series.push((p as f64, comp * 100.0));
        comm_series.push((p as f64, comm * 100.0));
        barr_series.push((p as f64, barrier * 100.0));
    }

    let mut out = table.render();
    out.push_str(&ascii_chart(
        "component share vs procs (x log): comm overtakes comp past ~32",
        &[
            ("comp%", comp_series),
            ("comm%", comm_series),
            ("barrier%", barr_series),
        ],
        true,
        false,
        60,
        14,
    ));
    table.write_csv(&results_dir().join("fig3.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossover_exists() {
        let net = paper_networks()[0].1.clone();
        let lo = modeled(net.clone(), "xeon", "ib", 4, 1.0).unwrap();
        let hi = modeled(net, "xeon", "ib", 256, 1.0).unwrap();
        let (c4, m4, _) = lo.components.fractions();
        let (c256, m256, _) = hi.components.fractions();
        assert!(c4 > m4, "computation dominates at 4 procs");
        assert!(m256 > c256, "communication dominates at 256 procs");
    }
}
