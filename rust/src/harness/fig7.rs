//! Fig 7 — "Scaling of the total power consumption on x86": simulated
//! multimeter traces of the same workload on 1..64 cores. Each trace has
//! the paper's texture: 5 s idle plateau (the baseline), a steep knee at
//! simulation start, the run plateau, and the final drop.

use anyhow::Result;

use crate::platform::presets::platform_by_name;
use crate::power::meter::{MeterMode, Multimeter};
use crate::power::model::PowerModel;
use crate::simnet::presets::interconnect_by_name;
use crate::util::table::{ascii_chart, Table};

use super::common::{results_dir, sim_seconds};
use super::table2::model_row;

/// The paper's pre-run artificial pause.
pub const IDLE_PREAMBLE_S: f64 = 5.0;

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let scale = 10.0 / sim_s;
    let platform = platform_by_name("westmere")?;
    let meter = Multimeter::new(MeterMode::Ac, 4.0, 0xF16_7);

    let cases: Vec<(String, u32, &str)> = vec![
        ("1".into(), 1, "ib"),
        ("2".into(), 2, "ib"),
        ("4".into(), 4, "ib"),
        ("8".into(), 8, "ib"),
        ("16".into(), 16, "ib"),
        ("32 IB".into(), 32, "ib"),
        ("32 ETH".into(), 32, "eth1g"),
        ("64 IB".into(), 64, "ib"),
        ("64 ETH".into(), 64, "eth1g"),
    ];

    let mut table = Table::new(
        "Fig 7 — x86 power traces (simulated GDM-8351, AC at the strip)",
        &["cores", "baseline (W)", "plateau (W)", "run (s)", "energy (J)"],
    );
    let mut chart_series = Vec::new();
    let mut csv_all = String::from("series,t_s,watts\n");
    for (label, procs, ic) in &cases {
        let r = model_row(*procs, ic, sim_s)?;
        let link = interconnect_by_name(ic)?;
        let pm = PowerModel::new(platform.clone(), link);
        let wall = r.wall_s * scale;
        let running = pm.absolute_running_power_w(
            *procs,
            r.components.fractions().0,
        );
        let trace = meter.sample(&[
            (IDLE_PREAMBLE_S, platform.baseline_w),
            (wall, running),
            (3.0, platform.baseline_w),
        ]);
        let baseline = trace.infer_baseline_w(IDLE_PREAMBLE_S);
        let energy = trace.energy_above_j(baseline);
        table.row(vec![
            label.clone(),
            format!("{baseline:.0}"),
            format!("{running:.0}"),
            format!("{wall:.1}"),
            format!("{energy:.0}"),
        ]);
        for (&t, &w) in trace.t_s.iter().zip(&trace.w) {
            csv_all.push_str(&format!("{label},{t:.2},{w:.1}\n"));
        }
        if matches!(label.as_str(), "1" | "8" | "32 ETH" | "64 ETH") {
            chart_series.push((
                label.clone(),
                trace
                    .t_s
                    .iter()
                    .zip(&trace.w)
                    .map(|(&t, &w)| (t.max(0.2), w))
                    .collect::<Vec<_>>(),
            ));
        }
    }

    let mut out = table.render();
    let named: Vec<(&str, Vec<(f64, f64)>)> = chart_series
        .iter()
        .map(|(n, s)| (n.as_str(), s.clone()))
        .collect();
    out.push_str(&ascii_chart(
        "power vs time (t log, as in the paper): knee at start, drop at end",
        &named,
        true,
        false,
        64,
        14,
    ));
    table.write_csv(&results_dir().join("fig7_summary.csv"))?;
    std::fs::create_dir_all(results_dir())?;
    std::fs::write(results_dir().join("fig7_traces.csv"), csv_all)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_energy_consistent_with_table2_model() {
        // integrating the simulated meter trace must land near P*t
        let platform = platform_by_name("westmere").unwrap();
        let meter = Multimeter::new(MeterMode::Ac, 4.0, 3);
        let r = model_row(8, "ib", 1.0).unwrap();
        let wall = r.wall_s * 10.0;
        let power = r.energy.unwrap().power_w;
        let trace = meter.sample(&[
            (IDLE_PREAMBLE_S, platform.baseline_w),
            (wall, platform.baseline_w + power),
            (3.0, platform.baseline_w),
        ]);
        let baseline = trace.infer_baseline_w(IDLE_PREAMBLE_S);
        let e = trace.energy_above_j(baseline);
        let expect = power * wall;
        assert!(
            (e - expect).abs() / expect < 0.1,
            "trace {e:.0} vs model {expect:.0}"
        );
    }
}
