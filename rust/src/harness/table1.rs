//! Table I — "Profiling of execution components for different network
//! sizes": wall-clock and comp/comm/barrier percentages for the
//! (network, procs) matrix the paper reports, side by side with the
//! paper's own measurements.

use anyhow::Result;

use crate::config::NetworkParams;
use crate::metrics::comm_volume::expected_recv_bytes_per_rank;
use crate::metrics::memory;
use crate::util::table::Table;

use super::common::{modeled, modeled_tree, paper_networks, results_dir, sim_seconds};

/// Per-rank resident memory (largest even-split rank) in the mode
/// `--connectivity auto` resolves for this cell, as "MB (mode)".
fn mem_cell(net: &NetworkParams, procs: u32) -> String {
    let mode = memory::auto_connectivity_mode(net, procs, memory::DEFAULT_RANK_BUDGET_BYTES);
    let bytes = memory::predicted_rank_bytes(net, net.n_neurons.div_ceil(procs), mode);
    format!("{:.0} ({})", bytes as f64 / 1e6, mode)
}

/// (net index, procs, paper wall s, paper comp %, comm %, barrier %)
pub const PAPER_ROWS: &[(usize, u32, f64, f64, f64, f64)] = &[
    (0, 4, 31.5, 97.6, 0.6, 1.3),
    (0, 32, 9.15, 69.7, 22.7, 7.5),
    (0, 256, 237.0, 6.6, 91.7, 1.6),
    (1, 4, 893.0, 98.1, 0.1, 1.8),
    (1, 256, 441.0, 21.7, 79.9, 1.1),
    (2, 4, 4341.0, 99.4, 0.1, 0.5),
    (2, 256, 561.0, 50.0, 48.1, 1.9),
];

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let nets = paper_networks();
    let mut table = Table::new(
        "Table I — execution-component profile (modeled vs paper; recv MB/r = \
         AER bytes each rank receives per 10 s sim under filtered routing)",
        &[
            "net", "procs", "wall (s)", "paper", "comp %", "paper", "comm %", "paper",
            "barrier %", "paper", "recv MB/r", "mem MB/r (mode)",
        ],
    );
    for &(ni, p, pw, pc, pm, pb) in PAPER_ROWS {
        let (name, net) = &nets[ni];
        let r = modeled(net.clone(), "xeon", "ib", p, sim_s)?;
        let (comp, comm, barrier) = r.components.fractions();
        let spikes_10s = (r.total_spikes as f64 * 10.0 / sim_s) as u64;
        let recv = expected_recv_bytes_per_rank(
            net.n_neurons,
            net.syn_per_neuron,
            p,
            spikes_10s,
            true,
        );
        table.row(vec![
            name.to_string(),
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{pw:.1}"),
            format!("{:.1}", comp * 100.0),
            format!("{pc:.1}"),
            format!("{:.1}", comm * 100.0),
            format!("{pm:.1}"),
            format!("{:.1}", barrier * 100.0),
            format!("{pb:.1}"),
            format!("{:.1}", recv / 1e6),
            mem_cell(net, p),
        ]);
    }
    // 100x appendix row: the 2M-neuron network the paper could not
    // host, priced through the tree model; procedural connectivity
    // keeps the auto-resolved per-rank memory below the table's cells
    // even though the network is 100x the paper's smallest.
    let big = NetworkParams::paper(2_000_000);
    let r = modeled_tree(big.clone(), 256, sim_s)?;
    let (comp, comm, barrier) = r.components.fractions();
    table.row(vec![
        "2000KN".to_string(),
        "256".to_string(),
        format!("{:.1}", r.wall_s * 10.0 / sim_s),
        "-".to_string(),
        format!("{:.1}", comp * 100.0),
        "-".to_string(),
        format!("{:.1}", comm * 100.0),
        "-".to_string(),
        format!("{:.1}", barrier * 100.0),
        "-".to_string(),
        "-".to_string(),
        mem_cell(&big, 256),
    ]);
    let out = table.render();
    table.write_csv(&results_dir().join("table1.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominant_component_matches_paper_in_every_cell() {
        let nets = paper_networks();
        for &(ni, p, _, pc, pm, _) in PAPER_ROWS {
            let r = modeled(nets[ni].1.clone(), "xeon", "ib", p, 1.0).unwrap();
            let (comp, comm, _) = r.components.fractions();
            let paper_comp_dominant = pc > pm;
            let model_comp_dominant = comp > comm;
            // 1280K@256 is ~50/50 in the paper; accept either side there
            if (pc - pm).abs() > 10.0 {
                assert_eq!(
                    paper_comp_dominant, model_comp_dominant,
                    "net {ni} procs {p}: paper {pc}/{pm}, model {comp:.2}/{comm:.2}"
                );
            }
        }
    }

    #[test]
    fn memory_column_reports_mode_and_megabytes() {
        // every paper cell fits the materialized table per rank...
        for &(ni, p, ..) in PAPER_ROWS {
            let cell = mem_cell(&paper_networks()[ni].1, p);
            assert!(cell.contains("(materialized)"), "{cell}");
        }
        // ...while the 2M appendix goes procedural on few ranks
        let cell = mem_cell(&NetworkParams::paper(2_000_000), 4);
        assert!(cell.contains("(procedural)"), "{cell}");
    }
}
