//! Table I — "Profiling of execution components for different network
//! sizes": wall-clock and comp/comm/barrier percentages for the
//! (network, procs) matrix the paper reports, side by side with the
//! paper's own measurements.

use anyhow::Result;

use crate::metrics::comm_volume::expected_recv_bytes_per_rank;
use crate::util::table::Table;

use super::common::{modeled, paper_networks, results_dir, sim_seconds};

/// (net index, procs, paper wall s, paper comp %, comm %, barrier %)
pub const PAPER_ROWS: &[(usize, u32, f64, f64, f64, f64)] = &[
    (0, 4, 31.5, 97.6, 0.6, 1.3),
    (0, 32, 9.15, 69.7, 22.7, 7.5),
    (0, 256, 237.0, 6.6, 91.7, 1.6),
    (1, 4, 893.0, 98.1, 0.1, 1.8),
    (1, 256, 441.0, 21.7, 79.9, 1.1),
    (2, 4, 4341.0, 99.4, 0.1, 0.5),
    (2, 256, 561.0, 50.0, 48.1, 1.9),
];

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let nets = paper_networks();
    let mut table = Table::new(
        "Table I — execution-component profile (modeled vs paper; recv MB/r = \
         AER bytes each rank receives per 10 s sim under filtered routing)",
        &[
            "net", "procs", "wall (s)", "paper", "comp %", "paper", "comm %", "paper",
            "barrier %", "paper", "recv MB/r",
        ],
    );
    for &(ni, p, pw, pc, pm, pb) in PAPER_ROWS {
        let (name, net) = &nets[ni];
        let r = modeled(net.clone(), "xeon", "ib", p, sim_s)?;
        let (comp, comm, barrier) = r.components.fractions();
        let spikes_10s = (r.total_spikes as f64 * 10.0 / sim_s) as u64;
        let recv = expected_recv_bytes_per_rank(
            net.n_neurons,
            net.syn_per_neuron,
            p,
            spikes_10s,
            true,
        );
        table.row(vec![
            name.to_string(),
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{pw:.1}"),
            format!("{:.1}", comp * 100.0),
            format!("{pc:.1}"),
            format!("{:.1}", comm * 100.0),
            format!("{pm:.1}"),
            format!("{:.1}", barrier * 100.0),
            format!("{pb:.1}"),
            format!("{:.1}", recv / 1e6),
        ]);
    }
    let out = table.render();
    table.write_csv(&results_dir().join("table1.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominant_component_matches_paper_in_every_cell() {
        let nets = paper_networks();
        for &(ni, p, _, pc, pm, _) in PAPER_ROWS {
            let r = modeled(nets[ni].1.clone(), "xeon", "ib", p, 1.0).unwrap();
            let (comp, comm, _) = r.components.fractions();
            let paper_comp_dominant = pc > pm;
            let model_comp_dominant = comp > comm;
            // 1280K@256 is ~50/50 in the paper; accept either side there
            if (pc - pm).abs() > 10.0 {
                assert_eq!(
                    paper_comp_dominant, model_comp_dominant,
                    "net {ni} procs {p}: paper {pc}/{pm}, model {comp:.2}/{comm:.2}"
                );
            }
        }
    }
}
