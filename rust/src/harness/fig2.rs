//! Fig 2 — "Strong scaling of different problem sizes on an IB-equipped
//! Intel-based platform. The red line is the threshold to be reached for
//! soft real-time execution."
//!
//! Three network sizes (20480N / 320KN / 1280KN), wall-clock for 10 s of
//! simulated activity vs process count. The 20480N curve must dip under
//! the 10 s real-time line near 32 processes and then *rise* — the
//! latency wall.

use anyhow::Result;

use crate::config::{ConnectivityMode, NetworkParams};
use crate::metrics::comm_volume::expected_recv_bytes_per_rank;
use crate::metrics::memory;
use crate::util::table::{ascii_chart, Table};

use super::common::{modeled, modeled_tree, paper_networks, results_dir, sim_seconds};

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let procs = [1u32, 2, 4, 8, 16, 32, 64, 128, 256];
    let nets = paper_networks();

    let mut table = Table::new(
        "Fig 2 — strong scaling vs real-time, Intel+IB (modeled, s per 10 s sim; \
         recv columns: 20480N AER bytes/rank, filtered vs broadcast routing)",
        &["procs", "20480N", "320KN", "1280KN", "real-time", "recv MB/rk", "bcast MB/rk"],
    );
    let mut cols: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nets.len()];
    for &p in &procs {
        let mut row = vec![p.to_string()];
        let mut spikes_20480_10s = 0u64;
        for (i, (_, net)) in nets.iter().enumerate() {
            let r = modeled(net.clone(), "xeon", "ib", p, sim_s)?;
            if i == 0 {
                spikes_20480_10s = (r.total_spikes as f64 * 10.0 / sim_s) as u64;
            }
            let wall10 = r.wall_s * 10.0 / sim_s;
            row.push(format!("{wall10:.1}"));
            cols[i].push((p as f64, wall10));
        }
        row.push("10.0".to_string());
        let n20 = &nets[0].1;
        for filtered in [true, false] {
            let bytes = expected_recv_bytes_per_rank(
                n20.n_neurons,
                n20.syn_per_neuron,
                p,
                spikes_20480_10s,
                filtered,
            );
            row.push(format!("{:.1}", bytes / 1e6));
        }
        table.row(row);
    }

    let series: Vec<(&str, Vec<(f64, f64)>)> = vec![
        ("20480N", cols[0].clone()),
        ("320KN", cols[1].clone()),
        ("1280KN", cols[2].clone()),
        (
            "real-time",
            procs.iter().map(|&p| (p as f64, 10.0)).collect(),
        ),
    ];
    let mut out = table.render();
    out.push_str(&ascii_chart(
        "wall-clock vs procs (log-log); paper: 20480N bottoms at 32 procs, 9.15 s",
        &series,
        true,
        true,
        60,
        16,
    ));
    table.write_csv(&results_dir().join("fig2.csv"))?;

    // 100x appendix: the 2M-neuron point procedural connectivity
    // unlocks, priced through the tree model (board -> chassis) and
    // the analytic per-rank memory model at the largest even-split
    // rank. The auto column is what `--connectivity auto` resolves:
    // materialized once enough ranks spread the table under the
    // 2 GiB/rank budget, procedural below that.
    let big = NetworkParams::paper(2_000_000);
    let mut big_tbl = Table::new(
        "2MN appendix — tree:16,4 pricing (modeled, xeon+IB) + memory model",
        &["procs", "wall (s/10s)", "mat GB/rk", "proc MB/rk", "auto mode"],
    );
    for &p in &[4u32, 8, 32, 64, 256] {
        let r = modeled_tree(big.clone(), p, sim_s)?;
        let n_local = big.n_neurons.div_ceil(p);
        let mat = memory::predicted_rank_bytes(&big, n_local, ConnectivityMode::Materialized);
        let pro = memory::predicted_rank_bytes(&big, n_local, ConnectivityMode::Procedural);
        let auto = memory::auto_connectivity_mode(&big, p, memory::DEFAULT_RANK_BUDGET_BYTES);
        big_tbl.row(vec![
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{:.2}", mat as f64 / 1e9),
            format!("{:.1}", pro as f64 / 1e6),
            auto.to_string(),
        ]);
    }
    out.push('\n');
    out.push_str(&big_tbl.render());
    big_tbl.write_csv(&results_dir().join("fig2_2m.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n20k_dips_under_realtime_then_rises() {
        let net = paper_networks()[0].1.clone();
        let w32 = modeled(net.clone(), "xeon", "ib", 32, 2.0).unwrap();
        let w256 = modeled(net, "xeon", "ib", 256, 2.0).unwrap();
        let wall32_10s = w32.wall_s * 5.0;
        let wall256_10s = w256.wall_s * 5.0;
        assert!(wall32_10s < 14.0, "near real-time at 32: {wall32_10s}");
        assert!(wall256_10s > 3.0 * wall32_10s, "latency wall at 256");
    }

    #[test]
    fn two_m_appendix_prices_the_tree_and_flips_the_memory_model() {
        let big = NetworkParams::paper(2_000_000);
        let r = modeled_tree(big.clone(), 64, 1.0).unwrap();
        assert!(r.wall_s > 0.0);
        // the appendix's auto column: the table busts the budget on few
        // ranks, spreads back under it with enough of them
        assert_eq!(
            memory::auto_connectivity_mode(&big, 4, memory::DEFAULT_RANK_BUDGET_BYTES),
            ConnectivityMode::Procedural
        );
        assert_eq!(
            memory::auto_connectivity_mode(&big, 64, memory::DEFAULT_RANK_BUDGET_BYTES),
            ConnectivityMode::Materialized
        );
    }
}
