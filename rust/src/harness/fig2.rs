//! Fig 2 — "Strong scaling of different problem sizes on an IB-equipped
//! Intel-based platform. The red line is the threshold to be reached for
//! soft real-time execution."
//!
//! Three network sizes (20480N / 320KN / 1280KN), wall-clock for 10 s of
//! simulated activity vs process count. The 20480N curve must dip under
//! the 10 s real-time line near 32 processes and then *rise* — the
//! latency wall.

use anyhow::Result;

use crate::metrics::comm_volume::expected_recv_bytes_per_rank;
use crate::util::table::{ascii_chart, Table};

use super::common::{modeled, paper_networks, results_dir, sim_seconds};

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let procs = [1u32, 2, 4, 8, 16, 32, 64, 128, 256];
    let nets = paper_networks();

    let mut table = Table::new(
        "Fig 2 — strong scaling vs real-time, Intel+IB (modeled, s per 10 s sim; \
         recv columns: 20480N AER bytes/rank, filtered vs broadcast routing)",
        &["procs", "20480N", "320KN", "1280KN", "real-time", "recv MB/rk", "bcast MB/rk"],
    );
    let mut cols: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nets.len()];
    for &p in &procs {
        let mut row = vec![p.to_string()];
        let mut spikes_20480_10s = 0u64;
        for (i, (_, net)) in nets.iter().enumerate() {
            let r = modeled(net.clone(), "xeon", "ib", p, sim_s)?;
            if i == 0 {
                spikes_20480_10s = (r.total_spikes as f64 * 10.0 / sim_s) as u64;
            }
            let wall10 = r.wall_s * 10.0 / sim_s;
            row.push(format!("{wall10:.1}"));
            cols[i].push((p as f64, wall10));
        }
        row.push("10.0".to_string());
        let n20 = &nets[0].1;
        for filtered in [true, false] {
            let bytes = expected_recv_bytes_per_rank(
                n20.n_neurons,
                n20.syn_per_neuron,
                p,
                spikes_20480_10s,
                filtered,
            );
            row.push(format!("{:.1}", bytes / 1e6));
        }
        table.row(row);
    }

    let series: Vec<(&str, Vec<(f64, f64)>)> = vec![
        ("20480N", cols[0].clone()),
        ("320KN", cols[1].clone()),
        ("1280KN", cols[2].clone()),
        (
            "real-time",
            procs.iter().map(|&p| (p as f64, 10.0)).collect(),
        ),
    ];
    let mut out = table.render();
    out.push_str(&ascii_chart(
        "wall-clock vs procs (log-log); paper: 20480N bottoms at 32 procs, 9.15 s",
        &series,
        true,
        true,
        60,
        16,
    ));
    table.write_csv(&results_dir().join("fig2.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n20k_dips_under_realtime_then_rises() {
        let net = paper_networks()[0].1.clone();
        let w32 = modeled(net.clone(), "xeon", "ib", 32, 2.0).unwrap();
        let w256 = modeled(net, "xeon", "ib", 256, 2.0).unwrap();
        let wall32_10s = w32.wall_s * 5.0;
        let wall256_10s = w256.wall_s * 5.0;
        assert!(wall32_10s < 14.0, "near real-time at 32: {wall32_10s}");
        assert!(wall256_10s > 3.0 * wall32_10s, "latency wall at 256");
    }
}
