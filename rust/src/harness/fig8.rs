//! Fig 8 — "Power consumption on ARM": simulated meter traces on the
//! Jetson platform. The paper splits the plot: 1–4 cores measured DC at a
//! single board's supply (clean, low baseline), 8 cores measured AC
//! upstream of both boards' transformers (noisy, 49.2 W baseline).

use anyhow::Result;

use crate::platform::presets::platform_by_name;
use crate::power::meter::{MeterMode, Multimeter};
use crate::util::table::{ascii_chart, Table};

use super::common::{results_dir, sim_seconds};
use super::fig7::IDLE_PREAMBLE_S;
use super::table3::model_row;

/// Single-board idle draw seen by the DC probe (not in the paper's
/// tables; a Jetson TX1 board idles at a few watts).
pub const DC_BOARD_IDLE_W: f64 = 4.0;

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let scale = 10.0 / sim_s;
    let platform = platform_by_name("jetson")?;

    let mut table = Table::new(
        "Fig 8 — ARM power traces (DC at one board for 1-4 cores, AC for 8)",
        &["cores", "meter", "baseline (W)", "plateau (W)", "run (s)", "energy (J)"],
    );
    let mut chart = Vec::new();
    let mut csv_all = String::from("series,t_s,watts\n");
    for &procs in &[1u32, 2, 4, 8] {
        let (mode, baseline) = if procs <= 4 {
            (MeterMode::Dc, DC_BOARD_IDLE_W)
        } else {
            (MeterMode::Ac, platform.baseline_w)
        };
        let meter = Multimeter::new(mode, 4.0, 0xF18 + procs as u64);
        let r = model_row(procs, sim_s)?;
        let wall = r.wall_s * scale;
        let running = baseline + r.energy.unwrap().power_w;
        let trace = meter.sample(&[
            (IDLE_PREAMBLE_S, baseline),
            (wall, running),
            (3.0, baseline),
        ]);
        let inferred = trace.infer_baseline_w(IDLE_PREAMBLE_S);
        table.row(vec![
            procs.to_string(),
            format!("{mode:?}"),
            format!("{inferred:.1}"),
            format!("{running:.1}"),
            format!("{wall:.1}"),
            format!("{:.0}", trace.energy_above_j(inferred)),
        ]);
        let label = format!("{procs} cores");
        for (&t, &w) in trace.t_s.iter().zip(&trace.w) {
            csv_all.push_str(&format!("{label},{t:.2},{w:.2}\n"));
        }
        chart.push((
            label,
            trace
                .t_s
                .iter()
                .zip(&trace.w)
                .map(|(&t, &w)| (t.max(0.2), w))
                .collect::<Vec<_>>(),
        ));
    }

    let mut out = table.render();
    let named: Vec<(&str, Vec<(f64, f64)>)> =
        chart.iter().map(|(n, s)| (n.as_str(), s.clone())).collect();
    out.push_str(&ascii_chart(
        "ARM power vs time (t log): AC 8-core branch is noisier + higher base",
        &named,
        true,
        false,
        64,
        14,
    ));
    table.write_csv(&results_dir().join("fig8_summary.csv"))?;
    std::fs::create_dir_all(results_dir())?;
    std::fs::write(results_dir().join("fig8_traces.csv"), csv_all)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_draw_is_single_digit_watts() {
        for procs in [1u32, 2, 4] {
            let r = model_row(procs, 1.0).unwrap();
            let p = r.energy.unwrap().power_w;
            assert!(p < 10.0, "{procs} cores draw {p} W above idle");
        }
    }
}
