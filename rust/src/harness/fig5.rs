//! Fig 5 — "DPSNN analysis of the Trenz platform": comp/comm/barrier
//! decomposition vs process count on the ExaNeSt prototype.

use anyhow::Result;

use crate::config::NetworkParams;
use crate::util::table::{ascii_chart, Table};

use super::common::{results_dir, sim_seconds};
use super::fig4::run_point;

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let net = NetworkParams::paper_20480();
    let procs = [1u32, 2, 4, 8, 16, 32, 64];

    let mut table = Table::new(
        "Fig 5 — execution components on Trenz+GbE, 20480N (modeled)",
        &["procs", "wall (s/10s)", "comp %", "comm %", "barrier %"],
    );
    let mut comp_s = Vec::new();
    let mut comm_s = Vec::new();
    let mut barr_s = Vec::new();
    for &p in &procs {
        let r = run_point(net.clone(), p, sim_s)?;
        let (comp, comm, barrier) = r.components.fractions();
        table.row(vec![
            p.to_string(),
            format!("{:.1}", r.wall_s * 10.0 / sim_s),
            format!("{:.1}", comp * 100.0),
            format!("{:.1}", comm * 100.0),
            format!("{:.1}", barrier * 100.0),
        ]);
        comp_s.push((p as f64, comp * 100.0));
        comm_s.push((p as f64, comm * 100.0));
        barr_s.push((p as f64, barrier * 100.0));
    }
    let mut out = table.render();
    out.push_str(&ascii_chart(
        "GbE: communication overtakes computation earlier than on IB",
        &[("comp%", comp_s), ("comm%", comm_s), ("barrier%", barr_s)],
        true,
        false,
        60,
        12,
    ));
    table.write_csv(&results_dir().join("fig5.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbe_comm_share_explodes_past_one_board() {
        let net = NetworkParams::paper_20480();
        let (c4, m4, _) = run_point(net.clone(), 4, 1.0)
            .unwrap()
            .components
            .fractions();
        let (_, m64, _) = run_point(net, 64, 1.0).unwrap().components.fractions();
        assert!(c4 > 0.9, "one board is compute-bound: comp={c4}");
        assert!(m4 < 0.05);
        assert!(m64 > 0.5, "GbE all-to-all dominates at 64: comm={m64}");
    }
}
