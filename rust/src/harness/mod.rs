//! Reproduction harnesses: one module per figure/table of the paper's
//! evaluation (experiment index in DESIGN.md §5). Each harness prints the
//! paper's rows/series next to the modeled/measured values and writes a
//! CSV under `results/`.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;

use anyhow::{bail, Result};

/// All harness ids in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
    "table1", "table2", "table3", "table4",
];

/// Run one harness by id.
pub fn run_one(id: &str, fast: bool) -> Result<String> {
    Ok(match id {
        "fig1" => fig1::run(fast)?,
        "fig2" => fig2::run(fast)?,
        "fig3" => fig3::run(fast)?,
        "fig4" => fig4::run(fast)?,
        "fig5" => fig5::run(fast)?,
        "fig6" => fig6::run(fast)?,
        "fig7" => fig7::run(fast)?,
        "fig8" => fig8::run(fast)?,
        "table1" => table1::run(fast)?,
        "table2" => table2::run(fast)?,
        "table3" => table3::run(fast)?,
        "table4" => table4::run(fast)?,
        other => bail!("unknown experiment {other:?}; known: {}", ALL.join(", ")),
    })
}
