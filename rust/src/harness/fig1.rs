//! Fig 1 — "Strong scaling up to 1024 processes of large neural networks
//! on an IB-equipped Intel-based cluster."
//!
//! These are the WaveScalES-class networks (thousands of synapses per
//! neuron, *spatially mapped* so the process-adjacency matrix is sparse —
//! the reduction demonstrated in the paper's ref. [9]). Far from
//! real-time, computation-dominated, and therefore scaling well past the
//! latency wall that kills the small real-time nets of Fig 2.

use anyhow::Result;

use crate::config::NetworkParams;
use crate::platform::hetero::HeteroCluster;
use crate::platform::presets::platform_by_name;
use crate::simnet::presets::IB;
use crate::timing::replay::ModelRun;
use crate::trace::analytic::AnalyticWorkload;
use crate::util::table::{ascii_chart, Table};

use super::common::results_dir;

/// Neighbor ranks each process exchanges spikes with (spatial mapping).
const PEERS: u32 = 40;

fn large_net(n: u32) -> NetworkParams {
    let mut p = NetworkParams::paper(n);
    // WaveScalES-class columnar nets: realistic fan-out
    p.syn_per_neuron = 5000;
    p
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = if fast { 0.5 } else { 2.0 };
    // grid sizes in the multi-billion-synapse class (scaled per sim_s —
    // the *shape* is P-dependence, not absolute seconds)
    let nets: Vec<(String, NetworkParams)> = [524_288u32, 2_097_152, 8_388_608]
        .iter()
        .map(|&n| {
            let net = large_net(n);
            (
                format!("{:.1}G syn", net.total_synapses() as f64 / 1e9),
                net,
            )
        })
        .collect();
    let procs: Vec<u32> = [32u32, 64, 128, 256, 512, 1024].to_vec();

    let mut table = Table::new(
        "Fig 1 — strong scaling, large nets, Intel+IB (modeled, s per 10 s sim)",
        &["procs", &nets[0].0, &nets[1].0, &nets[2].0],
    );
    let mut series: Vec<(&str, Vec<(f64, f64)>)> = Vec::new();
    let mut cols: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nets.len()];

    // the scaling cluster: one ranks-per-node notion from the preset
    let xeon = platform_by_name("xeon")?;
    for &p in &procs {
        let mut row = vec![p.to_string()];
        for (i, (_, net)) in nets.iter().enumerate() {
            let trace =
                AnalyticWorkload::paper_regime(net.clone(), 0x0F16).generate(p, sim_s);
            let run = ModelRun::new(
                HeteroCluster::homogeneous(xeon.node.core, p, xeon.ranks_per_node()),
                xeon.comm_model(IB),
            )
            .with_peers(PEERS);
            let o = run.replay(&trace);
            let wall_10s = o.wall_s * 10.0 / sim_s;
            row.push(format!("{wall_10s:.1}"));
            cols[i].push((p as f64, wall_10s));
        }
        table.row(row);
    }
    for (i, (name, _)) in nets.iter().enumerate() {
        series.push((name, cols[i].clone()));
    }

    let mut out = table.render();
    out.push_str(&ascii_chart(
        "wall-clock vs procs (log-log; down-and-right = good scaling)",
        &series,
        true,
        true,
        60,
        14,
    ));
    table.write_csv(&results_dir().join("fig1.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_nets_scale_monotonically() {
        // the figure's message: these nets keep accelerating to 1024 procs
        let net = large_net(2_097_152);
        let xeon = platform_by_name("xeon").unwrap();
        let wall = |p: u32| {
            let tr = AnalyticWorkload::paper_regime(net.clone(), 1).generate(p, 0.2);
            ModelRun::new(
                HeteroCluster::homogeneous(xeon.node.core, p, xeon.ranks_per_node()),
                xeon.comm_model(IB),
            )
            .with_peers(PEERS)
            .replay(&tr)
            .wall_s
        };
        let w32 = wall(32);
        let w256 = wall(256);
        let w1024 = wall(1024);
        assert!(w256 < w32 / 4.0, "w32={w32} w256={w256}");
        assert!(w1024 < w256, "w256={w256} w1024={w1024}");
    }
}
