//! Shared harness plumbing: standard workloads, run helpers, output paths.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{ExchangeCadence, Mode, NetworkParams, Routing, RunConfig, Topology};
use crate::coordinator::{run, RunResult};

/// Where harness CSVs land.
pub fn results_dir() -> PathBuf {
    std::env::var("DPSNN_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// The three paper network sizes (Fig 2 / Table I).
pub fn paper_networks() -> Vec<(&'static str, NetworkParams)> {
    vec![
        ("20480N", NetworkParams::paper_20480()),
        ("320KN", NetworkParams::paper_320k()),
        ("1280KN", NetworkParams::paper_1280k()),
    ]
}

/// A modeled run of `net` on `platform`+`interconnect` with `procs` ranks.
pub fn modeled(
    net: NetworkParams,
    platform: &str,
    interconnect: &str,
    procs: u32,
    sim_seconds: f64,
) -> Result<RunResult> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = procs;
    cfg.sim_seconds = sim_seconds;
    cfg.mode = Mode::Modeled;
    // The harnesses reproduce the paper, whose runs broadcast every
    // spike to every rank and synchronize every 1 ms step; filtered
    // pricing and min-delay epoch batching are opt-in via --routing /
    // --exchange-every and never touch the figure/table numbers.
    cfg.routing = Routing::Broadcast;
    cfg.exchange_every = ExchangeCadence::Step;
    cfg.platform = platform.to_string();
    cfg.interconnect = interconnect.to_string();
    run(&cfg)
}

/// A modeled run priced through the board → chassis tree model — the
/// pricing the 100× (2M-neuron) appendix rows quote.
pub fn modeled_tree(net: NetworkParams, procs: u32, sim_seconds: f64) -> Result<RunResult> {
    let mut cfg = RunConfig::default();
    cfg.net = net;
    cfg.procs = procs;
    cfg.sim_seconds = sim_seconds;
    cfg.mode = Mode::Modeled;
    cfg.routing = Routing::Broadcast;
    cfg.exchange_every = ExchangeCadence::Step;
    cfg.platform = "xeon".into();
    cfg.interconnect = "ib".into();
    cfg.topology = "tree:16,4".parse::<Topology>()?;
    run(&cfg)
}

/// Standard process sweeps.
pub fn pow2_procs(max: u32) -> Vec<u32> {
    let mut v = vec![1u32];
    while *v.last().unwrap() < max {
        v.push(v.last().unwrap() * 2);
    }
    v
}

/// `--fast` support: harnesses shorten the simulated time when set.
pub fn sim_seconds(fast: bool) -> f64 {
    if fast {
        1.0
    } else {
        10.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_sweep() {
        assert_eq!(pow2_procs(8), vec![1, 2, 4, 8]);
        assert_eq!(pow2_procs(1), vec![1]);
    }

    #[test]
    fn networks_have_paper_sizes() {
        let nets = paper_networks();
        assert_eq!(nets[0].1.n_neurons, 20_480);
        assert_eq!(nets[2].1.total_synapses(), 1_474_560_000);
    }
}
