//! Table II — "DPSNN time, power and energy to solution on x86": the
//! Westmere power platform, 1–64 cores, with the 2-HyperThread corner
//! case and the ETH-vs-IB branches at 32/64 cores.

use anyhow::Result;

use crate::coordinator::RunResult;
use crate::util::table::Table;

use super::common::{modeled, paper_networks, results_dir, sim_seconds};

/// Paper rows: (label, procs, interconnect, wall s, power W, energy J).
pub const PAPER_ROWS: &[(&str, u32, &str, f64, f64, f64)] = &[
    ("1", 1, "ib", 150.9, 48.0, 7243.2),
    ("2 HT", 0, "ib", 121.8, 53.0, 6455.4), // procs=0 -> HT special case
    ("2", 2, "ib", 80.7, 62.0, 5003.4),
    ("4", 4, "ib", 37.4, 92.0, 3440.8),
    ("8", 8, "ib", 25.3, 124.0, 3137.2),
    ("16", 16, "ib", 26.1, 166.0, 4332.6),
    ("32 plus ETH", 32, "eth1g", 30.0, 342.0, 10260.0),
    ("32 plus IB", 32, "ib", 19.7, 318.0, 6264.6),
    ("64 plus ETH", 64, "eth1g", 69.3, 531.0, 36798.3),
    ("64 plus IB", 64, "ib", 32.1, 501.0, 16082.1),
];

/// HyperThreading: two MPI ranks on one physical core. The paper measures
/// a 0.81x wall-clock gain and a ~10% power bump over one core; we model
/// the row with those published factors (no HT microarchitecture model).
const HT_WALL_FACTOR: f64 = 0.81;
const HT_POWER_FACTOR: f64 = 1.10;

pub fn model_row(procs: u32, interconnect: &str, sim_s: f64) -> Result<RunResult> {
    let net = paper_networks()[0].1.clone();
    modeled(net, "westmere", interconnect, procs, sim_s)
}

pub fn run(fast: bool) -> Result<String> {
    let sim_s = sim_seconds(fast);
    let scale = 10.0 / sim_s;
    let mut table = Table::new(
        "Table II — x86 time/power/energy (modeled vs paper, 20480N, 10 s sim)",
        &[
            "x86 cores", "time (s)", "paper", "power (W)", "paper",
            "energy (J)", "paper",
        ],
    );
    for &(label, procs, ic, pt, pp, pe) in PAPER_ROWS {
        let (wall, power) = if procs == 0 {
            let one = model_row(1, ic, sim_s)?;
            (
                one.wall_s * scale * HT_WALL_FACTOR,
                one.energy.unwrap().power_w * HT_POWER_FACTOR,
            )
        } else {
            let r = model_row(procs, ic, sim_s)?;
            (r.wall_s * scale, r.energy.unwrap().power_w)
        };
        let energy = wall * power;
        table.row(vec![
            label.to_string(),
            format!("{wall:.1}"),
            format!("{pt:.1}"),
            format!("{power:.0}"),
            format!("{pp:.0}"),
            format!("{energy:.0}"),
            format!("{pe:.1}"),
        ]);
    }
    let out = table.render();
    table.write_csv(&results_dir().join("table2.csv"))?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_energy_minimum_at_8_and_ib_beats_eth() {
        let sim_s = 1.0;
        let e = |p: u32, ic: &str| {
            let r = model_row(p, ic, sim_s).unwrap();
            r.wall_s * 10.0 * r.energy.unwrap().power_w
        };
        let e4 = e(4, "ib");
        let e8 = e(8, "ib");
        let e64ib = e(64, "ib");
        let e64eth = e(64, "eth1g");
        let e32ib = e(32, "ib");
        let e32eth = e(32, "eth1g");
        // minimum in the 4-16 region, far below the 64-core rows
        assert!(e8 < e64ib && e8 < e32eth, "e8={e8} e64ib={e64ib}");
        assert!(e8 < 1.5 * e4, "e8={e8} e4={e4}");
        // IB beats ETH in energy at both multi-node points
        assert!(e32ib < e32eth, "32: ib {e32ib} vs eth {e32eth}");
        assert!(e64ib < e64eth, "64: ib {e64ib} vs eth {e64eth}");
    }

    #[test]
    fn ht_row_between_one_and_two_cores() {
        let sim_s = 1.0;
        let w1 = model_row(1, "ib", sim_s).unwrap().wall_s;
        let w2 = model_row(2, "ib", sim_s).unwrap().wall_s;
        let ht = w1 * HT_WALL_FACTOR;
        assert!(ht < w1 && ht > w2, "w2={w2} ht={ht} w1={w1}");
    }
}
