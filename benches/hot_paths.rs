//! Micro-benchmarks of the per-step hot paths (in-tree harness,
//! `dpsnn::util::bench`; criterion is unavailable offline).
//!
//! Run: `cargo bench --offline` (or `cargo bench -- fast` for a quick pass).

use dpsnn::comm::aer::{decode_spikes, encode_spikes};
use dpsnn::config::NetworkParams;
use dpsnn::engine::delay_queue::DelayRing;
use dpsnn::engine::spike::Spike;
use dpsnn::model::connectivity::{ConnectivityParams, IncomingSynapses};
use dpsnn::model::neuron::{step_native, StepParams};
use dpsnn::model::poisson::ExternalStimulus;
use dpsnn::util::bench::{black_box, Bench};
use dpsnn::util::rng::SplitMix64;

fn main() {
    let fast = std::env::args().any(|a| a == "fast" || a == "--fast");
    let mut b = if fast { Bench::fast() } else { Bench::new() };
    println!("== hot paths ==");

    neuron_update(&mut b);
    synaptic_delivery(&mut b);
    poisson_fill(&mut b);
    aer_codec(&mut b);
    delay_ring(&mut b);
    connectivity_build(&mut b);
    modeled_replay(&mut b);
}

/// L3-native LIF+SFA update — must sustain >> real-time per core.
fn neuron_update(b: &mut Bench) {
    for n in [2_560usize, 20_480] {
        let params = StepParams::from_network(&NetworkParams::paper_20480());
        let mut rng = SplitMix64::new(1);
        let mut v: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 * 19.0).collect();
        let mut w = vec![0.1f32; n];
        let mut rf = vec![0.0f32; n];
        let i_syn: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 * 2.0).collect();
        let i_ext = vec![1.0f32; n];
        let sfa = vec![0.12f32; n];
        let mut spiked = Vec::with_capacity(n);
        b.bench_elems(&format!("neuron_update n={n}"), n as f64, || {
            spiked.clear();
            step_native(&params, &mut v, &mut w, &mut rf, &i_syn, &i_ext, &sfa, &mut spiked)
        });
    }
}

/// Synaptic event delivery through CSR rows into the delay ring —
/// the paper's dominant computation component.
fn synaptic_delivery(b: &mut Bench) {
    let n = 20_480u32;
    let net = NetworkParams::paper_20480();
    let cp = ConnectivityParams::from_network(&net, 7);
    let inc = IncomingSynapses::build(&cp, 0, n);
    let mut ring = DelayRing::new(n as usize, net.delay_max_steps);
    // one step's worth of spikes at 3.2 Hz
    let mut rng = SplitMix64::new(3);
    let spikes: Vec<u32> = (0..66).map(|_| rng.next_below(n)).collect();
    let events: usize = spikes.iter().map(|&s| inc.row(s).0.len()).sum();
    b.bench_elems(
        &format!("deliver {} spikes -> {events} syn events", spikes.len()),
        events as f64,
        || {
            for &s in &spikes {
                let (tgts, delays) = inc.row(s);
                for (&t, &d) in tgts.iter().zip(delays) {
                    ring.add(d, t, 0.4);
                }
            }
            ring.advance();
        },
    );
}

fn poisson_fill(b: &mut Bench) {
    let net = NetworkParams::paper_20480();
    let stim = ExternalStimulus::new(&net, 5);
    let mut buf = vec![0.0f32; 20_480];
    let mut step = 0u32;
    b.bench_elems("poisson_fill n=20480 (lambda 1.2)", 20_480.0, || {
        step = step.wrapping_add(1);
        stim.fill(step, 0, &mut buf);
    });
}

fn aer_codec(b: &mut Bench) {
    let spikes: Vec<Spike> = (0..1000).map(|i| Spike::new(i * 13, i)).collect();
    let mut wire = Vec::new();
    b.bench_elems("aer_encode 1000 spikes", 1000.0, || {
        wire.clear();
        encode_spikes(&spikes, 1.0, &mut wire);
    });
    let mut out = Vec::new();
    b.bench_elems("aer_decode 1000 spikes", 1000.0, || {
        out.clear();
        decode_spikes(&wire, 1.0, &mut out).unwrap()
    });
}

fn delay_ring(b: &mut Bench) {
    let mut ring = DelayRing::new(20_480, 16);
    let mut rng = SplitMix64::new(9);
    let adds: Vec<(u8, u32)> = (0..10_000)
        .map(|_| (1 + rng.next_below(16) as u8, rng.next_below(20_480)))
        .collect();
    b.bench_elems("delay_ring 10k adds + advance", 10_000.0, || {
        for &(d, t) in &adds {
            ring.add(d, t, 0.25);
        }
        ring.advance();
    });
}

/// One-off cost amortized per run: partition-aware connectivity build.
fn connectivity_build(b: &mut Bench) {
    let net = NetworkParams::paper_20480();
    let cp = ConnectivityParams::from_network(&net, 11);
    b.bench_elems(
        "connectivity_build 20480x1125 (1 rank of 8)",
        net.total_synapses() as f64,
        || black_box(IncomingSynapses::build(&cp, 0, 2560).n_synapses()),
    );
}

/// The modeled-mode replay engine itself (harnesses sweep it heavily).
fn modeled_replay(b: &mut Bench) {
    use dpsnn::platform::hetero::HeteroCluster;
    use dpsnn::platform::presets::XEON_E5_2630V2;
    use dpsnn::simnet::alltoall_model::AllToAllModel;
    use dpsnn::simnet::presets::IB;
    use dpsnn::timing::replay::ModelRun;
    use dpsnn::trace::analytic::AnalyticWorkload;

    let trace = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 3)
        .generate(32, 1.0);
    let run = ModelRun::new(
        HeteroCluster::homogeneous(XEON_E5_2630V2, 32, 12),
        AllToAllModel::new(IB, 12),
    );
    b.bench_elems("modeled_replay 1000 steps x 32 ranks", 32_000.0, || {
        black_box(run.replay(&trace).wall_s)
    });
}
