//! Micro-benchmarks of the per-step hot paths (in-tree harness,
//! `dpsnn::util::bench`; criterion is unavailable offline).
//!
//! The three compute kernels (neuron update, Poisson fill, synaptic
//! delivery) run through the shared `profiling::compute_bench` module —
//! the same kernels `dpsnn bench-smoke --compute-out` measures into
//! BENCH_compute.json — in both the scalar baseline and the SoA
//! production variants at 1/2/4 compute threads.
//!
//! Run: `cargo bench --offline` (or `cargo bench -- fast` for a quick pass).

use dpsnn::comm::aer::{decode_spikes, encode_spikes};
use dpsnn::config::NetworkParams;
use dpsnn::engine::delay_queue::DelayRing;
use dpsnn::engine::spike::Spike;
use dpsnn::model::connectivity::{ConnectivityParams, IncomingSynapses};
use dpsnn::profiling::run_compute_bench;
use dpsnn::util::bench::{black_box, Bench};
use dpsnn::util::rng::SplitMix64;

fn main() {
    let fast = std::env::args().any(|a| a == "fast" || a == "--fast");
    let mut b = if fast { Bench::fast() } else { Bench::new() };
    println!("== hot paths ==");

    compute_kernels(&mut b);
    aer_codec(&mut b);
    delay_ring(&mut b);
    connectivity_build(&mut b);
    modeled_replay(&mut b);
}

/// The compute engine's three kernels, scalar baseline vs SoA path.
fn compute_kernels(b: &mut Bench) {
    let report = run_compute_bench(b, 20_480, &[1, 2, 4]);
    for kind in ["neuron_update", "poisson_fill", "synaptic_delivery"] {
        if let Some(s) = report.speedup_vs_scalar(kind) {
            println!("  {kind}: best SoA path {s:.2}x over scalar baseline");
        }
    }
}

fn aer_codec(b: &mut Bench) {
    let spikes: Vec<Spike> = (0..1000).map(|i| Spike::new(i * 13, i)).collect();
    let mut wire = Vec::new();
    b.bench_elems("aer_encode 1000 spikes", 1000.0, || {
        wire.clear();
        encode_spikes(&spikes, 1.0, &mut wire);
    });
    let mut out = Vec::new();
    b.bench_elems("aer_decode 1000 spikes", 1000.0, || {
        out.clear();
        decode_spikes(&wire, 1.0, &mut out).unwrap()
    });
}

fn delay_ring(b: &mut Bench) {
    let mut ring = DelayRing::new(20_480, 16);
    let mut rng = SplitMix64::new(9);
    let adds: Vec<(u8, u32)> = (0..10_000)
        .map(|_| (1 + rng.next_below(16) as u8, rng.next_below(20_480)))
        .collect();
    b.bench_elems("delay_ring 10k adds + advance", 10_000.0, || {
        for &(d, t) in &adds {
            ring.add(d, t, 0.25);
        }
        ring.advance();
    });
}

/// One-off cost amortized per run: partition-aware connectivity build.
fn connectivity_build(b: &mut Bench) {
    let net = NetworkParams::paper_20480();
    let cp = ConnectivityParams::from_network(&net, 11);
    b.bench_elems(
        "connectivity_build 20480x1125 (1 rank of 8)",
        net.total_synapses() as f64,
        || black_box(IncomingSynapses::build(&cp, 0, 2560).n_synapses()),
    );
}

/// The modeled-mode replay engine itself (harnesses sweep it heavily).
fn modeled_replay(b: &mut Bench) {
    use dpsnn::platform::hetero::HeteroCluster;
    use dpsnn::platform::presets::XEON_E5_2630V2;
    use dpsnn::simnet::alltoall_model::AllToAllModel;
    use dpsnn::simnet::presets::IB;
    use dpsnn::timing::replay::ModelRun;
    use dpsnn::trace::analytic::AnalyticWorkload;

    let trace = AnalyticWorkload::paper_regime(NetworkParams::paper_20480(), 3)
        .generate(32, 1.0);
    let run = ModelRun::new(
        HeteroCluster::homogeneous(XEON_E5_2630V2, 32, 12),
        AllToAllModel::new(IB, 12),
    );
    b.bench_elems("modeled_replay 1000 steps x 32 ranks", 32_000.0, || {
        black_box(run.replay(&trace).wall_s)
    });
}
