//! End-to-end benches: one per paper table/figure family, exercising the
//! full live engine and the AOT/PJRT execution path.
//!
//! Run: `cargo bench --offline` (add `-- fast` for a quick pass).

use dpsnn::config::{Mode, NetworkParams, RunConfig};
use dpsnn::coordinator;
use dpsnn::util::bench::{black_box, Bench};

fn main() {
    let fast = std::env::args().any(|a| a == "fast" || a == "--fast");
    let mut b = if fast { Bench::fast() } else { Bench::new() };
    // end-to-end iterations are seconds-long; keep sample counts small
    b.measure = std::time::Duration::from_secs(if fast { 2 } else { 6 });

    println!("== end-to-end (live engine, this host) ==");
    live_scaling(&mut b, fast);
    println!("== xla artifact execution (L1/L2 via PJRT) ==");
    xla_exec(&mut b);
    println!("== harness regeneration (modeled pipeline) ==");
    harness_sweeps(&mut b);
}

/// Fig 2-family: live wall-clock per simulated second at several P.
fn live_scaling(b: &mut Bench, fast: bool) {
    let host = std::thread::available_parallelism().unwrap().get() as u32;
    let sim_s = if fast { 0.2 } else { 0.5 };
    for procs in [1u32, 2, 4, host.min(8)] {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::paper_20480();
        cfg.procs = procs;
        cfg.sim_seconds = sim_s;
        cfg.mode = Mode::Live;
        let steps = cfg.steps() as f64 * procs as f64;
        b.bench_elems(
            &format!("live 20480N P={procs} ({sim_s}s sim)"),
            steps,
            || black_box(coordinator::run(&cfg).unwrap().wall_s),
        );
    }
}

/// Table IV-family: the per-step cost of the AOT LIF+SFA artifact.
fn xla_exec(b: &mut Bench) {
    use dpsnn::model::population::PopulationSoA;
    use dpsnn::runtime::backend::XlaBackend;
    use dpsnn::runtime::NeuronBackend;

    if !std::path::Path::new("artifacts").exists() {
        println!("  (skipped: run `make artifacts`)");
        return;
    }
    for n in [2048u32, 20_480] {
        let net = NetworkParams::paper(n.max(4608)); // keep fan-out < n
        let pop = PopulationSoA::init(&net, 1, 0, n);
        let mut be = match XlaBackend::new(&net, pop, std::path::Path::new("artifacts")) {
            Ok(b) => b,
            Err(e) => {
                println!("  (xla backend unavailable: {e})");
                return;
            }
        };
        let i_syn = vec![0.5f32; n as usize];
        be.i_ext_mut().iter_mut().for_each(|x| *x = 1.0);
        let mut spiked = Vec::new();
        b.bench_elems(&format!("xla_step n={n}"), n as f64, || {
            spiked.clear();
            be.step(&i_syn, &mut spiked).unwrap()
        });
    }
}

/// Table I/II/III-family: the modeled pipeline that regenerates them.
fn harness_sweeps(b: &mut Bench) {
    let run = |platform: &str, ic: &str, procs: u32| {
        let mut cfg = RunConfig::default();
        cfg.net = NetworkParams::paper_20480();
        cfg.procs = procs;
        cfg.sim_seconds = 1.0;
        cfg.mode = Mode::Modeled;
        cfg.platform = platform.to_string();
        cfg.interconnect = ic.to_string();
        coordinator::run(&cfg).unwrap().wall_s
    };
    b.bench("modeled table2 row (westmere+ib, 32p, 1s)", || {
        black_box(run("westmere", "ib", 32))
    });
    b.bench("modeled table3 row (jetson+eth, 8p, 1s)", || {
        black_box(run("jetson", "eth1g", 8))
    });
}
