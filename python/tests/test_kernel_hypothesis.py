"""Hypothesis sweeps: the Pallas kernel must match the pure-jnp oracle for
arbitrary shapes, blocks, parameters and input regimes."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.lif_sfa import lif_sfa_step
from compile.kernels.ref import lif_sfa_step_ref
from compile.model import make_params

finite_f32 = st.floats(
    min_value=-1e3, max_value=1e3, allow_nan=False, allow_infinity=False, width=32
)


@st.composite
def step_case(draw):
    log2n = draw(st.integers(min_value=3, max_value=12))
    n = 1 << log2n
    block = 1 << draw(st.integers(min_value=3, max_value=log2n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    v = rng.uniform(-40, 30, n).astype(np.float32)
    w = rng.uniform(0, 10, n).astype(np.float32)
    rf = rng.integers(0, 4, n).astype(np.float32)
    i_syn = rng.normal(0, draw(st.floats(0.0, 50.0)), n).astype(np.float32)
    i_ext = rng.normal(draw(st.floats(-5.0, 5.0)), 1.0, n).astype(np.float32)
    sfa = np.where(rng.uniform(size=n) < 0.8, draw(st.floats(0.0, 2.0)), 0.0)
    tau_m = draw(st.floats(5.0, 50.0))
    tau_w = draw(st.floats(100.0, 1000.0))
    params = make_params(
        float(np.exp(-1.0 / tau_m)),
        float(np.exp(-1.0 / tau_w)),
        draw(st.floats(10.0, 30.0)),
        draw(st.floats(-5.0, 5.0)),
        float(draw(st.integers(0, 5))),
        draw(st.floats(-80.0, -30.0)),
    )
    state = tuple(
        jnp.asarray(a.astype(np.float32)) for a in (v, w, rf, i_syn, i_ext, sfa)
    )
    return params, state, block


@settings(max_examples=40, deadline=None)
@given(step_case())
def test_kernel_matches_ref_fuzzed(case):
    params, state, block = case
    got = lif_sfa_step(params, *state, block=block)
    want = lif_sfa_step_ref(params, *state)
    for g, w_, name in zip(got, want, ["v", "w", "rf", "spiked"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w_), rtol=1e-6, atol=1e-5, err_msg=name
        )


@settings(max_examples=20, deadline=None)
@given(step_case())
def test_spiked_is_binary_and_consistent(case):
    """Invariants: spiked ∈ {0,1}; spiking neurons sit at v_reset with the
    refractory clock armed; no neuron above threshold remains unspiked
    unless refractory."""
    params, state, block = case
    v2, w2, rf2, sp = (np.asarray(a) for a in lif_sfa_step(params, *state, block=block))
    theta, v_reset, t_ref = float(params[2]), float(params[3]), float(params[4])
    assert set(np.unique(sp)).issubset({0.0, 1.0})
    fired = sp == 1.0
    np.testing.assert_array_equal(v2[fired], v_reset)
    np.testing.assert_array_equal(rf2[fired], t_ref)
    # any neuron left >= theta must have been refractory on entry
    was_refractory = np.asarray(state[2]) > 0
    assert np.all(was_refractory[(v2 >= theta) & ~fired] | (v_reset >= theta))
