"""L1 kernel vs pure-jnp oracle, across shapes, regimes and edge cases."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.lif_sfa import lif_sfa_step, DEFAULT_BLOCK, N_PARAMS
from compile.kernels.ref import lif_sfa_step_ref, multi_step_ref
from compile.model import make_params, population_step

PARAMS = make_params(
    decay_v=float(np.exp(-1.0 / 20.0)),
    decay_w=float(np.exp(-1.0 / 500.0)),
    theta=20.0,
    v_reset=0.0,
    t_ref_steps=2.0,
    v_floor=-40.0,
)


def rand_state(n, seed, v_scale=10.0):
    rng = np.random.default_rng(seed)
    v = rng.uniform(-30.0, 25.0, n).astype(np.float32)
    w = rng.uniform(0.0, 5.0, n).astype(np.float32)
    rf = rng.integers(0, 3, n).astype(np.float32)
    i_syn = rng.normal(0.0, v_scale, n).astype(np.float32)
    i_ext = rng.normal(1.0, 2.0, n).astype(np.float32)
    sfa = np.where(rng.uniform(size=n) < 0.8, 0.3, 0.0).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (v, w, rf, i_syn, i_ext, sfa))


def assert_matches_ref(params, state, block=None):
    kwargs = {} if block is None else {"block": block}
    got = lif_sfa_step(params, *state, **kwargs)
    want = lif_sfa_step_ref(params, *state)
    for g, w_, name in zip(got, want, ["v", "w", "rf", "spiked"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w_), rtol=1e-6, atol=1e-6, err_msg=name
        )


@pytest.mark.parametrize("n", [8, 64, 256, 1024, 4096, 8192, 16384])
def test_kernel_matches_ref_sizes(n):
    assert_matches_ref(PARAMS, rand_state(n, n), block=min(n, DEFAULT_BLOCK))


@pytest.mark.parametrize("block", [8, 128, 2048, 8192])
def test_kernel_block_invariance(block):
    n = 8192
    state = rand_state(n, 7)
    a = lif_sfa_step(PARAMS, *state, block=block)
    b = lif_sfa_step_ref(PARAMS, *state)
    for g, w_ in zip(a, b):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


def test_block_must_divide_population():
    state = rand_state(100, 3)
    with pytest.raises(ValueError):
        lif_sfa_step(PARAMS, *state, block=64)


def test_refractory_neurons_do_not_spike():
    n = 256
    v = jnp.full((n,), 0.0, jnp.float32)
    w = jnp.zeros((n,), jnp.float32)
    rf = jnp.full((n,), 2.0, jnp.float32)   # all refractory
    i = jnp.full((n,), 100.0, jnp.float32)  # huge input
    z = jnp.zeros((n,), jnp.float32)
    v2, w2, rf2, sp = lif_sfa_step(PARAMS, v, w, rf, i, z, z, block=n)
    assert float(jnp.sum(sp)) == 0.0
    np.testing.assert_array_equal(np.asarray(v2), 0.0)   # pinned at reset
    np.testing.assert_array_equal(np.asarray(rf2), 1.0)  # counted down


def test_spike_resets_and_sets_refractory():
    n = 8
    v = jnp.full((n,), 19.0, jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    i = jnp.full((n,), 5.0, jnp.float32)
    sfa = jnp.full((n,), 0.3, jnp.float32)
    v2, w2, rf2, sp = lif_sfa_step(PARAMS, v, z, z, i, z, sfa, block=n)
    np.testing.assert_array_equal(np.asarray(sp), 1.0)
    np.testing.assert_array_equal(np.asarray(v2), 0.0)
    np.testing.assert_array_equal(np.asarray(rf2), 2.0)
    np.testing.assert_allclose(np.asarray(w2), 0.3, rtol=1e-6)


def test_sfa_accumulates_and_suppresses():
    """Repeated firing grows w, which lowers the effective drive (fatigue)."""
    n = 4
    params = PARAMS
    v = jnp.zeros((n,), jnp.float32)
    w = jnp.zeros((n,), jnp.float32)
    rf = jnp.zeros((n,), jnp.float32)
    sfa = jnp.full((n,), 1.0, jnp.float32)
    i = jnp.full((n,), 25.0, jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    w_hist = []
    for _ in range(10):
        v, w, rf, sp = lif_sfa_step(params, v, w, rf, i, z, sfa, block=n)
        w_hist.append(float(w[0]))
    # w decays slightly during refractory steps but ratchets up with every
    # spike: the trajectory must trend strongly upward overall.
    assert w_hist[-1] > w_hist[0]
    assert w_hist[-1] > 2.0


def test_inhibitory_neurons_have_no_sfa():
    n = 8
    v = jnp.full((n,), 25.0, jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    sfa = jnp.zeros((n,), jnp.float32)  # inhibitory: SFA off
    v2, w2, rf2, sp = lif_sfa_step(PARAMS, v, z, z, z, z, sfa, block=n)
    np.testing.assert_array_equal(np.asarray(sp), 1.0)
    np.testing.assert_array_equal(np.asarray(w2), 0.0)


def test_v_floor_clamps():
    n = 8
    v = jnp.zeros((n,), jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    i = jnp.full((n,), -500.0, jnp.float32)
    v2, *_ = lif_sfa_step(PARAMS, v, z, z, i, z, z, block=n)
    np.testing.assert_array_equal(np.asarray(v2), -40.0)


def test_multi_step_trajectory_matches_ref():
    n = 512
    rng = np.random.default_rng(11)
    v, w, rf, i_syn, i_ext, sfa = rand_state(n, 5)
    state_k = (v, w, rf)
    state_r = (v, w, rf, sfa)
    inputs = [
        (jnp.asarray(rng.normal(0, 8, n).astype(np.float32)),
         jnp.asarray(rng.normal(1, 2, n).astype(np.float32)))
        for _ in range(20)
    ]
    (_, _, _, _), rasters_ref = multi_step_ref(PARAMS, state_r, inputs)
    vk, wk, rfk = state_k
    for t, (a, b) in enumerate(inputs):
        vk, wk, rfk, sp = lif_sfa_step(PARAMS, vk, wk, rfk, a, b, sfa, block=n)
        np.testing.assert_array_equal(np.asarray(sp), np.asarray(rasters_ref[t]),
                                      err_msg=f"step {t}")


def test_params_vector_abi():
    assert N_PARAMS == 8
    p = make_params(0.9, 0.99, 20.0, 0.0, 2.0, -40.0)
    assert p.shape == (N_PARAMS,)
    assert p.dtype == jnp.float32
