"""L2 model tests: block selection, packed ABI, lowering shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    lower_population_step_packed,
    make_params,
    pick_block,
    population_step,
    population_step_packed,
)
from compile.kernels.ref import lif_sfa_step_ref

PARAMS = make_params(0.95, 0.998, 20.0, 0.0, 2.0, -40.0)


def rand_args(n, seed=0):
    rng = np.random.default_rng(seed)
    f = lambda scale: jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
    return f(10.0), f(3.0), jnp.zeros((n,), jnp.float32), f(5.0), f(2.0), jnp.full(
        (n,), 0.3, jnp.float32
    )


class TestPickBlock:
    def test_exact_power_of_two(self):
        assert pick_block(8192) == 8192
        assert pick_block(16384) == 8192

    def test_non_divisible_sizes_fall_back(self):
        # 20480 = 5 * 4096
        assert pick_block(20480) == 4096
        assert 20480 % pick_block(20480) == 0

    def test_odd_sizes(self):
        for n in [3, 7, 100, 12_345]:
            b = pick_block(n)
            assert n % b == 0, f"n={n} block={b}"

    def test_small_sizes(self):
        assert pick_block(1) == 1
        assert pick_block(2) == 2


@pytest.mark.parametrize("n", [64, 20480 // 8, 20480])
def test_packed_equals_unpacked(n):
    v, w, rf, i_syn, i_ext, sfa = rand_args(n, n)
    plain = population_step(PARAMS, v, w, rf, i_syn, i_ext, sfa)
    state = jnp.concatenate([v, w, rf])
    packed = population_step_packed(PARAMS, state, i_syn, i_ext, sfa)
    np.testing.assert_array_equal(
        np.asarray(packed), np.concatenate([np.asarray(x) for x in plain])
    )


def test_packed_matches_ref_oracle():
    n = 512
    v, w, rf, i_syn, i_ext, sfa = rand_args(n, 3)
    want = lif_sfa_step_ref(PARAMS, v, w, rf, i_syn, i_ext, sfa)
    state = jnp.concatenate([v, w, rf])
    got = population_step_packed(PARAMS, state, i_syn, i_ext, sfa)
    for i, w_ in enumerate(want):
        np.testing.assert_allclose(
            np.asarray(got[i * n:(i + 1) * n]),
            np.asarray(w_),
            rtol=1e-6,
            atol=1e-6,
        )


def test_lowered_abi_shapes():
    n = 256
    lowered = lower_population_step_packed(n)
    text = lowered.as_text()  # StableHLO: tensor<NxF32> shapes
    assert "tensor<8xf32>" in text           # params
    assert f"tensor<{3 * n}xf32>" in text    # state
    assert f"tensor<{4 * n}xf32>" in text    # packed output


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=3, max_value=11), st.integers(0, 2**31 - 1))
def test_packed_abi_fuzz(log2n, seed):
    n = 1 << log2n
    v, w, rf, i_syn, i_ext, sfa = rand_args(n, seed)
    state = jnp.concatenate([v, w, rf])
    packed = population_step_packed(PARAMS, state, i_syn, i_ext, sfa)
    assert packed.shape == (4 * n,)
    sp = np.asarray(packed[3 * n:])
    assert set(np.unique(sp)).issubset({0.0, 1.0})


def test_multi_step_packed_trajectory():
    """Iterating the packed step (as the rust runtime does) must follow
    the oracle trajectory exactly."""
    n = 256
    v, w, rf, _, _, sfa = rand_args(n, 9)
    rng = np.random.default_rng(4)
    state = jnp.concatenate([v, w, rf])
    vr, wr, rfr = v, w, rf
    for t in range(10):
        i_syn = jnp.asarray(rng.normal(0, 8, n).astype(np.float32))
        i_ext = jnp.asarray(rng.normal(1, 2, n).astype(np.float32))
        out = population_step_packed(PARAMS, state, i_syn, i_ext, sfa)
        state = out[: 3 * n]
        vr, wr, rfr, spr = lif_sfa_step_ref(PARAMS, vr, wr, rfr, i_syn, i_ext, sfa)
        np.testing.assert_array_equal(np.asarray(out[3 * n:]), np.asarray(spr),
                                      err_msg=f"step {t}")
    np.testing.assert_array_equal(np.asarray(state[:n]), np.asarray(vr))
