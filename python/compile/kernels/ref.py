"""Pure-jnp oracle for the LIF+SFA step kernel.

The same arithmetic as kernels/lif_sfa.py written without Pallas; used by
pytest to validate the kernel and by aot.py sanity checks.
"""

import jax
import jax.numpy as jnp


@jax.jit
def lif_sfa_step_ref(params, v, w, rf, i_syn, i_ext, sfa_inc):
    decay_v, decay_w, theta, v_reset, t_ref, v_floor = (
        params[0], params[1], params[2], params[3], params[4], params[5],
    )
    i = i_syn + i_ext
    active = rf <= 0.0
    v_int = v * decay_v + i - w
    v_int = jnp.maximum(v_int, v_floor)
    v_new = jnp.where(active, v_int, v_reset)
    spiked = active & (v_new >= theta)
    v_out = jnp.where(spiked, v_reset, v_new)
    w_out = w * decay_w + jnp.where(spiked, sfa_inc, 0.0)
    rf_out = jnp.where(spiked, t_ref, jnp.maximum(rf - 1.0, 0.0))
    return v_out, w_out, rf_out, spiked.astype(jnp.float32)


def multi_step_ref(params, state, inputs):
    """Run several steps; `inputs` is a list of (i_syn, i_ext) pairs.

    Returns the final state and the list of spike rasters.
    """
    v, w, rf, sfa_inc = state
    rasters = []
    for i_syn, i_ext in inputs:
        v, w, rf, sp = lif_sfa_step_ref(params, v, w, rf, i_syn, i_ext, sfa_inc)
        rasters.append(sp)
    return (v, w, rf, sfa_inc), rasters
